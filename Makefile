# Convenience targets for the ESACT reproduction.

ARTIFACTS := rust/artifacts

.PHONY: build test bench bench-serving bench-decode bench-forward bench-gateway bench-paged bench-gate serve-http check-features artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench kernel_micro

# The serving latency-vs-load-vs-replicas surface + BENCH_2.json report.
# (absolute path: cargo runs the bench with cwd = rust/)
bench-serving:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_2.json cargo bench --bench serving

# Decode tokens/sec vs prefix vs KV budget + BENCH_3.json report.
bench-decode:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_3.json cargo bench --bench decode

# Packed-vs-unpacked prefill throughput + BENCH_4.json report.
bench-forward:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_4.json cargo bench --bench forward

# HTTP gateway throughput/ttft over loopback + BENCH_5.json report.
bench-gateway:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_5.json cargo bench --bench gateway

# Paged-KV scaling/sharing/CoW surface + BENCH_6.json report.
bench-paged:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_6.json cargo bench --bench paged

# What CI's bench-regression job runs after the benches (the gate's
# own self-test first, so a broken gate can't silently pass).
bench-gate: bench-serving bench-decode bench-forward bench-gateway bench-paged
	python3 scripts/test_bench_gate.py
	python3 scripts/bench_gate.py BENCH_2.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_3.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_4.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_5.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_6.json bench_baseline.json

# Start a curl-able tiny gateway (SPLS mode, 2 replicas) on :8080.
# Drain it with: curl -X POST localhost:8080/admin/shutdown
serve-http:
	cargo run --release --example serve_tiny -- 64 2 http

# What CI's feature-matrix job runs.
check-features:
	cargo check --workspace --no-default-features
	cargo check --workspace --features pjrt

# Retrain the tiny substrate and export weights + test set for the rust
# harness (the checked-in artifacts were produced exactly this way).
artifacts:
	cd python && python3 -m compile.train_tiny --out-dir ../$(ARTIFACTS)

clean-artifacts:
	rm -f $(ARTIFACTS)/tiny_weights.bin $(ARTIFACTS)/tiny_testset.bin $(ARTIFACTS)/tiny_meta.txt
