# Convenience targets for the ESACT reproduction.

ARTIFACTS := rust/artifacts

.PHONY: build test bench bench-serving bench-decode bench-forward bench-gateway bench-paged bench-gate serve-http check-features chaos artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench kernel_micro

# The serving latency-vs-load-vs-replicas surface + BENCH_2.json report.
# (absolute path: cargo runs the bench with cwd = rust/)
bench-serving:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_2.json cargo bench --bench serving

# Decode tokens/sec vs prefix vs KV budget + BENCH_3.json report.
bench-decode:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_3.json cargo bench --bench decode

# Packed-vs-unpacked prefill throughput + BENCH_4.json report.
bench-forward:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_4.json cargo bench --bench forward

# HTTP gateway throughput/ttft over loopback + BENCH_5.json report.
bench-gateway:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_5.json cargo bench --bench gateway

# Paged-KV scaling/sharing/CoW surface + BENCH_6.json report.
bench-paged:
	ESACT_BENCH_JSON=$(CURDIR)/BENCH_6.json cargo bench --bench paged

# What CI's bench-regression job runs after the benches (the gate's
# own self-test first, so a broken gate can't silently pass).
bench-gate: bench-serving bench-decode bench-forward bench-gateway bench-paged
	python3 scripts/test_bench_gate.py
	python3 scripts/bench_gate.py BENCH_2.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_3.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_4.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_5.json bench_baseline.json
	python3 scripts/bench_gate.py BENCH_6.json bench_baseline.json

# Start a curl-able tiny gateway (SPLS mode, 2 replicas) on :8080.
# Drain it with: curl -X POST localhost:8080/admin/shutdown
serve-http:
	cargo run --release --example serve_tiny -- 64 2 http

# What CI's chaos-smoke job runs: a gateway with the deterministic
# fault injector armed (every 23rd replica job panics its worker),
# probed by a 64-request chaos burst. The tier must stay up, answer
# every request (200 or a typed 500 `replica_fault` envelope), show
# nonzero respawns on /metrics, and drain cleanly.
chaos: build
	ESACT_FAULT_SEED=7 ESACT_FAULT_EVERY=23 \
		./target/release/esact serve 2 --http 127.0.0.1:8843 --max-conns 512 & \
	sleep 1; \
	./target/release/esact http-check 127.0.0.1:8843 --chaos 64 --shutdown

# What CI's feature-matrix job runs.
check-features:
	cargo check --workspace --no-default-features
	cargo check --workspace --features pjrt

# Retrain the tiny substrate and export weights + test set for the rust
# harness (the checked-in artifacts were produced exactly this way).
artifacts:
	cd python && python3 -m compile.train_tiny --out-dir ../$(ARTIFACTS)

clean-artifacts:
	rm -f $(ARTIFACTS)/tiny_weights.bin $(ARTIFACTS)/tiny_testset.bin $(ARTIFACTS)/tiny_meta.txt
