# Convenience targets for the ESACT reproduction.

ARTIFACTS := rust/artifacts

.PHONY: build test bench artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench kernel_micro

# Retrain the tiny substrate and export weights + test set for the rust
# harness (the checked-in artifacts were produced exactly this way).
artifacts:
	cd python && python3 -m compile.train_tiny --out-dir ../$(ARTIFACTS)

clean-artifacts:
	rm -f $(ARTIFACTS)/tiny_weights.bin $(ARTIFACTS)/tiny_testset.bin $(ARTIFACTS)/tiny_meta.txt
