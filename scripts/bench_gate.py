#!/usr/bin/env python3
"""CI bench-regression gate.

Compares the serving bench's BENCH_2.json against the committed
bench_baseline.json and fails (exit 1) when:

  * throughput of any matching (mode, replicas) saturated cell regresses
    more than 15% below the baseline floor, or
  * the report is missing required fields (schema rot), or
  * 4-replica SPLS saturated throughput falls below 1-replica (scaling
    inversion — the serving tier's reason to exist).

Baseline refresh: run `ESACT_BENCH_JSON=BENCH_2.json cargo bench --bench
serving` on a quiet machine and copy BENCH_2.json over
bench_baseline.json (keep the floors conservative: CI runners are
noisy, and the gate only ever compares *against* the committed floor).

Usage: bench_gate.py BENCH_2.json bench_baseline.json
"""

import json
import sys

TOLERANCE = 0.85  # fail below 85% of the baseline floor


def die(msg: str) -> None:
    print(f"bench gate: FAIL — {msg}")
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 3:
        die(f"usage: {sys.argv[0]} CURRENT.json BASELINE.json")
    with open(sys.argv[1]) as f:
        cur = json.load(f)
    with open(sys.argv[2]) as f:
        base = json.load(f)

    for key in ("schema", "executor", "saturated", "poisson"):
        if key not in cur:
            die(f"current report missing '{key}'")
    for row in cur["saturated"] + cur["poisson"]:
        for field in (
            "mode",
            "replicas",
            "throughput_rps",
            "throughput_per_replica",
            "p50_ms",
            "p99_ms",
            "plan_cache_hit_rate",
        ):
            if field not in row:
                die(f"report row missing '{field}': {row}")

    current = {(r["mode"], r["replicas"]): r for r in cur["saturated"]}
    failures = []
    print(f"{'cell':<14} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in base.get("saturated", []):
        key = (b["mode"], b["replicas"])
        c = current.get(key)
        if c is None:
            failures.append(f"saturated cell {key} missing from current report")
            continue
        floor = TOLERANCE * b["throughput_rps"]
        ok = c["throughput_rps"] >= floor
        print(
            f"{b['mode']:<8} x{b['replicas']:<4} {b['throughput_rps']:>10.1f} "
            f"{c['throughput_rps']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{key}: {c['throughput_rps']:.1f} rps < floor {floor:.1f} "
                f"(baseline {b['throughput_rps']:.1f})"
            )

    spls = {r["replicas"]: r for r in cur["saturated"] if r["mode"] == "Spls"}
    if 1 in spls and 4 in spls:
        t1, t4 = spls[1]["throughput_rps"], spls[4]["throughput_rps"]
        trend = " → ".join(
            f"{spls[r]['throughput_rps']:.1f}" for r in sorted(spls)
        )
        print(f"SPLS saturated scaling: {trend} rps (1 → {sorted(spls)[-1]} replicas)")
        # single 64-request samples on oversubscribed shared runners are
        # noisy (one SPLS replica already parallelizes internally): fail
        # only on a clear inversion, warn otherwise
        if t4 < 0.75 * t1:
            failures.append(f"scaling inversion: 4 replicas {t4:.1f} < 1 replica {t1:.1f}")
        elif t4 < t1:
            print(f"  ! warning: t4 {t4:.1f} < t1 {t1:.1f} (within noise tolerance)")
    else:
        failures.append("report lacks SPLS saturated cells for replicas 1 and 4")

    if failures:
        for f in failures:
            print(f"  ✗ {f}")
        die(f"{len(failures)} regression check(s) failed")
    print("bench gate: OK")


if __name__ == "__main__":
    main()
