#!/usr/bin/env python3
"""CI bench-regression gate.

Dispatches on the current report's `schema`:

* schema 2 — the serving bench's BENCH_2.json: per-(mode, replicas)
  saturated-throughput floors plus the 1→4-replica SPLS scaling
  inversion check.
* schema 3 — the decode bench's BENCH_3.json: per-(mode, prefix,
  kv_budget) tokens/sec floors plus the headline evict-vs-dense check
  (evicting-cache decode must not lose to dense-cache decode at
  prefix ≥ 64 — warn below 1.0×, fail below 0.85×, mirroring the
  serving gate's noise tolerance on shared runners).
* schema 4 — the forward bench's BENCH_4.json: per-(path, seq_len)
  packed-engine tokens/sec floors plus the headline
  packed-must-beat-unpacked inversion check at seq_len ≥ 64 (target
  1.5×; fail below 1.15× to absorb runner noise, warn below 1.5×;
  warn-only when the runner has a single core, since the packed
  engine's row-parallel kernels have nothing to fan out over there),
  plus the sparse-vs-dense crossover check: at every operating point
  whose measured keep-density is at or below the baseline's
  `crossover.keep_density_threshold`, the compiled CSR/gather sparse
  forward must beat packed dense (fail below 0.85×, warn below 1.0×,
  single-core warn-only — same noise policy as the other headlines).
* schema 5 — the HTTP gateway bench's BENCH_5.json: per-(replicas,
  connections) closed-loop throughput floors, a connection-scaling
  inversion check (8 connections must not collapse below 75% of 1
  connection at the largest replica count), a streaming
  time-to-first-token ceiling + tokens/sec floor, and the
  machine-speed-independent structural check that ttft is well below
  the whole stream's wall time (a gateway that buffers the stream
  fails it on any hardware). The event-loop gateway adds two groups:
  `conn_sweep` — per-idle-herd-size throughput floors over {64, 256,
  1024} parked connections, a herd-scaling inversion check (the
  largest herd must not collapse below 75% of the smallest), and a
  marginal per-idle-connection memory cap — and `slow_loris` — every
  half-open connection must be reaped on the idle timer (structural,
  machine-independent) while active traffic holds its throughput
  floor. The observability PR adds a `tracing` cell: the same
  closed-loop run with 1-in-1 span tracing + histogram observation vs
  tracing disabled must not cost more than the baseline's
  `overhead_frac_max` of throughput (a same-machine, same-moment
  ratio, so no cross-runner noise).
* schema 6 — the paged-KV bench's BENCH_6.json: per-session-count
  aggregate tokens/sec floors at a fixed pool size, the headline
  aggregate-throughput-rises-with-sessions check (prefix sharing
  amortizes prefill, so 8 and 32 sessions must not fall below the
  smaller cell — fail below 0.9x the previous cell, warn below 1.0x),
  a prefix-sharing hit-rate floor at the largest session count, and
  three structural (machine-speed independent) checks: the first
  divergent append after an attach must copy-on-write at least one
  block, a shared-prefix run must peak at strictly fewer blocks than
  the same wave with private per-session prefixes, and every cell's
  peak must fit the declared pool. The report's pool size must equal
  the baseline's — floors at different pool memory don't compare.

All compare against the same committed bench_baseline.json; the cell
groups each schema reads are declared in BASELINE_GROUPS and validated
up front — a baseline that lost a group (or doesn't list the report's
schema under its "schemas" field) fails loudly instead of letting the
gate silently pass with nothing to compare against.

Baseline refresh: run the matching bench with ESACT_BENCH_JSON set on a
quiet machine and copy the cells over, scaled down ~2x for CI headroom
(the gate only ever compares *against* the committed floor).

Usage: bench_gate.py CURRENT.json BASELINE.json
"""

import json
import sys

TOLERANCE = 0.85  # fail below 85% of the baseline floor

# Baseline cell groups each report schema gates against. Validated
# before dispatch: every listed group must be present in the committed
# baseline, or the gate dies — `base.get(group, [])` fallbacks in the
# per-schema checks exist only for row-level shape, never as license
# for an absent group.
BASELINE_GROUPS = {
    2: ("saturated",),
    3: ("decode",),
    4: ("forward", "crossover"),
    5: ("gateway", "streaming", "conn_sweep", "slow_loris", "fault", "tracing"),
    6: ("paged",),
}


def die(msg: str) -> None:
    print(f"bench gate: FAIL — {msg}")
    sys.exit(1)


def check_serving(cur: dict, base: dict) -> list:
    failures = []
    for key in ("executor", "saturated", "poisson"):
        if key not in cur:
            die(f"current report missing '{key}'")
    for row in cur["saturated"] + cur["poisson"]:
        for field in (
            "mode",
            "replicas",
            "throughput_rps",
            "throughput_per_replica",
            "p50_ms",
            "p99_ms",
            "plan_cache_hit_rate",
        ):
            if field not in row:
                die(f"report row missing '{field}': {row}")

    current = {(r["mode"], r["replicas"]): r for r in cur["saturated"]}
    print(f"{'cell':<14} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in base.get("saturated", []):
        key = (b["mode"], b["replicas"])
        c = current.get(key)
        if c is None:
            failures.append(f"saturated cell {key} missing from current report")
            continue
        floor = TOLERANCE * b["throughput_rps"]
        ok = c["throughput_rps"] >= floor
        print(
            f"{b['mode']:<8} x{b['replicas']:<4} {b['throughput_rps']:>10.1f} "
            f"{c['throughput_rps']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{key}: {c['throughput_rps']:.1f} rps < floor {floor:.1f} "
                f"(baseline {b['throughput_rps']:.1f})"
            )

    spls = {r["replicas"]: r for r in cur["saturated"] if r["mode"] == "Spls"}
    if 1 in spls and 4 in spls:
        t1, t4 = spls[1]["throughput_rps"], spls[4]["throughput_rps"]
        trend = " → ".join(
            f"{spls[r]['throughput_rps']:.1f}" for r in sorted(spls)
        )
        print(f"SPLS saturated scaling: {trend} rps (1 → {sorted(spls)[-1]} replicas)")
        # single 64-request samples on oversubscribed shared runners are
        # noisy (one SPLS replica already parallelizes internally): fail
        # only on a clear inversion, warn otherwise
        if t4 < 0.75 * t1:
            failures.append(f"scaling inversion: 4 replicas {t4:.1f} < 1 replica {t1:.1f}")
        elif t4 < t1:
            print(f"  ! warning: t4 {t4:.1f} < t1 {t1:.1f} (within noise tolerance)")
    else:
        failures.append("report lacks SPLS saturated cells for replicas 1 and 4")
    return failures


def check_decode(cur: dict, base: dict) -> list:
    failures = []
    for key in ("decode", "budget_sweep", "evict_vs_dense", "plan_replay"):
        if key not in cur:
            die(f"current report missing '{key}'")
    for row in cur["decode"] + cur["budget_sweep"]:
        for field in ("mode", "prefix", "kv_budget", "tokens_per_sec", "ms_per_token"):
            if field not in row:
                die(f"report row missing '{field}': {row}")
    for row in cur["evict_vs_dense"]:
        for field in ("prefix", "dense_tps", "evict_tps", "speedup"):
            if field not in row:
                die(f"evict_vs_dense row missing '{field}': {row}")
    for field in ("cold_tps", "warm_tps", "step_hit_rate"):
        if field not in cur["plan_replay"]:
            die(f"plan_replay missing '{field}': {cur['plan_replay']}")

    current = {(r["mode"], r["prefix"], r["kv_budget"]): r for r in cur["decode"]}
    print(f"{'cell':<22} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in base.get("decode", []):
        key = (b["mode"], b["prefix"], b["kv_budget"])
        c = current.get(key)
        if c is None:
            failures.append(f"decode cell {key} missing from current report")
            continue
        floor = TOLERANCE * b["tokens_per_sec"]
        ok = c["tokens_per_sec"] >= floor
        label = f"{b['mode']} p{b['prefix']} b{b['kv_budget']}"
        print(
            f"{label:<22} {b['tokens_per_sec']:>10.1f} "
            f"{c['tokens_per_sec']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{key}: {c['tokens_per_sec']:.1f} tok/s < floor {floor:.1f} "
                f"(baseline {b['tokens_per_sec']:.1f})"
            )

    # headline: evicting cache must beat dense cache at prefix >= 64
    checked = False
    for row in cur["evict_vs_dense"]:
        prefix, speedup = row["prefix"], row["speedup"]
        if prefix < 64:
            continue
        checked = True
        print(
            f"evict vs dense @ prefix {prefix}: {speedup:.2f}x "
            f"({'wins' if speedup > 1.0 else 'LOSES'})"
        )
        if speedup < 0.85:
            failures.append(
                f"evicting-cache decode clearly loses to dense at prefix {prefix}: "
                f"{speedup:.2f}x"
            )
        elif speedup < 1.0:
            print(f"  ! warning: speedup {speedup:.2f}x < 1 (within noise tolerance)")
    if not checked:
        failures.append("report lacks evict_vs_dense cells at prefix >= 64")

    replay = cur["plan_replay"]
    if replay.get("step_hit_rate", 0.0) <= 0.0:
        failures.append(f"step-plan cache never hit on replay: {replay}")
    return failures


def check_forward(cur: dict, base: dict) -> list:
    failures = []
    for key in ("cores", "forward", "crossover"):
        if key not in cur:
            die(f"current report missing '{key}'")
    for row in cur["forward"]:
        for field in ("path", "seq_len", "unpacked_tps", "packed_tps", "speedup"):
            if field not in row:
                die(f"forward row missing '{field}': {row}")
    for row in cur["crossover"]:
        for field in ("op", "keep_density", "sparse_tps", "dense_tps", "speedup"):
            if field not in row:
                die(f"crossover row missing '{field}': {row}")

    current = {(r["path"], r["seq_len"]): r for r in cur["forward"]}
    print(f"{'cell':<16} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in base.get("forward", []):
        key = (b["path"], b["seq_len"])
        c = current.get(key)
        if c is None:
            failures.append(f"forward cell {key} missing from current report")
            continue
        floor = TOLERANCE * b["packed_tps"]
        ok = c["packed_tps"] >= floor
        label = f"{b['path']} L{b['seq_len']}"
        print(
            f"{label:<16} {b['packed_tps']:>10.1f} "
            f"{c['packed_tps']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{key}: packed {c['packed_tps']:.1f} tok/s < floor {floor:.1f} "
                f"(baseline {b['packed_tps']:.1f})"
            )

    # headline: the packed engine must beat the unpacked reference at
    # seq_len >= 64 (the 1.5x acceptance target, noise-tolerated)
    multicore = cur.get("cores", 1) >= 2
    checked = False
    for row in cur["forward"]:
        if row["seq_len"] < 64:
            continue
        checked = True
        sp = row["speedup"]
        verdict = "hits 1.5x" if sp >= 1.5 else ("wins" if sp > 1.0 else "LOSES")
        print(f"packed vs unpacked @ {row['path']} L{row['seq_len']}: {sp:.2f}x ({verdict})")
        if not multicore:
            if sp < 1.0:
                print(
                    f"  ! warning: inversion {sp:.2f}x on a single-core runner "
                    "(row-parallel kernels idle; not gated)"
                )
            continue
        if sp < 1.15:
            failures.append(
                f"packed engine loses its {row['path']} L{row['seq_len']} headline: "
                f"{sp:.2f}x < 1.15x (target 1.5x)"
            )
        elif sp < 1.5:
            print(f"  ! warning: speedup {sp:.2f}x below the 1.5x target (within tolerance)")
    if not checked:
        failures.append("report lacks forward cells at seq_len >= 64")

    # crossover: past the documented sparsity level, the compiled
    # CSR/gather sparse forward must beat packed dense — this is the
    # sparse-slower-than-dense inversion the plan compiler exists to
    # keep dead. Points above the threshold (e.g. the nothing-pruned
    # "open" operating point) are printed for the curve but not gated.
    threshold = base.get("crossover", {}).get("keep_density_threshold")
    if threshold is None:
        die("baseline 'crossover' group lacks 'keep_density_threshold'")
    gated = False
    for row in cur["crossover"]:
        kd, sp = row["keep_density"], row["speedup"]
        inside = kd <= threshold
        verdict = "wins" if sp > 1.0 else "LOSES"
        scope = "gated" if inside else "above threshold, informational"
        print(
            f"sparse vs dense @ {row['op']} keep-density {kd:.3f}: "
            f"{sp:.2f}x ({verdict}; {scope})"
        )
        if not inside:
            continue
        gated = True
        if not multicore:
            if sp < 1.0:
                print(
                    f"  ! warning: sparse loses ({sp:.2f}x) on a single-core "
                    "runner (row-parallel kernels idle; not gated)"
                )
            continue
        if sp < 0.85:
            failures.append(
                f"sparse forward loses to dense at keep-density {kd:.3f} "
                f"(<= threshold {threshold}): {sp:.2f}x — the "
                "sparse-slower-than-dense inversion is back"
            )
        elif sp < 1.0:
            print(f"  ! warning: speedup {sp:.2f}x < 1 (within noise tolerance)")
    if not gated:
        failures.append(
            f"report lacks crossover cells at keep-density <= {threshold} — "
            "nothing exercises the sparse-must-win region"
        )
    return failures


def check_gateway(cur: dict, base: dict) -> list:
    failures = []
    for key in ("gateway", "streaming", "conn_sweep", "slow_loris", "fault", "tracing"):
        if key not in cur:
            die(f"current report missing '{key}'")
    for row in cur["gateway"]:
        for field in (
            "replicas",
            "connections",
            "requests",
            "throughput_rps",
            "p50_ms",
            "p99_ms",
            "shed",
        ):
            if field not in row:
                die(f"gateway row missing '{field}': {row}")
    for field in ("ttft_ms", "ttft_frac", "tokens_per_sec"):
        if field not in cur["streaming"]:
            die(f"streaming missing '{field}': {cur['streaming']}")

    current = {(r["replicas"], r["connections"]): r for r in cur["gateway"]}
    print(f"{'cell':<18} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in base.get("gateway", []):
        key = (b["replicas"], b["connections"])
        c = current.get(key)
        if c is None:
            failures.append(f"gateway cell {key} missing from current report")
            continue
        floor = TOLERANCE * b["throughput_rps"]
        ok = c["throughput_rps"] >= floor
        label = f"x{b['replicas']} r, {b['connections']} conns"
        print(
            f"{label:<18} {b['throughput_rps']:>10.1f} "
            f"{c['throughput_rps']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{key}: {c['throughput_rps']:.1f} rps < floor {floor:.1f} "
                f"(baseline {b['throughput_rps']:.1f})"
            )

    # connection scaling: at the largest replica count, more offered
    # concurrency must not collapse throughput (noise-tolerated)
    by_replicas = {}
    for r in cur["gateway"]:
        by_replicas.setdefault(r["replicas"], {})[r["connections"]] = r["throughput_rps"]
    top = max(by_replicas) if by_replicas else None
    if top is not None and 1 in by_replicas[top] and 8 in by_replicas[top]:
        t1, t8 = by_replicas[top][1], by_replicas[top][8]
        print(f"conn scaling x{top} replicas: {t1:.1f} -> {t8:.1f} rps (1 -> 8 conns)")
        if t8 < 0.75 * t1:
            failures.append(
                f"connection-scaling inversion at {top} replicas: "
                f"8 conns {t8:.1f} rps < 1 conn {t1:.1f} rps"
            )
        elif t8 < t1:
            print(f"  ! warning: t8 {t8:.1f} < t1 {t1:.1f} (within noise tolerance)")
    else:
        failures.append("report lacks gateway cells at 1 and 8 connections")

    s = cur["streaming"]
    bs = base.get("streaming", {})
    ceiling = bs.get("ttft_ms", 1000.0) / TOLERANCE
    tps_floor = TOLERANCE * bs.get("tokens_per_sec", 0.0)
    print(
        f"streaming: ttft {s['ttft_ms']:.1f} ms (ceiling {ceiling:.1f}), "
        f"{s['tokens_per_sec']:.1f} tok/s (floor {tps_floor:.1f}), "
        f"ttft_frac {s['ttft_frac']:.2f}"
    )
    if s["ttft_ms"] > ceiling:
        failures.append(
            f"streaming ttft {s['ttft_ms']:.1f} ms above ceiling {ceiling:.1f} ms "
            f"(baseline {bs.get('ttft_ms', 1000.0):.1f})"
        )
    if s["tokens_per_sec"] < tps_floor:
        failures.append(
            f"streaming {s['tokens_per_sec']:.1f} tok/s < floor {tps_floor:.1f}"
        )
    # structural (machine-speed independent): the first token must land
    # well before the stream ends, or the gateway buffered the stream
    if s["ttft_frac"] > 0.9:
        failures.append(
            f"stream looks buffered, not streamed: ttft is {s['ttft_frac']:.2f} "
            "of the whole stream's wall time (limit 0.9)"
        )

    # --- conn sweep: idle herd must be nearly free ------------------
    sweep = cur["conn_sweep"]
    for field in ("idle_kb_per_conn", "cells"):
        if field not in sweep:
            die(f"conn_sweep missing '{field}': {sweep}")
    for row in sweep["cells"]:
        for field in ("idle_conns", "throughput_rps", "rss_kb"):
            if field not in row:
                die(f"conn_sweep cell missing '{field}': {row}")
    bsweep = base.get("conn_sweep", {})
    sweep_cells = {r["idle_conns"]: r for r in sweep["cells"]}
    print(f"{'idle herd':<18} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in bsweep.get("cells", []):
        c = sweep_cells.get(b["idle_conns"])
        if c is None:
            failures.append(
                f"conn_sweep cell at {b['idle_conns']} idle conns missing from report"
            )
            continue
        floor = TOLERANCE * b["throughput_rps"]
        ok = c["throughput_rps"] >= floor
        label = f"{b['idle_conns']} idle conns"
        print(
            f"{label:<18} {b['throughput_rps']:>10.1f} "
            f"{c['throughput_rps']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"conn_sweep @ {b['idle_conns']} idle conns: "
                f"{c['throughput_rps']:.1f} rps < floor {floor:.1f}"
            )
    # herd-scaling inversion: throughput with the largest idle herd must
    # not collapse relative to the smallest (noise-tolerated)
    if len(sweep_cells) >= 2:
        lo, hi = min(sweep_cells), max(sweep_cells)
        t_lo = sweep_cells[lo]["throughput_rps"]
        t_hi = sweep_cells[hi]["throughput_rps"]
        print(f"herd scaling: {t_lo:.1f} rps @ {lo} idle -> {t_hi:.1f} rps @ {hi} idle")
        if t_hi < 0.75 * t_lo:
            failures.append(
                f"idle-herd inversion: {hi} idle conns drop throughput to "
                f"{t_hi:.1f} rps from {t_lo:.1f} at {lo}"
            )
        elif t_hi < t_lo:
            print(f"  ! warning: {t_hi:.1f} < {t_lo:.1f} (within noise tolerance)")
    else:
        failures.append("conn_sweep has fewer than 2 cells — nothing to compare")
    # flat idle memory: the marginal kB per parked connection is capped
    cap = bsweep.get("idle_kb_per_conn_max")
    if cap is None:
        die("baseline 'conn_sweep' group lacks 'idle_kb_per_conn_max'")
    kb = sweep["idle_kb_per_conn"]
    print(f"idle memory: {kb:.1f} kB/conn marginal (cap {cap:.1f})")
    if kb > cap:
        failures.append(
            f"idle connections cost {kb:.1f} kB each, above the {cap:.1f} kB cap "
            "— per-connection state is no longer flat"
        )
    elif kb > 0.75 * cap:
        print(f"  ! warning: {kb:.1f} kB/conn is within 25% of the cap")

    # --- slow loris: structural reap + throughput under pressure ----
    loris = cur["slow_loris"]
    for field in ("lorises", "reaped", "throughput_rps"):
        if field not in loris:
            die(f"slow_loris missing '{field}': {loris}")
    bloris = base.get("slow_loris", {})
    print(
        f"slow loris: {loris['reaped']}/{loris['lorises']} reaped, "
        f"{loris['throughput_rps']:.1f} rps under pressure"
    )
    # structural (machine-speed independent): every half-open conn must
    # be reaped by the idle timer
    if loris["reaped"] < loris["lorises"]:
        failures.append(
            f"slow loris: only {loris['reaped']}/{loris['lorises']} half-open "
            "connections reaped — the idle timer is not defending the loop"
        )
    loris_floor = TOLERANCE * bloris.get("throughput_rps", 0.0)
    if loris["throughput_rps"] < loris_floor:
        failures.append(
            f"slow loris: {loris['throughput_rps']:.1f} rps under pressure "
            f"< floor {loris_floor:.1f}"
        )

    # --- fault cell: goodput under injected replica panics ----------
    fault = cur["fault"]
    for field in (
        "rate",
        "requests",
        "ok",
        "errors",
        "respawns",
        "retried",
        "goodput_frac",
    ):
        if field not in fault:
            die(f"fault cell missing '{field}': {fault}")
    bfault = base.get("fault", {})
    frac_min = bfault.get("goodput_frac_min")
    if frac_min is None:
        die("baseline 'fault' group lacks 'goodput_frac_min'")
    print(
        f"fault cell: {fault['ok']}/{fault['requests']} ok under {fault['rate']:.0%} "
        f"injected faults | {fault['respawns']} respawns, {fault['retried']} retried | "
        f"goodput {fault['goodput_frac']:.2f}x fault-free (floor {frac_min:.2f})"
    )
    # structural (machine-speed independent): the injector must have
    # actually killed workers, and the supervisor must have respawned
    # them — a run with zero respawns gates nothing
    if fault["respawns"] < 1:
        failures.append(
            "fault cell recorded zero replica respawns — injection never "
            "exercised the supervisor"
        )
    # retried batches make faults invisible to clients: terminal errors
    # are allowed (a batch can trip twice) but must stay rare
    if fault["errors"] > 0.1 * fault["requests"]:
        failures.append(
            f"fault cell: {fault['errors']}/{fault['requests']} requests answered "
            "with terminal faults — the retry budget is not absorbing injected panics"
        )
    # headline: goodput under ~1% faults must hold the committed floor
    # of the same run's fault-free cell (same machine, same moment — no
    # cross-machine noise in the ratio)
    if fault["goodput_frac"] < frac_min:
        failures.append(
            f"goodput under injected faults collapsed to "
            f"{fault['goodput_frac']:.2f}x fault-free (floor {frac_min:.2f})"
        )
    elif fault["goodput_frac"] < frac_min + 0.1:
        print(
            f"  ! warning: goodput frac {fault['goodput_frac']:.2f} is within "
            "0.1 of the floor"
        )

    # --- tracing cell: full observability must be nearly free -------
    tracing = cur["tracing"]
    for field in ("requests", "rps_on", "rps_off", "overhead_frac"):
        if field not in tracing:
            die(f"tracing cell missing '{field}': {tracing}")
    btracing = base.get("tracing", {})
    overhead_max = btracing.get("overhead_frac_max")
    if overhead_max is None:
        die("baseline 'tracing' group lacks 'overhead_frac_max'")
    print(
        f"tracing cell: {tracing['rps_on']:.1f} rps traced vs "
        f"{tracing['rps_off']:.1f} rps untraced | overhead "
        f"{tracing['overhead_frac']:+.1%} (cap {overhead_max:.0%})"
    )
    # structural: the traced run must actually have served traffic —
    # an empty cell would make any overhead ratio meaningless
    if tracing["rps_on"] <= 0.0:
        failures.append("tracing cell served zero traced throughput — nothing measured")
    # headline: 1-in-1 span tracing + histogram observation is a
    # same-machine, same-moment ratio against the untraced run and
    # must stay under the committed overhead cap
    if tracing["overhead_frac"] > overhead_max:
        failures.append(
            f"tracing overhead {tracing['overhead_frac']:.1%} exceeds the "
            f"{overhead_max:.0%} cap — span/histogram writes are on the hot "
            "path's critical section"
        )
    elif tracing["overhead_frac"] > 0.75 * overhead_max:
        print(
            f"  ! warning: tracing overhead {tracing['overhead_frac']:.1%} is "
            "within 25% of the cap"
        )
    return failures


def check_paged(cur: dict, base: dict) -> list:
    failures = []
    if "paged" not in cur:
        die("current report missing 'paged'")
    paged = cur["paged"]
    for key in ("pool_blocks", "block_size", "cells", "prefix_hit_rate", "cow", "sharing"):
        if key not in paged:
            die(f"paged group missing '{key}': {sorted(paged)}")
    for row in paged["cells"]:
        for field in ("sessions", "tokens_per_sec", "blocks_peak", "prefix_hit_rate"):
            if field not in row:
                die(f"paged cell missing '{field}': {row}")
    for field in ("sessions", "cow_copies", "shared_tokens"):
        if field not in paged["cow"]:
            die(f"paged cow missing '{field}': {paged['cow']}")
    for field in ("sessions", "sharing_blocks_peak", "nosharing_blocks_peak"):
        if field not in paged["sharing"]:
            die(f"paged sharing missing '{field}': {paged['sharing']}")

    bpaged = base["paged"]
    for key in ("pool_blocks", "prefix_hit_rate_min", "cells"):
        if key not in bpaged:
            die(f"baseline 'paged' group lacks '{key}'")

    # fixed-memory contract: floors only compare at the same pool size
    if paged["pool_blocks"] != bpaged["pool_blocks"]:
        failures.append(
            f"pool size changed: report ran {paged['pool_blocks']} blocks, baseline "
            f"floors assume {bpaged['pool_blocks']} — refresh the baseline"
        )

    current = {r["sessions"]: r for r in paged["cells"]}
    print(f"{'cell':<16} {'baseline':>10} {'current':>10} {'floor':>10}  verdict")
    for b in bpaged["cells"]:
        c = current.get(b["sessions"])
        if c is None:
            failures.append(f"paged cell at {b['sessions']} sessions missing from report")
            continue
        floor = TOLERANCE * b["tokens_per_sec"]
        ok = c["tokens_per_sec"] >= floor
        label = f"{b['sessions']} sessions"
        print(
            f"{label:<16} {b['tokens_per_sec']:>10.1f} "
            f"{c['tokens_per_sec']:>10.1f} {floor:>10.1f}  {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{b['sessions']} sessions: {c['tokens_per_sec']:.1f} tok/s < floor "
                f"{floor:.1f} (baseline {b['tokens_per_sec']:.1f})"
            )

    # headline: aggregate throughput must rise with session count at
    # fixed pool memory (prefix sharing amortizes the common prefill);
    # noise-tolerated like the other scaling checks
    ordered = sorted(paged["cells"], key=lambda r: r["sessions"])
    if len(ordered) < 2:
        failures.append("paged report has fewer than 2 cells — nothing to compare")
    for prev, nxt in zip(ordered, ordered[1:]):
        tp, tn = prev["tokens_per_sec"], nxt["tokens_per_sec"]
        trend = "rises" if tn > tp else "FLAT/FALLS"
        print(
            f"aggregate scaling {prev['sessions']} -> {nxt['sessions']} sessions: "
            f"{tp:.1f} -> {tn:.1f} tok/s ({trend})"
        )
        if tn < 0.9 * tp:
            failures.append(
                f"aggregate throughput inversion: {nxt['sessions']} sessions "
                f"{tn:.1f} tok/s < {prev['sessions']} sessions {tp:.1f}"
            )
        elif tn <= tp:
            print(f"  ! warning: {tn:.1f} <= {tp:.1f} (within noise tolerance)")

    # every cell must fit the declared pool (the hard memory cap held)
    for row in paged["cells"]:
        if row["blocks_peak"] > paged["pool_blocks"]:
            failures.append(
                f"{row['sessions']} sessions peaked at {row['blocks_peak']} blocks "
                f"> pool {paged['pool_blocks']} — the cap did not hold"
            )

    # structural: sharing must be visible in the pool counters
    hr = paged["prefix_hit_rate"]
    hr_min = bpaged["prefix_hit_rate_min"]
    print(f"prefix-sharing hit rate: {hr:.3f} (floor {hr_min:.3f})")
    if hr < hr_min:
        failures.append(
            f"prefix-sharing hit rate {hr:.3f} below floor {hr_min:.3f} — "
            "sessions replaying a published prefix are not attaching"
        )
    cow = paged["cow"]
    print(
        f"copy-on-write: {cow['cow_copies']} block copies across {cow['sessions']} "
        f"sessions, {cow['shared_tokens']} prefix tokens served shared"
    )
    if cow["cow_copies"] <= 0:
        failures.append(
            "no copy-on-write block copies recorded — divergent appends are "
            "either writing through shared blocks or never sharing a partial tail"
        )
    if cow["shared_tokens"] <= 0:
        failures.append("no prefix tokens served shared — the trie never attached")
    share = paged["sharing"]
    print(
        f"blocks peak @ {share['sessions']} sessions: sharing "
        f"{share['sharing_blocks_peak']} vs private {share['nosharing_blocks_peak']}"
    )
    if share["sharing_blocks_peak"] >= share["nosharing_blocks_peak"]:
        failures.append(
            f"prefix sharing saved no memory: sharing peaked at "
            f"{share['sharing_blocks_peak']} blocks vs {share['nosharing_blocks_peak']} "
            "with private prefixes"
        )
    return failures


def main() -> None:
    if len(sys.argv) != 3:
        die(f"usage: {sys.argv[0]} CURRENT.json BASELINE.json")
    with open(sys.argv[1]) as f:
        cur = json.load(f)
    with open(sys.argv[2]) as f:
        base = json.load(f)

    schema = cur.get("schema")
    if schema not in BASELINE_GROUPS:
        die(f"unknown report schema {schema!r}")

    # the baseline must explicitly declare the schemas it gates and
    # carry every cell group this schema reads — a stale or truncated
    # baseline must fail loudly, not let the gate pass over nothing
    declared = base.get("schemas")
    if not isinstance(declared, list) or schema not in declared:
        die(
            f"baseline does not declare schema {schema} under 'schemas' "
            f"(found {declared!r}); a lone top-level 'schema' field is a "
            "report's self-description, not a baseline's — list every "
            "gated schema in the 'schemas' array"
        )
    for group in BASELINE_GROUPS[schema]:
        if group not in base:
            die(
                f"baseline is missing its '{group}' cell group for "
                f"schema {schema} — nothing to gate against"
            )

    if schema == 2:
        failures = check_serving(cur, base)
    elif schema == 3:
        failures = check_decode(cur, base)
    elif schema == 4:
        failures = check_forward(cur, base)
    elif schema == 5:
        failures = check_gateway(cur, base)
    else:
        failures = check_paged(cur, base)

    if failures:
        for f in failures:
            print(f"  ✗ {f}")
        die(f"{len(failures)} regression check(s) failed")
    print("bench gate: OK")


if __name__ == "__main__":
    main()
