#!/usr/bin/env python3
"""Self-test for scripts/bench_gate.py's schema-5 and schema-6 checks.

Runs the gate as a subprocess against synthetic BENCH_5/BENCH_6 reports
and the committed bench_baseline.json, asserting the three verdict
classes:

* pass  — a healthy report clears every check and exits 0;
* warn  — a report inside the noise band (herd throughput dips but
  stays above 75% of the smallest cell; idle memory within 25% of the
  cap) still exits 0 but prints the warning lines;
* fail  — a collapsed conn-sweep floor, an idle-herd inversion, a
  blown per-connection memory cap, an unreaped loris, a collapsed
  fault-cell goodput fraction, a fault cell with zero respawns or too
  many terminal errors, a tracing cell whose overhead blows the cap
  (or that served zero traced throughput), and a missing group each
  exit 1 with the matching failure text; on the paged side,
  an aggregate-throughput inversion, a collapsed prefix hit rate, a
  sharing run that saves no blocks, a pool-size mismatch with the
  baseline, and zero copy-on-write copies each exit 1 likewise.

CI runs this before the real bench so a gate edit that silently stops
gating (or starts failing healthy runs) is caught without needing a
Rust toolchain or a live gateway.

Usage: test_bench_gate.py   (no arguments; exits non-zero on any miss)
"""

import copy
import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
GATE = os.path.join(HERE, "bench_gate.py")
BASELINE = os.path.join(HERE, os.pardir, "bench_baseline.json")


def healthy_report() -> dict:
    """A BENCH_5 report comfortably above every committed floor."""
    return {
        "schema": 5,
        "gateway": [
            {
                "replicas": r,
                "connections": c,
                "requests": 48,
                "throughput_rps": 40.0 + 5.0 * c,
                "p50_ms": 20.0,
                "p99_ms": 60.0,
                "shed": 0,
            }
            for r in (1, 2)
            for c in (1, 4, 8)
        ],
        "poisson": {
            "offered_rps": 30.0,
            "throughput_rps": 28.0,
            "p50_ms": 25.0,
            "p99_ms": 80.0,
            "shed": 0,
        },
        "streaming": {
            "sessions": 4,
            "tokens": 64,
            "ttft_ms": 80.0,
            "ttft_frac": 0.2,
            "tokens_per_sec": 120.0,
        },
        "conn_sweep": {
            "active_conns": 4,
            "idle_kb_per_conn": 6.0,
            "cells": [
                {"idle_conns": 64, "throughput_rps": 45.0, "p50_ms": 20.0,
                 "p99_ms": 60.0, "rss_kb": 90000},
                {"idle_conns": 256, "throughput_rps": 44.0, "p50_ms": 21.0,
                 "p99_ms": 62.0, "rss_kb": 91000},
                {"idle_conns": 1024, "throughput_rps": 43.0, "p50_ms": 22.0,
                 "p99_ms": 65.0, "rss_kb": 96000},
            ],
        },
        "slow_loris": {"lorises": 32, "reaped": 32, "throughput_rps": 40.0},
        "fault": {
            "rate": 0.01,
            "requests": 96,
            "ok": 96,
            "errors": 0,
            "respawns": 4,
            "retried": 4,
            "throughput_rps": 42.0,
            "fault_free_rps": 45.0,
            "goodput_frac": 0.93,
        },
        "tracing": {
            "requests": 96,
            "rps_on": 44.0,
            "rps_off": 45.0,
            "overhead_frac": 0.022,
            "queue_wait_p50_ms": 4.0,
            "execute_p50_ms": 18.0,
        },
    }


def healthy_report6() -> dict:
    """A BENCH_6 report comfortably above every committed paged floor."""
    return {
        "schema": 6,
        "paged": {
            "pool_blocks": 1024,
            "block_size": 8,
            "prefix_len": 48,
            "cells": [
                {"sessions": 1, "tokens_per_sec": 50.0, "blocks_peak": 72,
                 "prefix_hit_rate": 0.0},
                {"sessions": 8, "tokens_per_sec": 130.0, "blocks_peak": 240,
                 "prefix_hit_rate": 0.875},
                {"sessions": 32, "tokens_per_sec": 160.0, "blocks_peak": 816,
                 "prefix_hit_rate": 0.969},
            ],
            "prefix_hit_rate": 0.969,
            "cow": {"sessions": 8, "prefix_len": 50, "cow_copies": 64,
                    "shared_tokens": 350},
            "sharing": {"sessions": 8, "sharing_blocks_peak": 240,
                        "nosharing_blocks_peak": 576},
        },
    }


def run_gate(report: dict, baseline: dict) -> "tuple[int, str]":
    with tempfile.TemporaryDirectory() as d:
        cur = os.path.join(d, "cur.json")
        base = os.path.join(d, "base.json")
        with open(cur, "w") as f:
            json.dump(report, f)
        with open(base, "w") as f:
            json.dump(baseline, f)
        proc = subprocess.run(
            [sys.executable, GATE, cur, base],
            capture_output=True,
            text=True,
        )
        return proc.returncode, proc.stdout + proc.stderr


def expect(name: str, code: int, out: str, want_code: int, needles: "list[str]") -> "list[str]":
    problems = []
    if code != want_code:
        problems.append(f"{name}: exit {code}, wanted {want_code}\n{out}")
    for needle in needles:
        if needle not in out:
            problems.append(f"{name}: output lacks {needle!r}\n{out}")
    return problems


def main() -> None:
    with open(BASELINE) as f:
        baseline = json.load(f)
    problems = []

    # pass: a healthy report clears the gate
    code, out = run_gate(healthy_report(), baseline)
    problems += expect("healthy", code, out, 0, ["bench gate: OK"])

    # warn: herd throughput dips inside the noise band, idle memory
    # within 25% of the cap — still exits 0, but says so
    warn = healthy_report()
    warn["conn_sweep"]["cells"][2]["throughput_rps"] = 40.0  # < 45 but > 0.75*45
    warn["conn_sweep"]["idle_kb_per_conn"] = (
        0.8 * baseline["conn_sweep"]["idle_kb_per_conn_max"]
    )
    code, out = run_gate(warn, baseline)
    problems += expect(
        "warn", code, out, 0,
        ["bench gate: OK", "within noise tolerance", "within 25% of the cap"],
    )

    # fail: conn-sweep floor collapse
    bad = healthy_report()
    for cell in bad["conn_sweep"]["cells"]:
        cell["throughput_rps"] = 1.0
    code, out = run_gate(bad, baseline)
    problems += expect("sweep floor", code, out, 1, ["bench gate: FAIL", "conn_sweep @"])

    # fail: idle-herd inversion (floors still met, big herd collapses
    # relative to the small one)
    bad = healthy_report()
    bad["conn_sweep"]["cells"][0]["throughput_rps"] = 45.0
    bad["conn_sweep"]["cells"][2]["throughput_rps"] = 20.0  # > floor 8*0.85, < 0.75*45
    code, out = run_gate(bad, baseline)
    problems += expect(
        "herd inversion", code, out, 1, ["bench gate: FAIL", "idle-herd inversion"]
    )

    # fail: per-idle-connection memory above the cap
    bad = healthy_report()
    bad["conn_sweep"]["idle_kb_per_conn"] = (
        2.0 * baseline["conn_sweep"]["idle_kb_per_conn_max"]
    )
    code, out = run_gate(bad, baseline)
    problems += expect(
        "memory cap", code, out, 1, ["bench gate: FAIL", "no longer flat"]
    )

    # fail: a loris survived the idle timer (structural)
    bad = healthy_report()
    bad["slow_loris"]["reaped"] = 31
    code, out = run_gate(bad, baseline)
    problems += expect(
        "unreaped loris", code, out, 1, ["bench gate: FAIL", "idle timer is not defending"]
    )

    # fail: goodput under injected faults collapses below the floor
    bad = healthy_report()
    bad["fault"]["goodput_frac"] = 0.5
    code, out = run_gate(bad, baseline)
    problems += expect(
        "fault goodput", code, out, 1,
        ["bench gate: FAIL", "goodput under injected faults collapsed"],
    )

    # fail: zero respawns means injection never exercised the supervisor
    bad = healthy_report()
    bad["fault"]["respawns"] = 0
    code, out = run_gate(bad, baseline)
    problems += expect(
        "fault no respawns", code, out, 1,
        ["bench gate: FAIL", "never exercised the supervisor"],
    )

    # fail: the retry budget stopped absorbing injected panics
    bad = healthy_report()
    bad["fault"]["errors"] = 20
    code, out = run_gate(bad, baseline)
    problems += expect(
        "fault terminal errors", code, out, 1,
        ["bench gate: FAIL", "retry budget is not absorbing"],
    )

    # warn: tracing overhead within 25% of the cap still exits 0
    warn = healthy_report()
    warn["tracing"]["rps_on"] = 41.4  # overhead 0.08, > 0.75 * 0.10 cap
    warn["tracing"]["overhead_frac"] = 0.08
    code, out = run_gate(warn, baseline)
    problems += expect(
        "tracing warn", code, out, 0,
        ["bench gate: OK", "within 25% of the cap"],
    )

    # fail: tracing costs more than the overhead cap
    bad = healthy_report()
    bad["tracing"]["rps_on"] = 36.0  # overhead 0.2 vs cap 0.10
    bad["tracing"]["overhead_frac"] = 0.20
    code, out = run_gate(bad, baseline)
    problems += expect(
        "tracing overhead", code, out, 1,
        ["bench gate: FAIL", "tracing overhead"],
    )

    # fail: a traced run that served nothing gates nothing (structural)
    bad = healthy_report()
    bad["tracing"]["rps_on"] = 0.0
    bad["tracing"]["overhead_frac"] = 1.0
    code, out = run_gate(bad, baseline)
    problems += expect(
        "tracing empty", code, out, 1,
        ["bench gate: FAIL", "zero traced throughput"],
    )

    # fail: a baseline that lost the tracing group dies up front
    stale = copy.deepcopy(baseline)
    del stale["tracing"]
    code, out = run_gate(healthy_report(), stale)
    problems += expect(
        "tracing stale baseline", code, out, 1,
        ["bench gate: FAIL", "baseline is missing"],
    )

    # fail: report without the new groups must die loudly
    bad = healthy_report()
    del bad["conn_sweep"]
    code, out = run_gate(bad, baseline)
    problems += expect(
        "missing group", code, out, 1, ["bench gate: FAIL", "conn_sweep"]
    )

    # fail: a baseline that lost the conn_sweep group dies up front
    stale = copy.deepcopy(baseline)
    del stale["conn_sweep"]
    code, out = run_gate(healthy_report(), stale)
    problems += expect(
        "stale baseline", code, out, 1, ["bench gate: FAIL", "baseline is missing"]
    )

    # --- schema 6 (paged KV) -----------------------------------------

    # pass: a healthy paged report clears the gate
    code, out = run_gate(healthy_report6(), baseline)
    problems += expect("paged healthy", code, out, 0, ["bench gate: OK"])

    # warn: a flat-but-not-inverted scaling step still exits 0
    warn6 = healthy_report6()
    warn6["paged"]["cells"][2]["tokens_per_sec"] = 125.0  # < 130 but > 0.9*130
    code, out = run_gate(warn6, baseline)
    problems += expect(
        "paged scaling warn", code, out, 0,
        ["bench gate: OK", "within noise tolerance"],
    )

    # fail: aggregate throughput inverts past the noise band
    bad = healthy_report6()
    bad["paged"]["cells"][2]["tokens_per_sec"] = 50.0  # < 0.9 * 130
    code, out = run_gate(bad, baseline)
    problems += expect(
        "paged inversion", code, out, 1,
        ["bench gate: FAIL", "aggregate throughput inversion"],
    )

    # fail: sessions stop attaching to the published prefix
    bad = healthy_report6()
    bad["paged"]["prefix_hit_rate"] = 0.4
    code, out = run_gate(bad, baseline)
    problems += expect(
        "paged hit rate", code, out, 1, ["bench gate: FAIL", "hit rate"]
    )

    # fail: sharing saves no memory over private prefixes (structural)
    bad = healthy_report6()
    bad["paged"]["sharing"]["sharing_blocks_peak"] = 600
    code, out = run_gate(bad, baseline)
    problems += expect(
        "paged no saving", code, out, 1, ["bench gate: FAIL", "saved no memory"]
    )

    # fail: divergence never copied a shared block (structural)
    bad = healthy_report6()
    bad["paged"]["cow"]["cow_copies"] = 0
    code, out = run_gate(bad, baseline)
    problems += expect(
        "paged no cow", code, out, 1, ["bench gate: FAIL", "copy-on-write"]
    )

    # fail: the report ran at a different pool size than the baseline
    bad = healthy_report6()
    bad["paged"]["pool_blocks"] = 2048
    code, out = run_gate(bad, baseline)
    problems += expect(
        "paged pool mismatch", code, out, 1, ["bench gate: FAIL", "pool size changed"]
    )

    # fail: a cell burst the declared pool cap
    bad = healthy_report6()
    bad["paged"]["cells"][2]["blocks_peak"] = 1500
    code, out = run_gate(bad, baseline)
    problems += expect(
        "paged cap burst", code, out, 1, ["bench gate: FAIL", "cap did not hold"]
    )

    # fail: a baseline that lost the paged group dies up front
    stale = copy.deepcopy(baseline)
    del stale["paged"]
    code, out = run_gate(healthy_report6(), stale)
    problems += expect(
        "paged stale baseline", code, out, 1, ["bench gate: FAIL", "baseline is missing"]
    )

    if problems:
        for p in problems:
            print(f"✗ {p}")
        print(f"test_bench_gate: {len(problems)} check(s) failed")
        sys.exit(1)
    print("test_bench_gate: all verdict classes exercised, OK")


if __name__ == "__main__":
    main()
