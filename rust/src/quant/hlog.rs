//! HybridLog (HLog) quantization — the paper's contribution (§III-A).
//!
//! Level set (eq. 1): every power of two plus the midpoints between
//! adjacent powers,
//!
//! ```text
//! {2^0, 2^1, 2^0+2^1, 2^2, ..., 2^{n-2}, 2^{n-3}+2^{n-2}, 2^{n-1}}
//! ```
//!
//! i.e. {1, 2, 3, 4, 6, 8, 12, ..., 96, 128} for n = 8. Ties project to
//! the *higher* level.
//!
//! The quantizer is implemented exactly as the hardware shift detector
//! (paper Fig 12): find the leading one `I` of |x|, inspect the two bits
//! below it (b1, b0), then
//!
//! ```text
//! form = b1 XOR b0        1 -> sum form 2^e + 2^{e-1}, 0 -> single 2^e
//! e    = I + (b1 AND b0)  pattern 11 rounds up to the next power
//! ```
//!
//! This bit rule reproduces nearest-level-ties-up for every input and is
//! the software model that `python/compile/kernels/ref.py::hlog_quantize`
//! and the Pallas kernel must match bit-for-bit.

/// The positive HLog level set for an `nbits` input.
pub fn hlog_levels(nbits: u32) -> Vec<i32> {
    let mut lv = Vec::new();
    for m in 0..nbits {
        lv.push(1i32 << m);
        if (1..nbits - 1).contains(&m) {
            lv.push((1 << m) + (1 << (m - 1)));
        }
    }
    lv.sort_unstable();
    lv.dedup();
    lv
}

/// The 5-bit shift-detector code (paper Fig 12): sign, 3-bit exponent of
/// the dominant power-of-two term, and the form bit (0 = single `2^e`,
/// 1 = sum `2^e + 2^{e-1}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HlogCode {
    /// -1, 0, or +1.
    pub sign: i8,
    /// Exponent of the dominant power-of-two component.
    pub exp: u8,
    /// 1 if the value is the sum form `2^e + 2^{e-1}`.
    pub form: u8,
}

impl HlogCode {
    /// Decode back to the quantized integer level.
    pub fn value(self) -> i32 {
        if self.sign == 0 {
            return 0;
        }
        let mag = if self.form == 1 {
            3 * (1 << (self.exp.max(1) - 1))
        } else {
            1 << self.exp
        };
        self.sign as i32 * mag
    }

    /// Pack into the 5-bit hardware representation
    /// (sign bit, exponent[3], form bit) — used by the bit-level unit
    /// model and its tests.
    pub fn pack5(self) -> u8 {
        let s = u8::from(self.sign < 0);
        (s << 4) | ((self.exp & 0b111) << 1) | (self.form & 1)
    }
}

/// Compute the shift-detector code for an int8-valued input.
///
/// Exactly the hardware bit rule: leading-one index `I`, bits `b1 b0`
/// below it, `form = b1^b0`, `e = I + (b1&b0)`.
pub fn hlog_code(x: i32) -> HlogCode {
    debug_assert!((-255..=255).contains(&x), "HLog input out of range: {x}");
    if x == 0 {
        return HlogCode {
            sign: 0,
            exp: 0,
            form: 0,
        };
    }
    let a = x.unsigned_abs();
    let i = 31 - a.leading_zeros(); // floor(log2(a))
    let b1 = if i >= 1 { (a >> (i - 1)) & 1 } else { 0 };
    let b0 = if i >= 2 { (a >> (i - 2)) & 1 } else { 0 };
    let e = i + (b1 & b0);
    let form = b1 ^ b0;
    HlogCode {
        sign: if x > 0 { 1 } else { -1 },
        exp: e as u8,
        form: form as u8,
    }
}

/// HLog-quantize one int8-valued integer (nearest level, ties up).
#[inline]
pub fn hlog_quantize(x: i32) -> i32 {
    hlog_code(x).value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_set_n8() {
        assert_eq!(
            hlog_levels(8),
            vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128]
        );
    }

    /// Oracle: nearest level by brute force, ties to the higher level.
    fn nearest_ties_up(a: i32, levels: &[i32]) -> i32 {
        *levels
            .iter()
            .min_by_key(|&&lv| ((a - lv).abs(), -lv))
            .unwrap()
    }

    #[test]
    fn bit_rule_equals_nearest_level_exhaustive() {
        // 9-bit levels cover magnitudes up to 255 (requantized products
        // stay within int8 but the quantizer itself is total on ±255).
        let levels = hlog_levels(9);
        for x in -255..=255i32 {
            let got = hlog_quantize(x);
            let want = if x == 0 {
                0
            } else {
                x.signum() * nearest_ties_up(x.abs(), &levels)
            };
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn known_projections() {
        // From the paper's Fig 12 example: 0b00101010 = 42 -> (5, 1) i.e.
        // 2^5 + 2^4 = 48; 0b11101110 (two's-complement -18) -> (4, 0) = -16.
        assert_eq!(hlog_code(42), HlogCode { sign: 1, exp: 5, form: 1 });
        assert_eq!(hlog_quantize(42), 48);
        assert_eq!(hlog_code(-18), HlogCode { sign: -1, exp: 4, form: 0 });
        assert_eq!(hlog_quantize(-18), -16);
        // Ties round up: 5 is equidistant from 4 and 6 -> 6.
        assert_eq!(hlog_quantize(5), 6);
        assert_eq!(hlog_quantize(-5), -6);
    }

    #[test]
    fn code_roundtrip_and_pack() {
        for x in -255..=255i32 {
            let c = hlog_code(x);
            assert_eq!(c.value(), hlog_quantize(x), "x={x}");
            if x != 0 && x.abs() <= 128 {
                // 5-bit pack holds exponents 0..=7
                let p = c.pack5();
                assert_eq!((p >> 4) & 1, u8::from(x < 0));
                assert_eq!((p >> 1) & 0b111, c.exp & 0b111);
                assert_eq!(p & 1, c.form);
            }
        }
    }

    #[test]
    fn idempotent_on_levels() {
        for &lv in &hlog_levels(8) {
            assert_eq!(hlog_quantize(lv), lv);
            assert_eq!(hlog_quantize(-lv), -lv);
        }
    }

    #[test]
    fn zero_maps_to_zero() {
        assert_eq!(hlog_quantize(0), 0);
        assert_eq!(hlog_code(0).pack5(), 0);
    }

    #[test]
    fn prop_quantize_error_bounded() {
        // property: the HLog projection error never exceeds 20% of the
        // input magnitude (the worst case sits at x = 5·2^k, mid-gap
        // between 2^(k+2) and 3·2^(k+1)) — the "quantize→dequantize"
        // round-trip bound behind the paper's accuracy claims.
        crate::util::prop::check(200, |rng| {
            let x = rng.int_in(-255, 255) as i32;
            let q = hlog_quantize(x);
            let err = (q - x).abs() as f64;
            assert!(
                err <= 0.2 * x.abs() as f64 + 1e-9,
                "x={x} q={q} err={err}"
            );
            // and the projection is idempotent (levels are fixed points;
            // |x| ≥ 224 rounds up to 256, outside the quantizer's input
            // domain, so idempotence is checked on in-range outputs)
            if q.abs() <= 255 {
                assert_eq!(hlog_quantize(q), q, "x={x} q={q}");
            }
        });
    }

    #[test]
    fn prop_quantize_monotone_and_odd() {
        // property: x ≤ y ⇒ Q(x) ≤ Q(y) (monotonicity keeps the PAM's
        // ranking structure, which is what top-k consumes), and
        // Q(−x) = −Q(x) (sign symmetry of the shift detector).
        crate::util::prop::check(200, |rng| {
            let a = rng.int_in(-255, 255) as i32;
            let b = rng.int_in(-255, 255) as i32;
            let (lo, hi) = (a.min(b), a.max(b));
            assert!(
                hlog_quantize(lo) <= hlog_quantize(hi),
                "monotonicity broken at {lo}, {hi}"
            );
            assert_eq!(hlog_quantize(-a), -hlog_quantize(a), "odd symmetry at {a}");
        });
    }
}
