//! Symmetric int8 (re)quantization — the paper quantizes all linear
//! weights to 8 bit before anything else, and the prediction pipeline
//! requantizes intermediate int32 products back to int8 between the QK
//! and attention prediction stages (paper Fig 5a).
//!
//! Rounding is round-half-away-from-zero, matching `f32::round` and the
//! python reference (`ref.requantize_sym8`).

/// Quantize an f32 slice to int8-valued i32s with a shared symmetric
/// per-tensor scale. Returns `(values, scale)` where
/// `value ≈ x * scale`, `scale = 127 / max|x|`.
pub fn quantize_sym8(xs: &[f32]) -> (Vec<i32>, f32) {
    let maxabs = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-9);
    let s = 127.0 / maxabs;
    let q = xs
        .iter()
        .map(|&x| ((x * s).abs() + 0.5).floor() as i32 * x.signum() as i32)
        .map(|q| q.clamp(-127, 127))
        .collect();
    (q, s)
}

/// Dequantize int8-valued integers back to f32 with the given scale.
pub fn dequantize_sym8(qs: &[i32], scale: f32) -> Vec<f32> {
    qs.iter().map(|&q| q as f32 / scale).collect()
}

/// Requantize an int32 tensor (e.g. a prediction-stage product) to int8
/// with a fresh symmetric scale. Returns `(values, scale)`.
pub fn requantize_sym8(xs: &[i32]) -> (Vec<i32>, f32) {
    let maxabs = xs.iter().map(|x| x.abs()).max().unwrap_or(0).max(1) as f32;
    let s = 127.0 / maxabs;
    let q = xs
        .iter()
        .map(|&x| {
            let v = (x as f32 * s).abs() + 0.5;
            (v.floor() as i32 * x.signum()).clamp(-127, 127)
        })
        .collect();
    (q, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_full_scale() {
        let (q, s) = quantize_sym8(&[-1.0, 0.0, 0.5, 1.0]);
        assert_eq!(q, vec![-127, 0, 64, 127]); // 63.5 rounds away from zero
        assert!((s - 127.0).abs() < 1e-4);
    }

    #[test]
    fn requantize_endpoints() {
        let (q, s) = requantize_sym8(&[-1000, 0, 250, 500, 1000]);
        assert_eq!(q[0], -127);
        assert_eq!(q[1], 0);
        assert_eq!(q[4], 127);
        assert!((s - 127.0 / 1000.0).abs() < 1e-6);
        // 250 * 0.127 = 31.75 -> 32 (round half away from zero)
        assert_eq!(q[2], 32);
        assert_eq!(q[3], 64); // 63.5 -> 64
    }

    #[test]
    fn roundtrip_error_bounded() {
        let xs: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) / 17.0).collect();
        let (q, s) = quantize_sym8(&xs);
        let back = dequantize_sym8(&q, s);
        let step = 1.0 / s;
        for (x, y) in xs.iter().zip(&back) {
            assert!((x - y).abs() <= step * 0.5 + 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn all_zero_input() {
        let (q, _) = quantize_sym8(&[0.0, 0.0]);
        assert_eq!(q, vec![0, 0]);
        let (q, _) = requantize_sym8(&[0, 0]);
        assert_eq!(q, vec![0, 0]);
    }

    #[test]
    fn requantize_symmetry() {
        let xs: Vec<i32> = (-500..=500).step_by(7).collect();
        let (q, _) = requantize_sym8(&xs);
        let (qneg, _) = requantize_sym8(&xs.iter().map(|x| -x).collect::<Vec<_>>());
        for (a, b) in q.iter().zip(&qneg) {
            assert_eq!(*a, -*b);
        }
    }
}
