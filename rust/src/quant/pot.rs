//! PoT and APoT comparison quantizers (paper Figs 6/7, 17/18, Table III).
//!
//! PoT (FACT-style): levels are the plain powers of two; cheap (leading-
//! zero detector) but with projection error growing with magnitude.
//! APoT (Enhance-style, a = 2): levels are sums of at most two distinct
//! powers of two — denser, more accurate, but with irregular levels that
//! need comparison ladders / adder trees in hardware.
//!
//! Projection rule for both: nearest level, ties to the higher level —
//! the same rule as HLog so the three methods differ only in level sets.

/// Positive PoT level set for `nbits` inputs: {1, 2, 4, ..., 2^(n-1)}.
pub fn pot_levels(nbits: u32) -> Vec<i32> {
    (0..nbits).map(|m| 1i32 << m).collect()
}

/// Positive APoT (a = 2) level set: powers of two plus pairwise sums of
/// distinct powers that stay below 2^nbits.
pub fn apot_levels(nbits: u32) -> Vec<i32> {
    let base = pot_levels(nbits);
    let mut lv: Vec<i32> = base.clone();
    for (i, &hi) in base.iter().enumerate() {
        for &lo in &base[..i] {
            if hi + lo < (1 << nbits) {
                lv.push(hi + lo);
            }
        }
    }
    lv.sort_unstable();
    lv.dedup();
    lv
}

/// Project to the nearest level in `levels` (ties to the higher level).
pub fn project(x: i32, levels: &[i32]) -> i32 {
    if x == 0 {
        return 0;
    }
    let a = x.abs();
    let mag = *levels
        .iter()
        .min_by_key(|&&lv| ((a - lv).abs(), -lv))
        .expect("non-empty level set");
    x.signum() * mag
}

/// PoT-quantize an int8-valued integer (9-bit levels so magnitudes up to
/// 255 are covered, matching the python reference).
pub fn pot_quantize(x: i32) -> i32 {
    debug_assert!((-255..=255).contains(&x));
    project(x, &pot_levels(9))
}

/// APoT-quantize an int8-valued integer.
pub fn apot_quantize(x: i32) -> i32 {
    debug_assert!((-255..=255).contains(&x));
    project(x, &apot_levels(9))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hlog::hlog_levels;

    #[test]
    fn pot_levels_n8() {
        assert_eq!(pot_levels(8), vec![1, 2, 4, 8, 16, 32, 64, 128]);
    }

    #[test]
    fn apot_contains_pot_and_hlog() {
        let apot = apot_levels(8);
        for lv in pot_levels(8) {
            assert!(apot.contains(&lv));
        }
        for lv in hlog_levels(8) {
            assert!(apot.contains(&lv), "HLog level {lv} missing from APoT");
        }
        // paper: APoT has redundant extra levels relative to HLog
        assert!(apot.len() > hlog_levels(8).len());
        assert!(apot.contains(&160)); // 128 + 32, an APoT-only level
    }

    #[test]
    fn pot_projection_examples() {
        assert_eq!(pot_quantize(3), 4); // tie 2/4 -> up
        assert_eq!(pot_quantize(5), 4);
        assert_eq!(pot_quantize(6), 8); // tie 4/8 -> up
        assert_eq!(pot_quantize(-100), -128); // closer to 128 than 64
        assert_eq!(pot_quantize(0), 0);
    }

    #[test]
    fn apot_projection_examples() {
        assert_eq!(apot_quantize(3), 3);
        assert_eq!(apot_quantize(7), 8); // 7 is between 6 and 8, closer... |7-6|=1,|7-8|=1 tie -> 8
        assert_eq!(apot_quantize(100), 96);
        assert_eq!(apot_quantize(-100), -96);
    }

    #[test]
    fn idempotent_on_levels() {
        for &lv in &apot_levels(8) {
            assert_eq!(apot_quantize(lv), lv);
        }
        for &lv in &pot_levels(8) {
            assert_eq!(pot_quantize(lv), lv);
        }
    }

    #[test]
    fn projection_error_bounded_by_half_gap() {
        let levels = apot_levels(9);
        for x in 1..=255 {
            let q = apot_quantize(x);
            // error never exceeds half the largest inter-level gap around x
            let gap = levels
                .windows(2)
                .filter(|w| w[0] <= x && x <= w[1])
                .map(|w| w[1] - w[0])
                .max()
                .unwrap_or(0);
            assert!((q - x).abs() * 2 <= gap.max(1), "x={x} q={q} gap={gap}");
        }
    }
}
