//! Quantization schemes compared in the paper (§III-A, Figs 6/7, 17/18,
//! Table III): symmetric int8, Power-of-Two (PoT, FACT-style), Additive
//! PoT (APoT a=2, Enhance-style), and the paper's HybridLog (HLog).
//!
//! All quantizers here operate on int8-valued integers (the paper
//! quantizes weights/activations to 8 bit first, then the *prediction*
//! path re-quantizes those int8 values onto log-ish level sets). The
//! level sets and projection rules (nearest level, ties to the higher
//! level) are the correctness contract shared with
//! `python/compile/kernels/ref.py`.

mod hlog;
mod int8;
mod pot;

pub use hlog::{hlog_code, hlog_levels, hlog_quantize, HlogCode};
pub use int8::{dequantize_sym8, quantize_sym8, requantize_sym8};
pub use pot::{apot_levels, apot_quantize, pot_levels, pot_quantize};

/// Which prediction quantizer to use (for the Fig 17/18 comparisons).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    /// The paper's HybridLog quantization.
    Hlog,
    /// Power-of-two (FACT-style).
    Pot,
    /// Additive power-of-two with two terms (Enhance-style).
    Apot,
    /// Plain 4-bit linear quantization (Sanger-style).
    Linear4,
}

impl QuantMethod {
    pub const ALL: [QuantMethod; 4] = [
        QuantMethod::Hlog,
        QuantMethod::Pot,
        QuantMethod::Apot,
        QuantMethod::Linear4,
    ];

    pub fn name(self) -> &'static str {
        match self {
            QuantMethod::Hlog => "HLog",
            QuantMethod::Pot => "PoT",
            QuantMethod::Apot => "APoT",
            QuantMethod::Linear4 => "4-bit",
        }
    }

    /// Quantize one int8-valued integer under this method.
    pub fn quantize(self, x: i32) -> i32 {
        match self {
            QuantMethod::Hlog => hlog_quantize(x),
            QuantMethod::Pot => pot_quantize(x),
            QuantMethod::Apot => apot_quantize(x),
            QuantMethod::Linear4 => linear4_quantize(x),
        }
    }

    /// Quantize a slice in place (prediction-path helper).
    pub fn quantize_slice(self, xs: &mut [i32]) {
        for x in xs.iter_mut() {
            *x = self.quantize(*x);
        }
    }
}

/// 4-bit linear quantization of an int8 value (Sanger's predictor): keep
/// the top 4 magnitude bits, i.e. round to multiples of 16 on [-128, 127]
/// (round-half-up on magnitude, like the other quantizers here).
pub fn linear4_quantize(x: i32) -> i32 {
    let sign = x.signum();
    let a = x.abs().min(127);
    let q = ((a + 8) / 16) * 16;
    sign * q.min(127 - (127 % 16)) // clamp to representable grid: 0..=112
}

/// Mean absolute projection error of a quantizer over a slice.
pub fn mean_abs_error(method: QuantMethod, xs: &[i32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter()
        .map(|&x| (method.quantize(x) - x).abs() as f64)
        .sum::<f64>()
        / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear4_grid() {
        assert_eq!(linear4_quantize(0), 0);
        assert_eq!(linear4_quantize(7), 0);
        assert_eq!(linear4_quantize(8), 16);
        assert_eq!(linear4_quantize(-8), -16);
        assert_eq!(linear4_quantize(127), 112);
        assert_eq!(linear4_quantize(-127), -112);
        for x in -127..=127 {
            assert_eq!(linear4_quantize(x) % 16, 0, "x={x}");
        }
    }

    #[test]
    fn error_ordering_matches_paper() {
        // Paper Fig 7: PoT worst; HLog and APoT comparable; 4-bit linear
        // has large *relative* error for small values but small absolute.
        let xs: Vec<i32> = (1..=127).collect();
        let e_pot = mean_abs_error(QuantMethod::Pot, &xs);
        let e_hlog = mean_abs_error(QuantMethod::Hlog, &xs);
        let e_apot = mean_abs_error(QuantMethod::Apot, &xs);
        assert!(e_hlog < 0.6 * e_pot, "hlog {e_hlog} pot {e_pot}");
        assert!(e_apot <= e_hlog, "apot {e_apot} hlog {e_hlog}");
    }

    #[test]
    fn quantize_slice_applies_elementwise() {
        let mut xs = vec![5, -5, 100];
        QuantMethod::Hlog.quantize_slice(&mut xs);
        assert_eq!(xs, vec![6, -6, 96]);
    }
}
