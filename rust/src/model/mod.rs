//! The accuracy substrate: a tiny int8-weight transformer whose weights
//! are trained by the python compile path (`train_tiny.py`) and loaded
//! here for dense/SPLS-sparse evaluation on the host. The AOT artifacts
//! of the same model run through `runtime::` on the serve path.

pub mod accuracy;
pub mod engine;
pub mod sparse_kernels;
pub mod sparse_plan;
pub mod synth;
pub mod tensor;
pub mod transformer;
pub mod weights;

pub use accuracy::{eval_dense, eval_sparse, EvalResult};
pub use engine::{PackedLayer, PackedModel};
pub use sparse_plan::{
    within_parity_corridor, CompiledHeadPlan, CompiledLayerPlan, CompiledModelPlan,
    PARITY_EPS,
};
pub use transformer::{
    attention_probs, embed_row, forward_causal_hidden, forward_dense, forward_masked,
    forward_sparse, lm_logits_row, next_token_logits, plan_model,
};
pub use weights::{TestSet, TinyConfig, TinyWeights};
