//! Load the tiny transformer's weights + test set from the ESWT
//! artifacts written by `python/compile/train_tiny.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::eswt::{read_eswt, Tensor};
use crate::util::mat::MatF;

/// Tiny model hyperparameters (must mirror `model.TinyConfig` in python;
/// validated against `tiny_testset.bin`'s meta record on load).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TinyConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub n_classes: usize,
}

impl Default for TinyConfig {
    fn default() -> Self {
        Self {
            vocab: 64,
            seq_len: 64,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ffn: 256,
            n_classes: 16,
        }
    }
}

impl TinyConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Per-layer weights.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: MatF,
    pub bq: Vec<f32>,
    pub wk: MatF,
    pub bk: Vec<f32>,
    pub wv: MatF,
    pub bv: Vec<f32>,
    pub wo: MatF,
    pub bo: Vec<f32>,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub w1: MatF,
    pub b1: Vec<f32>,
    pub w2: MatF,
    pub b2: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

/// The full tiny-model parameter set.
#[derive(Clone, Debug)]
pub struct TinyWeights {
    pub cfg: TinyConfig,
    pub embed: MatF,
    pub pos: MatF,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub cls_w: MatF,
    pub cls_b: Vec<f32>,
}

fn get_mat(map: &BTreeMap<String, Tensor>, name: &str, rows: usize, cols: usize) -> Result<MatF> {
    let t = map.get(name).with_context(|| format!("missing tensor {name}"))?;
    let data = t.as_f32().with_context(|| format!("tensor {name} dtype"))?;
    if t.dims() != [rows, cols] {
        bail!("tensor {name}: dims {:?}, wanted [{rows}, {cols}]", t.dims());
    }
    Ok(MatF::from_vec(rows, cols, data.to_vec()))
}

fn get_vec(map: &BTreeMap<String, Tensor>, name: &str, len: usize) -> Result<Vec<f32>> {
    let t = map.get(name).with_context(|| format!("missing tensor {name}"))?;
    let data = t.as_f32().with_context(|| format!("tensor {name} dtype"))?;
    if t.dims() != [len] {
        bail!("tensor {name}: dims {:?}, wanted [{len}]", t.dims());
    }
    Ok(data.to_vec())
}

impl TinyWeights {
    /// Load from `artifacts/tiny_weights.bin`.
    pub fn load(path: &Path) -> Result<Self> {
        Self::load_with_config(path, TinyConfig::default())
    }

    pub fn load_with_config(path: &Path, cfg: TinyConfig) -> Result<Self> {
        let map = read_eswt(path)?;
        let (d, f) = (cfg.d_model, cfg.d_ffn);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |s: &str| format!("layer{i}.{s}");
            layers.push(LayerWeights {
                wq: get_mat(&map, &p("wq"), d, d)?,
                bq: get_vec(&map, &p("bq"), d)?,
                wk: get_mat(&map, &p("wk"), d, d)?,
                bk: get_vec(&map, &p("bk"), d)?,
                wv: get_mat(&map, &p("wv"), d, d)?,
                bv: get_vec(&map, &p("bv"), d)?,
                wo: get_mat(&map, &p("wo"), d, d)?,
                bo: get_vec(&map, &p("bo"), d)?,
                ln1_g: get_vec(&map, &p("ln1_g"), d)?,
                ln1_b: get_vec(&map, &p("ln1_b"), d)?,
                w1: get_mat(&map, &p("w1"), d, f)?,
                b1: get_vec(&map, &p("b1"), f)?,
                w2: get_mat(&map, &p("w2"), f, d)?,
                b2: get_vec(&map, &p("b2"), d)?,
                ln2_g: get_vec(&map, &p("ln2_g"), d)?,
                ln2_b: get_vec(&map, &p("ln2_b"), d)?,
            });
        }
        Ok(Self {
            embed: get_mat(&map, "embed", cfg.vocab, d)?,
            pos: get_mat(&map, "pos", cfg.seq_len, d)?,
            lnf_g: get_vec(&map, "lnf_g", d)?,
            lnf_b: get_vec(&map, "lnf_b", d)?,
            cls_w: get_mat(&map, "cls_w", d, cfg.n_classes)?,
            cls_b: get_vec(&map, "cls_b", cfg.n_classes)?,
            cfg,
            layers,
        })
    }
}

/// The held-out test set exported alongside the weights.
#[derive(Clone, Debug)]
pub struct TestSet {
    pub tokens: Vec<Vec<i32>>,
    pub labels: Vec<i32>,
}

impl TestSet {
    pub fn load(path: &Path) -> Result<Self> {
        let map = read_eswt(path)?;
        let toks = map.get("tokens").context("missing tokens")?;
        let labels = map.get("labels").context("missing labels")?;
        let dims = toks.dims().to_vec();
        if dims.len() != 2 {
            bail!("tokens should be 2-D, got {dims:?}");
        }
        let data = toks.as_i32()?;
        let (n, l) = (dims[0], dims[1]);
        let tokens = (0..n).map(|i| data[i * l..(i + 1) * l].to_vec()).collect();
        Ok(Self {
            tokens,
            labels: labels.as_i32()?.to_vec(),
        })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_trained_weights() {
        let w = TinyWeights::load(&artifacts().join("tiny_weights.bin")).unwrap();
        assert_eq!(w.layers.len(), 2);
        assert_eq!(w.embed.rows, 64);
        // trained weights are non-degenerate
        assert!(w.layers[0].wq.data.iter().any(|&v| v != 0.0));
        // matmul weights were snapped to the int8 grid at export
        let wq = &w.layers[0].wq;
        let maxabs = wq.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = 127.0 / maxabs;
        for &v in wq.data.iter().take(256) {
            let g = v * s;
            assert!((g - g.round()).abs() < 1e-3, "not on int8 grid: {v}");
        }
    }

    #[test]
    fn load_testset() {
        let t = TestSet::load(&artifacts().join("tiny_testset.bin")).unwrap();
        assert_eq!(t.len(), 512);
        assert_eq!(t.tokens[0].len(), 64);
        assert!(t.labels.iter().all(|&l| (0..16).contains(&l)));
    }

    #[test]
    fn missing_file_errors() {
        assert!(TinyWeights::load(Path::new("/nonexistent/w.bin")).is_err());
    }
}
