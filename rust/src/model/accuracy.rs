//! Accuracy harness: evaluate dense vs SPLS-sparse accuracy on the
//! held-out synthetic test set, sweeping the SPLS hyperparameters —
//! the substrate for the paper's accuracy experiments (Figs 15-19).

use crate::config::SplsConfig;
use crate::quant::QuantMethod;
use crate::spls::plan::LayerPlan;

use super::transformer::{forward_dense, forward_sparse, plan_model};
use super::tensor::argmax;
use super::weights::{TestSet, TinyWeights};

/// Result of one accuracy + sparsity evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub n: usize,
    pub accuracy: f64,
    /// Mean per-layer Q sparsity across the evaluated set.
    pub q_sparsity: f64,
    /// Mean K/V sparsity.
    pub kv_sparsity: f64,
    /// Mean attention sparsity (inter-row + intra-row).
    pub attn_sparsity: f64,
    /// Mean FFN token sparsity.
    pub ffn_sparsity: f64,
}

impl EvalResult {
    /// Accuracy drop in percentage points vs a dense baseline.
    pub fn loss_vs(&self, dense: &EvalResult) -> f64 {
        (dense.accuracy - self.accuracy) * 100.0
    }
}

fn mean_sparsities(plans: &[LayerPlan]) -> (f64, f64, f64, f64) {
    let n = plans.len().max(1) as f64;
    (
        plans.iter().map(|p| p.q_sparsity()).sum::<f64>() / n,
        plans.iter().map(|p| p.kv_sparsity()).sum::<f64>() / n,
        plans.iter().map(|p| p.attn_sparsity()).sum::<f64>() / n,
        plans.iter().map(|p| p.ffn_sparsity()).sum::<f64>() / n,
    )
}

/// Dense accuracy over (a subset of) the test set.
pub fn eval_dense(w: &TinyWeights, set: &TestSet, limit: usize) -> EvalResult {
    let n = set.len().min(limit);
    let mut correct = 0usize;
    for i in 0..n {
        let logits = forward_dense(w, &set.tokens[i]);
        if argmax(&logits) as i32 == set.labels[i] {
            correct += 1;
        }
    }
    EvalResult {
        n,
        accuracy: correct as f64 / n.max(1) as f64,
        q_sparsity: 0.0,
        kv_sparsity: 0.0,
        attn_sparsity: 0.0,
        ffn_sparsity: 0.0,
    }
}

/// SPLS-sparse accuracy + measured sparsity over the test set.
pub fn eval_sparse(
    w: &TinyWeights,
    set: &TestSet,
    limit: usize,
    spls: &SplsConfig,
    method: QuantMethod,
) -> EvalResult {
    let n = set.len().min(limit);
    let mut correct = 0usize;
    let mut sums = (0.0, 0.0, 0.0, 0.0);
    for i in 0..n {
        let plans = plan_model(w, &set.tokens[i], spls, method);
        let (q, kv, a, f) = mean_sparsities(&plans);
        sums = (sums.0 + q, sums.1 + kv, sums.2 + a, sums.3 + f);
        let logits = forward_sparse(w, &set.tokens[i], &plans);
        if argmax(&logits) as i32 == set.labels[i] {
            correct += 1;
        }
    }
    let nf = n.max(1) as f64;
    EvalResult {
        n,
        accuracy: correct as f64 / nf,
        q_sparsity: sums.0 / nf,
        kv_sparsity: sums.1 / nf,
        attn_sparsity: sums.2 / nf,
        ffn_sparsity: sums.3 / nf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn load() -> (TinyWeights, TestSet) {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        (
            TinyWeights::load(&dir.join("tiny_weights.bin")).unwrap(),
            TestSet::load(&dir.join("tiny_testset.bin")).unwrap(),
        )
    }

    #[test]
    fn dense_accuracy_well_above_chance() {
        let (w, set) = load();
        let r = eval_dense(&w, &set, 64);
        // 16 classes -> chance = 6.25%; the trained model should be far above
        assert!(r.accuracy > 0.5, "dense accuracy {}", r.accuracy);
    }

    #[test]
    fn sparse_operating_point_small_loss() {
        let (w, set) = load();
        let dense = eval_dense(&w, &set, 48);
        let sparse = eval_sparse(&w, &set, 48, &SplsConfig::default(), QuantMethod::Hlog);
        // paper's bar: loss <= 1%; give the tiny substrate a bit of slack
        // (statistical noise at n=48) but catch gross breakage
        assert!(
            sparse.loss_vs(&dense) <= 8.0,
            "loss {} pts (dense {} sparse {})",
            sparse.loss_vs(&dense),
            dense.accuracy,
            sparse.accuracy
        );
        assert!(sparse.attn_sparsity > 0.5);
    }

    #[test]
    fn degenerate_config_keeps_dense_accuracy() {
        let (w, set) = load();
        let spls = SplsConfig {
            top_k: 1.0,
            sim_threshold: -1.0,
            ffn_threshold: usize::MAX,
            window: 8,
        };
        let dense = eval_dense(&w, &set, 32);
        let sparse = eval_sparse(&w, &set, 32, &spls, QuantMethod::Hlog);
        assert_eq!(dense.accuracy, sparse.accuracy);
        assert_eq!(sparse.q_sparsity, 0.0);
        assert_eq!(sparse.ffn_sparsity, 0.0);
    }
}
