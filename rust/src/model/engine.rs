//! The packed execution engine: a [`PackedModel`] is built **once** per
//! loaded weight set and then drives every host forward path with
//! pre-packed operands and a reusable [`Scratch`] arena, so steady-state
//! forwards on the dense substrate allocate nothing and every inner
//! loop is a contiguous slice-zip kernel the compiler autovectorizes
//! (DESIGN.md §Host kernel layout).
//!
//! What gets packed, and why:
//!
//! * **per-head `Wq/Wk/Wv` column slices** (D × Dh, row-major) — the
//!   sparse path generates Q for critical rows and K/V for active
//!   columns one head at a time, and the decode path projects exactly
//!   one row per head per step; both previously re-materialized these
//!   slices with `MatF::from_fn` on every call;
//! * **int8 predictor operands** — `plan_model` and the incremental
//!   decode predictor re-quantized each head's weight slice per request
//!   (`quantize_sym8` of the slice); the quantization is deterministic,
//!   so it is hoisted to pack time and shared by both consumers;
//! * the dense substrate paths (`forward_dense` / `forward_masked` /
//!   `forward_causal_hidden`) keep the full D × D projections (their
//!   QKV is computed for every row and column anyway, and the
//!   row-parallel `tensor::linear_into_par` wants the widest panels)
//!   and run the per-head attention on **column views** of the packed
//!   Q/Kᵀ/V activations — zero per-head copies, with Kᵀ transposed once
//!   per layer into the arena so the score kernel's inner loop walks
//!   contiguous rows.
//!
//! **Bitwise contract.** Every packed forward is bit-identical to its
//! unpacked sibling in `model::transformer`: the kernels preserve the
//! per-output-element k-accumulation order (and the reference's
//! zero-skip / bias-placement quirks) exactly, and the row-parallel
//! kernels only partition disjoint output rows. `tests/packed_parity.rs`
//! asserts this across randomized shapes, tokens and plans for all four
//! forward paths, planning, and decode.

use std::sync::Arc;

use crate::config::SplsConfig;
use crate::quant::{quantize_sym8, QuantMethod};
use crate::spls::plan::{plan_layer_from_inputs, LayerPlan};
use crate::util::mat::{MatF, MatI};
use crate::util::scratch::Scratch;

use super::tensor::{
    add_inplace, gelu_inplace, layernorm_into, linear_into, linear_into_par,
    masked_softmax_rows, matmul_into, mean_rows_into, softmax_rows,
};
use super::transformer::lm_logits_row;
use super::weights::{LayerWeights, TinyWeights};

/// One layer's packed operands (indexed per head).
pub struct PackedLayer {
    /// Per-head D × Dh column slices of Wq / Wk / Wv.
    pub wq_h: Vec<MatF>,
    pub wk_h: Vec<MatF>,
    pub wv_h: Vec<MatF>,
    /// Matching per-head bias slices.
    pub bq_h: Vec<Vec<f32>>,
    pub bk_h: Vec<Vec<f32>>,
    pub bv_h: Vec<Vec<f32>>,
    /// Per-head int8 prediction operands (`quantize_sym8` of the f32
    /// slice — exactly what `plan_model` and the decode predictor
    /// computed per call before packing).
    pub pred_wq: Vec<MatI>,
    pub pred_wk: Vec<MatI>,
}

/// The packed model: immutable, cheap to share (`Arc`), `Send + Sync`.
/// Serving replicas, the planner and every decode session hold one
/// shared instance.
pub struct PackedModel {
    weights: Arc<TinyWeights>,
    layers: Vec<PackedLayer>,
}

/// Which softmax masking a dense-substrate block applies.
#[derive(Clone, Copy)]
enum BlockMask<'a> {
    /// Unmasked row softmax (`forward_dense`).
    Dense,
    /// Lower-triangular causal mask (`forward_causal_hidden`).
    Causal,
    /// One layer's `[n_heads, L, L]` f32 mask slice, keep iff `> 0.5`
    /// (`forward_masked`).
    External(&'a [f32]),
}

impl PackedModel {
    pub fn new(weights: Arc<TinyWeights>) -> Self {
        let cfg = weights.cfg;
        let dh = cfg.d_head();
        let layers = weights
            .layers
            .iter()
            .map(|lw| {
                let slice_f = |m: &MatF, hi: usize| {
                    MatF::from_fn(m.rows, dh, |r, c| m[(r, hi * dh + c)])
                };
                let slice_b = |b: &[f32], hi: usize| b[hi * dh..(hi + 1) * dh].to_vec();
                let slice_8 = |m: &MatF, hi: usize| {
                    let (q, _) = quantize_sym8(&slice_f(m, hi).data);
                    MatI::from_vec(m.rows, dh, q)
                };
                let mut pl = PackedLayer {
                    wq_h: Vec::new(),
                    wk_h: Vec::new(),
                    wv_h: Vec::new(),
                    bq_h: Vec::new(),
                    bk_h: Vec::new(),
                    bv_h: Vec::new(),
                    pred_wq: Vec::new(),
                    pred_wk: Vec::new(),
                };
                for hi in 0..cfg.n_heads {
                    pl.wq_h.push(slice_f(&lw.wq, hi));
                    pl.wk_h.push(slice_f(&lw.wk, hi));
                    pl.wv_h.push(slice_f(&lw.wv, hi));
                    pl.bq_h.push(slice_b(&lw.bq, hi));
                    pl.bk_h.push(slice_b(&lw.bk, hi));
                    pl.bv_h.push(slice_b(&lw.bv, hi));
                    pl.pred_wq.push(slice_8(&lw.wq, hi));
                    pl.pred_wk.push(slice_8(&lw.wk, hi));
                }
                pl
            })
            .collect();
        Self { weights, layers }
    }

    pub fn weights(&self) -> &Arc<TinyWeights> {
        &self.weights
    }

    /// Per-layer packed operands (the decode engine's working set).
    pub fn packed_layers(&self) -> &[PackedLayer] {
        &self.layers
    }

    /// Write `embed[tok] + pos` into `x` (`transformer::embed`'s values).
    fn embed_into(&self, tokens: &[i32], x: &mut MatF) {
        let w = &self.weights;
        let d = w.cfg.d_model;
        assert!(tokens.len() <= w.cfg.seq_len, "sequence too long");
        x.reshape(tokens.len(), d);
        for (r, (&t, xrow)) in tokens.iter().zip(x.data.chunks_mut(d)).enumerate() {
            let erow = w.embed.row(t as usize);
            let prow = w.pos.row(r);
            for ((o, &e), &p) in xrow.iter_mut().zip(erow).zip(prow) {
                *o = e + p;
            }
        }
    }

    /// One dense-substrate transformer block over `sc.x`, in place —
    /// the packed `block_dense` / masked-block / causal-block: full QKV
    /// projections (row-parallel), one Kᵀ transpose per layer, per-head
    /// attention on column views.
    fn block(&self, lw: &LayerWeights, sc: &mut Scratch, mask: BlockMask<'_>) {
        let cfg = &self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.h.reshape(l, d);
        layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
        sc.q.reshape(l, d);
        linear_into_par(&sc.h, &lw.wq, &lw.bq, &mut sc.q);
        sc.k.reshape(l, d);
        linear_into_par(&sc.h, &lw.wk, &lw.bk, &mut sc.k);
        sc.v.reshape(l, d);
        linear_into_par(&sc.h, &lw.wv, &lw.bv, &mut sc.v);
        sc.kt.reshape(d, l);
        sc.k.transpose_into(&mut sc.kt);
        sc.att.reset(l, d);
        if matches!(mask, BlockMask::Causal) {
            // head-independent: build the lower-triangular mask once
            // per block, not once per head
            sc.mask.reset(l, l);
            for r in 0..l {
                sc.mask.row_mut(r)[..=r].fill(true);
            }
        }
        let scale = 1.0 / (dh as f32).sqrt();
        for hi in 0..n_heads {
            sc.s.reset(l, l);
            scores_head(&sc.q, &sc.kt, hi, dh, &mut sc.s);
            scale_inplace(&mut sc.s, scale);
            match mask {
                BlockMask::Dense => softmax_rows(&mut sc.s),
                BlockMask::Causal => masked_softmax_rows(&mut sc.s, &sc.mask),
                BlockMask::External(m) => {
                    sc.mask.reset(l, l);
                    let head = &m[hi * l * l..(hi + 1) * l * l];
                    for (b, &mv) in sc.mask.data.iter_mut().zip(head) {
                        *b = mv > 0.5;
                    }
                    masked_softmax_rows(&mut sc.s, &sc.mask);
                }
            }
            attend_head(&sc.s, &sc.v, hi, dh, &mut sc.att);
        }
        sc.proj.reshape(l, d);
        linear_into_par(&sc.att, &lw.wo, &lw.bo, &mut sc.proj);
        add_inplace(&mut sc.x, &sc.proj);
        sc.h2.reshape(l, d);
        layernorm_into(&sc.x, &lw.ln2_g, &lw.ln2_b, &mut sc.h2);
        sc.ff.reshape(l, lw.w1.cols);
        linear_into_par(&sc.h2, &lw.w1, &lw.b1, &mut sc.ff);
        gelu_inplace(&mut sc.ff);
        sc.proj.reshape(l, d);
        linear_into_par(&sc.ff, &lw.w2, &lw.b2, &mut sc.proj);
        add_inplace(&mut sc.x, &sc.proj);
    }

    /// Final LayerNorm → mean-pool → classifier head over `sc.x`.
    fn classify_tail(&self, sc: &mut Scratch) -> Vec<f32> {
        let w = &self.weights;
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.h.reshape(l, d);
        layernorm_into(&sc.x, &w.lnf_g, &w.lnf_b, &mut sc.h);
        sc.pooled.reshape(1, d);
        mean_rows_into(&sc.h, &mut sc.pooled.data);
        sc.logits.reshape(1, w.cfg.n_classes);
        linear_into(&sc.pooled, &w.cls_w, &w.cls_b, &mut sc.logits);
        sc.logits.data.clone()
    }

    /// Packed [`super::forward_dense`] (bit-identical).
    pub fn forward_dense(&self, tokens: &[i32], sc: &mut Scratch) -> Vec<f32> {
        self.embed_into(tokens, &mut sc.x);
        for lw in &self.weights.layers {
            self.block(lw, sc, BlockMask::Dense);
        }
        self.classify_tail(sc)
    }

    /// Packed [`super::forward_masked`] (bit-identical). `masks` is
    /// row-major `[n_layers, n_heads, L, L]`, keep iff `> 0.5`.
    pub fn forward_masked(&self, tokens: &[i32], masks: &[f32], sc: &mut Scratch) -> Vec<f32> {
        let cfg = &self.weights.cfg;
        let l = tokens.len();
        let per = cfg.n_heads * l * l;
        assert_eq!(
            masks.len(),
            cfg.n_layers * per,
            "mask buffer must cover [n_layers, n_heads, L, L]"
        );
        self.embed_into(tokens, &mut sc.x);
        for (li, lw) in self.weights.layers.iter().enumerate() {
            self.block(lw, sc, BlockMask::External(&masks[li * per..(li + 1) * per]));
        }
        self.classify_tail(sc)
    }

    /// Packed [`super::forward_causal_hidden`] (bit-identical): the L×D
    /// hidden states after the last block, pre-`lnf`.
    pub fn forward_causal_hidden(&self, tokens: &[i32], sc: &mut Scratch) -> MatF {
        self.embed_into(tokens, &mut sc.x);
        for lw in &self.weights.layers {
            self.block(lw, sc, BlockMask::Causal);
        }
        sc.x.clone()
    }

    /// Packed [`super::next_token_logits`] (bit-identical).
    pub fn next_token_logits(&self, tokens: &[i32], sc: &mut Scratch) -> Vec<f32> {
        assert!(!tokens.is_empty(), "need at least one token of context");
        self.embed_into(tokens, &mut sc.x);
        for lw in &self.weights.layers {
            self.block(lw, sc, BlockMask::Causal);
        }
        let w = &self.weights;
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.h.reshape(l, d);
        layernorm_into(&sc.x, &w.lnf_g, &w.lnf_b, &mut sc.h);
        lm_logits_row(w, sc.h.row(l - 1))
    }

    /// Packed [`super::attention_probs`] (bit-identical).
    pub fn attention_probs(&self, tokens: &[i32], sc: &mut Scratch) -> Vec<Vec<MatF>> {
        let cfg = self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        self.embed_into(tokens, &mut sc.x);
        let mut all = Vec::with_capacity(self.weights.layers.len());
        for lw in &self.weights.layers {
            let (l, d) = (sc.x.rows, sc.x.cols);
            sc.h.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
            sc.q.reshape(l, d);
            linear_into_par(&sc.h, &lw.wq, &lw.bq, &mut sc.q);
            sc.k.reshape(l, d);
            linear_into_par(&sc.h, &lw.wk, &lw.bk, &mut sc.k);
            sc.kt.reshape(d, l);
            sc.k.transpose_into(&mut sc.kt);
            let mut heads = Vec::with_capacity(n_heads);
            for hi in 0..n_heads {
                sc.s.reset(l, l);
                scores_head(&sc.q, &sc.kt, hi, dh, &mut sc.s);
                scale_inplace(&mut sc.s, 1.0 / (dh as f32).sqrt());
                softmax_rows(&mut sc.s);
                heads.push(sc.s.clone());
            }
            all.push(heads);
            self.block(lw, sc, BlockMask::Dense);
        }
        all
    }

    /// Packed [`super::plan_model`]: the per-head int8 prediction
    /// operands come from pack time instead of being re-quantized per
    /// call; plans are bit-identical to unpacked planning.
    pub fn plan_model(
        &self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        sc: &mut Scratch,
    ) -> Vec<LayerPlan> {
        self.embed_into(tokens, &mut sc.x);
        let mut plans = Vec::with_capacity(self.weights.layers.len());
        for (lw, pl) in self.weights.layers.iter().zip(&self.layers) {
            let (l, d) = (sc.x.rows, sc.x.cols);
            sc.h.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
            // int8 activations (symmetric per-tensor, like the paper's
            // 8-bit deployment)
            let (hq, _) = quantize_sym8(&sc.h.data);
            let hq = MatI::from_vec(l, d, hq);
            plans.push(plan_layer_from_inputs(&hq, &pl.pred_wq, &pl.pred_wk, spls, method));
            self.block(lw, sc, BlockMask::Dense);
        }
        plans
    }

    /// Packed [`super::forward_sparse`] (bit-identical): critical-row Q
    /// generation, active-column K/V generation and MFI-gated FFN rows
    /// run on the pre-packed per-head slices, with recovery written
    /// straight into the arena. Only plan-derived index lists
    /// (`critical_rows`, `computed_tokens`) still allocate.
    pub fn forward_sparse(
        &self,
        tokens: &[i32],
        plans: &[LayerPlan],
        sc: &mut Scratch,
    ) -> Vec<f32> {
        assert_eq!(plans.len(), self.weights.layers.len());
        let cfg = self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        self.embed_into(tokens, &mut sc.x);
        let zipped = self.weights.layers.iter().zip(&self.layers).zip(plans);
        for ((lw, pl), plan) in zipped {
            let (l, d) = (sc.x.rows, sc.x.cols);
            sc.h.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
            // every head copy_from_slice-covers its columns for all rows,
            // so no zeroing needed before the recovery writes
            sc.att.reshape(l, d);
            let scale = 1.0 / (dh as f32).sqrt();
            for hi in 0..n_heads {
                let hp = &plan.heads[hi];
                let criticals = hp.sim.critical_rows();
                // --- Q generation: critical rows only ---------------
                sc.part.reshape(criticals.len(), dh);
                for (i, &row) in criticals.iter().enumerate() {
                    project_row(sc.h.row(row), &pl.wq_h[hi], &pl.bq_h[hi], sc.part.row_mut(i));
                }
                // --- K/V generation: active columns only ------------
                sc.k.reset(l, dh);
                sc.v.reset(l, dh);
                for &col in &hp.active_cols {
                    project_row(sc.h.row(col), &pl.wk_h[hi], &pl.bk_h[hi], sc.k.row_mut(col));
                    project_row(sc.h.row(col), &pl.wv_h[hi], &pl.bv_h[hi], sc.v.row_mut(col));
                }
                // --- masked attention on critical rows --------------
                sc.kt.reshape(dh, l);
                sc.k.transpose_into(&mut sc.kt);
                sc.s.reshape(criticals.len(), l);
                matmul_into(&sc.part, &sc.kt, &mut sc.s);
                scale_inplace(&mut sc.s, scale);
                sc.mask.reshape(criticals.len(), l);
                for (i, &row) in criticals.iter().enumerate() {
                    sc.mask.row_mut(i).copy_from_slice(hp.mask.row(row));
                }
                masked_softmax_rows(&mut sc.s, &sc.mask);
                sc.out.reshape(criticals.len(), dh);
                matmul_into(&sc.s, &sc.v, &mut sc.out);
                // --- recovery: replicate critical outputs to similar
                //     rows, straight into the head's att columns ------
                sc.idx.clear();
                sc.idx.resize(l, usize::MAX);
                for (i, &row) in criticals.iter().enumerate() {
                    sc.idx[row] = i;
                }
                for r in 0..l {
                    let src = sc.idx[hp.sim.rep[r]];
                    sc.att.row_mut(r)[hi * dh..(hi + 1) * dh]
                        .copy_from_slice(sc.out.row(src));
                }
            }
            sc.proj.reshape(l, d);
            linear_into_par(&sc.att, &lw.wo, &lw.bo, &mut sc.proj);
            add_inplace(&mut sc.x, &sc.proj);
            // --- FFN: MFI-representative tokens only ----------------
            sc.h2.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln2_g, &lw.ln2_b, &mut sc.h2);
            let computed = plan.ffn.computed_tokens();
            sc.part.reshape(computed.len(), d);
            for (i, &row) in computed.iter().enumerate() {
                sc.part.row_mut(i).copy_from_slice(sc.h2.row(row));
            }
            sc.ff.reshape(computed.len(), lw.w1.cols);
            linear_into_par(&sc.part, &lw.w1, &lw.b1, &mut sc.ff);
            gelu_inplace(&mut sc.ff);
            sc.out.reshape(computed.len(), d);
            linear_into_par(&sc.ff, &lw.w2, &lw.b2, &mut sc.out);
            sc.idx.clear();
            sc.idx.resize(l, usize::MAX);
            for (i, &row) in computed.iter().enumerate() {
                sc.idx[row] = i;
            }
            for r in 0..l {
                let src = sc.idx[plan.ffn.rep[r]];
                for (o, &v) in sc.x.row_mut(r).iter_mut().zip(sc.out.row(src)) {
                    *o += v;
                }
            }
        }
        self.classify_tail(sc)
    }
}

/// `s[r, c] += Σ_k q[r, hi·dh+k] · kᵀ[hi·dh+k, c]` — head `hi`'s block
/// of the attention-score matmul on column views of the packed Q and
/// the once-transposed Kᵀ. Same ikj order and zero-skip as
/// `tensor::matmul_into` over the sliced operands, so bits match the
/// per-head-copy reference exactly; `s` must be zeroed `q.rows × kt.cols`.
fn scores_head(q: &MatF, kt: &MatF, hi: usize, dh: usize, s: &mut MatF) {
    debug_assert_eq!((s.rows, s.cols), (q.rows, kt.cols));
    let n = kt.cols;
    for (r, srow) in s.data.chunks_mut(n.max(1)).enumerate() {
        let qrow = &q.row(r)[hi * dh..(hi + 1) * dh];
        for (k, &av) in qrow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = kt.row(hi * dh + k);
            for (o, &bv) in srow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `att[r, hi·dh+c] += Σ_k s[r, k] · v[k, hi·dh+c]` — head `hi`'s AV
/// matmul accumulated straight into its columns of the concatenated
/// attention output (no per-head staging copy). Zero-skip on the
/// (masked-softmax-sparse) score values, like `tensor::matmul_into`.
fn attend_head(s: &MatF, v: &MatF, hi: usize, dh: usize, att: &mut MatF) {
    debug_assert_eq!(att.rows, s.rows);
    debug_assert_eq!(s.cols, v.rows);
    let d = att.cols;
    for (r, arow) in att.data.chunks_mut(d).enumerate() {
        let orow = &mut arow[hi * dh..(hi + 1) * dh];
        for (k, &av) in s.row(r).iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &v.row(k)[hi * dh..(hi + 1) * dh];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `orow = b + hrow · w` with the **bias-first, no-zero-skip**
/// accumulation of the reference sparse Q/K/V generation loops
/// (`acc = bias; for k { acc += h·w }`), in vectorizable ikj form.
fn project_row(hrow: &[f32], w: &MatF, b: &[f32], orow: &mut [f32]) {
    debug_assert_eq!(hrow.len(), w.rows);
    debug_assert_eq!(orow.len(), w.cols);
    orow.copy_from_slice(b);
    for (k, &av) in hrow.iter().enumerate() {
        let wrow = w.row(k);
        for (o, &bv) in orow.iter_mut().zip(wrow) {
            *o += av * bv;
        }
    }
}

fn scale_inplace(m: &mut MatF, scale: f32) {
    for v in &mut m.data {
        *v *= scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        attention_probs, forward_causal_hidden, forward_dense, forward_masked, forward_sparse,
        plan_model,
    };

    fn packed() -> PackedModel {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/tiny_weights.bin");
        PackedModel::new(Arc::new(TinyWeights::load(&p).unwrap()))
    }

    fn toks(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn packed_dense_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        for l in [5usize, 17, 64] {
            let t = toks(21, l);
            assert_eq!(
                pm.forward_dense(&t, &mut sc),
                forward_dense(pm.weights(), &t),
                "L = {l}"
            );
        }
    }

    #[test]
    fn packed_masked_and_causal_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(22, 64);
        let masks = vec![1.0f32; 2 * 4 * 64 * 64];
        assert_eq!(
            pm.forward_masked(&t, &masks, &mut sc),
            forward_masked(pm.weights(), &t, &masks)
        );
        let hidden = pm.forward_causal_hidden(&t[..20], &mut sc);
        assert_eq!(hidden.data, forward_causal_hidden(pm.weights(), &t[..20]).data);
    }

    #[test]
    fn packed_planning_and_sparse_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(23, 64);
        let spls = SplsConfig::default();
        let plans = pm.plan_model(&t, &spls, QuantMethod::Hlog, &mut sc);
        assert_eq!(plans, plan_model(pm.weights(), &t, &spls, QuantMethod::Hlog));
        assert_eq!(
            pm.forward_sparse(&t, &plans, &mut sc),
            forward_sparse(pm.weights(), &t, &plans)
        );
    }

    #[test]
    fn packed_attention_probs_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(24, 64);
        let got = pm.attention_probs(&t, &mut sc);
        let want = attention_probs(pm.weights(), &t);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            for (gh, wh) in g.iter().zip(w) {
                assert_eq!(gh.data, wh.data);
            }
        }
    }

    #[test]
    fn steady_state_dense_forward_does_not_allocate_scratch() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(25, 64);
        let _ = pm.forward_dense(&t, &mut sc); // sizes the arena
        let caps = [
            sc.x.data.capacity(),
            sc.h.data.capacity(),
            sc.q.data.capacity(),
            sc.s.data.capacity(),
            sc.ff.data.capacity(),
        ];
        let _ = pm.forward_dense(&t, &mut sc);
        let after = [
            sc.x.data.capacity(),
            sc.h.data.capacity(),
            sc.q.data.capacity(),
            sc.s.data.capacity(),
            sc.ff.data.capacity(),
        ];
        assert_eq!(caps, after, "steady-state forward reallocated the arena");
    }
}
