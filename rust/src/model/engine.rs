//! The packed execution engine: a [`PackedModel`] is built **once** per
//! loaded weight set and then drives every host forward path with
//! pre-packed operands and a reusable [`Scratch`] arena, so steady-state
//! forwards on the dense substrate allocate nothing and every inner
//! loop is a contiguous slice-zip kernel the compiler autovectorizes
//! (DESIGN.md §Host kernel layout).
//!
//! What gets packed, and why:
//!
//! * **per-head `Wq/Wk/Wv` column slices** (D × Dh, row-major) — the
//!   sparse path generates Q for critical rows and K/V for active
//!   columns one head at a time, and the decode path projects exactly
//!   one row per head per step; both previously re-materialized these
//!   slices with `MatF::from_fn` on every call;
//! * **int8 predictor operands** — `plan_model` and the incremental
//!   decode predictor re-quantized each head's weight slice per request
//!   (`quantize_sym8` of the slice); the quantization is deterministic,
//!   so it is hoisted to pack time and shared by both consumers;
//! * the dense substrate paths (`forward_dense` / `forward_masked` /
//!   `forward_causal_hidden`) keep the full D × D projections (their
//!   QKV is computed for every row and column anyway, and the
//!   row-parallel `tensor::linear_into_par` wants the widest panels).
//!   The dense/causal blocks run per-head attention on **column views**
//!   of the packed Q/Kᵀ/V activations — zero per-head copies, with Kᵀ
//!   transposed once per layer so the score kernel walks contiguous
//!   rows — while the masked block and the compiled sparse path
//!   (`forward_sparse_compiled`) gather kept columns and run the
//!   SDDMM → sparse-softmax → axpy kernels of `model::sparse_kernels`,
//!   skipping pruned score work entirely (see `model::sparse_plan`).
//!
//! **Bitwise contract.** Every packed forward is bit-identical to its
//! unpacked sibling in `model::transformer`: the kernels preserve the
//! per-output-element k-accumulation order (and the reference's
//! zero-skip / bias-placement quirks) exactly, and the row-parallel
//! kernels only partition disjoint output rows. `tests/packed_parity.rs`
//! asserts this across randomized shapes, tokens and plans for all four
//! forward paths, planning, and decode.

use std::sync::Arc;

use crate::config::SplsConfig;
use crate::quant::{quantize_sym8, QuantMethod};
use crate::spls::plan::{plan_layer_from_inputs, LayerPlan};
use crate::util::mat::{MatF, MatI};
use crate::util::scratch::Scratch;

use super::sparse_kernels::{axpy_prob, dot_qk, softmax_row};
use super::sparse_plan::CompiledModelPlan;
use super::tensor::{
    add_inplace, gelu_inplace, layernorm_into, linear_into, linear_into_par,
    masked_softmax_rows, mean_rows_into, softmax_rows,
};
use super::transformer::lm_logits_row;
use super::weights::{LayerWeights, TinyWeights};

/// One layer's packed operands (indexed per head).
pub struct PackedLayer {
    /// Per-head D × Dh column slices of Wq / Wk / Wv.
    pub wq_h: Vec<MatF>,
    pub wk_h: Vec<MatF>,
    pub wv_h: Vec<MatF>,
    /// Matching per-head bias slices.
    pub bq_h: Vec<Vec<f32>>,
    pub bk_h: Vec<Vec<f32>>,
    pub bv_h: Vec<Vec<f32>>,
    /// Per-head int8 prediction operands (`quantize_sym8` of the f32
    /// slice — exactly what `plan_model` and the decode predictor
    /// computed per call before packing).
    pub pred_wq: Vec<MatI>,
    pub pred_wk: Vec<MatI>,
}

/// The packed model: immutable, cheap to share (`Arc`), `Send + Sync`.
/// Serving replicas, the planner and every decode session hold one
/// shared instance.
pub struct PackedModel {
    weights: Arc<TinyWeights>,
    layers: Vec<PackedLayer>,
}

/// Which softmax masking a dense-substrate block applies. External f32
/// masks no longer ride through here — `block_masked` gathers each
/// row's kept columns and runs the compacted kernels instead.
#[derive(Clone, Copy)]
enum BlockMask {
    /// Unmasked row softmax (`forward_dense`).
    Dense,
    /// Lower-triangular causal mask (`forward_causal_hidden`).
    Causal,
}

impl PackedModel {
    pub fn new(weights: Arc<TinyWeights>) -> Self {
        let cfg = weights.cfg;
        let dh = cfg.d_head();
        let layers = weights
            .layers
            .iter()
            .map(|lw| {
                let slice_f = |m: &MatF, hi: usize| {
                    MatF::from_fn(m.rows, dh, |r, c| m[(r, hi * dh + c)])
                };
                let slice_b = |b: &[f32], hi: usize| b[hi * dh..(hi + 1) * dh].to_vec();
                let slice_8 = |m: &MatF, hi: usize| {
                    let (q, _) = quantize_sym8(&slice_f(m, hi).data);
                    MatI::from_vec(m.rows, dh, q)
                };
                let mut pl = PackedLayer {
                    wq_h: Vec::new(),
                    wk_h: Vec::new(),
                    wv_h: Vec::new(),
                    bq_h: Vec::new(),
                    bk_h: Vec::new(),
                    bv_h: Vec::new(),
                    pred_wq: Vec::new(),
                    pred_wk: Vec::new(),
                };
                for hi in 0..cfg.n_heads {
                    pl.wq_h.push(slice_f(&lw.wq, hi));
                    pl.wk_h.push(slice_f(&lw.wk, hi));
                    pl.wv_h.push(slice_f(&lw.wv, hi));
                    pl.bq_h.push(slice_b(&lw.bq, hi));
                    pl.bk_h.push(slice_b(&lw.bk, hi));
                    pl.bv_h.push(slice_b(&lw.bv, hi));
                    pl.pred_wq.push(slice_8(&lw.wq, hi));
                    pl.pred_wk.push(slice_8(&lw.wk, hi));
                }
                pl
            })
            .collect();
        Self { weights, layers }
    }

    pub fn weights(&self) -> &Arc<TinyWeights> {
        &self.weights
    }

    /// Per-layer packed operands (the decode engine's working set).
    pub fn packed_layers(&self) -> &[PackedLayer] {
        &self.layers
    }

    /// Write `embed[tok] + pos` into `x` (`transformer::embed`'s values).
    fn embed_into(&self, tokens: &[i32], x: &mut MatF) {
        let w = &self.weights;
        let d = w.cfg.d_model;
        assert!(tokens.len() <= w.cfg.seq_len, "sequence too long");
        x.reshape(tokens.len(), d);
        for (r, (&t, xrow)) in tokens.iter().zip(x.data.chunks_mut(d)).enumerate() {
            let erow = w.embed.row(t as usize);
            let prow = w.pos.row(r);
            for ((o, &e), &p) in xrow.iter_mut().zip(erow).zip(prow) {
                *o = e + p;
            }
        }
    }

    /// One dense-substrate transformer block over `sc.x`, in place —
    /// the packed `block_dense` / masked-block / causal-block: full QKV
    /// projections (row-parallel), one Kᵀ transpose per layer, per-head
    /// attention on column views.
    fn block(&self, lw: &LayerWeights, sc: &mut Scratch, mask: BlockMask) {
        let cfg = &self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        let (l, d) = (sc.x.rows, sc.x.cols);
        self.qkv_into(lw, sc);
        sc.kt.reshape(d, l);
        sc.k.transpose_into(&mut sc.kt);
        sc.att.reset(l, d);
        if matches!(mask, BlockMask::Causal) {
            // head-independent: build the lower-triangular mask once
            // per block, not once per head
            sc.mask.reset(l, l);
            for r in 0..l {
                sc.mask.row_mut(r)[..=r].fill(true);
            }
        }
        let scale = 1.0 / (dh as f32).sqrt();
        for hi in 0..n_heads {
            sc.s.reset(l, l);
            scores_head(&sc.q, &sc.kt, hi, dh, &mut sc.s);
            scale_inplace(&mut sc.s, scale);
            match mask {
                BlockMask::Dense => softmax_rows(&mut sc.s),
                BlockMask::Causal => masked_softmax_rows(&mut sc.s, &sc.mask),
            }
            attend_head(&sc.s, &sc.v, hi, dh, &mut sc.att);
        }
        self.block_tail(lw, sc);
    }

    /// LayerNorm → full row-parallel Q/K/V projections over `sc.x`
    /// (shared by the dense-substrate blocks and the masked block).
    fn qkv_into(&self, lw: &LayerWeights, sc: &mut Scratch) {
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.h.reshape(l, d);
        layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
        sc.q.reshape(l, d);
        linear_into_par(&sc.h, &lw.wq, &lw.bq, &mut sc.q);
        sc.k.reshape(l, d);
        linear_into_par(&sc.h, &lw.wk, &lw.bk, &mut sc.k);
        sc.v.reshape(l, d);
        linear_into_par(&sc.h, &lw.wv, &lw.bv, &mut sc.v);
    }

    /// Output projection + residual + dense FFN over `sc.att`/`sc.x` —
    /// the block suffix shared by every non-sparse-FFN path.
    fn block_tail(&self, lw: &LayerWeights, sc: &mut Scratch) {
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.proj.reshape(l, d);
        linear_into_par(&sc.att, &lw.wo, &lw.bo, &mut sc.proj);
        add_inplace(&mut sc.x, &sc.proj);
        sc.h2.reshape(l, d);
        layernorm_into(&sc.x, &lw.ln2_g, &lw.ln2_b, &mut sc.h2);
        sc.ff.reshape(l, lw.w1.cols);
        linear_into_par(&sc.h2, &lw.w1, &lw.b1, &mut sc.ff);
        gelu_inplace(&mut sc.ff);
        sc.proj.reshape(l, d);
        linear_into_par(&sc.ff, &lw.w2, &lw.b2, &mut sc.proj);
        add_inplace(&mut sc.x, &sc.proj);
    }

    /// One masked-prefill block: full QKV like the dense block, but the
    /// attention gathers each row's kept columns and runs the compacted
    /// SDDMM → sparse-softmax → axpy kernels — no Kᵀ transpose, no L×L
    /// score matmul. Bit-identical to the dense-shaped masked reference:
    /// kept entries see the same accumulation chains and pruned entries
    /// never influenced the reference output (its masked softmax zeroed
    /// them before the zero-skipping AV matmul). A fully-masked row
    /// leaves its attention output zero — the raw-f32-mask path keeps
    /// that tolerance because arbitrary external masks may legally zero
    /// a row; plan-lowered execution cannot (see `model::sparse_plan`).
    fn block_masked(&self, lw: &LayerWeights, sc: &mut Scratch, masks: &[f32]) {
        let cfg = &self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        let l = sc.x.rows;
        self.qkv_into(lw, sc);
        sc.att.reset(l, sc.x.cols);
        let scale = 1.0 / (dh as f32).sqrt();
        for hi in 0..n_heads {
            let head = &masks[hi * l * l..(hi + 1) * l * l];
            let (h0, h1) = (hi * dh, (hi + 1) * dh);
            for r in 0..l {
                let mrow = &head[r * l..(r + 1) * l];
                sc.idx.clear();
                sc.idx.extend(
                    mrow.iter().enumerate().filter(|&(_, &mv)| mv > 0.5).map(|(c, _)| c),
                );
                if sc.idx.is_empty() {
                    continue; // fully-masked row: output row stays zero
                }
                let nk = sc.idx.len();
                sc.s.reshape(1, nk);
                let qrow = &sc.q.row(r)[h0..h1];
                for (j, &c) in sc.idx.iter().enumerate() {
                    sc.s.data[j] = dot_qk(qrow, &sc.k.row(c)[h0..h1]) * scale;
                }
                softmax_row(&mut sc.s.data[..nk]);
                let arow = &mut sc.att.row_mut(r)[h0..h1];
                for (j, &c) in sc.idx.iter().enumerate() {
                    let p = sc.s.data[j];
                    if p == 0.0 {
                        continue;
                    }
                    axpy_prob(p, &sc.v.row(c)[h0..h1], arow);
                }
            }
        }
        self.block_tail(lw, sc);
    }

    /// Final LayerNorm → mean-pool → classifier head over `sc.x`.
    fn classify_tail(&self, sc: &mut Scratch) -> Vec<f32> {
        let w = &self.weights;
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.h.reshape(l, d);
        layernorm_into(&sc.x, &w.lnf_g, &w.lnf_b, &mut sc.h);
        sc.pooled.reshape(1, d);
        mean_rows_into(&sc.h, &mut sc.pooled.data);
        sc.logits.reshape(1, w.cfg.n_classes);
        linear_into(&sc.pooled, &w.cls_w, &w.cls_b, &mut sc.logits);
        sc.logits.data.clone()
    }

    /// Packed [`super::forward_dense`] (bit-identical).
    pub fn forward_dense(&self, tokens: &[i32], sc: &mut Scratch) -> Vec<f32> {
        self.embed_into(tokens, &mut sc.x);
        for lw in &self.weights.layers {
            self.block(lw, sc, BlockMask::Dense);
        }
        self.classify_tail(sc)
    }

    /// Packed [`super::forward_masked`] (bit-identical). `masks` is
    /// row-major `[n_layers, n_heads, L, L]`, keep iff `> 0.5`.
    pub fn forward_masked(&self, tokens: &[i32], masks: &[f32], sc: &mut Scratch) -> Vec<f32> {
        let cfg = &self.weights.cfg;
        let l = tokens.len();
        let per = cfg.n_heads * l * l;
        assert_eq!(
            masks.len(),
            cfg.n_layers * per,
            "mask buffer must cover [n_layers, n_heads, L, L]"
        );
        self.embed_into(tokens, &mut sc.x);
        for (li, lw) in self.weights.layers.iter().enumerate() {
            self.block_masked(lw, sc, &masks[li * per..(li + 1) * per]);
        }
        self.classify_tail(sc)
    }

    /// Packed [`super::forward_causal_hidden`] (bit-identical): the L×D
    /// hidden states after the last block, pre-`lnf`.
    pub fn forward_causal_hidden(&self, tokens: &[i32], sc: &mut Scratch) -> MatF {
        self.embed_into(tokens, &mut sc.x);
        for lw in &self.weights.layers {
            self.block(lw, sc, BlockMask::Causal);
        }
        sc.x.clone()
    }

    /// Packed [`super::next_token_logits`] (bit-identical).
    pub fn next_token_logits(&self, tokens: &[i32], sc: &mut Scratch) -> Vec<f32> {
        assert!(!tokens.is_empty(), "need at least one token of context");
        self.embed_into(tokens, &mut sc.x);
        for lw in &self.weights.layers {
            self.block(lw, sc, BlockMask::Causal);
        }
        let w = &self.weights;
        let (l, d) = (sc.x.rows, sc.x.cols);
        sc.h.reshape(l, d);
        layernorm_into(&sc.x, &w.lnf_g, &w.lnf_b, &mut sc.h);
        lm_logits_row(w, sc.h.row(l - 1))
    }

    /// Packed [`super::attention_probs`] (bit-identical).
    pub fn attention_probs(&self, tokens: &[i32], sc: &mut Scratch) -> Vec<Vec<MatF>> {
        let cfg = self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        self.embed_into(tokens, &mut sc.x);
        let mut all = Vec::with_capacity(self.weights.layers.len());
        for lw in &self.weights.layers {
            let (l, d) = (sc.x.rows, sc.x.cols);
            sc.h.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
            sc.q.reshape(l, d);
            linear_into_par(&sc.h, &lw.wq, &lw.bq, &mut sc.q);
            sc.k.reshape(l, d);
            linear_into_par(&sc.h, &lw.wk, &lw.bk, &mut sc.k);
            sc.kt.reshape(d, l);
            sc.k.transpose_into(&mut sc.kt);
            let mut heads = Vec::with_capacity(n_heads);
            for hi in 0..n_heads {
                sc.s.reset(l, l);
                scores_head(&sc.q, &sc.kt, hi, dh, &mut sc.s);
                scale_inplace(&mut sc.s, 1.0 / (dh as f32).sqrt());
                softmax_rows(&mut sc.s);
                heads.push(sc.s.clone());
            }
            all.push(heads);
            self.block(lw, sc, BlockMask::Dense);
        }
        all
    }

    /// Packed [`super::plan_model`]: the per-head int8 prediction
    /// operands come from pack time instead of being re-quantized per
    /// call; plans are bit-identical to unpacked planning.
    pub fn plan_model(
        &self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        sc: &mut Scratch,
    ) -> Vec<LayerPlan> {
        self.embed_into(tokens, &mut sc.x);
        let mut plans = Vec::with_capacity(self.weights.layers.len());
        for (lw, pl) in self.weights.layers.iter().zip(&self.layers) {
            let (l, d) = (sc.x.rows, sc.x.cols);
            sc.h.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
            // int8 activations (symmetric per-tensor, like the paper's
            // 8-bit deployment)
            let (hq, _) = quantize_sym8(&sc.h.data);
            let hq = MatI::from_vec(l, d, hq);
            plans.push(plan_layer_from_inputs(&hq, &pl.pred_wq, &pl.pred_wk, spls, method));
            self.block(lw, sc, BlockMask::Dense);
        }
        plans
    }

    /// Packed [`super::forward_sparse`] (bit-identical): lowers the
    /// plans into a [`CompiledModelPlan`] and executes it. Callers that
    /// run many forwards per plan-set (the serving tier) should compile
    /// once with [`CompiledModelPlan::lower`] and call
    /// [`Self::forward_sparse_compiled`] directly.
    pub fn forward_sparse(
        &self,
        tokens: &[i32],
        plans: &[LayerPlan],
        sc: &mut Scratch,
    ) -> Vec<f32> {
        let compiled = CompiledModelPlan::lower(plans);
        self.forward_sparse_compiled(tokens, &compiled, sc)
    }

    /// The compiled SPLS forward: per head, Q is generated for the
    /// critical rows and K/V for the CSR panel columns only, the SDDMM
    /// evaluates exactly the kept (q, k) pairs, the sparse softmax
    /// normalizes each CSR row's compacted values in place, and the
    /// SpMM axpy accumulates the kept probabilities back to dense.
    /// Pruned work is *skipped*, not masked — there is no Kᵀ transpose,
    /// no L-wide score rows, no full-L zeroed K/V staging — yet every
    /// kernel preserves the reference accumulation chain, so the result
    /// is bit-identical to the unpacked `model::forward_sparse`.
    pub fn forward_sparse_compiled(
        &self,
        tokens: &[i32],
        cp: &CompiledModelPlan,
        sc: &mut Scratch,
    ) -> Vec<f32> {
        assert_eq!(cp.layers.len(), self.weights.layers.len());
        let cfg = self.weights.cfg;
        let (n_heads, dh) = (cfg.n_heads, cfg.d_head());
        self.embed_into(tokens, &mut sc.x);
        let zipped = self.weights.layers.iter().zip(&self.layers).zip(&cp.layers);
        for ((lw, pl), cl) in zipped {
            let (l, d) = (sc.x.rows, sc.x.cols);
            sc.h.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln1_g, &lw.ln1_b, &mut sc.h);
            // every head copy_from_slice-covers its columns for all rows,
            // so no zeroing needed before the recovery writes
            sc.att.reshape(l, d);
            let scale = 1.0 / (dh as f32).sqrt();
            for hi in 0..n_heads {
                let ch = &cl.heads[hi];
                let nc = ch.criticals.len();
                // --- Q generation: critical rows only ---------------
                sc.part.reshape(nc, dh);
                for (i, &row) in ch.criticals.iter().enumerate() {
                    project_row(sc.h.row(row), &pl.wq_h[hi], &pl.bq_h[hi], sc.part.row_mut(i));
                }
                // --- K/V generation: compact panels over the kept
                //     columns (no full-L staging) ---------------------
                sc.k.reshape(ch.panel_cols.len(), dh);
                sc.v.reshape(ch.panel_cols.len(), dh);
                for (p, &col) in ch.panel_cols.iter().enumerate() {
                    let hrow = sc.h.row(col as usize);
                    project_row(hrow, &pl.wk_h[hi], &pl.bk_h[hi], sc.k.row_mut(p));
                    project_row(hrow, &pl.wv_h[hi], &pl.bv_h[hi], sc.v.row_mut(p));
                }
                // --- SDDMM → sparse softmax → SpMM over CSR rows ----
                sc.s.reshape(1, ch.nnz());
                sc.out.reset(nc, dh);
                for i in 0..nc {
                    let (b, e) = (ch.row_offsets[i] as usize, ch.row_offsets[i + 1] as usize);
                    let qrow = sc.part.row(i);
                    for j in b..e {
                        let p = ch.col_indices[j] as usize;
                        sc.s.data[j] = dot_qk(qrow, sc.k.row(p)) * scale;
                    }
                    softmax_row(&mut sc.s.data[b..e]);
                    let orow = sc.out.row_mut(i);
                    for j in b..e {
                        let pv = sc.s.data[j];
                        if pv == 0.0 {
                            continue;
                        }
                        axpy_prob(pv, sc.v.row(ch.col_indices[j] as usize), orow);
                    }
                }
                // --- recovery: replicate critical outputs to similar
                //     rows, straight into the head's att columns ------
                for r in 0..l {
                    sc.att.row_mut(r)[hi * dh..(hi + 1) * dh]
                        .copy_from_slice(sc.out.row(ch.rep_pos[r] as usize));
                }
            }
            sc.proj.reshape(l, d);
            linear_into_par(&sc.att, &lw.wo, &lw.bo, &mut sc.proj);
            add_inplace(&mut sc.x, &sc.proj);
            // --- FFN: MFI-representative tokens only ----------------
            sc.h2.reshape(l, d);
            layernorm_into(&sc.x, &lw.ln2_g, &lw.ln2_b, &mut sc.h2);
            let computed = &cl.ffn.computed;
            sc.part.reshape(computed.len(), d);
            for (i, &row) in computed.iter().enumerate() {
                sc.part.row_mut(i).copy_from_slice(sc.h2.row(row));
            }
            sc.ff.reshape(computed.len(), lw.w1.cols);
            linear_into_par(&sc.part, &lw.w1, &lw.b1, &mut sc.ff);
            gelu_inplace(&mut sc.ff);
            sc.out.reshape(computed.len(), d);
            linear_into_par(&sc.ff, &lw.w2, &lw.b2, &mut sc.out);
            for r in 0..l {
                let src = cl.ffn.rep_pos[r] as usize;
                for (o, &v) in sc.x.row_mut(r).iter_mut().zip(sc.out.row(src)) {
                    *o += v;
                }
            }
        }
        self.classify_tail(sc)
    }
}

/// `s[r, c] += Σ_k q[r, hi·dh+k] · kᵀ[hi·dh+k, c]` — head `hi`'s block
/// of the attention-score matmul on column views of the packed Q and
/// the once-transposed Kᵀ. Same ikj order and zero-skip as
/// `tensor::matmul_into` over the sliced operands, so bits match the
/// per-head-copy reference exactly; `s` must be zeroed `q.rows × kt.cols`.
fn scores_head(q: &MatF, kt: &MatF, hi: usize, dh: usize, s: &mut MatF) {
    debug_assert_eq!((s.rows, s.cols), (q.rows, kt.cols));
    let n = kt.cols;
    for (r, srow) in s.data.chunks_mut(n.max(1)).enumerate() {
        let qrow = &q.row(r)[hi * dh..(hi + 1) * dh];
        for (k, &av) in qrow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = kt.row(hi * dh + k);
            for (o, &bv) in srow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `att[r, hi·dh+c] += Σ_k s[r, k] · v[k, hi·dh+c]` — head `hi`'s AV
/// matmul accumulated straight into its columns of the concatenated
/// attention output (no per-head staging copy). Zero-skip on the
/// (masked-softmax-sparse) score values, like `tensor::matmul_into`.
fn attend_head(s: &MatF, v: &MatF, hi: usize, dh: usize, att: &mut MatF) {
    debug_assert_eq!(att.rows, s.rows);
    debug_assert_eq!(s.cols, v.rows);
    let d = att.cols;
    for (r, arow) in att.data.chunks_mut(d).enumerate() {
        let orow = &mut arow[hi * dh..(hi + 1) * dh];
        for (k, &av) in s.row(r).iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &v.row(k)[hi * dh..(hi + 1) * dh];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `orow = b + hrow · w` with the **bias-first, no-zero-skip**
/// accumulation of the reference sparse Q/K/V generation loops
/// (`acc = bias; for k { acc += h·w }`), in vectorizable ikj form.
fn project_row(hrow: &[f32], w: &MatF, b: &[f32], orow: &mut [f32]) {
    debug_assert_eq!(hrow.len(), w.rows);
    debug_assert_eq!(orow.len(), w.cols);
    orow.copy_from_slice(b);
    for (k, &av) in hrow.iter().enumerate() {
        let wrow = w.row(k);
        for (o, &bv) in orow.iter_mut().zip(wrow) {
            *o += av * bv;
        }
    }
}

fn scale_inplace(m: &mut MatF, scale: f32) {
    for v in &mut m.data {
        *v *= scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        attention_probs, forward_causal_hidden, forward_dense, forward_masked, forward_sparse,
        plan_model,
    };

    fn packed() -> PackedModel {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/tiny_weights.bin");
        PackedModel::new(Arc::new(TinyWeights::load(&p).unwrap()))
    }

    fn toks(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn packed_dense_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        for l in [5usize, 17, 64] {
            let t = toks(21, l);
            assert_eq!(
                pm.forward_dense(&t, &mut sc),
                forward_dense(pm.weights(), &t),
                "L = {l}"
            );
        }
    }

    #[test]
    fn packed_masked_and_causal_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(22, 64);
        let masks = vec![1.0f32; 2 * 4 * 64 * 64];
        assert_eq!(
            pm.forward_masked(&t, &masks, &mut sc),
            forward_masked(pm.weights(), &t, &masks)
        );
        let hidden = pm.forward_causal_hidden(&t[..20], &mut sc);
        assert_eq!(hidden.data, forward_causal_hidden(pm.weights(), &t[..20]).data);
    }

    #[test]
    fn packed_planning_and_sparse_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(23, 64);
        let spls = SplsConfig::default();
        let plans = pm.plan_model(&t, &spls, QuantMethod::Hlog, &mut sc);
        assert_eq!(plans, plan_model(pm.weights(), &t, &spls, QuantMethod::Hlog));
        assert_eq!(
            pm.forward_sparse(&t, &plans, &mut sc),
            forward_sparse(pm.weights(), &t, &plans)
        );
    }

    #[test]
    fn packed_attention_probs_bit_identical_on_artifacts() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(24, 64);
        let got = pm.attention_probs(&t, &mut sc);
        let want = attention_probs(pm.weights(), &t);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            for (gh, wh) in g.iter().zip(w) {
                assert_eq!(gh.data, wh.data);
            }
        }
    }

    #[test]
    fn steady_state_dense_forward_does_not_allocate_scratch() {
        let pm = packed();
        let mut sc = Scratch::new();
        let t = toks(25, 64);
        let _ = pm.forward_dense(&t, &mut sc); // sizes the arena
        let caps = [
            sc.x.data.capacity(),
            sc.h.data.capacity(),
            sc.q.data.capacity(),
            sc.s.data.capacity(),
            sc.ff.data.capacity(),
        ];
        let _ = pm.forward_dense(&t, &mut sc);
        let after = [
            sc.x.data.capacity(),
            sc.h.data.capacity(),
            sc.q.data.capacity(),
            sc.s.data.capacity(),
            sc.ff.data.capacity(),
        ];
        assert_eq!(caps, after, "steady-state forward reallocated the arena");
    }
}
