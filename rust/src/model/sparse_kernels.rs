//! Gather/CSR microkernels for the compiled sparse execution paths
//! (`model::sparse_plan`): SDDMM dot products over kept (q, k) pairs,
//! sparse softmax over a CSR row's compacted values, and the SpMM axpy
//! back to dense. Each kernel preserves the exact per-output-element
//! accumulation chain of the dense-shaped reference it replaces
//! (k-ascending, zero-skip, `sum.max(1e-30)` guard — see DESIGN.md
//! §Host kernel layout), which is what keeps the compiled paths
//! **bit-identical** to `model::transformer` instead of merely close.

/// SDDMM dot product: `Σ_k q[k] · k_row[k]`, accumulated k-ascending
/// from 0.0 with zero `q` values skipped — the same per-element chain
/// as `tensor::matmul_row` (and `engine::scores_head`) produce for one
/// score, so a score computed only at a kept position matches the bit
/// the dense-shaped matmul would have produced there.
#[inline]
pub fn dot_qk(q: &[f32], k_row: &[f32]) -> f32 {
    debug_assert_eq!(q.len(), k_row.len());
    let mut acc = 0.0f32;
    for (&av, &bv) in q.iter().zip(k_row) {
        if av == 0.0 {
            continue;
        }
        acc += av * bv;
    }
    acc
}

/// Softmax over a compacted row of kept scores — the kept-entry chain
/// of `tensor::masked_softmax_row` with the gather already done: max
/// and exp/sum run over the values in ascending kept-column order
/// (exactly the order the masked form visits kept entries), and the
/// normalizer keeps the `sum.max(1e-30)` guard. An empty row is left
/// empty (the raw-mask path's zero-fill tolerance); plan-lowered rows
/// can never be empty (`spls::lower_mask_rows` forbids it).
pub fn softmax_row(row: &mut [f32]) {
    let mut max = f32::NEG_INFINITY;
    for &v in row.iter() {
        max = max.max(v);
    }
    if max == f32::NEG_INFINITY {
        return; // empty (or all-NaN-free empty) row: nothing to normalize
    }
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// SpMM accumulation step: `out[c] += p · v_row[c]` over the contiguous
/// value row. Callers skip `p == 0.0` entries before calling, mirroring
/// the zero-skip of `tensor::matmul_row` / `engine::attend_head` so the
/// surviving adds hit the accumulator in the identical order.
#[inline]
pub fn axpy_prob(p: f32, v_row: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v_row.len(), out.len());
    for (o, &bv) in out.iter_mut().zip(v_row) {
        *o += p * bv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tensor::{masked_softmax_row, matmul_into};
    use crate::util::mat::MatF;
    use crate::util::rng::Xoshiro256pp;

    fn rand_vec(rng: &mut Xoshiro256pp, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                // sprinkle exact zeros so the zero-skip paths engage
                if rng.f64() < 0.2 {
                    0.0
                } else {
                    (rng.f64() * 2.0 - 1.0) as f32
                }
            })
            .collect()
    }

    #[test]
    fn dot_qk_matches_matmul_row_element() {
        let mut rng = Xoshiro256pp::new(0x5dd);
        for n in [1usize, 7, 16, 33] {
            let q = rand_vec(&mut rng, n);
            let k = rand_vec(&mut rng, n);
            let a = MatF::from_vec(1, n, q.clone());
            let b = MatF::from_vec(n, 1, k.clone());
            let mut out = MatF::zeros(1, 1);
            matmul_into(&a, &b, &mut out);
            assert_eq!(dot_qk(&q, &k), out.data[0], "n = {n}");
        }
    }

    #[test]
    fn softmax_row_matches_masked_form_on_gathered_kept() {
        let mut rng = Xoshiro256pp::new(0x50f);
        for n in [1usize, 5, 12, 40] {
            let scores: Vec<f32> = (0..n).map(|_| (rng.f64() * 8.0 - 4.0) as f32).collect();
            let mask: Vec<bool> = (0..n).map(|i| i == 0 || rng.f64() < 0.5).collect();
            let mut reference = scores.clone();
            masked_softmax_row(&mut reference, &mask);
            let mut compact: Vec<f32> = scores
                .iter()
                .zip(&mask)
                .filter(|(_, &m)| m)
                .map(|(&v, _)| v)
                .collect();
            softmax_row(&mut compact);
            let kept_ref: Vec<f32> = reference
                .iter()
                .zip(&mask)
                .filter(|(_, &m)| m)
                .map(|(&v, _)| v)
                .collect();
            assert_eq!(compact, kept_ref, "n = {n}");
        }
    }

    #[test]
    fn softmax_row_leaves_empty_row_alone() {
        let mut empty: Vec<f32> = Vec::new();
        softmax_row(&mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn axpy_prob_matches_attend_accumulation() {
        let mut rng = Xoshiro256pp::new(0xa11);
        let probs = rand_vec(&mut rng, 9);
        let v = MatF::from_vec(9, 6, rand_vec(&mut rng, 54));
        // reference: matmul of the prob row against V
        let p = MatF::from_vec(1, 9, probs.clone());
        let mut want = MatF::zeros(1, 6);
        matmul_into(&p, &v, &mut want);
        let mut got = vec![0.0f32; 6];
        for (k, &pv) in probs.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            axpy_prob(pv, v.row(k), &mut got);
        }
        assert_eq!(got, want.data);
    }
}
