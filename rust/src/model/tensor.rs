//! f32 tensor ops for the host-side transformer (dense + SPLS-sparse
//! execution). Numerics mirror `python/compile/model.py` exactly:
//! tanh-GELU, LN with eps 1e-5, softmax with row-max subtraction, and
//! the same symmetric int8 fake-quant grid.
//!
//! Kernel layout (see DESIGN.md §Host kernel layout): `matmul` is a
//! plain row-major **ikj** loop — A's row is walked once (k outer,
//! skipping zero A-values, which is what makes pruned Q/K/V rows
//! cheap), and the inner loop is a contiguous slice-zip axpy over B's
//! row that the compiler autovectorizes (independent output columns, no
//! reduction). Every `*_into` variant reuses a caller-owned buffer
//! (`util::scratch`) and is the allocation-free form of its sibling;
//! `matmul_into_par` / `linear_into_par` additionally partition output
//! **rows** across the rayon pool — each row keeps the exact serial
//! per-element accumulation chain, so the parallel kernels are
//! bit-identical to the serial reference (asserted below and by
//! `tests/packed_parity.rs`).

use rayon::prelude::*;

use crate::util::mat::MatF;

/// Below this output-element count the rayon fork/join overhead exceeds
/// the matmul itself (same empirical tile as `spls::predict`).
const PAR_THRESHOLD: usize = 64 * 64;

/// C = A · B with a row-major ikj loop (zero A-values short-circuit).
pub fn matmul(a: &MatF, b: &MatF) -> MatF {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut out = MatF::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut out);
    out
}

/// The per-output-row ikj kernel shared by the serial and row-parallel
/// matmuls: k ascending, zero A-values skipped, inner axpy over the
/// contiguous B row. Per output element the accumulation chain is
/// exactly `(…(0 + a₀b₀) + a₁b₁…)` in k order.
#[inline]
fn matmul_row(arow: &[f32], b: &MatF, orow: &mut [f32]) {
    for (k, &av) in arow.iter().enumerate() {
        if av == 0.0 {
            continue; // sparse rows short-circuit (pruned Q/K/V)
        }
        let brow = b.row(k);
        for (o, &bv) in orow.iter_mut().zip(brow) {
            *o += av * bv;
        }
    }
}

/// In-place variant reusing an output buffer (hot-path allocation saver).
pub fn matmul_into(a: &MatF, b: &MatF, out: &mut MatF) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    out.data.fill(0.0);
    let n = b.cols;
    for (r, orow) in out.data.chunks_mut(n.max(1)).enumerate() {
        matmul_row(a.row(r), b, orow);
    }
}

/// Row-parallel `matmul_into`: output rows are disjoint, so they are
/// partitioned across the rayon pool; each row runs the identical
/// serial kernel, making the result bit-identical to [`matmul_into`].
/// Small shapes (or single-row inputs, i.e. decode) stay serial.
pub fn matmul_into_par(a: &MatF, b: &MatF, out: &mut MatF) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    let n = b.cols;
    if a.rows * n < PAR_THRESHOLD || a.rows <= 1 {
        return matmul_into(a, b, out);
    }
    out.data.fill(0.0);
    out.data
        .par_chunks_mut(n)
        .enumerate()
        .for_each(|(r, orow)| matmul_row(a.row(r), b, orow));
}

/// y = x · W + bias, where bias broadcasts over rows.
pub fn linear(x: &MatF, w: &MatF, bias: &[f32]) -> MatF {
    let mut y = MatF::zeros(x.rows, w.cols);
    linear_into(x, w, bias, &mut y);
    y
}

/// Buffer-reusing [`linear`]: matmul first, then the bias pass — the
/// same op order, so outputs are bit-identical.
pub fn linear_into(x: &MatF, w: &MatF, bias: &[f32], out: &mut MatF) {
    assert_eq!(bias.len(), w.cols);
    matmul_into(x, w, out);
    add_bias_rows(out, bias);
}

/// Row-parallel [`linear_into`] (see [`matmul_into_par`]).
pub fn linear_into_par(x: &MatF, w: &MatF, bias: &[f32], out: &mut MatF) {
    assert_eq!(bias.len(), w.cols);
    matmul_into_par(x, w, out);
    add_bias_rows(out, bias);
}

fn add_bias_rows(y: &mut MatF, bias: &[f32]) {
    for r in 0..y.rows {
        for (v, &b) in y.row_mut(r).iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Row-wise LayerNorm with learned gain/bias (eps = 1e-5, as python).
pub fn layernorm(x: &MatF, gain: &[f32], bias: &[f32]) -> MatF {
    let mut out = MatF::zeros(x.rows, x.cols);
    layernorm_into(x, gain, bias, &mut out);
    out
}

/// Buffer-reusing [`layernorm`] (identical float-op order).
pub fn layernorm_into(x: &MatF, gain: &[f32], bias: &[f32], out: &mut MatF) {
    assert_eq!(gain.len(), x.cols);
    assert_eq!(bias.len(), x.cols);
    assert_eq!((out.rows, out.cols), (x.rows, x.cols));
    let n = x.cols as f32;
    for r in 0..x.rows {
        let row = x.row(r);
        let mu = row.iter().sum::<f32>() / n;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (c, o) in out.row_mut(r).iter_mut().enumerate() {
            *o = (row[c] - mu) * inv * gain[c] + bias[c];
        }
    }
}

/// tanh-approximation GELU, bit-matching the python `_gelu`.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_inplace(x: &mut MatF) {
    for v in &mut x.data {
        *v = gelu(*v);
    }
}

/// Row-wise softmax with max subtraction.
pub fn softmax_rows(x: &mut MatF) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-30);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Masked row-softmax: positions with `mask == false` get probability 0.
/// Mirrors `ref.masked_attention`'s masking semantics.
pub fn masked_softmax_rows(x: &mut MatF, mask: &crate::util::mat::Mat<bool>) {
    assert_eq!((x.rows, x.cols), (mask.rows, mask.cols));
    for r in 0..x.rows {
        let mrow = &mask.data[r * mask.cols..(r + 1) * mask.cols];
        masked_softmax_row(x.row_mut(r), mrow);
    }
}

/// One row of [`masked_softmax_rows`] (the decode engine's single-query
/// form; identical op order, so decode stays bit-identical to prefill).
///
/// A fully-masked row zero-fills: this is the documented semantics of
/// the **raw-mask** paths (`forward_masked` accepts arbitrary external
/// f32 masks, which may legally zero a row, and the randomized parity
/// suites pin the zero-fill bit-for-bit). Plan-compiled sparse
/// execution must never reach this case — `spls::lower_mask_rows`
/// asserts every critical row keeps ≥ 1 column at plan-lowering time
/// (the diagonal invariant), so a fully-pruned row fails loudly at
/// compile time instead of silently propagating zeros from here.
pub fn masked_softmax_row(row: &mut [f32], mrow: &[bool]) {
    // hard assert: a keep-mask that disagrees with the score row must
    // fail at the fault site, not silently zip-truncate (the replaced
    // decode path enforced this via `Mat::from_vec`'s shape check)
    assert_eq!(row.len(), mrow.len(), "mask length != row length");
    let mut max = f32::NEG_INFINITY;
    for (v, &m) in row.iter().zip(mrow) {
        if m {
            max = max.max(*v);
        }
    }
    if max == f32::NEG_INFINITY {
        row.fill(0.0); // fully-masked row
        return;
    }
    let mut sum = 0.0;
    for (v, &m) in row.iter_mut().zip(mrow) {
        if m {
            *v = (*v - max).exp();
            sum += *v;
        } else {
            *v = 0.0;
        }
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Elementwise residual add: a += b.
pub fn add_inplace(a: &mut MatF, b: &MatF) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// Symmetric per-tensor int8 fake-quant of a weight matrix — matches
/// `model.fake_quant8` (round half away from zero, clip ±127).
pub fn fake_quant8(w: &MatF) -> MatF {
    let maxabs = w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-9);
    let s = 127.0 / maxabs;
    let mut out = w.clone();
    for v in &mut out.data {
        let q = (v.abs() * s + 0.5).floor().min(127.0) * v.signum();
        *v = q / s;
    }
    out
}

/// Mean over rows: (R, C) -> (C,) — the classifier pooling.
pub fn mean_rows(x: &MatF) -> Vec<f32> {
    let mut out = vec![0.0f32; x.cols];
    mean_rows_into(x, &mut out);
    out
}

/// Buffer-reusing [`mean_rows`]; `out` must be `cols` long.
pub fn mean_rows_into(x: &MatF, out: &mut [f32]) {
    assert_eq!(out.len(), x.cols);
    out.fill(0.0);
    for r in 0..x.rows {
        for (o, &v) in out.iter_mut().zip(x.row(r)) {
            *o += v;
        }
    }
    let inv = 1.0 / x.rows.max(1) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// argmax of a slice (ties toward the lower index, numpy convention).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::Mat;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f32);
        let eye = Mat::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(matmul(&a, &eye).data, a.data);
    }

    #[test]
    fn linear_bias_broadcasts() {
        let x = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let w = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 2.0]);
        let y = linear(&x, &w, &[10.0, 20.0]);
        assert_eq!(y.data, vec![12.0, 20.0, 10.0, 22.0]);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let y = layernorm(&x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = y.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = y.row(0).iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn gelu_known_values() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut x = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x);
        for r in 0..2 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(x.row(r).iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn masked_softmax_zeroes_masked() {
        let mut x = Mat::from_vec(1, 3, vec![5.0, 1.0, 100.0]);
        let mask = Mat::from_vec(1, 3, vec![true, true, false]);
        masked_softmax_rows(&mut x, &mask);
        assert_eq!(x.data[2], 0.0);
        assert!((x.data[0] + x.data[1] - 1.0).abs() < 1e-6);
        assert!(x.data[0] > x.data[1]);
    }

    #[test]
    fn masked_softmax_fully_masked_row_is_zero() {
        let mut x = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        let mask = Mat::from_vec(1, 2, vec![false, false]);
        masked_softmax_rows(&mut x, &mask);
        assert_eq!(x.data, vec![0.0, 0.0]);
    }

    #[test]
    fn fake_quant_idempotent() {
        let w = Mat::from_vec(1, 5, vec![0.1, -0.7, 0.33, 0.99, -1.0]);
        let q1 = fake_quant8(&w);
        let q2 = fake_quant8(&q1);
        for (a, b) in q1.data.iter().zip(&q2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mean_and_argmax() {
        let x = Mat::from_vec(2, 2, vec![1.0, 3.0, 3.0, 5.0]);
        assert_eq!(mean_rows(&x), vec![2.0, 4.0]);
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1); // tie -> lower index
    }

    fn rand_mat(rng: &mut crate::util::rng::Xoshiro256pp, r: usize, c: usize) -> MatF {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 2.0 - 1.0) as f32)
    }

    #[test]
    fn parallel_matmul_bit_identical_to_serial() {
        // 96×96 output is past PAR_THRESHOLD, so the rayon row
        // partition engages; every element must match the serial kernel
        let mut rng = crate::util::rng::Xoshiro256pp::new(91);
        let a = rand_mat(&mut rng, 96, 48);
        let b = rand_mat(&mut rng, 48, 96);
        assert!(a.rows * b.cols >= super::PAR_THRESHOLD);
        let want = matmul(&a, &b);
        let mut got = MatF::zeros(96, 96);
        matmul_into_par(&a, &b, &mut got);
        assert_eq!(got.data, want.data, "row partitioning changed bits");
        // linear variant too (bias pass after the matmul)
        let bias: Vec<f32> = (0..96).map(|i| i as f32 * 0.01).collect();
        let want = linear(&a, &b, &bias);
        let mut got = MatF::zeros(96, 96);
        linear_into_par(&a, &b, &bias, &mut got);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn into_variants_match_allocating_siblings() {
        let mut rng = crate::util::rng::Xoshiro256pp::new(92);
        let x = rand_mat(&mut rng, 5, 8);
        let w = rand_mat(&mut rng, 8, 7);
        let bias: Vec<f32> = (0..7).map(|i| i as f32 * 0.1).collect();
        let mut y = MatF::zeros(5, 7);
        linear_into(&x, &w, &bias, &mut y);
        assert_eq!(y.data, linear(&x, &w, &bias).data);

        let g = vec![1.5f32; 8];
        let b = vec![-0.25f32; 8];
        let mut ln = MatF::zeros(5, 8);
        layernorm_into(&x, &g, &b, &mut ln);
        assert_eq!(ln.data, layernorm(&x, &g, &b).data);

        let mut pooled = vec![0.0f32; 8];
        mean_rows_into(&x, &mut pooled);
        assert_eq!(pooled, mean_rows(&x));
    }

    #[test]
    fn masked_softmax_row_matches_rows_form() {
        let mut rng = crate::util::rng::Xoshiro256pp::new(93);
        let mut x = rand_mat(&mut rng, 4, 9);
        let mask = Mat::from_fn(4, 9, |r, c| (r * 7 + c * 5) % 3 != 0);
        let mut rows_form = x.clone();
        masked_softmax_rows(&mut rows_form, &mask);
        for r in 0..4 {
            masked_softmax_row(x.row_mut(r), &mask.data[r * 9..(r + 1) * 9]);
        }
        assert_eq!(x.data, rows_form.data);
    }
}
