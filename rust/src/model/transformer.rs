//! Host-side transformer forward passes over the trained tiny weights:
//! dense reference, SPLS-sparse execution (what the ESACT dataflow
//! computes, with Q-row skipping, K/V-column pruning, attention masking,
//! MFI-based FFN skipping, and recovery), and the attention probe used
//! by the local-similarity analyses (Figs 3/4).
//!
//! Numerics mirror `python/compile/model.py`; the integration tests
//! check logits against the AOT-compiled HLO executables bit-closely.

use crate::config::SplsConfig;
use crate::quant::{quantize_sym8, QuantMethod};
use crate::spls::plan::{plan_layer_from_inputs, LayerPlan};
use crate::spls::qkv::recover_rows;
use crate::util::mat::{Mat, MatF, MatI};

use super::tensor::*;
use super::weights::{LayerWeights, TinyWeights};

/// Slice head `h` (L×Dh) out of an L×D activation.
fn head_of(x: &MatF, h: usize, dh: usize) -> MatF {
    MatF::from_fn(x.rows, dh, |r, c| x[(r, h * dh + c)])
}

/// Write head `h` back into the concatenated L×D output.
fn set_head(out: &mut MatF, h: usize, dh: usize, head: &MatF) {
    for r in 0..head.rows {
        for c in 0..dh {
            out[(r, h * dh + c)] = head[(r, c)];
        }
    }
}

/// Embed a token sequence: `embed[tok] + pos`.
pub fn embed(w: &TinyWeights, tokens: &[i32]) -> MatF {
    assert!(tokens.len() <= w.cfg.seq_len, "sequence too long");
    MatF::from_fn(tokens.len(), w.cfg.d_model, |r, c| {
        w.embed[(tokens[r] as usize, c)] + w.pos[(r, c)]
    })
}

fn dense_attention_head(q: &MatF, k: &MatF, v: &MatF) -> MatF {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut s = matmul(q, &k.transpose());
    for val in &mut s.data {
        *val *= scale;
    }
    softmax_rows(&mut s);
    matmul(&s, v)
}

fn block_dense(lw: &LayerWeights, x: &MatF, n_heads: usize) -> MatF {
    let dh = x.cols / n_heads;
    let h = layernorm(x, &lw.ln1_g, &lw.ln1_b);
    let q = linear(&h, &lw.wq, &lw.bq);
    let k = linear(&h, &lw.wk, &lw.bk);
    let v = linear(&h, &lw.wv, &lw.bv);
    let mut att = MatF::zeros(x.rows, x.cols);
    for hi in 0..n_heads {
        let out = dense_attention_head(&head_of(&q, hi, dh), &head_of(&k, hi, dh), &head_of(&v, hi, dh));
        set_head(&mut att, hi, dh, &out);
    }
    let mut x1 = x.clone();
    add_inplace(&mut x1, &linear(&att, &lw.wo, &lw.bo));
    let h2 = layernorm(&x1, &lw.ln2_g, &lw.ln2_b);
    let mut ff = linear(&h2, &lw.w1, &lw.b1);
    gelu_inplace(&mut ff);
    let mut x2 = x1;
    add_inplace(&mut x2, &linear(&ff, &lw.w2, &lw.b2));
    x2
}

/// Dense forward: tokens → logits.
pub fn forward_dense(w: &TinyWeights, tokens: &[i32]) -> Vec<f32> {
    let mut x = embed(w, tokens);
    for lw in &w.layers {
        x = block_dense(lw, &x, w.cfg.n_heads);
    }
    let x = layernorm(&x, &w.lnf_g, &w.lnf_b);
    let pooled = MatF::from_vec(1, x.cols, mean_rows(&x));
    linear(&pooled, &w.cls_w, &w.cls_b).data
}

/// Masked forward implementing the AOT masked-artifact semantics on the
/// host: every attention row computes its own Q, but positions with
/// `mask == 0` are excluded from the softmax. Fed with SPLS masks whose
/// similar rows carry their critical row's mask (built per request,
/// with plan-cache reuse, by `coordinator::server`), this reproduces
/// what the ESACT dataflow produces after recovery — it is the
/// reference backend's masked program (`runtime::reference`).
///
/// `masks` is row-major `[n_layers, n_heads, L, L]`, keep iff `> 0.5`.
pub fn forward_masked(w: &TinyWeights, tokens: &[i32], masks: &[f32]) -> Vec<f32> {
    let cfg = &w.cfg;
    let n_heads = cfg.n_heads;
    let dh = cfg.d_head();
    let l = tokens.len();
    assert_eq!(
        masks.len(),
        cfg.n_layers * n_heads * l * l,
        "mask buffer must cover [n_layers, n_heads, L, L]"
    );
    let mut x = embed(w, tokens);
    for (li, lw) in w.layers.iter().enumerate() {
        let h = layernorm(&x, &lw.ln1_g, &lw.ln1_b);
        let q = linear(&h, &lw.wq, &lw.bq);
        let k = linear(&h, &lw.wk, &lw.bk);
        let v = linear(&h, &lw.wv, &lw.bv);
        let mut att = MatF::zeros(l, x.cols);
        for hi in 0..n_heads {
            let qh = head_of(&q, hi, dh);
            let kh = head_of(&k, hi, dh);
            let vh = head_of(&v, hi, dh);
            let scale = 1.0 / (dh as f32).sqrt();
            let mut s = matmul(&qh, &kh.transpose());
            for val in &mut s.data {
                *val *= scale;
            }
            let base = (li * n_heads + hi) * l * l;
            let mask = Mat::from_fn(l, l, |r, c| masks[base + r * l + c] > 0.5);
            masked_softmax_rows(&mut s, &mask);
            set_head(&mut att, hi, dh, &matmul(&s, &vh));
        }
        let mut x1 = x.clone();
        add_inplace(&mut x1, &linear(&att, &lw.wo, &lw.bo));
        let h2 = layernorm(&x1, &lw.ln2_g, &lw.ln2_b);
        let mut ff = linear(&h2, &lw.w1, &lw.b1);
        gelu_inplace(&mut ff);
        let mut x2 = x1;
        add_inplace(&mut x2, &linear(&ff, &lw.w2, &lw.b2));
        x = x2;
    }
    let x = layernorm(&x, &w.lnf_g, &w.lnf_b);
    let pooled = MatF::from_vec(1, x.cols, mean_rows(&x));
    linear(&pooled, &w.cls_w, &w.cls_b).data
}

/// Embed a single token at absolute position `pos`: `embed[tok] + pos`.
/// Positions beyond the trained table are clamped to the last row
/// (truncated absolute embeddings), which lets the decode engine run
/// past the compiled `seq_len` — within the table the row is
/// bit-identical to the matching row of [`embed`].
pub fn embed_row(w: &TinyWeights, token: i32, pos: usize) -> MatF {
    let p = pos.min(w.cfg.seq_len - 1);
    MatF::from_fn(1, w.cfg.d_model, |_, c| w.embed[(token as usize, c)] + w.pos[(p, c)])
}

/// Causal (decoder) forward over the residual stream: every attention
/// row sees only its visible prefix (lower-triangular mask through
/// `masked_softmax_rows`, the exact op sequence of `forward_masked`).
/// Returns the L×D hidden states after the last block, **pre-`lnf`**.
///
/// Row `r` depends only on rows `0..=r` — asserted by the prefix-
/// stability test below — which is what makes token-by-token KV-cache
/// decode (`decode::step`) bit-identical to re-running this prefill.
pub fn forward_causal_hidden(w: &TinyWeights, tokens: &[i32]) -> MatF {
    let n_heads = w.cfg.n_heads;
    let dh = w.cfg.d_head();
    let l = tokens.len();
    let mut x = embed(w, tokens);
    for lw in &w.layers {
        let h = layernorm(&x, &lw.ln1_g, &lw.ln1_b);
        let q = linear(&h, &lw.wq, &lw.bq);
        let k = linear(&h, &lw.wk, &lw.bk);
        let v = linear(&h, &lw.wv, &lw.bv);
        let mut att = MatF::zeros(l, x.cols);
        for hi in 0..n_heads {
            let qh = head_of(&q, hi, dh);
            let kh = head_of(&k, hi, dh);
            let vh = head_of(&v, hi, dh);
            let scale = 1.0 / (dh as f32).sqrt();
            let mut s = matmul(&qh, &kh.transpose());
            for val in &mut s.data {
                *val *= scale;
            }
            let mask = Mat::from_fn(l, l, |r, c| c <= r);
            masked_softmax_rows(&mut s, &mask);
            set_head(&mut att, hi, dh, &matmul(&s, &vh));
        }
        let mut x1 = x.clone();
        add_inplace(&mut x1, &linear(&att, &lw.wo, &lw.bo));
        let h2 = layernorm(&x1, &lw.ln2_g, &lw.ln2_b);
        let mut ff = linear(&h2, &lw.w1, &lw.b1);
        gelu_inplace(&mut ff);
        let mut x2 = x1;
        add_inplace(&mut x2, &linear(&ff, &lw.w2, &lw.b2));
        x = x2;
    }
    x
}

/// Above this vocab-row count the LM head fans logits out over the
/// rayon pool; the tiny 64-token vocab stays serial (fork/join would
/// dwarf the 64×64 dot products).
pub const LM_HEAD_PAR_VOCAB: usize = 1024;

/// Weight-tied language-model head over one `lnf`-normalized hidden
/// row: `logits[v] = Σ_c row[c] · embed[v, c]` (the tiny classifier has
/// no trained LM head, so next-token scores reuse the input embedding —
/// standard weight tying). Shared by the prefill reference and the
/// decode engine so both produce bit-identical logits.
///
/// Each logit is a dot product against `embed`'s row `v` — the rows are
/// already contiguous in the row-major embedding, so the kernel walks
/// row slices instead of indexing `embed[(v, c)]` per element, and
/// vocabularies past [`LM_HEAD_PAR_VOCAB`] partition `v` across rayon
/// (logits are independent, and each keeps the serial c-ascending
/// accumulation chain, so the parallel path is bit-identical — asserted
/// by `tests/packed_parity.rs`).
pub fn lm_logits_row(w: &TinyWeights, row: &[f32]) -> Vec<f32> {
    assert_eq!(row.len(), w.cfg.d_model);
    let logit = |v: usize| {
        let mut acc = 0.0f32;
        for (&x, &e) in row.iter().zip(w.embed.row(v)) {
            acc += x * e;
        }
        acc
    };
    if w.cfg.vocab >= LM_HEAD_PAR_VOCAB {
        use rayon::prelude::*;
        (0..w.cfg.vocab).into_par_iter().map(logit).collect()
    } else {
        (0..w.cfg.vocab).map(logit).collect()
    }
}

/// Next-token logits of a causal prefill over `tokens`: the iterated-
/// prefill reference that unbounded-budget decode must match bitwise.
pub fn next_token_logits(w: &TinyWeights, tokens: &[i32]) -> Vec<f32> {
    assert!(!tokens.is_empty(), "need at least one token of context");
    let x = forward_causal_hidden(w, tokens);
    let xf = layernorm(&x, &w.lnf_g, &w.lnf_b);
    lm_logits_row(w, xf.row(tokens.len() - 1))
}

/// Per-layer, per-head attention matrices for the similarity analyses.
pub fn attention_probs(w: &TinyWeights, tokens: &[i32]) -> Vec<Vec<MatF>> {
    let n_heads = w.cfg.n_heads;
    let dh = w.cfg.d_head();
    let mut x = embed(w, tokens);
    let mut all = Vec::with_capacity(w.layers.len());
    for lw in &w.layers {
        let h = layernorm(&x, &lw.ln1_g, &lw.ln1_b);
        let q = linear(&h, &lw.wq, &lw.bq);
        let k = linear(&h, &lw.wk, &lw.bk);
        let mut heads = Vec::with_capacity(n_heads);
        for hi in 0..n_heads {
            let qh = head_of(&q, hi, dh);
            let kh = head_of(&k, hi, dh);
            let scale = 1.0 / (dh as f32).sqrt();
            let mut s = matmul(&qh, &kh.transpose());
            for val in &mut s.data {
                *val *= scale;
            }
            softmax_rows(&mut s);
            heads.push(s);
        }
        all.push(heads);
        x = block_dense(lw, &x, n_heads);
    }
    all
}

/// Plan SPLS sparsity for every layer on *real activations*: at each
/// layer, quantize the LN'd input to int8 and run the bit-level
/// prediction pipeline per head with that layer's Wq/Wk.
pub fn plan_model(
    w: &TinyWeights,
    tokens: &[i32],
    spls: &SplsConfig,
    method: QuantMethod,
) -> Vec<LayerPlan> {
    let n_heads = w.cfg.n_heads;
    let dh = w.cfg.d_head();
    let mut x = embed(w, tokens);
    let mut plans = Vec::with_capacity(w.layers.len());
    for lw in &w.layers {
        let h = layernorm(&x, &lw.ln1_g, &lw.ln1_b);
        // int8 activations (symmetric per-tensor, like the paper's
        // 8-bit deployment)
        let (hq, _) = quantize_sym8(&h.data);
        let hq = MatI::from_vec(h.rows, h.cols, hq);
        let mut wqs = Vec::with_capacity(n_heads);
        let mut wks = Vec::with_capacity(n_heads);
        for hi in 0..n_heads {
            let slice = |m: &MatF| {
                let (q, _) = quantize_sym8(
                    &MatF::from_fn(m.rows, dh, |r, c| m[(r, hi * dh + c)]).data,
                );
                MatI::from_vec(m.rows, dh, q)
            };
            wqs.push(slice(&lw.wq));
            wks.push(slice(&lw.wk));
        }
        plans.push(plan_layer_from_inputs(&hq, &wqs, &wks, spls, method));
        x = block_dense(lw, &x, n_heads);
    }
    plans
}

/// SPLS-sparse forward implementing the ESACT dataflow on the host:
///
/// * Q rows generated only for critical rows (similar rows recovered by
///   replicating the critical row's attention output);
/// * K/V rows generated only for active columns;
/// * attention positions restricted to the SPA mask;
/// * FFN computed only for MFI-representative tokens, recovered after.
pub fn forward_sparse(w: &TinyWeights, tokens: &[i32], plans: &[LayerPlan]) -> Vec<f32> {
    assert_eq!(plans.len(), w.layers.len());
    let n_heads = w.cfg.n_heads;
    let dh = w.cfg.d_head();
    let mut x = embed(w, tokens);
    for (lw, plan) in w.layers.iter().zip(plans) {
        let l = x.rows;
        let h = layernorm(&x, &lw.ln1_g, &lw.ln1_b);
        let mut att = MatF::zeros(l, x.cols);
        for hi in 0..n_heads {
            let hp = &plan.heads[hi];
            let criticals = hp.sim.critical_rows();
            // --- Q generation: critical rows only -------------------
            let wq_h = MatF::from_fn(h.cols, dh, |r, c| lw.wq[(r, hi * dh + c)]);
            let q_part = MatF::from_fn(criticals.len(), dh, |i, c| {
                let row = criticals[i];
                let mut acc = lw.bq[hi * dh + c];
                for k in 0..h.cols {
                    acc += h[(row, k)] * wq_h[(k, c)];
                }
                acc
            });
            // --- K/V generation: active columns only ----------------
            let wk_h = MatF::from_fn(h.cols, dh, |r, c| lw.wk[(r, hi * dh + c)]);
            let wv_h = MatF::from_fn(h.cols, dh, |r, c| lw.wv[(r, hi * dh + c)]);
            let mut kfull = MatF::zeros(l, dh);
            let mut vfull = MatF::zeros(l, dh);
            for &col in &hp.active_cols {
                for c in 0..dh {
                    let mut ka = lw.bk[hi * dh + c];
                    let mut va = lw.bv[hi * dh + c];
                    for k in 0..h.cols {
                        ka += h[(col, k)] * wk_h[(k, c)];
                        va += h[(col, k)] * wv_h[(k, c)];
                    }
                    kfull[(col, c)] = ka;
                    vfull[(col, c)] = va;
                }
            }
            // --- masked attention on critical rows ------------------
            let scale = 1.0 / (dh as f32).sqrt();
            let mut s = matmul(&q_part, &kfull.transpose());
            for v in &mut s.data {
                *v *= scale;
            }
            let crit_mask = Mat::from_fn(criticals.len(), l, |i, c| hp.mask[(criticals[i], c)]);
            masked_softmax_rows(&mut s, &crit_mask);
            let out_part = matmul(&s, &vfull);
            // --- recovery: replicate critical outputs to similar rows
            let out_full = recover_rows(&out_part, &hp.sim);
            set_head(&mut att, hi, dh, &out_full);
        }
        let mut x1 = x.clone();
        add_inplace(&mut x1, &linear(&att, &lw.wo, &lw.bo));
        // --- FFN: MFI-representative tokens only --------------------
        let h2 = layernorm(&x1, &lw.ln2_g, &lw.ln2_b);
        let computed = plan.ffn.computed_tokens();
        let h2_part = MatF::from_fn(computed.len(), h2.cols, |i, c| h2[(computed[i], c)]);
        let mut ff = linear(&h2_part, &lw.w1, &lw.b1);
        gelu_inplace(&mut ff);
        let ffn_part = linear(&ff, &lw.w2, &lw.b2);
        let ffn_full = recover_rows(&ffn_part, &crate::spls::SimilarityMap {
            rep: plan.ffn.rep.clone(),
            window: l,
        });
        let mut x2 = x1;
        add_inplace(&mut x2, &ffn_full);
        x = x2;
    }
    let x = layernorm(&x, &w.lnf_g, &w.lnf_b);
    let pooled = MatF::from_vec(1, x.cols, mean_rows(&x));
    linear(&pooled, &w.cls_w, &w.cls_b).data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SplsConfig;

    fn weights() -> TinyWeights {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/tiny_weights.bin");
        TinyWeights::load(&p).unwrap()
    }

    fn toks(seed: u64, l: usize, vocab: u64) -> Vec<i32> {
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(vocab) as i32).collect()
    }

    #[test]
    fn dense_forward_finite_logits() {
        let w = weights();
        let logits = forward_dense(&w, &toks(1, 64, 64));
        assert_eq!(logits.len(), 16);
        assert!(logits.iter().all(|v| v.is_finite()));
        // logits should be non-degenerate (trained model)
        let spread = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
            - logits.iter().fold(f32::INFINITY, |m, &v| m.min(v));
        assert!(spread > 0.5, "spread {spread}");
    }

    #[test]
    fn attention_probs_rows_sum_to_one() {
        let w = weights();
        let probs = attention_probs(&w, &toks(2, 64, 64));
        assert_eq!(probs.len(), 2);
        assert_eq!(probs[0].len(), 4);
        for row in 0..64 {
            let s: f32 = probs[0][0].row(row).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sparse_with_dense_plan_matches_dense() {
        // top_k = 1.0 and no similarity -> the sparse path must equal
        // the dense path (all rows critical, full mask, all columns).
        let w = weights();
        let t = toks(3, 64, 64);
        let spls = SplsConfig {
            top_k: 1.0,
            sim_threshold: -1.0, // nothing is similar
            ffn_threshold: usize::MAX,
            window: 8,
        };
        let plans = plan_model(&w, &t, &spls, QuantMethod::Hlog);
        let dense = forward_dense(&w, &t);
        let sparse = forward_sparse(&w, &t, &plans);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_forward_close_to_dense_at_paper_operating_point() {
        let w = weights();
        let t = toks(4, 64, 64);
        let spls = SplsConfig::default();
        let plans = plan_model(&w, &t, &spls, QuantMethod::Hlog);
        let dense = forward_dense(&w, &t);
        let sparse = forward_sparse(&w, &t, &plans);
        // classifications usually agree; logits stay in the same ballpark
        assert!(sparse.iter().all(|v| v.is_finite()));
        let d_arg = argmax(&dense);
        let s_arg = argmax(&sparse);
        // not asserting equality on a single example (that's the
        // accuracy harness's statistical job), but the plan must have
        // real sparsity
        let q_sp: f64 = plans.iter().map(|p| p.q_sparsity()).sum::<f64>() / 2.0;
        assert!(q_sp >= 0.0);
        let _ = (d_arg, s_arg);
    }

    #[test]
    fn masked_forward_full_mask_equals_dense() {
        let w = weights();
        let t = toks(6, 64, 64);
        let masks = vec![1.0f32; 2 * 4 * 64 * 64];
        let dense = forward_dense(&w, &t);
        let masked = forward_masked(&w, &t, &masks);
        for (a, b) in dense.iter().zip(&masked) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn masked_forward_with_spls_masks_is_finite_and_sparse_aware() {
        let w = weights();
        let t = toks(7, 64, 64);
        let plans = plan_model(&w, &t, &SplsConfig::default(), QuantMethod::Hlog);
        let l = 64usize;
        let mut masks = Vec::with_capacity(2 * 4 * l * l);
        for p in &plans {
            for h in &p.heads {
                for r in 0..l {
                    let src = h.sim.rep[r];
                    for c in 0..l {
                        masks.push(if h.mask[(src, c)] { 1.0 } else { 0.0 });
                    }
                }
            }
        }
        let logits = forward_masked(&w, &t, &masks);
        assert_eq!(logits.len(), 16);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causal_hidden_is_prefix_stable() {
        // row r of the causal forward may depend only on rows 0..=r:
        // extending the sequence must leave earlier rows bit-identical
        let w = weights();
        let t = toks(8, 32, 64);
        let short = forward_causal_hidden(&w, &t[..16]);
        let long = forward_causal_hidden(&w, &t);
        for r in 0..16 {
            assert_eq!(short.row(r), long.row(r), "row {r} changed when the suffix grew");
        }
    }

    #[test]
    fn next_token_logits_vocab_sized_and_finite() {
        let w = weights();
        let logits = next_token_logits(&w, &toks(9, 24, 64));
        assert_eq!(logits.len(), w.cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        let spread = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
            - logits.iter().fold(f32::INFINITY, |m, &v| m.min(v));
        assert!(spread > 0.0, "degenerate LM head");
    }

    #[test]
    fn embed_row_matches_embed_within_table_and_clamps_beyond() {
        let w = weights();
        let t = toks(10, 64, 64);
        let full = embed(&w, &t);
        for p in [0usize, 1, 63] {
            assert_eq!(embed_row(&w, t[p], p).row(0), full.row(p));
        }
        // beyond the trained table: clamped to the last position row
        assert_eq!(embed_row(&w, t[0], 200).data, embed_row(&w, t[0], 63).data);
    }

    #[test]
    fn plan_model_produces_per_layer_head_plans() {
        let w = weights();
        let plans = plan_model(&w, &toks(5, 64, 64), &SplsConfig::default(), QuantMethod::Hlog);
        assert_eq!(plans.len(), 2);
        for p in &plans {
            assert_eq!(p.heads.len(), 4);
            assert!(p.ffn.validate());
            for h in &p.heads {
                assert!(h.sim.validate());
            }
        }
    }
}
