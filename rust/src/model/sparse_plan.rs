//! The sparse-plan compiler: lowers a request's per-layer SPLS
//! [`LayerPlan`]s (boolean keep-masks + similarity/MFI maps) into the
//! compact index structures the gather/CSR kernels execute — so the
//! formal phase *skips* pruned work instead of walking dense-shaped
//! loops gated by masks.
//!
//! Per head, the compiled plan carries:
//!
//! * the **critical rows** (ascending) whose Q is generated and whose
//!   attention is computed — everything else is recovered by
//!   replication;
//! * the **panel columns** — the ascending union of kept columns over
//!   the critical rows; K/V are projected only for these positions,
//!   into a compact `panel × Dh` buffer (no full-L zeroed staging);
//! * **CSR row-offsets / col-indices** over the critical rows, with
//!   column ids re-based onto panel positions — SDDMM evaluates only
//!   these (q, k) pairs, sparse softmax normalizes each CSR row in
//!   place, and the SpMM axpy scatters back to dense per kept entry;
//! * a per-token **rep_pos** map (token row → position of its
//!   representative in the compacted output) so recovery is a single
//!   indexed copy per row.
//!
//! Lowering asserts the **diagonal invariant** via
//! [`crate::spls::lower_mask_rows`]: every critical row keeps ≥ 1
//! column (top-k keeps ⌈k·L⌉ ≥ 1, the causal path force-includes the
//! diagonal), so a fully-pruned attention row cannot reach the kernels
//! — a hostile or corrupted plan fails loudly at compile time instead
//! of flowing a silently zero-filled row downstream.
//!
//! **Plan lifetime.** A compiled plan borrows nothing and is built once
//! per (request, plan-set): the serving tier compiles right after the
//! plan-cache lookup and executes every forward of the request against
//! it; `PackedModel::forward_sparse` compiles internally per call (its
//! callers hand it raw `LayerPlan`s). Lowering is O(nnz) index
//! shuffling — three orders of magnitude below the MACs it deletes.
//!
//! **Parity.** The compiled kernels preserve the reference accumulation
//! chains exactly (see `model::sparse_kernels`), so compiled execution
//! is bit-identical to the unpacked `model::transformer` paths. The
//! epsilon corridor ([`PARITY_EPS`]) exists for comparisons across
//! *different* dataflows — e.g. `forward_sparse` vs `forward_masked`
//! under a nothing-gated plan, whose bias placement and accumulation
//! widths legitimately differ by float reassociation.

use crate::spls::plan::{lower_mask_rows, LayerPlan};

/// Logit-space tolerance for cross-dataflow parity: two semantically
/// identical forwards whose accumulation chains differ (bias-first
/// per-head projection vs full-width matmul + bias-after) agree to
/// well within this bound on the tiny classifier's logits. Bitwise
/// suites stay the contract wherever the chain is preserved; this
/// corridor only covers documented reorderings.
pub const PARITY_EPS: f32 = 1e-3;

/// True iff `a` and `b` agree elementwise within `eps`.
pub fn within_parity_corridor(a: &[f32], b: &[f32], eps: f32) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= eps)
}

/// One head's compiled attention: gather lists + CSR structure over the
/// critical rows, with columns re-based onto the K/V panel.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledHeadPlan {
    /// Critical token rows, ascending (Q generation + attention set).
    pub criticals: Vec<usize>,
    /// `rep_pos[r]` = index into `criticals` of row r's representative.
    pub rep_pos: Vec<u32>,
    /// Ascending union of kept columns over the critical rows — the
    /// K/V gather list (a subset of the plan's `active_cols`; columns
    /// no critical row keeps are never read, so they are not projected).
    pub panel_cols: Vec<u32>,
    /// `criticals.len() + 1` CSR offsets into `col_indices`.
    pub row_offsets: Vec<u32>,
    /// Kept positions as indices **into `panel_cols`**, ascending per
    /// row (panel columns are ascending, so panel order = column order).
    pub col_indices: Vec<u32>,
}

impl CompiledHeadPlan {
    pub fn nnz(&self) -> usize {
        self.col_indices.len()
    }
}

/// The FFN's compiled gather: MFI-representative rows + recovery map.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledFfnPlan {
    /// Computed (representative) token rows, ascending.
    pub computed: Vec<usize>,
    /// `rep_pos[r]` = index into `computed` of row r's representative.
    pub rep_pos: Vec<u32>,
}

/// One layer's compiled plan.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledLayerPlan {
    pub heads: Vec<CompiledHeadPlan>,
    pub ffn: CompiledFfnPlan,
}

/// The whole model's compiled plan — what the serving tier holds per
/// request and `forward_sparse_compiled` executes.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledModelPlan {
    pub layers: Vec<CompiledLayerPlan>,
}

/// Build the position map `rep_pos` for a representative map `rep`
/// whose fixed points are listed (ascending) in `members`.
fn position_map(rep: &[usize], members: &[usize]) -> Vec<u32> {
    let mut pos = vec![u32::MAX; rep.len()];
    for (i, &m) in members.iter().enumerate() {
        pos[m] = i as u32;
    }
    rep.iter()
        .map(|&r| {
            let p = pos[r];
            assert!(p != u32::MAX, "representative {r} is not a member row");
            p
        })
        .collect()
}

impl CompiledModelPlan {
    /// Compile per-layer SPLS plans into gather/CSR execution form.
    /// Panics (diagonal invariant) if any critical row keeps nothing.
    pub fn lower(plans: &[LayerPlan]) -> Self {
        let layers = plans
            .iter()
            .map(|plan| {
                let heads = plan.heads.iter().map(lower_head).collect();
                let computed = plan.ffn.computed_tokens();
                let rep_pos = position_map(&plan.ffn.rep, &computed);
                CompiledLayerPlan { heads, ffn: CompiledFfnPlan { computed, rep_pos } }
            })
            .collect();
        Self { layers }
    }
}

fn lower_head(hp: &crate::spls::qkv::HeadPlan) -> CompiledHeadPlan {
    let criticals = hp.sim.critical_rows();
    let rep_pos = position_map(&hp.sim.rep, &criticals);
    // absolute kept columns per critical row (empty rows forbidden —
    // this is the loud failure the silent zero-fill used to hide)
    let csr = lower_mask_rows(&hp.mask, &criticals, true);
    // panel = ascending union of kept columns; re-base the CSR columns
    // onto panel positions
    let l = hp.mask.cols;
    let mut on_panel = vec![u32::MAX; l];
    let mut panel_cols = Vec::new();
    for &c in &csr.col_indices {
        if on_panel[c as usize] == u32::MAX {
            on_panel[c as usize] = 0; // mark; position assigned below
            panel_cols.push(c);
        }
    }
    panel_cols.sort_unstable();
    for (i, &c) in panel_cols.iter().enumerate() {
        on_panel[c as usize] = i as u32;
    }
    let col_indices = csr.col_indices.iter().map(|&c| on_panel[c as usize]).collect();
    CompiledHeadPlan {
        criticals,
        rep_pos,
        panel_cols,
        row_offsets: csr.row_offsets,
        col_indices,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SplsConfig;
    use crate::spls::plan::plan_layer;
    use crate::util::mat::MatI;
    use crate::util::rng::Xoshiro256pp;

    fn synth_plan(l: usize, h: usize, seed: u64) -> LayerPlan {
        let mut rng = Xoshiro256pp::new(seed);
        let pams: Vec<MatI> = (0..h)
            .map(|_| {
                MatI::from_fn(l, l, |r, c| {
                    ((r / 2 * 13 + c * 3) % 61) as i32 + rng.int_in(-1, 1) as i32
                })
            })
            .collect();
        plan_layer(&pams, &SplsConfig::default())
    }

    #[test]
    fn lowered_plan_structure_is_consistent() {
        let plan = synth_plan(32, 4, 7);
        let cp = CompiledModelPlan::lower(std::slice::from_ref(&plan));
        assert_eq!(cp.layers.len(), 1);
        let layer = &cp.layers[0];
        assert_eq!(layer.heads.len(), 4);
        for (hp, ch) in plan.heads.iter().zip(&layer.heads) {
            assert_eq!(ch.criticals, hp.sim.critical_rows());
            assert_eq!(ch.row_offsets.len(), ch.criticals.len() + 1);
            assert_eq!(*ch.row_offsets.last().unwrap() as usize, ch.nnz());
            // offsets monotone, every row non-empty
            for w in ch.row_offsets.windows(2) {
                assert!(w[0] < w[1], "empty or reversed CSR row");
            }
            // panel ascending + unique; per-row panel indices ascending
            assert!(ch.panel_cols.windows(2).all(|w| w[0] < w[1]));
            for w in ch.row_offsets.windows(2) {
                let row = &ch.col_indices[w[0] as usize..w[1] as usize];
                assert!(row.windows(2).all(|p| p[0] < p[1]));
            }
            // nnz equals kept entries over critical rows; every kept
            // (row, col) appears at its panel position
            let mut nnz = 0;
            for (i, &r) in ch.criticals.iter().enumerate() {
                let row = &ch.col_indices
                    [ch.row_offsets[i] as usize..ch.row_offsets[i + 1] as usize];
                let cols: Vec<usize> =
                    row.iter().map(|&p| ch.panel_cols[p as usize] as usize).collect();
                let want: Vec<usize> = (0..hp.mask.cols)
                    .filter(|&c| hp.mask[(r, c)])
                    .collect();
                assert_eq!(cols, want, "row {r}");
                nnz += want.len();
            }
            assert_eq!(nnz, ch.nnz());
            // panel ⊆ active_cols
            for &c in &ch.panel_cols {
                assert!(hp.active_cols.contains(&(c as usize)), "panel col {c}");
            }
            // rep_pos round-trips through criticals
            for (r, &p) in ch.rep_pos.iter().enumerate() {
                assert_eq!(ch.criticals[p as usize], hp.sim.rep[r], "row {r}");
            }
        }
        // FFN gather round-trips too
        assert_eq!(layer.ffn.computed, plan.ffn.computed_tokens());
        for (r, &p) in layer.ffn.rep_pos.iter().enumerate() {
            assert_eq!(layer.ffn.computed[p as usize], plan.ffn.rep[r]);
        }
    }

    #[test]
    #[should_panic(expected = "diagonal invariant")]
    fn hostile_all_false_mask_row_fails_at_lowering() {
        use crate::spls::qkv::HeadPlan;
        use crate::spls::similarity::SimilarityMap;
        use crate::util::mat::Mat;
        let l = 6;
        // row 2 keeps nothing — a corrupted plan the compiler must
        // refuse rather than zero-fill
        let mask = Mat::from_fn(l, l, |r, c| r != 2 && (c == r || c == 0));
        let sim = SimilarityMap { rep: (0..l).collect(), window: 4 };
        let head = HeadPlan::new(mask, sim);
        let plan = LayerPlan {
            heads: vec![head],
            ffn: crate::spls::mfi::FfnPlan { rep: (0..l).collect() },
        };
        let _ = CompiledModelPlan::lower(&[plan]);
    }

    #[test]
    fn parity_corridor_helper() {
        assert!(within_parity_corridor(&[1.0, 2.0], &[1.0 + 5e-4, 2.0 - 5e-4], PARITY_EPS));
        assert!(!within_parity_corridor(&[1.0], &[1.0 + 2e-3], PARITY_EPS));
        assert!(!within_parity_corridor(&[1.0], &[1.0, 2.0], PARITY_EPS));
    }
}
