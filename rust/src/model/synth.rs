//! Synthetic local-similarity data generator — bit-exact mirror of
//! `python/compile/data.py` (same xoshiro256++ stream, same run/cluster
//! construction), so both sides regenerate identical splits from a seed.

use crate::util::rng::Xoshiro256pp;

pub const N_CLUSTERS: u64 = 16;
pub const VARIANTS: u64 = 4;

/// One (tokens, label) example: runs of 2..8 same-cluster tokens;
/// label = majority cluster (ties -> lowest id, argmax convention).
pub fn gen_example(rng: &mut Xoshiro256pp, seq_len: usize) -> (Vec<i32>, i32) {
    let mut toks = vec![0i32; seq_len];
    let mut counts = [0i64; N_CLUSTERS as usize];
    let mut pos = 0usize;
    while pos < seq_len {
        let cluster = rng.below(N_CLUSTERS);
        let run = (2 + rng.below(7)).min((seq_len - pos) as u64);
        for _ in 0..run {
            toks[pos] = (cluster * VARIANTS + rng.below(VARIANTS)) as i32;
            pos += 1;
        }
        counts[cluster as usize] += run as i64;
    }
    let label = counts
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i as i32)
        .unwrap();
    (toks, label)
}

/// A batch of examples.
pub fn gen_batch(rng: &mut Xoshiro256pp, n: usize, seq_len: usize) -> (Vec<Vec<i32>>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let (t, l) = gen_example(rng, seq_len);
        xs.push(t);
        ys.push(l);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::TestSet;
    use std::path::Path;

    #[test]
    fn matches_exported_testset_bit_exact() {
        // python exported tiny_testset.bin from Xoshiro256pp(1234);
        // regenerating from the same seed must match exactly — the
        // cross-language PRNG contract.
        let set = TestSet::load(
            &Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny_testset.bin"),
        )
        .unwrap();
        let mut rng = Xoshiro256pp::new(1234);
        let (xs, ys) = gen_batch(&mut rng, set.len(), 64);
        assert_eq!(xs, set.tokens, "token streams diverge");
        assert_eq!(ys, set.labels, "labels diverge");
    }

    #[test]
    fn label_is_majority_cluster() {
        let mut rng = Xoshiro256pp::new(9);
        for _ in 0..50 {
            let (toks, label) = gen_example(&mut rng, 48);
            let mut counts = [0usize; 16];
            for &t in &toks {
                counts[(t as u64 / VARIANTS) as usize] += 1;
            }
            let max = *counts.iter().max().unwrap();
            assert_eq!(counts[label as usize], max);
            // argmax tie convention: no earlier cluster has the same count
            for c in 0..label as usize {
                assert!(counts[c] < max);
            }
        }
    }

    #[test]
    fn adjacent_tokens_share_clusters() {
        let mut rng = Xoshiro256pp::new(99);
        let (xs, _) = gen_batch(&mut rng, 64, 64);
        let mut same = 0usize;
        let mut total = 0usize;
        for toks in &xs {
            for w in toks.windows(2) {
                same += usize::from(w[0] as u64 / VARIANTS == w[1] as u64 / VARIANTS);
                total += 1;
            }
        }
        assert!(same as f64 / total as f64 > 0.5);
    }
}
