//! # ESACT — End-to-end Sparse Accelerator for Compute-intensive
//! Transformers via local similarity
//!
//! Full-system reproduction of *ESACT* (Liu, Deng, Pu, Lu — 2025):
//! the SPLS sparsity-prediction algorithm, a software model of the
//! bit-level prediction unit, a cycle-level simulator of the 16×64-PE
//! accelerator (progressive generation + dynamic allocation), energy /
//! area models, accelerator baselines (dense ASIC, V100, SpAtten,
//! Sanger, FACT), the 26-benchmark workload zoo, and a serving
//! coordinator that runs AOT-compiled JAX/Pallas artifacts through the
//! PJRT C API (`xla` crate) with python never on the request path.
//!
//! See `DESIGN.md` for the paper → module map and `EXPERIMENTS.md` for
//! the measured reproduction of every table and figure.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod spls;
pub mod util;
pub mod workloads;
