//! # ESACT — End-to-end Sparse Accelerator for Compute-intensive
//! Transformers via local similarity
//!
//! Full-system reproduction of *ESACT* (Liu, Deng, Pu, Lu — 2025):
//! the SPLS sparsity-prediction algorithm, a software model of the
//! bit-level prediction unit, a cycle-level simulator of the 16×64-PE
//! accelerator (progressive generation + dynamic allocation), energy /
//! area models, accelerator baselines (dense ASIC, V100, SpAtten,
//! Sanger, FACT), the 26-benchmark workload zoo, and a serving
//! coordinator with python never on the request path.
//!
//! The serve-time executor is backend-neutral (`runtime::`): the
//! default, hermetic build interprets the trained tiny transformer in
//! pure Rust (`runtime::reference`); the `pjrt` cargo feature swaps in
//! AOT-compiled JAX/Pallas artifacts run through the PJRT C API
//! (`xla` crate — see Cargo.toml before enabling).
//!
//! Generation workloads run through `decode::`: a sparsity-aware KV
//! cache (SPLS-scored eviction), incremental per-step SPLS planning,
//! and a streaming `Server::serve_generate` path that continuously
//! batches decode slices across the replica tier.
//!
//! The tier is reachable over the network through `net::` (`esact
//! serve --http`): a std-only HTTP/1.1 gateway with batched
//! `/v1/classify`, chunked-streaming `/v1/generate`, Prometheus
//! `/metrics`, admission-bound 429 backpressure, and graceful drain —
//! results over the wire are bit-identical to the in-process paths
//! (`tests/integration_gateway.rs`).
//!
//! Host execution runs on the **packed engine** (`model::engine`): a
//! `PackedModel` built once per weight set (per-head weight slices,
//! pre-quantized predictor operands) drives every forward path with a
//! reusable scratch arena (`util::scratch`) and row-parallel
//! autovectorized kernels — bit-identical to the unpacked
//! `model::transformer` references (`tests/packed_parity.rs`), with the
//! packed-vs-unpacked speedup gated in CI (`benches/forward.rs`).
//!
//! The SPLS→simulator hot path is parallelized with rayon: per-head
//! planning (`spls::plan_layer`), Q/K prediction and row-partitioned
//! HLog matmuls (`spls::predict`), and per-layer simulation fan-out
//! (`sim::engine::simulate_model`) — all bit-deterministic (asserted
//! by tests against single-thread runs).
//!
//! See `DESIGN.md` for the paper → module map and `README.md` for
//! build/test/bench commands.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod decode;
pub mod energy;
pub mod model;
pub mod net;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod spls;
pub mod util;
pub mod workloads;
