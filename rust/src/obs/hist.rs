//! Fixed-bucket log2 latency histograms: 28 power-of-two bucket bounds
//! from ~1 µs (1024 ns) to ~137 s plus an overflow bucket, recorded
//! with relaxed atomics so every request can be observed on the hot
//! path without locks or sampling. Quantiles are extracted from a
//! snapshot by linear interpolation inside the covering bucket and
//! clamped to the observed min/max, so `p50 ≤ p99 ≤ max` holds exactly
//! — the replacement for the sorted-vector percentile math the leader
//! lanes used to carry (`ServeOutcome`/`GenerateOutcome`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of finite buckets; bucket `i` covers `(BOUNDS_NS[i-1],
/// BOUNDS_NS[i]]` nanoseconds (the first covers `[0, 1024]`).
pub const N_BUCKETS: usize = 28;

/// Upper bounds in nanoseconds: `1024 << i`, ~1 µs … ~137 s.
pub const BOUNDS_NS: [u64; N_BUCKETS] = {
    let mut b = [0u64; N_BUCKETS];
    let mut i = 0;
    while i < N_BUCKETS {
        b[i] = 1024u64 << i;
        i += 1;
    }
    b
};

/// Lock-free latency histogram. One extra slot past [`N_BUCKETS`]
/// counts overflow (`+Inf` in the Prometheus exposition).
pub struct LatencyHistogram {
    buckets: [AtomicU64; N_BUCKETS + 1],
    sum_ns: AtomicU64,
    /// Smallest observation (u64::MAX while empty) — quantile clamp.
    min_ns: AtomicU64,
    /// Largest observation — quantile clamp.
    max_ns: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, d: Duration) {
        self.observe_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn observe_ns(&self, ns: u64) {
        // first bound >= ns; everything past the last bound overflows
        let idx = BOUNDS_NS.partition_point(|&b| b < ns);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// A point-in-time copy for rendering/quantiles. The snapshot's
    /// `count` is derived from the bucket counts, so `_count` always
    /// equals the bucket sum even if a concurrent observe lands
    /// between the individual loads.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = buckets.iter().sum();
        HistSnapshot {
            buckets,
            count,
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            min_ns: self.min_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A consistent copy of one histogram's state.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    /// Per-bucket (non-cumulative) counts, length [`N_BUCKETS`] + 1
    /// (the last slot is the overflow bucket).
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl HistSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean observation in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 / 1e9
        }
    }

    /// Quantile in seconds by linear interpolation inside the covering
    /// bucket, clamped to the observed `[min, max]` — so reported
    /// percentiles never exceed the largest real sample and are
    /// monotone in `q`. Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        let mut ns = self.max_ns as f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = cum as f64;
            cum += c;
            if cum as f64 >= target {
                let lower = if i == 0 { 0.0 } else { BOUNDS_NS[i - 1] as f64 };
                let upper =
                    if i < N_BUCKETS { BOUNDS_NS[i] as f64 } else { self.max_ns as f64 };
                let frac = ((target - before) / c as f64).clamp(0.0, 1.0);
                ns = lower + frac * (upper - lower);
                break;
            }
        }
        ns.clamp(self.min_ns as f64, self.max_ns as f64) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_log2_from_a_microsecond() {
        assert_eq!(BOUNDS_NS[0], 1024);
        assert_eq!(BOUNDS_NS[1], 2048);
        assert_eq!(BOUNDS_NS[N_BUCKETS - 1], 137_438_953_472, "~137 s cap");
        assert!(BOUNDS_NS.windows(2).all(|w| w[1] == 2 * w[0]));
    }

    #[test]
    fn observations_land_in_the_covering_bucket() {
        let h = LatencyHistogram::new();
        h.observe_ns(1000); // <= 1024       -> bucket 0
        h.observe_ns(1024); // boundary      -> bucket 0
        h.observe_ns(1025); // just past     -> bucket 1
        h.observe(Duration::from_secs(200)); // past the last bound -> overflow
        let s = h.snapshot();
        assert_eq!(s.buckets[0], 2);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[N_BUCKETS], 1);
        assert_eq!(s.count, 4);
        assert_eq!(s.min_ns, 1000);
        assert_eq!(s.max_ns, 200_000_000_000);
    }

    #[test]
    fn quantiles_interpolate_and_clamp_to_observed_extremes() {
        // 3×1 ms (bucket (524288, 1048576]) + 1×4 ms (bucket
        // (2097152, 4194304]): interpolation below the real minimum
        // must clamp up to it, and q=1 must clamp down to the maximum
        let h = LatencyHistogram::new();
        for _ in 0..3 {
            h.observe(Duration::from_millis(1));
        }
        h.observe(Duration::from_millis(4));
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 0.001, "interpolant 873813.3 ns clamps to min");
        assert_eq!(s.quantile(1.0), 0.004, "top quantile clamps to max");
        let p90 = s.quantile(0.9);
        assert!((p90 - 0.003_355_443_2).abs() < 1e-12, "p90 interpolates: {p90}");
        // monotone in q, bounded by the extremes
        let qs: Vec<f64> = (0..=10).map(|i| s.quantile(i as f64 / 10.0)).collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]));
        assert!(qs.iter().all(|&v| (0.001..=0.004).contains(&v)));
    }

    #[test]
    fn empty_histogram_reads_zeros() {
        let s = LatencyHistogram::new().snapshot();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn mean_is_sum_over_count() {
        let h = LatencyHistogram::new();
        h.observe(Duration::from_millis(2));
        h.observe(Duration::from_millis(4));
        assert!((h.snapshot().mean() - 0.003).abs() < 1e-12);
    }
}
