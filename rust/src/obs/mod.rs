//! Observability for the serving tier: per-request trace spans with
//! monotonic stage events, fixed-bucket log2 latency histograms, and a
//! Prometheus text-format renderer/parser — std-only, like the rest of
//! the networking stack.
//!
//! Three pieces (see DESIGN.md §Observability):
//!
//! * [`clock`] — an injectable nanosecond clock ([`Clock`]): monotonic
//!   in production, manually advanced under test, so every histogram
//!   and span assertion is deterministic.
//! * [`hist`] — [`LatencyHistogram`], lock-free fixed log2 buckets
//!   (~1 µs … ~137 s) with min/max-clamped quantile extraction. Every
//!   request is observed (cheap atomics); histograms are never sampled.
//! * [`span`] — [`TraceHub`], sharded fixed-capacity ring buffers of
//!   per-request [`Span`]s (stage timestamps + retry lineage), behind a
//!   1-in-N sampling knob so tracing overhead is bounded and droppable.
//!   Completed spans feed `GET /debug/trace` and the opt-in
//!   `ESACT_TRACE_FILE` JSONL sink.
//! * [`prom`] — Prometheus exposition writer (`# HELP`/`# TYPE`,
//!   `_bucket`/`_sum`/`_count`) plus the text-format parser the
//!   integration tests and the loadgen client scrape with.

pub mod clock;
pub mod hist;
pub mod prom;
pub mod span;

pub use clock::Clock;
pub use hist::{HistSnapshot, LatencyHistogram};
pub use prom::PromWriter;
pub use span::{Lane, Span, Stage, TraceHub};

/// The four latency families exported per lane: end-to-end total,
/// admission-to-execution queue wait, execution time, and time to
/// first output (for classify, first output *is* the full response).
pub struct LaneHists {
    pub total: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub execute: LatencyHistogram,
    pub ttft: LatencyHistogram,
}

impl LaneHists {
    pub fn new() -> LaneHists {
        LaneHists {
            total: LatencyHistogram::new(),
            queue_wait: LatencyHistogram::new(),
            execute: LatencyHistogram::new(),
            ttft: LatencyHistogram::new(),
        }
    }
}

impl Default for LaneHists {
    fn default() -> Self {
        Self::new()
    }
}

/// The server-wide observability state: one [`TraceHub`] plus the
/// per-lane histogram families, shared by the gateway, the leader
/// lanes, and the replica workers (it lives on `ServerCore`).
pub struct Obs {
    pub trace: TraceHub,
    pub classify: LaneHists,
    pub generate: LaneHists,
}

/// Per-shard capacity of completed-span ring buffers (total retained
/// spans = this × the shard count).
pub const DEFAULT_SPAN_CAPACITY: usize = 128;

impl Obs {
    /// Production state: monotonic clock, 1-in-1 sampling (the knob is
    /// re-set by `TierConfig`/`GatewayConfig` at tier/gateway start).
    pub fn new() -> Obs {
        Obs::with_clock(Clock::monotonic())
    }

    /// Test state under an injected clock.
    pub fn with_clock(clock: Clock) -> Obs {
        Obs {
            trace: TraceHub::new(clock, 1, DEFAULT_SPAN_CAPACITY),
            classify: LaneHists::new(),
            generate: LaneHists::new(),
        }
    }

    /// The histogram family for one lane.
    pub fn lane(&self, lane: Lane) -> &LaneHists {
        match lane {
            Lane::Classify => &self.classify,
            Lane::Generate => &self.generate,
        }
    }
}

impl Default for Obs {
    fn default() -> Self {
        Self::new()
    }
}
