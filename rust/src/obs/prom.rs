//! Prometheus text exposition: a writer that renders the tier's metric
//! rows and latency histograms with `# HELP`/`# TYPE` headers, and a
//! small parser for the same format used by the integration tests, the
//! `http-check` smoke probe, and the loadgen client to round-trip what
//! a live gateway serves. Both ends are deliberately minimal — exactly
//! the subset of the exposition format this repo emits.

use std::collections::{HashMap, HashSet};

use crate::obs::hist::{HistSnapshot, BOUNDS_NS, N_BUCKETS};

/// Prometheus metric-name charset: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
pub fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Renders the `/metrics` body. Emits `# HELP`/`# TYPE` once per metric
/// name (counter when the name ends in `_total`, gauge otherwise,
/// histogram via [`PromWriter::histogram`]) and prefixes every name on
/// the wire (`esact_`). Scalar rows are passed through pre-rendered so
/// the wire format stays byte-identical to the CLI `Display` rows the
/// existing scrapers parse (name first, value last, padded).
pub struct PromWriter {
    prefix: &'static str,
    out: String,
    described: HashSet<String>,
}

impl PromWriter {
    pub fn new(prefix: &'static str) -> PromWriter {
        PromWriter { prefix, out: String::with_capacity(4096), described: HashSet::new() }
    }

    fn describe(&mut self, name: &str, kind: &str, help: &str) {
        debug_assert!(valid_metric_name(name), "invalid metric name: {name}");
        if self.described.insert(name.to_string()) {
            self.out.push_str(&format!("# HELP {}{} {}\n", self.prefix, name, help));
            self.out.push_str(&format!("# TYPE {}{} {}\n", self.prefix, name, kind));
        }
    }

    /// One scalar sample. `rendered` is the row's existing `Display`
    /// output (`name{label="i"}   value`), emitted verbatim after the
    /// prefix; `name` is the bare metric name for the header lines.
    pub fn scalar(&mut self, name: &str, rendered: &str, help: &str) {
        let kind = if name.ends_with("_total") { "counter" } else { "gauge" };
        self.describe(name, kind, help);
        self.out.push_str(self.prefix);
        self.out.push_str(rendered);
        self.out.push('\n');
    }

    /// One full histogram family: cumulative `_bucket{le="…"}` rows in
    /// seconds, the `+Inf` bucket, `_sum`, and `_count`.
    pub fn histogram(&mut self, name: &str, snap: &HistSnapshot, help: &str) {
        self.describe(name, "histogram", help);
        let mut cum = 0u64;
        for i in 0..N_BUCKETS {
            cum += snap.buckets.get(i).copied().unwrap_or(0);
            // f64 Display never uses an exponent, so 1024 ns renders
            // as le="0.000001024" — parseable by str::parse::<f64>
            let le = BOUNDS_NS[i] as f64 / 1e9;
            self.out.push_str(&format!(
                "{}{}_bucket{{le=\"{}\"}} {}\n",
                self.prefix, name, le, cum
            ));
        }
        cum += snap.buckets.get(N_BUCKETS).copied().unwrap_or(0);
        self.out
            .push_str(&format!("{}{}_bucket{{le=\"+Inf\"}} {}\n", self.prefix, name, cum));
        self.out.push_str(&format!(
            "{}{}_sum {}\n",
            self.prefix,
            name,
            snap.sum_ns as f64 / 1e9
        ));
        self.out.push_str(&format!("{}{}_count {}\n", self.prefix, name, snap.count));
    }

    pub fn into_string(self) -> String {
        self.out
    }
}

/// Curated `# HELP` text for the tier's exported rows; anything not in
/// the table gets a generic line (the exposition stays well-formed).
pub fn help_for(name: &str) -> &'static str {
    match name {
        "serve_requests_total" => "Classify requests served to completion.",
        "serve_batches_total" => "Classify batches executed across replicas.",
        "serve_shed_total" => "Classify requests shed at admission.",
        "serve_jobs_retried_total" => "Classify jobs retried after a replica fault.",
        "serve_jobs_faulted_total" => "Classify jobs terminally faulted.",
        "serve_replica_respawns_total" => "Classify replica workers respawned.",
        "generate_sessions_total" => "Generate sessions run to completion.",
        "generate_tokens_total" => "Tokens emitted across generate sessions.",
        "generate_rejected_total" => "Generate sessions rejected at admission.",
        "generate_aborted_total" => "Generate sessions aborted mid-stream.",
        "generate_sessions_migrated_total" => "Sessions migrated off a faulted replica.",
        "generate_jobs_faulted_total" => "Decode jobs terminally faulted.",
        "generate_replica_respawns_total" => "Decode replica workers respawned.",
        "jobs_retried_total" => "Jobs retried after replica faults (all lanes).",
        "fault_injected_total" => "Faults injected by the seeded fault plan.",
        "http_requests_total" => "HTTP requests accepted by the gateway.",
        "http_active_connections" => "Connections currently open at the gateway.",
        "trace_spans_completed_total" => "Trace spans completed since startup.",
        "classify_latency_seconds" => "End-to-end classify request latency.",
        "classify_queue_wait_seconds" => "Classify admission-to-execution queue wait.",
        "classify_execute_seconds" => "Classify replica execution time.",
        "classify_ttft_seconds" => "Classify time to first (and only) output.",
        "generate_latency_seconds" => "End-to-end generate session latency.",
        "generate_queue_wait_seconds" => "Generate admission-to-first-execution queue wait.",
        "generate_execute_seconds" => "Decode slice execution time (one sample per slice).",
        "generate_ttft_seconds" => "Generate time to first streamed chunk.",
        _ => "ESACT serving tier metric (see DESIGN.md, Observability).",
    }
}

/// One parsed sample line.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// The label's value, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// A parsed `/metrics` body.
#[derive(Debug, Default)]
pub struct Scrape {
    pub samples: Vec<Sample>,
    /// `# TYPE` declarations by (base) metric name.
    pub types: HashMap<String, String>,
    /// `# HELP` declarations by (base) metric name.
    pub helps: HashMap<String, String>,
}

impl Scrape {
    /// First unlabeled sample with this exact name.
    pub fn value(&self, name: &str) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| s.name == name && s.labels.is_empty())
            .map(|s| s.value)
    }

    /// All samples with this exact name (labeled families).
    pub fn all(&self, name: &str) -> Vec<&Sample> {
        self.samples.iter().filter(|s| s.name == name).collect()
    }

    /// The declared type for a sample name; `_bucket`/`_sum`/`_count`
    /// children resolve to their base histogram declaration.
    pub fn type_of(&self, sample_name: &str) -> Option<&str> {
        if let Some(t) = self.types.get(sample_name) {
            return Some(t);
        }
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = sample_name.strip_suffix(suffix) {
                if let Some(t) = self.types.get(base) {
                    if t == "histogram" {
                        return Some(t);
                    }
                }
            }
        }
        None
    }

    /// Reassemble one histogram family from its child samples.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        let bucket_name = format!("{name}_bucket");
        let mut buckets: Vec<(f64, u64)> = Vec::new();
        for s in self.samples.iter().filter(|s| s.name == bucket_name) {
            let le = s.label("le")?;
            let le = if le == "+Inf" { f64::INFINITY } else { le.parse().ok()? };
            buckets.push((le, s.value as u64));
        }
        if buckets.is_empty() {
            return None;
        }
        buckets.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        Some(Histogram {
            buckets,
            sum: self.value(&format!("{name}_sum"))?,
            count: self.value(&format!("{name}_count"))? as u64,
        })
    }
}

/// A histogram reassembled from a scrape: cumulative `(le_seconds,
/// count)` buckets sorted by bound, plus `_sum`/`_count`.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub buckets: Vec<(f64, u64)>,
    pub sum: f64,
    pub count: u64,
}

impl Histogram {
    /// Buckets must be non-decreasing in cumulative count and the
    /// `+Inf` bucket must equal `_count`.
    pub fn is_well_formed(&self) -> bool {
        let monotone = self.buckets.windows(2).all(|w| w[0].1 <= w[1].1);
        let closed = self
            .buckets
            .last()
            .map(|&(le, c)| le.is_infinite() && c == self.count)
            .unwrap_or(false);
        monotone && closed
    }

    /// Quantile in seconds by linear interpolation over the cumulative
    /// buckets (the scrape-side mirror of `HistSnapshot::quantile`,
    /// minus the min/max clamp a scrape cannot see). 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut prev_le = 0.0f64;
        let mut prev_cum = 0u64;
        for &(le, cum) in &self.buckets {
            if cum > prev_cum && cum as f64 >= target {
                let upper = if le.is_infinite() { prev_le } else { le };
                let frac =
                    ((target - prev_cum as f64) / (cum - prev_cum) as f64).clamp(0.0, 1.0);
                return prev_le + frac * (upper - prev_le);
            }
            prev_cum = cum;
            if !le.is_infinite() {
                prev_le = le;
            }
        }
        prev_le
    }
}

/// Parse a text-format exposition body. Handles `# HELP`/`# TYPE`
/// headers, other comments, and sample lines with an optional single
/// `{k="v",…}` label set — label values must not contain `"` or `}`
/// (ours never do). Errors name the offending line.
pub fn parse(text: &str) -> Result<Scrape, String> {
    let mut scrape = Scrape::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            for (tag, map) in
                [("HELP ", &mut scrape.helps), ("TYPE ", &mut scrape.types)]
            {
                if let Some(decl) = rest.strip_prefix(tag) {
                    let mut it = decl.splitn(2, char::is_whitespace);
                    let name = it.next().unwrap_or("").to_string();
                    if name.is_empty() {
                        return Err(format!("line {}: empty {} name", lineno + 1, tag.trim()));
                    }
                    map.insert(name, it.next().unwrap_or("").trim().to_string());
                }
            }
            continue;
        }
        scrape.samples.push(parse_sample(line, lineno + 1)?);
    }
    Ok(scrape)
}

fn parse_sample(line: &str, lineno: usize) -> Result<Sample, String> {
    let (name, labels, value_part) = match line.find('{') {
        Some(open) => {
            let close = line[open..]
                .find('}')
                .map(|i| open + i)
                .ok_or_else(|| format!("line {lineno}: unterminated label set"))?;
            let mut labels = Vec::new();
            let inner = &line[open + 1..close];
            for pair in inner.split(',').filter(|p| !p.trim().is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| format!("line {lineno}: bad label pair {pair:?}"))?;
                let v = v.trim().trim_matches('"');
                labels.push((k.trim().to_string(), v.to_string()));
            }
            (&line[..open], labels, &line[close + 1..])
        }
        None => {
            let name_end = line
                .find(char::is_whitespace)
                .ok_or_else(|| format!("line {lineno}: no value in {line:?}"))?;
            (&line[..name_end], Vec::new(), &line[name_end..])
        }
    };
    if name.is_empty() {
        return Err(format!("line {lineno}: empty metric name"));
    }
    // value is the last whitespace token — rows pad the name column
    let value_str = value_part
        .split_whitespace()
        .last()
        .ok_or_else(|| format!("line {lineno}: no value in {line:?}"))?;
    let value = value_str
        .parse::<f64>()
        .map_err(|_| format!("line {lineno}: bad value {value_str:?}"))?;
    Ok(Sample { name: name.to_string(), labels, value })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::LatencyHistogram;
    use std::time::Duration;

    #[test]
    fn metric_name_charset() {
        assert!(valid_metric_name("esact_serve_requests_total"));
        assert!(valid_metric_name("_x:y9"));
        assert!(!valid_metric_name(""));
        assert!(!valid_metric_name("9leading_digit"));
        assert!(!valid_metric_name("has-dash"));
        assert!(!valid_metric_name("has space"));
    }

    #[test]
    fn writer_emits_help_and_type_once_per_name() {
        let mut w = PromWriter::new("esact_");
        w.scalar("serve_requests_total", "serve_requests_total    42", "Requests.");
        w.scalar(
            "replica_busy_seconds",
            "replica_busy_seconds{replica=\"0\"}     0.5",
            "Busy.",
        );
        w.scalar(
            "replica_busy_seconds",
            "replica_busy_seconds{replica=\"1\"}     0.25",
            "Busy.",
        );
        let text = w.into_string();
        assert_eq!(text.matches("# HELP esact_replica_busy_seconds").count(), 1);
        assert_eq!(text.matches("# TYPE esact_replica_busy_seconds gauge").count(), 1);
        assert_eq!(text.matches("# TYPE esact_serve_requests_total counter").count(), 1);

        let scrape = parse(&text).unwrap();
        assert_eq!(scrape.value("esact_serve_requests_total"), Some(42.0));
        assert_eq!(scrape.type_of("esact_serve_requests_total"), Some("counter"));
        let busy = scrape.all("esact_replica_busy_seconds");
        assert_eq!(busy.len(), 2);
        assert_eq!(busy[1].label("replica"), Some("1"));
        assert_eq!(busy[1].value, 0.25);
    }

    #[test]
    fn histogram_round_trips_through_the_parser() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 1, 2, 4, 8, 150_000] {
            h.observe(Duration::from_millis(ms));
        }
        let mut w = PromWriter::new("esact_");
        w.histogram("classify_latency_seconds", &h.snapshot(), "Latency.");
        let text = w.into_string();
        let scrape = parse(&text).unwrap();

        assert_eq!(scrape.type_of("esact_classify_latency_seconds"), Some("histogram"));
        assert_eq!(
            scrape.type_of("esact_classify_latency_seconds_bucket"),
            Some("histogram")
        );
        let hist = scrape.histogram("esact_classify_latency_seconds").unwrap();
        assert_eq!(hist.count, 6);
        assert_eq!(hist.buckets.len(), N_BUCKETS + 1);
        assert!(hist.is_well_formed());
        assert!((hist.sum - 150.016).abs() < 1e-9);
        // 150 s exceeds the ~137 s cap, so only the +Inf bucket holds it
        assert_eq!(hist.buckets[N_BUCKETS - 1].1, 5);
        assert_eq!(hist.buckets[N_BUCKETS].1, 6);
        // the median lands in the (1.048576 ms, 2.097152 ms] bucket
        let p50 = hist.quantile(0.5);
        assert!((0.0008..=0.0022).contains(&p50), "p50 = {p50}");
        // quantiles are monotone in q
        let qs: Vec<f64> = (0..=10).map(|i| hist.quantile(i as f64 / 10.0)).collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn parser_reads_padded_rows_and_rejects_garbage() {
        let scrape = parse("esact_x                                 7\n").unwrap();
        assert_eq!(scrape.value("esact_x"), Some(7.0));
        assert!(parse("just_a_name_no_value\n").is_err());
        assert!(parse("name 12.5.7\n").is_err());
        assert!(parse("open{le=\"1\" 3\n").is_err());
        // non-HELP/TYPE comments are ignored
        assert!(parse("# a free-form comment\n").unwrap().samples.is_empty());
    }

    #[test]
    fn empty_histogram_is_well_formed_and_quantile_is_zero() {
        let mut w = PromWriter::new("");
        w.histogram("h_seconds", &LatencyHistogram::new().snapshot(), "Empty.");
        let scrape = parse(&w.into_string()).unwrap();
        let hist = scrape.histogram("h_seconds").unwrap();
        assert_eq!(hist.count, 0);
        assert!(hist.is_well_formed());
        assert_eq!(hist.quantile(0.5), 0.0);
    }
}
