//! Injectable nanosecond clock. Production code runs on the monotonic
//! variant (epoch-relative `Instant`, so timestamps are small u64 ns
//! offsets); tests run on the manual variant and advance time
//! explicitly, making every span timestamp and histogram bucket a
//! deterministic function of the test script.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A nanosecond clock: monotonic (epoch = construction time) or
/// manually driven. Cheap to clone; manual clones share the same time.
#[derive(Clone, Debug)]
pub enum Clock {
    Monotonic(Instant),
    Manual(Arc<AtomicU64>),
}

impl Clock {
    /// The production clock: now = nanoseconds since construction.
    pub fn monotonic() -> Clock {
        Clock::Monotonic(Instant::now())
    }

    /// A test clock starting at 0 ns; advance it with [`Clock::advance`].
    pub fn manual() -> Clock {
        Clock::Manual(Arc::new(AtomicU64::new(0)))
    }

    /// Current time in nanoseconds since the clock's epoch.
    pub fn now_ns(&self) -> u64 {
        match self {
            Clock::Monotonic(epoch) => epoch.elapsed().as_nanos() as u64,
            Clock::Manual(t) => t.load(Ordering::SeqCst),
        }
    }

    /// Advance a manual clock. Panics on the monotonic variant — a
    /// test that means to control time must have injected a manual
    /// clock, and silently ignoring the advance would hide that bug.
    pub fn advance(&self, by: Duration) {
        match self {
            Clock::Manual(t) => {
                t.fetch_add(by.as_nanos() as u64, Ordering::SeqCst);
            }
            Clock::Monotonic(_) => panic!("cannot advance a monotonic clock"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_explicit_and_shared_across_clones() {
        let c = Clock::manual();
        let c2 = c.clone();
        assert_eq!(c.now_ns(), 0);
        c.advance(Duration::from_micros(5));
        assert_eq!(c.now_ns(), 5_000);
        assert_eq!(c2.now_ns(), 5_000, "clones share the same time");
        c2.advance(Duration::from_nanos(3));
        assert_eq!(c.now_ns(), 5_003);
    }

    #[test]
    fn monotonic_clock_never_goes_backwards() {
        let c = Clock::monotonic();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    #[should_panic(expected = "cannot advance")]
    fn advancing_a_monotonic_clock_panics() {
        Clock::monotonic().advance(Duration::from_secs(1));
    }
}
