//! Per-request trace spans. A span is minted at admission
//! (`TierHandle::submit`) — the gateway backdates its `accepted`/
//! `parsed` stamps once the ids come back — and accumulates monotonic
//! stage timestamps as the request moves through the tier: admission,
//! batcher queue, replica dispatch, execution, first streamed output,
//! completion or fault. Completed spans land in sharded fixed-capacity
//! ring buffers (oldest dropped), feed `GET /debug/trace?n=`, and are
//! optionally appended as JSONL to `ESACT_TRACE_FILE`.
//!
//! Sampling: 1-in-N by request id (`id % n == 0`; `n = 0` disables
//! tracing entirely). Histograms are *not* behind this knob — they
//! observe every request; spans are the bounded, droppable artifact.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::obs::clock::Clock;

/// Stage-event taxonomy, in nominal lifecycle order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// The gateway read a complete request off the socket.
    Accepted,
    /// The request body parsed into tier submissions.
    Parsed,
    /// `TierHandle::submit` admitted it (span birth for in-process
    /// callers; the gateway backdates the two stages above).
    Admitted,
    /// The leader queued it (classify: into the batcher; generate:
    /// session admitted to the decode lane).
    Queued,
    /// The leader pushed its job onto a replica deque.
    Dispatched,
    /// A replica worker began executing it (earliest attempt wins).
    ExecStart,
    /// A replica worker finished executing it (latest attempt wins).
    ExecEnd,
    /// First streamed output reached the leader (generate lane).
    FirstChunk,
    /// Final success: reply forwarded / `done` chunk sent.
    Done,
    /// Terminal fault: retry budget spent, abort, or stream fault.
    Faulted,
}

/// Number of distinct stages (span array sizing).
pub const N_STAGES: usize = 10;

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Accepted,
        Stage::Parsed,
        Stage::Admitted,
        Stage::Queued,
        Stage::Dispatched,
        Stage::ExecStart,
        Stage::ExecEnd,
        Stage::FirstChunk,
        Stage::Done,
        Stage::Faulted,
    ];

    fn index(self) -> usize {
        match self {
            Stage::Accepted => 0,
            Stage::Parsed => 1,
            Stage::Admitted => 2,
            Stage::Queued => 3,
            Stage::Dispatched => 4,
            Stage::ExecStart => 5,
            Stage::ExecEnd => 6,
            Stage::FirstChunk => 7,
            Stage::Done => 8,
            Stage::Faulted => 9,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Accepted => "accepted",
            Stage::Parsed => "parsed",
            Stage::Admitted => "admitted",
            Stage::Queued => "queued",
            Stage::Dispatched => "dispatched",
            Stage::ExecStart => "exec_start",
            Stage::ExecEnd => "exec_end",
            Stage::FirstChunk => "first_chunk",
            Stage::Done => "done",
            Stage::Faulted => "faulted",
        }
    }

    /// Merge policy for repeated recordings (retries/migrations replay
    /// stages): completion-flavored stages keep the latest stamp, the
    /// rest keep the earliest — so `exec_start` is the first attempt's
    /// start and `exec_end` the last attempt's end, bracketing the
    /// whole retry lineage.
    fn latest_wins(self) -> bool {
        matches!(self, Stage::ExecEnd | Stage::Done | Stage::Faulted)
    }
}

/// Which leader lane served the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    Classify,
    Generate,
}

impl Lane {
    pub fn name(self) -> &'static str {
        match self {
            Lane::Classify => "classify",
            Lane::Generate => "generate",
        }
    }
}

/// One request's trace: stage timestamps (ns on the hub's clock) plus
/// retry lineage and, for generate sessions, the prefill/decode
/// execution split (the paper's stage-level accounting, per request).
#[derive(Clone, Debug)]
pub struct Span {
    pub id: u64,
    pub lane: Lane,
    stages: [Option<u64>; N_STAGES],
    /// Dispatch attempts consumed (1 = served first try).
    pub attempts: u32,
    /// Session migrations (generate lane) absorbed by this request.
    pub migrated: u32,
    /// Terminal fault code (`replica_fault`, `decode_aborted`, …).
    pub fault: Option<&'static str>,
    /// Cumulative prefill execution time (generate sessions).
    pub prefill_ns: Option<u64>,
    /// Cumulative decode execution time (generate sessions).
    pub decode_ns: Option<u64>,
}

impl Span {
    fn new(id: u64, lane: Lane) -> Span {
        Span {
            id,
            lane,
            stages: [None; N_STAGES],
            attempts: 1,
            migrated: 0,
            fault: None,
            prefill_ns: None,
            decode_ns: None,
        }
    }

    fn record(&mut self, stage: Stage, t_ns: u64) {
        let slot = &mut self.stages[stage.index()];
        if slot.is_none() || stage.latest_wins() {
            *slot = Some(t_ns);
        }
    }

    /// Timestamp of one stage, if recorded.
    pub fn stage(&self, s: Stage) -> Option<u64> {
        self.stages[s.index()]
    }

    /// Terminal timestamp: `done`, else `faulted`.
    pub fn finished_at(&self) -> Option<u64> {
        self.stage(Stage::Done).or_else(|| self.stage(Stage::Faulted))
    }

    /// End-to-end ns from the earliest recorded stage to the terminal
    /// one, when both exist.
    pub fn total_ns(&self) -> Option<u64> {
        let first = self.stages.iter().flatten().min()?;
        Some(self.finished_at()?.saturating_sub(*first))
    }

    /// Render as a single JSON object (one JSONL line / one element of
    /// the `/debug/trace` array). Stage names map to ns timestamps;
    /// absent stages are omitted.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256);
        out.push_str(&format!(
            "{{\"id\":{},\"lane\":\"{}\",\"attempts\":{},\"migrated\":{}",
            self.id,
            self.lane.name(),
            self.attempts,
            self.migrated
        ));
        match self.fault {
            Some(code) => out.push_str(&format!(",\"fault\":\"{code}\"")),
            None => out.push_str(",\"fault\":null"),
        }
        if let Some(p) = self.prefill_ns {
            out.push_str(&format!(",\"prefill_ns\":{p}"));
        }
        if let Some(d) = self.decode_ns {
            out.push_str(&format!(",\"decode_ns\":{d}"));
        }
        if let Some(t) = self.total_ns() {
            out.push_str(&format!(",\"total_ns\":{t}"));
        }
        out.push_str(",\"stages\":{");
        let mut first = true;
        for s in Stage::ALL {
            if let Some(t) = self.stage(s) {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("\"{}\":{}", s.name(), t));
            }
        }
        out.push_str("}}");
        out
    }
}

/// Spans in flight never outgrow this per-shard bound (a begun span
/// whose request is orphaned by a tier error would otherwise leak).
const MAX_ACTIVE_PER_SHARD: usize = 4096;

const N_SHARDS: usize = 8;

#[derive(Default)]
struct Shard {
    active: HashMap<u64, Span>,
    done: VecDeque<Span>,
}

/// The process-wide span store: sharded by request id (8 shards, one
/// mutex each — submit ids are sequential, so consecutive requests hit
/// different shards), fixed-capacity completed rings, 1-in-N sampling.
pub struct TraceHub {
    clock: Clock,
    sample_every: AtomicU64,
    capacity: usize,
    shards: [Mutex<Shard>; N_SHARDS],
    completed: AtomicU64,
    sink: Option<Mutex<std::fs::File>>,
}

impl TraceHub {
    /// `sample_every = 1` traces everything, `n` traces 1-in-n by id,
    /// `0` disables tracing. `capacity` bounds completed spans kept
    /// per shard. An `ESACT_TRACE_FILE` env var arms the JSONL sink.
    pub fn new(clock: Clock, sample_every: u64, capacity: usize) -> TraceHub {
        let sink = std::env::var("ESACT_TRACE_FILE")
            .ok()
            .filter(|p| !p.is_empty())
            .and_then(|p| {
                std::fs::OpenOptions::new().create(true).append(true).open(p).ok()
            })
            .map(Mutex::new);
        TraceHub {
            clock,
            sample_every: AtomicU64::new(sample_every),
            capacity: capacity.max(1),
            shards: std::array::from_fn(|_| Mutex::new(Shard::default())),
            completed: AtomicU64::new(0),
            sink,
        }
    }

    /// Route the JSONL sink to an explicit path (tests; the env knob
    /// is process-global and races under the parallel test harness).
    pub fn with_sink_path(mut self, path: &std::path::Path) -> std::io::Result<TraceHub> {
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        self.sink = Some(Mutex::new(f));
        Ok(self)
    }

    /// Reconfigure the sampling knob (set from `TierConfig` /
    /// `GatewayConfig` at tier start).
    pub fn set_sample_every(&self, n: u64) {
        self.sample_every.store(n, Ordering::SeqCst);
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every.load(Ordering::SeqCst)
    }

    /// Whether this request id is traced under the current knob.
    pub fn sampled(&self, id: u64) -> bool {
        let n = self.sample_every.load(Ordering::Relaxed);
        n != 0 && id % n == 0
    }

    /// Now on the hub's clock (callers that must backdate a stage
    /// capture this before doing the work, then use [`Self::event_at`]).
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    fn shard(&self, id: u64) -> &Mutex<Shard> {
        &self.shards[(id % N_SHARDS as u64) as usize]
    }

    fn lock(&self, id: u64) -> std::sync::MutexGuard<'_, Shard> {
        // tracing must never take the tier down: recover from poison
        self.shard(id).lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Mint the span for a sampled request and stamp `stage` now.
    pub fn begin(&self, id: u64, lane: Lane, stage: Stage) {
        if !self.sampled(id) {
            return;
        }
        let t = self.clock.now_ns();
        let mut sh = self.lock(id);
        if sh.active.len() >= MAX_ACTIVE_PER_SHARD {
            return;
        }
        let span = sh.active.entry(id).or_insert_with(|| Span::new(id, lane));
        span.record(stage, t);
    }

    /// Stamp `stage` now on an active span (no-op if unsampled/unknown).
    pub fn event(&self, id: u64, stage: Stage) {
        self.event_at(id, stage, self.clock.now_ns());
    }

    /// Stamp `stage` at an explicit time — how the gateway backdates
    /// `accepted`/`parsed` once `submit` has returned the ids.
    pub fn event_at(&self, id: u64, stage: Stage, t_ns: u64) {
        if !self.sampled(id) {
            return;
        }
        if let Some(span) = self.lock(id).active.get_mut(&id) {
            span.record(stage, t_ns);
        }
    }

    /// Record one more dispatch attempt (classify retry).
    pub fn attempt(&self, id: u64) {
        if !self.sampled(id) {
            return;
        }
        if let Some(span) = self.lock(id).active.get_mut(&id) {
            span.attempts += 1;
        }
    }

    /// Record a session migration (generate lane fault recovery).
    pub fn migrated(&self, id: u64) {
        if !self.sampled(id) {
            return;
        }
        if let Some(span) = self.lock(id).active.get_mut(&id) {
            span.migrated += 1;
            span.attempts += 1;
        }
    }

    /// Attach the terminal fault code.
    pub fn fault(&self, id: u64, code: &'static str) {
        if !self.sampled(id) {
            return;
        }
        if let Some(span) = self.lock(id).active.get_mut(&id) {
            span.fault = Some(code);
        }
    }

    /// Attach the prefill/decode execution split (generate sessions).
    pub fn phases(&self, id: u64, prefill: Duration, decode: Duration) {
        if !self.sampled(id) {
            return;
        }
        if let Some(span) = self.lock(id).active.get_mut(&id) {
            span.prefill_ns = Some(prefill.as_nanos() as u64);
            span.decode_ns = Some(decode.as_nanos() as u64);
        }
    }

    /// Terminal stamp (`Done` or `Faulted`): move the span to the
    /// completed ring (dropping the oldest at capacity) and append it
    /// to the JSONL sink when armed.
    pub fn finish(&self, id: u64, stage: Stage) {
        if !self.sampled(id) {
            return;
        }
        let t = self.clock.now_ns();
        let mut sh = self.lock(id);
        if let Some(mut span) = sh.active.remove(&id) {
            span.record(stage, t);
            if sh.done.len() >= self.capacity {
                sh.done.pop_front();
            }
            sh.done.push_back(span.clone());
            drop(sh);
            self.completed.fetch_add(1, Ordering::Relaxed);
            if let Some(f) = &self.sink {
                let mut f = f.lock().unwrap_or_else(|e| e.into_inner());
                let _ = writeln!(f, "{}", span.to_json());
            }
        }
    }

    /// The most recently completed `n` spans, newest first (merged
    /// across shards by terminal timestamp).
    pub fn recent(&self, n: usize) -> Vec<Span> {
        let mut all: Vec<Span> = Vec::new();
        for sh in &self.shards {
            let sh = sh.lock().unwrap_or_else(|e| e.into_inner());
            all.extend(sh.done.iter().cloned());
        }
        all.sort_by_key(|s| std::cmp::Reverse((s.finished_at().unwrap_or(0), s.id)));
        all.truncate(n);
        all
    }

    /// Completed spans since startup (spans can age out of the rings;
    /// this counter does not).
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Spans currently in flight.
    pub fn active_count(&self) -> usize {
        self.shards
            .iter()
            .map(|sh| sh.lock().unwrap_or_else(|e| e.into_inner()).active.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::json::Json;

    fn manual_hub(sample_every: u64, cap: usize) -> (TraceHub, Clock) {
        let clock = Clock::manual();
        (TraceHub::new(clock.clone(), sample_every, cap), clock)
    }

    #[test]
    fn span_lifecycle_is_deterministic_under_a_manual_clock() {
        let (hub, clock) = manual_hub(1, 16);
        hub.begin(7, Lane::Classify, Stage::Admitted);
        clock.advance(Duration::from_micros(10));
        hub.event(7, Stage::Queued);
        clock.advance(Duration::from_micros(5));
        hub.event(7, Stage::Dispatched);
        clock.advance(Duration::from_micros(20));
        hub.event(7, Stage::ExecStart);
        clock.advance(Duration::from_micros(100));
        hub.event(7, Stage::ExecEnd);
        clock.advance(Duration::from_micros(1));
        hub.finish(7, Stage::Done);

        let spans = hub.recent(8);
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.id, 7);
        assert_eq!(s.stage(Stage::Admitted), Some(0));
        assert_eq!(s.stage(Stage::Queued), Some(10_000));
        assert_eq!(s.stage(Stage::Dispatched), Some(15_000));
        assert_eq!(s.stage(Stage::ExecStart), Some(35_000));
        assert_eq!(s.stage(Stage::ExecEnd), Some(135_000));
        assert_eq!(s.stage(Stage::Done), Some(136_000));
        assert_eq!(s.total_ns(), Some(136_000));
        assert_eq!(s.attempts, 1);
        assert_eq!(hub.completed(), 1);
        assert_eq!(hub.active_count(), 0);
    }

    #[test]
    fn merge_policy_keeps_first_start_and_last_end_across_retries() {
        let (hub, clock) = manual_hub(1, 16);
        hub.begin(0, Lane::Classify, Stage::Admitted);
        clock.advance(Duration::from_micros(1));
        hub.event(0, Stage::ExecStart); // attempt 1 @ 1000
        clock.advance(Duration::from_micros(1));
        hub.attempt(0);
        hub.event(0, Stage::ExecStart); // attempt 2 @ 2000: earliest wins
        clock.advance(Duration::from_micros(1));
        hub.event(0, Stage::ExecEnd); // @ 3000
        clock.advance(Duration::from_micros(1));
        hub.event(0, Stage::ExecEnd); // @ 4000: latest wins
        hub.finish(0, Stage::Done);
        let s = &hub.recent(1)[0];
        assert_eq!(s.stage(Stage::ExecStart), Some(1_000));
        assert_eq!(s.stage(Stage::ExecEnd), Some(4_000));
        assert_eq!(s.attempts, 2);
    }

    #[test]
    fn sampling_knob_drops_unselected_ids_and_zero_disables() {
        let (hub, _clock) = manual_hub(4, 16);
        for id in 0..8u64 {
            hub.begin(id, Lane::Classify, Stage::Admitted);
            hub.finish(id, Stage::Done);
        }
        assert_eq!(hub.completed(), 2, "ids 0 and 4 out of 0..8 at 1-in-4");
        hub.set_sample_every(0);
        hub.begin(8, Lane::Classify, Stage::Admitted);
        hub.finish(8, Stage::Done);
        assert_eq!(hub.completed(), 2, "0 disables tracing");
        assert_eq!(hub.active_count(), 0);
    }

    #[test]
    fn completed_ring_is_bounded_and_recent_returns_newest_first() {
        let (hub, clock) = manual_hub(1, 2); // 2 per shard × 8 shards
        for id in 0..64u64 {
            hub.begin(id, Lane::Classify, Stage::Admitted);
            clock.advance(Duration::from_nanos(1));
            hub.finish(id, Stage::Done);
        }
        let spans = hub.recent(1000);
        assert_eq!(spans.len(), 16, "rings cap retention at 2 × 8 shards");
        assert_eq!(spans[0].id, 63, "newest first");
        assert!(spans.windows(2).all(|w| {
            w[0].finished_at().unwrap() >= w[1].finished_at().unwrap()
        }));
        assert_eq!(hub.completed(), 64, "the counter outlives the rings");
    }

    #[test]
    fn fault_lineage_and_phases_land_in_the_json() {
        let (hub, clock) = manual_hub(1, 16);
        hub.begin(3, Lane::Generate, Stage::Admitted);
        clock.advance(Duration::from_micros(2));
        hub.migrated(3);
        hub.fault(3, "replica_fault");
        hub.phases(3, Duration::from_micros(7), Duration::from_micros(9));
        hub.finish(3, Stage::Faulted);
        let s = &hub.recent(1)[0];
        assert_eq!(s.fault, Some("replica_fault"));
        assert_eq!(s.migrated, 1);
        assert_eq!(s.attempts, 2);

        let doc = Json::parse(&s.to_json()).expect("span JSON parses");
        assert_eq!(doc.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("lane").unwrap().as_str(), Some("generate"));
        assert_eq!(doc.get("fault").unwrap().as_str(), Some("replica_fault"));
        assert_eq!(doc.get("prefill_ns").unwrap().as_f64(), Some(7_000.0));
        assert_eq!(doc.get("decode_ns").unwrap().as_f64(), Some(9_000.0));
        let stages = doc.get("stages").unwrap();
        assert_eq!(stages.get("admitted").unwrap().as_f64(), Some(0.0));
        assert_eq!(stages.get("faulted").unwrap().as_f64(), Some(2_000.0));
        assert!(stages.get("done").is_none(), "absent stages are omitted");
    }

    #[test]
    fn jsonl_sink_appends_one_parseable_line_per_span() {
        let path = std::env::temp_dir()
            .join(format!("esact_trace_test_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let clock = Clock::manual();
            let hub = TraceHub::new(clock.clone(), 1, 16).with_sink_path(&path).unwrap();
            for id in 0..3u64 {
                hub.begin(id, Lane::Classify, Stage::Admitted);
                clock.advance(Duration::from_micros(1));
                hub.finish(id, Stage::Done);
            }
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for (i, line) in lines.iter().enumerate() {
            let doc = Json::parse(line).expect("JSONL line parses");
            assert_eq!(doc.get("id").unwrap().as_f64(), Some(i as f64));
        }
        let _ = std::fs::remove_file(&path);
    }
}
