//! Analytic FLOP accounting (paper Fig 1): the computation breakdown of
//! a transformer and the break-even argument against *global* similarity.

use crate::config::ModelConfig;
use crate::spls::plan::{dense_model_flops, LayerFlops};

/// MHA vs FFN computation breakdown of a model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeBreakdown {
    pub total_gflops: f64,
    pub mha_frac: f64,
    pub ffn_frac: f64,
    pub per_component: LayerFlops,
}

/// Whole-model GFLOPs (MAC = 1 FLOP) and breakdown.
pub fn model_gflops(cfg: &ModelConfig) -> ComputeBreakdown {
    let f = dense_model_flops(cfg);
    let total = f.total();
    ComputeBreakdown {
        total_gflops: total / 1e9,
        mha_frac: (f.qkv + f.attn) / total,
        ffn_frac: f.ffn / total,
        per_component: f,
    }
}

/// Paper Fig 1's break-even: with global inter-row similarity, computing
/// the similarity between two rows costs as much as one attention score
/// row-pair comparison; pairwise similarity over l rows costs l(l-1)/2
/// score-equivalents while each sparsified row saves l scores, so more
/// than (l-1)/2 rows must be pruned for net gain. Returns the minimum
/// number of rows to sparsify for any benefit.
pub fn breakeven_rows_global_similarity(l: usize) -> usize {
    // cost = l(l-1)/2 comparisons; saving = rows_pruned * l
    // net > 0  <=>  rows_pruned > (l-1)/2
    l.saturating_sub(1).div_ceil(2)
}

/// Local-similarity comparison count: l/w windows × w(w-1)/2 pairs
/// = l(w-1)/2 (paper §II-B).
pub fn local_similarity_comparisons(l: usize, w: usize) -> usize {
    l * (w - 1) / 2
}

/// Global-similarity comparison count: l(l-1)/2.
pub fn global_similarity_comparisons(l: usize) -> usize {
    l * (l - 1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    #[test]
    fn fig1_bert_large_breakdown() {
        let b = model_gflops(&config::bert_large(512));
        assert!((b.total_gflops - 167.5).abs() < 2.0, "{}", b.total_gflops);
        assert!((b.mha_frac - 0.3846).abs() < 0.01);
        assert!((b.ffn_frac - 0.6154).abs() < 0.01);
    }

    #[test]
    fn breakeven_over_half() {
        assert_eq!(breakeven_rows_global_similarity(512), 256);
        assert_eq!(breakeven_rows_global_similarity(128), 64);
        assert_eq!(breakeven_rows_global_similarity(1), 0);
    }

    #[test]
    fn local_vs_global_comparison_reduction() {
        // paper §II-B: l(l-1)/2 -> l(w-1)/2
        let l = 512;
        let local = local_similarity_comparisons(l, 8);
        let global = global_similarity_comparisons(l);
        assert_eq!(local, 512 * 7 / 2);
        assert!((global as f64 / local as f64 - (l - 1) as f64 / 7.0).abs() < 1.0);
    }

    #[test]
    fn ffn_dominates_bert_like_models() {
        for cfg in [config::bert_base(128), config::bert_base(512), config::gpt2(512)] {
            let b = model_gflops(&cfg);
            assert!(b.ffn_frac > 0.5, "{}: ffn {}", cfg.name, b.ffn_frac);
        }
    }

    #[test]
    fn attention_share_grows_with_seq_len() {
        let short = model_gflops(&config::bert_base(128));
        let long = model_gflops(&config::bert_base(512));
        let attn_frac = |b: &ComputeBreakdown| b.per_component.attn / b.per_component.total();
        assert!(attn_frac(&long) > attn_frac(&short));
    }
}
