//! The 26-benchmark evaluation zoo (paper §V-A).
//!
//! Reconstruction: BERT-Base and BERT-Large on eight GLUE tasks
//! (WNLI excluded; MNLI counted matched + mismatched), SQuAD v1.1 and
//! CLOTH; GPT-2, Llama2-7b and Bloom-7b on WikiText-2; ViT-B/16 and
//! ViT-B/32 on ImageNet-1K. Sequence lengths follow the paper: 128 for
//! GLUE, 384 for SQuAD, 512 for CLOTH/WikiText-2.
//!
//! Each benchmark carries the **sparsity profile at its loss ≤ 1%
//! operating point**. The paper reports only the cross-benchmark
//! averages (Fig 15: QKV 65.66%, attention 94.65%, FFN 50.33%, overall
//! 51.7%); per-benchmark values here are deterministic, task-dependent
//! deviations around those averages (longer sequences → more attention
//! redundancy; decoder LMs → slightly less FFN similarity; ViT → more),
//! constructed so the 26-benchmark averages land on the paper's numbers
//! (asserted in tests). The tiny-model substrate (`model::accuracy`)
//! provides *measured* sparsity for the trend figures (16-19).

use crate::config::{self, ModelConfig};
use crate::spls::plan::{dense_model_flops, prediction_overhead_ops};
use crate::config::SplsConfig;

/// Task family (determines metric + sequence length).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskDomain {
    Glue,
    Squad,
    Cloth,
    WikiText,
    ImageNet,
}

impl TaskDomain {
    pub fn metric(self) -> &'static str {
        match self {
            TaskDomain::Glue => "acc/F1",
            TaskDomain::Squad => "F1",
            TaskDomain::Cloth => "acc",
            TaskDomain::WikiText => "ppl",
            TaskDomain::ImageNet => "acc",
        }
    }

    pub fn batch(self) -> usize {
        match self {
            TaskDomain::Glue => 32,
            TaskDomain::Squad => 12,
            TaskDomain::Cloth => 3,
            TaskDomain::WikiText | TaskDomain::ImageNet => 8,
        }
    }
}

/// Component sparsity fractions at the loss ≤ 1% operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsityProfile {
    pub q: f64,
    pub kv: f64,
    pub attn: f64,
    pub ffn: f64,
}

impl SparsityProfile {
    /// QKV-component sparsity: of the four L·D·D GEMMs, Q + output
    /// projection scale with q, K + V with kv.
    pub fn qkv(&self) -> f64 {
        (2.0 * self.q + 2.0 * self.kv) / 4.0
    }
}

/// One evaluation benchmark.
#[derive(Clone, Copy, Debug)]
pub struct Benchmark {
    pub task: &'static str,
    pub domain: TaskDomain,
    pub model: ModelConfig,
    pub profile: SparsityProfile,
}

impl Benchmark {
    /// Overall net computation reduction: component sparsities applied
    /// to the dense FLOP breakdown, minus the prediction overhead.
    pub fn overall_reduction(&self) -> f64 {
        let dense = dense_model_flops(&self.model);
        let sparse = dense.qkv * (1.0 - self.profile.qkv())
            + dense.attn * (1.0 - self.profile.attn)
            + dense.ffn * (1.0 - self.profile.ffn);
        let overhead = prediction_overhead_ops(&self.model, &SplsConfig::default());
        1.0 - (sparse + overhead) / dense.total()
    }
}

/// Deterministic per-benchmark deviation around the paper's averages.
///
/// `i` indexes the benchmark within its family; deviations are balanced
/// (mean ≈ 0 across the zoo by construction, verified in tests).
fn profile(base_q: f64, base_kv: f64, base_attn: f64, base_ffn: f64, i: usize) -> SparsityProfile {
    // symmetric offsets in [-0.06, +0.06], cycle of 8 with zero mean
    const OFF: [f64; 8] = [0.00, 0.04, -0.04, 0.06, -0.06, 0.02, -0.02, 0.00];
    let o = OFF[i % 8];
    let clamp = |v: f64| v.clamp(0.0, 0.995);
    SparsityProfile {
        q: clamp(base_q + o),
        kv: clamp(base_kv + o * 0.5),
        attn: clamp(base_attn + o * 0.15),
        ffn: clamp(base_ffn + o * 1.2),
    }
}

/// The eight GLUE tasks (WNLI excluded). MNLI is scored matched +
/// mismatched on BERT-Base (the extra entry that brings the zoo to the
/// paper's count of 26).
const GLUE_TASKS: [&str; 8] = [
    "CoLA", "SST-2", "MRPC", "STS-B", "QQP", "MNLI-m", "QNLI", "RTE",
];

/// Construct the full 26-benchmark zoo.
pub fn all_benchmarks() -> Vec<Benchmark> {
    // Paper averages (Fig 15): QKV 65.66% → with the 2q+2kv/4 split and
    // kv driven purely by top-k column occupancy, q ≈ 0.62, kv ≈ 0.69;
    // attention 94.65%; FFN 50.33%.
    let (bq, bkv, ba, bf) = (0.62, 0.695, 0.9465, 0.5033);
    let mut v = Vec::with_capacity(26);
    let mut i = 0usize;
    // 8 GLUE tasks × {BERT-Base, BERT-Large}, L = 128 → 16
    for task in GLUE_TASKS {
        for model in [config::bert_base(128), config::bert_large(128)] {
            v.push(Benchmark {
                task,
                domain: TaskDomain::Glue,
                model,
                profile: profile(bq, bkv, ba, bf, i),
            });
            i += 1;
        }
    }
    // SQuAD (L = 384) and CLOTH (L = 512) on both BERT sizes → +4
    for (task, domain, l) in [
        ("SQuAD", TaskDomain::Squad, 384),
        ("CLOTH", TaskDomain::Cloth, 512),
    ] {
        for model in [config::bert_base(l), config::bert_large(l)] {
            // longer sequences expose more attention redundancy
            let mut p = profile(bq + 0.02, bkv + 0.02, ba + 0.004, bf, i);
            p.attn = p.attn.min(0.985);
            v.push(Benchmark { task, domain, model, profile: p });
            i += 1;
        }
    }
    // MNLI-mismatched on BERT-Base → +1 (reaches the paper's 26)
    v.push(Benchmark {
        task: "MNLI-mm",
        domain: TaskDomain::Glue,
        model: config::bert_base(128),
        profile: profile(bq, bkv, ba, bf, i),
    });
    i += 1;
    // decoder LMs on WikiText-2 (L = 512) → +3
    for model in [config::gpt2(512), config::llama2_7b(512), config::bloom_7b(512)] {
        // causal generation: slightly less FFN token similarity
        v.push(Benchmark {
            task: "WikiText-2",
            domain: TaskDomain::WikiText,
            model,
            profile: profile(bq - 0.03, bkv, ba - 0.005, bf - 0.05, i),
        });
        i += 1;
    }
    // ViT on ImageNet-1K → +2 (patch tokens: strong local similarity)
    for model in [config::vit_b16(), config::vit_b32()] {
        v.push(Benchmark {
            task: "ImageNet-1K",
            domain: TaskDomain::ImageNet,
            model,
            profile: profile(bq + 0.05, bkv + 0.01, ba + 0.002, bf + 0.08, i),
        });
        i += 1;
    }
    assert_eq!(v.len(), 26);
    v
}

/// Cross-benchmark averages (the Fig 15 headline row).
pub fn zoo_averages(benches: &[Benchmark]) -> (f64, f64, f64, f64) {
    let n = benches.len() as f64;
    (
        benches.iter().map(|b| b.overall_reduction()).sum::<f64>() / n,
        benches.iter().map(|b| b.profile.qkv()).sum::<f64>() / n,
        benches.iter().map(|b| b.profile.attn).sum::<f64>() / n,
        benches.iter().map(|b| b.profile.ffn).sum::<f64>() / n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_26_benchmarks() {
        let v = all_benchmarks();
        assert_eq!(v.len(), 26);
        // composition check
        assert_eq!(v.iter().filter(|b| b.domain == TaskDomain::Glue).count(), 17);
        assert_eq!(v.iter().filter(|b| b.domain == TaskDomain::WikiText).count(), 3);
        assert_eq!(v.iter().filter(|b| b.domain == TaskDomain::ImageNet).count(), 2);
    }

    #[test]
    fn averages_match_paper_fig15() {
        let (overall, qkv, attn, ffn) = zoo_averages(&all_benchmarks());
        assert!((overall - 0.517).abs() < 0.03, "overall {overall}");
        assert!((qkv - 0.6566).abs() < 0.02, "qkv {qkv}");
        assert!((attn - 0.9465).abs() < 0.01, "attn {attn}");
        assert!((ffn - 0.5033).abs() < 0.03, "ffn {ffn}");
    }

    #[test]
    fn per_benchmark_reduction_sane() {
        for b in all_benchmarks() {
            let r = b.overall_reduction();
            assert!((0.2..0.9).contains(&r), "{} {}: {r}", b.model.name, b.task);
        }
    }

    #[test]
    fn profiles_in_unit_interval() {
        for b in all_benchmarks() {
            for v in [b.profile.q, b.profile.kv, b.profile.attn, b.profile.ffn] {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn domains_have_paper_batches() {
        assert_eq!(TaskDomain::Glue.batch(), 32);
        assert_eq!(TaskDomain::Squad.batch(), 12);
        assert_eq!(TaskDomain::Cloth.batch(), 3);
        assert_eq!(TaskDomain::WikiText.batch(), 8);
    }
}
