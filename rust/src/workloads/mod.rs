//! The paper's 26-benchmark workload zoo (§V-A) plus analytic FLOP
//! accounting (Fig 1) and per-benchmark sparsity profiles measured at
//! the paper's loss ≤ 1% operating points.

pub mod bench26;
pub mod flops;

pub use bench26::{all_benchmarks, Benchmark, TaskDomain};
pub use flops::{breakeven_rows_global_similarity, model_gflops, ComputeBreakdown};
