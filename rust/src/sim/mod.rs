//! Cycle-level ESACT simulator (the paper's Verilator + custom-simulator
//! substitute — DESIGN.md §Substitutions): PE array, bit-level
//! prediction unit, functional units, SRAM working sets, DRAM timing
//! (the Ramulator substitute), the progressive-generation overlap and
//! the dynamic-allocation balancer, composed by [`engine`].

pub mod cluster;
pub mod dram;
pub mod dynalloc;
pub mod engine;
pub mod functional;
pub mod pe;
pub mod prediction_unit;
pub mod progressive;
pub mod sram;

pub use cluster::{simulate_cluster, ClusterResult};
pub use engine::{ablation, layer_breakdown, simulate_model, Features, LayerBreakdown, SimResult};
