//! On-chip SRAM buffer model: capacity accounting for the
//! weight/token/temp buffers and double-buffering feasibility checks
//! (whether a layer's working set streams or thrashes).

use crate::config::{HardwareConfig, ModelConfig};

/// Working-set requirement of one layer stage, in bytes (int8 data).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkingSet {
    /// Weight panel resident during the stage.
    pub weights: usize,
    /// Activation tokens resident.
    pub tokens: usize,
    /// Intermediate (scores, partial sums).
    pub temp: usize,
}

impl WorkingSet {
    /// QKV-generation stage per head-group: D×D weight panel tile,
    /// L×D tokens, L×Dh output per head.
    pub fn qkv_stage(cfg: &ModelConfig, hw: &HardwareConfig) -> WorkingSet {
        // weights stream tile-by-tile: resident tile = pe_rows × d_model
        // columns double-buffered
        WorkingSet {
            weights: 2 * hw.pe_rows * cfg.d_model,
            tokens: cfg.seq_len * cfg.d_model,
            temp: cfg.seq_len * cfg.d_head() * 4, // int32 psums
        }
    }

    /// Attention stage per head: K/V panels + SPA mask + score rows.
    pub fn attn_stage(cfg: &ModelConfig) -> WorkingSet {
        let l = cfg.seq_len;
        let dh = cfg.d_head();
        WorkingSet {
            weights: 0,
            tokens: 2 * l * dh, // K and V panels
            temp: l * l / 8 + l * 4 * 2, // bitmask + one score row (int32) dbl-buffered
        }
    }

    /// FFN stage: D×F tile + tokens + hidden activations.
    pub fn ffn_stage(cfg: &ModelConfig, hw: &HardwareConfig) -> WorkingSet {
        WorkingSet {
            weights: 2 * hw.pe_rows * cfg.d_ffn.min(cfg.d_model * 4),
            tokens: cfg.seq_len * cfg.d_model,
            temp: hw.pe_cols * cfg.seq_len * 4,
        }
    }

    pub fn total(&self) -> usize {
        self.weights + self.tokens + self.temp
    }

    /// Does this working set fit the three buffers?
    pub fn fits(&self, hw: &HardwareConfig) -> bool {
        self.weights <= hw.weight_buf && self.tokens <= hw.token_buf && self.temp <= hw.temp_buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    #[test]
    fn bert_base_stages_fit() {
        let hw = HardwareConfig::default();
        let cfg = config::bert_base(128);
        assert!(WorkingSet::qkv_stage(&cfg, &hw).fits(&hw));
        assert!(WorkingSet::attn_stage(&cfg).fits(&hw));
        assert!(WorkingSet::ffn_stage(&cfg, &hw).fits(&hw));
    }

    #[test]
    fn long_sequence_attention_fits() {
        // L = 512: K/V panels 2·512·64 = 64 KB, mask 32 KB — still fits
        let cfg = config::bert_large(512);
        let hw = HardwareConfig::default();
        assert!(WorkingSet::attn_stage(&cfg).fits(&hw));
    }

    #[test]
    fn oversized_tokens_detected() {
        // Llama2-7b @ L=512: tokens 512·4096 = 2 MB > 192 KB token buffer
        // → the engine must tile the sequence (checked by the engine)
        let cfg = config::llama2_7b(512);
        let hw = HardwareConfig::default();
        assert!(!WorkingSet::qkv_stage(&cfg, &hw).fits(&hw));
    }

    #[test]
    fn totals_add_up() {
        let ws = WorkingSet { weights: 10, tokens: 20, temp: 30 };
        assert_eq!(ws.total(), 60);
    }
}
