//! Progressive generation scheme (paper §IV-C, Fig 13): overlap the
//! window-wise prediction pipeline (predict Q / attention / similarity
//! per window) with formal QKV generation, eliminating most of the PE
//! idle time that a serial predict-then-generate schedule would incur.
//!
//! Schedule model:
//!
//! ```text
//! serial:       [ predict all ][ generate all ]
//! progressive:  [ predict K ][ w0 pred ][ w1 pred ]...
//!                            [ w0 gen  ][ w1 gen  ]...   (PE array)
//! total ≈ predict_K + pred_w + max(total_pred - pred_w, total_gen)
//! ```
//!
//! K is predicted first (all windows need K); after the first window's
//! prediction lands, the PE array starts generating and the two
//! pipelines run concurrently, bounded by the slower one.

/// Cycle accounting for one layer's prediction + generation phases.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Overlap {
    /// Serial schedule total.
    pub serial: u64,
    /// Progressive schedule total.
    pub progressive: u64,
}

impl Overlap {
    pub fn speedup(&self) -> f64 {
        self.serial as f64 / self.progressive.max(1) as f64
    }
}

/// Compose the overlap for one layer.
///
/// * `predict_k`: cycles to predict all K vectors (serial prefix);
/// * `predict_windows`: per-window prediction cycles (Q + attention +
///   similarity for that window), in window order;
/// * `generate`: total PE-array cycles for the layer's sparse QKV +
///   attention generation (assumed evenly divisible across windows).
pub fn overlap(predict_k: u64, predict_windows: &[u64], generate: u64) -> Overlap {
    let total_pred: u64 = predict_k + predict_windows.iter().sum::<u64>();
    let serial = total_pred + generate;
    if predict_windows.is_empty() {
        return Overlap { serial, progressive: serial };
    }
    // generation of window i can start once its prediction is done;
    // the PE array processes windows in order at gen_per_window each.
    let n = predict_windows.len() as u64;
    let gen_per_window = generate / n;
    let gen_rem = generate % n;
    let mut pred_done = predict_k;
    let mut pe_free = 0u64;
    for (i, &pw) in predict_windows.iter().enumerate() {
        pred_done += pw;
        let gw = gen_per_window + u64::from((i as u64) < gen_rem);
        let start = pred_done.max(pe_free);
        pe_free = start + gw;
    }
    Overlap { serial, progressive: pe_free }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_bound_when_prediction_cheap() {
        // tiny prediction, huge generation: progressive ≈ generation
        let o = overlap(10, &[5; 8], 8000);
        assert!(o.progressive < 8000 + 10 + 5 * 8);
        assert!(o.progressive >= 8000);
        assert!(o.speedup() > 1.0);
    }

    #[test]
    fn prediction_bound_when_generation_cheap() {
        let o = overlap(100, &[100; 8], 80);
        // progressive ≈ total prediction + last window's generation
        assert!(o.progressive <= 100 + 800 + 10 + 1);
        assert!(o.progressive >= 900);
    }

    #[test]
    fn no_windows_degenerates_to_serial() {
        let o = overlap(50, &[], 100);
        assert_eq!(o.progressive, o.serial);
        assert_eq!(o.speedup(), 1.0);
    }

    #[test]
    fn paper_magnitude_speedup() {
        // Fig 20: progressive contributes ≈1.18× when prediction is
        // ~20% of a layer's work (serial = 1.2·gen; progressive ≈ gen
        // + first-window latency)
        let gen = 10_000u64;
        let pred_w = vec![240u64; 8]; // 1920 window prediction
        let o = overlap(80, &pred_w, gen);
        let s = o.speedup();
        assert!((1.10..1.25).contains(&s), "speedup {s}");
    }

    #[test]
    fn monotone_in_generation() {
        let a = overlap(10, &[20; 4], 100).progressive;
        let b = overlap(10, &[20; 4], 1000).progressive;
        assert!(b > a);
    }

    #[test]
    fn prop_overlap_cycle_counts_well_formed() {
        // invariants for any schedule: the progressive total is bounded
        // below by each pipeline alone (overlap can hide work, never
        // create negative time) and above by the serial schedule, so
        // speedup ∈ [1, ∞) and no cycle count ever underflows.
        crate::util::prop::check(100, |rng| {
            let predict_k = rng.below(10_000);
            let n_windows = 1 + rng.below(32) as usize;
            let windows: Vec<u64> =
                (0..n_windows).map(|_| rng.below(5_000)).collect();
            let generate = rng.below(1_000_000);
            let o = overlap(predict_k, &windows, generate);
            let total_pred: u64 = predict_k + windows.iter().sum::<u64>();
            assert_eq!(o.serial, total_pred + generate);
            assert!(o.progressive >= generate, "generation hidden entirely");
            assert!(
                o.progressive >= total_pred,
                "prediction hidden entirely: {} < {total_pred}",
                o.progressive
            );
            assert!(o.progressive <= o.serial, "overlap slower than serial");
            if o.serial > 0 {
                assert!(o.speedup() >= 1.0 - 1e-12);
            }
        });
    }
}
