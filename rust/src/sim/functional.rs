//! Functional-module cycle models (paper Table II: top-k, LayerNorm,
//! softmax, "others"). Each unit is a simple throughput machine:
//! `lanes` elements per cycle plus a fixed pipeline latency.

/// Throughput/latency of one functional unit.
#[derive(Clone, Copy, Debug)]
pub struct FuncUnit {
    pub lanes: u64,
    pub pipeline: u64,
}

impl FuncUnit {
    pub const fn new(lanes: u64, pipeline: u64) -> Self {
        Self { lanes, pipeline }
    }

    /// Cycles to stream `n` elements through.
    pub fn cycles(&self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        n.div_ceil(self.lanes) + self.pipeline
    }
}

/// Top-k selector: a systolic bitonic partial-sorter over row chunks.
/// Selecting k of L per row costs ~L/lanes cycles per row (single pass,
/// keep-heap of bounded k ≤ 0.2·L — the paper caps k at 0.2 to bound
/// the subtractor count).
pub const TOPK: FuncUnit = FuncUnit::new(128, 6);

/// Softmax: exp lookup + row-sum + divide, 64 lanes.
pub const SOFTMAX: FuncUnit = FuncUnit::new(64, 10);

/// LayerNorm: two-pass mean/var + normalize, 64 lanes.
pub const LAYERNORM: FuncUnit = FuncUnit::new(64, 8);

/// Row-wise top-k over an L×L matrix.
pub fn topk_cycles(l: usize) -> u64 {
    (0..l).map(|_| TOPK.cycles(l as u64)).sum()
}

/// Softmax over `rows` rows of `cols` kept entries each.
pub fn softmax_cycles(rows: usize, cols_kept: usize) -> u64 {
    (rows as u64) * SOFTMAX.cycles(cols_kept as u64)
}

/// LayerNorm over an L×D activation.
pub fn layernorm_cycles(l: usize, d: usize) -> u64 {
    (l as u64) * LAYERNORM.cycles(d as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_elements_free() {
        assert_eq!(TOPK.cycles(0), 0);
        assert_eq!(softmax_cycles(0, 64), 0);
    }

    #[test]
    fn throughput_scaling() {
        assert_eq!(TOPK.cycles(128), 1 + 6);
        assert_eq!(TOPK.cycles(256), 2 + 6);
        assert!(topk_cycles(512) > topk_cycles(128) * 3);
    }

    #[test]
    fn sparse_softmax_cheaper() {
        // softmax over kept entries only (SPA rows)
        let dense = softmax_cycles(128, 128);
        let sparse = softmax_cycles(128, 13);
        assert!(sparse < dense);
    }

    #[test]
    fn functional_minor_vs_gemm() {
        // functional units must not dominate a BERT-base layer
        let hw = crate::config::HardwareConfig::default();
        let gemm = crate::sim::pe::gemm(&hw, 128, 768, 768).cycles;
        let func = topk_cycles(128) + softmax_cycles(128, 16) + layernorm_cycles(128, 768);
        assert!(func < gemm, "func {func} gemm {gemm}");
    }
}
