//! Dynamic allocation strategy (paper §IV-D, Fig 14): after multi-head
//! concatenation, the number of preserved critical vectors differs per
//! row, unbalancing the PE lines. The strategy compresses the
//! concatenated map and dynamically matches work to PE lines, with a
//! FIFO-based recovery that reconstructs similar vectors' partial sums
//! from their critical rows.

use crate::config::HardwareConfig;
use crate::sim::pe::{gemm_irregular, GemmCycles};

/// Per-row work after concatenation: how many head-blocks of partial
/// sums each output row needs computed (critical) vs recovered.
#[derive(Clone, Debug, Default)]
pub struct ConcatLoad {
    /// `work[r]` = number of Psum blocks row r computes explicitly.
    pub work: Vec<usize>,
    /// blocks recovered via FIFO replication (free on the PE array,
    /// one FIFO push each).
    pub recovered: u64,
}

/// Build the concatenated load from per-head critical/similar maps:
/// for each head, critical rows contribute one block of work at their
/// row; similar rows contribute a recovery.
pub fn concat_load(head_reps: &[Vec<usize>]) -> ConcatLoad {
    assert!(!head_reps.is_empty());
    let l = head_reps[0].len();
    let mut work = vec![0usize; l];
    let mut recovered = 0u64;
    for rep in head_reps {
        assert_eq!(rep.len(), l);
        for (r, &c) in rep.iter().enumerate() {
            if r == c {
                work[r] += 1;
            } else {
                recovered += 1;
            }
        }
    }
    ConcatLoad { work, recovered }
}

/// Output-projection cycles for the concatenated attention under a
/// static (round-robin rows → PE lines, stragglers stall) or dynamic
/// (compressed + matched) allocation. `dh` is the per-block depth.
pub fn projection_cycles(
    hw: &HardwareConfig,
    load: &ConcatLoad,
    dh: usize,
    dynamic: bool,
) -> GemmCycles {
    let mut g = gemm_irregular(hw, &load.work, dh, dynamic);
    // FIFO recovery: one push per recovered block, hidden behind
    // compute when dynamic (the FIFOs fill while the lines crunch);
    // serialized on the critical path when static.
    if !dynamic {
        g.cycles += load.recovered.div_ceil(hw.pe_rows as u64);
    }
    g
}

/// Speedup of dynamic over static allocation for a given load.
pub fn dynalloc_speedup(hw: &HardwareConfig, load: &ConcatLoad, dh: usize) -> f64 {
    let stat = projection_cycles(hw, load, dh, false);
    let dynm = projection_cycles(hw, load, dh, true);
    stat.cycles as f64 / dynm.cycles.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::util::rng::Xoshiro256pp;

    fn hw() -> HardwareConfig {
        HardwareConfig::default()
    }

    #[test]
    fn concat_load_counts() {
        // 2 heads, 4 rows; head0: row1 similar to 0; head1: all critical
        let load = concat_load(&[vec![0, 0, 2, 3], vec![0, 1, 2, 3]]);
        assert_eq!(load.work, vec![2, 1, 2, 2]);
        assert_eq!(load.recovered, 1);
    }

    #[test]
    fn balanced_loads_gain_nothing() {
        let load = ConcatLoad { work: vec![8; 64], recovered: 0 };
        let s = dynalloc_speedup(&hw(), &load, 64);
        assert!((0.95..=1.1).contains(&s), "speedup {s}");
    }

    #[test]
    fn skewed_loads_gain() {
        // highly irregular per-row work — the case Fig 14 targets
        let mut rng = Xoshiro256pp::new(5);
        let work: Vec<usize> = (0..128)
            .map(|_| if rng.below(4) == 0 { 12 } else { 1 })
            .collect();
        let load = ConcatLoad { work, recovered: 300 };
        let s = dynalloc_speedup(&hw(), &load, 64);
        assert!(s > 1.2, "speedup {s}");
    }

    #[test]
    fn paper_magnitude_speedup() {
        // Fig 20: dynamic allocation contributes ≈1.04× end-to-end; at
        // the attention-concat stage itself the local gain is modest —
        // mild head-dependent skew (paper: similarity differs per head)
        let mut rng = Xoshiro256pp::new(11);
        let reps: Vec<Vec<usize>> = (0..12)
            .map(|_| {
                (0..128)
                    .map(|r| if rng.below(5) < 2 && r % 8 != 0 { r - (r % 8) } else { r })
                    .collect()
            })
            .collect();
        let load = concat_load(&reps);
        let s = dynalloc_speedup(&hw(), &load, 64);
        // local stage gain exceeds the paper's 1.04× *end-to-end* figure
        // because the projection stage is a small slice of a layer
        assert!((1.0..2.2).contains(&s), "speedup {s}");
    }

    #[test]
    fn prop_balanced_schedule_within_one_tile_of_ideal() {
        // load-balancing invariant: under dynamic allocation no PE lane
        // ends up with more than the ideal mean share plus ONE tile of
        // work — i.e. balanced cycles ∈ [ideal, ideal + one tile pass]
        // (plus the fixed pipeline fill), for any per-row work pattern.
        let hw = hw();
        let col_pass = 64usize.div_ceil(hw.pe_cols) as u64; // dh = 64
        let fill = hw.pe_rows as u64 + 8;
        crate::util::prop::check(60, |rng| {
            let l = 1 + rng.below(256) as usize;
            let work: Vec<usize> =
                (0..l).map(|_| rng.below(33) as usize).collect();
            let load = ConcatLoad { work: work.clone(), recovered: rng.below(500) };
            let g = projection_cycles(&hw, &load, 64, true);
            let total: u64 = work.iter().map(|&w| w as u64).sum();
            if total == 0 {
                return;
            }
            let ideal = total.div_ceil(hw.pe_rows as u64) * col_pass;
            let balanced = g.cycles - fill;
            assert!(balanced >= total / hw.pe_rows as u64 * col_pass, "below ideal");
            assert!(
                balanced <= ideal + col_pass,
                "lane exceeds ideal by more than one tile: {balanced} vs {ideal}"
            );
            // and dynamic never loses to static
            let s = projection_cycles(&hw, &load, 64, false);
            assert!(g.cycles <= s.cycles, "dynamic {} static {}", g.cycles, s.cycles);
        });
    }

    #[test]
    fn recovery_hidden_when_dynamic() {
        let load = ConcatLoad { work: vec![4; 32], recovered: 1000 };
        let d = projection_cycles(&hw(), &load, 64, true);
        let st = projection_cycles(&hw(), &load, 64, false);
        assert!(st.cycles > d.cycles);
    }
}
