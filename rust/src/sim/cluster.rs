//! Cluster-level deployment simulation (paper §V-C): 125 ESACT units
//! in 25 clusters, each workload partitioned batch → head → sequence
//! and assigned in order. Models per-cluster queueing and completion
//! skew instead of assuming perfect division by 125, so imbalance,
//! stragglers and small-batch under-filling show up in the end-to-end
//! throughput exactly where the paper's deployment would see them.

use crate::config::{DeployConfig, HardwareConfig, ModelConfig, SplsConfig};
use crate::coordinator::partition::partition_workload;
use crate::sim::engine::{simulate_model, Features, SimResult};
use crate::workloads::bench26::SparsityProfile;

/// Result of running one batch of a model across the cluster array.
#[derive(Clone, Copy, Debug)]
pub struct ClusterResult {
    /// Wall-clock seconds for the whole batch (slowest cluster).
    pub batch_seconds: f64,
    /// Mean per-cluster busy time / wall time.
    pub cluster_utilization: f64,
    /// Sequences per second across the array.
    pub throughput_seq_s: f64,
}

/// Simulate one batch of `cfg` across the deployment.
///
/// Per-sequence per-unit time comes from the single-unit cycle model;
/// a shard covering `v` (batch·head·seq) cells costs proportionally.
/// Units inside a cluster split their cluster's shards evenly; the
/// batch completes when the slowest cluster finishes.
pub fn simulate_cluster(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
    deploy: &DeployConfig,
    batch: usize,
    feat: Features,
) -> (ClusterResult, SimResult) {
    let unit = simulate_model(cfg, hw, spls, profile, feat);
    let per_seq = unit.seconds(hw);
    let total_cells = (batch * cfg.n_heads * cfg.seq_len) as f64;
    let assignment = partition_workload(deploy, cfg, batch);
    let units_per_cluster = deploy.units_per_cluster() as f64;
    let mut busy = vec![0.0f64; deploy.n_clusters];
    for item in &assignment.items {
        // shard cost: fraction of a full sequence-batch, split across
        // the units of the cluster
        let frac = item.volume() as f64 / total_cells;
        busy[item.cluster] += frac * per_seq * batch as f64 / units_per_cluster;
    }
    let wall = busy.iter().cloned().fold(0.0, f64::max);
    let mean_busy = busy.iter().sum::<f64>() / deploy.n_clusters as f64;
    (
        ClusterResult {
            batch_seconds: wall,
            cluster_utilization: if wall > 0.0 { mean_busy / wall } else { 1.0 },
            throughput_seq_s: if wall > 0.0 { batch as f64 / wall } else { 0.0 },
        },
        unit,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn setup() -> (HardwareConfig, SplsConfig, SparsityProfile, DeployConfig) {
        (
            HardwareConfig::default(),
            SplsConfig::default(),
            SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 },
            DeployConfig::default(),
        )
    }

    #[test]
    fn big_batch_near_ideal_scaling() {
        let (hw, spls, prof, dep) = setup();
        let cfg = config::bert_base(128);
        let (c, unit) = simulate_cluster(&cfg, &hw, &spls, &prof, &dep, 125, Features::FULL);
        let ideal = 125.0 / unit.seconds(&hw);
        assert!(c.throughput_seq_s > 0.8 * ideal, "{} vs ideal {ideal}", c.throughput_seq_s);
        assert!(c.cluster_utilization > 0.8);
    }

    #[test]
    fn small_batch_underfills_clusters() {
        let (hw, spls, prof, dep) = setup();
        let cfg = config::bert_base(128);
        let (big, _) = simulate_cluster(&cfg, &hw, &spls, &prof, &dep, 125, Features::FULL);
        let (small, _) = simulate_cluster(&cfg, &hw, &spls, &prof, &dep, 3, Features::FULL);
        // per-sequence efficiency drops when the array is underfilled
        assert!(
            small.throughput_seq_s < big.throughput_seq_s,
            "small {} big {}",
            small.throughput_seq_s,
            big.throughput_seq_s
        );
    }

    #[test]
    fn utilization_bounded() {
        let (hw, spls, prof, dep) = setup();
        for batch in [1usize, 8, 32, 125] {
            let (c, _) =
                simulate_cluster(&config::gpt2(512), &hw, &spls, &prof, &dep, batch, Features::FULL);
            assert!((0.0..=1.0 + 1e-9).contains(&c.cluster_utilization), "{}", c.cluster_utilization);
        }
    }

    #[test]
    fn sparse_beats_dense_at_cluster_level_too() {
        let (hw, spls, prof, dep) = setup();
        let cfg = config::bert_large(512);
        let (dense, _) = simulate_cluster(&cfg, &hw, &spls, &prof, &dep, 32, Features::DENSE);
        let (full, _) = simulate_cluster(&cfg, &hw, &spls, &prof, &dep, 32, Features::FULL);
        assert!(full.throughput_seq_s > 1.4 * dense.throughput_seq_s);
    }
}
