//! Cycle model of the bit-level prediction unit (paper §IV-B, Fig 11):
//! 128 shift-detector lanes feeding an 8×128 shift-judgment adder array
//! and a converter. The unit produces 8 predicted output elements per
//! pass, each accumulating a 128-deep dot product per cycle.

use crate::config::HardwareConfig;

/// Cycle count + energy-relevant op count for predicting one GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictCycles {
    pub cycles: u64,
    /// HLog products formed (SD+SJA ops; drives the energy model).
    pub products: u64,
}

/// Predict an (M, K) × (K, N) product through the unit.
///
/// Throughput: 8 outputs in parallel, each consuming `lanes` (=128)
/// products per cycle → per cycle the unit retires `8 · lanes`
/// products. Conversion overlaps accumulation (the converter is
/// pipelined behind the SJA), adding a small drain.
pub fn predict_gemm(hw: &HardwareConfig, m: usize, k: usize, n: usize) -> PredictCycles {
    if m == 0 || k == 0 || n == 0 {
        return PredictCycles { cycles: 0, products: 0 };
    }
    let lanes = hw.pred_lanes as u64; // 128
    let k_pass = (k as u64).div_ceil(lanes);
    let out_groups = (m as u64 * n as u64).div_ceil(8);
    let drain = 4; // converter pipeline depth
    PredictCycles {
        cycles: out_groups * k_pass + drain,
        products: (m * k * n) as u64,
    }
}

/// Full attention-prediction cycles for one head (paper Fig 5a):
/// predict Q (L×D·Dh) + predict K + requantize + predict QKᵀ (L×Dh·L).
pub fn predict_attention_cycles(
    hw: &HardwareConfig,
    l: usize,
    d: usize,
    dh: usize,
) -> PredictCycles {
    let q = predict_gemm(hw, l, d, dh);
    let k = predict_gemm(hw, l, d, dh);
    let a = predict_gemm(hw, l, dh, l);
    // requantization: 2·L·Dh max/scale passes on the functional units,
    // 1 element/lane/cycle
    let requant = (2 * l * dh) as u64 / hw.pred_lanes as u64 + 2;
    PredictCycles {
        cycles: q.cycles + k.cycles + a.cycles + requant,
        products: q.products + k.products + a.products,
    }
}

/// Local similarity cycles over the SPA: the 8×26 subtractor bank
/// compares one row pair per `ceil(L / (8·26))` cycles; within a
/// window of w rows at most w−1 comparisons per row.
pub fn similarity_cycles(hw: &HardwareConfig, l: usize, window: usize) -> u64 {
    let _ = hw;
    let sub_lanes = 8 * 26u64;
    let comparisons = (l * (window - 1)) as u64; // paper §III-B bound
    let per_cmp = (l as u64).div_ceil(sub_lanes);
    comparisons * per_cmp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn hw() -> HardwareConfig {
        HardwareConfig::default()
    }

    #[test]
    fn predict_cycles_scale_with_work() {
        let a = predict_gemm(&hw(), 128, 768, 64);
        let b = predict_gemm(&hw(), 128, 768, 128);
        assert!(b.cycles > a.cycles * 19 / 10);
        assert_eq!(a.products, 128 * 768 * 64);
    }

    #[test]
    fn prediction_faster_than_pe_for_same_gemm() {
        // the unit retires 8·128 = 1024 products/cycle — same rate as
        // the PE array's peak, but on the *prediction* path where the
        // PE array would otherwise idle
        let p = predict_gemm(&hw(), 128, 768, 64);
        let g = crate::sim::pe::gemm(&hw(), 128, 768, 64);
        assert!(p.cycles < g.cycles * 2, "p {} g {}", p.cycles, g.cycles);
    }

    #[test]
    fn attention_prediction_composition() {
        let pa = predict_attention_cycles(&hw(), 128, 768, 64);
        let q = predict_gemm(&hw(), 128, 768, 64);
        assert!(pa.cycles > 2 * q.cycles);
        assert_eq!(
            pa.products,
            2 * (128 * 768 * 64) as u64 + (128 * 64 * 128) as u64
        );
    }

    #[test]
    fn similarity_much_cheaper_than_prediction() {
        let sim = similarity_cycles(&hw(), 128, 8);
        let pred = predict_attention_cycles(&hw(), 128, 768, 64).cycles;
        assert!(sim < pred / 4, "sim {sim} pred {pred}");
    }

    #[test]
    fn empty_prediction_free() {
        assert_eq!(predict_gemm(&hw(), 0, 10, 10).cycles, 0);
    }
}
