//! Whole-model cycle-level simulation (paper §V-C methodology): per
//! stage cycles from the PE-array / prediction-unit / functional models,
//! progressive-generation overlap, dynamic-allocation balancing, DRAM
//! overlap, and an op-level energy integral.
//!
//! Feature toggles reproduce the Fig 20 ablation waterfall:
//! dense ASIC → +SPLS → +progressive → +dynamic allocation.

use crate::config::{HardwareConfig, ModelConfig, SplsConfig};
use crate::energy::ops::E28;
use crate::sim::dram::{layer_traffic_bytes, Dram, DramConfig};
use crate::sim::functional::{layernorm_cycles, softmax_cycles, topk_cycles};
use crate::sim::pe::{gemm, gemm_irregular, gemm_rows};
use crate::sim::prediction_unit::{predict_attention_cycles, similarity_cycles};
use crate::sim::progressive::overlap;
use crate::workloads::bench26::SparsityProfile;

/// Which ESACT mechanisms are active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Features {
    pub spls: bool,
    pub progressive: bool,
    pub dynalloc: bool,
}

impl Features {
    pub const DENSE: Features = Features { spls: false, progressive: false, dynalloc: false };
    pub const SPLS: Features = Features { spls: true, progressive: false, dynalloc: false };
    pub const SPLS_PROG: Features = Features { spls: true, progressive: true, dynalloc: false };
    pub const FULL: Features = Features { spls: true, progressive: true, dynalloc: true };
}

/// Simulation result for one sequence through one model.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimResult {
    pub cycles: u64,
    /// MACs actually executed on the PE array.
    pub macs: u64,
    /// HLog products formed in the prediction unit.
    pub pred_products: u64,
    /// Dense-equivalent FLOPs (MAC = 1) of the workload.
    pub dense_flops: f64,
    /// Bytes moved over DRAM.
    pub dram_bytes: u64,
    /// Peak per-unit DRAM bandwidth demand observed (bytes/s).
    pub peak_bw: f64,
}

impl SimResult {
    /// Seconds at the accelerator clock.
    pub fn seconds(&self, hw: &HardwareConfig) -> f64 {
        self.cycles as f64 / hw.freq_hz
    }

    /// Dense-equivalent throughput in ops/s (2 ops per MAC — the TOPS
    /// convention of the paper's V100 comparison).
    pub fn effective_ops(&self, hw: &HardwareConfig) -> f64 {
        2.0 * self.dense_flops / self.seconds(hw)
    }

    /// PE-array utilization over the run.
    pub fn pe_utilization(&self, hw: &HardwareConfig) -> f64 {
        self.macs as f64 / (self.cycles as f64 * (hw.pe_rows * hw.pe_cols) as f64)
    }

    /// Average power draw in watts: the Table II module budgets scaled
    /// by measured activity (the paper's DC-power × simulated-time
    /// energy methodology, with activity from the cycle simulation).
    ///
    /// * PE array (324.14 mW) scales with PE utilization;
    /// * prediction module (57.43 mW) with its products/cycle occupancy;
    /// * SRAM (317.84 mW) tracks PE activity (operand streaming);
    /// * functional module (92.71 mW) at ~50% duty;
    /// * ~20% of the total is static/clock and burns regardless.
    pub fn avg_power_w(&self, hw: &HardwareConfig) -> f64 {
        let util = self.pe_utilization(hw);
        let pred_cap = 8.0 * hw.pred_lanes as f64; // products/cycle
        let pred_act =
            (self.pred_products as f64 / (self.cycles.max(1) as f64 * pred_cap)).min(1.0);
        let dynamic = 0.8
            * (0.32414 * util + 0.05743 * pred_act + 0.31784 * util + 0.09271 * 0.5);
        0.2 * 0.792 + dynamic
    }

    /// Energy in joules: average power × runtime + off-chip DRAM.
    pub fn energy_j(&self, hw: &HardwareConfig) -> f64 {
        let dram = self.dram_bytes as f64 * E28.dram_byte * 1e-12;
        self.avg_power_w(hw) * self.seconds(hw) + dram
    }

    /// Energy efficiency in TOPS/W (dense-equivalent).
    pub fn tops_per_watt(&self, hw: &HardwareConfig) -> f64 {
        2.0 * self.dense_flops / self.energy_j(hw) / 1e12
    }
}

/// Per-row attention keep counts for one head under a sparsity profile:
/// similar rows drop to 0, critical rows keep `ceil(k·L)`. Similar rows
/// are *scattered* through the sequence (they sit next to their
/// critical row inside each window, not in one contiguous block), which
/// is exactly the irregularity that stalls a statically-allocated PE
/// array and that the dynamic allocation strategy absorbs (Fig 14).
fn attention_keep(l: usize, profile: &SparsityProfile, spls: &SplsConfig) -> Vec<usize> {
    let kept_per_row = ((spls.top_k as f64 * l as f64).ceil()).max(1.0) as usize;
    let n_similar = (profile.q * l as f64).round() as usize;
    // deterministic scatter: mark every ⌈l/n_similar⌉-th position similar
    let mut keep = vec![kept_per_row; l];
    if n_similar > 0 {
        let stride = l as f64 / n_similar as f64;
        for i in 0..n_similar {
            let pos = (i as f64 * stride) as usize;
            keep[pos.min(l - 1)] = 0;
        }
    }
    keep
}

/// Per-row count of critical head-blocks after multi-head concat:
/// head `i`'s similar rows are the same scatter pattern phase-shifted
/// by `i` (different heads collapse different rows — paper §IV-D).
fn concat_work(l: usize, h: usize, profile: &SparsityProfile, spls: &SplsConfig) -> Vec<usize> {
    let mut work = vec![0usize; l];
    for head in 0..h {
        let base = attention_keep(l, profile, spls);
        for (r, w) in work.iter_mut().enumerate() {
            // phase shift the pattern by 3 rows per head within windows
            let src = (r + head * 3) % l;
            if base[src] > 0 {
                *w += 1;
            }
        }
    }
    work
}

/// Straggler penalty of static allocation: per `lanes`-row chunk the
/// line stalls at the chunk's max block count; dynamic allocation packs
/// to the mean. Returns max-based over mean-based cycles (≥ 1).
fn imbalance_factor(work: &[usize], lanes: usize) -> f64 {
    let total: usize = work.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let stalled: usize = work
        .chunks(lanes)
        .map(|c| c.iter().max().copied().unwrap_or(0) * c.len())
        .sum();
    (stalled as f64 / total as f64).max(1.0)
}

/// Simulate one layer; returns (compute cycles, prediction cycles,
/// macs, pred products).
fn simulate_layer(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
    feat: Features,
) -> (u64, u64, u64, u64) {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let dh = cfg.d_head();
    let h = cfg.n_heads;
    let f = cfg.d_ffn;

    let (q_keep, kv_keep, ffn_keep) = if feat.spls {
        (1.0 - profile.q, 1.0 - profile.kv, 1.0 - profile.ffn)
    } else {
        (1.0, 1.0, 1.0)
    };

    // --- formal-phase GEMMs on the PE array ------------------------
    let q_rows = (q_keep * l as f64).round() as usize;
    let kv_rows = (kv_keep * l as f64).round() as usize;
    let ffn_rows = (ffn_keep * l as f64).round() as usize;

    let g_q = gemm_rows(hw, q_rows, d, d);
    let g_k = gemm_rows(hw, kv_rows, d, d);
    let g_v = gemm_rows(hw, kv_rows, d, d);
    // Output projection over the concatenated heads: each head's
    // critical pattern differs (phase-shifted scatter), so per-row
    // valid-block counts are irregular after concat — the Fig 14
    // situation. Static allocation stalls on the per-chunk straggler;
    // the dynamic allocation strategy compresses and re-matches.
    let mut g_o = gemm_rows(hw, q_rows, d, d);
    if feat.spls && !feat.dynalloc {
        let work = concat_work(l, h, profile, spls);
        g_o.cycles = (g_o.cycles as f64 * imbalance_factor(&work, hw.pe_rows)) as u64;
    }

    let (attn_cycles, attn_macs) = if feat.spls {
        let keep = attention_keep(l, profile, spls);
        let qk = gemm_irregular(hw, &keep, dh, feat.dynalloc);
        let av = gemm_irregular(hw, &keep, dh, feat.dynalloc);
        ((qk.cycles + av.cycles) * h as u64, (qk.macs + av.macs) * h as u64)
    } else {
        let qk = gemm(hw, l, dh, l);
        let av = gemm(hw, l, l, dh);
        ((qk.cycles + av.cycles) * h as u64, (qk.macs + av.macs) * h as u64)
    };

    let g_f1 = gemm_rows(hw, ffn_rows, d, f);
    let g_f2 = gemm_rows(hw, ffn_rows, f, d);

    // functional units (softmax over kept entries, LN ×2, top-k when
    // predicting)
    let kept_cols = if feat.spls {
        ((spls.top_k as f64 * l as f64).ceil()) as usize
    } else {
        l
    };
    let func = softmax_cycles(q_rows, kept_cols) * h as u64
        + 2 * layernorm_cycles(l, d);

    let gen_cycles = g_q.cycles
        + g_k.cycles
        + g_v.cycles
        + g_o.cycles
        + attn_cycles
        + g_f1.cycles
        + g_f2.cycles
        + func;
    let macs = g_q.macs + g_k.macs + g_v.macs + g_o.macs + attn_macs + g_f1.macs + g_f2.macs;

    // --- prediction phase -------------------------------------------
    let (pred_cycles, pred_products) = if feat.spls {
        let pa = predict_attention_cycles(hw, l, d, dh);
        let per_head = pa.cycles + topk_cycles(l) / h as u64 + similarity_cycles(hw, l, spls.window) / h as u64;
        // heads predicted sequentially through the single 128-lane unit
        (per_head * h as u64, pa.products * h as u64)
    } else {
        (0, 0)
    };

    (gen_cycles, pred_cycles, macs, pred_products)
}

/// Per-stage cycle breakdown of one layer (observability for
/// `esact sim` and the trace tests; stages follow Fig 10's flow).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerBreakdown {
    pub qkv_gen: u64,
    pub attention: u64,
    pub out_proj: u64,
    pub ffn: u64,
    pub functional: u64,
    pub prediction: u64,
}

impl LayerBreakdown {
    pub fn compute_total(&self) -> u64 {
        self.qkv_gen + self.attention + self.out_proj + self.ffn + self.functional
    }
}

/// Expose the per-stage cycle breakdown of one layer (the same
/// arithmetic as `simulate_layer`, kept in sync by the
/// `breakdown_matches_engine` test).
pub fn layer_breakdown(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
    feat: Features,
) -> LayerBreakdown {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let dh = cfg.d_head();
    let h = cfg.n_heads;
    let f = cfg.d_ffn;
    let (q_keep, kv_keep, ffn_keep) = if feat.spls {
        (1.0 - profile.q, 1.0 - profile.kv, 1.0 - profile.ffn)
    } else {
        (1.0, 1.0, 1.0)
    };
    let q_rows = (q_keep * l as f64).round() as usize;
    let kv_rows = (kv_keep * l as f64).round() as usize;
    let ffn_rows = (ffn_keep * l as f64).round() as usize;
    let qkv_gen = gemm_rows(hw, q_rows, d, d).cycles + 2 * gemm_rows(hw, kv_rows, d, d).cycles;
    let mut out_proj = gemm_rows(hw, q_rows, d, d).cycles;
    if feat.spls && !feat.dynalloc {
        let work = concat_work(l, h, profile, spls);
        out_proj = (out_proj as f64 * imbalance_factor(&work, hw.pe_rows)) as u64;
    }
    let attention = if feat.spls {
        let keep = attention_keep(l, profile, spls);
        2 * gemm_irregular(hw, &keep, dh, feat.dynalloc).cycles * h as u64
    } else {
        (gemm(hw, l, dh, l).cycles + gemm(hw, l, l, dh).cycles) * h as u64
    };
    let ffn = gemm_rows(hw, ffn_rows, d, f).cycles + gemm_rows(hw, ffn_rows, f, d).cycles;
    let kept_cols = if feat.spls {
        (spls.top_k as f64 * l as f64).ceil() as usize
    } else {
        l
    };
    let functional = softmax_cycles(q_rows, kept_cols) * h as u64 + 2 * layernorm_cycles(l, d);
    let prediction = if feat.spls {
        let pa = predict_attention_cycles(hw, l, d, dh);
        (pa.cycles + topk_cycles(l) / h as u64 + similarity_cycles(hw, l, spls.window) / h as u64)
            * h as u64
    } else {
        0
    };
    LayerBreakdown { qkv_gen, attention, out_proj, ffn, functional, prediction }
}

/// Simulate a full model (one sequence) under a sparsity profile.
pub fn simulate_model(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
    feat: Features,
) -> SimResult {
    let mut total_cycles = 0u64;
    let mut macs = 0u64;
    let mut products = 0u64;
    let mut dram = Dram::new(DramConfig::default());
    let mut peak_bw = 0.0f64;

    // Per-layer cycle accounting is independent of simulator state — fan
    // the layers out over the rayon pool, then run the order-dependent
    // DRAM/overlap accumulation serially below (the DRAM row-buffer state
    // and layer start addresses depend on the running cycle count, so
    // that fold must stay sequential to remain bit-identical).
    //
    // NOTE: today every layer sees the same (cfg, profile), so the tasks
    // are replicas and the fan-out buys wall-clock only relative to the
    // equally-replicated serial loop; the structure is here for per-layer
    // sparsity profiles (measured plans differ by layer — Figs 16-19),
    // where the tasks become genuinely distinct.
    use rayon::prelude::*;
    let layers: Vec<(u64, u64, u64, u64)> = (0..cfg.n_layers)
        .into_par_iter()
        .map(|_| simulate_layer(cfg, hw, spls, profile, feat))
        .collect();

    for (gen, pred, m, p) in layers {
        let layer_compute = if feat.progressive && pred > 0 {
            // window-wise prediction: K first (~1/3 of prediction),
            // then per-window Q/attn/sim overlap with generation
            let n_windows = cfg.seq_len.div_ceil(spls.window).max(1);
            let pred_k = pred / 3;
            let per_window = (pred - pred_k) / n_windows as u64;
            let windows = vec![per_window; n_windows];
            overlap(pred_k, &windows, gen).progressive
        } else {
            gen + pred
        };
        // DRAM traffic overlapped with compute (double-buffered)
        let (qkv_keep, ffn_keep) = if feat.spls {
            (1.0 - profile.qkv(), 1.0 - profile.ffn)
        } else {
            (1.0, 1.0)
        };
        let bytes = layer_traffic_bytes(cfg.d_model, cfg.d_ffn, cfg.seq_len, qkv_keep, ffn_keep);
        let mem_cycles = dram.stream(total_cycles << 12, bytes as usize);
        let layer_cycles = layer_compute.max(mem_cycles);
        let bw = bytes as f64 * hw.freq_hz / layer_cycles.max(1) as f64;
        peak_bw = peak_bw.max(bw);
        total_cycles += layer_cycles;
        macs += m;
        products += p;
    }

    let dense = crate::spls::plan::dense_model_flops(cfg);
    SimResult {
        cycles: total_cycles,
        macs,
        pred_products: products,
        dense_flops: dense.total(),
        dram_bytes: dram.stats.bytes,
        peak_bw,
    }
}

/// The Fig 20 ablation for one model: returns effective ops/s under
/// dense / +SPLS / +progressive / +dynalloc.
pub fn ablation(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
) -> [SimResult; 4] {
    [
        simulate_model(cfg, hw, spls, profile, Features::DENSE),
        simulate_model(cfg, hw, spls, profile, Features::SPLS),
        simulate_model(cfg, hw, spls, profile, Features::SPLS_PROG),
        simulate_model(cfg, hw, spls, profile, Features::FULL),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::workloads::bench26::all_benchmarks;

    fn defaults() -> (HardwareConfig, SplsConfig) {
        (HardwareConfig::default(), SplsConfig::default())
    }

    fn paper_profile() -> SparsityProfile {
        // the paper's Verilator calibration point: Q/K/V 60%, attention
        // 60% inter-row, FFN 50%
        SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 }
    }

    #[test]
    fn dense_utilization_high() {
        let (hw, spls) = defaults();
        let cfg = config::bert_base(128);
        let r = simulate_model(&cfg, &hw, &spls, &paper_profile(), Features::DENSE);
        assert!(r.pe_utilization(&hw) > 0.8, "util {}", r.pe_utilization(&hw));
        // dense ASIC executes every dense MAC
        assert!((r.macs as f64 / r.dense_flops - 1.0).abs() < 0.1);
    }

    #[test]
    fn spls_reduces_cycles() {
        let (hw, spls) = defaults();
        let cfg = config::bert_base(128);
        let d = simulate_model(&cfg, &hw, &spls, &paper_profile(), Features::DENSE);
        let s = simulate_model(&cfg, &hw, &spls, &paper_profile(), Features::SPLS);
        let speedup = d.cycles as f64 / s.cycles as f64;
        assert!((1.25..2.2).contains(&speedup), "SPLS speedup {speedup}");
    }

    #[test]
    fn progressive_and_dynalloc_add_speedup() {
        let (hw, spls) = defaults();
        let cfg = config::bert_base(128);
        let [_, s, p, f] = ablation(&cfg, &hw, &spls, &paper_profile());
        let prog = s.cycles as f64 / p.cycles as f64;
        let dyna = p.cycles as f64 / f.cycles as f64;
        assert!((1.02..1.40).contains(&prog), "progressive {prog}");
        assert!(dyna >= 0.99, "dynalloc {dyna}");
    }

    #[test]
    fn parallel_layer_fanout_is_deterministic() {
        // the rayon fan-out must be bit-identical to a single-thread run
        let (hw, spls) = defaults();
        let cfg = config::bert_base(128);
        let a = simulate_model(&cfg, &hw, &spls, &paper_profile(), Features::FULL);
        let pool = rayon::ThreadPoolBuilder::new().num_threads(1).build().unwrap();
        let b =
            pool.install(|| simulate_model(&cfg, &hw, &spls, &paper_profile(), Features::FULL));
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.macs, b.macs);
        assert_eq!(a.pred_products, b.pred_products);
        assert_eq!(a.dram_bytes, b.dram_bytes);
        assert_eq!(a.peak_bw, b.peak_bw);
    }

    #[test]
    fn bandwidth_below_paper_bound() {
        // paper: max 4.7 GB/s per unit, under the 7.2 GB/s share
        let (hw, spls) = defaults();
        for b in all_benchmarks().iter().take(6) {
            let r = simulate_model(&b.model, &hw, &spls, &b.profile, Features::FULL);
            assert!(
                r.peak_bw < hw.dram_bw,
                "{}: {} GB/s",
                b.model.name,
                r.peak_bw / 1e9
            );
        }
    }

    #[test]
    fn energy_efficiency_magnitude() {
        // Fig 21: end-to-end average 3.27 TOPS/W
        let (hw, spls) = defaults();
        let mut sum = 0.0;
        let benches = all_benchmarks();
        for b in &benches {
            let r = simulate_model(&b.model, &hw, &spls, &b.profile, Features::FULL);
            sum += r.tops_per_watt(&hw);
        }
        let avg = sum / benches.len() as f64;
        assert!((2.2..4.5).contains(&avg), "avg TOPS/W {avg}");
    }

    #[test]
    fn breakdown_matches_engine() {
        // the observability wrapper must track simulate_layer exactly
        let (hw, spls) = defaults();
        let cfg = config::bert_base(128);
        for feat in [Features::DENSE, Features::SPLS, Features::FULL] {
            let b = layer_breakdown(&cfg, &hw, &spls, &paper_profile(), feat);
            let (gen, pred, _, _) = simulate_layer(&cfg, &hw, &spls, &paper_profile(), feat);
            assert_eq!(b.compute_total(), gen, "{feat:?} compute");
            assert_eq!(b.prediction, pred, "{feat:?} prediction");
        }
    }

    #[test]
    fn breakdown_stage_shares_sane() {
        let (hw, spls) = defaults();
        let cfg = config::bert_base(128);
        let b = layer_breakdown(&cfg, &hw, &spls, &paper_profile(), Features::DENSE);
        // Fig 1 structure: FFN dominates a dense BERT layer
        assert!(b.ffn > b.qkv_gen / 2);
        assert!(b.ffn > b.attention);
        assert!(b.functional < b.compute_total() / 4);
    }

    #[test]
    fn vit_small_seq_still_works() {
        let (hw, spls) = defaults();
        let cfg = config::vit_b32(); // L = 50
        let r = simulate_model(&cfg, &hw, &spls, &paper_profile(), Features::FULL);
        assert!(r.cycles > 0);
        assert!(r.effective_ops(&hw) > 0.0);
    }
}
