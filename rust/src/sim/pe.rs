//! Weight-stationary PE-array cycle model (paper §IV-A/D).
//!
//! The 16×64 array holds a K×N weight tile (16 rows of the reduction
//! dimension × 64 output columns) and streams M input rows through it,
//! one row per cycle per tile pass. A GEMM of shape (M, K) × (K, N)
//! therefore takes
//!
//! ```text
//! cycles = M · ceil(K / 16) · ceil(N / 64) + fill
//! ```
//!
//! with a pipeline fill/drain of `rows + cols` cycles per weight-tile
//! load. Utilization is exact MACs over cycles × array size; partial
//! edge tiles are what pull it below 100%.

use crate::config::HardwareConfig;

/// Result of simulating one GEMM on the array.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemmCycles {
    pub cycles: u64,
    pub macs: u64,
    pub utilization: f64,
}

/// Cycles for a dense (M, K) × (K, N) GEMM.
pub fn gemm(hw: &HardwareConfig, m: usize, k: usize, n: usize) -> GemmCycles {
    if m == 0 || k == 0 || n == 0 {
        return GemmCycles { cycles: 0, macs: 0, utilization: 1.0 };
    }
    let tiles_k = k.div_ceil(hw.pe_rows) as u64;
    let tiles_n = n.div_ceil(hw.pe_cols) as u64;
    // weight-stationary: each tile's weights load through a wide port in
    // `pe_rows` cycles before the M input rows stream through it
    let fill = hw.pe_rows as u64;
    let cycles = (m as u64 + fill) * tiles_k * tiles_n;
    let macs = (m * k * n) as u64;
    let peak = cycles * (hw.pe_rows * hw.pe_cols) as u64;
    GemmCycles {
        cycles,
        macs,
        utilization: macs as f64 / peak as f64,
    }
}

/// Cycles for a *row-sparse* GEMM: only `m_active` of `m` rows are
/// computed (Q generation over critical rows; FFN over MFI tokens).
pub fn gemm_rows(hw: &HardwareConfig, m_active: usize, k: usize, n: usize) -> GemmCycles {
    gemm(hw, m_active, k, n)
}

/// Cycles for an attention GEMM with irregular per-row work: row `r`
/// computes `keep[r]` of `n` outputs (the SPA pattern). Without load
/// balancing, each batch of `pe_rows` rows costs the *max* keep among
/// them (the straggler effect the dynamic allocation strategy fixes);
/// `balanced` models the compressed/dynamically-matched schedule where
/// rows are packed so each batch costs the *mean* (rounded up).
pub fn gemm_irregular(
    hw: &HardwareConfig,
    keep: &[usize],
    dh: usize,
    balanced: bool,
) -> GemmCycles {
    if keep.is_empty() {
        return GemmCycles { cycles: 0, macs: 0, utilization: 1.0 };
    }
    let lanes = hw.pe_rows;
    // each kept output needs a Dh-deep dot product; the 64 columns of
    // the array compute ceil(dh/64) passes per output element batch
    let col_pass = dh.div_ceil(hw.pe_cols) as u64;
    let mut cycles = 0u64;
    if balanced {
        let total: u64 = keep.iter().map(|&k| k as u64).sum();
        cycles = total.div_ceil(lanes as u64) * col_pass;
    } else {
        for chunk in keep.chunks(lanes) {
            let worst = *chunk.iter().max().unwrap() as u64;
            cycles += worst * col_pass;
        }
    }
    // attention panels keep K/V resident in VMEM-side SRAM: the swap
    // cost is one lane-depth refill + a short drain (not a full
    // rows+cols weight reload) — calibrated against the paper's 81.57%
    // utilization anchor at k = 0.1, L = 128.
    let fill = hw.pe_rows as u64 + 8;
    cycles += fill;
    let macs: u64 = keep.iter().map(|&k| (k * dh) as u64).sum();
    let peak = cycles * (hw.pe_rows * hw.pe_cols) as u64;
    GemmCycles {
        cycles,
        macs,
        utilization: (macs as f64 / peak as f64).min(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn hw() -> HardwareConfig {
        HardwareConfig::default()
    }

    #[test]
    fn exact_tile_full_utilization_asymptotically() {
        // K=16, N=64 exactly one tile: util -> 1 as M grows
        let g = gemm(&hw(), 10_000, 16, 64);
        assert!(g.utilization > 0.98, "{}", g.utilization);
    }

    #[test]
    fn cycles_scale_linearly_in_m() {
        let a = gemm(&hw(), 128, 768, 768);
        let b = gemm(&hw(), 256, 768, 768);
        // near-linear: the per-tile weight-load fill amortizes with M
        assert!(b.cycles > a.cycles * 18 / 10 && b.cycles < a.cycles * 21 / 10);
    }

    #[test]
    fn partial_tiles_hurt_utilization() {
        let full = gemm(&hw(), 1024, 16, 64);
        let ragged = gemm(&hw(), 1024, 17, 65); // 2×2 tiles mostly empty
        assert!(ragged.utilization < full.utilization * 0.5);
    }

    #[test]
    fn empty_gemm_is_free() {
        assert_eq!(gemm(&hw(), 0, 16, 64).cycles, 0);
        assert_eq!(gemm_rows(&hw(), 0, 768, 768).cycles, 0);
    }

    #[test]
    fn irregular_balanced_beats_unbalanced() {
        // one heavy row per 16-row chunk: stragglers dominate unbalanced
        let keep: Vec<usize> = (0..128).map(|r| if r % 16 == 0 { 64 } else { 4 }).collect();
        let ub = gemm_irregular(&hw(), &keep, 64, false);
        let ba = gemm_irregular(&hw(), &keep, 64, true);
        assert!(ba.cycles < ub.cycles, "balanced {} vs {}", ba.cycles, ub.cycles);
        assert_eq!(ba.macs, ub.macs);
        assert!(ba.utilization > ub.utilization);
    }

    #[test]
    fn uniform_keep_balanced_equals_unbalanced() {
        let keep = vec![13usize; 128];
        let ub = gemm_irregular(&hw(), &keep, 64, false);
        let ba = gemm_irregular(&hw(), &keep, 64, true);
        // identical work per row: balancing gains nothing (± rounding)
        assert!(ub.cycles.abs_diff(ba.cycles) <= hw().pe_rows as u64 + 8);
    }

    #[test]
    fn paper_utilization_anchor() {
        // §V-C: at k = 0.1, L = 128 the paper reports 81.57% PE
        // utilization for intra-row-sparse attention. With keep = 13
        // (= ceil(0.1·128)) per row and Dh = 64, the balanced schedule
        // lands close to that number.
        let keep = vec![13usize; 128];
        let g = gemm_irregular(&hw(), &keep, 64, true);
        assert!((g.utilization - 0.8157).abs() < 0.1, "{}", g.utilization);
    }
}
