//! Off-chip DRAM timing model (the Ramulator substitute — see DESIGN.md
//! §Substitutions): banked row-buffer DRAM with tRCD/tRP/tCL timing and
//! bandwidth accounting. Used to verify the paper's claim that ESACT is
//! compute-bound (max 4.7 GB/s per unit against a 7.2 GB/s share).

/// DRAM timing parameters in *accelerator* cycles @ 500 MHz
/// (DDR4-2400-ish: tRCD 15 ns ≈ 8 cyc, tCL 15 ns, tRP 15 ns,
/// burst of 64 B in 4 cyc at the interface).
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    pub n_banks: usize,
    pub row_bytes: usize,
    pub t_rcd: u64,
    pub t_cl: u64,
    pub t_rp: u64,
    /// cycles per 64-byte burst on the data bus
    pub burst_cycles: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            n_banks: 16,
            row_bytes: 2048,
            t_rcd: 8,
            t_cl: 8,
            t_rp: 8,
            burst_cycles: 4,
        }
    }
}

/// Accumulated access statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DramStats {
    pub reads: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub cycles: u64,
    pub bytes: u64,
}

impl DramStats {
    pub fn hit_rate(&self) -> f64 {
        if self.reads == 0 {
            return 1.0;
        }
        self.row_hits as f64 / self.reads as f64
    }

    /// Achieved bandwidth in bytes/s at the given clock.
    pub fn bandwidth(&self, freq_hz: f64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.bytes as f64 * freq_hz / self.cycles as f64
    }
}

/// Banked DRAM with open-row policy.
pub struct Dram {
    cfg: DramConfig,
    open_row: Vec<Option<u64>>,
    pub stats: DramStats,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Self {
        Self {
            open_row: vec![None; cfg.n_banks],
            cfg,
            stats: DramStats::default(),
        }
    }

    /// Access `bytes` starting at `addr`; returns the cycles consumed.
    /// Sequential bursts within one row hit the row buffer.
    pub fn access(&mut self, addr: u64, bytes: usize) -> u64 {
        let mut cycles = 0u64;
        let mut a = addr;
        let mut remaining = bytes as u64;
        while remaining > 0 {
            let row = a / self.cfg.row_bytes as u64;
            let bank = (row % self.cfg.n_banks as u64) as usize;
            let in_row = self.cfg.row_bytes as u64 - (a % self.cfg.row_bytes as u64);
            let chunk = remaining.min(in_row);
            self.stats.reads += 1;
            if self.open_row[bank] == Some(row) {
                self.stats.row_hits += 1;
                cycles += self.cfg.t_cl;
            } else {
                self.stats.row_misses += 1;
                cycles += self.cfg.t_rp + self.cfg.t_rcd + self.cfg.t_cl;
                self.open_row[bank] = Some(row);
            }
            cycles += chunk.div_ceil(64) * self.cfg.burst_cycles;
            a += chunk;
            remaining -= chunk;
        }
        self.stats.cycles += cycles;
        self.stats.bytes += bytes as u64;
        cycles
    }

    /// Stream a large sequential transfer (weights/activations): the
    /// common case on ESACT's request path.
    pub fn stream(&mut self, addr: u64, bytes: usize) -> u64 {
        self.access(addr, bytes)
    }
}

/// Bytes moved per layer for a model under given component sparsity:
/// int8 weights streamed once, activations in/out.
pub fn layer_traffic_bytes(
    d_model: usize,
    d_ffn: usize,
    seq_len: usize,
    qkv_keep: f64,
    ffn_keep: f64,
) -> u64 {
    let w_attn = 4.0 * (d_model * d_model) as f64 * qkv_keep;
    let w_ffn = 2.0 * (d_model * d_ffn) as f64 * ffn_keep;
    let acts = 2.0 * (seq_len * d_model) as f64;
    (w_attn + w_ffn + acts) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_mostly_hits() {
        let mut d = Dram::new(DramConfig::default());
        d.stream(0, 1 << 20); // 1 MB sequential
        assert!(d.stats.hit_rate() < 0.1); // each 2 KB row = 1 miss, then chunk consumed whole
        // …but per-row cost is dominated by bursts, so effective BW is high
        let bw = d.stats.bandwidth(500e6);
        assert!(bw > 4e9, "sequential BW {bw}");
    }

    #[test]
    fn random_access_slower_than_sequential() {
        let mut seq = Dram::new(DramConfig::default());
        let seq_cycles = seq.stream(0, 64 * 1024);
        let mut rnd = Dram::new(DramConfig::default());
        let mut rnd_cycles = 0;
        for i in 0..1024u64 {
            rnd_cycles += rnd.access(i * 4096 + (i % 7) * 64, 64);
        }
        // same total bytes (64 KB), far more cycles when hopping rows
        assert!(rnd_cycles > seq_cycles, "rnd {rnd_cycles} seq {seq_cycles}");
    }

    #[test]
    fn stats_accumulate() {
        let mut d = Dram::new(DramConfig::default());
        d.access(0, 128);
        d.access(0, 128); // same row: hit
        assert_eq!(d.stats.reads, 2);
        assert_eq!(d.stats.row_hits, 1);
        assert_eq!(d.stats.bytes, 256);
    }

    #[test]
    fn traffic_scales_with_sparsity() {
        let dense = layer_traffic_bytes(768, 3072, 128, 1.0, 1.0);
        let sparse = layer_traffic_bytes(768, 3072, 128, 0.35, 0.5);
        assert!(sparse < dense * 6 / 10);
    }
}
