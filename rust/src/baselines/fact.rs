//! FACT-style end-to-end baseline (paper Table I row: PoT-quantized
//! eager correlation prediction, QKV + attention sparsity, but **no
//! true FFN sparsity** — FACT runs mixed-precision FFN without
//! eliminating token work, and its PoT prediction cannot preserve
//! inter-row similarity, so inter-row (similarity) sparsity is
//! unavailable; only intra-row top-k sparsity applies).
//!
//! This model quantifies the paper's central comparison: how much of
//! ESACT's end-to-end win comes from (a) similarity-based inter-row
//! sparsity and (b) FFN token sparsity that FACT's mechanism cannot
//! express.

use crate::config::{HardwareConfig, ModelConfig, SplsConfig};
use crate::sim::engine::{simulate_model, Features, SimResult};
use crate::workloads::bench26::SparsityProfile;

/// Simulate a FACT-style accelerator on the same cycle model: the
/// profile is clamped to what PoT-predicted intra-row sparsity alone
/// can deliver.
pub fn simulate_fact(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
) -> SimResult {
    let fact_profile = SparsityProfile {
        // no inter-row similarity -> every Q row generated
        q: 0.0,
        // column pruning from top-k still works (it is magnitude-based)
        kv: profile.kv,
        // attention keeps only intra-row top-k sparsity: density = k
        attn: 1.0 - spls.top_k as f64,
        // mixed-precision FFN ≈ no token elimination
        ffn: 0.0,
    };
    // FACT's "eager correlation prediction" overlaps prediction with
    // computation much like the progressive scheme (its headline
    // mechanism), so it gets the overlap credit; it has no
    // dynamic-allocation equivalent.
    simulate_model(cfg, hw, spls, &fact_profile, Features::SPLS_PROG)
}

/// ESACT-over-FACT end-to-end speedup decomposition for one model.
#[derive(Clone, Copy, Debug)]
pub struct FactComparison {
    pub fact_seconds: f64,
    pub esact_seconds: f64,
    pub speedup: f64,
}

pub fn compare_with_fact(
    cfg: &ModelConfig,
    hw: &HardwareConfig,
    spls: &SplsConfig,
    profile: &SparsityProfile,
) -> FactComparison {
    let fact = simulate_fact(cfg, hw, spls, profile);
    let esact = simulate_model(cfg, hw, spls, profile, Features::FULL);
    FactComparison {
        fact_seconds: fact.seconds(hw),
        esact_seconds: esact.seconds(hw),
        speedup: fact.seconds(hw) / esact.seconds(hw),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn setup() -> (HardwareConfig, SplsConfig, SparsityProfile) {
        (
            HardwareConfig::default(),
            SplsConfig::default(),
            SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 },
        )
    }

    #[test]
    fn esact_beats_fact_end_to_end() {
        let (hw, spls, prof) = setup();
        for cfg in [config::bert_base(128), config::bert_large(512)] {
            let c = compare_with_fact(&cfg, &hw, &spls, &prof);
            assert!(
                c.speedup > 1.15,
                "{}: ESACT/FACT {}",
                cfg.name,
                c.speedup
            );
        }
    }

    #[test]
    fn fact_still_beats_dense() {
        // FACT's intra-row sparsity is real — it must land between
        // dense and full ESACT
        let (hw, spls, prof) = setup();
        let cfg = config::bert_base(128);
        let dense = simulate_model(&cfg, &hw, &spls, &prof, Features::DENSE);
        let fact = simulate_fact(&cfg, &hw, &spls, &prof);
        let esact = simulate_model(&cfg, &hw, &spls, &prof, Features::FULL);
        assert!(fact.cycles < dense.cycles);
        assert!(esact.cycles < fact.cycles);
    }

    #[test]
    fn ffn_gap_dominates_on_ffn_heavy_models() {
        // FFN is >60% of BERT compute (Fig 1): FACT's missing FFN
        // sparsity should account for the largest share of the gap
        let (hw, spls, prof) = setup();
        let cfg = config::bert_base(128);
        let with_ffn = compare_with_fact(&cfg, &hw, &spls, &prof).speedup;
        let no_ffn = compare_with_fact(
            &cfg,
            &hw,
            &spls,
            &SparsityProfile { ffn: 0.0, ..prof },
        )
        .speedup;
        assert!(with_ffn > no_ffn, "FFN sparsity must widen the gap");
    }
}
