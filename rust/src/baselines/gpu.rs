//! Analytic Nvidia V100 model (DESIGN.md §Substitutions): roofline over
//! 125 TOPS int8-equivalent peak and 900 GB/s HBM, with a
//! shape-dependent utilization curve calibrated so the dense ESACT ASIC
//! deployment (125 units, same peak, same bandwidth) lands at the
//! paper's 2.42× speedup (Fig 20).

use crate::config::ModelConfig;
use crate::spls::plan::dense_model_flops;

/// V100 deployment parameters.
#[derive(Clone, Copy, Debug)]
pub struct V100 {
    /// Peak throughput, ops/s (125 TOPS — the paper's normalization).
    pub peak_ops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
}

impl Default for V100 {
    fn default() -> Self {
        Self { peak_ops: 125e12, hbm_bw: 900e9 }
    }
}

impl V100 {
    /// Effective utilization for a transformer workload.
    ///
    /// GPUs lose throughput to kernel-launch latency, tensor-core tile
    /// quantization on short sequences, softmax/LN memory-bound phases,
    /// and batch under-filling. The paper's dense ASIC achieves 2.42×
    /// at equal peak → average GPU utilization ≈ 1/2.42 ≈ 41%. The
    /// curve gives small-batch short-sequence workloads less and large
    /// dense GEMMs more, centred on that anchor.
    pub fn utilization(&self, cfg: &ModelConfig, batch: usize) -> f64 {
        // GEMM-shape factor: relative occupancy of 128×128 tensor-core
        // tiles at (L·batch) × D
        let rows = (cfg.seq_len * batch) as f64;
        let tile = |dim: f64, t: f64| (dim / t).min((dim / t).ceil()).max(1e-9) / (dim / t).ceil();
        let occ = tile(rows, 128.0) * tile(cfg.d_model as f64, 128.0);
        // memory-bound phases (softmax, LN, residuals) cap utilization:
        // they are ~10% of ops but run at bandwidth speed. Base
        // calibrated so the dense ASIC lands at the paper's 2.42×
        // (dense-ASIC utilization ≈ 0.87 → GPU ≈ 0.87 / 2.42 ≈ 0.36).
        let base = 0.38;
        (base * occ.powf(0.5)).clamp(0.12, 0.50)
    }

    /// End-to-end time for one batch on the V100.
    pub fn batch_time(&self, cfg: &ModelConfig, batch: usize) -> f64 {
        let flops = dense_model_flops(cfg).total() * batch as f64;
        let ops = 2.0 * flops;
        let compute = ops / (self.peak_ops * self.utilization(cfg, batch));
        // weight + activation traffic (int8), streamed once per batch
        let bytes = (cfg.n_layers * (4 * cfg.d_model * cfg.d_model + 2 * cfg.d_model * cfg.d_ffn))
            as f64
            + (batch * cfg.seq_len * cfg.d_model * 2) as f64;
        let mem = bytes / self.hbm_bw;
        compute.max(mem)
    }
}

/// Convenience: V100 time per sequence.
pub fn v100_model_time(cfg: &ModelConfig, batch: usize) -> f64 {
    V100::default().batch_time(cfg, batch) / batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    #[test]
    fn utilization_anchor() {
        let v = V100::default();
        // big dense workloads sit near the 41% anchor (1/2.42)
        let u = v.utilization(&config::bert_large(512), 32);
        assert!((0.35..0.55).contains(&u), "util {u}");
    }

    #[test]
    fn small_workloads_less_efficient() {
        let v = V100::default();
        let small = v.utilization(&config::vit_b32(), 1);
        let big = v.utilization(&config::bert_large(512), 32);
        assert!(small <= big);
    }

    #[test]
    fn batch_time_scales_sublinearly_per_seq() {
        let per1 = v100_model_time(&config::bert_base(128), 1);
        let per32 = v100_model_time(&config::bert_base(128), 32);
        assert!(per32 <= per1, "batched per-seq {per32} vs {per1}");
    }

    #[test]
    fn compute_bound_for_bert() {
        let v = V100::default();
        let cfg = config::bert_base(128);
        let t = v.batch_time(&cfg, 32);
        let flops = crate::spls::plan::dense_model_flops(&cfg).total() * 32.0;
        let ideal = 2.0 * flops / v.peak_ops;
        assert!(t > ideal, "must be below peak");
        assert!(t < ideal * 8.0, "not absurdly below peak");
    }
}
