//! SOTA attention-accelerator baselines (Table IV): SpAtten and Sanger
//! published figures, technology-normalized to 28 nm, next to ESACT's
//! simulated attention-level throughput.

use crate::config::{HardwareConfig, SplsConfig};
use crate::energy::scaling::{scale_design, TechNode};
use crate::sim::pe::gemm_irregular;


/// One accelerator row of Table IV.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccelSpec {
    pub name: &'static str,
    pub accuracy_loss_pct: f64,
    pub tech_nm: f64,
    pub freq_hz: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    /// Attention throughput in dense-equivalent GOPS.
    pub attn_gops: f64,
}

impl AccelSpec {
    pub fn energy_eff(&self) -> f64 {
        self.attn_gops / self.power_w
    }

    pub fn area_eff(&self) -> f64 {
        self.attn_gops / self.area_mm2
    }

    /// Normalize to 28 nm (the Table IV methodology, after [45]).
    pub fn normalized_28nm(&self) -> AccelSpec {
        let (gops, power, area) = scale_design(
            self.attn_gops,
            self.power_w,
            self.area_mm2,
            TechNode(self.tech_nm),
            TechNode::NM28,
        );
        AccelSpec {
            tech_nm: 28.0,
            freq_hz: self.freq_hz * self.tech_nm / 28.0,
            area_mm2: area,
            power_w: power,
            attn_gops: gops,
            ..*self
        }
    }
}

/// SpAtten's published figures (40 nm, 1 GHz).
pub const SPATTEN: AccelSpec = AccelSpec {
    name: "SpAtten",
    accuracy_loss_pct: 0.7,
    tech_nm: 40.0,
    freq_hz: 1e9,
    area_mm2: 1.55,
    power_w: 0.325,
    attn_gops: 360.0,
};

/// Sanger's published figures (55 nm, 500 MHz).
pub const SANGER: AccelSpec = AccelSpec {
    name: "Sanger",
    accuracy_loss_pct: 0.1,
    tech_nm: 55.0,
    freq_hz: 500e6,
    area_mm2: 16.9,
    power_w: 2.76,
    attn_gops: 2116.0,
};

/// ESACT's attention-level throughput from the cycle model: dense
/// attention ops retired per second under SPLS sparsity (inter-row 60%
/// similar + intra-row top-k ≈ 0.15 on critical rows — the paper's
/// Verilator calibration workload).
///
/// Attention-level accounting (what SpAtten/Sanger report): the QKᵀ and
/// A·V products plus the *exposed* slice of the attention-prediction
/// pipeline and the row-pipelined softmax. QKV-generation prediction is
/// excluded (SpAtten/Sanger don't do it — it belongs to the end-to-end
/// numbers of Figs 20/21). With the progressive scheme the per-window
/// attention prediction overlaps generation of the previous window;
/// ~10% remains exposed (first window + drain).
pub fn esact_attention_entry(hw: &HardwareConfig, _spls: &SplsConfig) -> AccelSpec {
    let l = 128usize;
    let dh = 64usize;
    let h = 12usize;
    // per-row kept counts: 40% critical rows with ceil(0.15·L) kept
    let kept = (0.15 * l as f64).ceil() as usize;
    let n_crit = (0.4 * l as f64).round() as usize;
    let keep: Vec<usize> = (0..l).map(|r| if r < n_crit { kept } else { 0 }).collect();
    let qk = gemm_irregular(hw, &keep, dh, true);
    let av = gemm_irregular(hw, &keep, dh, true);
    // attention prediction (L×Dh × Dh×L through the bit-level unit);
    // ≈10% exposed past the progressive overlap
    let a_pred = crate::sim::prediction_unit::predict_gemm(hw, l, dh, l);
    let pred_exposed = a_pred.cycles / 10;
    // softmax over kept entries, row-pipelined (1 row/cycle + fill)
    let softmax = n_crit as u64 + 10;
    let cycles_per_head = qk.cycles + av.cycles + pred_exposed + softmax;
    let cycles = cycles_per_head as f64 * h as f64;
    let dense_ops = 2.0 * (2 * l * l * dh * h) as f64;
    let secs = cycles / hw.freq_hz;
    let gops = dense_ops / secs / 1e9;
    AccelSpec {
        name: "ESACT",
        accuracy_loss_pct: 0.2,
        tech_nm: 28.0,
        freq_hz: hw.freq_hz,
        area_mm2: 5.09,
        power_w: 0.792,
        attn_gops: gops,
    }
}

/// The three rows of Table IV, SpAtten/Sanger normalized to 28 nm.
pub fn attention_accelerators(hw: &HardwareConfig, spls: &SplsConfig) -> Vec<AccelSpec> {
    vec![
        SPATTEN.normalized_28nm(),
        SANGER.normalized_28nm(),
        esact_attention_entry(hw, spls),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn defaults() -> (HardwareConfig, SplsConfig) {
        (HardwareConfig::default(), SplsConfig::default())
    }

    #[test]
    fn published_efficiencies_normalize_to_paper_values() {
        // Table IV: SpAtten 2261 GOPS/W, Sanger 2958 GOPS/W after scaling
        let sp = SPATTEN.normalized_28nm();
        let sa = SANGER.normalized_28nm();
        assert!((sp.energy_eff() - 2261.0).abs() / 2261.0 < 0.35, "{}", sp.energy_eff());
        assert!((sa.energy_eff() - 2958.0).abs() / 2958.0 < 0.35, "{}", sa.energy_eff());
    }

    #[test]
    fn esact_attention_throughput_magnitude() {
        // Table IV: ESACT 5288 GOPS attention throughput
        let (hw, spls) = defaults();
        let e = esact_attention_entry(&hw, &spls);
        assert!(
            (e.attn_gops - 5288.0).abs() / 5288.0 < 0.4,
            "attention GOPS {}",
            e.attn_gops
        );
    }

    #[test]
    fn esact_beats_both_in_energy_efficiency() {
        // Table IV headline: 2.95× over SpAtten, 2.26× over Sanger
        let (hw, spls) = defaults();
        let v = attention_accelerators(&hw, &spls);
        let eff = |n: &str| v.iter().find(|a| a.name == n).unwrap().energy_eff();
        let r_spatten = eff("ESACT") / eff("SpAtten");
        let r_sanger = eff("ESACT") / eff("Sanger");
        assert!((1.8..4.5).contains(&r_spatten), "vs SpAtten {r_spatten}");
        assert!((1.5..3.5).contains(&r_sanger), "vs Sanger {r_sanger}");
    }

    #[test]
    fn esact_area_efficiency_near_sanger() {
        let (hw, spls) = defaults();
        let v = attention_accelerators(&hw, &spls);
        let ae = |n: &str| v.iter().find(|a| a.name == n).unwrap().area_eff();
        let ratio = ae("ESACT") / ae("Sanger");
        assert!((0.6..1.6).contains(&ratio), "area-eff ratio {ratio}");
        assert!(ae("ESACT") > ae("SpAtten"));
    }
}
