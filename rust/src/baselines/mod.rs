//! Comparison baselines: the dense ESACT ASIC, an analytic V100 model
//! (Fig 20), and the SOTA attention accelerators SpAtten / Sanger
//! normalized to 28 nm (Table IV), plus FACT's prediction unit
//! (Table III, via `energy::area`).

pub mod accel;
pub mod fact;
pub mod gpu;

pub use accel::{attention_accelerators, esact_attention_entry, AccelSpec};
pub use fact::{compare_with_fact, simulate_fact, FactComparison};
pub use gpu::{v100_model_time, V100};
