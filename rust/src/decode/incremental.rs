//! Step-wise SPLS for autoregressive decode: predict the **new query
//! row's** sparsity against the cached prefix, in O(prefix) per step
//! instead of re-planning the whole O(prefix²) PAM.
//!
//! Per head the predictor keeps an int8 cache of *predicted* K rows
//! (HLog bit-level prediction, requantized per row) alongside the f32
//! KV cache. Each step:
//!
//! 1. predict the new token's K row through the bit-level unit
//!    (`spls::predict::predict_matmul`) and append it — fixed O(D·Dh);
//! 2. predict the new Q row the same way, then the attention row
//!    `q₈ · K₈ᵀ` over the cached slots — O(prefix·Dh), the only part
//!    that scales with the prefix;
//! 3. compare the predicted row to the **previous step's row** over the
//!    shared prefix (normalized L1, exactly the paper's local-similarity
//!    metric applied temporally): a similar step reuses the previous
//!    keep-mask — and the decode engine reuses the previous attention
//!    *output* (recovery by replication, the paper's Q-row skipping
//!    along the time axis);
//! 4. otherwise rank the row top-k (diagonal always kept) to build the
//!    step's keep-mask.
//!
//! The full per-step decision is packaged as a [`StepPlan`] so the
//! serving tier can memoize it in `spls::plan_cache` (decode buckets):
//! replaying a prefix serves every step's planning from cache.

use crate::config::SplsConfig;
use crate::quant::requantize_sym8;
use crate::spls::maskgen::{MaskGen, SplsTopK};
use crate::spls::predict::predict_matmul;
use crate::spls::similarity::l1_norm_dist;
use crate::util::mat::MatI;

/// One head's decision for one decode step. Self-contained: applying it
/// to a fresh predictor reproduces the exact post-step state, which is
/// what makes cached step plans bit-equivalent to computed ones.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HeadStepPlan {
    /// Predicted attention row (int32 PAM row) over the cached slots.
    pub row: Vec<i32>,
    /// Keep-mask over the cached slots (same length as `row`).
    pub keep: Vec<bool>,
    /// The requantized predicted K row appended this step (Dh values).
    pub k8: Vec<i32>,
    /// Whether this step reused the previous step's mask (and the
    /// engine reuses the previous attention output).
    pub similar: bool,
}

/// All heads of one layer for one step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerStepPlan {
    pub heads: Vec<HeadStepPlan>,
}

/// One decode step's full plan (all layers), the plan-cache payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    pub layers: Vec<LayerStepPlan>,
}

/// Per-head incremental prediction state.
#[derive(Clone, Debug)]
pub struct HeadPredictor {
    dh: usize,
    /// Row-major `len × dh` int8 predicted-K cache (evicted in lockstep
    /// with the f32 KV cache).
    k8: Vec<i32>,
    prev_row: Vec<i32>,
    prev_keep: Vec<bool>,
    has_prev: bool,
}

impl HeadPredictor {
    pub fn new(dh: usize) -> Self {
        assert!(dh >= 1);
        Self { dh, k8: Vec::new(), prev_row: Vec::new(), prev_keep: Vec::new(), has_prev: false }
    }

    /// Cached predicted-K slots.
    pub fn len(&self) -> usize {
        self.k8.len() / self.dh
    }

    pub fn is_empty(&self) -> bool {
        self.k8.is_empty()
    }

    /// Run one step of incremental prediction. `hq` is the current
    /// token's LN'd activation row quantized to int8 (1×D); `wq8`/`wk8`
    /// are this head's int8 prediction weights (D×Dh). Non-similar
    /// steps build their keep-mask with the default SPLS top-k rule;
    /// [`HeadPredictor::step_with`] takes any [`MaskGen`].
    pub fn step(&mut self, hq: &MatI, wq8: &MatI, wk8: &MatI, spls: &SplsConfig) -> HeadStepPlan {
        self.step_with(hq, wq8, wk8, spls, &SplsTopK)
    }

    /// [`HeadPredictor::step`] with a pluggable keep-mask generator:
    /// the prediction pipeline (K/Q rows, attention row, temporal
    /// similarity) is identical; only the non-similar step's keep-mask
    /// construction is delegated to `gen`.
    pub fn step_with(
        &mut self,
        hq: &MatI,
        wq8: &MatI,
        wk8: &MatI,
        spls: &SplsConfig,
        gen: &dyn MaskGen,
    ) -> HeadStepPlan {
        assert_eq!(hq.rows, 1, "decode predicts one row per step");
        // predicted K row for the new token → int8 cache
        let kp = predict_matmul(hq, wk8);
        let (k8, _) = requantize_sym8(&kp.data);
        self.k8.extend_from_slice(&k8);
        let n = self.len();
        // predicted Q row, then the attention row over the cached slots
        let qp = predict_matmul(hq, wq8);
        let (q8, _) = requantize_sym8(&qp.data);
        let q8 = MatI::from_vec(1, self.dh, q8);
        let kmat = MatI::from_vec(n, self.dh, self.k8.clone());
        let row = predict_matmul(&q8, &kmat.transpose()).data;
        // temporal local similarity: shared prefix with the previous row
        let similar = self.has_prev
            && n >= 2
            && self.prev_row.len() == n - 1
            && l1_norm_dist(&row[..n - 1], &self.prev_row) <= spls.sim_threshold as f64;
        let keep = if similar {
            let mut k = self.prev_keep.clone();
            k.push(true); // the new diagonal slot is always visible
            k
        } else {
            gen.keep(&row, spls)
        };
        let plan = HeadStepPlan { row: row.clone(), keep: keep.clone(), k8, similar };
        self.prev_row = row;
        self.prev_keep = keep;
        self.has_prev = true;
        plan
    }

    /// Replay a memoized step plan: restores the exact state `step`
    /// would have produced, without running the prediction pipeline.
    pub fn apply(&mut self, plan: &HeadStepPlan) {
        assert_eq!(plan.k8.len(), self.dh, "plan K row width mismatch");
        self.k8.extend_from_slice(&plan.k8);
        assert_eq!(plan.row.len(), self.len(), "plan row must cover the cache");
        self.prev_row = plan.row.clone();
        self.prev_keep = plan.keep.clone();
        self.has_prev = true;
    }

    /// Drop one cached slot (KV-cache eviction rides along here so the
    /// predicted-K cache and the previous row stay slot-aligned).
    pub fn remove_slot(&mut self, slot: usize) {
        let d = self.dh;
        assert!(slot < self.len());
        self.k8.drain(slot * d..(slot + 1) * d);
        if slot < self.prev_row.len() {
            self.prev_row.remove(slot);
        }
        if slot < self.prev_keep.len() {
            self.prev_keep.remove(slot);
        }
    }
}

/// Row top-k keep-mask with the diagonal (last slot = the new token's
/// own position) always kept. Delegates to the single shared selection
/// rule in `spls::causal` so the decode keep-mask and the prefill
/// causal mask can never drift apart.
pub fn topk_keep_with_diagonal(row: &[i32], k_ratio: f32) -> Vec<bool> {
    crate::spls::causal::topk_row_keep_with_diagonal(row, k_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256pp;

    fn rand_mat(rng: &mut Xoshiro256pp, r: usize, c: usize) -> MatI {
        MatI::from_fn(r, c, |_, _| rng.int_in(-128, 127) as i32)
    }

    #[test]
    fn topk_exact_count_and_diagonal() {
        prop::check(80, |rng| {
            let n = 1 + rng.below(40) as usize;
            let k = 0.02 + rng.f64() as f32 * 0.98;
            let row: Vec<i32> = (0..n).map(|_| rng.int_in(-500, 500) as i32).collect();
            let keep = topk_keep_with_diagonal(&row, k);
            let want = (((k * n as f32).ceil()) as usize).clamp(1, n);
            assert_eq!(keep.iter().filter(|&&b| b).count(), want);
            assert!(keep[n - 1], "diagonal slot pruned");
        });
    }

    #[test]
    fn topk_prefers_large_magnitudes() {
        let keep = topk_keep_with_diagonal(&[50, -3, 40, 7, 1], 0.4);
        // count = 2: top entries 50 and 40, then 1 (diagonal) replaces 40
        assert_eq!(keep, vec![true, false, false, false, true]);
    }

    #[test]
    fn step_grows_cache_and_first_step_is_never_similar() {
        let mut rng = Xoshiro256pp::new(3);
        let (d, dh) = (16, 4);
        let wq = rand_mat(&mut rng, d, dh);
        let wk = rand_mat(&mut rng, d, dh);
        let mut p = HeadPredictor::new(dh);
        let spls = SplsConfig { sim_threshold: 2.0, ..SplsConfig::default() };
        let h0 = rand_mat(&mut rng, 1, d);
        let s0 = p.step(&h0, &wq, &wk, &spls);
        assert_eq!(p.len(), 1);
        assert_eq!(s0.row.len(), 1);
        assert!(!s0.similar, "no previous row to be similar to");
        assert_eq!(s0.keep, vec![true]);
        // an identical activation row one step later is similar at s=2
        let s1 = p.step(&h0, &wq, &wk, &spls);
        assert_eq!(p.len(), 2);
        assert!(s1.similar, "identical prefix rows must collapse");
        assert!(s1.keep[1], "diagonal appended to the reused mask");
    }

    #[test]
    fn negative_threshold_disables_similarity() {
        let mut rng = Xoshiro256pp::new(5);
        let (d, dh) = (16, 4);
        let wq = rand_mat(&mut rng, d, dh);
        let wk = rand_mat(&mut rng, d, dh);
        let mut p = HeadPredictor::new(dh);
        let spls = SplsConfig { sim_threshold: -1.0, ..SplsConfig::default() };
        let h = rand_mat(&mut rng, 1, d);
        for _ in 0..4 {
            assert!(!p.step(&h, &wq, &wk, &spls).similar);
        }
    }

    #[test]
    fn apply_replays_to_identical_state() {
        // compute a few steps on predictor A, record the plans, replay
        // them on predictor B: every later computed step must agree
        let mut rng = Xoshiro256pp::new(7);
        let (d, dh) = (16, 4);
        let wq = rand_mat(&mut rng, d, dh);
        let wk = rand_mat(&mut rng, d, dh);
        let spls = SplsConfig::default();
        let mut a = HeadPredictor::new(dh);
        let mut b = HeadPredictor::new(dh);
        let rows: Vec<MatI> = (0..5).map(|_| rand_mat(&mut rng, 1, d)).collect();
        for h in &rows[..3] {
            let plan = a.step(h, &wq, &wk, &spls);
            b.apply(&plan);
        }
        for h in &rows[3..] {
            assert_eq!(a.step(h, &wq, &wk, &spls), b.step(h, &wq, &wk, &spls));
        }
    }

    #[test]
    fn step_with_three_component_builds_structured_masks() {
        use crate::spls::maskgen::ThreeComponent;
        let mut rng = Xoshiro256pp::new(11);
        let (d, dh) = (16, 4);
        let wq = rand_mat(&mut rng, d, dh);
        let wk = rand_mat(&mut rng, d, dh);
        // similarity disabled: every step rebuilds its mask through the
        // generator, so the structure is visible on every plan
        let spls = SplsConfig { sim_threshold: -1.0, ..SplsConfig::default() };
        let gen = ThreeComponent { window: 2, top_k: 0.0, global: 1 };
        let mut p = HeadPredictor::new(dh);
        let mut last = None;
        for _ in 0..6 {
            let h = rand_mat(&mut rng, 1, d);
            last = Some(p.step_with(&h, &wq, &wk, &spls, &gen));
        }
        let plan = last.unwrap();
        assert!(!plan.similar);
        // n = 6: global sink slot 0 + local window slots 4, 5
        assert_eq!(plan.keep, vec![true, false, false, false, true, true]);
    }

    #[test]
    fn remove_slot_keeps_similarity_alignment() {
        let mut rng = Xoshiro256pp::new(9);
        let (d, dh) = (16, 4);
        let wq = rand_mat(&mut rng, d, dh);
        let wk = rand_mat(&mut rng, d, dh);
        let spls = SplsConfig { sim_threshold: 2.0, ..SplsConfig::default() };
        let mut p = HeadPredictor::new(dh);
        let h = rand_mat(&mut rng, 1, d);
        for _ in 0..4 {
            p.step(&h, &wq, &wk, &spls);
        }
        p.remove_slot(1);
        assert_eq!(p.len(), 3);
        // next step: prev_row has len()-… matching n-1 after the append,
        // and the identical activation stays similar
        let s = p.step(&h, &wq, &wk, &spls);
        assert_eq!(s.row.len(), 4);
        assert!(s.similar, "alignment survived the eviction");
    }
}
