//! The decode-step forward: single-row attention against the pruned
//! per-head KV cache, reusing the exact head math of
//! `model::transformer` (same accumulation order per output element, in
//! zero-copy slice-kernel form), so **unbounded-budget dense decode is
//! bit-identical to re-running `forward_causal_hidden` on the growing
//! sequence** — asserted by the tests here and by
//! `tests/integration_decode.rs`.
//!
//! The engine is a view over the shared [`PackedModel`] — per-head
//! weight slices and int8 predictor operands are packed once per weight
//! set (and shared with the serving executables and planner), and every
//! session carries its own `util::scratch::Scratch` arena, so
//! steady-state decode steps run without per-step matrix allocation.
//!
//! Two modes:
//!
//! * [`DecodeMode::Dense`] — full attention over every cached slot. With
//!   a finite budget the cache degrades to a sliding window (zero
//!   scores → oldest-first eviction).
//! * [`DecodeMode::Spls`] — the incremental SPLS predictor
//!   (`decode::incremental`) gates each step: similar steps reuse the
//!   previous step's attention output per head (recovery by
//!   replication), non-similar steps gather the keep-mask's kept slots
//!   and attend over exactly those — the compacted SDDMM → sparse
//!   softmax → axpy chain of `model::sparse_kernels`, so pruned slots
//!   are skipped, not masked; predicted row magnitudes accumulate into the KV cache's
//!   eviction scores; and when enough heads vote "similar" the FFN row
//!   is reused too (the MFI voting rule applied temporally). Step plans
//!   are memoized in the shared `spls::plan_cache` under decode
//!   buckets, so replaying a prefix skips planning entirely.

use std::sync::Arc;

use crate::config::SplsConfig;
use crate::decode::incremental::{HeadPredictor, HeadStepPlan, LayerStepPlan, StepPlan};
use crate::decode::kv_cache::{HeadKv, KvSlots};
use crate::model::sparse_kernels::softmax_row;
use crate::model::tensor::{
    add_inplace, gelu_inplace, layernorm_into, linear_into, masked_softmax_row,
};
use crate::model::{lm_logits_row, PackedModel, TinyWeights};
use crate::quant::quantize_sym8;
use crate::spls::maskgen::{MaskGen, SplsTopK};
use crate::spls::plan_cache::SharedPlanCache;
use crate::util::mat::MatI;
use crate::util::scratch::Scratch;

/// The default keep-mask generator (static so the hot loop can borrow
/// it alongside a custom `Arc<dyn MaskGen>`).
static DEFAULT_MASK_GEN: SplsTopK = SplsTopK;

/// Attention execution mode of a decode session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    /// Full attention over the cached prefix (the exactness baseline).
    Dense,
    /// Incremental-SPLS gated attention + sparsity-aware eviction.
    Spls,
}

/// Per-session decode configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeConfig {
    pub mode: DecodeMode,
    /// Per-head KV budget in cached tokens; `usize::MAX` = unbounded.
    pub kv_budget: usize,
    /// Newest slots never evicted (clamped to ≥ 1: the diagonal is
    /// always retained, and to < budget so eviction can make progress).
    pub recent: usize,
    /// SPLS operating point for the incremental predictor.
    pub spls: SplsConfig,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        Self {
            mode: DecodeMode::Dense,
            kv_budget: usize::MAX,
            recent: 8,
            spls: SplsConfig::default(),
        }
    }
}

/// Decode-side counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// Tokens pushed (prompt + generated).
    pub steps: usize,
    /// Head-steps that reused the previous attention output.
    pub sim_heads: usize,
    /// Layer-steps that reused the previous FFN row.
    pub ffn_skips: usize,
    /// KV slots evicted across all layers/heads.
    pub evictions: usize,
    /// Step plans served from the shared plan cache.
    pub plan_hits: usize,
    /// Step plans computed (and, when a cache is attached, inserted).
    pub plan_misses: usize,
}

/// Immutable per-weights state shared by every decode session — a view
/// over the [`PackedModel`]: per-head f32 weight slices (so a step
/// projects exactly one row per head, with accumulation bit-identical
/// to the full-matrix prefill projections — output columns of the
/// matmul are independent) and the per-head int8 prediction weights,
/// quantized exactly like `model::plan_model`'s operands. The serving
/// tier packs the model once and shares it with the executables, the
/// planner and this engine alike.
pub struct DecodeEngine {
    packed: Arc<PackedModel>,
}

impl DecodeEngine {
    /// Pack the weights and build the engine.
    pub fn new(weights: Arc<TinyWeights>) -> Self {
        Self::from_packed(Arc::new(PackedModel::new(weights)))
    }

    /// Wrap an already-packed model (no repacking — the serving tier's
    /// replicas all point at one `Arc<PackedModel>`).
    pub fn from_packed(packed: Arc<PackedModel>) -> Self {
        Self { packed }
    }

    pub fn weights(&self) -> &Arc<TinyWeights> {
        self.packed.weights()
    }

    /// The shared packed model this engine runs on.
    pub fn packed(&self) -> &Arc<PackedModel> {
        &self.packed
    }
}

pub(crate) struct HeadState<K> {
    pub(crate) kv: K,
    pub(crate) pred: HeadPredictor,
    pub(crate) prev_out: Option<Vec<f32>>,
}

pub(crate) struct LayerState<K> {
    pub(crate) heads: Vec<HeadState<K>>,
    pub(crate) prev_ffn: Option<Vec<f32>>,
}

/// One decode session's mutable state: the residual-stream position,
/// per-layer/per-head caches, and optional plan-cache handle. Generic
/// over the K/V storage ([`KvSlots`]): [`DecodeState`] is the
/// contiguous [`HeadKv`] instantiation, and
/// [`PagedDecodeState`](crate::decode::paged::PagedDecodeState) wraps
/// the block-table one — both run this one `push`, which is what makes
/// their outputs bit-identical for the same slot history.
pub struct DecodeStateOf<K: KvSlots> {
    eng: Arc<DecodeEngine>,
    cfg: DecodeConfig,
    recent: usize,
    tokens: Vec<i32>,
    layers: Vec<LayerState<K>>,
    cache: Option<SharedPlanCache>,
    /// Custom keep-mask generator (None = the SPLS top-k rule). Custom
    /// masks bypass the step-plan cache: plans are keyed on the SPLS
    /// operating point only.
    mask: Option<Arc<dyn MaskGen>>,
    stats: DecodeStats,
    /// Per-session scratch arena: steady-state steps reuse these
    /// buffers instead of allocating per-step matrices.
    scratch: Scratch,
}

/// The contiguous-cache decode session (the paper's serving baseline).
pub type DecodeState = DecodeStateOf<HeadKv>;

impl DecodeState {
    pub fn new(eng: Arc<DecodeEngine>, cfg: DecodeConfig) -> Self {
        let dh = eng.weights().cfg.d_head();
        Self::with_kv(eng, cfg, move || HeadKv::new(dh))
    }
}

impl<K: KvSlots> DecodeStateOf<K> {
    /// Build a session over caller-constructed head caches (one factory
    /// call per layer × head, in layer-major order).
    pub(crate) fn with_kv(
        eng: Arc<DecodeEngine>,
        cfg: DecodeConfig,
        mut kv: impl FnMut() -> K,
    ) -> Self {
        let mcfg = eng.weights().cfg;
        let dh = mcfg.d_head();
        if cfg.kv_budget != usize::MAX {
            assert!(cfg.kv_budget >= 2, "a finite KV budget needs at least 2 slots");
        }
        let recent = if cfg.kv_budget == usize::MAX {
            cfg.recent.max(1)
        } else {
            cfg.recent.max(1).min(cfg.kv_budget - 1)
        };
        let layers = (0..mcfg.n_layers)
            .map(|_| LayerState {
                heads: (0..mcfg.n_heads)
                    .map(|_| HeadState {
                        kv: kv(),
                        pred: HeadPredictor::new(dh),
                        prev_out: None,
                    })
                    .collect(),
                prev_ffn: None,
            })
            .collect();
        Self {
            eng,
            cfg,
            recent,
            tokens: Vec::new(),
            layers,
            cache: None,
            mask: None,
            stats: DecodeStats::default(),
            scratch: Scratch::new(),
        }
    }

    /// Attach a shared plan cache: step plans are looked up / inserted
    /// under the token prefix (decode buckets), so identical prefixes
    /// across sessions replay planning from cache.
    pub fn with_plan_cache(mut self, cache: SharedPlanCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Swap in a custom keep-mask generator (e.g.
    /// [`ThreeComponent`](crate::spls::maskgen::ThreeComponent)). The
    /// session stops consulting the shared step-plan cache — memoized
    /// plans encode the default SPLS rule.
    pub fn with_mask_gen(mut self, gen: Arc<dyn MaskGen>) -> Self {
        self.mask = Some(gen);
        self
    }

    /// Tokens pushed so far (prompt + generated).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats
    }

    /// Cached KV slots of one head (≤ the budget between steps).
    pub fn kv_len(&self, layer: usize, head: usize) -> usize {
        self.layers[layer].heads[head].kv.len()
    }

    pub(crate) fn config(&self) -> &DecodeConfig {
        &self.cfg
    }

    pub(crate) fn has_mask_gen(&self) -> bool {
        self.mask.is_some()
    }

    pub(crate) fn layers(&self) -> &[LayerState<K>] {
        &self.layers
    }

    pub(crate) fn layers_mut(&mut self) -> &mut [LayerState<K>] {
        &mut self.layers
    }

    /// Overwrite the token history (prefix attach restores a snapshot).
    pub(crate) fn set_tokens(&mut self, tokens: Vec<i32>) {
        self.tokens = tokens;
    }

    /// Push one token through the model; returns the next-token logits.
    pub fn push(&mut self, token: i32) -> Vec<f32> {
        let eng = Arc::clone(&self.eng);
        let w = Arc::clone(eng.weights());
        let mcfg = w.cfg;
        let (d, dh) = (mcfg.d_model, mcfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let spls_mode = self.cfg.mode == DecodeMode::Spls;
        let p = self.tokens.len();
        self.tokens.push(token);
        // memoized step plan for this exact prefix (Spls mode only;
        // custom mask generators bypass the cache — plans are keyed on
        // the SPLS operating point, not the generator)
        let memo = self.mask.is_none();
        let cached: Option<StepPlan> = match (&self.cache, spls_mode && memo) {
            (Some(c), true) => {
                c.get_step(&self.tokens, &self.cfg.spls, self.cfg.kv_budget, self.recent)
            }
            _ => None,
        };
        let plan_fresh = spls_mode && memo && self.cache.is_some() && cached.is_none();
        let mut fresh: Option<StepPlan> = if plan_fresh {
            Some(StepPlan { layers: Vec::with_capacity(mcfg.n_layers) })
        } else {
            None
        };
        if cached.is_some() {
            self.stats.plan_hits += 1;
        }
        // embed_row's values, written into the arena: embed[tok] + pos,
        // with positions past the trained table clamped to the last row
        let pos = p.min(mcfg.seq_len - 1);
        self.scratch.x.reshape(1, d);
        let erow = w.embed.row(token as usize);
        for ((o, &e), &pv) in self.scratch.x.data.iter_mut().zip(erow).zip(w.pos.row(pos)) {
            *o = e + pv;
        }
        for li in 0..mcfg.n_layers {
            let lw = &w.layers[li];
            let el = &eng.packed().packed_layers()[li];
            self.scratch.h.reshape(1, d);
            layernorm_into(&self.scratch.x, &lw.ln1_g, &lw.ln1_b, &mut self.scratch.h);
            let hq = if spls_mode && cached.is_none() {
                let (q, _) = quantize_sym8(&self.scratch.h.data);
                Some(MatI::from_vec(1, d, q))
            } else {
                None
            };
            self.scratch.att.reshape(1, d);
            let mut sim_heads = 0usize;
            let mut layer_plan =
                fresh.as_ref().map(|_| LayerStepPlan { heads: Vec::with_capacity(mcfg.n_heads) });
            for hi in 0..mcfg.n_heads {
                // K/V rows are always generated for the new token
                self.scratch.k.reshape(1, dh);
                linear_into(&self.scratch.h, &el.wk_h[hi], &el.bk_h[hi], &mut self.scratch.k);
                self.scratch.v.reshape(1, dh);
                linear_into(&self.scratch.h, &el.wv_h[hi], &el.bv_h[hi], &mut self.scratch.v);
                let hs = &mut self.layers[li].heads[hi];
                hs.kv.push(&self.scratch.k.data, &self.scratch.v.data, p);
                let n = hs.kv.len();
                let decision: Option<HeadStepPlan> = if spls_mode {
                    Some(match &cached {
                        Some(plan) => {
                            let d = &plan.layers[li].heads[hi];
                            hs.pred.apply(d);
                            d.clone()
                        }
                        None => {
                            let gen: &dyn MaskGen = match &self.mask {
                                Some(g) => g.as_ref(),
                                None => &DEFAULT_MASK_GEN,
                            };
                            let d = hs.pred.step_with(
                                hq.as_ref().expect("fresh Spls step quantizes h"),
                                &el.pred_wq[hi],
                                &el.pred_wk[hi],
                                &self.cfg.spls,
                                gen,
                            );
                            if let Some(lp) = layer_plan.as_mut() {
                                lp.heads.push(d.clone());
                            }
                            d
                        }
                    })
                } else {
                    None
                };
                if let Some(dn) = &decision {
                    hs.kv.accumulate(&dn.row);
                }
                let out_row: Vec<f32> = match &decision {
                    Some(dn) if dn.similar && hs.prev_out.is_some() => {
                        sim_heads += 1;
                        self.stats.sim_heads += 1;
                        hs.prev_out.clone().expect("checked above")
                    }
                    _ => {
                        // exact prefill head math on the cached slots:
                        // q · Kᵀ and the AV product run zero-copy
                        // against the cache's row-major storage
                        self.scratch.q.reshape(1, dh);
                        linear_into(
                            &self.scratch.h,
                            &el.wq_h[hi],
                            &el.bq_h[hi],
                            &mut self.scratch.q,
                        );
                        self.scratch.out.reset(1, dh);
                        match &decision {
                            Some(dn) => {
                                // compiled gated attention: gather the
                                // kept slots once, then run the
                                // compacted SDDMM → sparse softmax →
                                // axpy chain over exactly those slots
                                // (bit-identical to the masked form:
                                // kept entries see the same chains,
                                // pruned entries were zeroed before the
                                // zero-skipping AV product anyway)
                                self.scratch.idx.clear();
                                self.scratch.idx.extend(
                                    dn.keep
                                        .iter()
                                        .enumerate()
                                        .filter(|&(_, &k)| k)
                                        .map(|(i, _)| i),
                                );
                                assert!(
                                    !self.scratch.idx.is_empty(),
                                    "decode keep-mask kept no slots — the newest slot \
                                     (the diagonal) must always be kept"
                                );
                                let nk = self.scratch.idx.len();
                                self.scratch.s.reshape(1, nk);
                                hs.kv.dots_into(
                                    &self.scratch.q.data,
                                    &self.scratch.idx,
                                    scale,
                                    &mut self.scratch.s.data[..nk],
                                );
                                softmax_row(&mut self.scratch.s.data[..nk]);
                                hs.kv.attend_indexed_into(
                                    &self.scratch.s.data[..nk],
                                    &self.scratch.idx,
                                    &mut self.scratch.out.data,
                                );
                            }
                            None => {
                                self.scratch.s.reshape(1, n);
                                hs.kv.scores_into(&self.scratch.q.data, &mut self.scratch.s.data);
                                for v in &mut self.scratch.s.data {
                                    *v *= scale;
                                }
                                self.scratch.flags.clear();
                                self.scratch.flags.resize(n, true);
                                masked_softmax_row(&mut self.scratch.s.data, &self.scratch.flags);
                                hs.kv.attend_into(&self.scratch.s.data, &mut self.scratch.out.data);
                            }
                        }
                        self.scratch.out.data.clone()
                    }
                };
                hs.prev_out = Some(out_row.clone());
                self.scratch.att.row_mut(0)[hi * dh..(hi + 1) * dh].copy_from_slice(&out_row);
            }
            self.scratch.proj.reshape(1, d);
            linear_into(&self.scratch.att, &lw.wo, &lw.bo, &mut self.scratch.proj);
            add_inplace(&mut self.scratch.x, &self.scratch.proj);
            self.scratch.h2.reshape(1, d);
            layernorm_into(&self.scratch.x, &lw.ln2_g, &lw.ln2_b, &mut self.scratch.h2);
            let skip_ffn = spls_mode
                && sim_heads >= self.cfg.spls.ffn_threshold.max(1)
                && self.layers[li].prev_ffn.is_some();
            let ffn_row: Vec<f32> = if skip_ffn {
                self.stats.ffn_skips += 1;
                self.layers[li].prev_ffn.clone().expect("checked above")
            } else {
                self.scratch.ff.reshape(1, lw.w1.cols);
                linear_into(&self.scratch.h2, &lw.w1, &lw.b1, &mut self.scratch.ff);
                gelu_inplace(&mut self.scratch.ff);
                self.scratch.proj.reshape(1, d);
                linear_into(&self.scratch.ff, &lw.w2, &lw.b2, &mut self.scratch.proj);
                self.scratch.proj.data.clone()
            };
            self.layers[li].prev_ffn = Some(ffn_row.clone());
            for (o, &v) in self.scratch.x.data.iter_mut().zip(&ffn_row) {
                *o += v;
            }
            // eviction: drop lowest-cumulative-score slots over budget
            if self.cfg.kv_budget != usize::MAX {
                for hs in &mut self.layers[li].heads {
                    while hs.kv.len() > self.cfg.kv_budget {
                        match hs.kv.evict_lowest(self.recent) {
                            Some(slot) => {
                                // Dense mode never grows the predictor
                                // cache — only evict it in lockstep
                                // when it actually has slots (Spls)
                                if !hs.pred.is_empty() {
                                    hs.pred.remove_slot(slot);
                                }
                                self.stats.evictions += 1;
                            }
                            None => break,
                        }
                    }
                }
            }
            if let (Some(fp), Some(lp)) = (fresh.as_mut(), layer_plan) {
                fp.layers.push(lp);
            }
        }
        if let (Some(c), Some(plan)) = (&self.cache, fresh) {
            c.put_step(&self.tokens, &self.cfg.spls, self.cfg.kv_budget, self.recent, plan);
            self.stats.plan_misses += 1;
        } else if spls_mode && (self.cache.is_none() || !memo) {
            self.stats.plan_misses += 1;
        }
        self.stats.steps += 1;
        self.scratch.h.reshape(1, d);
        layernorm_into(&self.scratch.x, &w.lnf_g, &w.lnf_b, &mut self.scratch.h);
        lm_logits_row(&w, self.scratch.h.row(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::next_token_logits;
    use crate::util::rng::Xoshiro256pp;

    fn engine() -> Arc<DecodeEngine> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny_weights.bin");
        Arc::new(DecodeEngine::new(Arc::new(TinyWeights::load(&p).unwrap())))
    }

    fn toks(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn dense_decode_logits_bit_identical_to_causal_prefill() {
        let eng = engine();
        let w = Arc::clone(eng.weights());
        let seq = toks(1, 20);
        let mut st = DecodeState::new(eng, DecodeConfig::default());
        for t in 1..=seq.len() {
            let got = st.push(seq[t - 1]);
            let want = next_token_logits(&w, &seq[..t]);
            assert_eq!(got, want, "decode diverged from prefill at length {t}");
        }
        assert_eq!(st.stats().evictions, 0);
        assert_eq!(st.kv_len(0, 0), 20);
    }

    #[test]
    fn spls_full_keep_equals_dense_decode() {
        // top_k = 1 keeps every slot, sim_threshold < 0 disables reuse,
        // ffn_threshold = MAX disables FFN skipping: the Spls machinery
        // runs but gates nothing, so logits must equal the dense path
        let eng = engine();
        let seq = toks(2, 12);
        let spls = SplsConfig {
            top_k: 1.0,
            sim_threshold: -1.0,
            ffn_threshold: usize::MAX,
            window: 8,
        };
        let cfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
        let mut sparse = DecodeState::new(Arc::clone(&eng), cfg);
        let mut dense = DecodeState::new(eng, DecodeConfig::default());
        for &t in &seq {
            assert_eq!(sparse.push(t), dense.push(t));
        }
        assert_eq!(sparse.stats().sim_heads, 0);
        assert_eq!(sparse.stats().ffn_skips, 0);
    }

    #[test]
    fn budget_bounds_every_head_cache() {
        let eng = engine();
        let seq = toks(3, 32);
        let cfg = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 8,
            recent: 3,
            spls: SplsConfig::default(),
        };
        let mut st = DecodeState::new(eng, cfg);
        let mut last = Vec::new();
        for &t in &seq {
            last = st.push(t);
        }
        assert!(last.iter().all(|v| v.is_finite()));
        for li in 0..2 {
            for hi in 0..4 {
                assert!(st.kv_len(li, hi) <= 8, "head ({li},{hi}) over budget");
            }
        }
        assert!(st.stats().evictions > 0, "32 tokens into 8 slots must evict");
    }

    #[test]
    fn dense_mode_with_finite_budget_is_a_sliding_window() {
        // zero scores → oldest-first eviction; the predictor cache is
        // empty in Dense mode and must not be touched by eviction
        let eng = engine();
        let cfg = DecodeConfig { kv_budget: 8, recent: 2, ..DecodeConfig::default() };
        let mut st = DecodeState::new(eng, cfg);
        let seq = toks(6, 20);
        let mut last = Vec::new();
        for &t in &seq {
            last = st.push(t);
        }
        assert!(last.iter().all(|v| v.is_finite()));
        for li in 0..2 {
            for hi in 0..4 {
                assert!(st.kv_len(li, hi) <= 8);
            }
        }
        assert_eq!(st.stats().evictions, 12 * 8, "oldest slot dropped per head per step");
    }

    #[test]
    fn decode_runs_past_the_trained_position_table() {
        // positions ≥ seq_len clamp to the last pos row; with a finite
        // budget the session keeps streaming well past L = 64
        let eng = engine();
        let cfg = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 16,
            recent: 4,
            spls: SplsConfig::default(),
        };
        let mut st = DecodeState::new(eng, cfg);
        let mut rng = Xoshiro256pp::new(4);
        for _ in 0..96 {
            let logits = st.push(rng.below(64) as i32);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(st.len(), 96);
    }

    #[test]
    fn three_component_full_window_equals_dense_decode() {
        // a window covering every slot keeps everything, so the gated
        // executor must reproduce dense logits exactly — and a custom
        // generator must bypass the shared plan cache entirely
        use crate::spls::maskgen::ThreeComponent;
        let eng = engine();
        let seq = toks(5, 12);
        let spls = SplsConfig {
            top_k: 0.0,
            sim_threshold: -1.0,
            ffn_threshold: usize::MAX,
            window: 8,
        };
        let cfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
        let cache = SharedPlanCache::new(64);
        let mut masked = DecodeState::new(Arc::clone(&eng), cfg)
            .with_plan_cache(cache.clone())
            .with_mask_gen(Arc::new(ThreeComponent { window: 64, top_k: 0.0, global: 0 }));
        let mut dense = DecodeState::new(eng, DecodeConfig::default());
        for &t in &seq {
            assert_eq!(masked.push(t), dense.push(t));
        }
        let s = masked.stats();
        assert_eq!(s.plan_hits, 0, "custom masks never read the plan cache");
        assert_eq!(s.plan_misses, 12);
        assert_eq!(cache.stats().step_misses, 0, "custom masks never probe the cache");
    }

    #[test]
    fn sim_reuse_fires_when_threshold_admits_everything() {
        // normalized L1 distance is ≤ 2 by construction, so s = 2 makes
        // every step (after the first) similar: reuse and FFN skips are
        // guaranteed to fire, and the engine must stay finite
        let eng = engine();
        let spls = SplsConfig { sim_threshold: 2.0, ..SplsConfig::default() };
        let cfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
        let mut st = DecodeState::new(eng, cfg);
        let mut last = Vec::new();
        for _ in 0..12 {
            last = st.push(7);
        }
        let s = st.stats();
        assert!(last.iter().all(|v| v.is_finite()));
        assert_eq!(s.sim_heads, 2 * 4 * 11, "every head-step after the first reuses");
        assert_eq!(s.ffn_skips, 2 * 11, "every layer-step after the first skips the FFN");
    }
}
