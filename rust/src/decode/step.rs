//! The decode-step forward: single-row attention against the pruned
//! per-head KV cache, reusing the exact head math of
//! `model::transformer` (same `matmul`/`linear`/`masked_softmax_rows`
//! primitives, same accumulation order), so **unbounded-budget dense
//! decode is bit-identical to re-running `forward_causal_hidden` on the
//! growing sequence** — asserted by the tests here and by
//! `tests/integration_decode.rs`.
//!
//! Two modes:
//!
//! * [`DecodeMode::Dense`] — full attention over every cached slot. With
//!   a finite budget the cache degrades to a sliding window (zero
//!   scores → oldest-first eviction).
//! * [`DecodeMode::Spls`] — the incremental SPLS predictor
//!   (`decode::incremental`) gates each step: similar steps reuse the
//!   previous step's attention output per head (recovery by
//!   replication), non-similar steps attend only over the predicted
//!   keep-mask; predicted row magnitudes accumulate into the KV cache's
//!   eviction scores; and when enough heads vote "similar" the FFN row
//!   is reused too (the MFI voting rule applied temporally). Step plans
//!   are memoized in the shared `spls::plan_cache` under decode
//!   buckets, so replaying a prefix skips planning entirely.

use std::sync::Arc;

use crate::config::SplsConfig;
use crate::decode::incremental::{HeadPredictor, HeadStepPlan, LayerStepPlan, StepPlan};
use crate::decode::kv_cache::HeadKv;
use crate::model::tensor::{
    add_inplace, gelu_inplace, layernorm, linear, masked_softmax_rows, matmul,
};
use crate::model::{embed_row, lm_logits_row, TinyWeights};
use crate::quant::quantize_sym8;
use crate::spls::plan_cache::SharedPlanCache;
use crate::util::mat::{Mat, MatF, MatI};

/// Attention execution mode of a decode session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    /// Full attention over the cached prefix (the exactness baseline).
    Dense,
    /// Incremental-SPLS gated attention + sparsity-aware eviction.
    Spls,
}

/// Per-session decode configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeConfig {
    pub mode: DecodeMode,
    /// Per-head KV budget in cached tokens; `usize::MAX` = unbounded.
    pub kv_budget: usize,
    /// Newest slots never evicted (clamped to ≥ 1: the diagonal is
    /// always retained, and to < budget so eviction can make progress).
    pub recent: usize,
    /// SPLS operating point for the incremental predictor.
    pub spls: SplsConfig,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        Self {
            mode: DecodeMode::Dense,
            kv_budget: usize::MAX,
            recent: 8,
            spls: SplsConfig::default(),
        }
    }
}

/// Decode-side counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// Tokens pushed (prompt + generated).
    pub steps: usize,
    /// Head-steps that reused the previous attention output.
    pub sim_heads: usize,
    /// Layer-steps that reused the previous FFN row.
    pub ffn_skips: usize,
    /// KV slots evicted across all layers/heads.
    pub evictions: usize,
    /// Step plans served from the shared plan cache.
    pub plan_hits: usize,
    /// Step plans computed (and, when a cache is attached, inserted).
    pub plan_misses: usize,
}

/// Immutable per-weights state shared by every decode session: per-head
/// f32 weight slices (so a step projects exactly one row per head, with
/// accumulation bit-identical to the full-matrix prefill projections —
/// output columns of `matmul` are independent) and the per-head int8
/// prediction weights, quantized exactly like `model::plan_model` does.
pub struct DecodeEngine {
    weights: Arc<TinyWeights>,
    layers: Vec<EngineLayer>,
}

struct EngineLayer {
    wq: Vec<MatF>,
    bq: Vec<Vec<f32>>,
    wk: Vec<MatF>,
    bk: Vec<Vec<f32>>,
    wv: Vec<MatF>,
    bv: Vec<Vec<f32>>,
    pred_wq: Vec<MatI>,
    pred_wk: Vec<MatI>,
}

impl DecodeEngine {
    pub fn new(weights: Arc<TinyWeights>) -> Self {
        let cfg = weights.cfg;
        let dh = cfg.d_head();
        let layers = weights
            .layers
            .iter()
            .map(|lw| {
                let slice_f = |m: &MatF, hi: usize| {
                    MatF::from_fn(m.rows, dh, |r, c| m[(r, hi * dh + c)])
                };
                let slice_b = |b: &[f32], hi: usize| b[hi * dh..(hi + 1) * dh].to_vec();
                let slice_8 = |m: &MatF, hi: usize| {
                    let (q, _) = quantize_sym8(&slice_f(m, hi).data);
                    MatI::from_vec(m.rows, dh, q)
                };
                let mut l = EngineLayer {
                    wq: Vec::new(),
                    bq: Vec::new(),
                    wk: Vec::new(),
                    bk: Vec::new(),
                    wv: Vec::new(),
                    bv: Vec::new(),
                    pred_wq: Vec::new(),
                    pred_wk: Vec::new(),
                };
                for hi in 0..cfg.n_heads {
                    l.wq.push(slice_f(&lw.wq, hi));
                    l.bq.push(slice_b(&lw.bq, hi));
                    l.wk.push(slice_f(&lw.wk, hi));
                    l.bk.push(slice_b(&lw.bk, hi));
                    l.wv.push(slice_f(&lw.wv, hi));
                    l.bv.push(slice_b(&lw.bv, hi));
                    l.pred_wq.push(slice_8(&lw.wq, hi));
                    l.pred_wk.push(slice_8(&lw.wk, hi));
                }
                l
            })
            .collect();
        Self { weights, layers }
    }

    pub fn weights(&self) -> &Arc<TinyWeights> {
        &self.weights
    }
}

struct HeadState {
    kv: HeadKv,
    pred: HeadPredictor,
    prev_out: Option<Vec<f32>>,
}

struct LayerState {
    heads: Vec<HeadState>,
    prev_ffn: Option<Vec<f32>>,
}

/// One decode session's mutable state: the residual-stream position,
/// per-layer/per-head caches, and optional plan-cache handle.
pub struct DecodeState {
    eng: Arc<DecodeEngine>,
    cfg: DecodeConfig,
    recent: usize,
    tokens: Vec<i32>,
    layers: Vec<LayerState>,
    cache: Option<SharedPlanCache>,
    stats: DecodeStats,
}

impl DecodeState {
    pub fn new(eng: Arc<DecodeEngine>, cfg: DecodeConfig) -> Self {
        let mcfg = eng.weights.cfg;
        let dh = mcfg.d_head();
        if cfg.kv_budget != usize::MAX {
            assert!(cfg.kv_budget >= 2, "a finite KV budget needs at least 2 slots");
        }
        let recent = if cfg.kv_budget == usize::MAX {
            cfg.recent.max(1)
        } else {
            cfg.recent.max(1).min(cfg.kv_budget - 1)
        };
        let layers = (0..mcfg.n_layers)
            .map(|_| LayerState {
                heads: (0..mcfg.n_heads)
                    .map(|_| HeadState {
                        kv: HeadKv::new(dh),
                        pred: HeadPredictor::new(dh),
                        prev_out: None,
                    })
                    .collect(),
                prev_ffn: None,
            })
            .collect();
        Self {
            eng,
            cfg,
            recent,
            tokens: Vec::new(),
            layers,
            cache: None,
            stats: DecodeStats::default(),
        }
    }

    /// Attach a shared plan cache: step plans are looked up / inserted
    /// under the token prefix (decode buckets), so identical prefixes
    /// across sessions replay planning from cache.
    pub fn with_plan_cache(mut self, cache: SharedPlanCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Tokens pushed so far (prompt + generated).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats
    }

    /// Cached KV slots of one head (≤ the budget between steps).
    pub fn kv_len(&self, layer: usize, head: usize) -> usize {
        self.layers[layer].heads[head].kv.len()
    }

    /// Push one token through the model; returns the next-token logits.
    pub fn push(&mut self, token: i32) -> Vec<f32> {
        let eng = Arc::clone(&self.eng);
        let w = eng.weights();
        let mcfg = w.cfg;
        let dh = mcfg.d_head();
        let spls_mode = self.cfg.mode == DecodeMode::Spls;
        let p = self.tokens.len();
        self.tokens.push(token);
        // memoized step plan for this exact prefix (Spls mode only)
        let cached: Option<StepPlan> = match (&self.cache, spls_mode) {
            (Some(c), true) => {
                c.get_step(&self.tokens, &self.cfg.spls, self.cfg.kv_budget, self.recent)
            }
            _ => None,
        };
        let plan_fresh = spls_mode && self.cache.is_some() && cached.is_none();
        let mut fresh: Option<StepPlan> = if plan_fresh {
            Some(StepPlan { layers: Vec::with_capacity(mcfg.n_layers) })
        } else {
            None
        };
        if cached.is_some() {
            self.stats.plan_hits += 1;
        }
        let mut x = embed_row(w, token, p);
        for (li, (lw, el)) in w.layers.iter().zip(&eng.layers).enumerate() {
            let h = layernorm(&x, &lw.ln1_g, &lw.ln1_b);
            let hq = if spls_mode && cached.is_none() {
                let (q, _) = quantize_sym8(&h.data);
                Some(MatI::from_vec(1, h.cols, q))
            } else {
                None
            };
            let mut att = MatF::zeros(1, mcfg.d_model);
            let mut sim_heads = 0usize;
            let mut layer_plan =
                fresh.as_ref().map(|_| LayerStepPlan { heads: Vec::with_capacity(mcfg.n_heads) });
            for hi in 0..mcfg.n_heads {
                // K/V rows are always generated for the new token
                let kr = linear(&h, &el.wk[hi], &el.bk[hi]);
                let vr = linear(&h, &el.wv[hi], &el.bv[hi]);
                let hs = &mut self.layers[li].heads[hi];
                hs.kv.push(&kr.data, &vr.data, p);
                let n = hs.kv.len();
                let decision: Option<HeadStepPlan> = if spls_mode {
                    Some(match &cached {
                        Some(plan) => {
                            let d = &plan.layers[li].heads[hi];
                            hs.pred.apply(d);
                            d.clone()
                        }
                        None => {
                            let d = hs.pred.step(
                                hq.as_ref().expect("fresh Spls step quantizes h"),
                                &el.pred_wq[hi],
                                &el.pred_wk[hi],
                                &self.cfg.spls,
                            );
                            if let Some(lp) = layer_plan.as_mut() {
                                lp.heads.push(d.clone());
                            }
                            d
                        }
                    })
                } else {
                    None
                };
                if let Some(d) = &decision {
                    hs.kv.accumulate(&d.row);
                }
                let out_row: Vec<f32> = match &decision {
                    Some(d) if d.similar && hs.prev_out.is_some() => {
                        sim_heads += 1;
                        self.stats.sim_heads += 1;
                        hs.prev_out.clone().expect("checked above")
                    }
                    _ => {
                        // exact prefill head math on the cached slots
                        let q = linear(&h, &el.wq[hi], &el.bq[hi]);
                        let kmat = hs.kv.k_mat();
                        let vmat = hs.kv.v_mat();
                        let scale = 1.0 / (dh as f32).sqrt();
                        let mut s = matmul(&q, &kmat.transpose());
                        for v in &mut s.data {
                            *v *= scale;
                        }
                        let mask = match &decision {
                            Some(d) => Mat::from_vec(1, n, d.keep.clone()),
                            None => Mat::from_vec(1, n, vec![true; n]),
                        };
                        masked_softmax_rows(&mut s, &mask);
                        matmul(&s, &vmat).data
                    }
                };
                hs.prev_out = Some(out_row.clone());
                for (c, v) in out_row.iter().enumerate() {
                    att[(0, hi * dh + c)] = *v;
                }
            }
            let mut x1 = x.clone();
            add_inplace(&mut x1, &linear(&att, &lw.wo, &lw.bo));
            let h2 = layernorm(&x1, &lw.ln2_g, &lw.ln2_b);
            let skip_ffn = spls_mode
                && sim_heads >= self.cfg.spls.ffn_threshold.max(1)
                && self.layers[li].prev_ffn.is_some();
            let ffn_row: Vec<f32> = if skip_ffn {
                self.stats.ffn_skips += 1;
                self.layers[li].prev_ffn.clone().expect("checked above")
            } else {
                let mut ff = linear(&h2, &lw.w1, &lw.b1);
                gelu_inplace(&mut ff);
                linear(&ff, &lw.w2, &lw.b2).data
            };
            self.layers[li].prev_ffn = Some(ffn_row.clone());
            let mut x2 = x1;
            add_inplace(&mut x2, &MatF::from_vec(1, mcfg.d_model, ffn_row));
            x = x2;
            // eviction: drop lowest-cumulative-score slots over budget
            if self.cfg.kv_budget != usize::MAX {
                for hs in &mut self.layers[li].heads {
                    while hs.kv.len() > self.cfg.kv_budget {
                        match hs.kv.evict_lowest(self.recent) {
                            Some(slot) => {
                                // Dense mode never grows the predictor
                                // cache — only evict it in lockstep
                                // when it actually has slots (Spls)
                                if !hs.pred.is_empty() {
                                    hs.pred.remove_slot(slot);
                                }
                                self.stats.evictions += 1;
                            }
                            None => break,
                        }
                    }
                }
            }
            if let (Some(fp), Some(lp)) = (fresh.as_mut(), layer_plan) {
                fp.layers.push(lp);
            }
        }
        if let (Some(c), Some(plan)) = (&self.cache, fresh) {
            c.put_step(&self.tokens, &self.cfg.spls, self.cfg.kv_budget, self.recent, plan);
            self.stats.plan_misses += 1;
        } else if spls_mode && self.cache.is_none() {
            self.stats.plan_misses += 1;
        }
        self.stats.steps += 1;
        let xf = layernorm(&x, &w.lnf_g, &w.lnf_b);
        lm_logits_row(w, xf.row(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::next_token_logits;
    use crate::util::rng::Xoshiro256pp;

    fn engine() -> Arc<DecodeEngine> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny_weights.bin");
        Arc::new(DecodeEngine::new(Arc::new(TinyWeights::load(&p).unwrap())))
    }

    fn toks(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn dense_decode_logits_bit_identical_to_causal_prefill() {
        let eng = engine();
        let w = Arc::clone(eng.weights());
        let seq = toks(1, 20);
        let mut st = DecodeState::new(eng, DecodeConfig::default());
        for t in 1..=seq.len() {
            let got = st.push(seq[t - 1]);
            let want = next_token_logits(&w, &seq[..t]);
            assert_eq!(got, want, "decode diverged from prefill at length {t}");
        }
        assert_eq!(st.stats().evictions, 0);
        assert_eq!(st.kv_len(0, 0), 20);
    }

    #[test]
    fn spls_full_keep_equals_dense_decode() {
        // top_k = 1 keeps every slot, sim_threshold < 0 disables reuse,
        // ffn_threshold = MAX disables FFN skipping: the Spls machinery
        // runs but gates nothing, so logits must equal the dense path
        let eng = engine();
        let seq = toks(2, 12);
        let spls = SplsConfig {
            top_k: 1.0,
            sim_threshold: -1.0,
            ffn_threshold: usize::MAX,
            window: 8,
        };
        let cfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
        let mut sparse = DecodeState::new(Arc::clone(&eng), cfg);
        let mut dense = DecodeState::new(eng, DecodeConfig::default());
        for &t in &seq {
            assert_eq!(sparse.push(t), dense.push(t));
        }
        assert_eq!(sparse.stats().sim_heads, 0);
        assert_eq!(sparse.stats().ffn_skips, 0);
    }

    #[test]
    fn budget_bounds_every_head_cache() {
        let eng = engine();
        let seq = toks(3, 32);
        let cfg = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 8,
            recent: 3,
            spls: SplsConfig::default(),
        };
        let mut st = DecodeState::new(eng, cfg);
        let mut last = Vec::new();
        for &t in &seq {
            last = st.push(t);
        }
        assert!(last.iter().all(|v| v.is_finite()));
        for li in 0..2 {
            for hi in 0..4 {
                assert!(st.kv_len(li, hi) <= 8, "head ({li},{hi}) over budget");
            }
        }
        assert!(st.stats().evictions > 0, "32 tokens into 8 slots must evict");
    }

    #[test]
    fn dense_mode_with_finite_budget_is_a_sliding_window() {
        // zero scores → oldest-first eviction; the predictor cache is
        // empty in Dense mode and must not be touched by eviction
        let eng = engine();
        let cfg = DecodeConfig { kv_budget: 8, recent: 2, ..DecodeConfig::default() };
        let mut st = DecodeState::new(eng, cfg);
        let seq = toks(6, 20);
        let mut last = Vec::new();
        for &t in &seq {
            last = st.push(t);
        }
        assert!(last.iter().all(|v| v.is_finite()));
        for li in 0..2 {
            for hi in 0..4 {
                assert!(st.kv_len(li, hi) <= 8);
            }
        }
        assert_eq!(st.stats().evictions, 12 * 8, "oldest slot dropped per head per step");
    }

    #[test]
    fn decode_runs_past_the_trained_position_table() {
        // positions ≥ seq_len clamp to the last pos row; with a finite
        // budget the session keeps streaming well past L = 64
        let eng = engine();
        let cfg = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 16,
            recent: 4,
            spls: SplsConfig::default(),
        };
        let mut st = DecodeState::new(eng, cfg);
        let mut rng = Xoshiro256pp::new(4);
        for _ in 0..96 {
            let logits = st.push(rng.below(64) as i32);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(st.len(), 96);
    }

    #[test]
    fn sim_reuse_fires_when_threshold_admits_everything() {
        // normalized L1 distance is ≤ 2 by construction, so s = 2 makes
        // every step (after the first) similar: reuse and FFN skips are
        // guaranteed to fire, and the engine must stay finite
        let eng = engine();
        let spls = SplsConfig { sim_threshold: 2.0, ..SplsConfig::default() };
        let cfg = DecodeConfig { mode: DecodeMode::Spls, spls, ..DecodeConfig::default() };
        let mut st = DecodeState::new(eng, cfg);
        let mut last = Vec::new();
        for _ in 0..12 {
            last = st.push(7);
        }
        let s = st.stats();
        assert!(last.iter().all(|v| v.is_finite()));
        assert_eq!(s.sim_heads, 2 * 4 * 11, "every head-step after the first reuses");
        assert_eq!(s.ffn_skips, 2 * 11, "every layer-step after the first skips the FFN");
    }
}
