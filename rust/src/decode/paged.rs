//! Paged KV: a fixed-size-block, refcounted K/V pool shared by every
//! decode session on a server, with prompt-prefix sharing and
//! copy-on-write divergence — the multi-session workload class
//! (thousands of sessions sharing one long system prompt) the
//! contiguous per-session [`HeadKv`](crate::decode::kv_cache::HeadKv)
//! cannot reach.
//!
//! Three layers:
//!
//! * [`PagedPool`] — the block pool: every block holds `block_size`
//!   K and V rows of one head, allocation pops a free list under a hard
//!   `max_blocks` cap, and a per-block refcount counts the **logical
//!   slots** referencing it (session chains plus prefix-trie
//!   snapshots). A block frees the instant its last reference drops.
//! * [`PagedHeadKv`] — one head's block table: an ordered list of
//!   `(block, row)` slot references plus the same positions/eviction
//!   scores as the contiguous cache. Appends go to an *owned* tail
//!   block; appending to a *shared* partial tail first copies it
//!   (copy-on-write), so divergence after a shared prefix never
//!   mutates another session's view. Score eviction only considers
//!   slots in **private** blocks (refcount == this head's slot count in
//!   the block): shared slots are pinned by refcount first, SpAtten
//!   score eviction second — exactly the contiguous policy once every
//!   block is private, which is the single-session case.
//! * [`PagedDecodeState`] — a decode session over the pool, plus the
//!   prefix trie protocol: the first session to complete a declared
//!   prefix publishes a snapshot (block table + predictor + reuse rows,
//!   refcounts bumped) under the token IDs; later sessions with the
//!   same prefix and the same [`DecodeConfig`] attach to it — mapping
//!   the same physical blocks and skipping the prefix forward passes
//!   entirely. The decode forward is deterministic, so an attached
//!   continuation is bit-identical to recomputing the prefix.
//!
//! **Bitwise-parity contract**: `PagedHeadKv` implements
//! [`KvSlots`] with the same `dot_qk`/`axpy_prob` accumulation chains,
//! in the same ascending-slot order, as the contiguous cache, and
//! [`PagedDecodeState`] runs the *same* generic `push`
//! (`DecodeStateOf`). A single uncontended session is therefore
//! bit-identical to [`DecodeState`](crate::decode::DecodeState) at
//! every step — asserted on the trained artifacts by
//! `tests/integration_paged.rs`.
//!
//! Custom [`MaskGen`] sessions neither publish nor attach (snapshots
//! encode the default SPLS rule; [`PagedDecodeState::with_mask_gen`]
//! refuses a session that already declared a prefix). Trie entries are
//! keyed on **(prefix tokens, [`DecodeConfig`])** — distinct configs
//! publish and share the same prefix independently — and the trie
//! itself is bounded: at most `max_trie_entries` snapshots live at
//! once, evicted least-recently-used (publish and attach both refresh
//! an entry). Allocation failure is **recoverable**: when the pool is
//! at its hard cap it first sheds cold trie snapshots (LRU), and only
//! if nothing frees does the push unwind with a typed [`PoolExhausted`]
//! payload — the replica worker downcasts it and fails just the
//! offending session, never the tier. Admission can keep sessions
//! inside the cap up front via the reservation ledger
//! ([`PagedPool::try_reserve`] / [`PagedPool::session_demand`]). A
//! session whose shared prefix exceeds its KV budget simply stops
//! evicting (refcount precedence), mirroring the contiguous
//! `None`-break.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::decode::incremental::HeadPredictor;
use crate::decode::kv_cache::KvSlots;
use crate::decode::step::{DecodeConfig, DecodeEngine, DecodeStateOf, DecodeStats};
use crate::model::sparse_kernels::{axpy_prob, dot_qk};
use crate::spls::maskgen::MaskGen;
use crate::spls::plan_cache::SharedPlanCache;
use crate::util::fault::{FaultInjector, FaultSite};

/// One fixed-size page of K/V rows for one head.
struct Block {
    /// Row-major `block_size × dh` key rows (rows ≥ `fill` are unset).
    k: Vec<f32>,
    /// Row-major `block_size × dh` value rows.
    v: Vec<f32>,
    /// Rows written so far; appends always land at `fill`.
    fill: usize,
    /// Logical slot references: one per session-chain slot plus one per
    /// prefix-trie snapshot slot pointing at this block.
    refs: usize,
}

/// One head-chain slot: which block, which row inside it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct SlotRef {
    block: usize,
    row: usize,
}

/// Prefix-trie node keyed on token IDs. A node holds one entry per
/// published [`DecodeConfig`], so distinct configs share independently.
#[derive(Default)]
struct TrieNode {
    children: HashMap<i32, TrieNode>,
    entries: Vec<PrefixEntry>,
}

/// Published snapshot of a completed prefix: everything a session needs
/// to continue decoding as if it had pushed the prefix itself.
#[derive(Clone)]
struct PrefixEntry {
    /// Sessions attach only under the exact same decode config.
    cfg: DecodeConfig,
    /// LRU stamp (the pool's `lru_clock` at last publish/attach).
    last_used: u64,
    layers: Vec<LayerSnapshot>,
}

#[derive(Clone)]
struct LayerSnapshot {
    heads: Vec<HeadSnapshot>,
    prev_ffn: Option<Vec<f32>>,
}

#[derive(Clone)]
struct HeadSnapshot {
    slots: Vec<SlotRef>,
    positions: Vec<usize>,
    scores: Vec<f64>,
    /// The publisher's partially-filled tail block, if any; attachers
    /// adopt it as a *shared* tail (their first append copies it).
    tail: Option<usize>,
    pred: HeadPredictor,
    prev_out: Option<Vec<f32>>,
}

struct PoolInner {
    block_size: usize,
    dh: usize,
    max_blocks: usize,
    blocks: Vec<Option<Block>>,
    free: Vec<usize>,
    in_use: usize,
    peak: usize,
    allocated_total: usize,
    cow_copies: usize,
    prefix_hits: usize,
    prefix_misses: usize,
    shared_attach_tokens: usize,
    trie: TrieNode,
    /// Monotone LRU clock; bumped on every publish/attach.
    lru_clock: u64,
    /// Live trie entries, bounded by `max_trie_entries` (LRU-evicted).
    trie_entries: usize,
    max_trie_entries: usize,
    trie_evictions: usize,
    /// Admission reservation ledger, in blocks (see
    /// [`PagedPool::try_reserve`]). Independent of `in_use`: a
    /// reservation is an upper bound a session may still allocate.
    reserved: usize,
    /// Optional deterministic fault injection (chaos testing): when a
    /// scheduled allocation trips, it fails with [`PoolExhausted`] —
    /// the pool's real recoverable failure path. Default off.
    fault: Option<FaultInjector>,
}

/// Recursive min-`last_used` scan; `best` is `(stamp, path, index)`.
/// Stamps are unique (every publish/attach bumps the clock), so the
/// result is deterministic despite `HashMap` iteration order.
fn find_lru(node: &TrieNode, path: &mut Vec<i32>, best: &mut Option<(u64, Vec<i32>, usize)>) {
    for (i, e) in node.entries.iter().enumerate() {
        if best.as_ref().map_or(true, |(b, _, _)| e.last_used < *b) {
            *best = Some((e.last_used, path.clone(), i));
        }
    }
    for (&t, child) in &node.children {
        path.push(t);
        find_lru(child, path, best);
        path.pop();
    }
}

/// Remove the entry at `path`/`idx`, pruning now-empty trie nodes on
/// the way back up.
fn remove_entry_at(node: &mut TrieNode, path: &[i32], idx: usize) -> PrefixEntry {
    match path.split_first() {
        None => node.entries.remove(idx),
        Some((&t, rest)) => {
            let child = node.children.get_mut(&t).expect("trie path exists");
            let e = remove_entry_at(child, rest, idx);
            if child.entries.is_empty() && child.children.is_empty() {
                node.children.remove(&t);
            }
            e
        }
    }
}

impl PoolInner {
    fn block(&self, b: usize) -> &Block {
        self.blocks[b].as_ref().expect("live block reference")
    }

    fn block_mut(&mut self, b: usize) -> &mut Block {
        self.blocks[b].as_mut().expect("live block reference")
    }

    /// Pop the free list (or grow, under the hard cap) and install a
    /// zeroed block with no references yet. At the cap with nothing
    /// free, cold trie snapshots are shed (LRU) until a block frees;
    /// if none does, the allocation fails recoverably.
    fn alloc_block(&mut self) -> Result<usize, PoolExhausted> {
        if let Some(f) = &self.fault {
            if f.trip(FaultSite::PoolAlloc) {
                return Err(PoolExhausted { in_use: self.in_use, max_blocks: self.max_blocks });
            }
        }
        let b = if let Some(b) = self.free.pop() {
            b
        } else if self.blocks.len() < self.max_blocks {
            self.blocks.push(None);
            self.blocks.len() - 1
        } else {
            loop {
                if !self.evict_lru_entry() {
                    return Err(PoolExhausted {
                        in_use: self.in_use,
                        max_blocks: self.max_blocks,
                    });
                }
                // an evicted snapshot only frees blocks no live session
                // still references — keep shedding until one does
                if let Some(b) = self.free.pop() {
                    break b;
                }
            }
        };
        let n = self.block_size * self.dh;
        self.blocks[b] = Some(Block { k: vec![0.0; n], v: vec![0.0; n], fill: 0, refs: 0 });
        self.in_use += 1;
        self.allocated_total += 1;
        self.peak = self.peak.max(self.in_use);
        Ok(b)
    }

    /// Copy-on-write: clone block `b`'s payload (rows + fill) into a
    /// fresh block. References move separately via `add_refs`/`sub_refs`.
    fn cow_block(&mut self, b: usize) -> Result<usize, PoolExhausted> {
        let nb = self.alloc_block()?;
        let (k, v, fill) = {
            let src = self.block(b);
            (src.k.clone(), src.v.clone(), src.fill)
        };
        let dst = self.block_mut(nb);
        dst.k = k;
        dst.v = v;
        dst.fill = fill;
        self.cow_copies += 1;
        Ok(nb)
    }

    fn add_refs(&mut self, b: usize, n: usize) {
        self.block_mut(b).refs += n;
    }

    /// Drop `n` references; the block frees (free-list return) at zero.
    fn sub_refs(&mut self, b: usize, n: usize) {
        let blk = self.block_mut(b);
        assert!(blk.refs >= n, "paged block refcount underflow");
        blk.refs -= n;
        if blk.refs == 0 {
            self.blocks[b] = None;
            self.free.push(b);
            self.in_use -= 1;
        }
    }

    fn is_freed(&self, b: usize) -> bool {
        self.blocks[b].is_none()
    }

    /// Write one K/V row at the block's fill cursor; returns the row.
    fn append_row(&mut self, b: usize, k_row: &[f32], v_row: &[f32]) -> usize {
        let d = self.dh;
        let blk = self.block_mut(b);
        let row = blk.fill;
        debug_assert!(row < self.block_size);
        blk.k[row * d..(row + 1) * d].copy_from_slice(k_row);
        blk.v[row * d..(row + 1) * d].copy_from_slice(v_row);
        blk.fill += 1;
        blk.refs += 1;
        row
    }

    fn k_row(&self, s: SlotRef, d: usize) -> &[f32] {
        &self.block(s.block).k[s.row * d..(s.row + 1) * d]
    }

    fn v_row(&self, s: SlotRef, d: usize) -> &[f32] {
        &self.block(s.block).v[s.row * d..(s.row + 1) * d]
    }

    /// Find the entry published for `(prefix, cfg)`, refreshing its LRU
    /// stamp, and return a clone for the attaching session.
    fn touch_lookup(&mut self, prefix: &[i32], cfg: &DecodeConfig) -> Option<PrefixEntry> {
        self.lru_clock += 1;
        let stamp = self.lru_clock;
        let mut node = &mut self.trie;
        for t in prefix {
            node = node.children.get_mut(t)?;
        }
        let e = node.entries.iter_mut().find(|e| e.cfg == *cfg)?;
        e.last_used = stamp;
        Some(e.clone())
    }

    /// Whether `(prefix, cfg)` is already published (publish-race check;
    /// does not refresh the LRU stamp).
    fn has_entry(&self, prefix: &[i32], cfg: &DecodeConfig) -> bool {
        let mut node = &self.trie;
        for t in prefix {
            match node.children.get(t) {
                Some(n) => node = n,
                None => return false,
            }
        }
        node.entries.iter().any(|e| e.cfg == *cfg)
    }

    /// Publish an entry under `(prefix, entry.cfg)`, evicting LRU
    /// entries first while the trie is at its cap.
    fn insert_entry(&mut self, prefix: &[i32], mut entry: PrefixEntry) {
        while self.trie_entries >= self.max_trie_entries {
            if !self.evict_lru_entry() {
                break; // unreachable with a cap ≥ 1, but never spin
            }
        }
        self.lru_clock += 1;
        entry.last_used = self.lru_clock;
        let mut node = &mut self.trie;
        for t in prefix {
            node = node.children.entry(*t).or_default();
        }
        node.entries.push(entry);
        self.trie_entries += 1;
    }

    /// Evict the least-recently-used trie entry, dropping its block
    /// references (blocks free once no live session shares them).
    /// Returns `false` when the trie is empty.
    fn evict_lru_entry(&mut self) -> bool {
        let mut best: Option<(u64, Vec<i32>, usize)> = None;
        let mut path = Vec::new();
        find_lru(&self.trie, &mut path, &mut best);
        let Some((_, path, idx)) = best else {
            return false;
        };
        let entry = remove_entry_at(&mut self.trie, &path, idx);
        self.trie_entries -= 1;
        self.trie_evictions += 1;
        for ls in &entry.layers {
            for hs in &ls.heads {
                for s in &hs.slots {
                    self.sub_refs(s.block, 1);
                }
            }
        }
        true
    }
}

/// Recoverable allocation failure: the pool is at its hard `max_blocks`
/// cap and no block could be freed (every live block is referenced by a
/// live session; cold trie snapshots were already shed). `KvSlots::push`
/// is infallible by signature, so the paged cache raises this as a
/// typed panic payload (`std::panic::panic_any`); the replica worker
/// downcasts it and aborts only the offending session — the tier, and
/// every other session, keeps serving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolExhausted {
    /// Blocks live at the failed allocation.
    pub in_use: usize,
    /// The pool's hard cap.
    pub max_blocks: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "paged KV pool exhausted: {} blocks live (cap {}) — the session was aborted; \
             raise the pool cap or lower concurrent paged sessions",
            self.in_use, self.max_blocks
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Pool-level counters, snapshot for `/metrics` and BENCH_6.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolStats {
    /// Rows per block.
    pub block_size: usize,
    /// Hard cap on live blocks (the fixed pool memory).
    pub max_blocks: usize,
    /// Blocks currently live.
    pub in_use: usize,
    /// High-water mark of live blocks.
    pub peak: usize,
    /// Blocks ever allocated (free-list reuse counts again).
    pub allocated_total: usize,
    /// Copy-on-write block copies (shared-tail divergences).
    pub cow_copies: usize,
    /// Prefix-trie attaches served.
    pub prefix_hits: usize,
    /// Prefix declarations that found no (matching) entry.
    pub prefix_misses: usize,
    /// Prefix tokens whose forward passes were skipped by attaching.
    pub shared_attach_tokens: usize,
    /// Blocks reserved by admission (upper bounds; see
    /// [`PagedPool::try_reserve`]).
    pub reserved: usize,
    /// Live prefix-trie entries (bounded by the trie cap).
    pub trie_entries: usize,
    /// Trie entries evicted (LRU cap + emergency shedding).
    pub trie_evictions: usize,
}

impl PoolStats {
    /// Hit fraction over prefix declarations (0 when cold).
    pub fn hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }
}

/// The shared block pool (cheap to clone: all clones are handles onto
/// one pool). One pool serves every layer/head of every session on a
/// server; `max_blocks` is the hard memory cap.
#[derive(Clone)]
pub struct PagedPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl PagedPool {
    /// Default bound on live prefix-trie entries ([`Self::with_trie_cap`]).
    pub const DEFAULT_TRIE_ENTRIES: usize = 64;

    /// `block_size` rows per block, at most `max_blocks` live blocks,
    /// `dh` values per K (and V) row, default trie cap.
    pub fn new(block_size: usize, max_blocks: usize, dh: usize) -> Self {
        Self::with_trie_cap(block_size, max_blocks, dh, Self::DEFAULT_TRIE_ENTRIES)
    }

    /// [`Self::new`] with an explicit bound on live prefix-trie entries:
    /// publishing past it evicts the least-recently-used snapshot, so
    /// arbitrary client-declared prefixes cannot pin the pool forever.
    pub fn with_trie_cap(
        block_size: usize,
        max_blocks: usize,
        dh: usize,
        max_trie_entries: usize,
    ) -> Self {
        assert!(block_size >= 1 && max_blocks >= 1 && dh >= 1 && max_trie_entries >= 1);
        Self {
            inner: Arc::new(Mutex::new(PoolInner {
                block_size,
                dh,
                max_blocks,
                blocks: Vec::new(),
                free: Vec::new(),
                in_use: 0,
                peak: 0,
                allocated_total: 0,
                cow_copies: 0,
                prefix_hits: 0,
                prefix_misses: 0,
                shared_attach_tokens: 0,
                trie: TrieNode::default(),
                lru_clock: 0,
                trie_entries: 0,
                max_trie_entries,
                trie_evictions: 0,
                reserved: 0,
                fault: None,
            })),
        }
    }

    /// Install a deterministic fault injector on the allocation path
    /// (chaos testing; see `util::fault`). Default off — without one
    /// the allocator behaves exactly as before.
    pub fn set_fault_injector(&self, fault: FaultInjector) {
        self.lock().fault = Some(fault);
    }

    /// Poison-tolerant lock: a panicked session (e.g. pool exhaustion
    /// unwinding through a replica) must not wedge every other session.
    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn stats(&self) -> PoolStats {
        let g = self.lock();
        PoolStats {
            block_size: g.block_size,
            max_blocks: g.max_blocks,
            in_use: g.in_use,
            peak: g.peak,
            allocated_total: g.allocated_total,
            cow_copies: g.cow_copies,
            prefix_hits: g.prefix_hits,
            prefix_misses: g.prefix_misses,
            shared_attach_tokens: g.shared_attach_tokens,
            reserved: g.reserved,
            trie_entries: g.trie_entries,
            trie_evictions: g.trie_evictions,
        }
    }

    /// Rows per block (the K/V granularity of sharing).
    pub fn block_size(&self) -> usize {
        self.lock().block_size
    }

    /// Worst-case block demand of one session that will hold
    /// `total_tokens` K/V rows in each of its `n_layers × n_heads`
    /// chains: `⌈tokens/block_size⌉` blocks per chain plus one for a
    /// copy-on-write divergence off a shared partial tail. An upper
    /// bound — attached sessions allocate less (the shared prefix's
    /// blocks already exist) and evicting sessions cap out at their KV
    /// budget.
    pub fn session_demand(&self, total_tokens: usize, n_layers: usize, n_heads: usize) -> usize {
        let bs = self.lock().block_size;
        n_layers * n_heads * (total_tokens.div_ceil(bs) + 1)
    }

    /// Reserve `n` blocks in the admission ledger: succeeds iff every
    /// admitted session's worst-case demand still fits the hard cap
    /// (`reserved + n ≤ max_blocks`). Admission that reserves before
    /// dispatch and [`Self::release`]s at session end keeps sessions'
    /// own allocations inside the cap, so mid-decode exhaustion can
    /// only come from out-of-ledger users sharing the pool.
    pub fn try_reserve(&self, n: usize) -> bool {
        let mut g = self.lock();
        if g.reserved + n <= g.max_blocks {
            g.reserved += n;
            true
        } else {
            false
        }
    }

    /// Non-binding preview of [`Self::try_reserve`] (frontend preflight:
    /// shed with a 429 before submitting to the tier).
    pub fn can_reserve(&self, n: usize) -> bool {
        let g = self.lock();
        g.reserved + n <= g.max_blocks
    }

    /// Return `n` reserved blocks to the ledger.
    pub fn release(&self, n: usize) {
        let mut g = self.lock();
        g.reserved = g.reserved.saturating_sub(n);
    }
}

/// Unwind with the typed [`PoolExhausted`] payload: the `KvSlots`
/// trait's `push` is infallible by signature (making it fallible would
/// thread `Result` through every decode layer for an event that only
/// the paged backend can raise), so exhaustion propagates as a panic
/// the replica worker downcasts and contains to the one session. The
/// pool mutex is poison-tolerant, so the unwind cannot wedge peers.
fn or_unwind(r: Result<usize, PoolExhausted>) -> usize {
    match r {
        Ok(b) => b,
        Err(e) => std::panic::panic_any(e),
    }
}

/// One attention head's block table over the shared pool — the paged
/// counterpart of [`HeadKv`](crate::decode::kv_cache::HeadKv).
pub struct PagedHeadKv {
    pool: PagedPool,
    dh: usize,
    /// Ordered logical slots (one per cached token), each holding one
    /// block reference.
    slots: Vec<SlotRef>,
    positions: Vec<usize>,
    score: Vec<f64>,
    /// Block this head appends into, while it has room.
    tail: Option<usize>,
    /// Whether the tail may be appended to in place. `false` after the
    /// head's chain was published to (or attached from) the prefix
    /// trie: the next append copies the tail first (CoW).
    tail_owned: bool,
}

impl PagedHeadKv {
    pub fn new(pool: PagedPool, dh: usize) -> Self {
        assert!(dh >= 1);
        debug_assert_eq!(pool.lock().dh, dh, "pool row width must match the head");
        Self {
            pool,
            dh,
            slots: Vec::new(),
            positions: Vec::new(),
            score: Vec::new(),
            tail: None,
            tail_owned: false,
        }
    }

    /// Distinct live blocks this head references.
    pub fn blocks_referenced(&self) -> usize {
        let mut seen: Vec<usize> = self.slots.iter().map(|s| s.block).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    /// Cumulative importance scores, in slot order.
    pub fn scores(&self) -> &[f64] {
        &self.score
    }
}

impl KvSlots for PagedHeadKv {
    fn len(&self) -> usize {
        self.slots.len()
    }

    fn push(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        assert_eq!(k_row.len(), self.dh);
        assert_eq!(v_row.len(), self.dh);
        let mut pool = self.pool.lock();
        let bs = pool.block_size;
        // exhaustion unwinds with the typed payload *before* any of
        // this head's state mutates, so the session stays consistent
        // and its Drop releases every block reference it holds
        let tb = match self.tail {
            Some(b) if self.tail_owned && pool.block(b).fill < bs => b,
            Some(b) if !self.tail_owned && pool.block(b).fill < bs => {
                // copy-on-write: first divergent append after sharing
                let nb = or_unwind(pool.cow_block(b));
                let mut moved = 0usize;
                for s in self.slots.iter_mut().filter(|s| s.block == b) {
                    s.block = nb;
                    moved += 1;
                }
                pool.add_refs(nb, moved);
                pool.sub_refs(b, moved);
                self.tail = Some(nb);
                self.tail_owned = true;
                nb
            }
            _ => {
                // no tail, or the tail is full: open a fresh block
                let nb = or_unwind(pool.alloc_block());
                self.tail = Some(nb);
                self.tail_owned = true;
                nb
            }
        };
        let row = pool.append_row(tb, k_row, v_row);
        self.slots.push(SlotRef { block: tb, row });
        self.positions.push(pos);
        self.score.push(0.0);
    }

    fn positions(&self) -> &[usize] {
        &self.positions
    }

    fn accumulate(&mut self, row: &[i32]) {
        assert_eq!(row.len(), self.slots.len(), "score row must cover the cache");
        let max = row.iter().map(|r| r.unsigned_abs()).max().unwrap_or(0).max(1) as f64;
        for (s, &r) in self.score.iter_mut().zip(row) {
            *s += r.unsigned_abs() as f64 / max;
        }
    }

    fn evict_lowest(&mut self, recent: usize) -> Option<usize> {
        let n = self.slots.len();
        let protected = recent.max(1);
        if n <= protected {
            return None;
        }
        let lim = n - protected;
        let mut pool = self.pool.lock();
        // refcount precedence: a block is evictable only when private —
        // every reference to it is one of this head's own slots
        let mut mine: HashMap<usize, usize> = HashMap::new();
        for s in &self.slots {
            *mine.entry(s.block).or_insert(0) += 1;
        }
        let mut best: Option<usize> = None;
        for i in 0..lim {
            let b = self.slots[i].block;
            if pool.block(b).refs != mine[&b] {
                continue; // shared (trie or sibling session): pinned
            }
            match best {
                Some(j) if self.score[i] >= self.score[j] => {}
                _ => best = Some(i),
            }
        }
        let best = best?;
        let b = self.slots[best].block;
        pool.sub_refs(b, 1);
        if pool.is_freed(b) && self.tail == Some(b) {
            self.tail = None;
        }
        drop(pool);
        self.slots.remove(best);
        self.positions.remove(best);
        self.score.remove(best);
        Some(best)
    }

    fn scores_into(&self, q: &[f32], srow: &mut [f32]) {
        let pool = self.pool.lock();
        for (o, &s) in srow.iter_mut().zip(&self.slots) {
            *o = dot_qk(q, pool.k_row(s, self.dh));
        }
    }

    fn attend_into(&self, s: &[f32], orow: &mut [f32]) {
        let pool = self.pool.lock();
        for (&av, &sl) in s.iter().zip(&self.slots) {
            if av == 0.0 {
                continue;
            }
            axpy_prob(av, pool.v_row(sl, self.dh), orow);
        }
    }

    fn dots_into(&self, q: &[f32], idx: &[usize], scale: f32, s: &mut [f32]) {
        let pool = self.pool.lock();
        for (o, &slot) in s.iter_mut().zip(idx) {
            *o = dot_qk(q, pool.k_row(self.slots[slot], self.dh)) * scale;
        }
    }

    fn attend_indexed_into(&self, s: &[f32], idx: &[usize], orow: &mut [f32]) {
        let pool = self.pool.lock();
        for (&pv, &slot) in s.iter().zip(idx) {
            if pv == 0.0 {
                continue;
            }
            axpy_prob(pv, pool.v_row(self.slots[slot], self.dh), orow);
        }
    }
}

impl Drop for PagedHeadKv {
    fn drop(&mut self) {
        let mut pool = self.pool.lock();
        for s in &self.slots {
            pool.sub_refs(s.block, 1);
        }
    }
}

/// A decode session over the shared block pool, with optional
/// prefix-trie sharing. Single sessions are bit-identical to
/// [`DecodeState`](crate::decode::DecodeState) (module docs).
pub struct PagedDecodeState {
    inner: DecodeStateOf<PagedHeadKv>,
    pool: PagedPool,
    /// Declared shared prefix (prompt head), if any.
    prefix: Option<Vec<i32>>,
    /// Whether this session restored the prefix from the trie.
    attached: bool,
    /// Whether this session already published (or raced) its prefix.
    published: bool,
}

impl PagedDecodeState {
    pub fn new(eng: Arc<DecodeEngine>, cfg: DecodeConfig, pool: &PagedPool) -> Self {
        let dh = eng.weights().cfg.d_head();
        let p = pool.clone();
        let inner = DecodeStateOf::with_kv(eng, cfg, move || PagedHeadKv::new(p.clone(), dh));
        Self {
            inner,
            pool: pool.clone(),
            prefix: None,
            attached: false,
            published: false,
        }
    }

    /// Attach a shared plan cache (see `DecodeStateOf::with_plan_cache`).
    pub fn with_plan_cache(mut self, cache: SharedPlanCache) -> Self {
        self.inner = self.inner.with_plan_cache(cache);
        self
    }

    /// Swap in a custom keep-mask generator. Mask sessions opt out of
    /// prefix sharing: snapshots encode the default SPLS rule, so a
    /// prefix attached (or declared for publishing) *before* the
    /// generator would silently mix KV computed under one rule with
    /// decoding under another. Builder order is enforced: set the mask
    /// generator first, then (never) declare a prefix.
    pub fn with_mask_gen(mut self, gen: Arc<dyn MaskGen>) -> Self {
        assert!(
            self.prefix.is_none(),
            "set the mask generator before declaring a prefix: a prefix declared first \
             attaches (or publishes) KV computed under the default SPLS rule, which a \
             custom mask generator would then silently contradict"
        );
        self.inner = self.inner.with_mask_gen(gen);
        self
    }

    /// Declare the shared prompt prefix. On a trie hit (same tokens,
    /// same config) the session maps the published blocks and skips the
    /// prefix's forward passes; on a miss it remembers the prefix and
    /// publishes once its pushes complete it.
    pub fn with_prefix(mut self, prefix: &[i32]) -> Self {
        if prefix.is_empty() || self.inner.has_mask_gen() {
            return self;
        }
        let restored: Option<PrefixEntry> = {
            let mut pool = self.pool.lock();
            let found = pool.touch_lookup(prefix, self.inner.config());
            match found {
                Some(e) => {
                    for ls in &e.layers {
                        for hs in &ls.heads {
                            for s in &hs.slots {
                                pool.add_refs(s.block, 1);
                            }
                        }
                    }
                    pool.prefix_hits += 1;
                    pool.shared_attach_tokens += prefix.len();
                    Some(e)
                }
                None => {
                    pool.prefix_misses += 1;
                    None
                }
            }
        };
        self.prefix = Some(prefix.to_vec());
        if let Some(entry) = restored {
            self.inner.set_tokens(prefix.to_vec());
            let layers = self.inner.layers_mut();
            assert_eq!(entry.layers.len(), layers.len(), "snapshot/model layer mismatch");
            for (layer, ls) in layers.iter_mut().zip(entry.layers) {
                assert_eq!(ls.heads.len(), layer.heads.len(), "snapshot/model head mismatch");
                layer.prev_ffn = ls.prev_ffn;
                for (head, hs) in layer.heads.iter_mut().zip(ls.heads) {
                    head.kv.slots = hs.slots;
                    head.kv.positions = hs.positions;
                    head.kv.score = hs.scores;
                    head.kv.tail = hs.tail;
                    head.kv.tail_owned = false;
                    head.pred = hs.pred;
                    head.prev_out = hs.prev_out;
                }
            }
            self.attached = true;
            self.published = true; // the entry exists; nothing to publish
        }
        self
    }

    /// Push one token; returns the next-token logits. Completing a
    /// declared (un-attached) prefix publishes its snapshot to the trie.
    pub fn push(&mut self, token: i32) -> Vec<f32> {
        let logits = self.inner.push(token);
        if !self.published && !self.inner.has_mask_gen() {
            if let Some(pfx) = &self.prefix {
                if self.inner.len() == pfx.len() && self.inner.tokens() == &pfx[..] {
                    self.publish();
                    self.published = true;
                }
            }
        }
        logits
    }

    /// Snapshot the current (prefix-complete) state into the trie and
    /// mark every tail shared, so this session's own next append CoWs
    /// instead of mutating the published rows.
    fn publish(&mut self) {
        let pfx = self.prefix.clone().expect("publish requires a declared prefix");
        {
            let mut pool = self.pool.lock();
            let cfg = *self.inner.config();
            if pool.has_entry(&pfx, &cfg) {
                return; // a racing publisher won this config; its snapshot stands
            }
            let mut layers = Vec::with_capacity(self.inner.layers().len());
            for ls in self.inner.layers() {
                let mut heads = Vec::with_capacity(ls.heads.len());
                for hs in &ls.heads {
                    for s in &hs.kv.slots {
                        pool.add_refs(s.block, 1);
                    }
                    heads.push(HeadSnapshot {
                        slots: hs.kv.slots.clone(),
                        positions: hs.kv.positions.clone(),
                        scores: hs.kv.score.clone(),
                        tail: hs.kv.tail,
                        pred: hs.pred.clone(),
                        prev_out: hs.prev_out.clone(),
                    });
                }
                layers.push(LayerSnapshot { heads, prev_ffn: ls.prev_ffn.clone() });
            }
            pool.insert_entry(&pfx, PrefixEntry { cfg, last_used: 0, layers });
        }
        for ls in self.inner.layers_mut() {
            for hs in &mut ls.heads {
                hs.kv.tail_owned = false;
            }
        }
    }

    /// Tokens pushed or attached so far.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    pub fn tokens(&self) -> &[i32] {
        self.inner.tokens()
    }

    pub fn stats(&self) -> DecodeStats {
        self.inner.stats()
    }

    pub fn kv_len(&self, layer: usize, head: usize) -> usize {
        self.inner.kv_len(layer, head)
    }

    /// Whether the declared prefix was served from the trie.
    pub fn attached(&self) -> bool {
        self.attached
    }

    /// Distinct live blocks referenced across every layer/head.
    pub fn blocks_referenced(&self) -> usize {
        let mut seen: Vec<usize> = Vec::new();
        for ls in self.inner.layers() {
            for hs in &ls.heads {
                seen.extend(hs.kv.slots.iter().map(|s| s.block));
            }
        }
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    pub fn pool(&self) -> &PagedPool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::kv_cache::HeadKv;

    fn row(dh: usize, f: f32) -> Vec<f32> {
        (0..dh).map(|i| f + i as f32 * 0.25).collect()
    }

    fn push_n(kv: &mut PagedHeadKv, n: usize, base: usize) {
        for i in 0..n {
            let f = (base + i) as f32;
            kv.push(&row(2, f), &row(2, -f), base + i);
        }
    }

    #[test]
    fn blocks_allocate_fill_and_free() {
        let pool = PagedPool::new(4, 8, 2);
        let mut kv = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut kv, 5, 0);
        let s = pool.stats();
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.blocks_referenced(), 2, "5 rows at block size 4 = 2 blocks");
        assert_eq!((s.in_use, s.peak, s.allocated_total), (2, 2, 2));
        drop(kv);
        let s = pool.stats();
        assert_eq!(s.in_use, 0, "dropping the head frees its blocks");
        // the free list is reused, not regrown
        let mut kv2 = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut kv2, 8, 0);
        let s = pool.stats();
        assert_eq!((s.in_use, s.peak, s.allocated_total), (2, 2, 4));
    }

    #[test]
    fn hard_cap_unwinds_with_a_typed_exhaustion_payload() {
        let pool = PagedPool::new(2, 1, 2);
        let mut kv = PagedHeadKv::new(pool.clone(), 2);
        let panic = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            push_n(&mut kv, 3, 0); // third row needs a second block
        }))
        .expect_err("allocation past the cap must unwind");
        let e = panic
            .downcast_ref::<PoolExhausted>()
            .expect("payload must be the typed PoolExhausted");
        assert_eq!((e.in_use, e.max_blocks), (1, 1));
        assert!(e.to_string().contains("paged KV pool exhausted"));
        // the failed push mutated nothing: the session still holds its
        // 2 cached rows and dropping it returns the pool to empty
        assert_eq!(kv.len(), 2);
        drop(kv);
        assert_eq!(pool.stats().in_use, 0, "unwound session released its blocks");
    }

    /// Build a one-head trie snapshot of `kv`'s chain (as `publish`
    /// would) and install it under `prefix`.
    fn snapshot_into_trie(pool: &PagedPool, kv: &PagedHeadKv, prefix: &[i32]) {
        let mut g = pool.lock();
        for s in &kv.slots {
            g.add_refs(s.block, 1);
        }
        let entry = PrefixEntry {
            cfg: DecodeConfig::default(),
            last_used: 0,
            layers: vec![LayerSnapshot {
                heads: vec![HeadSnapshot {
                    slots: kv.slots.clone(),
                    positions: kv.positions.clone(),
                    scores: kv.score.clone(),
                    tail: kv.tail,
                    pred: HeadPredictor::new(2),
                    prev_out: None,
                }],
                prev_ffn: None,
            }],
        };
        g.insert_entry(prefix, entry);
    }

    #[test]
    fn exhaustion_sheds_cold_trie_snapshots_before_failing() {
        // a snapshot whose publisher is gone pins 2 of the 3 blocks;
        // a new session needing a 3rd block must evict it, not die
        let pool = PagedPool::new(2, 3, 2);
        let mut kv = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut kv, 4, 0); // 2 full blocks
        snapshot_into_trie(&pool, &kv, &[9, 9]);
        drop(kv); // only the snapshot's refs remain
        let s = pool.stats();
        assert_eq!((s.in_use, s.trie_entries), (2, 1));
        let mut fresh = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut fresh, 6, 0); // needs all 3 blocks
        let s = pool.stats();
        assert_eq!(s.trie_evictions, 1, "the cold snapshot was shed");
        assert_eq!(s.trie_entries, 0);
        assert_eq!(s.in_use, 3);
        assert_eq!(fresh.len(), 6);
    }

    #[test]
    fn trie_cap_evicts_least_recently_used_entry() {
        let pool = PagedPool::with_trie_cap(2, 16, 2, 2);
        let mut kvs: Vec<PagedHeadKv> = Vec::new();
        for i in 0..3 {
            let mut kv = PagedHeadKv::new(pool.clone(), 2);
            push_n(&mut kv, 2, i * 10);
            kvs.push(kv);
        }
        snapshot_into_trie(&pool, &kvs[0], &[1]);
        snapshot_into_trie(&pool, &kvs[1], &[2]);
        // touching entry [1] makes [2] the LRU victim of the next insert
        assert!(pool.lock().touch_lookup(&[1], &DecodeConfig::default()).is_some());
        snapshot_into_trie(&pool, &kvs[2], &[3]);
        let s = pool.stats();
        assert_eq!((s.trie_entries, s.trie_evictions), (2, 1));
        let mut g = pool.lock();
        assert!(g.touch_lookup(&[2], &DecodeConfig::default()).is_none(), "LRU entry [2] gone");
        assert!(g.touch_lookup(&[1], &DecodeConfig::default()).is_some());
        assert!(g.touch_lookup(&[3], &DecodeConfig::default()).is_some());
    }

    #[test]
    fn trie_entries_are_keyed_on_config_too() {
        let pool = PagedPool::new(2, 16, 2);
        let mut kv = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut kv, 2, 0);
        snapshot_into_trie(&pool, &kv, &[5]);
        let other = DecodeConfig { kv_budget: 7, ..DecodeConfig::default() };
        let mut g = pool.lock();
        assert!(g.touch_lookup(&[5], &DecodeConfig::default()).is_some());
        assert!(
            g.touch_lookup(&[5], &other).is_none(),
            "a different config must miss, not adopt the default-config snapshot"
        );
        assert!(!g.has_entry(&[5], &other), "and may publish its own entry");
    }

    #[test]
    fn reservation_ledger_enforces_the_cap_and_releases() {
        let pool = PagedPool::new(8, 10, 2);
        assert_eq!(pool.session_demand(16, 2, 2), 2 * 2 * (2 + 1));
        assert!(pool.can_reserve(10));
        assert!(pool.try_reserve(6));
        assert!(!pool.can_reserve(5), "6 + 5 > 10");
        assert!(!pool.try_reserve(5));
        assert!(pool.try_reserve(4));
        assert_eq!(pool.stats().reserved, 10);
        pool.release(6);
        assert_eq!(pool.stats().reserved, 4);
        pool.release(100); // over-release saturates, never underflows
        assert_eq!(pool.stats().reserved, 0);
    }

    #[test]
    fn paged_head_matches_contiguous_reference() {
        // same pushes + accumulate + evictions → same slots, scores and
        // attention outputs as HeadKv, block boundaries notwithstanding
        let pool = PagedPool::new(3, 16, 2);
        let mut paged = PagedHeadKv::new(pool, 2);
        let mut flat = HeadKv::new(2);
        for i in 0..8 {
            let f = i as f32;
            let (k, v) = (row(2, f), row(2, -f));
            KvSlots::push(&mut paged, &k, &v, i);
            KvSlots::push(&mut flat, &k, &v, i);
        }
        let srow = [3, -1, 4, 1, -5, 9, 2, 6];
        KvSlots::accumulate(&mut paged, &srow);
        KvSlots::accumulate(&mut flat, &srow);
        assert_eq!(
            KvSlots::evict_lowest(&mut paged, 2),
            KvSlots::evict_lowest(&mut flat, 2)
        );
        assert_eq!(KvSlots::positions(&paged), KvSlots::positions(&flat));
        let q = [0.75, -0.5];
        let (mut sp, mut sf) = (vec![0.0f32; 7], vec![0.0f32; 7]);
        paged.scores_into(&q, &mut sp);
        flat.scores_into(&q, &mut sf);
        assert_eq!(sp, sf);
        let (mut op, mut of) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        paged.attend_into(&sp, &mut op);
        flat.attend_into(&sf, &mut of);
        assert_eq!(op, of);
        let idx = [0usize, 3, 6];
        let (mut gp, mut gf) = (vec![0.0f32; 3], vec![0.0f32; 3]);
        paged.dots_into(&q, &idx, 0.5, &mut gp);
        flat.dots_into(&q, &idx, 0.5, &mut gf);
        assert_eq!(gp, gf);
        let (mut ap, mut af) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        paged.attend_indexed_into(&gp, &idx, &mut ap);
        flat.attend_indexed_into(&gf, &idx, &mut af);
        assert_eq!(ap, af);
    }

    #[test]
    fn shared_partial_tail_copies_on_write() {
        let pool = PagedPool::new(4, 8, 2);
        let mut a = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut a, 3, 0); // one partial block (fill 3)
        // share a's chain, as a trie snapshot would: bump refs, hand b
        // the same slots with an un-owned tail
        let tail = a.tail.expect("partial block is the tail");
        let mut b = PagedHeadKv::new(pool.clone(), 2);
        {
            let mut g = pool.lock();
            g.add_refs(tail, a.slots.len());
        }
        b.slots = a.slots.clone();
        b.positions = a.positions.clone();
        b.score = a.score.clone();
        b.tail = Some(tail);
        b.tail_owned = false;
        a.tail_owned = false;
        // b diverges: its append must copy the shared block
        b.push(&row(2, 50.0), &row(2, -50.0), 3);
        let s = pool.stats();
        assert_eq!(s.cow_copies, 1);
        assert_eq!(s.in_use, 2, "original + copied block");
        assert_ne!(b.slots[0].block, a.slots[0].block, "b repointed off the shared block");
        // a's view is untouched; b sees the shared rows plus its own
        let q = [1.0, 0.0];
        let mut sa = vec![0.0f32; 3];
        a.scores_into(&q, &mut sa);
        assert_eq!(sa, [0.0, 1.0, 2.0]);
        let mut sb = vec![0.0f32; 4];
        b.scores_into(&q, &mut sb);
        assert_eq!(sb, [0.0, 1.0, 2.0, 50.0]);
        // a diverging later also CoWs (its tail went shared at publish)
        a.push(&row(2, 9.0), &row(2, -9.0), 3);
        assert_eq!(pool.stats().cow_copies, 2);
    }

    #[test]
    fn eviction_pins_shared_blocks_and_drops_private_ones() {
        let pool = PagedPool::new(2, 16, 2);
        let mut kv = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut kv, 6, 0); // blocks: [0,1] [2,3] [4,5]
        // pin the first block as a trie snapshot would
        let shared = kv.slots[0].block;
        pool.lock().add_refs(shared, 1);
        // zero scores tie toward the lowest slot — but slots 0 and 1
        // live in the pinned block, so slot 2 goes first
        assert_eq!(KvSlots::evict_lowest(&mut kv, 1), Some(2));
        assert_eq!(KvSlots::positions(&kv), &[0, 1, 3, 4, 5]);
        // nothing evictable → None (only pinned + protected slots left)
        let mut small = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut small, 2, 10);
        let b = small.slots[0].block;
        pool.lock().add_refs(b, 1);
        assert_eq!(KvSlots::evict_lowest(&mut small, 1), None);
        pool.lock().sub_refs(b, 1);
    }

    #[test]
    fn evicting_a_whole_block_returns_it_to_the_free_list() {
        let pool = PagedPool::new(1, 8, 2);
        let mut kv = PagedHeadKv::new(pool.clone(), 2);
        push_n(&mut kv, 3, 0); // one block per row
        // evicting the newest-but-protected rows is impossible; evict
        // slot 0 (its own block) and confirm the pool reclaims it
        assert_eq!(KvSlots::evict_lowest(&mut kv, 1), Some(0));
        assert_eq!(pool.stats().in_use, 2);
        assert_eq!(kv.len(), 2);
        // the tail block still belongs to the newest slot, so pushes
        // keep working and reuse the freed block
        kv.push(&row(2, 7.0), &row(2, 7.0), 3);
        assert_eq!(pool.stats().in_use, 3);
        assert_eq!(pool.stats().allocated_total, 4);
    }
}
