//! Per-layer, per-head K/V storage for autoregressive decode, with a
//! **sparsity-aware eviction policy**: every decode step the incremental
//! SPLS predictor scores each cached token's importance to the new query
//! row (`|PAM|` magnitudes, normalized per row); the scores accumulate
//! per cached token, and when a head exceeds its budget it drops the
//! token with the lowest cumulative score — SpAtten's cascade token
//! pruning driven by the prediction we already compute, instead of by
//! post-hoc softmax probabilities.
//!
//! The `recent` newest tokens (which always include the current step's
//! diagonal) are never evicted: the causal diagonal is always visible
//! and usually dominant (paper §III / Fig 3c), and a recency floor is
//! what keeps eviction from starving the local window the SPLS
//! similarity scheme depends on.
//!
//! Without scores (dense decode), ties resolve to the lowest slot, so a
//! budgeted dense cache degrades gracefully to a sliding window.
//!
//! [`KvSlots`] is the storage interface the decode step reads K/V
//! through: the contiguous [`HeadKv`] here and the block-table
//! [`PagedHeadKv`](crate::decode::paged::PagedHeadKv) both implement
//! it, and both are required to preserve the **exact per-slot
//! accumulation order** of the attention kernels below — which is what
//! makes a single-session paged decode bit-identical to the contiguous
//! cache (`tests/integration_paged.rs`).

use crate::model::sparse_kernels::{axpy_prob, dot_qk};
use crate::util::mat::MatF;

/// The K/V storage interface of one attention head, as consumed by the
/// decode step (`decode::step`). Slots are logical token positions in
/// insertion order; implementations own the physical layout (contiguous
/// rows, paged blocks, …) but must run the attention accumulations in
/// ascending-slot order with the same per-element chains as the
/// reference kernels, so every implementation is bit-identical to
/// [`HeadKv`] given the same push/evict history.
pub trait KvSlots {
    /// Number of cached token slots.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append the new token's K and V rows (eviction score starts at 0).
    fn push(&mut self, k_row: &[f32], v_row: &[f32], pos: usize);

    /// Original absolute positions of the cached slots, in slot order.
    fn positions(&self) -> &[usize];

    /// Fold one predicted attention row into the cumulative eviction
    /// scores (row-max-normalized `|PAM|` magnitudes).
    fn accumulate(&mut self, row: &[i32]);

    /// Evict the lowest-cumulative-score evictable slot outside the
    /// protected `recent` tail; returns its index, or `None` when no
    /// slot is evictable.
    fn evict_lowest(&mut self, recent: usize) -> Option<usize>;

    /// `srow[c] = q · k_c` for every slot `c` (zero-skip on `q`,
    /// k-ascending accumulation — see [`scores_row`]).
    fn scores_into(&self, q: &[f32], srow: &mut [f32]);

    /// `orow += Σ_c s[c] · v_c` in ascending slot order, zero-skip on
    /// `s[c]` (see [`attend_row`]); `orow` must be pre-zeroed.
    fn attend_into(&self, s: &[f32], orow: &mut [f32]);

    /// Gated SDDMM: `s[j] = dot_qk(q, k_idx[j]) · scale` over the kept
    /// slots only.
    fn dots_into(&self, q: &[f32], idx: &[usize], scale: f32, s: &mut [f32]);

    /// Gated AV product: `orow += s[j] · v_idx[j]` (zero-skip on
    /// `s[j]`); `orow` must be pre-zeroed.
    fn attend_indexed_into(&self, s: &[f32], idx: &[usize], orow: &mut [f32]);
}

/// `srow[c] = Σ_k q[k] · K[c, k]` over row-major cached key slots — the
/// reference's `matmul(q, Kᵀ)` with the identical k-ascending,
/// zero-skip-on-q accumulation chain per element, minus the per-step
/// K-matrix clone and transpose.
pub(crate) fn scores_row(q: &[f32], kdata: &[f32], dh: usize, srow: &mut [f32]) {
    for (c, o) in srow.iter_mut().enumerate() {
        *o = dot_qk(q, &kdata[c * dh..(c + 1) * dh]);
    }
}

/// `orow[c] = Σ_k s[k] · V[k, c]` (zero-skip on the masked scores, which
/// is where the SPLS keep-mask's zeros actually save work) — the
/// reference's `matmul(s, V)`; `orow` must be zeroed.
pub(crate) fn attend_row(s: &[f32], vdata: &[f32], dh: usize, orow: &mut [f32]) {
    for (k, &av) in s.iter().enumerate() {
        if av == 0.0 {
            continue;
        }
        axpy_prob(av, &vdata[k * dh..(k + 1) * dh], orow);
    }
}

/// One attention head's append-only K/V cache plus eviction state.
#[derive(Clone, Debug)]
pub struct HeadKv {
    dh: usize,
    /// Row-major `len × dh` key rows.
    k: Vec<f32>,
    /// Row-major `len × dh` value rows.
    v: Vec<f32>,
    /// Original absolute position of each cached slot (ascending).
    positions: Vec<usize>,
    /// Cumulative SPLS column-importance score per cached slot.
    score: Vec<f64>,
}

impl HeadKv {
    pub fn new(dh: usize) -> Self {
        assert!(dh >= 1);
        Self { dh, k: Vec::new(), v: Vec::new(), positions: Vec::new(), score: Vec::new() }
    }

    /// Number of cached token slots.
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    /// Original positions of the cached slots, in slot order.
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// Cumulative importance scores, in slot order.
    pub fn scores(&self) -> &[f64] {
        &self.score
    }

    /// Append the new token's K and V rows (score starts at 0).
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        assert_eq!(k_row.len(), self.dh);
        assert_eq!(v_row.len(), self.dh);
        self.k.extend_from_slice(k_row);
        self.v.extend_from_slice(v_row);
        self.positions.push(pos);
        self.score.push(0.0);
    }

    /// The cached keys as a `len × dh` matrix (copies; the decode hot
    /// path reads [`HeadKv::k_data`] instead).
    pub fn k_mat(&self) -> MatF {
        MatF::from_vec(self.len(), self.dh, self.k.clone())
    }

    /// The cached values as a `len × dh` matrix (copying sibling of
    /// [`HeadKv::v_data`]).
    pub fn v_mat(&self) -> MatF {
        MatF::from_vec(self.len(), self.dh, self.v.clone())
    }

    /// Zero-copy view of the cached keys, row-major `len × dh` — the
    /// decode step computes `q · Kᵀ` directly against this (exact
    /// prefill accumulation order, no per-step matrix clone).
    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    /// Zero-copy view of the cached values, row-major `len × dh`.
    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    /// Fold one predicted attention row into the cumulative scores:
    /// each slot gains its normalized `|PAM|` magnitude (row-max
    /// normalization keeps steps comparable across the per-row
    /// requantization scales).
    pub fn accumulate(&mut self, row: &[i32]) {
        assert_eq!(row.len(), self.len(), "score row must cover the cache");
        let max = row.iter().map(|r| r.unsigned_abs()).max().unwrap_or(0).max(1) as f64;
        for (s, &r) in self.score.iter_mut().zip(row) {
            *s += r.unsigned_abs() as f64 / max;
        }
    }

    /// Evict the lowest-cumulative-score slot outside the protected
    /// `recent` tail (ties toward the lowest slot = oldest token).
    /// Returns the removed slot index so the caller can keep parallel
    /// state (the incremental predictor) aligned, or `None` when every
    /// slot is inside the protected window.
    pub fn evict_lowest(&mut self, recent: usize) -> Option<usize> {
        let n = self.len();
        let protected = recent.max(1);
        if n <= protected {
            return None;
        }
        let lim = n - protected;
        let mut best = 0usize;
        for i in 1..lim {
            if self.score[i] < self.score[best] {
                best = i;
            }
        }
        self.remove(best);
        Some(best)
    }

    fn remove(&mut self, slot: usize) {
        let d = self.dh;
        self.k.drain(slot * d..(slot + 1) * d);
        self.v.drain(slot * d..(slot + 1) * d);
        self.positions.remove(slot);
        self.score.remove(slot);
    }
}

impl KvSlots for HeadKv {
    fn len(&self) -> usize {
        HeadKv::len(self)
    }

    fn push(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        HeadKv::push(self, k_row, v_row, pos);
    }

    fn positions(&self) -> &[usize] {
        HeadKv::positions(self)
    }

    fn accumulate(&mut self, row: &[i32]) {
        HeadKv::accumulate(self, row);
    }

    fn evict_lowest(&mut self, recent: usize) -> Option<usize> {
        HeadKv::evict_lowest(self, recent)
    }

    fn scores_into(&self, q: &[f32], srow: &mut [f32]) {
        scores_row(q, &self.k, self.dh, srow);
    }

    fn attend_into(&self, s: &[f32], orow: &mut [f32]) {
        attend_row(s, &self.v, self.dh, orow);
    }

    fn dots_into(&self, q: &[f32], idx: &[usize], scale: f32, s: &mut [f32]) {
        let d = self.dh;
        for (o, &slot) in s.iter_mut().zip(idx) {
            *o = dot_qk(q, &self.k[slot * d..(slot + 1) * d]) * scale;
        }
    }

    fn attend_indexed_into(&self, s: &[f32], idx: &[usize], orow: &mut [f32]) {
        let d = self.dh;
        for (&pv, &slot) in s.iter().zip(idx) {
            if pv == 0.0 {
                continue;
            }
            axpy_prob(pv, &self.v[slot * d..(slot + 1) * d], orow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(n: usize) -> HeadKv {
        let mut kv = HeadKv::new(2);
        for i in 0..n {
            let f = i as f32;
            kv.push(&[f, f + 0.5], &[-f, f * 2.0], i);
        }
        kv
    }

    #[test]
    fn push_preserves_row_layout_and_positions() {
        let kv = filled(3);
        assert_eq!(kv.len(), 3);
        let k = kv.k_mat();
        let v = kv.v_mat();
        assert_eq!((k.rows, k.cols), (3, 2));
        assert_eq!(k.row(1), &[1.0, 1.5]);
        assert_eq!(v.row(2), &[-2.0, 4.0]);
        assert_eq!(kv.positions(), &[0, 1, 2]);
    }

    #[test]
    fn accumulate_normalizes_by_row_max() {
        let mut kv = filled(3);
        kv.accumulate(&[-10, 5, 0]);
        let s = kv.scores();
        assert!((s[0] - 1.0).abs() < 1e-12);
        assert!((s[1] - 0.5).abs() < 1e-12);
        assert_eq!(s[2], 0.0);
        // second row stacks on top
        kv.accumulate(&[0, 4, 4]);
        assert!((kv.scores()[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_lowest_score_outside_recent_window() {
        let mut kv = filled(5);
        kv.accumulate(&[8, 1, 6, 2, 0]); // slot 4 lowest but recent-protected
        let gone = kv.evict_lowest(2).expect("over-budget head must evict");
        // evictable slots are 0..3; slot 1 has the lowest score there
        assert_eq!(gone, 1);
        assert_eq!(kv.positions(), &[0, 2, 3, 4]);
        assert_eq!(kv.k_mat().row(1), &[2.0, 2.5]);
    }

    #[test]
    fn zero_scores_degrade_to_sliding_window() {
        // dense decode never accumulates: ties resolve to the oldest slot
        let mut kv = filled(4);
        assert_eq!(kv.evict_lowest(1), Some(0));
        assert_eq!(kv.positions(), &[1, 2, 3]);
        assert_eq!(kv.evict_lowest(1), Some(0));
        assert_eq!(kv.positions(), &[2, 3]);
    }

    #[test]
    fn recent_window_blocks_eviction_entirely() {
        let mut kv = filled(3);
        assert_eq!(kv.evict_lowest(3), None);
        assert_eq!(kv.evict_lowest(8), None, "window larger than cache");
        assert_eq!(kv.len(), 3);
        // recent = 0 still protects the newest slot (the diagonal)
        kv.accumulate(&[5, 5, 0]);
        assert!(kv.evict_lowest(0).is_some());
        assert_eq!(kv.len(), 2);
        assert!(kv.positions().contains(&2), "diagonal slot survived");
    }

    #[test]
    fn budget_boundary_evicts_only_past_the_exact_budget() {
        // Pins the decode engine's eviction contract (`while len > budget`)
        // at the exact budget == seq_len boundary, so paged eviction can
        // be diffed against this contiguous behavior.
        let budget = 6usize;
        let mut kv = HeadKv::new(2);
        let mut evictions = 0usize;
        for i in 0..budget {
            let f = i as f32;
            kv.push(&[f, f], &[f, -f], i);
            while kv.len() > budget {
                kv.evict_lowest(2).expect("over budget must evict");
                evictions += 1;
            }
        }
        assert_eq!(evictions, 0, "len == budget is in-budget: no eviction");
        assert_eq!(kv.positions(), &[0, 1, 2, 3, 4, 5]);
        // one token past the boundary: exactly one eviction, oldest slot
        // (zero scores tie toward the lowest slot)
        kv.push(&[9.0, 9.0], &[9.0, 9.0], budget);
        while kv.len() > budget {
            assert_eq!(kv.evict_lowest(2), Some(0));
            evictions += 1;
        }
        assert_eq!(evictions, 1);
        assert_eq!(kv.positions(), &[1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn zero_scores_sliding_window_pins_surviving_positions() {
        // The dense-mode degradation path end to end: never accumulating
        // scores turns a budgeted cache into an exact sliding window.
        let budget = 4usize;
        let mut kv = HeadKv::new(2);
        for i in 0..12 {
            let f = i as f32;
            kv.push(&[f, 0.0], &[0.0, f], i);
            while kv.len() > budget {
                assert_eq!(kv.evict_lowest(1), Some(0), "ties fall to the oldest slot");
            }
        }
        assert_eq!(kv.positions(), &[8, 9, 10, 11]);
    }

    #[test]
    fn kv_slots_attention_ops_match_the_reference_loops() {
        // filled(3): k_i = [i, i+0.5], v_i = [-i, 2i]
        let kv = filled(3);
        let q = [0.5, 0.0]; // exercises the zero-skip-on-q chain
        let mut srow = [0.0f32; 3];
        kv.scores_into(&q, &mut srow);
        assert_eq!(srow, [0.0, 0.5, 1.0]);

        let s = [0.25, 0.0, 0.5];
        let mut orow = [0.0f32; 2];
        kv.attend_into(&s, &mut orow);
        assert_eq!(orow, [-1.0, 2.0]);

        let idx = [0usize, 2];
        let mut sg = [0.0f32; 2];
        kv.dots_into(&q, &idx, 2.0, &mut sg);
        assert_eq!(sg, [0.0, 2.0]);
        let mut og = [0.0f32; 2];
        kv.attend_indexed_into(&sg, &idx, &mut og);
        assert_eq!(og, [-4.0, 8.0]);
    }

    #[test]
    fn scores_follow_surviving_slots_after_eviction() {
        let mut kv = filled(4);
        kv.accumulate(&[1, 9, 9, 9]);
        assert_eq!(kv.evict_lowest(1), Some(0));
        // the surviving scores kept their slots' values
        for &s in kv.scores() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        // and a fresh accumulate still lines up with the new layout
        kv.accumulate(&[2, 0, 2]);
        assert!((kv.scores()[1] - 1.0).abs() < 1e-12);
    }
}
