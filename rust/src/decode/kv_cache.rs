//! Per-layer, per-head K/V storage for autoregressive decode, with a
//! **sparsity-aware eviction policy**: every decode step the incremental
//! SPLS predictor scores each cached token's importance to the new query
//! row (`|PAM|` magnitudes, normalized per row); the scores accumulate
//! per cached token, and when a head exceeds its budget it drops the
//! token with the lowest cumulative score — SpAtten's cascade token
//! pruning driven by the prediction we already compute, instead of by
//! post-hoc softmax probabilities.
//!
//! The `recent` newest tokens (which always include the current step's
//! diagonal) are never evicted: the causal diagonal is always visible
//! and usually dominant (paper §III / Fig 3c), and a recency floor is
//! what keeps eviction from starving the local window the SPLS
//! similarity scheme depends on.
//!
//! Without scores (dense decode), ties resolve to the lowest slot, so a
//! budgeted dense cache degrades gracefully to a sliding window.

use crate::util::mat::MatF;

/// One attention head's append-only K/V cache plus eviction state.
#[derive(Clone, Debug)]
pub struct HeadKv {
    dh: usize,
    /// Row-major `len × dh` key rows.
    k: Vec<f32>,
    /// Row-major `len × dh` value rows.
    v: Vec<f32>,
    /// Original absolute position of each cached slot (ascending).
    positions: Vec<usize>,
    /// Cumulative SPLS column-importance score per cached slot.
    score: Vec<f64>,
}

impl HeadKv {
    pub fn new(dh: usize) -> Self {
        assert!(dh >= 1);
        Self { dh, k: Vec::new(), v: Vec::new(), positions: Vec::new(), score: Vec::new() }
    }

    /// Number of cached token slots.
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    /// Original positions of the cached slots, in slot order.
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// Cumulative importance scores, in slot order.
    pub fn scores(&self) -> &[f64] {
        &self.score
    }

    /// Append the new token's K and V rows (score starts at 0).
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32], pos: usize) {
        assert_eq!(k_row.len(), self.dh);
        assert_eq!(v_row.len(), self.dh);
        self.k.extend_from_slice(k_row);
        self.v.extend_from_slice(v_row);
        self.positions.push(pos);
        self.score.push(0.0);
    }

    /// The cached keys as a `len × dh` matrix (copies; the decode hot
    /// path reads [`HeadKv::k_data`] instead).
    pub fn k_mat(&self) -> MatF {
        MatF::from_vec(self.len(), self.dh, self.k.clone())
    }

    /// The cached values as a `len × dh` matrix (copying sibling of
    /// [`HeadKv::v_data`]).
    pub fn v_mat(&self) -> MatF {
        MatF::from_vec(self.len(), self.dh, self.v.clone())
    }

    /// Zero-copy view of the cached keys, row-major `len × dh` — the
    /// decode step computes `q · Kᵀ` directly against this (exact
    /// prefill accumulation order, no per-step matrix clone).
    pub fn k_data(&self) -> &[f32] {
        &self.k
    }

    /// Zero-copy view of the cached values, row-major `len × dh`.
    pub fn v_data(&self) -> &[f32] {
        &self.v
    }

    /// Fold one predicted attention row into the cumulative scores:
    /// each slot gains its normalized `|PAM|` magnitude (row-max
    /// normalization keeps steps comparable across the per-row
    /// requantization scales).
    pub fn accumulate(&mut self, row: &[i32]) {
        assert_eq!(row.len(), self.len(), "score row must cover the cache");
        let max = row.iter().map(|r| r.unsigned_abs()).max().unwrap_or(0).max(1) as f64;
        for (s, &r) in self.score.iter_mut().zip(row) {
            *s += r.unsigned_abs() as f64 / max;
        }
    }

    /// Evict the lowest-cumulative-score slot outside the protected
    /// `recent` tail (ties toward the lowest slot = oldest token).
    /// Returns the removed slot index so the caller can keep parallel
    /// state (the incremental predictor) aligned, or `None` when every
    /// slot is inside the protected window.
    pub fn evict_lowest(&mut self, recent: usize) -> Option<usize> {
        let n = self.len();
        let protected = recent.max(1);
        if n <= protected {
            return None;
        }
        let lim = n - protected;
        let mut best = 0usize;
        for i in 1..lim {
            if self.score[i] < self.score[best] {
                best = i;
            }
        }
        self.remove(best);
        Some(best)
    }

    fn remove(&mut self, slot: usize) {
        let d = self.dh;
        self.k.drain(slot * d..(slot + 1) * d);
        self.v.drain(slot * d..(slot + 1) * d);
        self.positions.remove(slot);
        self.score.remove(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(n: usize) -> HeadKv {
        let mut kv = HeadKv::new(2);
        for i in 0..n {
            let f = i as f32;
            kv.push(&[f, f + 0.5], &[-f, f * 2.0], i);
        }
        kv
    }

    #[test]
    fn push_preserves_row_layout_and_positions() {
        let kv = filled(3);
        assert_eq!(kv.len(), 3);
        let k = kv.k_mat();
        let v = kv.v_mat();
        assert_eq!((k.rows, k.cols), (3, 2));
        assert_eq!(k.row(1), &[1.0, 1.5]);
        assert_eq!(v.row(2), &[-2.0, 4.0]);
        assert_eq!(kv.positions(), &[0, 1, 2]);
    }

    #[test]
    fn accumulate_normalizes_by_row_max() {
        let mut kv = filled(3);
        kv.accumulate(&[-10, 5, 0]);
        let s = kv.scores();
        assert!((s[0] - 1.0).abs() < 1e-12);
        assert!((s[1] - 0.5).abs() < 1e-12);
        assert_eq!(s[2], 0.0);
        // second row stacks on top
        kv.accumulate(&[0, 4, 4]);
        assert!((kv.scores()[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_lowest_score_outside_recent_window() {
        let mut kv = filled(5);
        kv.accumulate(&[8, 1, 6, 2, 0]); // slot 4 lowest but recent-protected
        let gone = kv.evict_lowest(2).expect("over-budget head must evict");
        // evictable slots are 0..3; slot 1 has the lowest score there
        assert_eq!(gone, 1);
        assert_eq!(kv.positions(), &[0, 2, 3, 4]);
        assert_eq!(kv.k_mat().row(1), &[2.0, 2.5]);
    }

    #[test]
    fn zero_scores_degrade_to_sliding_window() {
        // dense decode never accumulates: ties resolve to the oldest slot
        let mut kv = filled(4);
        assert_eq!(kv.evict_lowest(1), Some(0));
        assert_eq!(kv.positions(), &[1, 2, 3]);
        assert_eq!(kv.evict_lowest(1), Some(0));
        assert_eq!(kv.positions(), &[2, 3]);
    }

    #[test]
    fn recent_window_blocks_eviction_entirely() {
        let mut kv = filled(3);
        assert_eq!(kv.evict_lowest(3), None);
        assert_eq!(kv.evict_lowest(8), None, "window larger than cache");
        assert_eq!(kv.len(), 3);
        // recent = 0 still protects the newest slot (the diagonal)
        kv.accumulate(&[5, 5, 0]);
        assert!(kv.evict_lowest(0).is_some());
        assert_eq!(kv.len(), 2);
        assert!(kv.positions().contains(&2), "diagonal slot survived");
    }

    #[test]
    fn scores_follow_surviving_slots_after_eviction() {
        let mut kv = filled(4);
        kv.accumulate(&[1, 9, 9, 9]);
        assert_eq!(kv.evict_lowest(1), Some(0));
        // the surviving scores kept their slots' values
        for &s in kv.scores() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        // and a fresh accumulate still lines up with the new layout
        kv.accumulate(&[2, 0, 2]);
        assert!((kv.scores()[1] - 1.0).abs() < 1e-12);
    }
}
