//! Autoregressive decode engine (the serving workload the paper's
//! causal rows — GPT-2, Llama2-7b, Bloom-7b — actually run): token-by-
//! token generation over a **sparsity-aware KV cache**.
//!
//! * [`kv_cache`] — per-layer, per-head append-only K/V storage whose
//!   eviction is driven by cumulative SPLS column-importance scores
//!   (SpAtten-style cascade token pruning from the prediction we
//!   already compute), with the recent window always retained;
//! * [`incremental`] — step-wise SPLS: predict the new query row's
//!   sparsity against the cached prefix in O(prefix) via local
//!   similarity to the previous step's row, memoizable in
//!   `spls::plan_cache` under decode buckets;
//! * [`step`] — the `decode_step` forward (single-row attention against
//!   the pruned cache, bit-identical to causal prefill at unbounded
//!   budget) behind [`DecodeEngine`] / [`DecodeState`];
//! * [`generate`] — greedy + seeded top-k generation, sliceable for the
//!   serving tier's continuous decode batching
//!   (`coordinator::Server::serve_generate`);
//! * [`paged`] — the multi-session block-pool KV backend: fixed-size
//!   refcounted blocks, prefix-trie sharing with copy-on-write
//!   divergence, and a paged decode session whose single-session output
//!   is bit-identical to the contiguous [`DecodeState`].

pub mod generate;
pub mod incremental;
pub mod kv_cache;
pub mod paged;
pub mod step;

pub use generate::{generate, GenResult, GenSession, Sampler, Sampling};
pub use incremental::{
    topk_keep_with_diagonal, HeadPredictor, HeadStepPlan, LayerStepPlan, StepPlan,
};
pub use kv_cache::{HeadKv, KvSlots};
pub use paged::{PagedDecodeState, PagedHeadKv, PagedPool, PoolExhausted, PoolStats};
pub use step::{DecodeConfig, DecodeEngine, DecodeMode, DecodeState, DecodeStateOf, DecodeStats};
