//! Deterministic generation on top of the decode engine: greedy
//! decoding and seeded top-k sampling (`util::rng`, the cross-language
//! xoshiro256++), driven either to completion ([`generate`]) or in
//! bounded slices ([`GenSession::run_steps`]) — the unit the serving
//! tier's continuous decode batching dispatches onto the replica pool.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::decode::paged::{PagedDecodeState, PagedPool};
use crate::decode::step::{DecodeConfig, DecodeEngine, DecodeState, DecodeStats};
use crate::model::tensor::argmax;
use crate::spls::plan_cache::SharedPlanCache;
use crate::util::rng::Xoshiro256pp;

/// Token-selection policy. Both variants are fully deterministic:
/// greedy ties resolve to the lower token id (argmax convention), and
/// top-k draws from a session-owned seeded xoshiro256++ stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// Stateful sampler (owns the RNG stream for top-k).
pub struct Sampler {
    kind: Sampling,
    rng: Option<Xoshiro256pp>,
}

impl Sampler {
    pub fn new(kind: Sampling) -> Self {
        let rng = match kind {
            Sampling::Greedy => None,
            Sampling::TopK { seed, .. } => Some(Xoshiro256pp::new(seed)),
        };
        Self { kind, rng }
    }

    /// Pick the next token from a logits vector.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        match self.kind {
            Sampling::Greedy => argmax(logits) as i32,
            Sampling::TopK { k, temperature, .. } => {
                let k = k.clamp(1, logits.len());
                let t = temperature.max(1e-3) as f64;
                // rank descending, ties toward the lower token id
                // (total_cmp: panic-free even on a NaN logit)
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
                let top = &idx[..k];
                // softmax over the shortlist in f64, then one uniform draw
                let mx = logits[top[0]] as f64 / t;
                let weights: Vec<f64> =
                    top.iter().map(|&i| (logits[i] as f64 / t - mx).exp()).collect();
                let total: f64 = weights.iter().sum();
                let rng = self.rng.as_mut().expect("top-k sampler owns an RNG");
                let mut u = rng.f64() * total;
                for (i, &w) in top.iter().zip(&weights) {
                    if u < w {
                        return *i as i32;
                    }
                    u -= w;
                }
                top[k - 1] as i32 // numeric edge: fall back to the last
            }
        }
    }

    /// Advance the RNG stream as if `n` tokens had already been
    /// sampled. [`Sampler::sample`] draws exactly one uniform per
    /// sampled token, so skipping `n` draws puts a fresh sampler in the
    /// same stream position as one that produced `n` tokens — the
    /// session-migration primitive (a no-op for greedy).
    pub fn skip(&mut self, n: usize) {
        if let Some(rng) = self.rng.as_mut() {
            for _ in 0..n {
                rng.f64();
            }
        }
    }
}

/// The session's KV backend: a private contiguous cache, or a paged
/// session over a server-shared block pool (possibly attached to a
/// published prompt prefix). Single-session behavior is bit-identical
/// across the two (`decode::paged` module docs).
enum SessionState {
    Contiguous(DecodeState),
    Paged(PagedDecodeState),
}

impl SessionState {
    fn push(&mut self, token: i32) -> Vec<f32> {
        match self {
            SessionState::Contiguous(s) => s.push(token),
            SessionState::Paged(s) => s.push(token),
        }
    }

    fn stats(&self) -> DecodeStats {
        match self {
            SessionState::Contiguous(s) => s.stats(),
            SessionState::Paged(s) => s.stats(),
        }
    }
}

/// One generation session: prompt prefill (token-by-token through the
/// same decode path, building the KV cache) followed by sampled
/// continuation, resumable in slices of decode steps.
pub struct GenSession {
    state: SessionState,
    prompt: Vec<i32>,
    fed: usize,
    last_logits: Option<Vec<f32>>,
    generated: Vec<i32>,
    max_new: usize,
    sampler: Sampler,
    /// Wall time spent pushing prompt tokens (ESACT-style stage
    /// accounting, surfaced per request in trace spans).
    prefill_time: Duration,
    /// Wall time spent sampling + pushing generated tokens.
    decode_time: Duration,
}

impl GenSession {
    pub fn new(
        eng: Arc<DecodeEngine>,
        cfg: DecodeConfig,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: Sampling,
    ) -> Self {
        assert!(!prompt.is_empty(), "generation needs a non-empty prompt");
        Self {
            state: SessionState::Contiguous(DecodeState::new(eng, cfg)),
            prompt,
            fed: 0,
            last_logits: None,
            generated: Vec::with_capacity(max_new),
            max_new,
            sampler: Sampler::new(sampling),
            prefill_time: Duration::ZERO,
            decode_time: Duration::ZERO,
        }
    }

    /// Paged session over a shared block pool. The prompt is
    /// `prefix ++ tail`: the prefix is declared to the pool's trie (a
    /// hit maps the published blocks and skips those forward passes; a
    /// miss publishes them once prefilled) and the tail must be
    /// non-empty so the session always produces sampling logits.
    pub fn new_paged(
        eng: Arc<DecodeEngine>,
        cfg: DecodeConfig,
        pool: &PagedPool,
        prefix: &[i32],
        tail: Vec<i32>,
        max_new: usize,
        sampling: Sampling,
    ) -> Self {
        assert!(!tail.is_empty(), "paged generation needs a non-empty prompt tail");
        let state = PagedDecodeState::new(eng, cfg, pool).with_prefix(prefix);
        let fed = if state.attached() { prefix.len() } else { 0 };
        let mut prompt = prefix.to_vec();
        prompt.extend_from_slice(&tail);
        Self {
            state: SessionState::Paged(state),
            prompt,
            fed,
            last_logits: None,
            generated: Vec::with_capacity(max_new),
            max_new,
            sampler: Sampler::new(sampling),
            prefill_time: Duration::ZERO,
            decode_time: Duration::ZERO,
        }
    }

    /// Route this session's step planning through a shared plan cache.
    pub fn with_plan_cache(mut self, cache: SharedPlanCache) -> Self {
        self.state = match self.state {
            SessionState::Contiguous(s) => SessionState::Contiguous(s.with_plan_cache(cache)),
            SessionState::Paged(s) => SessionState::Paged(s.with_plan_cache(cache)),
        };
        self
    }

    /// Whether the next step still feeds prompt tokens (the continuous
    /// batcher dispatches prefilling sessions in chunked slices).
    pub fn prefilling(&self) -> bool {
        self.fed < self.prompt.len()
    }

    /// Whether this session's declared prefix was served from the pool.
    pub fn attached_prefix(&self) -> bool {
        match &self.state {
            SessionState::Contiguous(_) => false,
            SessionState::Paged(s) => s.attached(),
        }
    }

    /// All tokens generated so far (excluding the prompt).
    pub fn generated(&self) -> &[i32] {
        &self.generated
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    pub fn stats(&self) -> DecodeStats {
        self.state.stats()
    }

    /// Logits the next sample will draw from (None before prefill).
    pub fn last_logits(&self) -> Option<&[f32]> {
        self.last_logits.as_deref()
    }

    /// Fast-forward the sampling RNG past `n` already-emitted tokens.
    /// Used when migrating a faulted session to a fresh replica: the
    /// rebuilt session prefills `original prompt ++ emitted tokens`,
    /// then this aligns its sampler with the stream position the dead
    /// session had reached, so the continuation is bit-identical to an
    /// unfaulted run (the decode forward is deterministic and each
    /// sampled token consumes exactly one draw).
    pub fn fast_forward_sampling(&mut self, n: usize) {
        self.sampler.skip(n);
    }

    /// Run up to `n` decode steps (prompt tokens count as steps);
    /// returns the tokens generated during this slice. The final
    /// sampled token is not pushed back through the model — the
    /// session is `done` the moment `max_new` tokens exist.
    pub fn run_steps(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::new();
        for _ in 0..n {
            if self.done() {
                break;
            }
            let t0 = Instant::now();
            if self.fed < self.prompt.len() {
                let t = self.prompt[self.fed];
                self.fed += 1;
                self.last_logits = Some(self.state.push(t));
                self.prefill_time += t0.elapsed();
            } else {
                let logits = self.last_logits.as_ref().expect("prefill precedes sampling");
                let t = self.sampler.sample(logits);
                self.generated.push(t);
                out.push(t);
                if !self.done() {
                    self.last_logits = Some(self.state.push(t));
                }
                self.decode_time += t0.elapsed();
            }
        }
        out
    }

    /// Cumulative wall time spent in the two execution phases —
    /// `(prefill, decode)` — across every slice this session has run.
    /// Migration resets the split (the rebuilt session re-prefills),
    /// which matches what its replacement replica actually paid.
    pub fn phase_times(&self) -> (Duration, Duration) {
        (self.prefill_time, self.decode_time)
    }
}

/// Summary of one completed generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub tokens: Vec<i32>,
    pub stats: DecodeStats,
}

/// Drive a session to completion, streaming each generated token to
/// `on_token(index, token)` as it appears.
pub fn generate(
    eng: &Arc<DecodeEngine>,
    cfg: DecodeConfig,
    prompt: &[i32],
    max_new: usize,
    sampling: Sampling,
    mut on_token: impl FnMut(usize, i32),
) -> GenResult {
    let mut session = GenSession::new(Arc::clone(eng), cfg, prompt.to_vec(), max_new, sampling);
    let mut idx = 0usize;
    while !session.done() {
        for t in session.run_steps(1) {
            on_token(idx, t);
            idx += 1;
        }
    }
    GenResult { tokens: session.generated().to_vec(), stats: session.stats() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TinyWeights;

    fn engine() -> Arc<DecodeEngine> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny_weights.bin");
        Arc::new(DecodeEngine::new(Arc::new(TinyWeights::load(&p).unwrap())))
    }

    fn prompt(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let eng = engine();
        let p = prompt(1, 12);
        let run = || {
            generate(&eng, DecodeConfig::default(), &p, 10, Sampling::Greedy, |_, _| {}).tokens
        };
        let a = run();
        assert_eq!(a.len(), 10);
        assert_eq!(a, run(), "greedy must replay bit-identically");
        assert!(a.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn sliced_run_matches_one_shot_run() {
        let eng = engine();
        let p = prompt(2, 10);
        let one = generate(&eng, DecodeConfig::default(), &p, 8, Sampling::Greedy, |_, _| {});
        let mut s =
            GenSession::new(Arc::clone(&eng), DecodeConfig::default(), p, 8, Sampling::Greedy);
        let mut sliced = Vec::new();
        while !s.done() {
            sliced.extend(s.run_steps(3));
        }
        assert_eq!(sliced, one.tokens, "slicing must not change the stream");
    }

    #[test]
    fn topk_sampling_is_seed_deterministic_and_k1_is_greedy() {
        let eng = engine();
        let p = prompt(3, 12);
        let cfg = DecodeConfig::default();
        let sample = |seed| {
            generate(
                &eng,
                cfg,
                &p,
                8,
                Sampling::TopK { k: 4, temperature: 1.0, seed },
                |_, _| {},
            )
            .tokens
        };
        assert_eq!(sample(9), sample(9), "same seed, same stream");
        let greedy = generate(&eng, cfg, &p, 8, Sampling::Greedy, |_, _| {}).tokens;
        let k1 = generate(
            &eng,
            cfg,
            &p,
            8,
            Sampling::TopK { k: 1, temperature: 1.0, seed: 5 },
            |_, _| {},
        )
        .tokens;
        assert_eq!(k1, greedy, "k = 1 collapses to greedy");
    }

    #[test]
    fn on_token_streams_every_generated_token_in_order() {
        let eng = engine();
        let p = prompt(4, 8);
        let mut seen = Vec::new();
        let res = generate(&eng, DecodeConfig::default(), &p, 6, Sampling::Greedy, |i, t| {
            assert_eq!(i, seen.len());
            seen.push(t);
        });
        assert_eq!(seen, res.tokens);
        assert_eq!(res.stats.steps, 8 + 6 - 1, "final token is not pushed back");
    }

    #[test]
    fn paged_session_matches_contiguous_and_attaches_on_replay() {
        let eng = engine();
        let p = prompt(5, 10);
        let one = generate(&eng, DecodeConfig::default(), &p, 8, Sampling::Greedy, |_, _| {});
        let pool = PagedPool::new(8, 256, eng.weights().cfg.d_head());
        let paged = |eng: &Arc<DecodeEngine>| {
            GenSession::new_paged(
                Arc::clone(eng),
                DecodeConfig::default(),
                &pool,
                &p[..4],
                p[4..].to_vec(),
                8,
                Sampling::Greedy,
            )
        };
        let mut s = paged(&eng);
        assert!(!s.attached_prefix(), "cold pool: first session publishes");
        let mut toks = Vec::new();
        while !s.done() {
            toks.extend(s.run_steps(3));
        }
        assert_eq!(toks, one.tokens, "paged must match the contiguous stream");
        // an identical session now attaches, skips the prefix pushes,
        // and still produces the same stream
        let mut s2 = paged(&eng);
        assert!(s2.attached_prefix());
        let mut toks2 = Vec::new();
        while !s2.done() {
            toks2.extend(s2.run_steps(4));
        }
        assert_eq!(toks2, one.tokens);
        assert_eq!(s2.stats().steps, one.stats.steps - 4, "prefix pushes were skipped");
        assert_eq!(pool.stats().prefix_hits, 1);
    }

    #[test]
    fn migrated_session_continues_bit_identically() {
        // the generate leader's migration recipe: rebuild with
        // prompt ++ emitted, reduced max_new, RNG fast-forwarded by the
        // emitted count — the continuation must replay the unfaulted
        // stream exactly, including through sampled (top-k) decode
        let eng = engine();
        let p = prompt(6, 10);
        let cfg = DecodeConfig::default();
        let sampling = Sampling::TopK { k: 4, temperature: 0.9, seed: 11 };
        let want = generate(&eng, cfg, &p, 12, sampling, |_, _| {}).tokens;
        let mut orig = GenSession::new(Arc::clone(&eng), cfg, p.clone(), 12, sampling);
        let mut emitted = Vec::new();
        while emitted.len() < 5 {
            emitted.extend(orig.run_steps(3));
        }
        drop(orig); // the "replica panic": session state is gone
        let mut replay = p.clone();
        replay.extend_from_slice(&emitted);
        let mut migrated =
            GenSession::new(Arc::clone(&eng), cfg, replay, 12 - emitted.len(), sampling);
        migrated.fast_forward_sampling(emitted.len());
        while !migrated.done() {
            emitted.extend(migrated.run_steps(4));
        }
        assert_eq!(emitted, want, "migration must not change the stream");
    }

    #[test]
    fn phase_times_split_prefill_from_decode() {
        let eng = engine();
        let p = prompt(7, 8);
        let mut s =
            GenSession::new(Arc::clone(&eng), DecodeConfig::default(), p, 6, Sampling::Greedy);
        assert_eq!(s.phase_times(), (Duration::ZERO, Duration::ZERO));
        while !s.done() {
            s.run_steps(3);
        }
        let (prefill, decode) = s.phase_times();
        assert!(prefill > Duration::ZERO, "8 prompt pushes were timed");
        assert!(decode > Duration::ZERO, "6 sampled steps were timed");
    }

    #[test]
    fn zero_max_new_is_immediately_done() {
        let eng = engine();
        let mut s = GenSession::new(
            Arc::clone(&eng),
            DecodeConfig::default(),
            vec![1, 2, 3],
            0,
            Sampling::Greedy,
        );
        assert!(s.done());
        assert!(s.run_steps(10).is_empty());
        assert_eq!(s.stats().steps, 0);
    }
}
