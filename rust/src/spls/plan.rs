//! The end-to-end `SparsityPlan`: everything the formal computation
//! phase needs, produced by the prediction phase (paper Fig 5a), plus
//! exact FLOP accounting for dense vs SPLS execution (Figs 1/15).

use rayon::prelude::*;

use crate::config::{ModelConfig, SplsConfig};
use crate::quant::QuantMethod;
use crate::spls::mfi::{ffn_plan, FfnPlan};
use crate::spls::predict;
use crate::spls::qkv::HeadPlan;
use crate::spls::similarity::local_similarity;
use crate::spls::topk::sparsify;
use crate::util::mat::{Mat, MatI};

/// Plan for one transformer layer.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    pub heads: Vec<HeadPlan>,
    pub ffn: FfnPlan,
}

impl LayerPlan {
    pub fn q_sparsity(&self) -> f64 {
        avg(self.heads.iter().map(|h| h.q_sparsity()))
    }

    pub fn kv_sparsity(&self) -> f64 {
        avg(self.heads.iter().map(|h| h.kv_sparsity()))
    }

    pub fn attn_sparsity(&self) -> f64 {
        avg(self.heads.iter().map(|h| h.attn_sparsity()))
    }

    pub fn ffn_sparsity(&self) -> f64 {
        self.ffn.ffn_sparsity()
    }
}

fn avg(it: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut n) = (0.0, 0usize);
    for v in it {
        s += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

/// Build a layer plan from per-head predicted attention matrices.
///
/// `pams[h]` is head h's PAM (L×L int32) — from `predict::predict_attention`
/// on real activations, or synthetic for the analytic benchmarks.
pub fn plan_layer(pams: &[MatI], spls: &SplsConfig) -> LayerPlan {
    assert!(!pams.is_empty());
    // heads are independent — fan out over rayon (§IV-B: per-head
    // prediction is embarrassingly parallel; order is preserved by the
    // indexed parallel iterator so plans stay deterministic)
    let heads: Vec<HeadPlan> = pams
        .par_iter()
        .map(|pam| {
            let (spa, mask) = sparsify(pam, spls.top_k);
            let sim = local_similarity(&spa, spls.window, spls.sim_threshold);
            HeadPlan::new(mask, sim)
        })
        .collect();
    let sims: Vec<_> = heads.iter().map(|h| h.sim.clone()).collect();
    let ffn = ffn_plan(&sims, spls.ffn_threshold);
    LayerPlan { heads, ffn }
}

/// Build a layer plan for a **causal** (decoder) model: the PAM is
/// masked to its lower triangle, top-k operates on the visible prefix,
/// and similarity compares shared prefixes (paper §V-A's GPT-2 /
/// Llama2 / Bloom rows; see `spls::causal`).
pub fn plan_layer_causal(pams: &[MatI], spls: &SplsConfig) -> LayerPlan {
    use crate::spls::causal;
    assert!(!pams.is_empty());
    let heads: Vec<HeadPlan> = pams
        .par_iter()
        .map(|pam| {
            let mut p = pam.clone();
            causal::apply_causal_mask(&mut p);
            let mask = causal::causal_topk_mask(&p, spls.top_k);
            let spa = crate::spls::topk::apply_mask(&p, &mask);
            let sim = causal::causal_local_similarity(&spa, spls.window, spls.sim_threshold);
            HeadPlan::new(mask, sim)
        })
        .collect();
    let sims: Vec<_> = heads.iter().map(|h| h.sim.clone()).collect();
    let ffn = ffn_plan(&sims, spls.ffn_threshold);
    LayerPlan { heads, ffn }
}

/// Build a layer plan directly from embeddings + per-head Wq/Wk weights
/// (the real prediction path through the bit-level unit model).
pub fn plan_layer_from_inputs(
    x: &MatI,
    wq_heads: &[MatI],
    wk_heads: &[MatI],
    spls: &SplsConfig,
    method: QuantMethod,
) -> LayerPlan {
    assert_eq!(wq_heads.len(), wk_heads.len());
    let pams: Vec<MatI> = wq_heads
        .par_iter()
        .zip(wk_heads.par_iter())
        .map(|(wq, wk)| match method {
            QuantMethod::Hlog => predict::predict_attention(x, wq, wk),
            other => {
                // comparison path (Figs 17/18): same pipeline but with a
                // different prediction quantizer
                let quant_mat = |m: &MatI| {
                    Mat::from_vec(
                        m.rows,
                        m.cols,
                        m.data.iter().map(|&v| other.quantize(v)).collect(),
                    )
                };
                let q = int_matmul(&quant_mat(x), &quant_mat(wq));
                let k = int_matmul(&quant_mat(x), &quant_mat(wk));
                let (q8, _) = crate::quant::requantize_sym8(&q.data);
                let (k8, _) = crate::quant::requantize_sym8(&k.data);
                let q8 = Mat::from_vec(q.rows, q.cols, q8);
                let k8 = Mat::from_vec(k.rows, k.cols, k8);
                let q8q = quant_mat(&q8);
                let k8q = quant_mat(&k8);
                int_matmul(&q8q, &k8q.transpose())
            }
        })
        .collect();
    plan_layer(&pams, spls)
}

fn int_matmul(a: &MatI, b: &MatI) -> MatI {
    assert_eq!(a.cols, b.rows);
    let mut out = MatI::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        for k in 0..a.cols {
            let av = a[(r, k)] as i64;
            if av == 0 {
                continue;
            }
            for c in 0..b.cols {
                out[(r, c)] = (out[(r, c)] as i64 + av * b[(k, c)] as i64) as i32;
            }
        }
    }
    out
}

/// FLOP accounting for one transformer layer.
///
/// Convention: one multiply-accumulate = **1 FLOP** (the paper's
/// convention — it is what makes BERT-Large @ L = 512 come out at
/// 167.5 GFLOPs with MHA 38.46% / FFN 61.54%, Fig 1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerFlops {
    pub qkv: f64,
    pub attn: f64,
    pub ffn: f64,
}

impl LayerFlops {
    pub fn total(&self) -> f64 {
        self.qkv + self.attn + self.ffn
    }
}

/// Dense FLOPs of one layer of `cfg`.
///
/// QKV: 3 projections L·D·D plus the output projection L·D·D (the output
/// projection is part of MHA; the paper's "QKV generation" component
/// carries all four L·D·D GEMMs — this split reproduces Fig 1's
/// 38.46% / 61.54% MHA/FFN breakdown for BERT-Large @ 512).
/// Attention: QKᵀ and A·V, each L²·Dh per head.
/// FFN: two GEMMs L·D·F.
pub fn dense_layer_flops(cfg: &ModelConfig) -> LayerFlops {
    let l = cfg.seq_len as f64;
    let d = cfg.d_model as f64;
    let f = cfg.d_ffn as f64;
    LayerFlops {
        qkv: 4.0 * l * d * d,
        attn: 2.0 * l * l * d,
        ffn: 2.0 * l * d * f,
    }
}

/// Dense FLOPs of the whole model.
pub fn dense_model_flops(cfg: &ModelConfig) -> LayerFlops {
    let per = dense_layer_flops(cfg);
    let n = cfg.n_layers as f64;
    LayerFlops { qkv: per.qkv * n, attn: per.attn * n, ffn: per.ffn * n }
}

/// Sparse FLOPs of one layer under measured sparsity fractions.
///
/// * Q generation scales with critical-row fraction; K/V generation with
///   active-column fraction; the output projection scales with the
///   critical fraction (similar rows are recovered, not projected).
/// * Attention scales with the computed-position density (QKᵀ) and the
///   same density for A·V.
/// * FFN scales with computed-token fraction.
pub fn sparse_layer_flops(cfg: &ModelConfig, plan: &LayerPlan) -> LayerFlops {
    let dense = dense_layer_flops(cfg);
    let q_keep = 1.0 - plan.q_sparsity();
    let kv_keep = 1.0 - plan.kv_sparsity();
    let attn_keep = 1.0 - plan.attn_sparsity();
    let ffn_keep = 1.0 - plan.ffn_sparsity();
    // of the 4 L·D·D GEMMs: Q scales q_keep, K and V scale kv_keep,
    // output projection scales q_keep
    let qkv = dense.qkv / 4.0 * (2.0 * q_keep + 2.0 * kv_keep);
    LayerFlops {
        qkv,
        attn: dense.attn * attn_keep,
        ffn: dense.ffn * ffn_keep,
    }
}

/// Energy-equivalent cost of one 8-bit addition relative to one 8-bit
/// MAC (Horowitz ISSCC'14: add ≈ 0.03 pJ, mult+acc ≈ 0.23 pJ). The
/// prediction unit performs *only* additions — this weight is what makes
/// its op count comparable with the formal phase's MAC count, and is why
/// the unit lands at 7.25% of total power (Table II) despite predicting
/// every QK entry.
pub const ADD_COST_VS_MAC: f64 = 0.13;

/// Prediction-phase overhead in MAC-equivalent FLOPs: HLog QK
/// prediction + attention prediction + similarity L1 distances, all
/// addition-only, weighted by [`ADD_COST_VS_MAC`]. This is what makes
/// the *net* reduction of Fig 15 honest.
pub fn prediction_overhead_ops(cfg: &ModelConfig, spls: &SplsConfig) -> f64 {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let dh = cfg.d_head();
    let h = cfg.n_heads;
    // per head: predict Q (L×D × D×Dh) + predict K + predict attention
    // (L×Dh × Dh×L), all as additions through the bit-level unit
    let per_head = predict::prediction_adds(l, d, dh) * 2
        + predict::prediction_adds(l, dh, l);
    // similarity: ≤ L·(w−1) row comparisons × L adds+subs each
    let sim = (l * (spls.window - 1) * l) as u64;
    ((per_head * h as u64 + sim) * cfg.n_layers as u64) as f64 * ADD_COST_VS_MAC
}

/// Whole-model computation reduction under per-layer plans, including
/// prediction overhead. Returns (overall, qkv, attn, ffn) reduction
/// fractions — the quantities plotted in Fig 15.
pub fn computation_reduction(
    cfg: &ModelConfig,
    plans: &[LayerPlan],
) -> (f64, f64, f64, f64) {
    assert_eq!(plans.len(), cfg.n_layers);
    let dense = dense_model_flops(cfg);
    let mut sparse = LayerFlops::default();
    for plan in plans {
        let s = sparse_layer_flops(cfg, plan);
        sparse.qkv += s.qkv;
        sparse.attn += s.attn;
        sparse.ffn += s.ffn;
    }
    let overhead = prediction_overhead_ops(cfg, &SplsConfig::default());
    let overall = 1.0 - (sparse.total() + overhead) / dense.total();
    (
        overall,
        1.0 - sparse.qkv / dense.qkv,
        1.0 - sparse.attn / dense.attn,
        1.0 - sparse.ffn / dense.ffn,
    )
}

/// CSR index structure for a set of kept attention rows: row `i` of the
/// compacted problem keeps columns `col_indices[row_offsets[i] ..
/// row_offsets[i+1]]`, ascending. Column ids are absolute token
/// positions; `model::sparse_plan` re-bases them onto gathered K/V
/// panels when it compiles a whole model plan.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CsrRows {
    /// `rows.len() + 1` offsets into `col_indices`, monotone.
    pub row_offsets: Vec<u32>,
    /// Kept column positions, ascending within each row.
    pub col_indices: Vec<u32>,
}

impl CsrRows {
    pub fn nnz(&self) -> usize {
        self.col_indices.len()
    }
}

/// Lower the given `rows` of a boolean keep-mask into CSR form.
///
/// With `forbid_empty`, a row that keeps nothing panics: in a lowered
/// SPLS plan every critical row keeps at least one column (bidirectional
/// top-k keeps ⌈k·L⌉ ≥ 1 per row, the causal path force-includes the
/// diagonal, decode force-keeps the newest slot), so an empty row here
/// means a corrupted plan — failing loudly beats the silent zero-filled
/// attention row `masked_softmax_row` would otherwise produce. The raw
/// f32-mask path (`forward_masked`) deliberately does *not* route
/// through this: arbitrary external masks may legally zero a row.
pub fn lower_mask_rows(mask: &Mat<bool>, rows: &[usize], forbid_empty: bool) -> CsrRows {
    let mut row_offsets = Vec::with_capacity(rows.len() + 1);
    let mut col_indices = Vec::new();
    row_offsets.push(0u32);
    for &r in rows {
        let before = col_indices.len();
        for (c, &keep) in mask.row(r).iter().enumerate() {
            if keep {
                col_indices.push(c as u32);
            }
        }
        if forbid_empty {
            assert!(
                col_indices.len() > before,
                "plan lowering: attention row {r} keeps no columns — the \
                 diagonal invariant (every kept row attends to at least \
                 itself) is broken; refusing to compile a plan that would \
                 silently zero-fill this row"
            );
        }
        row_offsets.push(col_indices.len() as u32);
    }
    CsrRows { row_offsets, col_indices }
}

/// Fraction of dense model FLOPs the per-layer plans actually keep —
/// the measured keep-density plotted on the BENCH_4 crossover x-axis
/// (1 − keep_density is the paper's computation-reduction fraction,
/// before prediction overhead).
pub fn keep_density(cfg: &ModelConfig, plans: &[LayerPlan]) -> f64 {
    assert_eq!(plans.len(), cfg.n_layers);
    let dense = dense_model_flops(cfg).total();
    let sparse: f64 = plans.iter().map(|p| sparse_layer_flops(cfg, p).total()).sum();
    sparse / dense
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::util::rng::Xoshiro256pp;

    fn synth_pams(l: usize, h: usize, seed: u64) -> Vec<MatI> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..h)
            .map(|_| {
                MatI::from_fn(l, l, |r, c| {
                    // window-correlated rows: base pattern on r/2
                    ((r / 2 * 13 + c * 3) % 61) as i32 + rng.int_in(-1, 1) as i32
                })
            })
            .collect()
    }

    #[test]
    fn bert_large_fig1_numbers() {
        // Paper Fig 1: BERT-Large @ L=512 totals 167.5 GFLOPs,
        // MHA 38.46%, FFN 61.54%.
        let cfg = config::bert_large(512);
        let f = dense_model_flops(&cfg);
        let total_g = f.total() / 1e9;
        assert!((total_g - 167.5).abs() < 2.5, "total {total_g} GFLOPs");
        let mha_frac = (f.qkv + f.attn) / f.total();
        assert!((mha_frac - 0.3846).abs() < 0.02, "MHA {mha_frac}");
    }

    #[test]
    fn plan_layer_produces_consistent_sparsity() {
        let pams = synth_pams(32, 4, 5);
        let spls = SplsConfig::default();
        let plan = plan_layer(&pams, &spls);
        assert_eq!(plan.heads.len(), 4);
        assert!(plan.attn_sparsity() > 0.8); // top-k 0.12 alone gives ~0.88
        assert!(plan.ffn.validate());
    }

    #[test]
    fn sparse_flops_bounded_by_dense() {
        let cfg = config::ModelConfig::new("tiny", 32, 64, 4, 1, 256, false);
        let plan = plan_layer(&synth_pams(32, 4, 9), &SplsConfig::default());
        let d = dense_layer_flops(&cfg);
        let s = sparse_layer_flops(&cfg, &plan);
        assert!(s.qkv <= d.qkv && s.attn <= d.attn && s.ffn <= d.ffn);
        assert!(s.total() > 0.0);
    }

    #[test]
    fn reduction_fractions_in_range() {
        let cfg = config::ModelConfig::new("tiny", 32, 64, 4, 2, 256, false);
        let plans: Vec<LayerPlan> = (0..2)
            .map(|i| plan_layer(&synth_pams(32, 4, 100 + i), &SplsConfig::default()))
            .collect();
        let (overall, qkv, attn, ffn) = computation_reduction(&cfg, &plans);
        for v in [overall, qkv, attn, ffn] {
            assert!((-0.5..=1.0).contains(&v), "{v}");
        }
        assert!(attn > 0.85); // intra-row top-k dominates
    }

    #[test]
    fn prediction_overhead_is_small_fraction() {
        let cfg = config::bert_base(128);
        let dense = dense_model_flops(&cfg).total();
        let ovh = prediction_overhead_ops(&cfg, &SplsConfig::default());
        assert!(ovh / dense < 0.1, "overhead fraction {}", ovh / dense);
    }

    #[test]
    fn causal_plan_respects_visibility() {
        let pams = synth_pams(32, 4, 21);
        let plan = plan_layer_causal(&pams, &SplsConfig::default());
        for head in &plan.heads {
            for r in 0..32 {
                for c in (r + 1)..32 {
                    assert!(!head.mask[(r, c)], "future position kept");
                }
            }
            assert!(head.sim.validate());
        }
        assert!(plan.ffn.validate());
        // causal attention sparsity is even higher than bidirectional
        // (half the matrix is invisible to begin with)
        assert!(plan.attn_sparsity() > 0.9);
    }

    #[test]
    fn causal_vs_bidirectional_q_sparsity() {
        // decoder rows see different-length prefixes, so fewer rows
        // collapse than in the bidirectional case at the same s
        let pams = synth_pams(64, 4, 22);
        let spls = SplsConfig::default();
        let bi = plan_layer(&pams, &spls);
        let ca = plan_layer_causal(&pams, &spls);
        assert!(ca.q_sparsity() <= bi.q_sparsity() + 0.15);
    }

    #[test]
    fn lower_mask_rows_matches_hand_counted_csr() {
        let mask = Mat::from_fn(4, 5, |r, c| match r {
            0 => c == 0 || c == 3,      // ragged
            1 => true,                  // full row
            2 => c == 2,                // singleton
            _ => false,                 // empty (never selected below)
        });
        let csr = lower_mask_rows(&mask, &[0, 1, 2], true);
        assert_eq!(csr.row_offsets, vec![0, 2, 7, 8]);
        assert_eq!(csr.col_indices, vec![0, 3, 0, 1, 2, 3, 4, 2]);
        assert_eq!(csr.nnz(), 8);
    }

    #[test]
    #[should_panic(expected = "diagonal invariant")]
    fn lower_mask_rows_rejects_empty_row() {
        let mask = Mat::from_fn(3, 3, |r, _| r != 1);
        lower_mask_rows(&mask, &[0, 1, 2], true);
    }

    #[test]
    fn lower_mask_rows_tolerates_empty_when_allowed() {
        let mask: Mat<bool> = Mat::zeros(2, 4);
        let csr = lower_mask_rows(&mask, &[0, 1], false);
        assert_eq!(csr.row_offsets, vec![0, 0, 0]);
        assert!(csr.col_indices.is_empty());
    }

    #[test]
    fn keep_density_complements_reduction() {
        let cfg = config::ModelConfig::new("tiny", 32, 64, 4, 2, 256, false);
        let plans: Vec<LayerPlan> = (0..2)
            .map(|i| plan_layer(&synth_pams(32, 4, 300 + i), &SplsConfig::default()))
            .collect();
        let kd = keep_density(&cfg, &plans);
        assert!((0.0..=1.0).contains(&kd), "{kd}");
        // keep_density is the pre-overhead complement of the Fig 15
        // overall reduction
        let dense = dense_model_flops(&cfg).total();
        let overhead = prediction_overhead_ops(&cfg, &SplsConfig::default());
        let (overall, ..) = computation_reduction(&cfg, &plans);
        let expect = 1.0 - overall - overhead / dense;
        assert!((kd - expect).abs() < 1e-12, "{kd} vs {expect}");
    }

    #[test]
    fn quant_method_comparison_path_runs() {
        let mut rng = Xoshiro256pp::new(3);
        let x = MatI::from_fn(16, 16, |_, _| rng.int_in(-128, 127) as i32);
        let wq: Vec<MatI> = (0..2)
            .map(|_| MatI::from_fn(16, 8, |_, _| rng.int_in(-128, 127) as i32))
            .collect();
        let wk = wq.clone();
        for m in QuantMethod::ALL {
            let plan =
                plan_layer_from_inputs(&x, &wq, &wk, &SplsConfig::default(), m);
            assert_eq!(plan.heads.len(), 2);
        }
    }
}
