//! QKV sparsification from the SPA (paper §III-C, Fig 8).
//!
//! * **Q** — similarity-based: Q vectors are generated only for critical
//!   attention rows; similar rows are recovered by replication after
//!   attention.
//! * **K/V** — column-based: zero columns of the SPA mark K rows (and,
//!   since A·V consumes the same positions, V rows) that are never read
//!   by any kept attention entry and can be pruned.

use crate::spls::similarity::SimilarityMap;
use crate::spls::topk;
use crate::util::mat::Mat;

/// Per-head sparsification decisions derived from one head's SPA.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadPlan {
    /// Critical-row representative per row (`rep[r] == r` iff critical).
    pub sim: SimilarityMap,
    /// Columns of the SPA with at least one kept entry, ascending —
    /// exactly the K/V rows that must be generated.
    pub active_cols: Vec<usize>,
    /// The SPA keep-mask restricted to critical rows (what the PE array
    /// actually computes); similar rows are recovered afterwards.
    pub mask: Mat<bool>,
}

impl HeadPlan {
    /// Build the head plan from a head's SPA mask + similarity map.
    pub fn new(mask: Mat<bool>, sim: SimilarityMap) -> Self {
        assert_eq!(mask.rows, sim.rep.len());
        let zero = topk::zero_columns(&mask);
        let mut is_zero = vec![false; mask.cols];
        for &c in &zero {
            is_zero[c] = true;
        }
        let active_cols = (0..mask.cols).filter(|&c| !is_zero[c]).collect();
        Self { sim, active_cols, mask }
    }

    pub fn l(&self) -> usize {
        self.mask.rows
    }

    /// Fraction of Q rows skipped (similar rows).
    pub fn q_sparsity(&self) -> f64 {
        self.sim.q_sparsity()
    }

    /// Fraction of K (and V) rows skipped (zero columns).
    pub fn kv_sparsity(&self) -> f64 {
        1.0 - self.active_cols.len() as f64 / self.mask.cols.max(1) as f64
    }

    /// Fraction of attention *positions* actually computed: kept mask
    /// entries on critical rows only, over L².
    pub fn attn_density(&self) -> f64 {
        let mut kept = 0usize;
        for r in 0..self.mask.rows {
            if self.sim.rep[r] == r {
                kept += self.mask.row(r).iter().filter(|&&b| b).count();
            }
        }
        kept as f64 / (self.mask.rows * self.mask.cols).max(1) as f64
    }

    /// Attention-level sparsity (1 − density), combining inter-row
    /// (similarity) and intra-row (top-k) effects — the paper's 94.65%.
    pub fn attn_sparsity(&self) -> f64 {
        1.0 - self.attn_density()
    }

    /// Number of critical rows (Q vectors generated).
    pub fn n_critical(&self) -> usize {
        self.sim.critical_rows().len()
    }
}

/// Recover a full L×Dh output from critical-row results by replicating
/// each similar row's critical row (the paper's recovery operation).
/// `partial` holds rows only for critical indices, in ascending critical
/// order.
pub fn recover_rows(partial: &Mat<f32>, sim: &SimilarityMap) -> Mat<f32> {
    let criticals = sim.critical_rows();
    assert_eq!(partial.rows, criticals.len(), "partial rows != #critical");
    // critical row index -> position in `partial`
    let mut pos = vec![usize::MAX; sim.rep.len()];
    for (i, &c) in criticals.iter().enumerate() {
        pos[c] = i;
    }
    Mat::from_fn(sim.rep.len(), partial.cols, |r, c| {
        partial[(pos[sim.rep[r]], c)]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spls::similarity::local_similarity;
    use crate::spls::topk::sparsify;
    use crate::util::mat::MatI;
    use crate::util::rng::Xoshiro256pp;

    fn demo_plan(l: usize, seed: u64, k: f32, s: f32, w: usize) -> HeadPlan {
        let mut rng = Xoshiro256pp::new(seed);
        // low-rank-ish PAM so similarity exists: row r profile depends on r/2
        let pam = MatI::from_fn(l, l, |r, c| {
            ((r / 2 * 31 + c * 7) % 97) as i32 + rng.int_in(-2, 2) as i32
        });
        let (spa, mask) = sparsify(&pam, k);
        let sim = local_similarity(&spa, w, s);
        HeadPlan::new(mask, sim)
    }

    #[test]
    fn sparsity_fractions_consistent() {
        let p = demo_plan(32, 3, 0.25, 0.6, 8);
        assert!(p.q_sparsity() >= 0.0 && p.q_sparsity() < 1.0);
        assert!(p.kv_sparsity() >= 0.0 && p.kv_sparsity() < 1.0);
        assert!(p.attn_sparsity() >= 1.0 - 0.25 - 1e-9); // at least top-k level
        assert_eq!(
            p.n_critical() + p.sim.n_similar(),
            p.l()
        );
    }

    #[test]
    fn active_cols_complement_zero_cols() {
        let p = demo_plan(16, 7, 0.12, 0.5, 8);
        let zeros = topk::zero_columns(&p.mask);
        let mut all: Vec<usize> = p.active_cols.iter().copied().chain(zeros).collect();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn recover_replicates_critical_rows() {
        // rows 0,2 critical; 1 -> 0, 3 -> 2
        let sim = SimilarityMap { rep: vec![0, 0, 2, 2], window: 4 };
        let partial = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 9.0, 8.0, 7.0]);
        let full = recover_rows(&partial, &sim);
        assert_eq!(full.row(0), full.row(1));
        assert_eq!(full.row(2), full.row(3));
        assert_eq!(full.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(full.row(3), &[9.0, 8.0, 7.0]);
    }

    #[test]
    #[should_panic]
    fn recover_checks_row_count() {
        let sim = SimilarityMap { rep: vec![0, 0], window: 2 };
        let partial = Mat::from_vec(2, 1, vec![1.0, 2.0]); // should be 1 row
        recover_rows(&partial, &sim);
    }

    #[test]
    fn all_rows_critical_recovery_is_identity() {
        let sim = SimilarityMap { rep: vec![0, 1, 2], window: 8 };
        let partial = Mat::from_fn(3, 2, |r, c| (r * 2 + c) as f32);
        assert_eq!(recover_rows(&partial, &sim), partial);
    }

    #[test]
    fn higher_similarity_threshold_more_q_sparsity() {
        let lo = demo_plan(64, 9, 0.12, 0.1, 8).q_sparsity();
        let hi = demo_plan(64, 9, 0.12, 0.9, 8).q_sparsity();
        assert!(hi >= lo);
    }

    #[test]
    fn kv_sparsity_independent_of_similarity_threshold() {
        // paper Fig 18: K sparsity is flat in s (driven only by top-k)
        let a = demo_plan(64, 13, 0.12, 0.1, 8);
        let b = demo_plan(64, 13, 0.12, 0.9, 8);
        assert_eq!(a.active_cols, b.active_cols);
    }
}
