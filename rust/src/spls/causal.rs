//! Causal (decoder) support for SPLS (paper §V-A evaluates GPT-2,
//! Llama2-7b and Bloom-7b): the PAM of a causal model is lower-
//! triangular, which changes the pipeline in three ways —
//!
//! * top-k per row operates over the *visible* prefix only (row r sees
//!   columns 0..=r), so early rows keep fewer than ⌈k·L⌉ entries;
//! * column pruning must never drop column r from row r (the diagonal
//!   is always visible and usually dominant);
//! * local similarity compares only the overlapping visible prefix of
//!   two rows, normalized by the shorter row's mass — otherwise longer
//!   rows look spuriously dissimilar.

use crate::spls::similarity::SimilarityMap;
use crate::util::mat::{Mat, MatI};

/// Zero the strictly-upper triangle of a PAM (apply causal visibility).
pub fn apply_causal_mask(pam: &mut MatI) {
    for r in 0..pam.rows {
        for c in (r + 1)..pam.cols {
            pam[(r, c)] = 0;
        }
    }
}

/// Row-wise top-k over the visible prefix: row r keeps
/// `min(ceil(k·(r+1)), r+1)` entries, at least 1.
pub fn causal_topk_mask(pam: &MatI, k_ratio: f32) -> Mat<bool> {
    let mut mask = Mat::from_vec(pam.rows, pam.cols, vec![false; pam.rows * pam.cols]);
    let mut idx: Vec<usize> = Vec::new();
    for r in 0..pam.rows {
        let visible = (r + 1).min(pam.cols);
        let keep = (((k_ratio * visible as f32).ceil()) as usize).clamp(1, visible);
        idx.clear();
        idx.extend(0..visible);
        let row = pam.row(r);
        idx.sort_by(|&a, &b| row[b].cmp(&row[a]));
        for &c in idx.iter().take(keep) {
            mask[(r, c)] = true;
        }
    }
    mask
}

/// Normalized L1 distance over the shared visible prefix of rows
/// `a` (row index ra) and `b` (row index rb).
fn causal_l1(a: &[i32], b: &[i32], ra: usize, rb: usize) -> f64 {
    let shared = ra.min(rb) + 1;
    let mut diff = 0i64;
    let mut na = 0i64;
    let mut nb = 0i64;
    for c in 0..shared {
        diff += (a[c] as i64 - b[c] as i64).abs();
        na += (a[c] as i64).abs();
        nb += (b[c] as i64).abs();
    }
    diff as f64 / na.max(nb).max(1) as f64
}

/// Windowed local similarity on a causal SPA: rows compare over the
/// shared prefix; the diagonal-dominant early rows rarely collapse
/// (matching the paper's Fig 3(c) diagonal-heads observation).
pub fn causal_local_similarity(spa: &MatI, window: usize, threshold: f32) -> SimilarityMap {
    assert!(window >= 1);
    let l = spa.rows;
    let mut rep = vec![0usize; l];
    let mut criticals: Vec<usize> = Vec::new();
    let mut w0 = 0;
    while w0 < l {
        let w1 = (w0 + window).min(l);
        criticals.clear();
        for r in w0..w1 {
            let mut assigned = None;
            for &c in &criticals {
                if causal_l1(spa.row(r), spa.row(c), r, c) <= threshold as f64 {
                    assigned = Some(c);
                    break;
                }
            }
            match assigned {
                Some(c) => rep[r] = c,
                None => {
                    rep[r] = r;
                    criticals.push(r);
                }
            }
        }
        w0 = w1;
    }
    SimilarityMap { rep, window }
}

/// Zero-column detection that always protects the diagonal: column c
/// is prunable only if no kept entry exists *and* it is not any row's
/// own diagonal with visible mass (which it always is), so only the
/// K rows beyond every row's kept set are dropped — in practice the
/// columns where all kept entries vanished.
pub fn causal_zero_columns(mask: &Mat<bool>) -> Vec<usize> {
    (0..mask.cols)
        .filter(|&c| (0..mask.rows).all(|r| !mask[(r, c)]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn causal_pam(l: usize, seed: u64) -> MatI {
        let mut rng = Xoshiro256pp::new(seed);
        let mut pam = MatI::from_fn(l, l, |r, c| {
            ((r / 2 * 29 + c * 5) % 83) as i32 + rng.int_in(-2, 2) as i32 + if r == c { 60 } else { 0 }
        });
        apply_causal_mask(&mut pam);
        pam
    }

    #[test]
    fn causal_mask_zeroes_upper_triangle() {
        let pam = causal_pam(16, 1);
        for r in 0..16 {
            for c in (r + 1)..16 {
                assert_eq!(pam[(r, c)], 0);
            }
        }
    }

    #[test]
    fn topk_respects_visibility() {
        let pam = causal_pam(32, 2);
        let mask = causal_topk_mask(&pam, 0.25);
        for r in 0..32 {
            // nothing kept beyond the diagonal
            for c in (r + 1)..32 {
                assert!(!mask[(r, c)], "row {r} kept future col {c}");
            }
            let kept = mask.row(r).iter().filter(|&&b| b).count();
            let visible = r + 1;
            let want = ((0.25 * visible as f32).ceil() as usize).clamp(1, visible);
            assert_eq!(kept, want, "row {r}");
        }
    }

    #[test]
    fn row_zero_keeps_exactly_diagonal() {
        let pam = causal_pam(8, 3);
        let mask = causal_topk_mask(&pam, 0.1);
        assert!(mask[(0, 0)]);
        assert_eq!(mask.row(0).iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn shared_prefix_similarity() {
        // identical prefixes, divergent tails: rows must still match
        let mut pam = MatI::zeros(8, 8);
        for r in 0..8 {
            for c in 0..=r {
                pam[(r, c)] = 10;
            }
        }
        let sm = causal_local_similarity(&pam, 8, 0.05);
        // every row's shared prefix with row 0 is identical
        assert!(sm.n_similar() >= 6, "{:?}", sm.rep);
        assert!(sm.validate());
    }

    #[test]
    fn diagonal_heads_stay_critical() {
        // diagonal-only SPA (Fig 3c): no two rows share kept positions →
        // no similarity, matching "similarity computations are
        // unnecessary in these heads"
        let pam = MatI::from_fn(16, 16, |r, c| if r == c { 99 } else { 0 });
        let mask = causal_topk_mask(&pam, 0.05);
        let spa = crate::spls::topk::apply_mask(&pam, &mask);
        let sm = causal_local_similarity(&spa, 8, 0.1);
        assert_eq!(sm.n_similar(), 0);
    }

    #[test]
    fn zero_columns_exclude_kept_diagonals() {
        let pam = causal_pam(16, 5);
        let mask = causal_topk_mask(&pam, 0.3);
        let zeros = causal_zero_columns(&mask);
        for &c in &zeros {
            assert!(!mask[(c, c)], "col {c} reported zero but diagonal kept");
        }
    }

    #[test]
    fn more_rows_similar_with_higher_threshold() {
        let pam = causal_pam(64, 7);
        let mask = causal_topk_mask(&pam, 0.2);
        let spa = crate::spls::topk::apply_mask(&pam, &mask);
        let lo = causal_local_similarity(&spa, 8, 0.1).n_similar();
        let hi = causal_local_similarity(&spa, 8, 0.9).n_similar();
        assert!(hi >= lo);
    }
}
