//! Causal (decoder) support for SPLS (paper §V-A evaluates GPT-2,
//! Llama2-7b and Bloom-7b): the PAM of a causal model is lower-
//! triangular, which changes the pipeline in three ways —
//!
//! * top-k per row operates over the *visible* prefix only (row r sees
//!   columns 0..=r), so early rows keep fewer than ⌈k·L⌉ entries;
//! * column pruning must never drop column r from row r (the diagonal
//!   is always visible and usually dominant);
//! * local similarity compares only the overlapping visible prefix of
//!   two rows, normalized by the shorter row's mass — otherwise longer
//!   rows look spuriously dissimilar.

use crate::spls::similarity::SimilarityMap;
use crate::util::mat::{Mat, MatI};

/// Zero the strictly-upper triangle of a PAM (apply causal visibility).
pub fn apply_causal_mask(pam: &mut MatI) {
    for r in 0..pam.rows {
        for c in (r + 1)..pam.cols {
            pam[(r, c)] = 0;
        }
    }
}

/// Diagonal-preserving top-k over one visible row: keep exactly
/// `clamp(⌈k·n⌉, 1, n)` entries (largest first, ties toward the lower
/// slot), with the last slot — the row's own diagonal position —
/// always among them (swapped for the weakest selection when it misses
/// the natural top-k, so the count is unchanged). This single helper
/// is the selection rule shared by the prefill causal mask below and
/// the decode engine's per-step keep-mask
/// (`decode::incremental::topk_keep_with_diagonal`), which keeps the
/// two paths bit-equivalent by construction.
pub fn topk_row_keep_with_diagonal(row: &[i32], k_ratio: f32) -> Vec<bool> {
    let n = row.len();
    assert!(n >= 1);
    let count = (((k_ratio * n as f32).ceil()) as usize).clamp(1, n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| row[b].cmp(&row[a]));
    let chosen = &mut idx[..count];
    if !chosen.contains(&(n - 1)) {
        chosen[count - 1] = n - 1;
    }
    let mut keep = vec![false; n];
    for &c in chosen.iter() {
        keep[c] = true;
    }
    keep
}

/// Row-wise top-k over the visible prefix: row r keeps exactly
/// `min(ceil(k·(r+1)), r+1)` entries (at least 1), and **never prunes
/// the diagonal** — position r is always visible to row r and usually
/// dominant, and the decode path's recovery semantics rely on it
/// (selection rule: [`topk_row_keep_with_diagonal`]).
pub fn causal_topk_mask(pam: &MatI, k_ratio: f32) -> Mat<bool> {
    let mut mask = Mat::from_vec(pam.rows, pam.cols, vec![false; pam.rows * pam.cols]);
    for r in 0..pam.rows {
        let visible = (r + 1).min(pam.cols);
        let keep = topk_row_keep_with_diagonal(&pam.row(r)[..visible], k_ratio);
        for (c, &kept) in keep.iter().enumerate() {
            if kept {
                mask[(r, c)] = true;
            }
        }
    }
    mask
}

/// Normalized L1 distance over the shared visible prefix of rows
/// `a` (row index ra) and `b` (row index rb).
fn causal_l1(a: &[i32], b: &[i32], ra: usize, rb: usize) -> f64 {
    let shared = ra.min(rb) + 1;
    let mut diff = 0i64;
    let mut na = 0i64;
    let mut nb = 0i64;
    for c in 0..shared {
        diff += (a[c] as i64 - b[c] as i64).abs();
        na += (a[c] as i64).abs();
        nb += (b[c] as i64).abs();
    }
    diff as f64 / na.max(nb).max(1) as f64
}

/// Causal similarity of two rows over their shared visible prefix, in
/// `[0, 1]`: `1 − dist/2`, where the normalized L1 distance is bounded
/// by 2 (`Σ|aᵢ−bᵢ| ≤ Σ|aᵢ| + Σ|bᵢ| ≤ 2·max(Σ|aᵢ|, Σ|bᵢ|)`). Symmetric
/// in its arguments; identical prefixes score exactly 1. This is the
/// analysis-facing form of the threshold comparison in
/// [`causal_local_similarity`].
pub fn causal_row_similarity(a: &[i32], b: &[i32], ra: usize, rb: usize) -> f64 {
    1.0 - causal_l1(a, b, ra, rb) / 2.0
}

/// Windowed local similarity on a causal SPA: rows compare over the
/// shared prefix; the diagonal-dominant early rows rarely collapse
/// (matching the paper's Fig 3(c) diagonal-heads observation).
pub fn causal_local_similarity(spa: &MatI, window: usize, threshold: f32) -> SimilarityMap {
    assert!(window >= 1);
    let l = spa.rows;
    let mut rep = vec![0usize; l];
    let mut criticals: Vec<usize> = Vec::new();
    let mut w0 = 0;
    while w0 < l {
        let w1 = (w0 + window).min(l);
        criticals.clear();
        for r in w0..w1 {
            let mut assigned = None;
            for &c in &criticals {
                if causal_l1(spa.row(r), spa.row(c), r, c) <= threshold as f64 {
                    assigned = Some(c);
                    break;
                }
            }
            match assigned {
                Some(c) => rep[r] = c,
                None => {
                    rep[r] = r;
                    criticals.push(r);
                }
            }
        }
        w0 = w1;
    }
    SimilarityMap { rep, window }
}

/// Zero-column detection that always protects the diagonal: column c
/// is prunable only if no kept entry exists *and* it is not any row's
/// own diagonal with visible mass (which it always is), so only the
/// K rows beyond every row's kept set are dropped — in practice the
/// columns where all kept entries vanished.
pub fn causal_zero_columns(mask: &Mat<bool>) -> Vec<usize> {
    (0..mask.cols)
        .filter(|&c| (0..mask.rows).all(|r| !mask[(r, c)]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn causal_pam(l: usize, seed: u64) -> MatI {
        let mut rng = Xoshiro256pp::new(seed);
        let mut pam = MatI::from_fn(l, l, |r, c| {
            ((r / 2 * 29 + c * 5) % 83) as i32 + rng.int_in(-2, 2) as i32 + if r == c { 60 } else { 0 }
        });
        apply_causal_mask(&mut pam);
        pam
    }

    #[test]
    fn causal_mask_zeroes_upper_triangle() {
        let pam = causal_pam(16, 1);
        for r in 0..16 {
            for c in (r + 1)..16 {
                assert_eq!(pam[(r, c)], 0);
            }
        }
    }

    #[test]
    fn topk_respects_visibility() {
        let pam = causal_pam(32, 2);
        let mask = causal_topk_mask(&pam, 0.25);
        for r in 0..32 {
            // nothing kept beyond the diagonal
            for c in (r + 1)..32 {
                assert!(!mask[(r, c)], "row {r} kept future col {c}");
            }
            let kept = mask.row(r).iter().filter(|&&b| b).count();
            let visible = r + 1;
            let want = ((0.25 * visible as f32).ceil() as usize).clamp(1, visible);
            assert_eq!(kept, want, "row {r}");
        }
    }

    #[test]
    fn row_zero_keeps_exactly_diagonal() {
        let pam = causal_pam(8, 3);
        let mask = causal_topk_mask(&pam, 0.1);
        assert!(mask[(0, 0)]);
        assert_eq!(mask.row(0).iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn shared_prefix_similarity() {
        // identical prefixes, divergent tails: rows must still match
        let mut pam = MatI::zeros(8, 8);
        for r in 0..8 {
            for c in 0..=r {
                pam[(r, c)] = 10;
            }
        }
        let sm = causal_local_similarity(&pam, 8, 0.05);
        // every row's shared prefix with row 0 is identical
        assert!(sm.n_similar() >= 6, "{:?}", sm.rep);
        assert!(sm.validate());
    }

    #[test]
    fn diagonal_heads_stay_critical() {
        // diagonal-only SPA (Fig 3c): no two rows share kept positions →
        // no similarity, matching "similarity computations are
        // unnecessary in these heads"
        let pam = MatI::from_fn(16, 16, |r, c| if r == c { 99 } else { 0 });
        let mask = causal_topk_mask(&pam, 0.05);
        let spa = crate::spls::topk::apply_mask(&pam, &mask);
        let sm = causal_local_similarity(&spa, 8, 0.1);
        assert_eq!(sm.n_similar(), 0);
    }

    #[test]
    fn zero_columns_exclude_kept_diagonals() {
        let pam = causal_pam(16, 5);
        let mask = causal_topk_mask(&pam, 0.3);
        let zeros = causal_zero_columns(&mask);
        for &c in &zeros {
            assert!(!mask[(c, c)], "col {c} reported zero but diagonal kept");
        }
    }

    #[test]
    fn more_rows_similar_with_higher_threshold() {
        let pam = causal_pam(64, 7);
        let mask = causal_topk_mask(&pam, 0.2);
        let spa = crate::spls::topk::apply_mask(&pam, &mask);
        let lo = causal_local_similarity(&spa, 8, 0.1).n_similar();
        let hi = causal_local_similarity(&spa, 8, 0.9).n_similar();
        assert!(hi >= lo);
    }

    #[test]
    fn prop_topk_keeps_exact_count_and_never_prunes_diagonal() {
        // property: per row, exactly min(⌈k·(r+1)⌉, r+1) (≥ 1) entries
        // survive, the diagonal is always among them, and nothing
        // beyond the visible prefix is kept — on *random* PAMs, where
        // the diagonal is frequently not in the natural top-k
        crate::util::prop::check(60, |rng| {
            let l = 2 + rng.below(30) as usize;
            let k = 0.02 + rng.f64() as f32 * 0.98;
            let mut pam = MatI::from_fn(l, l, |_, _| rng.int_in(-100, 100) as i32);
            apply_causal_mask(&mut pam);
            let mask = causal_topk_mask(&pam, k);
            for r in 0..l {
                let visible = r + 1;
                let want = (((k * visible as f32).ceil()) as usize).clamp(1, visible);
                let kept = mask.row(r).iter().filter(|&&b| b).count();
                assert_eq!(kept, want, "row {r}: kept {kept}, want {want} (k={k})");
                assert!(mask[(r, r)], "row {r} pruned its diagonal");
                for c in visible..l {
                    assert!(!mask[(r, c)], "row {r} kept future col {c}");
                }
            }
        });
    }

    #[test]
    fn prop_causal_similarity_symmetric_and_in_unit_range() {
        crate::util::prop::check(60, |rng| {
            let l = 1 + rng.below(24) as usize;
            let a: Vec<i32> = (0..l).map(|_| rng.int_in(-80, 80) as i32).collect();
            let b: Vec<i32> = (0..l).map(|_| rng.int_in(-80, 80) as i32).collect();
            let ra = rng.below(l as u64) as usize;
            let rb = rng.below(l as u64) as usize;
            let s_ab = causal_row_similarity(&a, &b, ra, rb);
            let s_ba = causal_row_similarity(&b, &a, rb, ra);
            assert_eq!(s_ab, s_ba, "similarity must be symmetric");
            assert!((0.0..=1.0).contains(&s_ab), "similarity {s_ab} out of [0,1]");
            assert_eq!(causal_row_similarity(&a, &a, ra, ra), 1.0, "self-similarity");
        });
    }

    #[test]
    fn prop_identical_visible_prefixes_are_fully_similar() {
        // rows that agree on the shared prefix score 1 even when their
        // (invisible) tails diverge — the causal-similarity contract
        crate::util::prop::check(40, |rng| {
            let l = 2 + rng.below(20) as usize;
            let ra = rng.below(l as u64) as usize;
            let rb = rng.below(l as u64) as usize;
            let mut a: Vec<i32> = (0..l).map(|_| rng.int_in(-50, 50) as i32).collect();
            let mut b = a.clone();
            let shared = ra.min(rb) + 1;
            for c in shared..l {
                a[c] = rng.int_in(-50, 50) as i32;
                b[c] = rng.int_in(-50, 50) as i32;
            }
            assert_eq!(causal_row_similarity(&a, &b, ra, rb), 1.0);
        });
    }
}
