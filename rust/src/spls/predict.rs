//! Software model of the bit-level prediction unit (paper §IV-B,
//! Figs 11/12): shift detector (SD) → shift judgment array (SJA) →
//! converter. This is the *hardware-faithful* path — every product is
//! decomposed into at most two power-of-two terms and accumulated by
//! counting exponents, exactly as the ASIC does with adders only.
//!
//! Contract: `predict_matmul` must agree bit-for-bit with the plain
//! "quantize then multiply" reference (`quant::hlog_quantize` +
//! integer matmul), which in turn matches the python Pallas kernel.
//! The tests enforce both.

use rayon::prelude::*;

use crate::quant::{hlog_code, requantize_sym8, HlogCode};
use crate::util::mat::{Mat, MatI};

/// Below this output-element count the rayon fork/join overhead exceeds
/// the matmul itself — stay single-threaded (empirically ~a 64×64 tile).
const PAR_THRESHOLD: usize = 64 * 64;

/// One SJA product: sign and up to two power-of-two exponents (the
/// 9-bit compact output of Fig 12: sign + two 4-bit exponents).
///
/// The three multiplication cases for HLog operands `2^m` / `3·2^{m-1}`:
///
/// ```text
/// single × single : 2^(ea+eb)                      -> {e}
/// single × sum    : 2^(ea+eb) + 2^(ea+eb-1)        -> {e, e-1}
/// sum    × sum    : 9·2^(ea+eb-2)
///                 = 2^(ea+eb+1) + 2^(ea+eb-2)      -> {e+1, e-2}
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SjaProduct {
    /// Product sign: -1, 0, +1.
    pub sign: i8,
    /// First power-of-two exponent (always valid when sign != 0).
    pub exp0: u8,
    /// Optional second exponent.
    pub exp1: Option<u8>,
}

impl SjaProduct {
    /// Decode to the exact integer product.
    pub fn value(self) -> i64 {
        if self.sign == 0 {
            return 0;
        }
        let mut v = 1i64 << self.exp0;
        if let Some(e1) = self.exp1 {
            v += 1i64 << e1;
        }
        self.sign as i64 * v
    }
}

/// The SJA multiply: exponent additions and a 3-way form select — no
/// multiplier anywhere.
pub fn sja_multiply(a: HlogCode, b: HlogCode) -> SjaProduct {
    if a.sign == 0 || b.sign == 0 {
        return SjaProduct { sign: 0, exp0: 0, exp1: None };
    }
    let sign = a.sign * b.sign;
    let e = a.exp as u16 + b.exp as u16;
    match (a.form, b.form) {
        (0, 0) => SjaProduct { sign, exp0: e as u8, exp1: None },
        (0, 1) | (1, 0) => SjaProduct {
            sign,
            exp0: e as u8,
            exp1: Some((e - 1) as u8),
        },
        _ => SjaProduct {
            sign,
            exp0: (e + 1) as u8,
            exp1: Some((e - 2) as u8),
        },
    }
}

/// The converter (paper Fig 11, FACT-style one-hot adder): group SJA
/// products by sign, count exponent occurrences per group, convert the
/// counts to binary (shift-accumulate), subtract negative from positive.
///
/// Exponent range: two int8 HLog operands have exponents ≤ 8 each, so
/// products need exponents ≤ 8+8+1 = 17; we keep 32 counters for slack.
pub fn converter(products: &[SjaProduct]) -> i64 {
    let mut pos = [0u32; 32];
    let mut neg = [0u32; 32];
    for p in products {
        let group = match p.sign {
            1 => &mut pos,
            -1 => &mut neg,
            _ => continue,
        };
        group[p.exp0 as usize] += 1;
        if let Some(e1) = p.exp1 {
            group[e1 as usize] += 1;
        }
    }
    let weigh = |cnt: &[u32; 32]| -> i64 {
        cnt.iter()
            .enumerate()
            .map(|(e, &c)| (c as i64) << e)
            .sum()
    };
    weigh(&pos) - weigh(&neg)
}

/// One dot product through the full SD → SJA → converter pipeline.
pub fn predict_dot(x: &[i32], w: &[i32]) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    let products: Vec<SjaProduct> = x
        .iter()
        .zip(w)
        .map(|(&a, &b)| sja_multiply(hlog_code(a), hlog_code(b)))
        .collect();
    converter(&products)
}

/// Hardware-faithful prediction matmul: every product goes through the
/// explicit SD → SJA → converter object pipeline. This is the model the
/// unit tests validate bit-for-bit; it is O(allocations) slow and kept
/// for verification — the serve path uses [`predict_matmul`].
pub fn predict_matmul_faithful(x: &MatI, w: &MatI) -> MatI {
    assert_eq!(x.cols, w.rows, "shape mismatch");
    // Pre-encode both operands once (the hardware's SD stage), then run
    // SJA products column-wise against the transposed weight panel.
    let xc: Vec<HlogCode> = x.data.iter().map(|&v| hlog_code(v)).collect();
    let wt = w.transpose();
    let wc: Vec<HlogCode> = wt.data.iter().map(|&v| hlog_code(v)).collect();
    let k = x.cols;
    Mat::from_fn(x.rows, w.cols, |r, c| {
        let xrow = &xc[r * k..(r + 1) * k];
        let wrow = &wc[c * k..(c + 1) * k];
        let products: Vec<SjaProduct> = xrow
            .iter()
            .zip(wrow)
            .map(|(&a, &b)| sja_multiply(a, b))
            .collect();
        converter(&products) as i32
    })
}

/// Full prediction matmul `(M, K) × (K, N) -> (M, N)` through the
/// bit-level unit semantics. Operands are int8-valued; output is exact
/// int32 (HLog products of int8 values cannot overflow i32 for
/// K ≤ 2^13).
///
/// Fast path (§Perf): the SD→SJA→converter pipeline is *provably*
/// equal to "HLog-quantize both operands, then exact integer matmul"
/// (`sja_matches_integer_multiply_exhaustive`,
/// `fast_path_equals_faithful`), so the software model quantizes once
/// and runs a row-major ikj integer matmul (contiguous inner axpy,
/// rayon over rows) — ~40× faster than the object-level pipeline while
/// bit-identical.
pub fn predict_matmul(x: &MatI, w: &MatI) -> MatI {
    assert_eq!(x.cols, w.rows, "shape mismatch");
    let (m, k, n) = (x.rows, x.cols, w.cols);
    let qx: Vec<i32> = x.data.iter().map(|&v| hlog_quantize_fast(v)).collect();
    let qw: Vec<i32> = w.data.iter().map(|&v| hlog_quantize_fast(v)).collect();
    let mut out = vec![0i32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return Mat::from_vec(m, n, out);
    }
    let row_kernel = |r: usize, orow: &mut [i32]| {
        let xrow = &qx[r * k..(r + 1) * k];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let wrow = &qw[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    };
    if m * n >= PAR_THRESHOLD && m > 1 {
        // output rows are disjoint — partition `out` by row across the
        // rayon pool; per-row accumulation order is unchanged, so the
        // result is bit-identical to the serial path
        out.par_chunks_mut(n)
            .enumerate()
            .for_each(|(r, orow)| row_kernel(r, orow));
    } else {
        for (r, orow) in out.chunks_mut(n).enumerate() {
            row_kernel(r, orow);
        }
    }
    Mat::from_vec(m, n, out)
}

/// Table-driven HLog quantization of an int8-valued input (±255):
/// a 511-entry lookup beats the bit-twiddle chain in the matmul loop.
#[inline]
fn hlog_quantize_fast(v: i32) -> i32 {
    const TABLE: [i32; 511] = {
        let mut t = [0i32; 511];
        let mut i = 0usize;
        while i < 511 {
            let x = i as i32 - 255;
            t[i] = hlog_quantize_const(x);
            i += 1;
        }
        t
    };
    debug_assert!((-255..=255).contains(&v));
    TABLE[(v + 255) as usize]
}

/// const-evaluable copy of `quant::hlog_quantize` (the bit rule).
const fn hlog_quantize_const(x: i32) -> i32 {
    if x == 0 {
        return 0;
    }
    let a = x.unsigned_abs();
    let i = 31 - a.leading_zeros();
    let b1 = if i >= 1 { (a >> (i - 1)) & 1 } else { 0 };
    let b0 = if i >= 2 { (a >> (i - 2)) & 1 } else { 0 };
    let e = i + (b1 & b0);
    let form = b1 ^ b0;
    let mag = if form == 1 { 3 * (1 << (e - 1)) } else { 1 << e };
    if x > 0 {
        mag
    } else {
        -mag
    }
}

/// The full SPLS attention prediction (paper Fig 5a): predict Q and K
/// via the bit-level unit, requantize each to int8, then predict the
/// attention scores Q·Kᵀ the same way. Returns the PAM.
///
/// Mirrors `ref.predict_attention` in python exactly.
pub fn predict_attention(x: &MatI, wq: &MatI, wk: &MatI) -> MatI {
    // Q and K prediction are independent (the hardware runs them through
    // the same unit back-to-back; the software model forks them)
    let (q_pred, k_pred) = rayon::join(|| predict_matmul(x, wq), || predict_matmul(x, wk));
    let (q8, _) = requantize_sym8(&q_pred.data);
    let (k8, _) = requantize_sym8(&k_pred.data);
    let q8 = Mat::from_vec(q_pred.rows, q_pred.cols, q8);
    let k8 = Mat::from_vec(k_pred.rows, k_pred.cols, k8);
    predict_matmul(&q8, &k8.transpose())
}

/// Operation count of the prediction path for a `(M, K) × (K, N)`
/// predict_matmul: additions only (the whole point of the unit).
/// Each product is ≤ 2 counter increments; conversion + subtraction is
/// O(exponent range) per output.
pub fn prediction_adds(m: usize, k: usize, n: usize) -> u64 {
    (m * n) as u64 * (2 * k as u64 + 32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hlog_quantize;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sja_three_cases() {
        // single(4=2^2) × single(8=2^3) = 32
        let p = sja_multiply(hlog_code(4), hlog_code(8));
        assert_eq!(p.value(), 32);
        assert_eq!(p.exp1, None);
        // single(4) × sum(6=2^2+2^1) = 24 = 16 + 8
        let p = sja_multiply(hlog_code(4), hlog_code(6));
        assert_eq!(p.value(), 24);
        assert_eq!((p.exp0, p.exp1), (4, Some(3)));
        // sum(6) × sum(12) = 72 = 64 + 8
        let p = sja_multiply(hlog_code(6), hlog_code(12));
        assert_eq!(p.value(), 72);
        assert_eq!((p.exp0, p.exp1), (6, Some(3)));
    }

    #[test]
    fn sja_matches_integer_multiply_exhaustive() {
        for a in -128i32..=127 {
            for b in [-128, -97, -5, -1, 0, 1, 3, 42, 100, 127] {
                let want = hlog_quantize(a) as i64 * hlog_quantize(b) as i64;
                let got = sja_multiply(hlog_code(a), hlog_code(b)).value();
                assert_eq!(got, want, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn converter_signed_accumulation() {
        let products = vec![
            sja_multiply(hlog_code(4), hlog_code(4)),   // +16
            sja_multiply(hlog_code(-2), hlog_code(8)),  // -16
            sja_multiply(hlog_code(6), hlog_code(1)),   // +6
            sja_multiply(hlog_code(0), hlog_code(99)),  // 0
        ];
        assert_eq!(converter(&products), 6);
        assert_eq!(converter(&[]), 0);
    }

    #[test]
    fn predict_dot_equals_quantized_dot() {
        let mut rng = Xoshiro256pp::new(17);
        for _ in 0..50 {
            let k = 1 + rng.below(64) as usize;
            let x: Vec<i32> = (0..k).map(|_| rng.int_in(-128, 127) as i32).collect();
            let w: Vec<i32> = (0..k).map(|_| rng.int_in(-128, 127) as i32).collect();
            let want: i64 = x
                .iter()
                .zip(&w)
                .map(|(&a, &b)| hlog_quantize(a) as i64 * hlog_quantize(b) as i64)
                .sum();
            assert_eq!(predict_dot(&x, &w), want);
        }
    }

    #[test]
    fn predict_matmul_equals_reference() {
        let mut rng = Xoshiro256pp::new(23);
        let x = Mat::from_fn(9, 13, |_, _| rng.int_in(-128, 127) as i32);
        let w = Mat::from_fn(13, 7, |_, _| rng.int_in(-128, 127) as i32);
        let got = predict_matmul(&x, &w);
        // reference: quantize then exact integer matmul
        for r in 0..9 {
            for c in 0..7 {
                let want: i64 = (0..13)
                    .map(|k| {
                        hlog_quantize(x[(r, k)]) as i64 * hlog_quantize(w[(k, c)]) as i64
                    })
                    .sum();
                assert_eq!(got[(r, c)] as i64, want, "({r},{c})");
            }
        }
    }

    #[test]
    fn predict_attention_shape_and_bounds() {
        let mut rng = Xoshiro256pp::new(29);
        let x = Mat::from_fn(16, 32, |_, _| rng.int_in(-128, 127) as i32);
        let wq = Mat::from_fn(32, 8, |_, _| rng.int_in(-128, 127) as i32);
        let wk = Mat::from_fn(32, 8, |_, _| rng.int_in(-128, 127) as i32);
        let pam = predict_attention(&x, &wq, &wk);
        assert_eq!((pam.rows, pam.cols), (16, 16));
        // requantized operands bound each product by 127·128 (HLog of 127
        // rounds up to 128), times Dh = 8 accumulations
        for &v in &pam.data {
            assert!(v.abs() <= 127 * 128 * 8 * 2);
        }
    }

    #[test]
    fn fast_path_equals_faithful() {
        // the perf-pass contract: table-lookup + integer matmul is
        // bit-identical to the SD→SJA→converter object pipeline
        let mut rng = Xoshiro256pp::new(41);
        for _ in 0..10 {
            let m = 1 + rng.below(20) as usize;
            let k = 1 + rng.below(96) as usize;
            let n = 1 + rng.below(20) as usize;
            let x = Mat::from_fn(m, k, |_, _| rng.int_in(-128, 127) as i32);
            let w = Mat::from_fn(k, n, |_, _| rng.int_in(-128, 127) as i32);
            assert_eq!(predict_matmul(&x, &w), predict_matmul_faithful(&x, &w));
        }
    }

    #[test]
    fn parallel_rows_bit_identical_to_faithful_pipeline() {
        // a shape large enough (96·96 > PAR_THRESHOLD) to take the rayon
        // row-partitioned path; must still equal the serial object model
        let mut rng = Xoshiro256pp::new(53);
        let x = Mat::from_fn(96, 64, |_, _| rng.int_in(-128, 127) as i32);
        let w = Mat::from_fn(64, 96, |_, _| rng.int_in(-128, 127) as i32);
        assert!(x.rows * w.cols >= super::PAR_THRESHOLD);
        assert_eq!(predict_matmul(&x, &w), predict_matmul_faithful(&x, &w));
    }

    #[test]
    fn fast_quantize_table_matches_bit_rule() {
        for v in -255..=255 {
            assert_eq!(hlog_quantize_fast(v), hlog_quantize(v), "v={v}");
        }
    }

    #[test]
    fn prediction_adds_scales_linearly() {
        assert!(prediction_adds(64, 64, 64) < prediction_adds(128, 64, 64));
        assert_eq!(prediction_adds(1, 1, 1), 34);
    }
}
