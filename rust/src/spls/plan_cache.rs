//! SPLS plan cache for the serving tier: memoize `plan_model` results
//! so repeated request shapes skip host-side planning entirely — the
//! planner is the per-batch bottleneck once the executors are fast
//! (the serving-systems analogue of AccelTran's amortized
//! dynamic-sparsity scheduling across parallel compute units).
//!
//! Entries are keyed per **(seq-len bucket, quant method, layer)** plus
//! a fingerprint of the token sequence and the SPLS hyperparameters
//! (plans depend on activations, so the tokens are part of the
//! identity; the bucket keys let a deployment bound per-shape
//! residency). Eviction is LRU. A hit returns a clone of the exact
//! `LayerPlan` the planner produced, so cached plans are **bit-identical**
//! to freshly computed ones — asserted by `coordinator::server` tests.
//!
//! `PlanCache` is single-threaded; [`SharedPlanCache`] wraps it in
//! `Arc<Mutex<..>>` for the replica pool (std sync only — no tokio in
//! the vendored crate set, see DESIGN.md §Environment). Lookups and
//! inserts hold the lock; planning itself never does.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::SplsConfig;
use crate::quant::QuantMethod;
use crate::spls::plan::LayerPlan;

/// Default entry capacity of a serving deployment's plan cache
/// (per-layer entries; 256 ≈ 128 distinct sequences on the 2-layer
/// tiny substrate).
pub const DEFAULT_CAPACITY: usize = 256;

/// Cache identity of one layer's plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Sequence-length bucket (next power of two, ≥ 8) — groups
    /// same-shape requests the way the compiled artifacts do.
    pub bucket: usize,
    /// Prediction quantizer the plan was computed with.
    pub method: QuantMethod,
    /// Layer index within the model.
    pub layer: usize,
    /// FNV-1a fingerprint of the token ids + SPLS hyperparameters.
    fingerprint: u64,
}

/// Bucket a sequence length like the artifact shapes do: next power of
/// two, clamped below at 8.
pub fn seq_bucket(len: usize) -> usize {
    len.max(8).next_power_of_two()
}

/// FNV-1a over the token ids and the SPLS operating point. Collisions
/// are guarded by an exact token comparison on lookup, so a collision
/// can cause a spurious miss-style recompute but never a wrong plan.
fn fingerprint(tokens: &[i32], spls: &SplsConfig) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for &t in tokens {
        eat(t as u32 as u64);
    }
    eat(spls.top_k.to_bits() as u64);
    eat(spls.sim_threshold.to_bits() as u64);
    eat(spls.ffn_threshold as u64);
    eat(spls.window as u64);
    h
}

struct Entry {
    /// Exact tokens (collision guard for the 64-bit fingerprint) —
    /// shared across a model's per-layer entries, not duplicated.
    tokens: Arc<[i32]>,
    spls: SplsConfig,
    plan: LayerPlan,
    /// Monotonic recency stamp (larger = more recent).
    tick: u64,
}

/// Aggregate cache counters, snapshot into `ServeMetrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Whole-model lookups fully served from cache.
    pub hits: usize,
    /// Whole-model lookups that fell through to the planner.
    pub misses: usize,
    /// Per-layer entries evicted by LRU.
    pub evictions: usize,
    /// Live per-layer entries.
    pub entries: usize,
    /// Configured per-layer entry capacity.
    pub capacity: usize,
}

impl CacheStats {
    /// Hit fraction over all whole-model lookups (0 when cold).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// LRU cache of per-layer SPLS plans.
pub struct PlanCache {
    map: HashMap<PlanKey, Entry>,
    capacity: usize,
    tick: u64,
    hits: usize,
    misses: usize,
    evictions: usize,
}

impl PlanCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "plan cache needs at least one slot");
        Self {
            map: HashMap::with_capacity(capacity.min(4096)),
            capacity,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up one layer's plan under a precomputed fingerprint;
    /// refreshes recency on hit. Does not touch the hit/miss counters
    /// (those count whole-model lookups).
    fn get_layer_fp(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
        fp: u64,
    ) -> Option<LayerPlan> {
        let key = PlanKey { bucket: seq_bucket(tokens.len()), method, layer, fingerprint: fp };
        self.tick += 1;
        let tick = self.tick;
        let entry = self.map.get_mut(&key)?;
        if entry.tokens.as_ref() != tokens || entry.spls != *spls {
            return None; // fingerprint collision: treat as a miss
        }
        entry.tick = tick;
        Some(entry.plan.clone())
    }

    /// Look up one layer's plan; refreshes recency on hit.
    pub fn get_layer(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
    ) -> Option<LayerPlan> {
        let fp = fingerprint(tokens, spls);
        self.get_layer_fp(tokens, spls, method, layer, fp)
    }

    /// Insert one layer's plan under a precomputed fingerprint,
    /// evicting the least-recently-used entry when at capacity.
    fn put_layer_fp(
        &mut self,
        tokens: Arc<[i32]>,
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
        fp: u64,
        plan: LayerPlan,
    ) {
        let key = PlanKey { bucket: seq_bucket(tokens.len()), method, layer, fingerprint: fp };
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k)
            {
                self.map.remove(&lru);
                self.evictions += 1;
            }
        }
        self.map.insert(key, Entry { tokens, spls: *spls, plan, tick: self.tick });
    }

    /// Insert one layer's plan.
    pub fn put_layer(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
        plan: LayerPlan,
    ) {
        let fp = fingerprint(tokens, spls);
        self.put_layer_fp(tokens.to_vec().into(), spls, method, layer, fp, plan);
    }

    /// Whole-model lookup: every layer must hit, else the lookup is a
    /// miss (partial residency is not useful — `plan_model` recomputes
    /// all layers anyway, since each layer's plan rides on the previous
    /// layers' activations). The fingerprint is computed once for all
    /// layers — the serving replicas serialize on this cache's mutex,
    /// so lookups stay cheap.
    pub fn get_model(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        n_layers: usize,
    ) -> Option<Vec<LayerPlan>> {
        let fp = fingerprint(tokens, spls);
        let mut plans = Vec::with_capacity(n_layers);
        for layer in 0..n_layers {
            match self.get_layer_fp(tokens, spls, method, layer, fp) {
                Some(p) => plans.push(p),
                None => {
                    self.misses += 1;
                    return None;
                }
            }
        }
        self.hits += 1;
        Some(plans)
    }

    /// Insert a whole model's plans: one entry per layer, all sharing
    /// one token allocation and one fingerprint computation.
    pub fn put_model(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        plans: &[LayerPlan],
    ) {
        let fp = fingerprint(tokens, spls);
        let shared: Arc<[i32]> = tokens.to_vec().into();
        for (layer, plan) in plans.iter().enumerate() {
            self.put_layer_fp(Arc::clone(&shared), spls, method, layer, fp, plan.clone());
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.map.len(),
            capacity: self.capacity,
        }
    }
}

/// Thread-safe plan cache handle shared by all serving replicas.
#[derive(Clone)]
pub struct SharedPlanCache(Arc<Mutex<PlanCache>>);

impl SharedPlanCache {
    pub fn new(capacity: usize) -> Self {
        Self(Arc::new(Mutex::new(PlanCache::new(capacity))))
    }

    /// Serve the plans from cache, or run `compute` (outside the lock)
    /// and insert the result. Two replicas racing on the same cold key
    /// both compute — plans are deterministic, so the duplicate insert
    /// is idempotent and still bit-identical.
    pub fn get_or_compute(
        &self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        n_layers: usize,
        compute: impl FnOnce() -> Vec<LayerPlan>,
    ) -> Vec<LayerPlan> {
        if let Some(plans) = self
            .0
            .lock()
            .unwrap()
            .get_model(tokens, spls, method, n_layers)
        {
            return plans;
        }
        let plans = compute();
        self.0
            .lock()
            .unwrap()
            .put_model(tokens, spls, method, &plans);
        plans
    }

    pub fn stats(&self) -> CacheStats {
        self.0.lock().unwrap().stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spls::plan::plan_layer;
    use crate::util::mat::MatI;
    use crate::util::rng::Xoshiro256pp;

    fn synth_plan(seed: u64) -> LayerPlan {
        let mut rng = Xoshiro256pp::new(seed);
        let pams: Vec<MatI> = (0..2)
            .map(|_| {
                MatI::from_fn(16, 16, |r, c| {
                    ((r * 7 + c * 3) % 31) as i32 + rng.int_in(-1, 1) as i32
                })
            })
            .collect();
        plan_layer(&pams, &SplsConfig::default())
    }

    fn toks(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn bucket_is_next_power_of_two_min_8() {
        assert_eq!(seq_bucket(1), 8);
        assert_eq!(seq_bucket(8), 8);
        assert_eq!(seq_bucket(9), 16);
        assert_eq!(seq_bucket(64), 64);
        assert_eq!(seq_bucket(65), 128);
    }

    #[test]
    fn hit_returns_equal_plan_and_counts() {
        let mut cache = PlanCache::new(8);
        let spls = SplsConfig::default();
        let t = toks(1, 64);
        let plans = vec![synth_plan(1), synth_plan(2)];
        assert!(cache.get_model(&t, &spls, QuantMethod::Hlog, 2).is_none());
        cache.put_model(&t, &spls, QuantMethod::Hlog, &plans);
        let got = cache.get_model(&t, &spls, QuantMethod::Hlog, 2).expect("hit");
        assert_eq!(got, plans, "cached plans must be bit-identical");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 2));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_tokens_methods_and_spls_do_not_alias() {
        let mut cache = PlanCache::new(32);
        let spls = SplsConfig::default();
        let t1 = toks(1, 64);
        let t2 = toks(2, 64);
        cache.put_model(&t1, &spls, QuantMethod::Hlog, &[synth_plan(1)]);
        assert!(cache.get_model(&t2, &spls, QuantMethod::Hlog, 1).is_none());
        assert!(cache.get_model(&t1, &spls, QuantMethod::Pot, 1).is_none());
        let other = SplsConfig { top_k: 0.5, ..spls };
        assert!(cache.get_model(&t1, &other, QuantMethod::Hlog, 1).is_none());
        assert!(cache.get_model(&t1, &spls, QuantMethod::Hlog, 1).is_some());
    }

    #[test]
    fn lru_evicts_oldest_entry_first() {
        let mut cache = PlanCache::new(2);
        let spls = SplsConfig::default();
        let (a, b, c) = (toks(1, 16), toks(2, 16), toks(3, 16));
        cache.put_model(&a, &spls, QuantMethod::Hlog, &[synth_plan(1)]);
        cache.put_model(&b, &spls, QuantMethod::Hlog, &[synth_plan(2)]);
        // touch a so b becomes LRU
        assert!(cache.get_model(&a, &spls, QuantMethod::Hlog, 1).is_some());
        cache.put_model(&c, &spls, QuantMethod::Hlog, &[synth_plan(3)]);
        assert!(cache.get_model(&b, &spls, QuantMethod::Hlog, 1).is_none(), "b evicted");
        assert!(cache.get_model(&a, &spls, QuantMethod::Hlog, 1).is_some(), "a retained");
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn partial_residency_is_a_miss() {
        let mut cache = PlanCache::new(8);
        let spls = SplsConfig::default();
        let t = toks(4, 32);
        cache.put_layer(&t, &spls, QuantMethod::Hlog, 0, synth_plan(1));
        // layer 1 missing -> whole-model lookup misses
        assert!(cache.get_model(&t, &spls, QuantMethod::Hlog, 2).is_none());
    }

    #[test]
    fn shared_cache_computes_once_then_hits() {
        let cache = SharedPlanCache::new(16);
        let spls = SplsConfig::default();
        let t = toks(5, 64);
        let plans = vec![synth_plan(9), synth_plan(10)];
        let computed = plans.clone();
        let first =
            cache.get_or_compute(&t, &spls, QuantMethod::Hlog, 2, move || computed);
        assert_eq!(first, plans);
        let second = cache.get_or_compute(&t, &spls, QuantMethod::Hlog, 2, || {
            panic!("second lookup must be served from cache")
        });
        assert_eq!(second, plans, "hit is bit-identical to the computed plans");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }
}
