//! SPLS plan cache for the serving tier: memoize `plan_model` results
//! so repeated request shapes skip host-side planning entirely — the
//! planner is the per-batch bottleneck once the executors are fast
//! (the serving-systems analogue of AccelTran's amortized
//! dynamic-sparsity scheduling across parallel compute units).
//!
//! Entries are keyed per **(seq-len bucket, quant method, layer)** plus
//! a fingerprint of the token sequence and the SPLS hyperparameters
//! (plans depend on activations, so the tokens are part of the
//! identity; the bucket keys let a deployment bound per-shape
//! residency). Eviction is LRU. A hit returns a clone of the exact
//! `LayerPlan` the planner produced, so cached plans are **bit-identical**
//! to freshly computed ones — asserted by `coordinator::server` tests.
//!
//! The decode tier memoizes **per-step plans** here too
//! (`get_step`/`put_step`): entries live in their own map under
//! [`decode_bucket`] power-of-two *prefix* buckets (a growing session
//! transitions buckets O(log L) times instead of once per step), keyed
//! by the exact token prefix + SPLS point + eviction parameters, so
//! replaying a prefix serves every step's planning from cache —
//! bit-equivalently, since a `StepPlan` fully determines the
//! predictor's post-step state.
//!
//! `PlanCache` is single-threaded; [`SharedPlanCache`] shards it by key
//! fingerprint across [`DEFAULT_SHARDS`] mutexes for the replica pool
//! (std sync only — no tokio in the vendored crate set, see DESIGN.md
//! §Environment), so replicas planning unrelated requests no longer
//! serialize on one lock. Lookups and inserts hold only their shard's
//! lock; planning itself never holds any.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::SplsConfig;
use crate::decode::incremental::StepPlan;
use crate::quant::QuantMethod;
use crate::spls::plan::LayerPlan;

/// Default entry capacity of a serving deployment's plan cache
/// (per-layer entries; 256 ≈ 128 distinct sequences on the 2-layer
/// tiny substrate).
pub const DEFAULT_CAPACITY: usize = 256;

/// Cache identity of one layer's plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Sequence-length bucket (next power of two, ≥ 8) — groups
    /// same-shape requests the way the compiled artifacts do.
    pub bucket: usize,
    /// Prediction quantizer the plan was computed with.
    pub method: QuantMethod,
    /// Layer index within the model.
    pub layer: usize,
    /// FNV-1a fingerprint of the token ids + SPLS hyperparameters.
    fingerprint: u64,
}

/// Bucket a sequence length like the artifact shapes do: next power of
/// two, clamped below at 8.
pub fn seq_bucket(len: usize) -> usize {
    len.max(8).next_power_of_two()
}

/// Decode-aware bucket for step-plan entries: power-of-two **prefix**
/// buckets (≥ 8), the partition key a bucket-scoped residency bound
/// would operate on. Prefill shapes arrive at a handful of fixed
/// lengths, but decode grows the prefix by 1 every step — per-length
/// buckets would make every step of every session its own group, while
/// power-of-two prefix buckets change only at 8 → 16 → 32 → …
/// boundaries: O(log L) groups per L-step generation (pinned by the
/// unit tests below). Today the bucket only partitions [`StepKey`]s —
/// per-bucket capacity bounds are the deployment hook, not yet wired.
pub fn decode_bucket(prefix_len: usize) -> usize {
    prefix_len.max(8).next_power_of_two()
}

/// FNV-1a over the token ids and the SPLS operating point. Collisions
/// are guarded by an exact token comparison on lookup, so a collision
/// can cause a spurious miss-style recompute but never a wrong plan.
fn fingerprint(tokens: &[i32], spls: &SplsConfig) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for &t in tokens {
        eat(t as u32 as u64);
    }
    eat(spls.top_k.to_bits() as u64);
    eat(spls.sim_threshold.to_bits() as u64);
    eat(spls.ffn_threshold as u64);
    eat(spls.window as u64);
    h
}

struct Entry {
    /// Exact tokens (collision guard for the 64-bit fingerprint) —
    /// shared across a model's per-layer entries, not duplicated.
    tokens: Arc<[i32]>,
    spls: SplsConfig,
    plan: LayerPlan,
    /// Monotonic recency stamp (larger = more recent).
    tick: u64,
}

/// Cache identity of one decode step's plan: the decode bucket of the
/// token prefix plus a fingerprint of the exact prefix, the SPLS
/// operating point, and the eviction parameters (budget/recent change
/// which slots exist, so they are part of the plan's identity).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct StepKey {
    bucket: usize,
    fingerprint: u64,
}

fn fingerprint_step(tokens: &[i32], spls: &SplsConfig, budget: usize, recent: usize) -> u64 {
    let mut h = fingerprint(tokens, spls);
    for v in [budget as u64, recent as u64] {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

struct StepEntry {
    tokens: Arc<[i32]>,
    spls: SplsConfig,
    budget: usize,
    recent: usize,
    plan: StepPlan,
    tick: u64,
}

/// Aggregate cache counters, snapshot into `ServeMetrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Whole-model lookups fully served from cache.
    pub hits: usize,
    /// Whole-model lookups that fell through to the planner.
    pub misses: usize,
    /// Per-layer entries evicted by LRU.
    pub evictions: usize,
    /// Live per-layer entries.
    pub entries: usize,
    /// Configured per-layer entry capacity.
    pub capacity: usize,
    /// Decode step-plan lookups served from cache.
    pub step_hits: usize,
    /// Decode step-plan lookups that fell through to the predictor.
    pub step_misses: usize,
    /// Live decode step-plan entries.
    pub step_entries: usize,
    /// Decode step-plan entries evicted by LRU (separate from the
    /// prefill-plan `evictions` so mixed workloads stay diagnosable).
    pub step_evictions: usize,
}

impl CacheStats {
    /// Hit fraction over all whole-model lookups (0 when cold).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Hit fraction over decode step-plan lookups (0 when cold).
    pub fn step_hit_rate(&self) -> f64 {
        let total = self.step_hits + self.step_misses;
        if total == 0 {
            0.0
        } else {
            self.step_hits as f64 / total as f64
        }
    }
}

/// LRU cache of per-layer SPLS plans plus decode step plans (separate
/// map, same capacity bound and LRU discipline).
pub struct PlanCache {
    map: HashMap<PlanKey, Entry>,
    steps: HashMap<StepKey, StepEntry>,
    capacity: usize,
    tick: u64,
    hits: usize,
    misses: usize,
    evictions: usize,
    step_hits: usize,
    step_misses: usize,
    step_evictions: usize,
}

impl PlanCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "plan cache needs at least one slot");
        Self {
            map: HashMap::with_capacity(capacity.min(4096)),
            steps: HashMap::new(),
            capacity,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            step_hits: 0,
            step_misses: 0,
            step_evictions: 0,
        }
    }

    /// Look up one layer's plan under a precomputed fingerprint;
    /// refreshes recency on hit. Does not touch the hit/miss counters
    /// (those count whole-model lookups).
    fn get_layer_fp(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
        fp: u64,
    ) -> Option<LayerPlan> {
        let key = PlanKey { bucket: seq_bucket(tokens.len()), method, layer, fingerprint: fp };
        self.tick += 1;
        let tick = self.tick;
        let entry = self.map.get_mut(&key)?;
        if entry.tokens.as_ref() != tokens || entry.spls != *spls {
            return None; // fingerprint collision: treat as a miss
        }
        entry.tick = tick;
        Some(entry.plan.clone())
    }

    /// Look up one layer's plan; refreshes recency on hit.
    pub fn get_layer(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
    ) -> Option<LayerPlan> {
        let fp = fingerprint(tokens, spls);
        self.get_layer_fp(tokens, spls, method, layer, fp)
    }

    /// Insert one layer's plan under a precomputed fingerprint,
    /// evicting the least-recently-used entry when at capacity.
    fn put_layer_fp(
        &mut self,
        tokens: Arc<[i32]>,
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
        fp: u64,
        plan: LayerPlan,
    ) {
        let key = PlanKey { bucket: seq_bucket(tokens.len()), method, layer, fingerprint: fp };
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k)
            {
                self.map.remove(&lru);
                self.evictions += 1;
            }
        }
        self.map.insert(key, Entry { tokens, spls: *spls, plan, tick: self.tick });
    }

    /// Insert one layer's plan.
    pub fn put_layer(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        layer: usize,
        plan: LayerPlan,
    ) {
        let fp = fingerprint(tokens, spls);
        self.put_layer_fp(tokens.to_vec().into(), spls, method, layer, fp, plan);
    }

    /// Whole-model lookup: every layer must hit, else the lookup is a
    /// miss (partial residency is not useful — `plan_model` recomputes
    /// all layers anyway, since each layer's plan rides on the previous
    /// layers' activations). The fingerprint is computed once for all
    /// layers — the serving replicas serialize on this cache's mutex,
    /// so lookups stay cheap.
    pub fn get_model(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        n_layers: usize,
    ) -> Option<Vec<LayerPlan>> {
        let fp = fingerprint(tokens, spls);
        let mut plans = Vec::with_capacity(n_layers);
        for layer in 0..n_layers {
            match self.get_layer_fp(tokens, spls, method, layer, fp) {
                Some(p) => plans.push(p),
                None => {
                    self.misses += 1;
                    return None;
                }
            }
        }
        self.hits += 1;
        Some(plans)
    }

    /// Insert a whole model's plans: one entry per layer, all sharing
    /// one token allocation and one fingerprint computation.
    pub fn put_model(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        plans: &[LayerPlan],
    ) {
        let fp = fingerprint(tokens, spls);
        let shared: Arc<[i32]> = tokens.to_vec().into();
        for (layer, plan) in plans.iter().enumerate() {
            self.put_layer_fp(Arc::clone(&shared), spls, method, layer, fp, plan.clone());
        }
    }

    /// Look up one decode step's plan under the exact token prefix +
    /// SPLS operating point + eviction parameters; refreshes recency.
    pub fn get_step(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        budget: usize,
        recent: usize,
    ) -> Option<StepPlan> {
        let key = StepKey {
            bucket: decode_bucket(tokens.len()),
            fingerprint: fingerprint_step(tokens, spls, budget, recent),
        };
        self.tick += 1;
        let tick = self.tick;
        let hit = match self.steps.get_mut(&key) {
            Some(e)
                if e.tokens.as_ref() == tokens
                    && e.spls == *spls
                    && e.budget == budget
                    && e.recent == recent =>
            {
                e.tick = tick;
                Some(e.plan.clone())
            }
            _ => None,
        };
        if hit.is_some() {
            self.step_hits += 1;
        } else {
            self.step_misses += 1;
        }
        hit
    }

    /// Insert one decode step's plan, evicting the LRU step entry at
    /// capacity (step entries share the configured capacity bound but
    /// live in their own map — decode residency never evicts prefill
    /// plans, and vice versa).
    pub fn put_step(
        &mut self,
        tokens: &[i32],
        spls: &SplsConfig,
        budget: usize,
        recent: usize,
        plan: StepPlan,
    ) {
        let key = StepKey {
            bucket: decode_bucket(tokens.len()),
            fingerprint: fingerprint_step(tokens, spls, budget, recent),
        };
        self.tick += 1;
        if self.steps.len() >= self.capacity && !self.steps.contains_key(&key) {
            if let Some(lru) = self
                .steps
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k)
            {
                self.steps.remove(&lru);
                self.step_evictions += 1;
            }
        }
        self.steps.insert(
            key,
            StepEntry {
                tokens: tokens.to_vec().into(),
                spls: *spls,
                budget,
                recent,
                plan,
                tick: self.tick,
            },
        );
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.map.len(),
            capacity: self.capacity,
            step_hits: self.step_hits,
            step_misses: self.step_misses,
            step_entries: self.steps.len(),
            step_evictions: self.step_evictions,
        }
    }
}

/// Lock shards a [`SharedPlanCache`] spreads its entries over. The
/// replica pool serializes every lookup/insert on the cache, so a
/// single mutex becomes the contention point as replicas scale; keys
/// route to a shard by fingerprint, which keeps a whole model's
/// per-layer entries (one fingerprint) on one lock while unrelated
/// requests proceed in parallel.
pub const DEFAULT_SHARDS: usize = 8;

/// Thread-safe plan cache handle shared by all serving replicas:
/// fingerprint-sharded `Mutex<PlanCache>`s (std sync only — no tokio in
/// the vendored crate set). Hit/miss/eviction counters live in the
/// shards and are summed by [`SharedPlanCache::stats`], so sharding is
/// invisible to metrics consumers (asserted below).
#[derive(Clone)]
pub struct SharedPlanCache {
    shards: Arc<[Mutex<PlanCache>]>,
}

impl SharedPlanCache {
    pub fn new(capacity: usize) -> Self {
        Self::with_shards(capacity, DEFAULT_SHARDS)
    }

    /// Build with an explicit shard count. The total entry capacity is
    /// split evenly (rounded up) across shards; shard count is clamped
    /// to the capacity so every shard can hold at least one entry.
    pub fn with_shards(capacity: usize, shards: usize) -> Self {
        let n = shards.max(1).min(capacity.max(1));
        let per_shard = capacity.max(1).div_ceil(n);
        let shards: Vec<Mutex<PlanCache>> =
            (0..n).map(|_| Mutex::new(PlanCache::new(per_shard))).collect();
        Self { shards: shards.into() }
    }

    fn shard(&self, fp: u64) -> &Mutex<PlanCache> {
        &self.shards[(fp % self.shards.len() as u64) as usize]
    }

    /// Lock a shard, recovering from poisoning: the cache is shared by
    /// every replica worker, so a worker panicking mid-insert must not
    /// take cache hits (or the whole tier) down with it. Every cached
    /// value is a complete, immutable plan inserted after construction
    /// — an unwind between `get` and `put` can at worst lose the
    /// insert, never corrupt an entry.
    fn lock(shard: &Mutex<PlanCache>) -> std::sync::MutexGuard<'_, PlanCache> {
        shard.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Serve the plans from cache, or run `compute` (outside any lock)
    /// and insert the result. Two replicas racing on the same cold key
    /// both compute — plans are deterministic, so the duplicate insert
    /// is idempotent and still bit-identical.
    pub fn get_or_compute(
        &self,
        tokens: &[i32],
        spls: &SplsConfig,
        method: QuantMethod,
        n_layers: usize,
        compute: impl FnOnce() -> Vec<LayerPlan>,
    ) -> Vec<LayerPlan> {
        let shard = self.shard(fingerprint(tokens, spls));
        if let Some(plans) = Self::lock(shard).get_model(tokens, spls, method, n_layers) {
            return plans;
        }
        let plans = compute();
        Self::lock(shard).put_model(tokens, spls, method, &plans);
        plans
    }

    /// Decode-step lookup (see [`PlanCache::get_step`]).
    pub fn get_step(
        &self,
        tokens: &[i32],
        spls: &SplsConfig,
        budget: usize,
        recent: usize,
    ) -> Option<StepPlan> {
        Self::lock(self.shard(fingerprint_step(tokens, spls, budget, recent)))
            .get_step(tokens, spls, budget, recent)
    }

    /// Decode-step insert (see [`PlanCache::put_step`]).
    pub fn put_step(
        &self,
        tokens: &[i32],
        spls: &SplsConfig,
        budget: usize,
        recent: usize,
        plan: StepPlan,
    ) {
        Self::lock(self.shard(fingerprint_step(tokens, spls, budget, recent)))
            .put_step(tokens, spls, budget, recent, plan)
    }

    /// Per-shard counter snapshots (index = shard position). [`stats`]
    /// is the sum of these; the split view feeds dashboards that watch
    /// the shard distribution (e.g. the gateway's `/metrics`).
    ///
    /// [`stats`]: SharedPlanCache::stats
    pub fn shard_stats(&self) -> Vec<CacheStats> {
        self.shards.iter().map(|s| Self::lock(s).stats()).collect()
    }

    /// Aggregate counters summed across every shard.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for shard in self.shards.iter() {
            let s = Self::lock(shard).stats();
            total.hits += s.hits;
            total.misses += s.misses;
            total.evictions += s.evictions;
            total.entries += s.entries;
            total.capacity += s.capacity;
            total.step_hits += s.step_hits;
            total.step_misses += s.step_misses;
            total.step_entries += s.step_entries;
            total.step_evictions += s.step_evictions;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spls::plan::plan_layer;
    use crate::util::mat::MatI;
    use crate::util::rng::Xoshiro256pp;

    fn synth_plan(seed: u64) -> LayerPlan {
        let mut rng = Xoshiro256pp::new(seed);
        let pams: Vec<MatI> = (0..2)
            .map(|_| {
                MatI::from_fn(16, 16, |r, c| {
                    ((r * 7 + c * 3) % 31) as i32 + rng.int_in(-1, 1) as i32
                })
            })
            .collect();
        plan_layer(&pams, &SplsConfig::default())
    }

    fn toks(seed: u64, l: usize) -> Vec<i32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..l).map(|_| rng.below(64) as i32).collect()
    }

    #[test]
    fn bucket_is_next_power_of_two_min_8() {
        assert_eq!(seq_bucket(1), 8);
        assert_eq!(seq_bucket(8), 8);
        assert_eq!(seq_bucket(9), 16);
        assert_eq!(seq_bucket(64), 64);
        assert_eq!(seq_bucket(65), 128);
    }

    fn synth_step(prefix: usize) -> StepPlan {
        use crate::decode::incremental::{HeadStepPlan, LayerStepPlan};
        StepPlan {
            layers: vec![LayerStepPlan {
                heads: vec![HeadStepPlan {
                    row: (0..prefix as i32).collect(),
                    keep: vec![true; prefix],
                    k8: vec![1, 2, 3, 4],
                    similar: false,
                }],
            }],
        }
    }

    #[test]
    fn decode_bucket_boundaries_pinned() {
        assert_eq!(decode_bucket(1), 8);
        assert_eq!(decode_bucket(8), 8);
        assert_eq!(decode_bucket(9), 16);
        assert_eq!(decode_bucket(16), 16);
        assert_eq!(decode_bucket(17), 32);
        assert_eq!(decode_bucket(64), 64);
        assert_eq!(decode_bucket(65), 128);
        assert_eq!(decode_bucket(128), 128);
    }

    #[test]
    fn decode_bucket_transitions_log_not_linear_over_growth_sweep() {
        // a 1..=128-step generation must touch only the 5 power-of-two
        // buckets and transition at most 4 times — not once per step
        let buckets: Vec<usize> = (1..=128).map(decode_bucket).collect();
        let mut distinct = buckets.clone();
        distinct.dedup();
        assert_eq!(distinct, vec![8, 16, 32, 64, 128]);
        let transitions = buckets.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 4);
    }

    #[test]
    fn step_growth_sweep_replays_at_full_hit_rate() {
        // first pass over a growing 1..=128 prefix populates; a replay
        // of the same generation must hit on every step
        let mut cache = PlanCache::new(256);
        let spls = SplsConfig::default();
        let full = toks(9, 128);
        for t in 1..=128 {
            let prefix = &full[..t];
            assert!(cache.get_step(prefix, &spls, 32, 4).is_none(), "cold step {t}");
            cache.put_step(prefix, &spls, 32, 4, synth_step(t));
        }
        for t in 1..=128 {
            let prefix = &full[..t];
            let plan = cache.get_step(prefix, &spls, 32, 4).expect("warm step");
            assert_eq!(plan, synth_step(t), "cached step plan must be bit-identical");
        }
        let s = cache.stats();
        assert_eq!((s.step_hits, s.step_misses, s.step_entries), (128, 128, 128));
        assert!((s.step_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn step_identity_includes_budget_and_recent() {
        let mut cache = PlanCache::new(16);
        let spls = SplsConfig::default();
        let t = toks(3, 24);
        cache.put_step(&t, &spls, 32, 4, synth_step(24));
        assert!(cache.get_step(&t, &spls, 16, 4).is_none(), "budget is identity");
        assert!(cache.get_step(&t, &spls, 32, 8).is_none(), "recent is identity");
        assert!(cache.get_step(&t, &spls, 32, 4).is_some());
    }

    #[test]
    fn step_entries_lru_evict_without_touching_layer_plans() {
        let mut cache = PlanCache::new(2);
        let spls = SplsConfig::default();
        let t = toks(5, 32);
        cache.put_model(&t, &spls, QuantMethod::Hlog, &[synth_plan(1), synth_plan(2)]);
        for len in [8usize, 12, 16] {
            cache.put_step(&t[..len], &spls, 32, 4, synth_step(len));
        }
        // capacity 2: the oldest step prefix fell out…
        assert!(cache.get_step(&t[..8], &spls, 32, 4).is_none());
        assert!(cache.get_step(&t[..16], &spls, 32, 4).is_some());
        // …but the prefill layer plans are untouched
        assert!(cache.get_model(&t, &spls, QuantMethod::Hlog, 2).is_some());
        let s = cache.stats();
        assert_eq!(s.step_entries, 2);
        assert_eq!(s.step_evictions, 1, "step eviction counted separately");
        assert_eq!(s.evictions, 0, "prefill evictions untouched by step churn");
    }

    #[test]
    fn hit_returns_equal_plan_and_counts() {
        let mut cache = PlanCache::new(8);
        let spls = SplsConfig::default();
        let t = toks(1, 64);
        let plans = vec![synth_plan(1), synth_plan(2)];
        assert!(cache.get_model(&t, &spls, QuantMethod::Hlog, 2).is_none());
        cache.put_model(&t, &spls, QuantMethod::Hlog, &plans);
        let got = cache.get_model(&t, &spls, QuantMethod::Hlog, 2).expect("hit");
        assert_eq!(got, plans, "cached plans must be bit-identical");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 2));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_tokens_methods_and_spls_do_not_alias() {
        let mut cache = PlanCache::new(32);
        let spls = SplsConfig::default();
        let t1 = toks(1, 64);
        let t2 = toks(2, 64);
        cache.put_model(&t1, &spls, QuantMethod::Hlog, &[synth_plan(1)]);
        assert!(cache.get_model(&t2, &spls, QuantMethod::Hlog, 1).is_none());
        assert!(cache.get_model(&t1, &spls, QuantMethod::Pot, 1).is_none());
        let other = SplsConfig { top_k: 0.5, ..spls };
        assert!(cache.get_model(&t1, &other, QuantMethod::Hlog, 1).is_none());
        assert!(cache.get_model(&t1, &spls, QuantMethod::Hlog, 1).is_some());
    }

    #[test]
    fn lru_evicts_oldest_entry_first() {
        let mut cache = PlanCache::new(2);
        let spls = SplsConfig::default();
        let (a, b, c) = (toks(1, 16), toks(2, 16), toks(3, 16));
        cache.put_model(&a, &spls, QuantMethod::Hlog, &[synth_plan(1)]);
        cache.put_model(&b, &spls, QuantMethod::Hlog, &[synth_plan(2)]);
        // touch a so b becomes LRU
        assert!(cache.get_model(&a, &spls, QuantMethod::Hlog, 1).is_some());
        cache.put_model(&c, &spls, QuantMethod::Hlog, &[synth_plan(3)]);
        assert!(cache.get_model(&b, &spls, QuantMethod::Hlog, 1).is_none(), "b evicted");
        assert!(cache.get_model(&a, &spls, QuantMethod::Hlog, 1).is_some(), "a retained");
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn partial_residency_is_a_miss() {
        let mut cache = PlanCache::new(8);
        let spls = SplsConfig::default();
        let t = toks(4, 32);
        cache.put_layer(&t, &spls, QuantMethod::Hlog, 0, synth_plan(1));
        // layer 1 missing -> whole-model lookup misses
        assert!(cache.get_model(&t, &spls, QuantMethod::Hlog, 2).is_none());
    }

    #[test]
    fn sharded_cache_stats_survive_sharding() {
        let cache = SharedPlanCache::with_shards(64, 4);
        let spls = SplsConfig::default();
        let seqs: Vec<Vec<i32>> = (0..12).map(|s| toks(100 + s, 32)).collect();
        for t in &seqs {
            let plans = vec![synth_plan(1), synth_plan(2)];
            let got = cache.get_or_compute(t, &spls, QuantMethod::Hlog, 2, move || plans);
            assert_eq!(got.len(), 2);
        }
        for t in &seqs {
            let got = cache.get_or_compute(t, &spls, QuantMethod::Hlog, 2, || {
                panic!("warm lookup must hit its shard")
            });
            assert_eq!(got.len(), 2);
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (12, 12), "counters sum across shards");
        assert_eq!(s.entries, 24, "every per-layer entry counted exactly once");
        assert_eq!(s.capacity, 64, "per-shard capacities sum back to the total");
        // step counters aggregate identically
        let t = &seqs[0];
        for len in [8usize, 16, 24] {
            assert!(cache.get_step(&t[..len], &spls, 32, 4).is_none());
            cache.put_step(&t[..len], &spls, 32, 4, synth_step(len));
            assert!(cache.get_step(&t[..len], &spls, 32, 4).is_some());
        }
        let s = cache.stats();
        assert_eq!((s.step_hits, s.step_misses, s.step_entries), (3, 3, 3));
    }

    #[test]
    fn sharded_cache_survives_concurrent_mixed_load() {
        let cache = SharedPlanCache::with_shards(128, 8);
        let spls = SplsConfig::default();
        let handles: Vec<_> = (0..8u64)
            .map(|i| {
                let cache = cache.clone();
                std::thread::spawn(move || {
                    let t = toks(200 + (i % 4), 24);
                    for _ in 0..20 {
                        let plans = cache
                            .get_or_compute(&t, &spls, QuantMethod::Hlog, 1, || {
                                vec![synth_plan(i)]
                            });
                        assert_eq!(plans.len(), 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 8 * 20, "every lookup counted exactly once");
        assert!(s.hits >= 8 * 20 - 8, "at most one racing cold miss per thread");
        assert!(s.entries <= 4, "4 distinct keys -> at most 4 live entries");
    }

    #[test]
    fn shared_cache_computes_once_then_hits() {
        let cache = SharedPlanCache::new(16);
        let spls = SplsConfig::default();
        let t = toks(5, 64);
        let plans = vec![synth_plan(9), synth_plan(10)];
        let computed = plans.clone();
        let first =
            cache.get_or_compute(&t, &spls, QuantMethod::Hlog, 2, move || computed);
        assert_eq!(first, plans);
        let second = cache.get_or_compute(&t, &spls, QuantMethod::Hlog, 2, || {
            panic!("second lookup must be served from cache")
        });
        assert_eq!(second, plans, "hit is bit-identical to the computed plans");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn shared_cache_keeps_serving_hits_after_a_holder_panics() {
        // a replica worker panicking while it holds a shard lock
        // poisons the mutex; the cache must recover (the guarded value
        // is a complete, immutable map) and keep serving warm hits to
        // the surviving replicas instead of cascading the panic
        let cache = SharedPlanCache::with_shards(16, 1); // one shard: the panic poisons it for sure
        let spls = SplsConfig::default();
        let t = toks(7, 48);
        let plans = vec![synth_plan(3), synth_plan(4)];
        let computed = plans.clone();
        cache.get_or_compute(&t, &spls, QuantMethod::Hlog, 2, move || computed);

        let poisoner = cache.clone();
        let tp = t.clone();
        let spls2 = spls;
        let result = std::thread::spawn(move || {
            let _hit = poisoner.get_or_compute(&tp, &spls2, QuantMethod::Hlog, 2, || {
                unreachable!("warm key")
            });
            // panic while a subsequent shard lock is held
            let _guard = SharedPlanCache::lock(poisoner.shard(fingerprint(&tp, &spls2)));
            panic!("poison the shard under load");
        })
        .join();
        assert!(result.is_err(), "the holder thread must have panicked");

        let got = cache.get_or_compute(&t, &spls, QuantMethod::Hlog, 2, || {
            panic!("post-poison lookup must still be a cache hit")
        });
        assert_eq!(got, plans, "post-panic hits stay bit-identical");
        assert!(cache.stats().hits >= 2, "stats path also tolerates the poison");
        assert!(cache.get_step(&t[..8], &spls, 32, 4).is_none(), "step path tolerates it too");
    }
}
