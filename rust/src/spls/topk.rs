//! Row-wise top-k pruning of the predicted attention matrix (PAM),
//! producing the sparsified predicted attention (SPA) — paper §III,
//! Fig 5(a) step 2.
//!
//! Keeps `ceil(k · L)` entries per row (at least 1); ties break toward
//! the lower column index (stable ordering), matching
//! `ref.topk_mask` in python so the three implementations agree.

use crate::util::mat::{Mat, MatI};

/// Number of entries kept per row for ratio `k` over row length `l`.
pub fn keep_count(k_ratio: f32, l: usize) -> usize {
    ((k_ratio * l as f32).ceil() as usize).clamp(1, l)
}

/// Row-wise top-k boolean mask over an integer score matrix.
pub fn topk_mask(scores: &MatI, k_ratio: f32) -> Mat<bool> {
    let keep = keep_count(k_ratio, scores.cols);
    let mut mask = Mat::from_vec(
        scores.rows,
        scores.cols,
        vec![false; scores.rows * scores.cols],
    );
    let mut idx: Vec<usize> = Vec::with_capacity(scores.cols);
    for r in 0..scores.rows {
        idx.clear();
        idx.extend(0..scores.cols);
        let row = scores.row(r);
        // stable sort by descending score -> ties keep lower column index
        idx.sort_by(|&a, &b| row[b].cmp(&row[a]));
        for &c in idx.iter().take(keep) {
            mask[(r, c)] = true;
        }
    }
    mask
}

/// Apply a top-k mask to the PAM, zeroing dropped entries: the SPA.
pub fn apply_mask(pam: &MatI, mask: &Mat<bool>) -> MatI {
    assert_eq!((pam.rows, pam.cols), (mask.rows, mask.cols));
    Mat::from_fn(pam.rows, pam.cols, |r, c| if mask[(r, c)] { pam[(r, c)] } else { 0 })
}

/// Convenience: PAM -> SPA in one step.
pub fn sparsify(pam: &MatI, k_ratio: f32) -> (MatI, Mat<bool>) {
    let mask = topk_mask(pam, k_ratio);
    (apply_mask(pam, &mask), mask)
}

/// Column indices of the SPA that are entirely zero *in the mask* —
/// drives K/V pruning (paper §III-C: "directly identify zero columns in
/// the SPA"). Uses the mask (kept positions), not values, so a kept
/// entry whose predicted score is 0 still counts as active.
pub fn zero_columns(mask: &Mat<bool>) -> Vec<usize> {
    (0..mask.cols)
        .filter(|&c| (0..mask.rows).all(|r| !mask[(r, c)]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, v: &[i32]) -> MatI {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn keep_count_bounds() {
        assert_eq!(keep_count(0.12, 64), 8); // ceil(7.68)
        assert_eq!(keep_count(0.0, 64), 1); // at least one
        assert_eq!(keep_count(1.0, 64), 64);
        assert_eq!(keep_count(0.1, 10), 1);
    }

    #[test]
    fn mask_keeps_row_maxima() {
        let pam = mat(2, 4, &[1, 9, 3, 7, -5, -1, -9, -2]);
        let m = topk_mask(&pam, 0.5);
        assert_eq!(m.row(0), &[false, true, false, true]);
        assert_eq!(m.row(1), &[false, true, false, true]);
    }

    #[test]
    fn ties_go_to_lower_column() {
        let pam = mat(1, 4, &[5, 5, 5, 5]);
        let m = topk_mask(&pam, 0.5);
        assert_eq!(m.row(0), &[true, true, false, false]);
    }

    #[test]
    fn spa_zeroes_dropped() {
        let pam = mat(2, 4, &[1, 9, 3, 7, -5, -1, -9, -2]);
        let (spa, _) = sparsify(&pam, 0.5);
        assert_eq!(spa.row(0), &[0, 9, 0, 7]);
        assert_eq!(spa.row(1), &[0, -1, 0, -2]);
    }

    #[test]
    fn zero_columns_detected() {
        let pam = mat(3, 4, &[9, 1, 1, 1, 8, 1, 1, 1, 7, 1, 1, 1]);
        let (_, mask) = sparsify(&pam, 0.25); // keep 1/row -> col 0 only
        assert_eq!(zero_columns(&mask), vec![1, 2, 3]);
    }

    #[test]
    fn full_ratio_has_no_zero_columns() {
        let pam = mat(3, 3, &[0; 9]);
        let (_, mask) = sparsify(&pam, 1.0);
        assert!(zero_columns(&mask).is_empty());
    }

    #[test]
    fn per_row_count_invariant() {
        // property: every row keeps exactly keep_count entries
        let mut rng = crate::util::rng::Xoshiro256pp::new(5);
        for _ in 0..20 {
            let l = 1 + rng.below(40) as usize;
            let pam = Mat::from_fn(l, l, |_, _| rng.int_in(-1000, 1000) as i32);
            for &k in &[0.05f32, 0.12, 0.3, 0.9] {
                let m = topk_mask(&pam, k);
                let keep = keep_count(k, l);
                for r in 0..l {
                    assert_eq!(m.row(r).iter().filter(|&&b| b).count(), keep);
                }
            }
        }
    }

    #[test]
    fn prop_mask_keeps_exactly_k_and_only_top_scores() {
        // property: each row keeps exactly keep_count entries, and every
        // kept entry's score is ≥ every dropped entry's score
        crate::util::prop::check(60, |rng| {
            let l = 1 + rng.below(32) as usize;
            let k = rng.f64() as f32;
            // small value range forces plenty of ties
            let pam = Mat::from_fn(l, l, |_, _| rng.int_in(-4, 4) as i32);
            let mask = topk_mask(&pam, k);
            let keep = keep_count(k, l);
            for r in 0..l {
                let kept: Vec<usize> =
                    (0..l).filter(|&c| mask[(r, c)]).collect();
                assert_eq!(kept.len(), keep, "row {r} kept {} of {keep}", kept.len());
                let min_kept = kept.iter().map(|&c| pam[(r, c)]).min().unwrap();
                let max_dropped = (0..l)
                    .filter(|&c| !mask[(r, c)])
                    .map(|c| pam[(r, c)])
                    .max();
                if let Some(max_dropped) = max_dropped {
                    assert!(
                        min_kept >= max_dropped,
                        "row {r}: kept {min_kept} < dropped {max_dropped}"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_ties_deterministic_toward_lower_column() {
        // property: the mask is a pure function of the scores (two calls
        // agree), and among equal scores the lower column index wins —
        // the stable-ordering contract shared with python's ref.topk_mask
        crate::util::prop::check(60, |rng| {
            let l = 2 + rng.below(24) as usize;
            let k = 0.05 + rng.f64() as f32 * 0.9;
            let pam = Mat::from_fn(l, l, |_, _| rng.int_in(-3, 3) as i32);
            let m1 = topk_mask(&pam, k);
            let m2 = topk_mask(&pam, k);
            assert_eq!(m1.data, m2.data, "mask not deterministic");
            for r in 0..l {
                for c_dropped in 0..l {
                    if m1[(r, c_dropped)] {
                        continue;
                    }
                    // no kept entry with the same score at a higher column
                    for c_kept in (c_dropped + 1)..l {
                        if m1[(r, c_kept)] {
                            assert!(
                                pam[(r, c_kept)] > pam[(r, c_dropped)],
                                "row {r}: tie broke toward higher col {c_kept} over {c_dropped}"
                            );
                        }
                    }
                }
            }
        });
    }
}
