//! Fixed-window local similarity over the SPA (paper §III-B).
//!
//! The L×L SPA is partitioned into non-overlapping windows of `w` rows
//! (remainder rows form a final short window). Within a window, rows are
//! compared by L1 distance; each row either joins an existing *critical*
//! row as a *similar* row, or becomes critical itself. Windows are
//! independent (the hardware parallelizes across them); the total cost
//! is O(L·w·L) = `L²(w-1)` adds/subs in the worst case, versus the
//! quadratic `l(l-1)/2 · L` of global similarity.
//!
//! The threshold `s` is on the *normalized* L1 distance
//! `Σ|aᵢ−bᵢ| / max(Σ|aᵢ|, Σ|bᵢ|, 1)`: larger `s` admits more rows as
//! similar (paper: "larger s for QKV induce[s] greater sparsity").

use crate::util::mat::MatI;

/// The similarity verdict for every row of one head's SPA.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SimilarityMap {
    /// `rep[r]` = index of the critical row representing row `r`
    /// (`rep[r] == r` iff row r is critical).
    pub rep: Vec<usize>,
    /// Window size used (for accounting).
    pub window: usize,
}

impl SimilarityMap {
    /// Indices of critical rows, ascending.
    pub fn critical_rows(&self) -> Vec<usize> {
        self.rep
            .iter()
            .enumerate()
            .filter(|&(r, &c)| r == c)
            .map(|(r, _)| r)
            .collect()
    }

    /// Number of similar (skipped) rows.
    pub fn n_similar(&self) -> usize {
        self.rep.iter().enumerate().filter(|&(r, &c)| r != c).count()
    }

    /// Fraction of rows whose Q generation is skipped.
    pub fn q_sparsity(&self) -> f64 {
        self.n_similar() as f64 / self.rep.len().max(1) as f64
    }

    /// Invariant check: representatives are critical, in-window, and at
    /// a lower-or-equal index (greedy scan order).
    pub fn validate(&self) -> bool {
        self.rep.iter().enumerate().all(|(r, &c)| {
            c <= r && self.rep[c] == c && (r / self.window == c / self.window)
        })
    }
}

/// Normalized L1 distance between two rows.
#[inline]
pub fn l1_norm_dist(a: &[i32], b: &[i32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut diff: i64 = 0;
    let mut na: i64 = 0;
    let mut nb: i64 = 0;
    for (&x, &y) in a.iter().zip(b) {
        diff += (x as i64 - y as i64).abs();
        na += (x as i64).abs();
        nb += (y as i64).abs();
    }
    diff as f64 / na.max(nb).max(1) as f64
}

/// Greedy windowed similarity detection over the SPA rows.
///
/// Within each window the first row is critical; every later row is
/// compared against the window's critical rows in order and joins the
/// first one within threshold, else becomes critical.
pub fn local_similarity(spa: &MatI, window: usize, threshold: f32) -> SimilarityMap {
    assert!(window >= 1);
    let l = spa.rows;
    let mut rep = vec![0usize; l];
    let mut criticals: Vec<usize> = Vec::with_capacity(window);
    let mut w0 = 0;
    while w0 < l {
        let w1 = (w0 + window).min(l);
        criticals.clear();
        for r in w0..w1 {
            let mut assigned = None;
            for &c in &criticals {
                if l1_norm_dist(spa.row(r), spa.row(c)) <= threshold as f64 {
                    assigned = Some(c);
                    break;
                }
            }
            match assigned {
                Some(c) => rep[r] = c,
                None => {
                    rep[r] = r;
                    criticals.push(r);
                }
            }
        }
        w0 = w1;
    }
    SimilarityMap { rep, window }
}

/// Count of L1 row-comparisons performed by the windowed scheme on an
/// L-row SPA in the worst case (every row critical): per window of size
/// w it is w(w-1)/2; the paper's headline is the per-element cost
/// `L²(w-1)` adds versus global similarity's `L²(L-1)/2`-ish scaling.
pub fn worst_case_comparisons(l: usize, window: usize) -> usize {
    let full = l / window;
    let rem = l % window;
    full * window * (window - 1) / 2 + rem * rem.saturating_sub(1) / 2
}

/// Fraction of windows in one attention head that contain at least one
/// similar row pair — the RWS metric behind paper Fig 4.
pub fn ratio_windows_similar(spa: &MatI, window: usize, threshold: f32) -> f64 {
    let sm = local_similarity(spa, window, threshold);
    let l = spa.rows;
    let n_windows = l.div_ceil(window);
    let mut similar_windows = 0usize;
    let mut w0 = 0;
    while w0 < l {
        let w1 = (w0 + window).min(l);
        if (w0..w1).any(|r| sm.rep[r] != r) {
            similar_windows += 1;
        }
        w0 = w1;
    }
    similar_windows as f64 / n_windows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::Mat;

    fn mat(rows: usize, cols: usize, v: &[i32]) -> MatI {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn identical_rows_in_window_collapse() {
        let spa = mat(4, 3, &[1, 2, 3, 1, 2, 3, 9, 9, 9, 1, 2, 3]);
        let sm = local_similarity(&spa, 4, 0.0);
        assert_eq!(sm.rep, vec![0, 0, 2, 0]);
        assert_eq!(sm.critical_rows(), vec![0, 2]);
        assert_eq!(sm.n_similar(), 2);
        assert!(sm.validate());
    }

    #[test]
    fn similarity_respects_window_boundaries() {
        // rows 0 and 2 identical but in different windows (w = 2)
        let spa = mat(4, 2, &[5, 5, 0, 9, 5, 5, 0, 9]);
        let sm = local_similarity(&spa, 2, 0.0);
        assert_eq!(sm.rep, vec![0, 1, 2, 3]); // nothing collapses across windows
        assert!(sm.validate());
    }

    #[test]
    fn threshold_zero_requires_exact_match() {
        let spa = mat(2, 2, &[10, 0, 10, 1]);
        assert_eq!(local_similarity(&spa, 2, 0.0).n_similar(), 0);
        // dist = 1/11 ≈ 0.09 -> similar at s = 0.1
        assert_eq!(local_similarity(&spa, 2, 0.1).n_similar(), 1);
    }

    #[test]
    fn monotone_in_threshold() {
        let mut rng = crate::util::rng::Xoshiro256pp::new(11);
        let spa = Mat::from_fn(32, 16, |_, _| rng.int_in(-50, 50) as i32);
        let mut prev = 0usize;
        for s in [0.0f32, 0.2, 0.5, 0.8, 1.0, 2.0] {
            let n = local_similarity(&spa, 8, s).n_similar();
            assert!(n >= prev, "similarity not monotone at s={s}");
            prev = n;
        }
    }

    #[test]
    fn l1_dist_properties() {
        assert_eq!(l1_norm_dist(&[1, 2], &[1, 2]), 0.0);
        assert!((l1_norm_dist(&[2, 0], &[0, 2]) - 2.0).abs() < 1e-12);
        assert_eq!(l1_norm_dist(&[0, 0], &[0, 0]), 0.0); // guarded denom
        // symmetry
        let a = [3, -4, 0, 9];
        let b = [-1, 2, 5, 9];
        assert_eq!(l1_norm_dist(&a, &b), l1_norm_dist(&b, &a));
    }

    #[test]
    fn remainder_window_covered() {
        // L = 10, w = 8: rows 8, 9 form a short second window
        let spa = Mat::from_fn(10, 4, |r, _| if r >= 8 { 7 } else { r as i32 * 10 });
        let sm = local_similarity(&spa, 8, 0.0);
        assert_eq!(sm.rep[9], 8);
        assert!(sm.validate());
    }

    #[test]
    fn worst_case_comparison_count() {
        assert_eq!(worst_case_comparisons(16, 8), 2 * 28);
        assert_eq!(worst_case_comparisons(10, 8), 28 + 1);
        // windowed << global for realistic L
        let l = 512;
        assert!(worst_case_comparisons(l, 8) < l * (l - 1) / 2 / 10);
    }

    #[test]
    fn rws_full_and_empty() {
        let same = Mat::from_fn(16, 4, |_, _| 3);
        assert_eq!(ratio_windows_similar(&same, 8, 0.0), 1.0);
        let distinct = Mat::from_fn(16, 4, |r, c| (r * 17 + c * 5) as i32);
        assert_eq!(ratio_windows_similar(&distinct, 8, 0.0), 0.0);
    }
}
