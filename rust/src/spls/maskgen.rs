//! Pluggable keep-mask generators for the incremental decode predictor.
//!
//! The SPLS decode path reduces every per-step sparsity decision to one
//! question: *given the predicted attention row over the cached slots,
//! which slots does this step attend to?* [`MaskGen`] isolates exactly
//! that question so alternative structured-sparsity schemes can ride
//! the same predictor, KV cache and gated-attention executor:
//!
//! * [`SplsTopK`] — the paper's rule: row top-k by predicted value with
//!   the diagonal always kept (`spls::causal::topk_row_keep_with_diagonal`,
//!   the single selection rule shared with the prefill causal mask).
//! * [`ThreeComponent`] — the Spark/DeepSeek-style statically structured
//!   mask (PAPERS.md; SNIPPETS.md §3): a **local window** of the newest
//!   slots, a few **global** sink slots at the start of the sequence,
//!   and a **learned top-k** component over the remaining middle ranked
//!   by predicted `|PAM|` magnitude — the same magnitude signal the
//!   eviction scores accumulate.
//!
//! Every generator must keep the diagonal (the newest slot): the decode
//! engine's recovery-by-replication semantics and the keep-mask
//! non-empty assertion both rely on it. Masks produced by a non-default
//! generator are **not** memoized in the shared step-plan cache (plans
//! are keyed on the SPLS operating point only), and prefix sharing
//! publishes/attaches only under the default generator — both guards
//! live in `decode::step`.

use crate::config::SplsConfig;

/// A keep-mask generator: maps one predicted attention row (int32 PAM
/// row over the `n` cached slots, slot `n-1` = the new token's own
/// diagonal) to the slots the step attends to.
pub trait MaskGen: Send + Sync {
    /// Short stable name (reports, logs).
    fn name(&self) -> &'static str;

    /// Build the keep-mask. `row` is never empty; implementations must
    /// keep at least the diagonal (last slot).
    fn keep(&self, row: &[i32], spls: &SplsConfig) -> Vec<bool>;
}

/// The default SPLS rule: row top-k (ties toward the higher predicted
/// value, then the lower slot), diagonal always kept.
#[derive(Clone, Copy, Debug, Default)]
pub struct SplsTopK;

impl MaskGen for SplsTopK {
    fn name(&self) -> &'static str {
        "spls-topk"
    }

    fn keep(&self, row: &[i32], spls: &SplsConfig) -> Vec<bool> {
        crate::spls::causal::topk_row_keep_with_diagonal(row, spls.top_k)
    }
}

/// Spark/DeepSeek-style three-component structured mask: local window +
/// global sinks + learned top-k over the middle. Deterministic: the
/// learned component ranks by `|row|` magnitude, ties toward the lower
/// slot (stable sort).
#[derive(Clone, Copy, Debug)]
pub struct ThreeComponent {
    /// Newest slots always kept (≥ 1; the diagonal is inside it).
    pub window: usize,
    /// Fraction of the visible slots granted to the learned component
    /// (on top of the window and globals), `ceil(top_k · n)`.
    pub top_k: f32,
    /// Oldest slots always kept (attention sinks).
    pub global: usize,
}

impl Default for ThreeComponent {
    fn default() -> Self {
        Self { window: 8, top_k: 0.12, global: 1 }
    }
}

impl MaskGen for ThreeComponent {
    fn name(&self) -> &'static str {
        "three-component"
    }

    fn keep(&self, row: &[i32], _spls: &SplsConfig) -> Vec<bool> {
        let n = row.len();
        assert!(n >= 1);
        let mut keep = vec![false; n];
        // 1. local window: the newest `window` slots (clamped ≥ 1 so
        //    the diagonal is always kept)
        let w = self.window.max(1).min(n);
        for k in keep.iter_mut().skip(n - w) {
            *k = true;
        }
        // 2. global sinks: the oldest `global` slots
        for k in keep.iter_mut().take(self.global.min(n)) {
            *k = true;
        }
        // 3. learned top-k over the uncovered middle, ranked by
        //    predicted |PAM| magnitude (the eviction-score signal)
        let extra = ((self.top_k * n as f32).ceil()) as usize;
        if extra > 0 {
            let mut mid: Vec<usize> = (0..n).filter(|&i| !keep[i]).collect();
            mid.sort_by(|&a, &b| row[b].unsigned_abs().cmp(&row[a].unsigned_abs()));
            for &i in mid.iter().take(extra) {
                keep[i] = true;
            }
        }
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kept(keep: &[bool]) -> Vec<usize> {
        keep.iter().enumerate().filter(|&(_, &k)| k).map(|(i, _)| i).collect()
    }

    #[test]
    fn spls_topk_matches_the_shared_selection_rule() {
        let spls = SplsConfig { top_k: 0.4, ..SplsConfig::default() };
        let row = [50, -3, 40, 7, 1];
        assert_eq!(
            SplsTopK.keep(&row, &spls),
            crate::spls::causal::topk_row_keep_with_diagonal(&row, 0.4)
        );
    }

    #[test]
    fn three_component_keeps_window_globals_and_learned_slots() {
        let g = ThreeComponent { window: 2, top_k: 0.2, global: 1 };
        // n = 10: window = slots 8..10, global = slot 0, learned
        // ceil(0.2·10) = 2 from the middle by |row|: slots 3 (|-90|)
        // and 5 (80)
        let row = [1, 2, 3, -90, 4, 80, 5, 6, 7, 8];
        let keep = g.keep(&row, &SplsConfig::default());
        assert_eq!(kept(&keep), vec![0, 3, 5, 8, 9]);
    }

    #[test]
    fn three_component_ties_resolve_to_the_lower_slot() {
        let g = ThreeComponent { window: 1, top_k: 0.2, global: 0 };
        // n = 5 → 1 learned slot; middle slots 0..4 all equal → slot 0
        let keep = g.keep(&[7, 7, 7, 7, 7], &SplsConfig::default());
        assert_eq!(kept(&keep), vec![0, 4]);
    }

    #[test]
    fn three_component_always_keeps_the_diagonal() {
        let g = ThreeComponent { window: 0, top_k: 0.0, global: 0 };
        let keep = g.keep(&[9, 9, 9], &SplsConfig::default());
        assert!(keep[2], "window clamps to ≥ 1: the diagonal survives");
        assert_eq!(kept(&keep), vec![2]);
    }

    #[test]
    fn three_component_window_covering_everything_is_full_keep() {
        let g = ThreeComponent { window: 64, top_k: 0.0, global: 0 };
        let keep = g.keep(&[1, -2, 3, -4], &SplsConfig::default());
        assert_eq!(keep, vec![true; 4]);
    }
}
