//! Most Frequent Index (MFI) token similarity for FFN sparsification
//! (paper §III-D, Fig 9).
//!
//! A token's similarity pattern differs across heads, so token-level
//! similarity for the FFN is decided by voting: for token `t`, each head
//! contributes the critical-row index representing row `t` in that head;
//! the most frequent critical index (MFI) wins, and if its occurrence
//! count reaches the threshold `f` and it is not `t` itself, token `t`
//! is declared similar to token MFI and its FFN computation is skipped
//! (recovered by replication after the FFN).

use crate::spls::similarity::SimilarityMap;

/// Token-level FFN plan: `rep[t]` = representative token computed in the
/// FFN (`rep[t] == t` iff token t is computed).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FfnPlan {
    pub rep: Vec<usize>,
}

impl FfnPlan {
    pub fn n_tokens(&self) -> usize {
        self.rep.len()
    }

    pub fn computed_tokens(&self) -> Vec<usize> {
        self.rep
            .iter()
            .enumerate()
            .filter(|&(t, &r)| t == r)
            .map(|(t, _)| t)
            .collect()
    }

    /// Fraction of tokens skipped in the FFN.
    pub fn ffn_sparsity(&self) -> f64 {
        let skipped = self.rep.iter().enumerate().filter(|&(t, &r)| t != r).count();
        skipped as f64 / self.rep.len().max(1) as f64
    }

    /// Invariant: every representative is itself computed (no chains).
    pub fn validate(&self) -> bool {
        self.rep.iter().all(|&r| self.rep[r] == r)
    }
}

/// Per-token MFI vote result (exposed for the figure-19 analysis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MfiVote {
    /// Most frequent critical index across heads.
    pub mfi: usize,
    /// Its occurrence count (out of #heads).
    pub count: usize,
}

/// Compute each token's MFI over the per-head similarity maps.
pub fn mfi_votes(heads: &[SimilarityMap]) -> Vec<MfiVote> {
    assert!(!heads.is_empty());
    let l = heads[0].rep.len();
    assert!(heads.iter().all(|h| h.rep.len() == l));
    (0..l)
        .map(|t| {
            // mode over heads of the critical index representing row t;
            // ties toward the lower index (deterministic, matches the
            // hardware's counter-compare order).
            let mut counts: Vec<(usize, usize)> = Vec::with_capacity(heads.len());
            for h in heads {
                let c = h.rep[t];
                match counts.iter_mut().find(|(idx, _)| *idx == c) {
                    Some((_, n)) => *n += 1,
                    None => counts.push((c, 1)),
                }
            }
            let &(mfi, count) = counts
                .iter()
                .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
                .unwrap();
            MfiVote { mfi, count }
        })
        .collect()
}

/// Build the FFN plan: token `t` is similar to `mfi` iff `mfi != t`,
/// `count >= f`, and the chain resolves to a computed token. A *smaller*
/// `f` admits more similar tokens → more FFN sparsity (paper Fig 19).
pub fn ffn_plan(heads: &[SimilarityMap], f_threshold: usize) -> FfnPlan {
    let votes = mfi_votes(heads);
    let l = votes.len();
    let mut rep: Vec<usize> = (0..l).collect();
    for (t, v) in votes.iter().enumerate() {
        if v.mfi != t && v.count >= f_threshold {
            rep[t] = v.mfi;
        }
    }
    // Resolve chains (t -> a -> b): follow until a fixpoint, with a path
    // bound of l to guard against cycles; any token on a cycle becomes
    // its own representative (computed).
    let resolved: Vec<usize> = (0..l)
        .map(|t| {
            let mut cur = t;
            for _ in 0..l {
                let nxt = rep[cur];
                if nxt == cur {
                    return cur;
                }
                cur = nxt;
            }
            t // cycle: compute t itself
        })
        .collect();
    let mut rep = resolved;
    // After cycle-breaking some reps may point at tokens that resolved to
    // themselves being skipped; one more normalization pass guarantees
    // rep[rep[t]] == rep[t].
    for t in 0..l {
        let r = rep[t];
        if rep[r] != r {
            rep[t] = t;
        }
    }
    FfnPlan { rep }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sm(rep: Vec<usize>) -> SimilarityMap {
        SimilarityMap { window: 8, rep }
    }

    #[test]
    fn unanimous_vote_collapses_token() {
        // 3 heads, 4 tokens; token 1 maps to 0 in every head
        let heads = vec![
            sm(vec![0, 0, 2, 3]),
            sm(vec![0, 0, 2, 3]),
            sm(vec![0, 0, 2, 3]),
        ];
        let votes = mfi_votes(&heads);
        assert_eq!(votes[1], MfiVote { mfi: 0, count: 3 });
        let plan = ffn_plan(&heads, 2);
        assert_eq!(plan.rep, vec![0, 0, 2, 3]);
        assert!(plan.validate());
        assert!((plan.ffn_sparsity() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn threshold_blocks_weak_votes() {
        // token 1 -> 0 in only 1 of 3 heads
        let heads = vec![
            sm(vec![0, 0, 2, 3]),
            sm(vec![0, 1, 2, 3]),
            sm(vec![0, 1, 2, 3]),
        ];
        // MFI of token 1 is 1 (count 2) -> self, stays computed
        let plan = ffn_plan(&heads, 2);
        assert_eq!(plan.rep, vec![0, 1, 2, 3]);
        // with f = 1 the non-self vote still loses the mode to self
        let votes = mfi_votes(&heads);
        assert_eq!(votes[1].mfi, 1);
    }

    #[test]
    fn smaller_f_more_sparsity() {
        // token 2 -> 0 in 2 of 4 heads; token 3 -> 0 in 3 of 4
        let heads = vec![
            sm(vec![0, 1, 0, 0]),
            sm(vec![0, 1, 0, 0]),
            sm(vec![0, 1, 2, 0]),
            sm(vec![0, 1, 2, 3]),
        ];
        let s_hi_f = ffn_plan(&heads, 4).ffn_sparsity();
        let s_mid_f = ffn_plan(&heads, 3).ffn_sparsity();
        let s_lo_f = ffn_plan(&heads, 2).ffn_sparsity();
        assert!(s_lo_f >= s_mid_f && s_mid_f >= s_hi_f);
        assert_eq!(s_hi_f, 0.0);
    }

    #[test]
    fn chains_resolve_to_computed_tokens() {
        // votes produce 2 -> 1 and 1 -> 0: chain must flatten to 2 -> 0
        let heads = vec![sm(vec![0, 0, 1, 3]), sm(vec![0, 0, 1, 3])];
        let plan = ffn_plan(&heads, 2);
        assert!(plan.validate());
        assert_eq!(plan.rep[2], 0);
        assert_eq!(plan.rep[1], 0);
    }

    #[test]
    fn tie_vote_prefers_lower_index() {
        // token 2: heads split 1/1 between critical 0 and critical 2(self)
        let heads = vec![sm(vec![0, 1, 0]), sm(vec![0, 1, 2])];
        let votes = mfi_votes(&heads);
        assert_eq!(votes[2].mfi, 0);
        assert_eq!(votes[2].count, 1);
    }

    #[test]
    fn all_self_plan_is_dense() {
        let heads = vec![sm((0..8).collect()), sm((0..8).collect())];
        let plan = ffn_plan(&heads, 1);
        assert_eq!(plan.ffn_sparsity(), 0.0);
        assert_eq!(plan.computed_tokens().len(), 8);
    }

    #[test]
    #[should_panic]
    fn mismatched_head_lengths_rejected() {
        mfi_votes(&[sm(vec![0, 1]), sm(vec![0, 1, 2])]);
    }
}
