//! SPLS — Sparsity Prediction with Local Similarity (paper §III).
//!
//! The full prediction pipeline (Fig 5a):
//!
//! 1. [`predict`] — HLog attention prediction through the bit-level
//!    unit model (SD → SJA → converter) producing the PAM;
//! 2. [`topk`] — row-wise top-k pruning producing the SPA;
//! 3. [`similarity`] — fixed-window local L1 similarity over the SPA;
//! 4. [`qkv`] — similarity-based Q and column-based K/V sparsification;
//! 5. [`mfi`] — Most-Frequent-Index token similarity for the FFN;
//! 6. [`plan`] — the combined `SparsityPlan` + FLOP accounting;
//! 7. [`plan_cache`] — the serving tier's LRU memo of per-layer plans
//!    (hits bit-identical to fresh planning);
//! 8. [`maskgen`] — pluggable decode keep-mask generators: the SPLS
//!    top-k rule plus the Spark/DeepSeek-style three-component
//!    (window + top-k + global) structured mask.

pub mod causal;
pub mod maskgen;
pub mod mfi;
pub mod plan;
pub mod plan_cache;
pub mod predict;
pub mod qkv;
pub mod similarity;
pub mod topk;

pub use causal::{
    apply_causal_mask, causal_local_similarity, causal_row_similarity, causal_topk_mask,
    topk_row_keep_with_diagonal,
};
pub use maskgen::{MaskGen, SplsTopK, ThreeComponent};
pub use mfi::{ffn_plan, FfnPlan, MfiVote};
pub use plan::{
    plan_layer_causal,
    computation_reduction, dense_layer_flops, dense_model_flops, keep_density,
    lower_mask_rows, plan_layer, plan_layer_from_inputs, sparse_layer_flops, CsrRows,
    LayerFlops, LayerPlan,
};
pub use plan_cache::{decode_bucket, seq_bucket, CacheStats, PlanCache, PlanKey, SharedPlanCache};
pub use predict::{predict_attention, predict_matmul, predict_matmul_faithful, SjaProduct};
pub use qkv::{recover_rows, HeadPlan};
pub use similarity::{local_similarity, ratio_windows_similar, SimilarityMap};
pub use topk::{sparsify, topk_mask};
