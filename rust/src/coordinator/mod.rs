//! L3 serving coordinator: request queue → dynamic batcher → worker
//! pool executing the AOT-compiled PJRT executables, plus the
//! cluster-level workload partitioner modelling the paper's 125-unit /
//! 25-cluster deployment (§V-C). Python never runs here.

pub mod batcher;
pub mod loadgen;
pub mod partition;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use loadgen::{arrivals, trace_stats, Arrival, TraceStats};
pub use partition::{partition_workload, ClusterAssignment, WorkItem};
pub use server::{Mode, Reply, ServeMetrics, Server};
