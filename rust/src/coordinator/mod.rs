//! L3 serving coordinator: request queue → admission + continuous
//! batching on the leader → a multi-replica, data-parallel worker tier
//! (per-replica work-stealing deques, each replica owning its own
//! executor handle) → replies, with host-side SPLS planning amortized
//! through the shared plan cache (`spls::plan_cache`). Also the
//! cluster-level workload partitioner modelling the paper's 125-unit /
//! 25-cluster deployment (§V-C). Python never runs here.

pub mod batcher;
pub mod loadgen;
pub mod partition;
pub mod replica;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use loadgen::{arrivals, trace_stats, Arrival, TraceStats};
pub use partition::{partition_workload, ClusterAssignment, WorkItem};
pub use replica::{JobFault, ReplicaMetrics, WorkQueue, MAX_JOB_ATTEMPTS};
pub use server::{
    paged_rows, replica_rows, Completion, GenChunk, GenRequest, GenTask, GenerateMetrics,
    GenerateOutcome, MetricRow, Mode, Reply, ServeMetrics, ServeOutcome, Server, StreamFault,
    Submission, SubmitError, Tier, TierConfig, TierHandle, TierSnapshot, DEFAULT_POOL_BLOCKS,
    PAGED_BLOCK_SIZE,
};
