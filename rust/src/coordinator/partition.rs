//! Cluster-level workload partitioner (paper §V-C): 125 ESACT units in
//! 25 clusters; each workload is split along batch → head → sequence
//! (lowest dimension first) and assigned to clusters in order.

use crate::config::{DeployConfig, ModelConfig};

/// One shard of a workload, assigned to a cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkItem {
    pub cluster: usize,
    /// batch indices [b0, b1)
    pub batch_range: (usize, usize),
    /// head indices [h0, h1)
    pub head_range: (usize, usize),
    /// sequence-row indices [s0, s1)
    pub seq_range: (usize, usize),
}

impl WorkItem {
    pub fn volume(&self) -> usize {
        (self.batch_range.1 - self.batch_range.0)
            * (self.head_range.1 - self.head_range.0)
            * (self.seq_range.1 - self.seq_range.0)
    }
}

/// Assignment of a full workload to the cluster array.
#[derive(Clone, Debug)]
pub struct ClusterAssignment {
    pub items: Vec<WorkItem>,
    pub n_clusters: usize,
}

impl ClusterAssignment {
    /// Load imbalance: max cluster volume / mean cluster volume.
    pub fn imbalance(&self) -> f64 {
        let mut per = vec![0usize; self.n_clusters];
        for it in &self.items {
            per[it.cluster] += it.volume();
        }
        let max = *per.iter().max().unwrap_or(&0) as f64;
        let mean = per.iter().sum::<usize>() as f64 / self.n_clusters as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Every (batch, head, seq) cell covered exactly once?
    pub fn covers_exactly(&self, batch: usize, heads: usize, seq: usize) -> bool {
        let mut count = vec![0u8; batch * heads * seq];
        for it in &self.items {
            for b in it.batch_range.0..it.batch_range.1 {
                for h in it.head_range.0..it.head_range.1 {
                    for s in it.seq_range.0..it.seq_range.1 {
                        let idx = (b * heads + h) * seq + s;
                        count[idx] += 1;
                    }
                }
            }
        }
        count.iter().all(|&c| c == 1)
    }
}

/// Split `n` into `parts` contiguous ranges (as even as possible).
fn split(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(n).max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Partition a (batch × heads × seq) workload over the clusters:
/// batch first, then heads, then sequence rows — "assigned to the
/// clusters in order from the lowest to the highest dimension".
pub fn partition_workload(
    deploy: &DeployConfig,
    cfg: &ModelConfig,
    batch: usize,
) -> ClusterAssignment {
    let c = deploy.n_clusters;
    let mut items = Vec::new();
    // split batch as far as it goes
    let batch_parts = split(batch, c);
    let clusters_per_batch = (c / batch_parts.len()).max(1);
    let mut cluster = 0usize;
    for &(b0, b1) in &batch_parts {
        // within a batch shard, split heads over the clusters allotted
        let head_parts = split(cfg.n_heads, clusters_per_batch);
        let clusters_per_head = (clusters_per_batch / head_parts.len()).max(1);
        for &(h0, h1) in &head_parts {
            // finally split the sequence
            let seq_parts = split(cfg.seq_len, clusters_per_head);
            for &(s0, s1) in &seq_parts {
                items.push(WorkItem {
                    cluster: cluster % c,
                    batch_range: (b0, b1),
                    head_range: (h0, h1),
                    seq_range: (s0, s1),
                });
                cluster += 1;
            }
        }
    }
    ClusterAssignment { items, n_clusters: c }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn deploy() -> DeployConfig {
        DeployConfig::default()
    }

    #[test]
    fn large_batch_splits_on_batch_only() {
        let cfg = config::bert_base(128);
        let a = partition_workload(&deploy(), &cfg, 32);
        assert!(a.covers_exactly(32, 12, 128));
        // contiguous batch split of 32 over 25 clusters: 7 clusters get
        // 2 sequences → max/mean = 2/(32/25) = 1.5625
        assert!(a.imbalance() < 1.6, "imbalance {}", a.imbalance());
        // batch dominates: every item spans all heads
        assert!(a.items.iter().all(|i| i.head_range == (0, 12)));
    }

    #[test]
    fn batch_one_splits_heads_then_seq() {
        let cfg = config::bert_base(128);
        let a = partition_workload(&deploy(), &cfg, 1);
        assert!(a.covers_exactly(1, 12, 128));
        // 25 clusters > 12 heads: sequence must split too
        assert!(a.items.len() >= 12);
        assert!(a.imbalance() < 2.0, "imbalance {}", a.imbalance());
    }

    #[test]
    fn tiny_workload_still_covered() {
        let cfg = config::vit_b32(); // L = 50
        let a = partition_workload(&deploy(), &cfg, 2);
        assert!(a.covers_exactly(2, 12, 50));
    }

    #[test]
    fn split_helper_even() {
        assert_eq!(split(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(split(2, 5).len(), 2); // parts clamped to n
        assert_eq!(split(5, 1), vec![(0, 5)]);
    }

    #[test]
    fn volumes_sum_to_total() {
        let cfg = config::bert_large(512);
        let a = partition_workload(&deploy(), &cfg, 12);
        let total: usize = a.items.iter().map(|i| i.volume()).sum();
        assert_eq!(total, 12 * 16 * 512);
    }
}
