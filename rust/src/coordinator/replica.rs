//! The data-parallel worker tier of the serving coordinator: N replica
//! threads, each owning its own executor handle, pulling dispatched
//! batches from per-replica deques with work-stealing — the
//! SpAtten-style amortization of planning decisions across a pipeline
//! of workers (see DESIGN.md §Serving coordinator).
//!
//! std threads + channels + a single `Mutex<_>`/`Condvar` pair (no
//! tokio / crossbeam-deque in the vendored crate set). Stealing is
//! coarse-grained — jobs are whole executor batches, milliseconds each
//! — so one lock around the deques is contention-free in practice.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::Batch;
use crate::coordinator::server::{Reply, ServerCore};

/// One dispatched unit of work: a padded batch bound for an executor.
pub struct Job {
    pub batch: Batch,
}

/// What a replica reports back to the leader after each batch.
pub enum ReplicaEvent {
    Done {
        replica: usize,
        replies: Vec<Reply>,
        padding: usize,
        stolen: bool,
    },
    Failed {
        replica: usize,
        error: anyhow::Error,
    },
}

/// Per-replica execution counters, joined by the leader at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ReplicaMetrics {
    pub replica: usize,
    pub batches: usize,
    pub requests: usize,
    /// Batches this replica stole from a peer's deque.
    pub steals: usize,
    /// Wall time spent executing (vs idle/blocked on the queue).
    pub busy: Duration,
}

struct QueueState {
    /// One FIFO deque per replica. The owner pops from the front;
    /// thieves steal from the back of the longest peer deque.
    locals: Vec<VecDeque<Job>>,
    closed: bool,
}

/// The shared work queue: per-replica deques + leader dispatch.
pub struct WorkQueue {
    state: Mutex<QueueState>,
    available: Condvar,
}

impl WorkQueue {
    pub fn new(n_replicas: usize) -> Self {
        assert!(n_replicas >= 1);
        Self {
            state: Mutex::new(QueueState {
                locals: (0..n_replicas).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            available: Condvar::new(),
        }
    }

    /// Leader dispatch: append to the shortest deque (ties to the
    /// lowest replica id, deterministically). Returns the chosen
    /// replica.
    pub fn push_least_loaded(&self, job: Job) -> usize {
        let mut st = self.state.lock().unwrap();
        let idx = st
            .locals
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| q.len())
            .map(|(i, _)| i)
            .expect("at least one replica");
        st.locals[idx].push_back(job);
        drop(st);
        self.available.notify_all();
        idx
    }

    /// Targeted dispatch (tests and pinned workloads).
    pub fn push_to(&self, replica: usize, job: Job) {
        let mut st = self.state.lock().unwrap();
        st.locals[replica].push_back(job);
        drop(st);
        self.available.notify_all();
    }

    /// Worker pop: own deque front first; if empty, steal from the
    /// back of the longest peer deque. Blocks until a job arrives or
    /// the queue is closed *and* fully drained (then `None`). The
    /// returned flag is `true` when the job was stolen.
    pub fn pop(&self, replica: usize) -> Option<(Job, bool)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.locals[replica].pop_front() {
                return Some((job, false));
            }
            let victim = (0..st.locals.len())
                .filter(|&i| i != replica)
                .max_by_key(|&i| st.locals[i].len());
            if let Some(v) = victim {
                if let Some(job) = st.locals[v].pop_back() {
                    return Some((job, true));
                }
            }
            if st.closed {
                return None;
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Total queued (not yet popped) jobs across all deques.
    pub fn depth(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.locals.iter().map(|q| q.len()).sum()
    }

    /// Close the queue: workers drain what remains, then exit.
    /// Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.available.notify_all();
    }
}

/// Spawn the replica pool. Each worker grabs its own executor handle
/// (`ArtifactSet::replica_handle`, falling back to the shared set for
/// backends that cannot clone executables), then loops: pop → execute
/// → report. Workers exit when the queue closes or the event channel
/// hangs up, returning their counters.
pub(crate) fn spawn_replicas(
    core: Arc<ServerCore>,
    queue: Arc<WorkQueue>,
    events: mpsc::Sender<ReplicaEvent>,
    n_replicas: usize,
) -> Vec<JoinHandle<ReplicaMetrics>> {
    (0..n_replicas)
        .map(|id| {
            let core = Arc::clone(&core);
            let queue = Arc::clone(&queue);
            let events = events.clone();
            std::thread::Builder::new()
                .name(format!("esact-replica-{id}"))
                .spawn(move || {
                    let own_handle = core.artifacts().replica_handle().ok();
                    let mut m = ReplicaMetrics { replica: id, ..Default::default() };
                    while let Some((job, stolen)) = queue.pop(id) {
                        m.steals += usize::from(stolen);
                        let t0 = Instant::now();
                        let artifacts =
                            own_handle.as_ref().unwrap_or_else(|| core.artifacts());
                        // a panic here (bad request shape, poisoned
                        // planner) must still produce an event, or the
                        // leader would wait on this batch forever
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || {
                                core.execute_on(
                                    artifacts,
                                    &job.batch.requests,
                                    job.batch.padding,
                                )
                            },
                        ))
                        .unwrap_or_else(|panic| {
                            Err(anyhow::anyhow!(
                                "replica {id} panicked executing a batch: {}",
                                panic_message(&panic)
                            ))
                        });
                        m.busy += t0.elapsed();
                        match result {
                            Ok(replies) => {
                                m.batches += 1;
                                m.requests += replies.len();
                                let ev = ReplicaEvent::Done {
                                    replica: id,
                                    replies,
                                    padding: job.batch.padding,
                                    stolen,
                                };
                                if events.send(ev).is_err() {
                                    break; // leader gone: shut down
                                }
                            }
                            Err(error) => {
                                let _ = events
                                    .send(ReplicaEvent::Failed { replica: id, error });
                                break;
                            }
                        }
                    }
                    m
                })
                .expect("spawn replica thread")
        })
        .collect()
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic payload>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Request;

    fn job(id: u64) -> Job {
        let req = Request { id, tokens: vec![0; 8], arrived: Instant::now() };
        Job { batch: Batch { requests: vec![req], padding: 0 } }
    }

    fn job_id(j: &Job) -> u64 {
        j.batch.requests[0].id
    }

    #[test]
    fn owner_pops_fifo_without_stealing() {
        let q = WorkQueue::new(2);
        q.push_to(0, job(1));
        q.push_to(0, job(2));
        let (a, stolen_a) = q.pop(0).unwrap();
        let (b, stolen_b) = q.pop(0).unwrap();
        assert_eq!((job_id(&a), stolen_a), (1, false));
        assert_eq!((job_id(&b), stolen_b), (2, false));
    }

    #[test]
    fn idle_replica_steals_from_loaded_peer_back() {
        let q = WorkQueue::new(2);
        q.push_to(0, job(1));
        q.push_to(0, job(2));
        q.push_to(0, job(3));
        // replica 1 is empty: it must steal, from the BACK of 0's deque
        let (s, stolen) = q.pop(1).unwrap();
        assert!(stolen);
        assert_eq!(job_id(&s), 3);
        // owner still sees its front in FIFO order
        let (a, stolen_a) = q.pop(0).unwrap();
        assert_eq!((job_id(&a), stolen_a), (1, false));
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn closed_and_drained_returns_none() {
        let q = WorkQueue::new(1);
        q.push_to(0, job(1));
        q.close();
        assert!(q.pop(0).is_some(), "drain continues after close");
        assert!(q.pop(0).is_none(), "then workers exit");
        q.close(); // idempotent
    }

    #[test]
    fn least_loaded_dispatch_balances() {
        let q = WorkQueue::new(3);
        let mut chosen = Vec::new();
        for i in 0..6 {
            chosen.push(q.push_least_loaded(job(i)));
        }
        // deterministic round-robin over equal depths
        assert_eq!(chosen, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(q.depth(), 6);
    }

    #[test]
    fn blocked_pop_wakes_on_push_and_on_close() {
        let q = Arc::new(WorkQueue::new(1));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let first = q2.pop(0).map(|(j, _)| job_id(&j));
            let second = q2.pop(0).map(|(j, _)| job_id(&j));
            (first, second)
        });
        std::thread::sleep(Duration::from_millis(20));
        q.push_to(0, job(7));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        let (first, second) = h.join().unwrap();
        assert_eq!(first, Some(7));
        assert_eq!(second, None);
    }
}
