//! The data-parallel worker tier of the serving coordinator: N replica
//! threads, each owning its own executor handle, pulling dispatched
//! batches from per-replica deques with work-stealing — the
//! SpAtten-style amortization of planning decisions across a pipeline
//! of workers (see DESIGN.md §Serving coordinator).
//!
//! std threads + channels + a single `Mutex<_>`/`Condvar` pair (no
//! tokio / crossbeam-deque in the vendored crate set). Stealing is
//! coarse-grained — jobs are whole executor batches, milliseconds each
//! — so one lock around the deques is contention-free in practice.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::Batch;
use crate::coordinator::server::{GenTask, Reply, ServerCore};
use crate::decode::paged::PoolExhausted;
use crate::obs::Stage;
use crate::util::fault::FaultSite;

/// One dispatched unit of work: a padded classification batch bound for
/// an executor, or a slice of decode steps of one generation session
/// (continuous decode batching — sessions interleave across the same
/// work-stealing deques the classify path uses).
pub enum Job {
    Classify {
        batch: Batch,
        /// Which delivery this is (1 on first dispatch). The leader
        /// stamps it, the worker echoes it back on a fault, and retry
        /// stops at `MAX_JOB_ATTEMPTS` — at-most-N execution, so a
        /// poisoned batch degrades to a per-request error instead of a
        /// crash loop (decode retries are tracked leader-side, on the
        /// session record).
        attempt: u32,
    },
    Decode {
        task: Box<GenTask>,
        steps: usize,
    },
}

/// Retry budget for a faulted job: first dispatch plus one retry, then
/// the leader answers the requests with a per-request fault outcome.
pub const MAX_JOB_ATTEMPTS: u32 = 2;

/// A typed per-job fault: the worker panicked (or an injected fault
/// tripped) executing this job. Carried by [`ReplicaEvent::Faulted`] so
/// the leader can retry, migrate, or answer in-band — [`ReplicaEvent::
/// Failed`] stays reserved for genuinely unrecoverable states
/// (executor-level errors, every replica dead).
pub enum JobFault {
    /// A classify batch's execution died before producing replies. The
    /// batch rides along untouched (execution only borrows it), so the
    /// leader can requeue it on a healthy replica.
    Classify { batch: Batch, attempt: u32, message: String },
    /// A decode slice died; the session's state was consumed by the
    /// unwind (its Drop released any paged block refs), so only the id
    /// travels. The leader migrates the session from its retained
    /// record or aborts the stream in-band.
    Decode { id: u64, message: String },
}

/// What a replica reports back to the leader after each job.
pub enum ReplicaEvent {
    Done {
        replica: usize,
        replies: Vec<Reply>,
        padding: usize,
        stolen: bool,
        /// Execution wall time of this batch on the replica, so the
        /// leader can keep live per-replica busy counters (scraped by
        /// the network gateway's `/metrics` mid-run; the joined
        /// [`ReplicaMetrics`] remain the end-of-run source).
        busy: Duration,
    },
    DecodeDone {
        replica: usize,
        task: Box<GenTask>,
        /// Tokens generated during this slice (may be empty while the
        /// slice is still consuming the prompt).
        fresh: Vec<i32>,
        stolen: bool,
        /// Execution wall time of this slice (see [`ReplicaEvent::Done::busy`]).
        busy: Duration,
        /// Admission-to-execution wait, measured at this slice's exec
        /// start. The leader observes only the session's *first* slice
        /// into the queue-wait histogram (later slices re-queue by
        /// design under continuous batching).
        queue_wait: Duration,
    },
    /// One decode session died of a recoverable, per-session fault
    /// (paged KV pool exhaustion): the session's state dropped during
    /// the unwind (releasing its block references) and the replica
    /// keeps serving. The leader finishes the session's stream with an
    /// empty `done` chunk — the tier-killing `Failed` path is reserved
    /// for faults that indict the replica itself.
    DecodeAborted {
        replica: usize,
        /// The aborted session's request id (the task box was consumed
        /// by the unwind, so the id travels on its own).
        id: u64,
        stolen: bool,
        busy: Duration,
        reason: String,
    },
    /// The worker panicked executing one job and is exiting; the
    /// supervisor (leader) respawns the replica and retries, migrates,
    /// or answers the faulted job — queued work on the dead worker's
    /// deque survives (peers steal it, and the respawned worker drains
    /// its own deque). This is the recoverable counterpart of
    /// [`ReplicaEvent::Failed`].
    Faulted {
        replica: usize,
        fault: JobFault,
        stolen: bool,
        busy: Duration,
    },
    Failed {
        replica: usize,
        error: anyhow::Error,
    },
}

/// Per-replica execution counters, joined by the leader at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ReplicaMetrics {
    pub replica: usize,
    pub batches: usize,
    pub requests: usize,
    /// Decode slices executed.
    pub decode_slices: usize,
    /// Tokens generated by decode slices on this replica.
    pub tokens: usize,
    /// Jobs this replica stole from a peer's deque.
    pub steals: usize,
    /// Wall time spent executing (vs idle/blocked on the queue).
    pub busy: Duration,
}

struct QueueState {
    /// One FIFO deque per replica. The owner pops from the front;
    /// thieves steal from the back of the longest peer deque.
    locals: Vec<VecDeque<Job>>,
    closed: bool,
}

/// The shared work queue: per-replica deques + leader dispatch.
pub struct WorkQueue {
    state: Mutex<QueueState>,
    available: Condvar,
}

impl WorkQueue {
    pub fn new(n_replicas: usize) -> Self {
        assert!(n_replicas >= 1);
        Self {
            state: Mutex::new(QueueState {
                locals: (0..n_replicas).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            available: Condvar::new(),
        }
    }

    /// Leader dispatch: append to the shortest deque (ties to the
    /// lowest replica id, deterministically). Returns the chosen
    /// replica.
    pub fn push_least_loaded(&self, job: Job) -> usize {
        let mut st = self.state.lock().unwrap();
        let idx = st
            .locals
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| q.len())
            .map(|(i, _)| i)
            .expect("at least one replica");
        st.locals[idx].push_back(job);
        drop(st);
        self.available.notify_all();
        idx
    }

    /// Targeted dispatch (tests and pinned workloads).
    pub fn push_to(&self, replica: usize, job: Job) {
        let mut st = self.state.lock().unwrap();
        st.locals[replica].push_back(job);
        drop(st);
        self.available.notify_all();
    }

    /// Worker pop: own deque front first; if empty, steal from the
    /// back of the longest peer deque. Blocks until a job arrives or
    /// the queue is closed *and* fully drained (then `None`). The
    /// returned flag is `true` when the job was stolen.
    pub fn pop(&self, replica: usize) -> Option<(Job, bool)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.locals[replica].pop_front() {
                return Some((job, false));
            }
            let victim = (0..st.locals.len())
                .filter(|&i| i != replica)
                .max_by_key(|&i| st.locals[i].len());
            if let Some(v) = victim {
                if let Some(job) = st.locals[v].pop_back() {
                    return Some((job, true));
                }
            }
            if st.closed {
                return None;
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Total queued (not yet popped) jobs across all deques.
    pub fn depth(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.locals.iter().map(|q| q.len()).sum()
    }

    /// Whether [`WorkQueue::close`] has been called — the leaders stop
    /// requeueing faulted work during the shutdown drain (a retry
    /// pushed after the last worker exits would be lost; answering the
    /// fault in-band is always safe).
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Close the queue: workers drain what remains, then exit.
    /// Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.available.notify_all();
    }
}

/// Spawn the replica pool. Each worker grabs its own executor handle
/// (`ArtifactSet::replica_handle`, falling back to the shared set for
/// backends that cannot clone executables), then loops: pop → execute
/// → report. Workers exit when the queue closes or the event channel
/// hangs up, returning their counters.
pub(crate) fn spawn_replicas(
    core: Arc<ServerCore>,
    queue: Arc<WorkQueue>,
    events: mpsc::Sender<ReplicaEvent>,
    n_replicas: usize,
) -> Vec<JoinHandle<ReplicaMetrics>> {
    (0..n_replicas)
        .map(|id| spawn_replica(Arc::clone(&core), Arc::clone(&queue), events.clone(), id))
        .collect()
}

/// Spawn one replica worker — also the supervisor's respawn primitive:
/// after a [`ReplicaEvent::Faulted`] worker exits, the leader joins the
/// dead handle and spawns a fresh worker under the same id, which
/// resumes draining the same deque (queued jobs survive a worker death
/// untouched; peers can also steal them meanwhile).
pub(crate) fn spawn_replica(
    core: Arc<ServerCore>,
    queue: Arc<WorkQueue>,
    events: mpsc::Sender<ReplicaEvent>,
    id: usize,
) -> JoinHandle<ReplicaMetrics> {
    std::thread::Builder::new()
        .name(format!("esact-replica-{id}"))
        .spawn(move || {
            let own_handle = core.artifacts().replica_handle().ok();
            let mut m = ReplicaMetrics { replica: id, ..Default::default() };
            while let Some((job, stolen)) = queue.pop(id) {
                m.steals += usize::from(stolen);
                let t0 = Instant::now();
                let trace = &core.obs().trace;
                match job {
                    Job::Classify { batch, attempt } => {
                        // exec_start is earliest-wins, so a retried
                        // batch keeps its first attempt's start stamp
                        for r in &batch.requests {
                            trace.event(r.id, Stage::ExecStart);
                        }
                        // injected faults take the same exit as a real
                        // panic — before the executor touches anything,
                        // so the requeued batch replays bit-identically
                        if core.fault_injector().is_some_and(|f| f.trip(FaultSite::ClassifyJob)) {
                            let busy = t0.elapsed();
                            m.busy += busy;
                            let _ = events.send(ReplicaEvent::Faulted {
                                replica: id,
                                fault: JobFault::Classify {
                                    batch,
                                    attempt,
                                    message: format!(
                                        "injected fault: classify job on replica {id}"
                                    ),
                                },
                                stolen,
                                busy,
                            });
                            break;
                        }
                        let artifacts = own_handle.as_ref().unwrap_or_else(|| core.artifacts());
                        // a panic here (bad request shape, poisoned
                        // planner) must still produce an event, or the
                        // leader would wait on this batch forever — and
                        // execution only borrows the batch, so it
                        // survives the unwind for the leader to retry
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            core.execute_on(artifacts, &batch.requests, batch.padding)
                        }));
                        let busy = t0.elapsed();
                        m.busy += busy;
                        match result {
                            Ok(Ok(replies)) => {
                                m.batches += 1;
                                m.requests += replies.len();
                                for r in &replies {
                                    trace.event(r.id, Stage::ExecEnd);
                                }
                                let ev = ReplicaEvent::Done {
                                    replica: id,
                                    replies,
                                    padding: batch.padding,
                                    stolen,
                                    busy,
                                };
                                if events.send(ev).is_err() {
                                    break; // leader gone: shut down
                                }
                            }
                            // a clean executor `Err` indicts the
                            // artifacts/backend, not this batch —
                            // retrying elsewhere would fail the same
                            // way, so this stays a tier-level error
                            Ok(Err(error)) => {
                                let _ = events.send(ReplicaEvent::Failed { replica: id, error });
                                break;
                            }
                            // a panic indicts this worker's execution
                            // of this batch: hand the batch back for
                            // retry on a healthy replica and exit (the
                            // supervisor respawns this slot)
                            Err(panic) => {
                                let _ = events.send(ReplicaEvent::Faulted {
                                    replica: id,
                                    fault: JobFault::Classify {
                                        batch,
                                        attempt,
                                        message: format!(
                                            "replica {id} panicked executing a batch: {}",
                                            panic_message(&panic)
                                        ),
                                    },
                                    stolen,
                                    busy,
                                });
                                break;
                            }
                        }
                    }
                    Job::Decode { mut task, steps } => {
                        // the unwind consumes the task box (its Drop
                        // releases any paged block refs), so keep the
                        // id for the abort/fault event
                        let task_id = task.id;
                        let queue_wait = t0.saturating_duration_since(task.arrived);
                        trace.event(task_id, Stage::ExecStart);
                        if core.fault_injector().is_some_and(|f| f.trip(FaultSite::DecodeJob)) {
                            // drop first: the session's Drop releases
                            // its paged block refs, exactly like a real
                            // panic's unwind would
                            drop(task);
                            let busy = t0.elapsed();
                            m.busy += busy;
                            let _ = events.send(ReplicaEvent::Faulted {
                                replica: id,
                                fault: JobFault::Decode {
                                    id: task_id,
                                    message: format!(
                                        "injected fault: decode slice on replica {id}"
                                    ),
                                },
                                stolen,
                                busy,
                            });
                            break;
                        }
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            move || {
                                let fresh = task.session.run_steps(steps);
                                (task, fresh)
                            },
                        ));
                        let busy = t0.elapsed();
                        m.busy += busy;
                        match result {
                            Ok((task, fresh)) => {
                                m.decode_slices += 1;
                                m.tokens += fresh.len();
                                trace.event(task_id, Stage::ExecEnd);
                                let ev = ReplicaEvent::DecodeDone {
                                    replica: id,
                                    task,
                                    fresh,
                                    stolen,
                                    busy,
                                    queue_wait,
                                };
                                if events.send(ev).is_err() {
                                    break;
                                }
                            }
                            // pool exhaustion indicts the one session,
                            // not the replica: report the abort and
                            // keep serving
                            Err(panic) if panic.downcast_ref::<PoolExhausted>().is_some() => {
                                let e = panic
                                    .downcast_ref::<PoolExhausted>()
                                    .expect("guard checked the payload type");
                                trace.event(task_id, Stage::ExecEnd);
                                let ev = ReplicaEvent::DecodeAborted {
                                    replica: id,
                                    id: task_id,
                                    stolen,
                                    busy,
                                    reason: e.to_string(),
                                };
                                if events.send(ev).is_err() {
                                    break;
                                }
                            }
                            // any other panic: the session state is
                            // gone, but the leader retains what it
                            // needs to migrate the stream — report the
                            // fault and exit for respawn
                            Err(panic) => {
                                let _ = events.send(ReplicaEvent::Faulted {
                                    replica: id,
                                    fault: JobFault::Decode {
                                        id: task_id,
                                        message: format!(
                                            "replica {id} panicked in a decode slice: {}",
                                            panic_message(&panic)
                                        ),
                                    },
                                    stolen,
                                    busy,
                                });
                                break;
                            }
                        }
                    }
                }
            }
            m
        })
        .expect("spawn replica thread")
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic payload>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Request;

    fn job(id: u64) -> Job {
        let req = Request { id, tokens: vec![0; 8], arrived: Instant::now() };
        Job::Classify { batch: Batch { requests: vec![req], padding: 0 }, attempt: 1 }
    }

    fn job_id(j: &Job) -> u64 {
        match j {
            Job::Classify { batch, .. } => batch.requests[0].id,
            Job::Decode { task, .. } => task.id,
        }
    }

    #[test]
    fn owner_pops_fifo_without_stealing() {
        let q = WorkQueue::new(2);
        q.push_to(0, job(1));
        q.push_to(0, job(2));
        let (a, stolen_a) = q.pop(0).unwrap();
        let (b, stolen_b) = q.pop(0).unwrap();
        assert_eq!((job_id(&a), stolen_a), (1, false));
        assert_eq!((job_id(&b), stolen_b), (2, false));
    }

    #[test]
    fn idle_replica_steals_from_loaded_peer_back() {
        let q = WorkQueue::new(2);
        q.push_to(0, job(1));
        q.push_to(0, job(2));
        q.push_to(0, job(3));
        // replica 1 is empty: it must steal, from the BACK of 0's deque
        let (s, stolen) = q.pop(1).unwrap();
        assert!(stolen);
        assert_eq!(job_id(&s), 3);
        // owner still sees its front in FIFO order
        let (a, stolen_a) = q.pop(0).unwrap();
        assert_eq!((job_id(&a), stolen_a), (1, false));
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn closed_and_drained_returns_none() {
        let q = WorkQueue::new(1);
        q.push_to(0, job(1));
        q.close();
        assert!(q.pop(0).is_some(), "drain continues after close");
        assert!(q.pop(0).is_none(), "then workers exit");
        q.close(); // idempotent
    }

    #[test]
    fn least_loaded_dispatch_balances() {
        let q = WorkQueue::new(3);
        let mut chosen = Vec::new();
        for i in 0..6 {
            chosen.push(q.push_least_loaded(job(i)));
        }
        // deterministic round-robin over equal depths
        assert_eq!(chosen, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(q.depth(), 6);
    }

    #[test]
    fn blocked_pop_wakes_on_push_and_on_close() {
        let q = Arc::new(WorkQueue::new(1));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let first = q2.pop(0).map(|(j, _)| job_id(&j));
            let second = q2.pop(0).map(|(j, _)| job_id(&j));
            (first, second)
        });
        std::thread::sleep(Duration::from_millis(20));
        q.push_to(0, job(7));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        let (first, second) = h.join().unwrap();
        assert_eq!(first, Some(7));
        assert_eq!(second, None);
    }
}
