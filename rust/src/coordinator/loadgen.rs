//! Trace-driven load generation: Poisson / bursty arrival processes
//! over the synthetic request corpus, used to characterize the serving
//! coordinator's latency-vs-load curve (the serving-systems complement
//! to the paper's throughput tables; see `examples/serve_tiny.rs` and
//! the serving bench).

use std::time::Duration;

use crate::util::rng::Xoshiro256pp;

/// Arrival process shapes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Exponential inter-arrival times at `rate` requests/second.
    Poisson { rate: f64 },
    /// Deterministic spacing at `rate` requests/second.
    Uniform { rate: f64 },
    /// Bursts of `burst` back-to-back requests, bursts arriving at
    /// `burst_rate` bursts/second (models batched upstream callers).
    Bursty { burst: usize, burst_rate: f64 },
}

/// One scheduled arrival: offset from trace start.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ArrivalAt(pub Duration);

/// Generate `n` arrival offsets for the given process.
///
/// `n` counts **requests**, not bursts: a `Bursty` trace truncates its
/// final burst so the trace holds exactly `n` arrivals, with the first
/// burst at `t = 0` jitter-free (an exponential gap precedes every
/// burst *after* the first, not the first itself).
pub fn arrivals(rng: &mut Xoshiro256pp, process: Arrival, n: usize) -> Vec<ArrivalAt> {
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    match process {
        Arrival::Poisson { rate } => {
            assert!(rate > 0.0);
            for _ in 0..n {
                // inverse-CDF exponential sample
                let u = rng.f64().max(1e-12);
                t += -u.ln() / rate;
                out.push(ArrivalAt(Duration::from_secs_f64(t)));
            }
        }
        Arrival::Uniform { rate } => {
            assert!(rate > 0.0);
            let step = 1.0 / rate;
            for _ in 0..n {
                t += step;
                out.push(ArrivalAt(Duration::from_secs_f64(t)));
            }
        }
        Arrival::Bursty { burst, burst_rate } => {
            assert!(burst > 0 && burst_rate > 0.0);
            // first burst at t = 0, jitter-free; exponential gaps only
            // between bursts
            while out.len() < n {
                for _ in 0..burst.min(n - out.len()) {
                    out.push(ArrivalAt(Duration::from_secs_f64(t)));
                }
                let u = rng.f64().max(1e-12);
                t += -u.ln() / burst_rate;
            }
        }
    }
    out
}

/// Offered load summary of a trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceStats {
    pub n: usize,
    pub duration: Duration,
    pub mean_rate: f64,
    /// Peak 10 ms-window arrival count (burstiness indicator).
    pub peak_window: usize,
}

pub fn trace_stats(trace: &[ArrivalAt]) -> TraceStats {
    assert!(!trace.is_empty());
    let duration = trace.last().unwrap().0;
    let window = Duration::from_millis(10);
    let mut peak = 0usize;
    let mut lo = 0usize;
    for hi in 0..trace.len() {
        while trace[hi].0.saturating_sub(trace[lo].0) > window {
            lo += 1;
        }
        peak = peak.max(hi - lo + 1);
    }
    TraceStats {
        n: trace.len(),
        duration,
        mean_rate: trace.len() as f64 / duration.as_secs_f64().max(1e-9),
        peak_window: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_converges() {
        let mut rng = Xoshiro256pp::new(1);
        let trace = arrivals(&mut rng, Arrival::Poisson { rate: 1000.0 }, 5000);
        let stats = trace_stats(&trace);
        assert!((stats.mean_rate - 1000.0).abs() < 60.0, "rate {}", stats.mean_rate);
        // arrivals sorted
        assert!(trace.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn uniform_exactly_spaced() {
        let mut rng = Xoshiro256pp::new(2);
        let trace = arrivals(&mut rng, Arrival::Uniform { rate: 100.0 }, 10);
        for (i, a) in trace.iter().enumerate() {
            let want = (i + 1) as f64 * 0.01;
            assert!((a.0.as_secs_f64() - want).abs() < 1e-9);
        }
    }

    #[test]
    fn bursts_are_coincident() {
        let mut rng = Xoshiro256pp::new(3);
        let trace = arrivals(&mut rng, Arrival::Bursty { burst: 8, burst_rate: 10.0 }, 64);
        assert_eq!(trace.len(), 64);
        // each group of 8 shares a timestamp
        for chunk in trace.chunks(8) {
            assert!(chunk.iter().all(|a| *a == chunk[0]));
        }
        let stats = trace_stats(&trace);
        assert!(stats.peak_window >= 8);
    }

    #[test]
    fn burstier_traces_have_higher_peaks() {
        let mut r1 = Xoshiro256pp::new(4);
        let mut r2 = Xoshiro256pp::new(4);
        let uniform = trace_stats(&arrivals(&mut r1, Arrival::Uniform { rate: 500.0 }, 500));
        let bursty = trace_stats(&arrivals(
            &mut r2,
            Arrival::Bursty { burst: 16, burst_rate: 500.0 / 16.0 },
            500,
        ));
        assert!(bursty.peak_window > uniform.peak_window);
    }

    #[test]
    fn bursty_first_burst_at_zero_and_n_counts_requests() {
        let mut rng = Xoshiro256pp::new(11);
        // 21 is not a multiple of 8: the last burst must truncate
        let trace = arrivals(&mut rng, Arrival::Bursty { burst: 8, burst_rate: 10.0 }, 21);
        assert_eq!(trace.len(), 21, "n counts requests, not bursts");
        for a in &trace[..8] {
            assert_eq!(a.0, Duration::ZERO, "first burst is at t=0, jitter-free");
        }
        assert!(trace[8].0 > Duration::ZERO, "second burst is jittered");
        // truncated final burst still shares one timestamp
        assert!(trace[16..].iter().all(|a| *a == trace[16]));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = arrivals(&mut Xoshiro256pp::new(9), Arrival::Poisson { rate: 50.0 }, 100);
        let b = arrivals(&mut Xoshiro256pp::new(9), Arrival::Poisson { rate: 50.0 }, 100);
        assert_eq!(a, b);
    }
}
