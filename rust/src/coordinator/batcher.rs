//! Dynamic batcher: coalesce incoming requests into the batch sizes the
//! AOT artifacts were compiled for (1 and 8), trading batching latency
//! against executor efficiency — the standard serving trade-off, with
//! the artifact-shape constraint that a real single-model deployment
//! has.
//!
//! The leader runs **continuous batching** on top of this queue: a
//! partially-filled batch stays open (and is refilled by later
//! arrivals) while every replica is busy — waiting costs nothing then —
//! and is dispatched eagerly the moment a replica goes idle
//! ([`Batcher::pop_eager`]), instead of the old fill-or-timeout-only
//! policy. Admission control ([`Batcher::admit`]) bounds the queue:
//! the in-process leader stops pulling from the request channel at
//! `max_queue` (backpressure, lossless), and a frontend without a
//! bufferable channel sheds at `admit` instead — either way, queued
//! latency stays bounded under overload.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrived: Instant,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Preferred (largest compiled) batch size.
    pub max_batch: usize,
    /// How long a request may wait for the batch to fill before being
    /// dispatched in a smaller (padded) batch even with no idle
    /// replica.
    pub max_wait: Duration,
    /// Admission bound on the batcher queue. The serving leader stops
    /// pulling from the request channel at this depth (backpressure —
    /// nothing is dropped); `admit` callers without a bufferable
    /// source shed beyond it (counted in `ServeMetrics::shed`).
    pub max_queue: usize,
    /// Continuous batching: dispatch a partial batch immediately when
    /// an executor replica is idle, instead of holding it until full
    /// or `max_wait`-stale.
    pub eager_dispatch: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue: 4096,
            eager_dispatch: true,
        }
    }
}

/// A dispatched batch: the requests plus the padding count (padded
/// slots replay request 0 and are discarded on output).
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub padding: usize,
}

impl Batch {
    pub fn size(&self) -> usize {
        self.requests.len() + self.padding
    }
}

/// FIFO dynamic batcher.
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, queue: VecDeque::new() }
    }

    /// Enqueue unconditionally (tests / trusted internal producers).
    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Admission-controlled enqueue: `false` means the request was
    /// shed (queue at `max_queue`) and will never produce a reply.
    pub fn admit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.policy.max_queue {
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Take up to `max_batch` requests off the queue front, padding a
    /// partial batch to the nearest compiled shape: 1 stays 1,
    /// everything else pads up to `max_batch`.
    fn take_batch(&mut self) -> Batch {
        let take = self.queue.len().min(self.policy.max_batch);
        let requests: Vec<Request> = self.queue.drain(..take).collect();
        let padding =
            if requests.len() == 1 { 0 } else { self.policy.max_batch - requests.len() };
        Batch { requests, padding }
    }

    /// Pop the next batch if the policy allows dispatch at `now`:
    /// dispatch when a full batch is ready, or when the oldest request
    /// has waited `max_wait` or longer (boundary inclusive — a request
    /// exactly at `max_wait` dispatches).
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.policy.max_batch;
        let stale = now.duration_since(self.queue[0].arrived) >= self.policy.max_wait;
        if !full && !stale {
            return None;
        }
        Some(self.take_batch())
    }

    /// Continuous-batching dispatch: pop whatever is queued *right
    /// now* (an idle replica makes further waiting pure latency), or
    /// `None` on an empty queue — an empty dispatch tick is a no-op.
    pub fn pop_eager(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        Some(self.take_batch())
    }

    /// Drain everything immediately (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(batch) = self.pop_eager() {
            out.push(batch);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, at: Instant) -> Request {
        Request { id, tokens: vec![0; 64], arrived: at }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..8 {
            b.push(req(i, t0));
        }
        let batch = b.pop_ready(t0).expect("full batch");
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(batch.padding, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i, t0));
        }
        assert!(b.pop_ready(t0).is_none(), "should wait for more");
        let later = t0 + Duration::from_millis(5);
        let batch = b.pop_ready(later).expect("stale dispatch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.padding, 5);
        assert_eq!(batch.size(), 8);
    }

    #[test]
    fn request_waiting_exactly_max_wait_dispatches() {
        // boundary inclusive: `>=` — a request at exactly max_wait goes
        let policy = BatchPolicy::default();
        let mut b = Batcher::new(policy);
        let t0 = Instant::now();
        b.push(req(0, t0));
        b.push(req(1, t0));
        let just_before = t0 + policy.max_wait - Duration::from_nanos(1);
        assert!(b.pop_ready(just_before).is_none(), "one ns early must wait");
        let batch = b.pop_ready(t0 + policy.max_wait).expect("dispatch at the boundary");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.padding, 6);
    }

    #[test]
    fn empty_queue_dispatch_tick_is_noop() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.pop_ready(Instant::now()).is_none());
        assert!(b.pop_eager().is_none());
        assert!(b.drain_all().is_empty());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn eager_dispatch_pads_partial_batch_to_compiled_shape() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i, t0));
        }
        // an idle replica asks immediately — no max_wait stall
        let batch = b.pop_eager().expect("eager partial dispatch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.padding, 5);
        assert_eq!(batch.size(), 8);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn admission_sheds_beyond_max_queue() {
        let mut b = Batcher::new(BatchPolicy { max_queue: 2, ..Default::default() });
        let t0 = Instant::now();
        assert!(b.admit(req(0, t0)));
        assert!(b.admit(req(1, t0)));
        assert!(!b.admit(req(2, t0)), "third request must shed");
        assert_eq!(b.pending(), 2);
        // shed request is gone: draining yields only the admitted two
        let ids: Vec<u64> = b
            .drain_all()
            .iter()
            .flat_map(|x| x.requests.iter().map(|r| r.id))
            .collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn single_request_uses_batch1() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        b.push(req(0, t0));
        let batch = b.pop_ready(t0 + Duration::from_millis(5)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.padding, 0);
        assert_eq!(batch.size(), 1);
    }

    #[test]
    fn overflow_queues_remainder() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..11 {
            b.push(req(i, t0));
        }
        let batch = b.pop_ready(t0).unwrap();
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..10 {
            b.push(req(i, t0));
        }
        let batches = b.drain_all();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches.iter().map(|x| x.requests.len()).sum::<usize>(), 10);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..8 {
            b.push(req(i, t0));
        }
        let batch = b.pop_ready(t0).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }
}
