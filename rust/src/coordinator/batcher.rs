//! Dynamic batcher: coalesce incoming requests into the batch sizes the
//! AOT artifacts were compiled for (1 and 8), trading batching latency
//! against executor efficiency — the standard serving trade-off, with
//! the artifact-shape constraint that a real single-model deployment
//! has.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrived: Instant,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Preferred (largest compiled) batch size.
    pub max_batch: usize,
    /// How long a request may wait for the batch to fill before being
    /// dispatched in a smaller (padded) batch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A dispatched batch: the requests plus the padding count (padded
/// slots replay request 0 and are discarded on output).
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub padding: usize,
}

impl Batch {
    pub fn size(&self) -> usize {
        self.requests.len() + self.padding
    }
}

/// FIFO dynamic batcher.
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop the next batch if the policy allows dispatch at `now`:
    /// dispatch when a full batch is ready, or when the oldest request
    /// has waited past `max_wait` (padding up to the compiled size).
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.policy.max_batch;
        let stale = now.duration_since(self.queue[0].arrived) >= self.policy.max_wait;
        if !full && !stale {
            return None;
        }
        let take = self.queue.len().min(self.policy.max_batch);
        let requests: Vec<Request> = self.queue.drain(..take).collect();
        // pad to the nearest compiled shape: 1 stays 1, everything else
        // pads up to max_batch
        let padding = if requests.len() == 1 { 0 } else { self.policy.max_batch - requests.len() };
        Some(Batch { requests, padding })
    }

    /// Drain everything immediately (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.policy.max_batch);
            let requests: Vec<Request> = self.queue.drain(..take).collect();
            let padding =
                if requests.len() == 1 { 0 } else { self.policy.max_batch - requests.len() };
            out.push(Batch { requests, padding });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, at: Instant) -> Request {
        Request { id, tokens: vec![0; 64], arrived: at }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..8 {
            b.push(req(i, t0));
        }
        let batch = b.pop_ready(t0).expect("full batch");
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(batch.padding, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i, t0));
        }
        assert!(b.pop_ready(t0).is_none(), "should wait for more");
        let later = t0 + Duration::from_millis(5);
        let batch = b.pop_ready(later).expect("stale dispatch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.padding, 5);
        assert_eq!(batch.size(), 8);
    }

    #[test]
    fn single_request_uses_batch1() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        b.push(req(0, t0));
        let batch = b.pop_ready(t0 + Duration::from_millis(5)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.padding, 0);
        assert_eq!(batch.size(), 1);
    }

    #[test]
    fn overflow_queues_remainder() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..11 {
            b.push(req(i, t0));
        }
        let batch = b.pop_ready(t0).unwrap();
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..10 {
            b.push(req(i, t0));
        }
        let batches = b.drain_all();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches.iter().map(|x| x.requests.len()).sum::<usize>(), 10);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatchPolicy::default());
        let t0 = Instant::now();
        for i in 0..8 {
            b.push(req(i, t0));
        }
        let batch = b.pop_ready(t0).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }
}
