//! The serving loop, grown into a multi-replica data-parallel tier:
//! a leader thread owns admission + continuous batching and dispatches
//! padded batches onto per-replica work-stealing deques
//! (`coordinator::replica`); N replica workers each own an executor
//! handle over the loaded artifacts and execute batches independently.
//! Dense batches run the AOT executables; Spls requests are planned on
//! the host, compiled into CSR/gather execution plans
//! (`model::sparse_plan`) and run through the packed sparse forward —
//! pruned work is skipped outright rather than masked out of a
//! dense-shaped program. Repeated shapes are served from the shared
//! [`SharedPlanCache`] — cache hits are bit-identical to fresh
//! planning (asserted below), so sparsity decisions are amortized
//! across the pipeline of workers instead of recomputed per batch.
//!
//! Single-process deployment with std threads + channels (no tokio in
//! the vendored crate set — see DESIGN.md §Environment).

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::SplsConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher, Request};
use crate::coordinator::replica::{
    self, Job, JobFault, ReplicaEvent, ReplicaMetrics, WorkQueue, MAX_JOB_ATTEMPTS,
};
use crate::decode::{
    DecodeConfig, DecodeEngine, DecodeMode, GenSession, PagedPool, PoolStats, Sampling,
};
use crate::model::{CompiledModelPlan, PackedModel, TinyWeights};
use crate::obs::{Lane, LatencyHistogram, Obs, Stage};
use crate::quant::QuantMethod;
use crate::runtime::{Arg, ArtifactSet};
use crate::spls::plan_cache::{CacheStats, SharedPlanCache, DEFAULT_CAPACITY};
use crate::util::fault::{FaultInjector, FaultPlan};
use crate::util::stats::LatencyWindow;

/// Tokens per paged KV block (pool geometry; see `decode::paged`).
/// Small enough that a shared prompt prefix maps mostly-full blocks,
/// large enough to amortize the per-block bookkeeping.
pub const PAGED_BLOCK_SIZE: usize = 8;

/// Default hard capacity of the server's paged KV pool, in blocks.
/// The pool never allocates past it: admission reserves each paged
/// session's worst-case demand up front ([`Server::paged_session_demand`])
/// and refuses sessions that don't fit, and if a live session still
/// hits the cap the allocator sheds cold prefix snapshots and, failing
/// that, aborts only that session (`PoolExhausted`) — never the tier.
pub const DEFAULT_POOL_BLOCKS: usize = 8192;

/// Serving statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    pub padded_slots: usize,
    pub total_latency: Duration,
    pub max_latency: Duration,
    pub p50_latency: Duration,
    pub p99_latency: Duration,
    /// Queue-wait percentiles: admission → replica pickup, per served
    /// request (log2-bucketed, see `obs::hist`).
    pub queue_wait_p50: Duration,
    pub queue_wait_p99: Duration,
    /// Execute percentiles: replica pickup → reply, per served request.
    pub execute_p50: Duration,
    pub execute_p99: Duration,
    pub wall: Duration,
    /// Requests rejected by `Batcher::admit` (never replied to). The
    /// in-process leader applies channel backpressure instead of
    /// shedding, so this stays 0 here; it is the hook for frontends
    /// that push into the batcher without a bufferable channel.
    pub shed: usize,
    /// Batches executed by a replica other than the dispatch target.
    pub steals: usize,
    /// Replica count the run was served with.
    pub replicas: usize,
    /// Classify batches requeued to a healthy replica after a worker
    /// fault (attempt count below [`MAX_JOB_ATTEMPTS`]).
    pub retried: usize,
    /// Batches that exhausted their retry budget: every request in
    /// them was answered with a per-request fault outcome (the gateway
    /// renders `replica_fault`), never a tier error.
    pub faulted: usize,
    /// Replica workers respawned by the supervisor after a fault.
    pub respawns: usize,
    /// Plan-cache counters (cumulative over the server's lifetime).
    pub plan_cache: CacheStats,
}

impl ServeMetrics {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.requests as f64 / self.wall.as_secs_f64()
        }
    }

    /// Throughput normalized by replica count (the scaling-efficiency
    /// axis of the serving bench surface).
    pub fn throughput_per_replica(&self) -> f64 {
        self.throughput_rps() / self.replicas.max(1) as f64
    }
}

/// A serve run's full outcome: aggregate metrics plus the per-replica
/// breakdown joined from the worker threads.
#[derive(Debug)]
pub struct ServeOutcome {
    pub metrics: ServeMetrics,
    pub per_replica: Vec<ReplicaMetrics>,
}

/// One named metric sample. This is the **single source of truth** for
/// the tier's observable numbers: the CLI `Display` impls and the
/// gateway's Prometheus `/metrics` endpoint both render the same rows,
/// so the two surfaces cannot drift.
#[derive(Clone, Debug)]
pub struct MetricRow {
    /// Prometheus-style snake_case name (without the `esact_` prefix
    /// the gateway adds on the wire).
    pub name: &'static str,
    /// Optional single label, e.g. `("replica", 0)` or `("shard", 3)`.
    pub label: Option<(&'static str, usize)>,
    pub value: f64,
}

impl MetricRow {
    pub fn of(name: &'static str, value: f64) -> Self {
        Self { name, label: None, value }
    }

    pub fn labeled(name: &'static str, key: &'static str, index: usize, value: f64) -> Self {
        Self { name, label: Some((key, index)), value }
    }
}

impl fmt::Display for MetricRow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let label = match self.label {
            Some((k, v)) => format!("{}{{{k}=\"{v}\"}}", self.name),
            None => self.name.to_string(),
        };
        // counters print as integers, gauges with enough precision for
        // sub-millisecond latencies
        if self.value.fract().abs() < 1e-9 && self.value.abs() < 1e15 {
            write!(f, "{label:<44} {:.0}", self.value)
        } else {
            write!(f, "{label:<44} {:.6}", self.value)
        }
    }
}

/// Shared cache-counter rows (prefill plans + decode step plans).
fn cache_rows(c: &CacheStats) -> Vec<MetricRow> {
    vec![
        MetricRow::of("plan_cache_hits_total", c.hits as f64),
        MetricRow::of("plan_cache_misses_total", c.misses as f64),
        MetricRow::of("plan_cache_hit_rate", c.hit_rate()),
        MetricRow::of("plan_cache_entries", c.entries as f64),
        MetricRow::of("plan_cache_evictions_total", c.evictions as f64),
        MetricRow::of("plan_cache_step_hits_total", c.step_hits as f64),
        MetricRow::of("plan_cache_step_misses_total", c.step_misses as f64),
        MetricRow::of("plan_cache_step_hit_rate", c.step_hit_rate()),
        MetricRow::of("plan_cache_step_entries", c.step_entries as f64),
        MetricRow::of("plan_cache_step_evictions_total", c.step_evictions as f64),
    ]
}

/// Paged KV pool rows (block accounting + prefix-sharing counters).
/// Exported by the gateway's `/metrics` next to the tier rows, so the
/// pool's residency and sharing behavior are observable mid-run.
pub fn paged_rows(s: &PoolStats) -> Vec<MetricRow> {
    vec![
        MetricRow::of("paged_blocks_in_use", s.in_use as f64),
        MetricRow::of("paged_blocks_peak", s.peak as f64),
        MetricRow::of("paged_blocks_capacity", s.max_blocks as f64),
        MetricRow::of("paged_blocks_allocated_total", s.allocated_total as f64),
        MetricRow::of("paged_cow_copies_total", s.cow_copies as f64),
        MetricRow::of("paged_prefix_hits_total", s.prefix_hits as f64),
        MetricRow::of("paged_prefix_misses_total", s.prefix_misses as f64),
        MetricRow::of("paged_prefix_hit_rate", s.hit_rate()),
        MetricRow::of("paged_shared_tokens_total", s.shared_attach_tokens as f64),
        MetricRow::of("paged_blocks_reserved", s.reserved as f64),
        MetricRow::of("paged_trie_entries", s.trie_entries as f64),
        MetricRow::of("paged_trie_evictions_total", s.trie_evictions as f64),
    ]
}

impl ServeMetrics {
    /// The classify tier's metric rows (plan-cache rows included).
    pub fn rows(&self) -> Vec<MetricRow> {
        let mut rows = vec![
            MetricRow::of("serve_requests_total", self.requests as f64),
            MetricRow::of("serve_batches_total", self.batches as f64),
            MetricRow::of("serve_padded_slots_total", self.padded_slots as f64),
            MetricRow::of("serve_shed_total", self.shed as f64),
            MetricRow::of("serve_steals_total", self.steals as f64),
            MetricRow::of("serve_replicas", self.replicas as f64),
            MetricRow::of("serve_jobs_retried_total", self.retried as f64),
            MetricRow::of("serve_jobs_faulted_total", self.faulted as f64),
            MetricRow::of("serve_replica_respawns_total", self.respawns as f64),
            MetricRow::of("serve_latency_p50_seconds", self.p50_latency.as_secs_f64()),
            MetricRow::of("serve_latency_p99_seconds", self.p99_latency.as_secs_f64()),
            MetricRow::of("serve_queue_wait_p50_seconds", self.queue_wait_p50.as_secs_f64()),
            MetricRow::of("serve_queue_wait_p99_seconds", self.queue_wait_p99.as_secs_f64()),
            MetricRow::of("serve_execute_p50_seconds", self.execute_p50.as_secs_f64()),
            MetricRow::of("serve_execute_p99_seconds", self.execute_p99.as_secs_f64()),
            MetricRow::of("serve_latency_max_seconds", self.max_latency.as_secs_f64()),
            MetricRow::of("serve_throughput_rps", self.throughput_rps()),
        ];
        rows.extend(cache_rows(&self.plan_cache));
        rows
    }
}

impl GenerateMetrics {
    /// The decode tier's metric rows (step-cache rows included).
    pub fn rows(&self) -> Vec<MetricRow> {
        let mut rows = vec![
            MetricRow::of("generate_sessions_total", self.sessions as f64),
            MetricRow::of("generate_tokens_total", self.tokens as f64),
            MetricRow::of("generate_slices_total", self.slices as f64),
            MetricRow::of("generate_rejected_total", self.rejected as f64),
            MetricRow::of("generate_aborted_total", self.aborted as f64),
            MetricRow::of("generate_steals_total", self.steals as f64),
            MetricRow::of("generate_replicas", self.replicas as f64),
            MetricRow::of("generate_sessions_migrated_total", self.migrated as f64),
            MetricRow::of("generate_jobs_faulted_total", self.faulted as f64),
            MetricRow::of("generate_replica_respawns_total", self.respawns as f64),
            MetricRow::of("generate_session_p50_seconds", self.p50_session.as_secs_f64()),
            MetricRow::of("generate_session_p99_seconds", self.p99_session.as_secs_f64()),
            MetricRow::of("generate_ttft_p50_seconds", self.ttft_p50.as_secs_f64()),
            MetricRow::of("generate_ttft_p99_seconds", self.ttft_p99.as_secs_f64()),
            MetricRow::of("generate_queue_wait_p50_seconds", self.queue_wait_p50.as_secs_f64()),
            MetricRow::of("generate_queue_wait_p99_seconds", self.queue_wait_p99.as_secs_f64()),
            MetricRow::of("generate_tokens_per_sec", self.tokens_per_sec()),
        ];
        rows.extend(cache_rows(&self.plan_cache));
        rows
    }
}

/// Per-replica counter rows (classify and decode tiers share the
/// replica pool schema).
pub fn replica_rows(per_replica: &[ReplicaMetrics]) -> Vec<MetricRow> {
    let mut rows = Vec::with_capacity(per_replica.len() * 6);
    for r in per_replica {
        let of = |name, value| MetricRow::labeled(name, "replica", r.replica, value);
        rows.push(of("replica_batches_total", r.batches as f64));
        rows.push(of("replica_requests_total", r.requests as f64));
        rows.push(of("replica_decode_slices_total", r.decode_slices as f64));
        rows.push(of("replica_tokens_total", r.tokens as f64));
        rows.push(of("replica_steals_total", r.steals as f64));
        rows.push(of("replica_busy_seconds", r.busy.as_secs_f64()));
    }
    rows
}

fn fmt_rows(f: &mut fmt::Formatter<'_>, rows: &[MetricRow]) -> fmt::Result {
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

impl fmt::Display for ServeOutcome {
    /// Renders exactly the rows `/metrics` exports (same names, same
    /// values) — see [`MetricRow`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_rows(f, &self.metrics.rows())?;
        fmt_rows(f, &replica_rows(&self.per_replica))
    }
}

/// A terminal per-request fault outcome: the request's job died on a
/// replica more times than the retry budget allows. Delivered through
/// the normal reply/chunk plumbing (never a tier error) with a stable
/// machine-readable code the gateway renders into its error envelope —
/// `replica_fault`, distinct from `tier_timeout`, so clients can tell
/// "your request kept killing workers" from "the tier is slow".
#[derive(Clone, Debug)]
pub struct StreamFault {
    pub code: &'static str,
    pub message: String,
}

impl StreamFault {
    /// The stable code for replica-fault outcomes.
    pub const REPLICA_FAULT: &'static str = "replica_fault";

    fn replica_fault(message: String) -> Self {
        Self { code: Self::REPLICA_FAULT, message }
    }
}

/// One served reply.
#[derive(Clone, Debug)]
pub struct Reply {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Admission → replica pickup for this request (zero on the fault
    /// path, where no execution started on the final attempt).
    pub queue_wait: Duration,
    /// Set when the request's batch exhausted its retry budget: the
    /// logits are empty and the gateway answers a 500 `replica_fault`
    /// envelope instead of a result.
    pub fault: Option<StreamFault>,
}

/// One streaming generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    /// With `prefix: None`, the whole prompt (private contiguous KV).
    /// With `prefix: Some(p)`, the prompt *tail* after the shared
    /// prefix `p` — the session decodes `p ++ prompt` through the
    /// server's paged pool, mapping `p`'s published blocks on a trie
    /// hit.
    pub prompt: Vec<i32>,
    /// Optional shared-prefix handle (token ids) for paged decode.
    pub prefix: Option<Vec<i32>>,
    pub max_new: usize,
    pub sampling: Sampling,
    pub arrived: Instant,
}

/// One streamed chunk of a generation: the tokens produced by the
/// latest decode slice (possibly empty while the prompt prefills) and
/// whether the session finished.
#[derive(Clone, Debug)]
pub struct GenChunk {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub done: bool,
    /// Set on the final chunk of a stream that was aborted by a
    /// replica fault (retry budget exhausted): the gateway emits the
    /// in-band `replica_fault` abort envelope before closing.
    pub fault: Option<StreamFault>,
}

/// A generation session in flight on the replica tier.
pub struct GenTask {
    pub id: u64,
    pub arrived: Instant,
    pub session: GenSession,
}

/// Aggregate metrics of one `serve_generate` run.
#[derive(Clone, Copy, Debug, Default)]
pub struct GenerateMetrics {
    pub sessions: usize,
    /// Tokens generated across all sessions.
    pub tokens: usize,
    /// Decode slices dispatched (continuous-batching granularity).
    pub slices: usize,
    /// Paged sessions refused at admission because their worst-case
    /// block demand did not fit the pool's reservation ledger (the
    /// client saw an immediate empty `done` chunk; the gateway's
    /// preflight answers 429 before it gets this far).
    pub rejected: usize,
    /// Sessions aborted mid-decode by a recoverable per-session fault
    /// (paged pool exhaustion); each ended with an empty `done` chunk
    /// while the tier kept serving.
    pub aborted: usize,
    /// Slices executed by a replica other than the dispatch target.
    pub steals: usize,
    /// Sessions migrated to a healthy replica after a worker fault:
    /// re-prefilled from the retained prompt + emitted tokens through
    /// the chunked-prefill path, sampling RNG fast-forwarded — the
    /// continuation is bit-identical to an unfaulted run.
    pub migrated: usize,
    /// Decode jobs whose session exhausted its retry budget; the
    /// stream ended with an in-band `replica_fault` abort envelope.
    pub faulted: usize,
    /// Replica workers respawned by the supervisor after a fault.
    pub respawns: usize,
    pub wall: Duration,
    pub replicas: usize,
    pub p50_session: Duration,
    pub p99_session: Duration,
    /// Time-to-first-token percentiles: admission → first fresh token
    /// forwarded to the client.
    pub ttft_p50: Duration,
    pub ttft_p99: Duration,
    /// Queue-wait percentiles: admission → first decode slice picked
    /// up by a replica.
    pub queue_wait_p50: Duration,
    pub queue_wait_p99: Duration,
    /// Plan-cache counters (step hits/misses live here too).
    pub plan_cache: CacheStats,
}

impl GenerateMetrics {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.tokens as f64 / self.wall.as_secs_f64()
        }
    }
}

/// A generate run's outcome: aggregates plus per-replica counters.
#[derive(Debug)]
pub struct GenerateOutcome {
    pub metrics: GenerateMetrics,
    pub per_replica: Vec<ReplicaMetrics>,
}

impl fmt::Display for GenerateOutcome {
    /// Renders exactly the rows `/metrics` exports — see [`MetricRow`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_rows(f, &self.metrics.rows())?;
        fmt_rows(f, &replica_rows(&self.per_replica))
    }
}

/// Execution mode of the serve path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Dense executable.
    Dense,
    /// SPLS: host planner builds SPA masks, masked executable runs.
    Spls,
}

/// Cumulative live counters for the whole serving tier, updated by the
/// leader loops as they absorb replica events, so an external scraper
/// (the network gateway's `/metrics`) can observe the tier *mid-run*.
/// The per-run [`ServeOutcome`] / [`GenerateOutcome`] joined at drain
/// remain the exact end-of-run accounting.
#[derive(Default)]
pub(crate) struct LiveTier {
    started: Option<Instant>,
    serve: ServeMetrics,
    generate: GenerateMetrics,
    latencies: LatencyWindow,
    session_latencies: LatencyWindow,
    per_replica: Vec<ReplicaMetrics>,
}

impl LiveTier {
    fn touch(&mut self) {
        self.started.get_or_insert_with(Instant::now);
    }

    fn replica_mut(&mut self, id: usize) -> &mut ReplicaMetrics {
        if self.per_replica.len() <= id {
            self.per_replica.resize_with(id + 1, Default::default);
            for (i, r) in self.per_replica.iter_mut().enumerate() {
                r.replica = i;
            }
        }
        &mut self.per_replica[id]
    }

    fn record_batch(
        &mut self,
        replica: usize,
        replies: &[Reply],
        padding: usize,
        stolen: bool,
        busy: Duration,
    ) {
        self.serve.batches += 1;
        self.serve.padded_slots += padding;
        self.serve.steals += usize::from(stolen);
        for reply in replies {
            self.serve.requests += 1;
            self.serve.total_latency += reply.latency;
            self.serve.max_latency = self.serve.max_latency.max(reply.latency);
            self.latencies.push(reply.latency.as_secs_f64());
        }
        let r = self.replica_mut(replica);
        r.batches += 1;
        r.requests += replies.len();
        r.steals += usize::from(stolen);
        r.busy += busy;
    }

    fn record_decode(
        &mut self,
        replica: usize,
        fresh: usize,
        stolen: bool,
        busy: Duration,
        session_latency: Option<f64>,
    ) {
        self.generate.slices += 1;
        self.generate.tokens += fresh;
        self.generate.steals += usize::from(stolen);
        if let Some(lat) = session_latency {
            self.session_latencies.push(lat);
        }
        let r = self.replica_mut(replica);
        r.decode_slices += 1;
        r.tokens += fresh;
        r.steals += usize::from(stolen);
        r.busy += busy;
    }
}

/// A point-in-time snapshot of the live tier counters (see
/// [`Server::live_snapshot`]): the network gateway renders this through
/// the same [`MetricRow`] schema the CLI `Display` impls use.
#[derive(Debug)]
pub struct TierSnapshot {
    pub serve: ServeMetrics,
    pub generate: GenerateMetrics,
    pub per_replica: Vec<ReplicaMetrics>,
    /// Time since the first serve/generate leader started (zero before
    /// any work arrived). `serve.wall`/`generate.wall` are set to this,
    /// so the snapshot's `throughput_rps()` reads as a lifetime mean.
    pub uptime: Duration,
}

impl TierSnapshot {
    /// All rows: classify tier + decode tier + per-replica counters.
    /// The plan-cache rows appear in both tiers' standalone `Display`
    /// output but are deduplicated here (they snapshot the same shared
    /// cache), so a Prometheus scrape never sees a name twice.
    pub fn rows(&self) -> Vec<MetricRow> {
        let mut rows = self.serve.rows();
        let mut seen: Vec<(&'static str, Option<(&'static str, usize)>)> =
            rows.iter().map(|r| (r.name, r.label)).collect();
        for row in self.generate.rows().into_iter().chain(replica_rows(&self.per_replica)) {
            let key = (row.name, row.label);
            if !seen.contains(&key) {
                seen.push(key);
                rows.push(row);
            }
        }
        // tier-wide degradation counters: the per-lane rows above keep
        // the breakdown, these sum both lanes under the stable names
        // dashboards alert on
        rows.push(MetricRow::of(
            "replica_respawns_total",
            (self.serve.respawns + self.generate.respawns) as f64,
        ));
        rows.push(MetricRow::of("jobs_retried_total", self.serve.retried as f64));
        rows.push(MetricRow::of(
            "jobs_faulted_total",
            (self.serve.faulted + self.generate.faulted) as f64,
        ));
        rows.push(MetricRow::of("sessions_migrated_total", self.generate.migrated as f64));
        rows
    }
}

/// Everything the replicas share: the loaded artifacts (each worker
/// clones its own `Send`-able handle at startup), the weights the host
/// planner reads, and the plan cache. Lives behind one `Arc` so the
/// leader and every worker see the same state.
pub(crate) struct ServerCore {
    artifacts: ArtifactSet,
    weights: Arc<TinyWeights>,
    /// The packed execution model the host planner and every decode
    /// session share (one packing per server, backend-independent —
    /// the reference backend's executables hold their own shared
    /// instance inside `artifacts`).
    packed: Arc<PackedModel>,
    spls: SplsConfig,
    mode: Mode,
    n_classes: usize,
    cache: SharedPlanCache,
    /// Shared decode engine (a view over `packed`) for
    /// `serve_generate` sessions.
    engine: Arc<DecodeEngine>,
    /// Shared paged KV block pool: every `serve_generate` session that
    /// declares a prompt prefix maps/publishes blocks here (prefix-trie
    /// sharing with copy-on-write divergence — `decode::paged`).
    paged: PagedPool,
    /// Live tier counters (see [`LiveTier`]); leaders update it as
    /// they absorb replica events, `/metrics` scrapes it mid-run.
    live: Mutex<LiveTier>,
    /// Optional deterministic fault injection (chaos testing): replica
    /// workers consult it at job start, the paged pool holds its own
    /// handle on the allocation path, and the gateway checks it on
    /// socket writes. `None` (the default) costs one branch per job.
    fault: Option<FaultInjector>,
    /// Tier-wide observability: the trace hub (per-request stage spans)
    /// and the shared per-lane latency histograms `/metrics` exports.
    /// Atomic counters + sharded span buffers — replicas and leaders
    /// record into it without coordination (`obs::`).
    obs: Arc<Obs>,
}

impl ServerCore {
    pub(crate) fn artifacts(&self) -> &ArtifactSet {
        &self.artifacts
    }

    pub(crate) fn engine(&self) -> &Arc<DecodeEngine> {
        &self.engine
    }

    pub(crate) fn fault_injector(&self) -> Option<&FaultInjector> {
        self.fault.as_ref()
    }

    /// The tier's observability state (trace hub + latency histograms).
    pub(crate) fn obs(&self) -> &Obs {
        &self.obs
    }

    /// Poison-tolerant lock on the live tier counters: a replica panic
    /// unwinding while a leader held this lock would poison it, and
    /// counters must never take down otherwise-healthy threads (the
    /// counters are plain sums — every update leaves them consistent).
    pub(crate) fn live(&self) -> MutexGuard<'_, LiveTier> {
        live_lock(&self.live)
    }

    /// Plan one request's per-layer SPLS plans, serving repeated shapes
    /// from the shared plan cache (hits are bit-identical to fresh
    /// planning — the cache stores the planner's own output). Fresh
    /// plans run on the shared packed model (pre-quantized predictor
    /// operands) with this worker thread's scratch arena; packed
    /// planning is bit-identical to `model::plan_model`
    /// (`tests/packed_parity.rs`).
    fn plans_for(&self, tokens: &[i32]) -> Vec<crate::spls::plan::LayerPlan> {
        self.cache.get_or_compute(
            tokens,
            &self.spls,
            QuantMethod::Hlog,
            self.weights.cfg.n_layers,
            || {
                crate::util::scratch::with_thread_scratch(|sc| {
                    self.packed.plan_model(tokens, &self.spls, QuantMethod::Hlog, sc)
                })
            },
        )
    }

    /// Execute one batch (size 1 or 8, padded by the batcher) on the
    /// given executor handle — the caller (a replica worker) owns the
    /// handle; the core supplies planning + weights. Dense mode pads to
    /// the compiled batch shape; Spls mode executes per request on the
    /// host (compiled sparse forward) and never runs padding slots.
    pub(crate) fn execute_on(
        &self,
        artifacts: &ArtifactSet,
        requests: &[Request],
        padding: usize,
    ) -> Result<Vec<Reply>> {
        let t_exec = Instant::now();
        let batch = requests.len() + padding;
        let cfg = &self.weights.cfg;
        let l = cfg.seq_len;
        let mut toks = Vec::with_capacity(batch * l);
        for r in requests {
            assert_eq!(r.tokens.len(), l, "request length != compiled L");
            toks.extend_from_slice(&r.tokens);
        }
        for _ in 0..padding {
            toks.extend_from_slice(&requests[0].tokens);
        }
        let logits = match self.mode {
            Mode::Dense => artifacts
                .dense_for_batch(batch)?
                .run_f32(&[Arg::I32(&toks, &[batch, l])])?,
            Mode::Spls => {
                // SPLS planning *and* compiled sparse execution are
                // per-request independent — fan both out over scoped
                // threads (§Perf step 5: the planner was the serving
                // bottleneck once the executables got fast; cache hits
                // return without planning at all). Each worker lowers
                // its request's plans into CSR/gather form and runs the
                // packed sparse forward on the host — pruned work is
                // skipped, not masked, and padding slots are never
                // executed (no fixed batch shape to fill).
                let per: Vec<Vec<f32>> = crossbeam_utils::thread::scope(|scope| {
                    let handles: Vec<_> = requests
                        .iter()
                        .map(|r| {
                            let tokens = &r.tokens;
                            scope.spawn(move |_| {
                                let plans = self.plans_for(tokens);
                                let compiled = CompiledModelPlan::lower(&plans);
                                crate::util::scratch::with_thread_scratch(|sc| {
                                    self.packed.forward_sparse_compiled(tokens, &compiled, sc)
                                })
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                })
                .expect("sparse worker thread panicked");
                per.into_iter().flatten().collect()
            }
        };
        let now = Instant::now();
        Ok(requests
            .iter()
            .enumerate()
            .map(|(i, r)| Reply {
                id: r.id,
                logits: logits[i * self.n_classes..(i + 1) * self.n_classes].to_vec(),
                latency: now.duration_since(r.arrived),
                queue_wait: t_exec.saturating_duration_since(r.arrived),
                fault: None,
            })
            .collect())
    }
}

/// Lock a [`LiveTier`] mutex, recovering from poisoning: the guarded
/// value is a bag of monotonic counters, so a panic mid-update leaves
/// it merely stale, never structurally broken — and the metrics path
/// must not cascade a replica panic into the leader or the gateway.
pub(crate) fn live_lock(live: &Mutex<LiveTier>) -> MutexGuard<'_, LiveTier> {
    live.lock().unwrap_or_else(|e| e.into_inner())
}

/// The serving coordinator.
pub struct Server {
    core: Arc<ServerCore>,
    seq_len: usize,
}

impl Server {
    pub fn new(artifact_dir: &Path, mode: Mode, spls: SplsConfig) -> Result<Self> {
        Self::with_plan_cache_capacity(artifact_dir, mode, spls, DEFAULT_CAPACITY)
    }

    /// Like [`Server::new`] with an explicit plan-cache entry capacity
    /// (per-layer entries; see `spls::plan_cache`).
    pub fn with_plan_cache_capacity(
        artifact_dir: &Path,
        mode: Mode,
        spls: SplsConfig,
        cache_capacity: usize,
    ) -> Result<Self> {
        Self::build(artifact_dir, mode, spls, cache_capacity, DEFAULT_POOL_BLOCKS, None)
    }

    /// Like [`Server::new`] with an explicit paged-pool block capacity
    /// (tests exercise exhaustion/rejection against a small pool; the
    /// production default is [`DEFAULT_POOL_BLOCKS`]).
    pub fn with_pool_blocks(
        artifact_dir: &Path,
        mode: Mode,
        spls: SplsConfig,
        pool_blocks: usize,
    ) -> Result<Self> {
        Self::build(artifact_dir, mode, spls, DEFAULT_CAPACITY, pool_blocks, None)
    }

    /// Like [`Server::new`] with a deterministic [`FaultPlan`] armed:
    /// replica workers, the paged pool and the gateway will consult the
    /// shared injector at their respective sites. Chaos/CI entry point —
    /// production callers use the plain constructors (injection off).
    pub fn with_fault_plan(
        artifact_dir: &Path,
        mode: Mode,
        spls: SplsConfig,
        plan: FaultPlan,
    ) -> Result<Self> {
        Self::build(
            artifact_dir,
            mode,
            spls,
            DEFAULT_CAPACITY,
            DEFAULT_POOL_BLOCKS,
            Some(plan),
        )
    }

    fn build(
        artifact_dir: &Path,
        mode: Mode,
        spls: SplsConfig,
        cache_capacity: usize,
        pool_blocks: usize,
        fault: Option<FaultPlan>,
    ) -> Result<Self> {
        let artifacts = ArtifactSet::load(artifact_dir)?;
        // one packing serves the whole coordinator: planner, decode
        // engine and (on the reference backend) every replica's executor
        // handle share a single Arc<PackedModel>, built once at load.
        // The pjrt ArtifactSet doesn't expose weights, so that backend
        // loads and packs its own copy here.
        #[cfg(not(feature = "pjrt"))]
        let (weights, packed) = (Arc::clone(&artifacts.weights), Arc::clone(&artifacts.packed));
        #[cfg(feature = "pjrt")]
        let (weights, packed) = {
            let weights = Arc::new(TinyWeights::load(&artifact_dir.join("tiny_weights.bin"))?);
            let packed = Arc::new(PackedModel::new(Arc::clone(&weights)));
            (weights, packed)
        };
        let engine = Arc::new(DecodeEngine::from_packed(Arc::clone(&packed)));
        let paged = PagedPool::new(PAGED_BLOCK_SIZE, pool_blocks, weights.cfg.d_head());
        let fault = fault.map(FaultInjector::new);
        if let Some(inj) = &fault {
            // One injector, shared by every site: call counters are
            // per-site, so arming the pool does not perturb the job
            // sites' deterministic schedules.
            paged.set_fault_injector(inj.clone());
        }
        Ok(Self {
            seq_len: weights.cfg.seq_len,
            core: Arc::new(ServerCore {
                n_classes: weights.cfg.n_classes,
                artifacts,
                weights,
                packed,
                spls,
                mode,
                cache: SharedPlanCache::new(cache_capacity),
                engine,
                paged,
                live: Mutex::new(LiveTier::default()),
                fault,
                obs: Arc::new(Obs::new()),
            }),
        })
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Vocabulary size of the loaded model — the gateway validates
    /// token ids against it before they can reach an executor.
    pub fn vocab(&self) -> usize {
        self.core.weights.cfg.vocab
    }

    /// Classifier output width.
    pub fn n_classes(&self) -> usize {
        self.core.n_classes
    }

    /// The armed deterministic fault injector, if any — chaos/CI runs
    /// arm one via [`Server::with_fault_plan`]; production servers
    /// return `None` and every injection site is a single branch.
    pub fn fault_injector(&self) -> Option<&FaultInjector> {
        self.core.fault_injector()
    }

    /// Plan-cache counters (cumulative across serve runs).
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.core.cache.stats()
    }

    /// Per-shard plan-cache counters (index = shard), for dashboards
    /// that want the shard distribution rather than the summed view.
    pub fn plan_cache_shard_stats(&self) -> Vec<CacheStats> {
        self.core.cache.shard_stats()
    }

    /// The server's shared paged KV block pool (prefix sharing + CoW).
    pub fn paged_pool(&self) -> &PagedPool {
        &self.core.paged
    }

    /// The tier's observability state: per-request trace spans and the
    /// shared per-lane latency histograms (`obs::`). The gateway reads
    /// it to render `/metrics` histograms and `/debug/trace`.
    pub fn obs(&self) -> &Obs {
        self.core.obs()
    }

    /// Point-in-time counters of the paged KV pool (see [`paged_rows`]).
    pub fn paged_stats(&self) -> PoolStats {
        self.core.paged.stats()
    }

    /// Worst-case paged-pool block demand of one session whose prompt
    /// plus generated tokens total `total_tokens`, over this model's
    /// layer/head grid — the unit the generate leader reserves at
    /// admission and the gateway preflights against
    /// [`PagedPool::can_reserve`].
    pub fn paged_session_demand(&self, total_tokens: usize) -> usize {
        let cfg = &self.core.weights.cfg;
        self.core.paged.session_demand(total_tokens, cfg.n_layers, cfg.n_heads)
    }

    /// Snapshot the live tier counters (see [`TierSnapshot`]). Live
    /// percentiles are estimated over a bounded sliding window of the
    /// most recent samples ([`LatencyWindow`]).
    pub fn live_snapshot(&self) -> TierSnapshot {
        let live = self.core.live();
        let uptime = live.started.map(|t| t.elapsed()).unwrap_or_default();
        let mut serve = live.serve;
        let mut generate = live.generate;
        let cache = self.core.cache.stats();
        serve.plan_cache = cache;
        generate.plan_cache = cache;
        serve.wall = uptime;
        generate.wall = uptime;
        serve.replicas = live.per_replica.len();
        generate.replicas = live.per_replica.len();
        let as_durations = |(p50, p99): (f64, f64)| {
            (Duration::from_secs_f64(p50), Duration::from_secs_f64(p99))
        };
        (serve.p50_latency, serve.p99_latency) = as_durations(live.latencies.percentiles());
        (generate.p50_session, generate.p99_session) =
            as_durations(live.session_latencies.percentiles());
        // Stage breakdowns come from the shared lifetime histograms
        // (exact log2-bucket quantiles, not a sliding window).
        let obs = self.core.obs();
        let q = |h: &LatencyHistogram| {
            let s = h.snapshot();
            (
                Duration::from_secs_f64(s.quantile(0.50)),
                Duration::from_secs_f64(s.quantile(0.99)),
            )
        };
        (serve.queue_wait_p50, serve.queue_wait_p99) = q(&obs.classify.queue_wait);
        (serve.execute_p50, serve.execute_p99) = q(&obs.classify.execute);
        (generate.ttft_p50, generate.ttft_p99) = q(&obs.generate.ttft);
        (generate.queue_wait_p50, generate.queue_wait_p99) = q(&obs.generate.queue_wait);
        TierSnapshot { serve, generate, per_replica: live.per_replica.clone(), uptime }
    }

    /// Execute one batch inline on the shared artifacts (tests and
    /// offline comparisons; the serve path goes through the replicas).
    fn execute(&self, requests: &[Request], padding: usize) -> Result<Vec<Reply>> {
        self.core.execute_on(&self.core.artifacts, requests, padding)
    }

    /// Serve a stream of requests from a channel until it closes, on a
    /// single replica; replies go out on `replies`. Returns aggregate
    /// metrics. See [`Server::serve_replicated`] for the scaled tier.
    pub fn serve(
        &self,
        requests: mpsc::Receiver<Request>,
        replies: mpsc::Sender<Reply>,
        policy: BatchPolicy,
    ) -> Result<ServeMetrics> {
        self.serve_replicated(requests, replies, policy, 1).map(|o| o.metrics)
    }

    /// Serve a stream of requests across `n_replicas` data-parallel
    /// worker replicas:
    ///
    /// * the leader admits arrivals (applying channel backpressure at
    ///   `policy.max_queue`) and runs continuous batching — full or
    ///   `max_wait`-stale batches dispatch while the replica pipeline
    ///   has room; a partial batch is refilled by later arrivals while
    ///   every replica is busy and dispatched eagerly the moment one
    ///   goes idle;
    /// * dispatch targets the least-loaded replica deque; idle
    ///   replicas steal queued batches from loaded peers;
    /// * every replica owns its own executor handle and reports
    ///   per-batch events back to the leader, which forwards replies
    ///   and aggregates latency percentiles.
    pub fn serve_replicated(
        &self,
        requests: mpsc::Receiver<Request>,
        replies: mpsc::Sender<Reply>,
        policy: BatchPolicy,
        n_replicas: usize,
    ) -> Result<ServeOutcome> {
        assert!(n_replicas >= 1, "need at least one replica");
        self.core.live().touch();
        let queue = Arc::new(WorkQueue::new(n_replicas));
        let (etx, erx) = mpsc::channel();
        // the leader keeps its own etx clone so it can hand fresh
        // senders to respawned workers; every worker death is preceded
        // by an event (Faulted/Failed), so the leader never depends on
        // a channel disconnect to learn the tier is empty
        let mut workers: Vec<Option<JoinHandle<ReplicaMetrics>>> =
            replica::spawn_replicas(Arc::clone(&self.core), Arc::clone(&queue), etx.clone(), n_replicas)
                .into_iter()
                .map(Some)
                .collect();
        let mut dead_metrics: Vec<ReplicaMetrics> = Vec::new();

        let mut batcher = Batcher::new(policy);
        let mut st = LeaderState {
            metrics: ServeMetrics { replicas: n_replicas, ..Default::default() },
            total_hist: LatencyHistogram::new(),
            queue_wait_hist: LatencyHistogram::new(),
            execute_hist: LatencyHistogram::new(),
            in_flight: 0,
            first_error: None,
            pending_respawns: Vec::new(),
            core: Arc::clone(&self.core),
        };
        let start = Instant::now();
        let tick = Duration::from_micros(200);
        // max_queue = 0 would mean "never pull" and hang; clamp to 1
        let max_queue = policy.max_queue.max(1);
        let mut open = true;
        let mut queue_closed = false;

        while !(queue_closed && st.in_flight == 0) && st.first_error.is_none() {
            // 1. admit new arrivals — but only while the batcher has
            //    room: at max_queue the leader stops *pulling*, leaving
            //    excess buffered in the channel (backpressure, no
            //    loss), instead of shedding requests it could serve
            //    later. Once the input closes, pace on completions.
            if open && batcher.pending() < max_queue {
                match requests.recv_timeout(tick) {
                    Ok(r) => {
                        let id = r.id;
                        if batcher.admit(r) {
                            self.core.obs().trace.event(id, Stage::Queued);
                        } else {
                            st.metrics.shed += 1;
                            self.core.obs().trace.fault(id, "shed");
                            self.core.obs().trace.finish(id, Stage::Faulted);
                        }
                        while batcher.pending() < max_queue {
                            match requests.try_recv() {
                                Ok(r) => {
                                    let id = r.id;
                                    if batcher.admit(r) {
                                        self.core.obs().trace.event(id, Stage::Queued);
                                    } else {
                                        st.metrics.shed += 1;
                                        self.core.obs().trace.fault(id, "shed");
                                        self.core.obs().trace.finish(id, Stage::Faulted);
                                    }
                                }
                                Err(_) => break,
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            } else if st.in_flight > 0 {
                match erx.recv_timeout(tick) {
                    Ok(ev) => st.absorb(ev, &replies, &queue, &self.core.live),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // every worker exited without reporting the
                        // outstanding batches — don't wait forever
                        st.first_error = Some(anyhow::anyhow!(
                            "all replicas exited with {} batches in flight",
                            st.in_flight
                        ));
                    }
                }
            }
            // 2. drain completion events without blocking, then
            //    supervise: every Faulted event left a dead worker slot
            //    behind — join its counters and respawn it on the same
            //    deque (queued jobs survive a worker death untouched)
            while let Ok(ev) = erx.try_recv() {
                st.absorb(ev, &replies, &queue, &self.core.live);
            }
            let respawned = respawn_workers(
                &self.core,
                &queue,
                &etx,
                &mut workers,
                &mut dead_metrics,
                &mut st.pending_respawns,
            );
            if respawned > 0 {
                st.metrics.respawns += respawned;
                self.core.live().serve.respawns += respawned;
            }
            // 3. dispatch: full/stale batches while the pipeline has
            //    room (≤ 2 outstanding batches per replica, so
            //    admission's max_queue — not the replica deques — is
            //    what bounds overload); partial batches eagerly while a
            //    replica is truly idle (continuous batching);
            //    everything on the shutdown drain
            let now = Instant::now();
            let dispatch_cap = 2 * n_replicas;
            loop {
                let batch = if !open {
                    batcher.pop_eager()
                } else if st.in_flight >= dispatch_cap {
                    None
                } else if let Some(b) = batcher.pop_ready(now) {
                    Some(b)
                } else if policy.eager_dispatch && st.in_flight < n_replicas {
                    batcher.pop_eager()
                } else {
                    None
                };
                match batch {
                    Some(batch) => {
                        st.in_flight += 1;
                        for r in &batch.requests {
                            self.core.obs().trace.event(r.id, Stage::Dispatched);
                        }
                        queue.push_least_loaded(Job::Classify { batch, attempt: 1 });
                    }
                    None => break,
                }
            }
            // 4. input closed and everything dispatched → let workers
            //    drain out and exit
            if !open && batcher.pending() == 0 && !queue_closed {
                queue.close();
                queue_closed = true;
            }
        }

        queue.close(); // idempotent; reached early only on Failed
        let per_replica: Vec<ReplicaMetrics> = workers
            .into_iter()
            .flatten()
            .map(|h| h.join().expect("replica thread panicked"))
            .collect();
        // absorb events that raced shutdown (workers drained the queue
        // between our last poll and their exit); the queue is closed,
        // so a raced Faulted is answered in-band, never requeued
        while let Ok(ev) = erx.try_recv() {
            st.absorb(ev, &replies, &queue, &self.core.live);
        }
        // fold counters of mid-run casualties into their slot's final
        // row: ServeOutcome keeps one row per replica slot
        let per_replica = merge_replica_metrics(per_replica, dead_metrics);
        if let Some(err) = st.first_error.take() {
            return Err(err);
        }

        let LeaderState { mut metrics, total_hist, queue_wait_hist, execute_hist, .. } = st;
        let total = total_hist.snapshot();
        if !total.is_empty() {
            metrics.p50_latency = Duration::from_secs_f64(total.quantile(0.50));
            metrics.p99_latency = Duration::from_secs_f64(total.quantile(0.99));
            let qw = queue_wait_hist.snapshot();
            metrics.queue_wait_p50 = Duration::from_secs_f64(qw.quantile(0.50));
            metrics.queue_wait_p99 = Duration::from_secs_f64(qw.quantile(0.99));
            let ex = execute_hist.snapshot();
            metrics.execute_p50 = Duration::from_secs_f64(ex.quantile(0.50));
            metrics.execute_p99 = Duration::from_secs_f64(ex.quantile(0.99));
        }
        metrics.wall = start.elapsed();
        metrics.plan_cache = self.core.cache.stats();
        Ok(ServeOutcome { metrics, per_replica })
    }

    /// Serve a stream of generation requests across `n_replicas`
    /// replicas with **continuous batching of decode steps**: every
    /// session is dispatched as slices of `steps_per_slice` decode
    /// steps onto the same work-stealing deques the classify path uses;
    /// after each slice the leader streams the fresh tokens to
    /// `replies` (one [`GenChunk`] per slice) and requeues the session,
    /// so many sessions interleave across few replicas and new arrivals
    /// start decoding immediately instead of waiting for a whole
    /// generation to finish. In `Spls` mode every session shares the
    /// server's plan cache (decode-bucket step plans).
    pub fn serve_generate(
        &self,
        requests: mpsc::Receiver<GenRequest>,
        replies: mpsc::Sender<GenChunk>,
        decode: DecodeConfig,
        n_replicas: usize,
        steps_per_slice: usize,
    ) -> Result<GenerateOutcome> {
        self.serve_generate_chunked(requests, replies, decode, n_replicas, steps_per_slice, 0)
    }

    /// [`Server::serve_generate`] with **chunked prefill**: sessions
    /// still feeding prompt tokens are dispatched in slices of
    /// `prefill_chunk` steps (0 ⇒ same as `steps_per_slice`), so a long
    /// prompt fills its KV cache in bounded chunks interleaved with
    /// other sessions' decode slices instead of monopolizing a replica
    /// until the whole prompt is in.
    pub fn serve_generate_chunked(
        &self,
        requests: mpsc::Receiver<GenRequest>,
        replies: mpsc::Sender<GenChunk>,
        decode: DecodeConfig,
        n_replicas: usize,
        steps_per_slice: usize,
        prefill_chunk: usize,
    ) -> Result<GenerateOutcome> {
        assert!(n_replicas >= 1, "need at least one replica");
        self.core.live().touch();
        let slice = steps_per_slice.max(1);
        let prefill = if prefill_chunk == 0 { slice } else { prefill_chunk };
        let queue = Arc::new(WorkQueue::new(n_replicas));
        let (etx, erx) = mpsc::channel();
        // etx clone retained for respawns — see serve_replicated
        let mut workers: Vec<Option<JoinHandle<ReplicaMetrics>>> =
            replica::spawn_replicas(Arc::clone(&self.core), Arc::clone(&queue), etx.clone(), n_replicas)
                .into_iter()
                .map(Some)
                .collect();
        let mut dead_metrics: Vec<ReplicaMetrics> = Vec::new();
        let start = Instant::now();
        let tick = Duration::from_micros(200);
        let mut st = GenLeader {
            metrics: GenerateMetrics { replicas: n_replicas, ..Default::default() },
            total_hist: LatencyHistogram::new(),
            ttft_hist: LatencyHistogram::new(),
            queue_wait_hist: LatencyHistogram::new(),
            in_flight: 0,
            first_error: None,
            slice,
            prefill,
            pool: self.core.paged.clone(),
            reservations: HashMap::new(),
            sessions: HashMap::new(),
            pending_respawns: Vec::new(),
            core: Arc::clone(&self.core),
            decode,
        };
        let mut open = true;
        // admission bound: cap live sessions (each owns KV/predictor
        // buffers) and leave the excess buffered in the channel —
        // backpressure, not loss, mirroring the classify leader's
        // max_queue invariant
        let max_active = 8 * n_replicas;
        loop {
            // 1. admit up to the session bound from the channel; every
            //    admitted session becomes a dispatchable decode slice
            //    immediately (work stealing balances the deques)
            if open {
                while st.in_flight < max_active {
                    match requests.try_recv() {
                        Ok(r) => self.admit_generate(r, decode, &queue, &replies, &mut st),
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            // 2. block on whichever side can make progress
            if st.in_flight > 0 {
                match erx.recv_timeout(tick) {
                    Ok(ev) => st.absorb(ev, &replies, &queue, &self.core.live),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        st.first_error = Some(anyhow::anyhow!(
                            "all replicas exited with {} decode slices in flight",
                            st.in_flight
                        ));
                    }
                }
                while let Ok(ev) = erx.try_recv() {
                    st.absorb(ev, &replies, &queue, &self.core.live);
                }
            } else if open {
                match requests.recv_timeout(tick) {
                    Ok(r) => self.admit_generate(r, decode, &queue, &replies, &mut st),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            } else {
                break; // input closed, nothing in flight
            }
            // supervise: respawn every worker slot a Faulted event left
            // dead, so migrated slices have a replica to land on
            let respawned = respawn_workers(
                &self.core,
                &queue,
                &etx,
                &mut workers,
                &mut dead_metrics,
                &mut st.pending_respawns,
            );
            if respawned > 0 {
                st.metrics.respawns += respawned;
                self.core.live().generate.respawns += respawned;
            }
            if st.first_error.is_some() {
                break;
            }
        }
        queue.close();
        let per_replica: Vec<ReplicaMetrics> = workers
            .into_iter()
            .flatten()
            .map(|h| h.join().expect("replica thread panicked"))
            .collect();
        while let Ok(ev) = erx.try_recv() {
            st.absorb(ev, &replies, &queue, &self.core.live);
        }
        let per_replica = merge_replica_metrics(per_replica, dead_metrics);
        // sessions cut short by an error path never completed: hand
        // their reserved blocks back to the admission ledger
        for (_, n) in st.reservations.drain() {
            st.pool.release(n);
        }
        if let Some(err) = st.first_error.take() {
            return Err(err);
        }
        let GenLeader { mut metrics, total_hist, ttft_hist, queue_wait_hist, .. } = st;
        let total = total_hist.snapshot();
        if !total.is_empty() {
            metrics.p50_session = Duration::from_secs_f64(total.quantile(0.50));
            metrics.p99_session = Duration::from_secs_f64(total.quantile(0.99));
        }
        let ttft = ttft_hist.snapshot();
        if !ttft.is_empty() {
            metrics.ttft_p50 = Duration::from_secs_f64(ttft.quantile(0.50));
            metrics.ttft_p99 = Duration::from_secs_f64(ttft.quantile(0.99));
        }
        let qw = queue_wait_hist.snapshot();
        if !qw.is_empty() {
            metrics.queue_wait_p50 = Duration::from_secs_f64(qw.quantile(0.50));
            metrics.queue_wait_p99 = Duration::from_secs_f64(qw.quantile(0.99));
        }
        metrics.wall = start.elapsed();
        metrics.plan_cache = self.core.cache.stats();
        Ok(GenerateOutcome { metrics, per_replica })
    }

    /// Build a session for one generation request and dispatch its
    /// first decode slice. A degenerate request (empty prompt — which,
    /// with a declared prefix, means an empty prompt *tail*) is
    /// rejected with an immediate empty `done` chunk instead of
    /// panicking the leader (`GenSession::new`/`new_paged` assert on
    /// it). Paged sessions additionally reserve their worst-case block
    /// demand in the pool's admission ledger; a session the pool cannot
    /// hold is rejected the same way (`generate_rejected_total`), so
    /// one oversized request can never exhaust the shared pool mid-
    /// decode and take other sessions down with it.
    fn admit_generate(
        &self,
        req: GenRequest,
        decode: DecodeConfig,
        queue: &WorkQueue,
        replies: &mpsc::Sender<GenChunk>,
        st: &mut GenLeader,
    ) {
        if req.prompt.is_empty() {
            let _ = replies.send(GenChunk { id: req.id, tokens: Vec::new(), done: true, fault: None });
            return;
        }
        let mut session = match &req.prefix {
            // a declared prefix routes the session through the shared
            // paged pool: the prompt field is the tail after the prefix
            Some(prefix) if !prefix.is_empty() => {
                let total = prefix.len() + req.prompt.len() + req.max_new;
                let need = self.paged_session_demand(total);
                if !self.core.paged.try_reserve(need) {
                    st.metrics.rejected += 1;
                    self.core.live().generate.rejected += 1;
                    let _ = replies
                        .send(GenChunk { id: req.id, tokens: Vec::new(), done: true, fault: None });
                    return;
                }
                st.reservations.insert(req.id, need);
                GenSession::new_paged(
                    Arc::clone(self.core.engine()),
                    decode,
                    &self.core.paged,
                    prefix,
                    req.prompt.clone(),
                    req.max_new,
                    req.sampling,
                )
            }
            _ => GenSession::new(
                Arc::clone(self.core.engine()),
                decode,
                req.prompt.clone(),
                req.max_new,
                req.sampling,
            ),
        };
        if decode.mode == DecodeMode::Spls {
            session = session.with_plan_cache(self.core.cache.clone());
        }
        // retain what migration needs: a replica fault destroys the
        // session state, so the leader must be able to rebuild it
        st.sessions.insert(
            req.id,
            SessionRecord {
                prompt: req.prompt,
                prefix: req.prefix,
                max_new: req.max_new,
                sampling: req.sampling,
                arrived: req.arrived,
                emitted: Vec::new(),
                attempts: 1,
                queue_wait_seen: false,
            },
        );
        st.metrics.sessions += 1;
        self.core.live().generate.sessions += 1;
        st.in_flight += 1;
        // admission and first dispatch are one step in this lane
        let trace = &self.core.obs().trace;
        trace.event(req.id, Stage::Queued);
        trace.event(req.id, Stage::Dispatched);
        let steps = st.steps_for(&session);
        queue.push_least_loaded(Job::Decode {
            task: Box::new(GenTask { id: req.id, arrived: req.arrived, session }),
            steps,
        });
    }
}

/// The leader's running aggregates over replica completion events.
struct LeaderState {
    metrics: ServeMetrics,
    /// Run-local log2 histograms backing this outcome's percentiles
    /// (total / queue-wait / execute). The shared tier-lifetime copies
    /// on `ServerCore::obs` are fed in the same place.
    total_hist: LatencyHistogram,
    queue_wait_hist: LatencyHistogram,
    execute_hist: LatencyHistogram,
    in_flight: usize,
    first_error: Option<anyhow::Error>,
    /// Replica slots whose worker died on a fault since the last
    /// supervision pass; the leader loop joins + respawns them.
    pending_respawns: Vec<usize>,
    /// Shared server state — the trace hub and lifetime histograms.
    core: Arc<ServerCore>,
}

impl LeaderState {
    /// Fold one replica event in, forwarding replies to the caller and
    /// mirroring the counters into the shared live tier.
    fn absorb(
        &mut self,
        ev: ReplicaEvent,
        out: &mpsc::Sender<Reply>,
        queue: &WorkQueue,
        live: &Mutex<LiveTier>,
    ) {
        self.in_flight = self.in_flight.saturating_sub(1);
        match ev {
            ReplicaEvent::Done { replica, replies, padding, stolen, busy } => {
                self.metrics.batches += 1;
                self.metrics.padded_slots += padding;
                self.metrics.steals += usize::from(stolen);
                live_lock(live).record_batch(replica, &replies, padding, stolen, busy);
                let obs = self.core.obs();
                for reply in replies {
                    self.metrics.requests += 1;
                    self.metrics.total_latency += reply.latency;
                    self.metrics.max_latency = self.metrics.max_latency.max(reply.latency);
                    // only served requests are observed: histogram
                    // counts reconcile with serve_requests_total
                    let execute = reply.latency.saturating_sub(reply.queue_wait);
                    self.total_hist.observe(reply.latency);
                    self.queue_wait_hist.observe(reply.queue_wait);
                    self.execute_hist.observe(execute);
                    obs.classify.total.observe(reply.latency);
                    obs.classify.queue_wait.observe(reply.queue_wait);
                    obs.classify.execute.observe(execute);
                    // classify's first output is the full response
                    obs.classify.ttft.observe(reply.latency);
                    obs.trace.finish(reply.id, Stage::Done);
                    // receiver may have hung up at shutdown; fine
                    let _ = out.send(reply);
                }
            }
            // a worker died executing one batch: queue the slot for
            // respawn, then either requeue the batch (at-most-
            // MAX_JOB_ATTEMPTS) or answer its requests with a typed
            // per-request fault — never a tier error
            ReplicaEvent::Faulted { replica, fault, stolen, busy } => {
                self.metrics.steals += usize::from(stolen);
                self.pending_respawns.push(replica);
                {
                    let mut live = live_lock(live);
                    let r = live.replica_mut(replica);
                    r.steals += usize::from(stolen);
                    r.busy += busy;
                }
                match fault {
                    JobFault::Classify { batch, attempt, message } => {
                        if attempt < MAX_JOB_ATTEMPTS && !queue.is_closed() {
                            self.metrics.retried += 1;
                            live_lock(live).serve.retried += 1;
                            self.in_flight += 1;
                            for r in &batch.requests {
                                self.core.obs().trace.attempt(r.id);
                            }
                            queue.push_least_loaded(Job::Classify {
                                batch,
                                attempt: attempt + 1,
                            });
                        } else {
                            // retry budget spent (or draining): fault
                            // replies are delivered, not counted as
                            // served requests — latency stats stay
                            // honest
                            self.metrics.faulted += 1;
                            live_lock(live).serve.faulted += 1;
                            let now = Instant::now();
                            let obs = self.core.obs();
                            for req in batch.requests {
                                obs.trace.fault(req.id, StreamFault::REPLICA_FAULT);
                                obs.trace.finish(req.id, Stage::Faulted);
                                let _ = out.send(Reply {
                                    id: req.id,
                                    logits: Vec::new(),
                                    latency: now.duration_since(req.arrived),
                                    queue_wait: Duration::ZERO,
                                    fault: Some(StreamFault::replica_fault(message.clone())),
                                });
                            }
                        }
                    }
                    // the classify leader never dispatches decode jobs
                    JobFault::Decode { .. } => {}
                }
            }
            // the classify leader never dispatches decode jobs; absorb
            // defensively so a stray event cannot wedge the loop
            ReplicaEvent::DecodeDone { .. } | ReplicaEvent::DecodeAborted { .. } => {}
            ReplicaEvent::Failed { error, .. } => {
                if self.first_error.is_none() {
                    self.first_error = Some(error);
                }
            }
        }
    }
}

/// Join every worker slot queued for respawn — the dead worker sent its
/// fault event and exited immediately after, so the join is prompt —
/// bank its counters, and spawn a fresh worker under the same replica
/// id (it resumes draining the same deque). Returns the respawn count
/// for the degradation metrics. Always respawns, even mid-drain: with
/// one replica, queued jobs behind the fault would otherwise never run.
fn respawn_workers(
    core: &Arc<ServerCore>,
    queue: &Arc<WorkQueue>,
    etx: &mpsc::Sender<ReplicaEvent>,
    workers: &mut [Option<JoinHandle<ReplicaMetrics>>],
    dead: &mut Vec<ReplicaMetrics>,
    pending: &mut Vec<usize>,
) -> usize {
    let mut n = 0;
    for id in pending.drain(..) {
        if let Some(handle) = workers[id].take() {
            if let Ok(m) = handle.join() {
                dead.push(m);
            }
        }
        workers[id] = Some(replica::spawn_replica(
            Arc::clone(core),
            Arc::clone(queue),
            etx.clone(),
            id,
        ));
        n += 1;
    }
    n
}

/// Fold the counters of workers that died mid-run (and were respawned
/// under the same id) into the final joined rows — outcomes keep the
/// "one row per replica slot" shape whether or not faults occurred.
fn merge_replica_metrics(
    mut per_replica: Vec<ReplicaMetrics>,
    dead: Vec<ReplicaMetrics>,
) -> Vec<ReplicaMetrics> {
    for d in dead {
        if let Some(m) = per_replica.iter_mut().find(|m| m.replica == d.replica) {
            m.batches += d.batches;
            m.requests += d.requests;
            m.decode_slices += d.decode_slices;
            m.tokens += d.tokens;
            m.steals += d.steals;
            m.busy += d.busy;
        } else {
            per_replica.push(d);
        }
    }
    per_replica.sort_by_key(|m| m.replica);
    per_replica
}

/// What the generate leader retains per live session so it can rebuild
/// (migrate) the stream after a replica fault destroys the in-flight
/// session state: re-prefill from prompt + already-emitted tokens,
/// fast-forward the sampler, and keep streaming bit-identically.
struct SessionRecord {
    /// The original prompt (the tail after `prefix` for paged sessions).
    prompt: Vec<i32>,
    /// Declared shared-prefix tokens, when the session is paged.
    prefix: Option<Vec<i32>>,
    max_new: usize,
    sampling: Sampling,
    arrived: Instant,
    /// Tokens already streamed to the client, in order.
    emitted: Vec<i32>,
    /// Dispatch attempts consumed (1 = first dispatch); migration
    /// stops at [`MAX_JOB_ATTEMPTS`].
    attempts: u32,
    /// Whether the session's first slice pickup has already been
    /// observed into the queue-wait histograms (only the first counts;
    /// later slices requeue instantly and would skew the stat).
    queue_wait_seen: bool,
}

/// The generate leader's running state over decode-slice completions.
struct GenLeader {
    metrics: GenerateMetrics,
    /// Run-local log2 histograms backing this outcome's percentiles
    /// (session total / ttft / queue-wait); the shared tier-lifetime
    /// copies on `ServerCore::obs` are fed in the same place.
    total_hist: LatencyHistogram,
    ttft_hist: LatencyHistogram,
    queue_wait_hist: LatencyHistogram,
    in_flight: usize,
    first_error: Option<anyhow::Error>,
    slice: usize,
    /// Steps per dispatch while a session is still prefilling its
    /// prompt (chunked prefill); equals `slice` when not configured.
    prefill: usize,
    /// Shared paged pool handle, for releasing admission reservations.
    pool: PagedPool,
    /// Outstanding admission reservations: blocks reserved per request
    /// id, released when the session finishes or aborts.
    reservations: HashMap<u64, usize>,
    /// Migration records of every live session, keyed by request id.
    sessions: HashMap<u64, SessionRecord>,
    /// Replica slots whose worker died on a fault since the last
    /// supervision pass (see [`LeaderState::pending_respawns`]).
    pending_respawns: Vec<usize>,
    /// Shared server state, for rebuilding migrated sessions.
    core: Arc<ServerCore>,
    decode: DecodeConfig,
}

impl GenLeader {
    /// Dispatch granularity for a session's next slice: prefilling
    /// sessions run in prefill chunks, decoding ones in decode slices.
    fn steps_for(&self, session: &GenSession) -> usize {
        if session.prefilling() {
            self.prefill
        } else {
            self.slice
        }
    }
}

impl GenLeader {
    /// Fold one replica event in: stream the chunk out, requeue the
    /// session if it has steps left, and mirror the counters into the
    /// shared live tier.
    fn absorb(
        &mut self,
        ev: ReplicaEvent,
        out: &mpsc::Sender<GenChunk>,
        queue: &WorkQueue,
        live: &Mutex<LiveTier>,
    ) {
        self.in_flight = self.in_flight.saturating_sub(1);
        match ev {
            ReplicaEvent::DecodeDone { replica, task, fresh, stolen, busy, queue_wait } => {
                self.metrics.slices += 1;
                self.metrics.steals += usize::from(stolen);
                self.metrics.tokens += fresh.len();
                let done = task.session.done();
                let session_latency = done.then(|| task.arrived.elapsed().as_secs_f64());
                live_lock(live).record_decode(replica, fresh.len(), stolen, busy, session_latency);
                let obs = self.core.obs();
                // per-slice execution time; slices are the execute
                // unit of this lane (count = generate_slices_total)
                obs.generate.execute.observe(busy);
                if let Some(rec) = self.sessions.get_mut(&task.id) {
                    if !rec.queue_wait_seen {
                        // admission → first pickup only: later slices
                        // requeue instantly and would skew the stat
                        rec.queue_wait_seen = true;
                        self.queue_wait_hist.observe(queue_wait);
                        obs.generate.queue_wait.observe(queue_wait);
                    }
                    if rec.emitted.is_empty() && !fresh.is_empty() {
                        let ttft = task.arrived.elapsed();
                        self.ttft_hist.observe(ttft);
                        obs.generate.ttft.observe(ttft);
                        obs.trace.event(task.id, Stage::FirstChunk);
                    }
                    // keep the migration record current *before* the
                    // tokens leave: a later fault re-prefills from
                    // exactly what the client has already seen
                    rec.emitted.extend_from_slice(&fresh);
                }
                if done {
                    // observe + finish the span *before* the chunk
                    // leaves (mirroring the classify lane), so a
                    // client that has seen `done` always finds the
                    // completed span on /debug/trace and the session
                    // in the histogram counts
                    self.sessions.remove(&task.id);
                    let total = task.arrived.elapsed();
                    self.total_hist.observe(total);
                    obs.generate.total.observe(total);
                    let (prefill, decode) = task.session.phase_times();
                    obs.trace.phases(task.id, prefill, decode);
                    obs.trace.finish(task.id, Stage::Done);
                }
                // receiver may have hung up at shutdown; fine
                let _ = out.send(GenChunk { id: task.id, tokens: fresh, done, fault: None });
                if done {
                    if let Some(n) = self.reservations.remove(&task.id) {
                        self.pool.release(n);
                    }
                } else {
                    self.in_flight += 1;
                    let steps = self.steps_for(&task.session);
                    queue.push_least_loaded(Job::Decode { task, steps });
                }
            }
            // a recoverable per-session fault (paged pool exhausted mid-
            // slice): the session is gone but the replica and the tier
            // keep serving — close the stream, hand the reservation
            // back, and count the abort
            ReplicaEvent::DecodeAborted { replica, id, stolen, busy, reason: _ } => {
                self.metrics.aborted += 1;
                let obs = self.core.obs();
                obs.trace.fault(id, "decode_aborted");
                obs.trace.finish(id, Stage::Faulted);
                self.sessions.remove(&id);
                if let Some(n) = self.reservations.remove(&id) {
                    self.pool.release(n);
                }
                {
                    let mut live = live_lock(live);
                    live.generate.aborted += 1;
                    let r = live.replica_mut(replica);
                    r.steals += usize::from(stolen);
                    r.busy += busy;
                }
                let _ = out.send(GenChunk { id, tokens: Vec::new(), done: true, fault: None });
            }
            // a worker died mid-slice: queue the slot for respawn, then
            // migrate the session (rebuild from its record, at-most-
            // MAX_JOB_ATTEMPTS) or abort the stream in-band with a
            // typed fault envelope — never a tier error
            ReplicaEvent::Faulted { replica, fault, stolen, busy } => {
                self.metrics.steals += usize::from(stolen);
                self.pending_respawns.push(replica);
                {
                    let mut live = live_lock(live);
                    let r = live.replica_mut(replica);
                    r.steals += usize::from(stolen);
                    r.busy += busy;
                }
                match fault {
                    JobFault::Decode { id, message } => {
                        let terminal = queue.is_closed()
                            || self
                                .sessions
                                .get(&id)
                                .map_or(true, |rec| rec.attempts >= MAX_JOB_ATTEMPTS);
                        if terminal {
                            self.metrics.aborted += 1;
                            self.metrics.faulted += 1;
                            let obs = self.core.obs();
                            obs.trace.fault(id, StreamFault::REPLICA_FAULT);
                            obs.trace.finish(id, Stage::Faulted);
                            self.sessions.remove(&id);
                            if let Some(n) = self.reservations.remove(&id) {
                                self.pool.release(n);
                            }
                            {
                                let mut live = live_lock(live);
                                live.generate.aborted += 1;
                                live.generate.faulted += 1;
                            }
                            let _ = out.send(GenChunk {
                                id,
                                tokens: Vec::new(),
                                done: true,
                                fault: Some(StreamFault::replica_fault(message)),
                            });
                        } else {
                            if let Some(rec) = self.sessions.get_mut(&id) {
                                rec.attempts += 1;
                            }
                            let task = {
                                let rec = self.sessions.get(&id).expect("live record");
                                self.rebuild_session(id, rec)
                            };
                            self.metrics.migrated += 1;
                            live_lock(live).generate.migrated += 1;
                            self.core.obs().trace.migrated(id);
                            self.in_flight += 1;
                            let steps = self.steps_for(&task.session);
                            queue.push_least_loaded(Job::Decode { task, steps });
                        }
                    }
                    // the generate leader never dispatches classify jobs
                    JobFault::Classify { .. } => {}
                }
            }
            ReplicaEvent::Done { .. } => {} // generate never dispatches classify jobs
            ReplicaEvent::Failed { error, .. } => {
                if self.first_error.is_none() {
                    self.first_error = Some(error);
                }
            }
        }
    }

    /// Rebuild a faulted session from its retained record: re-prefill
    /// from the original prompt plus every token already streamed, ask
    /// only for the remaining budget, and fast-forward the sampler past
    /// the draws the emitted tokens consumed — the continuation is
    /// bit-identical to the fault-free stream (tokens the faulted slice
    /// generated but never delivered are re-drawn at the same indices).
    /// Paged sessions re-declare the same prefix (the trie re-attaches
    /// to the shared blocks) and keep their admission reservation:
    /// total token demand is unchanged by migration.
    fn rebuild_session(&self, id: u64, rec: &SessionRecord) -> Box<GenTask> {
        let mut tail = rec.prompt.clone();
        tail.extend_from_slice(&rec.emitted);
        let remaining = rec.max_new.saturating_sub(rec.emitted.len());
        let mut session = match &rec.prefix {
            Some(prefix) if !prefix.is_empty() => GenSession::new_paged(
                Arc::clone(self.core.engine()),
                self.decode,
                &self.core.paged,
                prefix,
                tail,
                remaining,
                rec.sampling,
            ),
            _ => GenSession::new(
                Arc::clone(self.core.engine()),
                self.decode,
                tail,
                remaining,
                rec.sampling,
            ),
        };
        if self.decode.mode == DecodeMode::Spls {
            session = session.with_plan_cache(self.core.cache.clone());
        }
        session.fast_forward_sampling(rec.emitted.len());
        Box::new(GenTask { id, arrived: rec.arrived, session })
    }
}

// ---------------------------------------------------------------------------
// Unified tier submission API
// ---------------------------------------------------------------------------

/// One unit of work submitted to the tier through [`TierHandle`] —
/// classify and generate ride the same admission/dispatch code path
/// (they fan out to the two leader lanes internally, mirroring the
/// replica-level `Job` enum).
#[derive(Clone, Debug)]
pub enum Submission {
    Classify {
        tokens: Vec<i32>,
    },
    Generate {
        /// The prompt — or, with `prefix: Some(p)`, the tail after `p`.
        prompt: Vec<i32>,
        /// Optional shared-prefix handle (paged KV sharing).
        prefix: Option<Vec<i32>>,
        max_new: usize,
        sampling: Sampling,
    },
}

/// One completed (or partially streamed) unit of work, delivered
/// through [`TierHandle::take_completions`] after a notify wakeup.
#[derive(Clone, Debug)]
pub enum Completion {
    /// Final answer of a `Submission::Classify`.
    Classify {
        id: u64,
        logits: Vec<f32>,
        latency: Duration,
    },
    /// A `Submission::Classify` that exhausted its retry budget on
    /// faulted replicas: a typed per-request failure, delivered in the
    /// completion stream like any answer (the tier itself stays up).
    ClassifyFailed {
        id: u64,
        fault: StreamFault,
    },
    /// One streamed slice of a `Submission::Generate`; `done` marks
    /// the last. A stream cut short by an unrecoverable replica fault
    /// carries the typed fault on its final chunk.
    Generate {
        id: u64,
        tokens: Vec<i32>,
        done: bool,
        fault: Option<StreamFault>,
    },
}

/// Why [`TierHandle::submit`] refused a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// A lane's admission bound is full — the same bound the leader
    /// enforces (`BatchPolicy::max_queue` / `max_sessions`), so the
    /// frontend should shed (429 + Retry-After), not queue.
    Saturated,
    /// The tier is draining or stopped (503).
    Closed,
}

/// Knobs for [`Tier::start`].
#[derive(Clone, Copy, Debug)]
pub struct TierConfig {
    pub policy: BatchPolicy,
    pub decode: DecodeConfig,
    pub replicas: usize,
    pub steps_per_slice: usize,
    /// Admission bound of the generate lane (live sessions).
    pub max_sessions: usize,
    /// Steps per dispatch while a session is prefilling its prompt
    /// (chunked prefill); 0 falls back to `steps_per_slice`.
    pub prefill_chunk: usize,
    /// Trace-span sampling: record a span for 1-in-N submissions
    /// (1 = every request, 0 = tracing off). Latency histograms are
    /// never sampled — this knob only bounds span bookkeeping.
    pub trace_sample: u64,
}

/// The submit/complete face of a running tier. Frontends hold this:
/// admission-bounded `submit`, completions drained from one queue, an
/// optional `notify` callback fired on every completion so an event
/// loop can park in `epoll_wait` and be woken (eventfd) instead of
/// blocking a thread per in-flight request.
pub struct TierHandle {
    classify_tx: Mutex<Option<mpsc::Sender<Request>>>,
    generate_tx: Mutex<Option<mpsc::Sender<GenRequest>>>,
    classify_in_flight: AtomicUsize,
    generate_in_flight: AtomicUsize,
    classify_bound: usize,
    generate_bound: usize,
    next_id: AtomicU64,
    completions: Mutex<VecDeque<Completion>>,
    notify: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
    /// The tier's observability state: `submit` mints each job's trace
    /// span here (in-process callers get spans without a gateway).
    obs: Arc<Obs>,
}

impl TierHandle {
    fn new(
        classify_tx: mpsc::Sender<Request>,
        generate_tx: mpsc::Sender<GenRequest>,
        classify_bound: usize,
        generate_bound: usize,
        obs: Arc<Obs>,
    ) -> TierHandle {
        TierHandle {
            classify_tx: Mutex::new(Some(classify_tx)),
            generate_tx: Mutex::new(Some(generate_tx)),
            classify_in_flight: AtomicUsize::new(0),
            generate_in_flight: AtomicUsize::new(0),
            classify_bound,
            generate_bound,
            next_id: AtomicU64::new(0),
            completions: Mutex::new(VecDeque::new()),
            notify: Mutex::new(None),
            obs,
        }
    }

    /// Install the completion wakeup (e.g. an eventfd `Waker::wake`).
    /// Fired after every completion is queued.
    pub fn set_notify(&self, f: impl Fn() + Send + Sync + 'static) {
        *self.notify.lock().unwrap() = Some(Box::new(f));
    }

    /// Submitted-but-uncompleted classify jobs (a reply releases one).
    pub fn classify_in_flight(&self) -> usize {
        self.classify_in_flight.load(Ordering::SeqCst)
    }

    /// Live generate sessions (a `done` chunk releases one).
    pub fn generate_in_flight(&self) -> usize {
        self.generate_in_flight.load(Ordering::SeqCst)
    }

    /// Nothing in flight on either lane (the drain condition).
    pub fn idle(&self) -> bool {
        self.classify_in_flight() == 0 && self.generate_in_flight() == 0
    }

    pub fn classify_bound(&self) -> usize {
        self.classify_bound
    }

    pub fn generate_bound(&self) -> usize {
        self.generate_bound
    }

    fn try_admit(counter: &AtomicUsize, n: usize, bound: usize) -> bool {
        counter
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
                if cur + n <= bound {
                    Some(cur + n)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Admit and dispatch a batch atomically: per-lane all-or-nothing
    /// admission at the leaders' real bounds, then every item sent
    /// while holding the lane senders (a concurrent [`close`] cannot
    /// interleave mid-batch). Returns the job ids, in submission
    /// order; completions carry them back.
    ///
    /// [`close`]: TierHandle::close
    pub fn submit(&self, batch: Vec<Submission>) -> Result<Vec<u64>, SubmitError> {
        let k_classify = batch
            .iter()
            .filter(|s| matches!(s, Submission::Classify { .. }))
            .count();
        let k_generate = batch.len() - k_classify;
        if k_classify > 0
            && !Self::try_admit(&self.classify_in_flight, k_classify, self.classify_bound)
        {
            return Err(SubmitError::Saturated);
        }
        if k_generate > 0
            && !Self::try_admit(&self.generate_in_flight, k_generate, self.generate_bound)
        {
            if k_classify > 0 {
                self.classify_in_flight.fetch_sub(k_classify, Ordering::SeqCst);
            }
            return Err(SubmitError::Saturated);
        }

        let ids: Vec<u64> = (0..batch.len())
            .map(|_| self.next_id.fetch_add(1, Ordering::SeqCst))
            .collect();
        let arrived = Instant::now();
        let ctx = self.classify_tx.lock().unwrap();
        let gtx = self.generate_tx.lock().unwrap();
        let (mut sent_classify, mut sent_generate) = (0usize, 0usize);
        let mut ok = true;
        for (sub, id) in batch.into_iter().zip(&ids) {
            // mint the job's trace span at admission (the gateway
            // backdates accepted/parsed onto it afterwards; in-process
            // callers get spans that start here)
            let lane = match sub {
                Submission::Classify { .. } => Lane::Classify,
                Submission::Generate { .. } => Lane::Generate,
            };
            self.obs.trace.begin(*id, lane, Stage::Admitted);
            match sub {
                Submission::Classify { tokens } => {
                    ok = ctx
                        .as_ref()
                        .map(|tx| tx.send(Request { id: *id, tokens, arrived }).is_ok())
                        .unwrap_or(false);
                    sent_classify += ok as usize;
                }
                Submission::Generate { prompt, prefix, max_new, sampling } => {
                    ok = gtx
                        .as_ref()
                        .map(|tx| {
                            tx.send(GenRequest {
                                id: *id,
                                prompt,
                                prefix,
                                max_new,
                                sampling,
                                arrived,
                            })
                            .is_ok()
                        })
                        .unwrap_or(false);
                    sent_generate += ok as usize;
                }
            }
            if !ok {
                break;
            }
        }
        drop(gtx);
        drop(ctx);
        if !ok {
            // lanes closed under us: hand back the admission the
            // unsent items took; anything already sent releases
            // through its completion as usual
            if k_classify > sent_classify {
                self.classify_in_flight
                    .fetch_sub(k_classify - sent_classify, Ordering::SeqCst);
            }
            if k_generate > sent_generate {
                self.generate_in_flight
                    .fetch_sub(k_generate - sent_generate, Ordering::SeqCst);
            }
            return Err(SubmitError::Closed);
        }
        Ok(ids)
    }

    /// Drain queued completions into `out` (appends; does not block).
    pub fn take_completions(&self, out: &mut Vec<Completion>) {
        let mut q = self.completions.lock().unwrap();
        out.extend(q.drain(..));
    }

    /// Close both lanes: the leaders see end-of-input (and exit once
    /// their queues drain), and every later `submit` answers
    /// [`SubmitError::Closed`]. Idempotent.
    pub fn close(&self) {
        self.classify_tx.lock().unwrap().take();
        self.generate_tx.lock().unwrap().take();
    }

    fn push(&self, c: Completion) {
        self.completions.lock().unwrap().push_back(c);
        if let Some(f) = self.notify.lock().unwrap().as_ref() {
            f();
        }
    }
}

/// A running serving tier: both leader lanes (classify via
/// [`Server::serve_replicated`], generate via
/// [`Server::serve_generate`]) plus the completion pumps that feed the
/// shared [`TierHandle`] queue and release admission.
pub struct Tier {
    handle: Arc<TierHandle>,
    classify_leader: thread::JoinHandle<Result<ServeOutcome>>,
    generate_leader: thread::JoinHandle<Result<GenerateOutcome>>,
    pumps: Vec<thread::JoinHandle<()>>,
}

impl Tier {
    pub fn start(server: Arc<Server>, cfg: TierConfig) -> Result<Tier> {
        let replicas = cfg.replicas.max(1);
        let (creq_tx, creq_rx) = mpsc::channel();
        let (crep_tx, crep_rx) = mpsc::channel::<Reply>();
        let (greq_tx, greq_rx) = mpsc::channel();
        let (gchk_tx, gchk_rx) = mpsc::channel::<GenChunk>();
        server.obs().trace.set_sample_every(cfg.trace_sample);
        let handle = Arc::new(TierHandle::new(
            creq_tx,
            greq_tx,
            cfg.policy.max_queue,
            cfg.max_sessions,
            Arc::clone(&server.core.obs),
        ));

        let srv = Arc::clone(&server);
        let policy = cfg.policy;
        let classify_leader = thread::Builder::new()
            .name("esact-tier-classify".into())
            .spawn(move || srv.serve_replicated(creq_rx, crep_tx, policy, replicas))?;

        let srv = Arc::clone(&server);
        let (decode, steps, prefill) = (cfg.decode, cfg.steps_per_slice, cfg.prefill_chunk);
        let generate_leader = thread::Builder::new()
            .name("esact-tier-generate".into())
            .spawn(move || {
                srv.serve_generate_chunked(greq_rx, gchk_tx, decode, replicas, steps, prefill)
            })?;

        let h = Arc::clone(&handle);
        let classify_pump = thread::Builder::new()
            .name("esact-tier-cpump".into())
            .spawn(move || {
                for reply in crep_rx.iter() {
                    h.classify_in_flight.fetch_sub(1, Ordering::SeqCst);
                    h.push(match reply.fault {
                        Some(fault) => Completion::ClassifyFailed { id: reply.id, fault },
                        None => Completion::Classify {
                            id: reply.id,
                            logits: reply.logits,
                            latency: reply.latency,
                        },
                    });
                }
            })?;
        let h = Arc::clone(&handle);
        let generate_pump = thread::Builder::new()
            .name("esact-tier-gpump".into())
            .spawn(move || {
                for chunk in gchk_rx.iter() {
                    if chunk.done {
                        h.generate_in_flight.fetch_sub(1, Ordering::SeqCst);
                    }
                    h.push(Completion::Generate {
                        id: chunk.id,
                        tokens: chunk.tokens,
                        done: chunk.done,
                        fault: chunk.fault,
                    });
                }
            })?;

        Ok(Tier {
            handle,
            classify_leader,
            generate_leader,
            pumps: vec![classify_pump, generate_pump],
        })
    }

    pub fn handle(&self) -> Arc<TierHandle> {
        Arc::clone(&self.handle)
    }

    /// Join leaders and pumps. Call after [`TierHandle::close`] —
    /// otherwise the leaders never see end-of-input. Returns both
    /// outcomes (metrics + first replica error, if any).
    pub fn join(self) -> (Result<ServeOutcome>, Result<GenerateOutcome>) {
        let classify = self
            .classify_leader
            .join()
            .unwrap_or_else(|_| Err(anyhow::anyhow!("classify leader panicked")));
        let generate = self
            .generate_leader
            .join()
            .unwrap_or_else(|_| Err(anyhow::anyhow!("generate leader panicked")));
        for p in self.pumps {
            let _ = p.join();
        }
        (classify, generate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::plan_model;
    use crate::util::rng::Xoshiro256pp;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn gen_requests(n: usize) -> Vec<Request> {
        let mut rng = Xoshiro256pp::new(42);
        (0..n)
            .map(|i| {
                let (toks, _) = crate::model::synth::gen_example(&mut rng, 64);
                Request { id: i as u64, tokens: toks, arrived: Instant::now() }
            })
            .collect()
    }

    type Wired = (mpsc::Receiver<Request>, mpsc::Sender<Reply>, mpsc::Receiver<Reply>);

    fn preloaded(reqs: Vec<Request>) -> Wired {
        let (tx, rx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        for r in reqs {
            tx.send(r).unwrap();
        }
        drop(tx);
        (rx, rtx, rrx)
    }

    #[test]
    fn dense_server_end_to_end() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let (rx, rtx, rrx) = preloaded(gen_requests(20));
        let metrics = srv.serve(rx, rtx, BatchPolicy::default()).unwrap();
        assert_eq!(metrics.requests, 20);
        assert_eq!(metrics.replicas, 1);
        assert_eq!(metrics.shed, 0);
        let replies: Vec<Reply> = rrx.iter().collect();
        assert_eq!(replies.len(), 20);
        assert!(replies.iter().all(|r| r.logits.len() == 16));
        assert!(metrics.throughput_rps() > 0.0);
        assert!(metrics.p50_latency <= metrics.p99_latency);
        // p99 interpolates over f64 samples; allow 1 µs of rounding
        assert!(metrics.p99_latency <= metrics.max_latency + Duration::from_micros(1));
    }

    #[test]
    fn spls_server_agrees_with_dense_mostly() {
        // 24 requests in three compiled-size batches; the SPLS-masked
        // path flips the argmax only on near-ties, so ≥ 2/3 agreement is
        // a robust bar (measured: 18/24 on this seed, all agreeing
        // sequences with comfortable logit margins)
        let dense = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let spls = Server::new(&artifacts_dir(), Mode::Spls, SplsConfig::default()).unwrap();
        let reqs = gen_requests(24);
        let mut agree = 0usize;
        for chunk in reqs.chunks(8) {
            let d = dense.execute(chunk, 0).unwrap();
            let s = spls.execute(chunk, 0).unwrap();
            agree += d
                .iter()
                .zip(&s)
                .filter(|(a, b)| {
                    crate::model::tensor::argmax(&a.logits)
                        == crate::model::tensor::argmax(&b.logits)
                })
                .count();
        }
        assert!(agree >= 16, "only {agree}/24 classifications agree");
    }

    #[test]
    fn padding_replies_only_for_real_requests() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let reqs = gen_requests(3);
        let out = srv.execute(&reqs, 5).unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn batch_of_one_padded_into_eight_slot_artifact_discards_padding() {
        // force a single request through the 8-slot artifact: the 7
        // padded slots replay request 0 and must be discarded, and the
        // surviving reply must be bit-identical to the batch-1 run
        // (the reference backend computes each slot independently)
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let reqs = gen_requests(1);
        let padded = srv.execute(&reqs, 7).unwrap();
        assert_eq!(padded.len(), 1, "one reply for one real request");
        let solo = srv.execute(&reqs, 0).unwrap();
        assert_eq!(padded[0].logits, solo[0].logits, "padding must not perturb slot 0");
        assert_eq!(padded[0].id, reqs[0].id);
    }

    #[test]
    fn plan_cache_hits_are_bit_identical_to_fresh_plans() {
        let spls = SplsConfig::default();
        let w = TinyWeights::load(&artifacts_dir().join("tiny_weights.bin")).unwrap();
        let toks = gen_requests(1).remove(0).tokens;
        let fresh = plan_model(&w, &toks, &spls, QuantMethod::Hlog);

        let cache = SharedPlanCache::new(64);
        let first = cache.get_or_compute(&toks, &spls, QuantMethod::Hlog, w.cfg.n_layers, || {
            plan_model(&w, &toks, &spls, QuantMethod::Hlog)
        });
        let second = cache.get_or_compute(&toks, &spls, QuantMethod::Hlog, w.cfg.n_layers, || {
            panic!("second lookup must be a cache hit")
        });
        assert_eq!(first, fresh, "first (computed) plans equal offline planning");
        assert_eq!(second, fresh, "cached plans bit-identical to fresh ones");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn spls_serve_populates_plan_cache_and_replays_hit() {
        // two serve waves over the same 4 sequences: wave 1 populates
        // the cache (misses), wave 2 must be served from it (hits) with
        // identical logits — cached plans are bit-identical
        let srv = Server::new(&artifacts_dir(), Mode::Spls, SplsConfig::default()).unwrap();
        let reqs = gen_requests(4);
        let (rx, rtx, rrx) = preloaded(reqs.clone());
        let first = srv.serve(rx, rtx, BatchPolicy::default()).unwrap();
        assert_eq!(first.requests, 4);
        assert!(first.plan_cache.misses >= 4, "cold cache: {:?}", first.plan_cache);
        let mut wave1: Vec<Reply> = rrx.iter().collect();
        wave1.sort_by_key(|r| r.id);

        let (rx, rtx, rrx) = preloaded(reqs);
        let second = srv.serve(rx, rtx, BatchPolicy::default()).unwrap();
        assert!(
            second.plan_cache.hits >= 4,
            "repeated shapes must hit: {:?}",
            second.plan_cache
        );
        let mut wave2: Vec<Reply> = rrx.iter().collect();
        wave2.sort_by_key(|r| r.id);
        for (a, b) in wave1.iter().zip(&wave2) {
            assert_eq!(a.logits, b.logits, "cache hit changed served logits");
        }
    }

    #[test]
    fn replicated_serve_is_complete_and_correct() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let reqs = gen_requests(24);
        // single-replica reference results, via the inline executor
        let mut want: Vec<Vec<f32>> = Vec::new();
        for chunk in reqs.chunks(8) {
            want.extend(srv.execute(chunk, 0).unwrap().into_iter().map(|r| r.logits));
        }
        let (rx, rtx, rrx) = preloaded(reqs);
        let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), 3).unwrap();
        assert_eq!(outcome.metrics.requests, 24);
        assert_eq!(outcome.metrics.replicas, 3);
        assert_eq!(outcome.per_replica.len(), 3);
        let executed: usize = outcome.per_replica.iter().map(|m| m.requests).sum();
        assert_eq!(executed, 24, "every request executed exactly once");
        let mut replies: Vec<Reply> = rrx.iter().collect();
        assert_eq!(replies.len(), 24);
        replies.sort_by_key(|r| r.id);
        for (reply, want) in replies.iter().zip(&want) {
            assert_eq!(&reply.logits, want, "replication must not change results");
        }
    }

    #[test]
    fn live_snapshot_mirrors_outcome_and_display_is_row_exact() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let (rx, rtx, rrx) = preloaded(gen_requests(12));
        let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), 2).unwrap();
        assert_eq!(rrx.iter().count(), 12);
        // the live tier (scraped by the gateway's /metrics mid-run)
        // must agree with the joined end-of-run outcome
        let snap = srv.live_snapshot();
        assert_eq!(snap.serve.requests, outcome.metrics.requests);
        assert_eq!(snap.serve.batches, outcome.metrics.batches);
        assert_eq!(snap.serve.steals, outcome.metrics.steals);
        assert_eq!(snap.per_replica.len(), 2);
        let executed: usize = snap.per_replica.iter().map(|r| r.requests).sum();
        assert_eq!(executed, 12, "live per-replica counters must cover every request");
        let busy: Duration = snap.per_replica.iter().map(|r| r.busy).sum();
        assert!(busy > Duration::ZERO, "event plumbing must carry busy time");
        assert!(snap.uptime > Duration::ZERO);
        // Display is row-exact: every /metrics row appears verbatim
        let shown = outcome.to_string();
        for row in outcome.metrics.rows().iter().chain(replica_rows(&outcome.per_replica).iter())
        {
            assert!(shown.contains(&row.to_string()), "Display missing row {row}");
        }
        // a full snapshot never repeats a (name, replica) pair — the
        // Prometheus exposition invariant
        let rows = snap.rows();
        let mut keys: Vec<(&str, Option<(&str, usize)>)> =
            rows.iter().map(|r| (r.name, r.label)).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate metric rows in snapshot");
    }

    #[test]
    fn live_snapshot_tracks_generate_tier_too() {
        use crate::decode::{DecodeConfig, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let (tx, rx) = mpsc::channel();
        let (ctx, crx) = mpsc::channel();
        for (i, p) in gen_prompts(3, 12).into_iter().enumerate() {
            tx.send(GenRequest {
                id: i as u64,
                prompt: p,
                prefix: None,
                max_new: 6,
                sampling: Sampling::Greedy,
                arrived: Instant::now(),
            })
            .unwrap();
        }
        drop(tx);
        let drain = std::thread::spawn(move || crx.iter().count());
        let outcome = srv.serve_generate(rx, ctx, DecodeConfig::default(), 2, 2).unwrap();
        drain.join().unwrap();
        let snap = srv.live_snapshot();
        assert_eq!(snap.generate.sessions, outcome.metrics.sessions);
        assert_eq!(snap.generate.tokens, outcome.metrics.tokens);
        assert_eq!(snap.generate.slices, outcome.metrics.slices);
        let tokens: usize = snap.per_replica.iter().map(|r| r.tokens).sum();
        assert_eq!(tokens, 3 * 6);
        let shown = outcome.to_string();
        for row in outcome.metrics.rows() {
            assert!(shown.contains(&row.to_string()), "Display missing row {row}");
        }
    }

    #[test]
    fn tiny_max_queue_backpressures_without_loss() {
        // 32 requests burst into a 4-deep admission queue: the leader
        // must stop pulling (excess stays buffered in the channel) and
        // still serve every request — backpressure, not loss
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let policy = BatchPolicy { max_queue: 4, ..Default::default() };
        let (rx, rtx, rrx) = preloaded(gen_requests(32));
        let metrics = srv.serve(rx, rtx, policy).unwrap();
        assert_eq!(metrics.requests, 32, "no request may be dropped: {metrics:?}");
        assert_eq!(metrics.shed, 0);
        assert_eq!(rrx.iter().count(), 32);
    }

    fn gen_prompts(n: usize, l: usize) -> Vec<Vec<i32>> {
        let mut rng = Xoshiro256pp::new(77);
        (0..n).map(|_| crate::model::synth::gen_example(&mut rng, l).0).collect()
    }

    #[test]
    fn serve_generate_streams_every_session_to_completion() {
        use crate::decode::{generate, DecodeConfig, DecodeEngine, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let prompts = gen_prompts(5, 16);
        let max_new = 12usize;
        // offline reference: each session decoded alone (sessions are
        // independent, so replication must not change any stream)
        let w = TinyWeights::load(&artifacts_dir().join("tiny_weights.bin")).unwrap();
        let eng = std::sync::Arc::new(DecodeEngine::new(std::sync::Arc::new(w)));
        let want: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                generate(&eng, DecodeConfig::default(), p, max_new, Sampling::Greedy, |_, _| {})
                    .tokens
            })
            .collect();

        let (tx, rx) = mpsc::channel();
        let (ctx, crx) = mpsc::channel();
        for (i, p) in prompts.iter().enumerate() {
            tx.send(GenRequest {
                id: i as u64,
                prompt: p.clone(),
                prefix: None,
                max_new,
                sampling: Sampling::Greedy,
                arrived: Instant::now(),
            })
            .unwrap();
        }
        drop(tx);
        let drain = std::thread::spawn(move || {
            let mut streams: Vec<Vec<i32>> = vec![Vec::new(); 5];
            let mut done = vec![false; 5];
            for chunk in crx.iter() {
                streams[chunk.id as usize].extend(&chunk.tokens);
                if chunk.done {
                    done[chunk.id as usize] = true;
                }
            }
            (streams, done)
        });
        let outcome = srv
            .serve_generate(rx, ctx, DecodeConfig::default(), 2, 4)
            .unwrap();
        let (streams, done) = drain.join().unwrap();
        assert!(done.iter().all(|&d| d), "every session must report done");
        for (got, want) in streams.iter().zip(&want) {
            assert_eq!(got, want, "replicated decode changed a stream");
        }
        let m = outcome.metrics;
        assert_eq!(m.sessions, 5);
        assert_eq!(m.tokens, 5 * max_new);
        assert_eq!(m.replicas, 2);
        assert!(m.slices >= 5, "sessions must be sliced, not run whole");
        assert!(m.tokens_per_sec() > 0.0);
        let executed: usize = outcome.per_replica.iter().map(|r| r.tokens).sum();
        assert_eq!(executed, 5 * max_new);
    }

    #[test]
    fn serve_generate_rejects_empty_prompt_without_panicking() {
        use crate::decode::{DecodeConfig, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let good = gen_prompts(1, 12).remove(0);
        let (tx, rx) = mpsc::channel();
        let (ctx, crx) = mpsc::channel();
        for (id, prompt) in [(0u64, Vec::new()), (1u64, good)] {
            tx.send(GenRequest {
                id,
                prompt,
                prefix: None,
                max_new: 4,
                sampling: Sampling::Greedy,
                arrived: Instant::now(),
            })
            .unwrap();
        }
        drop(tx);
        let drain = std::thread::spawn(move || {
            let mut per_id: std::collections::HashMap<u64, (usize, bool)> = Default::default();
            for c in crx.iter() {
                let e = per_id.entry(c.id).or_default();
                e.0 += c.tokens.len();
                e.1 |= c.done;
            }
            per_id
        });
        let outcome = srv.serve_generate(rx, ctx, DecodeConfig::default(), 1, 4).unwrap();
        let per_id = drain.join().unwrap();
        assert_eq!(per_id[&0], (0, true), "empty prompt → immediate empty done chunk");
        assert_eq!(per_id[&1], (4, true), "valid session unaffected");
        assert_eq!(outcome.metrics.sessions, 1, "rejected request is not a session");
        assert_eq!(outcome.metrics.tokens, 4);
    }

    #[test]
    fn serve_generate_spls_sessions_share_the_step_plan_cache() {
        use crate::decode::{DecodeConfig, DecodeMode, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Spls, SplsConfig::default()).unwrap();
        let prompt = gen_prompts(1, 16).remove(0);
        let decode = DecodeConfig {
            mode: DecodeMode::Spls,
            kv_budget: 16,
            recent: 4,
            spls: SplsConfig::default(),
        };
        let run = |ids: std::ops::Range<u64>| {
            let (tx, rx) = mpsc::channel();
            let (ctx, crx) = mpsc::channel();
            for id in ids {
                tx.send(GenRequest {
                    id,
                    prompt: prompt.clone(),
                    prefix: None,
                    max_new: 8,
                    sampling: Sampling::Greedy,
                    arrived: Instant::now(),
                })
                .unwrap();
            }
            drop(tx);
            let drain = std::thread::spawn(move || {
                let mut per_id: std::collections::HashMap<u64, Vec<i32>> = Default::default();
                for chunk in crx.iter() {
                    per_id.entry(chunk.id).or_default().extend(&chunk.tokens);
                }
                per_id
            });
            let out = srv.serve_generate(rx, ctx, decode, 2, 4).unwrap();
            (out, drain.join().unwrap())
        };
        let (first, streams1) = run(0..1);
        assert!(first.metrics.plan_cache.step_misses > 0, "cold run computes step plans");
        let (second, streams2) = run(1..3);
        assert!(
            second.metrics.plan_cache.step_hits > first.metrics.plan_cache.step_hits,
            "replayed prefixes must hit the step cache: {:?}",
            second.metrics.plan_cache
        );
        // identical prompt + greedy sampling → identical streams, with
        // or without cache hits
        let a = &streams1[&0];
        assert_eq!(a, &streams2[&1]);
        assert_eq!(a, &streams2[&2]);
    }

    #[test]
    fn serve_generate_shared_prefix_attaches_and_streams_identically() {
        use crate::decode::{generate, DecodeConfig, DecodeEngine, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let prompt = gen_prompts(1, 16).remove(0);
        let (prefix, tail) = prompt.split_at(12);
        let max_new = 8usize;
        // offline reference: the same prompt decoded privately
        let w = TinyWeights::load(&artifacts_dir().join("tiny_weights.bin")).unwrap();
        let eng = std::sync::Arc::new(DecodeEngine::new(std::sync::Arc::new(w)));
        let want =
            generate(&eng, DecodeConfig::default(), &prompt, max_new, Sampling::Greedy, |_, _| {})
                .tokens;

        let run = |ids: std::ops::Range<u64>| {
            let (tx, rx) = mpsc::channel();
            let (ctx, crx) = mpsc::channel();
            for id in ids {
                tx.send(GenRequest {
                    id,
                    prompt: tail.to_vec(),
                    prefix: Some(prefix.to_vec()),
                    max_new,
                    sampling: Sampling::Greedy,
                    arrived: Instant::now(),
                })
                .unwrap();
            }
            drop(tx);
            let drain = std::thread::spawn(move || {
                let mut per_id: std::collections::HashMap<u64, Vec<i32>> = Default::default();
                for c in crx.iter() {
                    per_id.entry(c.id).or_default().extend(&c.tokens);
                }
                per_id
            });
            srv.serve_generate(rx, ctx, DecodeConfig::default(), 1, 4).unwrap();
            drain.join().unwrap()
        };
        // wave 1: cold pool — the session prefills the prefix and
        // publishes it to the trie
        let wave1 = run(0..1);
        assert_eq!(wave1[&0], want, "paged session must match the private stream");
        let cold = srv.paged_stats();
        assert_eq!(cold.prefix_hits, 0);
        assert!(cold.prefix_misses >= 1);
        // wave 2: both sessions attach to the published prefix and skip
        // its forward passes, still producing identical streams
        let wave2 = run(1..3);
        assert_eq!(wave2[&1], want);
        assert_eq!(wave2[&2], want);
        let warm = srv.paged_stats();
        assert_eq!(warm.prefix_hits, 2, "replayed prefixes must attach: {warm:?}");
        assert!(
            warm.shared_attach_tokens >= 2 * prefix.len(),
            "attaching skips prefix tokens: {warm:?}"
        );
    }

    #[test]
    fn serve_generate_rejects_empty_tail_with_prefix_without_panicking() {
        use crate::decode::{DecodeConfig, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let prompt = gen_prompts(1, 12).remove(0);
        let (tx, rx) = mpsc::channel();
        let (ctx, crx) = mpsc::channel();
        // a declared prefix with an empty tail would trip
        // `GenSession::new_paged`'s non-empty-tail assert on the leader
        // thread (outside any catch_unwind); admission must answer it
        // with an empty done chunk like the plain empty-prompt case
        tx.send(GenRequest {
            id: 0,
            prompt: Vec::new(),
            prefix: Some(prompt[..8].to_vec()),
            max_new: 4,
            sampling: Sampling::Greedy,
            arrived: Instant::now(),
        })
        .unwrap();
        tx.send(GenRequest {
            id: 1,
            prompt: prompt.clone(),
            prefix: None,
            max_new: 4,
            sampling: Sampling::Greedy,
            arrived: Instant::now(),
        })
        .unwrap();
        drop(tx);
        let drain = std::thread::spawn(move || {
            let mut per_id: std::collections::HashMap<u64, (usize, bool)> = Default::default();
            for c in crx.iter() {
                let e = per_id.entry(c.id).or_default();
                e.0 += c.tokens.len();
                e.1 |= c.done;
            }
            per_id
        });
        let outcome = srv.serve_generate(rx, ctx, DecodeConfig::default(), 1, 4).unwrap();
        let per_id = drain.join().unwrap();
        assert_eq!(per_id[&0], (0, true), "empty tail → immediate empty done chunk");
        assert_eq!(per_id[&1], (4, true), "valid session unaffected");
        assert_eq!(outcome.metrics.sessions, 1, "rejected request is not a session");
    }

    #[test]
    fn paged_admission_rejects_sessions_the_pool_cannot_hold() {
        use crate::decode::{DecodeConfig, Sampling};
        // a 16-block pool on the 2-layer × 4-head tiny model: a
        // session's worst-case demand is 8·(⌈total/8⌉+1) blocks, so
        // only sessions totalling ≤ 8 tokens fit
        let srv =
            Server::with_pool_blocks(&artifacts_dir(), Mode::Dense, SplsConfig::default(), 16)
                .unwrap();
        assert!(srv.paged_session_demand(24) > 16, "oversized demand exceeds the pool");
        assert!(srv.paged_session_demand(8) <= 16, "small demand fits the pool");
        let prompt = gen_prompts(1, 16).remove(0);
        let (tx, rx) = mpsc::channel();
        let (ctx, crx) = mpsc::channel();
        // id 0: 12-token prefix + 4 tail + 8 new = 24 total → refused
        tx.send(GenRequest {
            id: 0,
            prompt: prompt[12..16].to_vec(),
            prefix: Some(prompt[..12].to_vec()),
            max_new: 8,
            sampling: Sampling::Greedy,
            arrived: Instant::now(),
        })
        .unwrap();
        // id 1: 4-token prefix + 2 tail + 2 new = 8 total → admitted
        tx.send(GenRequest {
            id: 1,
            prompt: prompt[4..6].to_vec(),
            prefix: Some(prompt[..4].to_vec()),
            max_new: 2,
            sampling: Sampling::Greedy,
            arrived: Instant::now(),
        })
        .unwrap();
        drop(tx);
        let drain = std::thread::spawn(move || {
            let mut per_id: std::collections::HashMap<u64, (usize, bool)> = Default::default();
            for c in crx.iter() {
                let e = per_id.entry(c.id).or_default();
                e.0 += c.tokens.len();
                e.1 |= c.done;
            }
            per_id
        });
        let outcome = srv.serve_generate(rx, ctx, DecodeConfig::default(), 1, 4).unwrap();
        let per_id = drain.join().unwrap();
        assert_eq!(per_id[&0], (0, true), "oversized paged session is refused, not served");
        assert_eq!(per_id[&1], (2, true), "a session the pool can hold is admitted");
        assert_eq!(outcome.metrics.rejected, 1, "refusal is counted");
        assert_eq!(outcome.metrics.sessions, 1, "refused request is not a session");
        let stats = srv.paged_stats();
        assert_eq!(stats.reserved, 0, "reservations return to the ledger: {stats:?}");
    }

    #[test]
    fn pool_exhaustion_aborts_the_session_and_the_replica_survives() {
        use crate::decode::{PagedPool, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let prompt = gen_prompts(1, 12).remove(0);
        // a one-block private pool can't even hold the first prefill
        // step across the model's 8 layer-head slots, so PoolExhausted
        // unwinds inside run_steps on the replica thread
        let tiny = PagedPool::new(PAGED_BLOCK_SIZE, 1, srv.core.weights.cfg.d_head());
        let doomed = GenSession::new_paged(
            Arc::clone(srv.core.engine()),
            DecodeConfig::default(),
            &tiny,
            &prompt[..4],
            prompt[4..8].to_vec(),
            2,
            Sampling::Greedy,
        );
        let healthy = GenSession::new(
            Arc::clone(srv.core.engine()),
            DecodeConfig::default(),
            prompt.clone(),
            3,
            Sampling::Greedy,
        );
        let queue = Arc::new(WorkQueue::new(1));
        let (etx, erx) = mpsc::channel();
        let handles = replica::spawn_replicas(Arc::clone(&srv.core), Arc::clone(&queue), etx, 1);
        let job = |id, session, steps| Job::Decode {
            task: Box::new(GenTask { id, arrived: Instant::now(), session }),
            steps,
        };
        queue.push_to(0, job(7, doomed, 4));
        // large slice so the healthy session finishes in one dispatch
        // (no leader is running to requeue it)
        queue.push_to(0, job(8, healthy, 64));
        queue.close();
        let (mut aborted, mut served) = (false, false);
        for ev in erx.iter() {
            match ev {
                ReplicaEvent::DecodeAborted { id, reason, .. } => {
                    assert_eq!(id, 7);
                    assert!(reason.contains("paged KV pool exhausted"), "{reason}");
                    aborted = true;
                }
                ReplicaEvent::DecodeDone { task, .. } => {
                    assert_eq!(task.id, 8);
                    assert!(aborted, "the abort precedes the healthy session's slice");
                    assert!(task.session.done());
                    served = true;
                }
                ReplicaEvent::Failed { error, .. } => {
                    panic!("exhaustion must abort the session, not the replica: {error}")
                }
                _ => {}
            }
        }
        assert!(aborted && served);
        for h in handles {
            h.join().expect("replica thread survives the session abort");
        }
    }

    #[test]
    fn generate_leader_releases_reservations_on_abort() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let pool = srv.core.paged.clone();
        let need = srv.paged_session_demand(16);
        assert!(pool.try_reserve(need));
        let mut st = GenLeader {
            metrics: GenerateMetrics::default(),
            total_hist: LatencyHistogram::new(),
            ttft_hist: LatencyHistogram::new(),
            queue_wait_hist: LatencyHistogram::new(),
            in_flight: 1,
            first_error: None,
            slice: 4,
            prefill: 4,
            pool: pool.clone(),
            reservations: std::iter::once((3u64, need)).collect(),
            sessions: HashMap::new(),
            pending_respawns: Vec::new(),
            core: Arc::clone(&srv.core),
            decode: DecodeConfig::default(),
        };
        let (otx, orx) = mpsc::channel();
        let queue = WorkQueue::new(1);
        let live = Mutex::new(LiveTier::default());
        st.absorb(
            ReplicaEvent::DecodeAborted {
                replica: 0,
                id: 3,
                stolen: false,
                busy: Duration::from_millis(1),
                reason: "paged KV pool exhausted".into(),
            },
            &otx,
            &queue,
            &live,
        );
        assert_eq!(st.metrics.aborted, 1);
        assert!(st.first_error.is_none(), "an abort is not a tier error");
        assert!(st.reservations.is_empty());
        assert_eq!(pool.stats().reserved, 0, "the reservation returns to the ledger");
        let chunk = orx.try_recv().unwrap();
        assert_eq!((chunk.id, chunk.done, chunk.tokens.len()), (3, true, 0));
        assert_eq!(live.lock().unwrap().generate.aborted, 1, "mirrored into the live tier");
    }

    #[test]
    fn chunked_prefill_preserves_streams_and_raises_slice_count() {
        use crate::decode::{generate, DecodeConfig, DecodeEngine, Sampling};
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let prompt = gen_prompts(1, 24).remove(0);
        let max_new = 6usize;
        let w = TinyWeights::load(&artifacts_dir().join("tiny_weights.bin")).unwrap();
        let eng = std::sync::Arc::new(DecodeEngine::new(std::sync::Arc::new(w)));
        let want =
            generate(&eng, DecodeConfig::default(), &prompt, max_new, Sampling::Greedy, |_, _| {})
                .tokens;
        let run = |prefill_chunk: usize| {
            let (tx, rx) = mpsc::channel();
            let (ctx, crx) = mpsc::channel();
            tx.send(GenRequest {
                id: 0,
                prompt: prompt.clone(),
                prefix: None,
                max_new,
                sampling: Sampling::Greedy,
                arrived: Instant::now(),
            })
            .unwrap();
            drop(tx);
            let drain = std::thread::spawn(move || {
                let mut toks = Vec::new();
                for c in crx.iter() {
                    toks.extend(c.tokens);
                }
                toks
            });
            let out = srv
                .serve_generate_chunked(rx, ctx, DecodeConfig::default(), 1, 8, prefill_chunk)
                .unwrap();
            (out.metrics.slices, drain.join().unwrap())
        };
        let (whole_slices, whole) = run(0);
        let (chunked_slices, chunked) = run(3);
        assert_eq!(whole, want);
        assert_eq!(chunked, want, "chunked prefill must not change the stream");
        // 24 prompt tokens in chunks of 3 → ≥ 8 prefill slices, vs the
        // un-chunked run's ⌈24/8⌉ = 3
        assert!(
            chunked_slices > whole_slices,
            "chunking must split prefill into more slices ({chunked_slices} vs {whole_slices})"
        );
    }

    #[test]
    fn replicated_throughput_scales_with_replicas() {
        // closed-loop saturated load: more replicas must raise
        // throughput. Scaled to the runner: replica count never
        // oversubscribes the cores, and the margin is generous so a
        // noisy 2-core CI machine doesn't flake.
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        if cores < 2 {
            return; // meaningless on a single hardware thread
        }
        let n_hi = cores.min(4);
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let reqs = gen_requests(48);
        let run = |n_replicas: usize| {
            let (rx, rtx, rrx) = preloaded(reqs.clone());
            let drain = std::thread::spawn(move || rrx.iter().count());
            let out = srv
                .serve_replicated(rx, rtx, BatchPolicy::default(), n_replicas)
                .unwrap();
            assert_eq!(drain.join().unwrap(), 48);
            out.metrics.throughput_rps()
        };
        // best-of-two absorbs scheduler noise on shared runners
        let t1 = run(1).max(run(1));
        let thi = run(n_hi).max(run(n_hi));
        assert!(
            thi > t1 * 1.1,
            "{n_hi} replicas ({thi:.0} rps) must out-serve 1 replica ({t1:.0} rps)"
        );
    }

    #[test]
    fn tier_handle_routes_mixed_submissions_through_one_path() {
        use crate::decode::{DecodeConfig, Sampling};
        use std::sync::mpsc::channel;

        let srv =
            Arc::new(Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap());
        let policy = BatchPolicy { max_queue: 4, ..Default::default() };
        let tier = Tier::start(
            Arc::clone(&srv),
            TierConfig {
                policy,
                decode: DecodeConfig::default(),
                replicas: 1,
                steps_per_slice: 2,
                max_sessions: 2,
                prefill_chunk: 0,
                trace_sample: 1,
            },
        )
        .unwrap();
        let handle = tier.handle();

        // completion notify fires on a plain channel here; the gateway
        // installs an eventfd waker through the same hook
        let (ntx, nrx) = channel();
        handle.set_notify(move || {
            let _ = ntx.send(());
        });

        // a mixed batch: two classifies + one 3-token generation
        let seqs = gen_requests(2);
        let prompt: Vec<i32> = seqs[0].tokens[..8].to_vec();
        let ids = handle
            .submit(vec![
                Submission::Classify { tokens: seqs[0].tokens.clone() },
                Submission::Classify { tokens: seqs[1].tokens.clone() },
                Submission::Generate { prompt, prefix: None, max_new: 3, sampling: Sampling::Greedy },
            ])
            .unwrap();
        assert_eq!(ids.len(), 3);
        assert!(handle.classify_in_flight() <= 2);

        // admission bound is real: a 5-classify batch exceeds max_queue
        let fat: Vec<Submission> = (0..5)
            .map(|_| Submission::Classify { tokens: seqs[0].tokens.clone() })
            .collect();
        assert_eq!(handle.submit(fat), Err(SubmitError::Saturated));

        let mut done = std::collections::HashMap::new();
        let mut gen_tokens = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut completions = Vec::new();
        while done.len() < 3 {
            assert!(Instant::now() < deadline, "tier completions stalled");
            let _ = nrx.recv_timeout(Duration::from_millis(200));
            handle.take_completions(&mut completions);
            for c in completions.drain(..) {
                match c {
                    Completion::Classify { id, logits, .. } => {
                        assert_eq!(logits.len(), 16);
                        done.insert(id, ());
                    }
                    Completion::Generate { id, tokens, done: d, .. } => {
                        assert_eq!(id, ids[2]);
                        gen_tokens.extend(tokens);
                        if d {
                            done.insert(id, ());
                        }
                    }
                    Completion::ClassifyFailed { fault, .. } => {
                        panic!("no faults injected, none expected: {}", fault.message)
                    }
                }
            }
        }
        assert_eq!(gen_tokens.len(), 3);
        assert!(handle.idle(), "all admission released on completion");

        // closed lanes refuse work, then join returns both outcomes
        handle.close();
        assert_eq!(
            handle.submit(vec![Submission::Classify { tokens: seqs[0].tokens.clone() }]),
            Err(SubmitError::Closed)
        );
        let (classify, generate) = tier.join();
        let classify = classify.unwrap();
        let generate = generate.unwrap();
        assert_eq!(classify.metrics.requests, 2);
        assert_eq!(generate.metrics.sessions, 1);
        assert_eq!(generate.metrics.tokens, 3);

        // the tier recorded spans + histograms along the way: one span
        // per submission, one histogram sample per served request /
        // session, exec stages stamped by the replica worker
        let obs = srv.obs();
        assert_eq!(obs.trace.completed(), 3, "one completed span per submission");
        assert_eq!(obs.classify.total.snapshot().count, 2);
        assert_eq!(obs.classify.queue_wait.snapshot().count, 2);
        assert_eq!(obs.classify.execute.snapshot().count, 2);
        assert_eq!(obs.generate.total.snapshot().count, 1);
        assert_eq!(obs.generate.ttft.snapshot().count, 1);
        assert_eq!(obs.generate.queue_wait.snapshot().count, 1);
        assert_eq!(obs.generate.execute.snapshot().count, generate.metrics.slices as u64);
        let spans = obs.trace.recent(8);
        assert_eq!(spans.len(), 3);
        for span in &spans {
            assert!(span.fault.is_none(), "clean run, no faulted spans");
            let order: Vec<u64> = [
                Stage::Admitted,
                Stage::Queued,
                Stage::Dispatched,
                Stage::ExecStart,
                Stage::ExecEnd,
                Stage::Done,
            ]
            .iter()
            .map(|s| span.stage(*s).expect("full pipeline stamped"))
            .collect();
            assert!(order.windows(2).all(|w| w[0] <= w[1]), "stages monotone: {order:?}");
        }
        let gen_span = spans.iter().find(|s| s.id == ids[2]).expect("generate span retained");
        assert!(gen_span.stage(Stage::FirstChunk).is_some(), "ttft stage stamped");
        assert!(gen_span.prefill_ns.is_some() && gen_span.decode_ns.is_some());
    }
}
