//! The serving loop: a leader thread owns the batcher; worker execution
//! happens on the PJRT executables loaded at startup. The SPLS planner
//! runs on the *host* per batch (it is the coordinator's contribution),
//! producing SPA masks that the masked executable consumes.
//!
//! Single-process deployment with std threads + channels (no tokio in
//! the vendored crate set — see DESIGN.md §Environment).

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::SplsConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher, Request};
use crate::model::{plan_model, TinyWeights};
use crate::quant::QuantMethod;
use crate::runtime::{Arg, ArtifactSet};

/// Serving statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    pub padded_slots: usize,
    pub total_latency: Duration,
    pub max_latency: Duration,
    pub wall: Duration,
}

impl ServeMetrics {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.requests as f64 / self.wall.as_secs_f64()
        }
    }
}

/// One served reply.
#[derive(Clone, Debug)]
pub struct Reply {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
}

/// Execution mode of the serve path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Dense executable.
    Dense,
    /// SPLS: host planner builds SPA masks, masked executable runs.
    Spls,
}

/// Plan one request's SPLS masks (free function so the batch planner
/// can fan out over threads without capturing the non-`Sync` PJRT
/// client).
fn masks_for(weights: &TinyWeights, spls: &SplsConfig, tokens: &[i32]) -> Vec<f32> {
    let plans = plan_model(weights, tokens, spls, QuantMethod::Hlog);
    let cfg = &weights.cfg;
    let l = cfg.seq_len;
    let mut out = Vec::with_capacity(cfg.n_layers * cfg.n_heads * l * l);
    for plan in &plans {
        for head in &plan.heads {
            for r in 0..l {
                let src = head.sim.rep[r];
                for c in 0..l {
                    out.push(if head.mask[(src, c)] { 1.0 } else { 0.0 });
                }
            }
        }
    }
    out
}

/// The serving coordinator.
pub struct Server {
    artifacts: ArtifactSet,
    weights: TinyWeights,
    spls: SplsConfig,
    mode: Mode,
    seq_len: usize,
    n_classes: usize,
}

impl Server {
    pub fn new(artifact_dir: &Path, mode: Mode, spls: SplsConfig) -> Result<Self> {
        let artifacts = ArtifactSet::load(artifact_dir)?;
        let weights = TinyWeights::load(&artifact_dir.join("tiny_weights.bin"))?;
        Ok(Self {
            seq_len: weights.cfg.seq_len,
            n_classes: weights.cfg.n_classes,
            artifacts,
            weights,
            spls,
            mode,
        })
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Execute one batch (size 1 or 8, padded by the batcher).
    fn execute(&self, requests: &[Request], padding: usize) -> Result<Vec<Reply>> {
        let batch = requests.len() + padding;
        let l = self.seq_len;
        let mut toks = Vec::with_capacity(batch * l);
        for r in requests {
            assert_eq!(r.tokens.len(), l, "request length != compiled L");
            toks.extend_from_slice(&r.tokens);
        }
        for _ in 0..padding {
            toks.extend_from_slice(&requests[0].tokens);
        }
        let logits = match self.mode {
            Mode::Dense => self
                .artifacts
                .dense_for_batch(batch)?
                .run_f32(&[Arg::I32(&toks, &[batch, l])])?,
            Mode::Spls => {
                let cfg = &self.weights.cfg;
                let mask_len = cfg.n_layers * cfg.n_heads * l * l;
                // SPLS planning is per-request independent — fan it out
                // over scoped threads (§Perf step 5: the planner was the
                // serving bottleneck once the executables got fast)
                let weights = &self.weights;
                let spls_cfg = &self.spls;
                let planned: Vec<Vec<f32>> = crossbeam_utils::thread::scope(|scope| {
                    let handles: Vec<_> = requests
                        .iter()
                        .map(|r| {
                            let tokens = &r.tokens;
                            scope.spawn(move |_| masks_for(weights, spls_cfg, tokens))
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                })
                .expect("planner thread panicked");
                let mut masks = Vec::with_capacity(batch * mask_len);
                for m in planned {
                    masks.extend(m);
                }
                for _ in 0..padding {
                    masks.extend_from_within(..mask_len);
                }
                self.artifacts.masked_for_batch(batch)?.run_f32(&[
                    Arg::I32(&toks, &[batch, l]),
                    Arg::F32(&masks, &[batch, cfg.n_layers, cfg.n_heads, l, l]),
                ])?
            }
        };
        let now = Instant::now();
        Ok(requests
            .iter()
            .enumerate()
            .map(|(i, r)| Reply {
                id: r.id,
                logits: logits[i * self.n_classes..(i + 1) * self.n_classes].to_vec(),
                latency: now.duration_since(r.arrived),
            })
            .collect())
    }

    /// Serve a stream of requests from a channel until it closes;
    /// replies go out on `replies`. Returns aggregate metrics.
    pub fn serve(
        &self,
        requests: mpsc::Receiver<Request>,
        replies: mpsc::Sender<Reply>,
        policy: BatchPolicy,
    ) -> Result<ServeMetrics> {
        let mut batcher = Batcher::new(policy);
        let mut metrics = ServeMetrics::default();
        let start = Instant::now();
        let mut open = true;
        while open || batcher.pending() > 0 {
            // pull everything currently available without busy-waiting
            match requests.recv_timeout(Duration::from_micros(200)) {
                Ok(r) => {
                    batcher.push(r);
                    while let Ok(r) = requests.try_recv() {
                        batcher.push(r);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            let ready: Vec<_> = if open {
                batcher.pop_ready(Instant::now()).into_iter().collect()
            } else {
                batcher.drain_all()
            };
            for batch in ready {
                let out = self.execute(&batch.requests, batch.padding)?;
                metrics.batches += 1;
                metrics.padded_slots += batch.padding;
                for reply in out {
                    metrics.requests += 1;
                    metrics.total_latency += reply.latency;
                    metrics.max_latency = metrics.max_latency.max(reply.latency);
                    // receiver may have hung up at shutdown; fine
                    let _ = replies.send(reply);
                }
            }
        }
        metrics.wall = start.elapsed();
        Ok(metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn gen_requests(n: usize) -> Vec<Request> {
        let mut rng = Xoshiro256pp::new(42);
        (0..n)
            .map(|i| {
                let (toks, _) = crate::model::synth::gen_example(&mut rng, 64);
                Request { id: i as u64, tokens: toks, arrived: Instant::now() }
            })
            .collect()
    }

    #[test]
    fn dense_server_end_to_end() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let (tx, rx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        for r in gen_requests(20) {
            tx.send(r).unwrap();
        }
        drop(tx);
        let metrics = srv.serve(rx, rtx, BatchPolicy::default()).unwrap();
        assert_eq!(metrics.requests, 20);
        let replies: Vec<Reply> = rrx.iter().collect();
        assert_eq!(replies.len(), 20);
        assert!(replies.iter().all(|r| r.logits.len() == 16));
        assert!(metrics.throughput_rps() > 0.0);
    }

    #[test]
    fn spls_server_agrees_with_dense_mostly() {
        // 24 requests in three compiled-size batches; the SPLS-masked
        // path flips the argmax only on near-ties, so ≥ 2/3 agreement is
        // a robust bar (measured: 18/24 on this seed, all agreeing
        // sequences with comfortable logit margins)
        let dense = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let spls = Server::new(&artifacts_dir(), Mode::Spls, SplsConfig::default()).unwrap();
        let reqs = gen_requests(24);
        let mut agree = 0usize;
        for chunk in reqs.chunks(8) {
            let d = dense.execute(chunk, 0).unwrap();
            let s = spls.execute(chunk, 0).unwrap();
            agree += d
                .iter()
                .zip(&s)
                .filter(|(a, b)| {
                    crate::model::tensor::argmax(&a.logits)
                        == crate::model::tensor::argmax(&b.logits)
                })
                .count();
        }
        assert!(agree >= 16, "only {agree}/24 classifications agree");
    }

    #[test]
    fn padding_replies_only_for_real_requests() {
        let srv = Server::new(&artifacts_dir(), Mode::Dense, SplsConfig::default()).unwrap();
        let reqs = gen_requests(3);
        let out = srv.execute(&reqs, 5).unwrap();
        assert_eq!(out.len(), 3);
    }
}
