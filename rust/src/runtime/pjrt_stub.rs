//! API-compatible stub for the PJRT backend, compiled under
//! `--features pjrt` when the `xla` crate is absent (the `pjrt-xla`
//! feature is off). It keeps the `pjrt` feature *checkable* in CI —
//! the coordinator, binary and examples all type-check against the
//! PJRT artifact API — while every constructor fails loudly at runtime
//! with instructions for enabling the real backend.
//!
//! The real implementation lives in `runtime/pjrt.rs` and needs the
//! `xla` dependency uncommented in `Cargo.toml` plus
//! `--features pjrt,pjrt-xla` (see DESIGN.md §The `pjrt` cargo
//! feature).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::Arg;

const UNAVAILABLE: &str = "the PJRT backend is stubbed: the `xla` crate is not in the vendored \
     set — uncomment the `xla` dependency in rust/Cargo.toml and build with \
     `--features pjrt,pjrt-xla` (see DESIGN.md)";

/// Stub of the compiled-HLO executable. Never constructible.
pub struct Executable;

impl Executable {
    pub fn name(&self) -> &str {
        "pjrt-stub"
    }

    pub fn run_f32(&self, _args: &[Arg<'_>]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn run_i32(&self, _args: &[Arg<'_>]) -> Result<Vec<i32>> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of the PJRT artifact set; `load` always fails with pointers to
/// the real backend.
pub struct ArtifactSet {
    dir: PathBuf,
    pub dense_b1: Executable,
    pub dense_b8: Executable,
    pub masked_b1: Executable,
    pub masked_b8: Executable,
}

impl ArtifactSet {
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn replica_handle(&self) -> Result<ArtifactSet> {
        bail!(UNAVAILABLE)
    }

    pub fn dense_for_batch(&self, _batch: usize) -> Result<&Executable> {
        bail!(UNAVAILABLE)
    }

    pub fn masked_for_batch(&self, _batch: usize) -> Result<&Executable> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_with_enable_instructions() {
        let err = ArtifactSet::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt-xla"), "{err}");
    }
}
