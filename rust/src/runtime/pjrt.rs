//! PJRT backend: compiled-executable wrapper over the `xla` crate's
//! PJRT CPU client. Only compiled under `--features pjrt` (the `xla`
//! dependency is not in the vendored crate set — see Cargo.toml).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::Arg;

/// One compiled HLO artifact, executable with f32/i32 buffers.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Load + compile an HLO-text artifact on a shared PJRT client.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs; returns the f32 payload of the
    /// 1-tuple output (artifacts are lowered with `return_tuple=True`).
    pub fn run_f32(&self, args: &[Arg<'_>]) -> Result<Vec<f32>> {
        let literals = to_literals(args)?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute and return the i32 payload.
    pub fn run_i32(&self, args: &[Arg<'_>]) -> Result<Vec<i32>> {
        let literals = to_literals(args)?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}

fn to_literals(args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
    args.iter()
        .map(|a| match a {
            Arg::F32(data, dims) => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            }
            Arg::I32(data, dims) => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            }
        })
        .collect()
}

/// The full artifact set a serving deployment loads at startup.
pub struct ArtifactSet {
    pub client: Arc<xla::PjRtClient>,
    dir: PathBuf,
    /// batch-1 and batch-8 dense classifiers
    pub dense_b1: Executable,
    pub dense_b8: Executable,
    /// SPA-masked variants
    pub masked_b1: Executable,
    pub masked_b8: Executable,
}

impl ArtifactSet {
    /// Compile everything in `artifacts/` needed to serve.
    pub fn load(dir: &Path) -> Result<Self> {
        if !dir.join("tiny_dense_b1.hlo.txt").exists() {
            bail!(
                "artifacts missing in {} — run `make artifacts` first",
                dir.display()
            );
        }
        let client = Arc::new(xla::PjRtClient::cpu()?);
        Ok(Self {
            dense_b1: Executable::load(&client, &dir.join("tiny_dense_b1.hlo.txt"))?,
            dense_b8: Executable::load(&client, &dir.join("tiny_dense_b8.hlo.txt"))?,
            masked_b1: Executable::load(&client, &dir.join("tiny_masked_b1.hlo.txt"))?,
            masked_b8: Executable::load(&client, &dir.join("tiny_masked_b8.hlo.txt"))?,
            client,
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The PJRT backend cannot clone compiled executables onto worker
    /// threads — serving replicas fall back to the shared set (the
    /// reference backend returns a real per-replica handle here).
    pub fn replica_handle(&self) -> Result<ArtifactSet> {
        bail!("pjrt backend cannot clone compiled executables; replicas share the set")
    }

    /// Pick the dense executable for a batch size (1 or 8).
    pub fn dense_for_batch(&self, batch: usize) -> Result<&Executable> {
        match batch {
            1 => Ok(&self.dense_b1),
            8 => Ok(&self.dense_b8),
            other => bail!("no dense artifact for batch {other} (compiled: 1, 8)"),
        }
    }

    pub fn masked_for_batch(&self, batch: usize) -> Result<&Executable> {
        match batch {
            1 => Ok(&self.masked_b1),
            8 => Ok(&self.masked_b8),
            other => bail!("no masked artifact for batch {other} (compiled: 1, 8)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn standalone_hlog_matmul_artifact_matches_rust_model() {
        let client = xla::PjRtClient::cpu().unwrap();
        let exe =
            Executable::load(&client, &artifacts().join("hlog_matmul_64.hlo.txt")).unwrap();
        let mut rng = crate::util::rng::Xoshiro256pp::new(77);
        let x: Vec<i32> = (0..64 * 64).map(|_| rng.int_in(-128, 127) as i32).collect();
        let w: Vec<i32> = (0..64 * 64).map(|_| rng.int_in(-128, 127) as i32).collect();
        let got = exe
            .run_i32(&[Arg::I32(&x, &[64, 64]), Arg::I32(&w, &[64, 64])])
            .unwrap();
        // the rust bit-level unit model must agree bit-for-bit with the
        // Pallas kernel inside the artifact
        let xm = crate::util::mat::MatI::from_vec(64, 64, x);
        let wm = crate::util::mat::MatI::from_vec(64, 64, w);
        let want = crate::spls::predict::predict_matmul(&xm, &wm);
        assert_eq!(got, want.data, "AOT HLog kernel != rust bit-level model");
    }

    #[test]
    fn dense_artifact_matches_host_forward() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let w = crate::model::TinyWeights::load(&artifacts().join("tiny_weights.bin")).unwrap();
        let mut rng = crate::util::rng::Xoshiro256pp::new(5);
        let toks: Vec<i32> = (0..64).map(|_| rng.below(64) as i32).collect();
        let got = set
            .dense_b1
            .run_f32(&[Arg::I32(&toks, &[1, 64])])
            .unwrap();
        let want = crate::model::forward_dense(&w, &toks);
        assert_eq!(got.len(), 16);
        for (g, h) in got.iter().zip(&want) {
            assert!((g - h).abs() < 2e-2, "AOT {g} vs host {h}");
        }
    }

    #[test]
    fn masked_artifact_full_mask_equals_dense() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let mut rng = crate::util::rng::Xoshiro256pp::new(6);
        let toks: Vec<i32> = (0..64).map(|_| rng.below(64) as i32).collect();
        let masks = vec![1.0f32; 2 * 4 * 64 * 64];
        let dense = set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 64])]).unwrap();
        let masked = set
            .masked_b1
            .run_f32(&[
                Arg::I32(&toks, &[1, 64]),
                Arg::F32(&masks, &[1, 2, 4, 64, 64]),
            ])
            .unwrap();
        for (d, m) in dense.iter().zip(&masked) {
            assert!((d - m).abs() < 1e-3, "dense {d} vs full-mask {m}");
        }
    }

    #[test]
    fn batch_selection_errors_are_clear() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        assert!(set.dense_for_batch(8).is_ok());
        assert!(set.dense_for_batch(3).is_err());
    }
}
