//! Pure-Rust runtime backend (the default): interprets the trained tiny
//! transformer directly from `tiny_weights.bin` behind the same
//! `Executable` / `ArtifactSet` API the PJRT backend exposes, so the
//! coordinator, examples and benches run hermetically — no system
//! libraries, no HLO artifacts, no python.
//!
//! Program semantics mirror the AOT artifacts:
//!
//! * dense program  — `model::forward_dense` per sequence in the batch;
//! * masked program — `model::forward_masked`: every row computes its
//!   own Q under the (replicated) SPA mask, exactly like the Pallas
//!   `masked_attention` kernel inside the compiled artifact. The Spls
//!   serving tier no longer routes through this program (it executes
//!   the compiled CSR/gather sparse forward host-side — see
//!   `model::sparse_plan`); the masked executables remain the AOT
//!   parity surface and the masked bench cells.
//!
//! Execution runs on the packed engine (`model::engine::PackedModel` —
//! packed once at load, shared by every executable and replica handle
//! through one `Arc`) with a per-worker-thread scratch arena, and is
//! bit-identical to the unpacked `model::transformer` forwards
//! (asserted below and by `tests/packed_parity.rs`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::Arg;
use crate::model::{PackedModel, TinyWeights};
use crate::util::scratch::with_thread_scratch;

/// Which program an [`Executable`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Program {
    Dense,
    Masked,
}

/// One executable program bound to the loaded weights and a batch size.
///
/// `Clone` is cheap (the weights live behind an `Arc`), and both
/// `Executable` and [`ArtifactSet`] are `Send + Sync` — the serving
/// replicas move their own handles across worker threads
/// (`ArtifactSet::replica_handle`).
#[derive(Clone)]
pub struct Executable {
    name: String,
    program: Program,
    batch: usize,
    packed: Arc<PackedModel>,
}

impl Executable {
    fn new(program: Program, batch: usize, packed: Arc<PackedModel>) -> Self {
        let kind = match program {
            Program::Dense => "dense",
            Program::Masked => "masked",
        };
        Self {
            name: format!("tiny_{kind}_b{batch}"),
            program,
            batch,
            packed,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    fn tokens<'a>(&self, args: &'a [Arg<'_>]) -> Result<&'a [i32]> {
        let l = self.packed.weights().cfg.seq_len;
        match args.first() {
            Some(&Arg::I32(data, dims)) => {
                if *dims != [self.batch, l] {
                    bail!(
                        "{}: token dims {dims:?}, compiled for [{}, {l}]",
                        self.name,
                        self.batch
                    );
                }
                if data.len() != self.batch * l {
                    bail!("{}: token buffer length {}", self.name, data.len());
                }
                Ok(data)
            }
            _ => bail!("{}: first argument must be I32 tokens", self.name),
        }
    }

    /// Execute with the given inputs; returns the concatenated f32
    /// logits, `batch × n_classes` (the same payload the AOT artifacts
    /// return from their 1-tuple output). Runs on the packed engine
    /// with this worker thread's scratch arena — steady-state batches
    /// allocate nothing beyond the returned logits.
    pub fn run_f32(&self, args: &[Arg<'_>]) -> Result<Vec<f32>> {
        let cfg = self.packed.weights().cfg;
        let l = cfg.seq_len;
        let toks = self.tokens(args)?;
        let mut out = Vec::with_capacity(self.batch * cfg.n_classes);
        match self.program {
            Program::Dense => {
                if args.len() != 1 {
                    bail!("{}: dense program takes exactly one argument", self.name);
                }
                with_thread_scratch(|sc| {
                    for b in 0..self.batch {
                        out.extend(self.packed.forward_dense(&toks[b * l..(b + 1) * l], sc));
                    }
                });
            }
            Program::Masked => {
                let per = cfg.n_layers * cfg.n_heads * l * l;
                let masks = match args.get(1) {
                    Some(&Arg::F32(data, dims)) => {
                        if *dims != [self.batch, cfg.n_layers, cfg.n_heads, l, l] {
                            bail!(
                                "{}: mask dims {dims:?}, compiled for [{}, {}, {}, {l}, {l}]",
                                self.name,
                                self.batch,
                                cfg.n_layers,
                                cfg.n_heads
                            );
                        }
                        if data.len() != self.batch * per {
                            bail!("{}: mask buffer length {}", self.name, data.len());
                        }
                        data
                    }
                    _ => bail!("{}: second argument must be F32 masks", self.name),
                };
                with_thread_scratch(|sc| {
                    for b in 0..self.batch {
                        out.extend(self.packed.forward_masked(
                            &toks[b * l..(b + 1) * l],
                            &masks[b * per..(b + 1) * per],
                            sc,
                        ));
                    }
                });
            }
        }
        Ok(out)
    }

    /// The reference backend only serves the f32 classifier programs;
    /// int8 HLog kernels exist only as AOT artifacts (pjrt feature).
    pub fn run_i32(&self, _args: &[Arg<'_>]) -> Result<Vec<i32>> {
        bail!(
            "{}: run_i32 requires the pjrt backend (int8 HLog artifacts)",
            self.name
        )
    }
}

/// The full artifact set a serving deployment loads at startup — in the
/// reference backend, the trained weights plus the four programs the
/// AOT path would have compiled (dense/masked × batch 1/8).
#[derive(Clone)]
pub struct ArtifactSet {
    dir: PathBuf,
    pub weights: Arc<TinyWeights>,
    /// The packed execution engine every executable (and, via
    /// `replica_handle`, every serving replica) shares — weights are
    /// packed exactly once per load.
    pub packed: Arc<PackedModel>,
    pub dense_b1: Executable,
    pub dense_b8: Executable,
    pub masked_b1: Executable,
    pub masked_b8: Executable,
}

impl ArtifactSet {
    /// Load everything in `artifacts/` needed to serve.
    pub fn load(dir: &Path) -> Result<Self> {
        let wpath = dir.join("tiny_weights.bin");
        if !wpath.exists() {
            bail!(
                "artifacts missing in {} — run `make artifacts` first",
                dir.display()
            );
        }
        let weights = Arc::new(TinyWeights::load(&wpath)?);
        let packed = Arc::new(PackedModel::new(weights.clone()));
        Ok(Self {
            dense_b1: Executable::new(Program::Dense, 1, packed.clone()),
            dense_b8: Executable::new(Program::Dense, 8, packed.clone()),
            masked_b1: Executable::new(Program::Masked, 1, packed.clone()),
            masked_b8: Executable::new(Program::Masked, 8, packed.clone()),
            weights,
            packed,
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// A per-replica executor handle: clones the program table while
    /// sharing the loaded weights (`Arc`), so a serving replica gets a
    /// `Send`-able executor of its own without touching the
    /// filesystem. Always succeeds on this backend; the PJRT backend
    /// cannot clone compiled executables and returns an error (its
    /// replicas fall back to the shared set).
    pub fn replica_handle(&self) -> Result<ArtifactSet> {
        Ok(self.clone())
    }

    /// Pick the dense executable for a batch size (1 or 8).
    pub fn dense_for_batch(&self, batch: usize) -> Result<&Executable> {
        match batch {
            1 => Ok(&self.dense_b1),
            8 => Ok(&self.dense_b8),
            other => bail!("no dense artifact for batch {other} (compiled: 1, 8)"),
        }
    }

    pub fn masked_for_batch(&self, batch: usize) -> Result<&Executable> {
        match batch {
            1 => Ok(&self.masked_b1),
            8 => Ok(&self.masked_b8),
            other => bail!("no masked artifact for batch {other} (compiled: 1, 8)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward_dense;
    use crate::util::rng::Xoshiro256pp;

    fn artifacts() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn dense_program_matches_host_forward_exactly() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let mut rng = Xoshiro256pp::new(5);
        let toks: Vec<i32> = (0..64).map(|_| rng.below(64) as i32).collect();
        let got = set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 64])]).unwrap();
        let want = forward_dense(&set.weights, &toks);
        assert_eq!(got, want, "reference backend IS the host model");
        assert_eq!(got.len(), 16);
    }

    #[test]
    fn masked_program_full_mask_equals_dense() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let mut rng = Xoshiro256pp::new(6);
        let toks: Vec<i32> = (0..64).map(|_| rng.below(64) as i32).collect();
        let masks = vec![1.0f32; 2 * 4 * 64 * 64];
        let dense = set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 64])]).unwrap();
        let masked = set
            .masked_b1
            .run_f32(&[
                Arg::I32(&toks, &[1, 64]),
                Arg::F32(&masks, &[1, 2, 4, 64, 64]),
            ])
            .unwrap();
        for (d, m) in dense.iter().zip(&masked) {
            assert!((d - m).abs() < 1e-3, "dense {d} vs full-mask {m}");
        }
    }

    #[test]
    fn batch8_concatenates_per_sequence_logits() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let mut rng = Xoshiro256pp::new(7);
        let seqs: Vec<Vec<i32>> = (0..8)
            .map(|_| (0..64).map(|_| rng.below(64) as i32).collect())
            .collect();
        let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
        let batched = set.dense_b8.run_f32(&[Arg::I32(&flat, &[8, 64])]).unwrap();
        assert_eq!(batched.len(), 8 * 16);
        for (i, s) in seqs.iter().enumerate() {
            let single = set.dense_b1.run_f32(&[Arg::I32(s, &[1, 64])]).unwrap();
            assert_eq!(&batched[i * 16..(i + 1) * 16], &single[..]);
        }
    }

    #[test]
    fn batch_selection_errors_are_clear() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        assert!(set.dense_for_batch(8).is_ok());
        assert!(set.dense_for_batch(3).is_err());
        assert!(set.masked_for_batch(5).is_err());
    }

    #[test]
    fn wrong_shapes_rejected() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let toks = vec![0i32; 32];
        assert!(set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 32])]).is_err());
        let toks64 = vec![0i32; 64];
        let short_masks = vec![1.0f32; 64];
        assert!(set
            .masked_b1
            .run_f32(&[
                Arg::I32(&toks64, &[1, 64]),
                Arg::F32(&short_masks, &[1, 1, 1, 8, 8]),
            ])
            .is_err());
    }

    #[test]
    fn executor_handles_are_send_sync_and_cheap() {
        fn check<T: Send + Sync>() {}
        check::<ArtifactSet>();
        check::<Executable>();
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let handle = set.replica_handle().unwrap();
        // the handle shares the weights allocation (no reload, no copy)
        assert!(Arc::ptr_eq(&set.weights, &handle.weights));
        // …and the packed engine: replicas never repack
        assert!(Arc::ptr_eq(&set.packed, &handle.packed));
        let toks = vec![0i32; 64];
        let a = set.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 64])]).unwrap();
        let b = handle.dense_b1.run_f32(&[Arg::I32(&toks, &[1, 64])]).unwrap();
        assert_eq!(a, b, "handle executes the same programs");
    }

    #[test]
    fn run_i32_unsupported_without_pjrt() {
        let set = ArtifactSet::load(&artifacts()).unwrap();
        let toks = vec![0i32; 64];
        assert!(set.dense_b1.run_i32(&[Arg::I32(&toks, &[1, 64])]).is_err());
    }
}
