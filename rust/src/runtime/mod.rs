//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and execute them from the rust request path.
//! Python never runs at serve time — the interchange is HLO *text*
//! (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos; the text
//! parser reassigns instruction ids).

mod executable;

pub use executable::{Arg, ArtifactSet, Executable};
