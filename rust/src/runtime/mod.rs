//! Serving runtime: execute the tiny-classifier programs behind a
//! backend-neutral API (`Executable`, `ArtifactSet`, `Arg`).
//!
//! Two backends share the API:
//!
//! * [`reference`] (always compiled, default) — a pure-Rust interpreter
//!   over the trained weights (`model::transformer`), hermetic: no
//!   system libraries, no network, no python. The dense program is
//!   exactly `forward_dense`; the masked program applies per-(layer,
//!   head) SPA masks in attention like the AOT Pallas kernel does.
//! * `pjrt` (cargo feature `pjrt`) — loads AOT-compiled HLO-text
//!   artifacts (produced once by `python/compile/aot.py`) and executes
//!   them through the `xla` crate's PJRT CPU client. Python never runs
//!   at serve time — the interchange is HLO *text* (xla_extension 0.5.1
//!   rejects jax ≥ 0.5 serialized protos; the text parser reassigns
//!   instruction ids). The real client needs the `xla` dependency and
//!   the additional `pjrt-xla` feature; with `pjrt` alone the API
//!   compiles against a stub that fails loudly at load time — this is
//!   what keeps the gated backend checkable in CI's feature matrix
//!   without the unvendored `xla` crate.

pub mod reference;

#[cfg(all(feature = "pjrt", feature = "pjrt-xla"))]
pub mod pjrt;

#[cfg(all(feature = "pjrt", not(feature = "pjrt-xla")))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactSet, Executable};

#[cfg(not(feature = "pjrt"))]
pub use reference::{ArtifactSet, Executable};

/// Dims + data of one input buffer (shared by both backends).
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}
