//! Technology-node scaling (paper §V-E, methodology of Wang et al. [45])
//! used to normalize SpAtten (40 nm) and Sanger (55 nm) to 28 nm for the
//! Table IV comparison.
//!
//! First-order scaling with feature size λ (constant-field flavour —
//! the convention that reproduces the paper's normalized numbers
//! exactly: SpAtten 360 GOPS / 0.325 W @40 nm → 2261 GOPS/W @28 nm and
//! Sanger 2116 / 2.76 @55 nm → 2958 GOPS/W):
//!
//!   area      ∝ λ²
//!   delay     ∝ λ   (frequency, hence throughput, ∝ 1/λ)
//!   energy/op ∝ λ²  (C ∝ λ and V ∝ λ^~0.5 in this range)
//!   power = energy/op × op rate ∝ λ² / λ = λ

/// Process node in nanometres.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TechNode(pub f64);

impl TechNode {
    pub const NM28: TechNode = TechNode(28.0);
    pub const NM40: TechNode = TechNode(40.0);
    pub const NM55: TechNode = TechNode(55.0);
}

/// Scale (area mm², freq Hz) from one node to another.
pub fn scale_freq_area(area: f64, freq: f64, from: TechNode, to: TechNode) -> (f64, f64) {
    let r = to.0 / from.0; // < 1 when shrinking
    (area * r * r, freq / r)
}

/// Scale per-op energy between nodes (energy ∝ λ²).
pub fn scale_energy(energy: f64, from: TechNode, to: TechNode) -> f64 {
    let r = to.0 / from.0;
    energy * r * r
}

/// Scale a (throughput GOPS, power W, area mm²) triple to `to`,
/// assuming the design is re-timed at the scaled frequency (throughput
/// ∝ frequency) — the normalization applied to SpAtten/Sanger in
/// Table IV.
pub fn scale_design(
    gops: f64,
    power_w: f64,
    area_mm2: f64,
    from: TechNode,
    to: TechNode,
) -> (f64, f64, f64) {
    let r = to.0 / from.0;
    let gops2 = gops / r; // freq up by 1/r
    let power2 = power_w * r; // energy/op ∝ r², rate ∝ 1/r
    let area2 = area_mm2 * r * r;
    (gops2, power2, area2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrink_raises_freq_lowers_area() {
        let (a, f) = scale_freq_area(1.55, 1e9, TechNode::NM40, TechNode::NM28);
        assert!(a < 1.55);
        assert!(f > 1e9);
        assert!((a - 1.55 * (0.7 * 0.7)).abs() < 1e-9);
    }

    #[test]
    fn identity_scaling() {
        let (g, p, a) = scale_design(100.0, 1.0, 2.0, TechNode::NM28, TechNode::NM28);
        assert_eq!((g, p, a), (100.0, 1.0, 2.0));
    }

    #[test]
    fn energy_scales_quadratically() {
        let e = scale_energy(1.0, TechNode::NM55, TechNode::NM28);
        let r = 28.0 / 55.0;
        assert!((e - r * r).abs() < 1e-12);
    }

    #[test]
    fn spatten_normalization_matches_table4() {
        // paper Table IV: SpAtten normalizes to 2261 GOPS/W, 677 GOPS/mm²
        let (g, p, a) = scale_design(360.0, 0.325, 1.55, TechNode::NM40, TechNode::NM28);
        assert!((g / p - 2261.0).abs() / 2261.0 < 0.02, "{}", g / p);
        assert!((g / a - 677.0).abs() / 677.0 < 0.02, "{}", g / a);
    }

    #[test]
    fn sanger_normalization_matches_table4() {
        // paper Table IV: Sanger → 2958 GOPS/W, ~1025 GOPS/mm²
        let (g, p, a) = scale_design(2116.0, 2.76, 16.9, TechNode::NM55, TechNode::NM28);
        assert!((g / p - 2958.0).abs() / 2958.0 < 0.02, "{}", g / p);
        assert!((g / a - 1025.0).abs() / 1025.0 < 0.10, "{}", g / a);
    }
}
