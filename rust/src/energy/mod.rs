//! Energy / area models: op-level energies (Horowitz ISSCC'14 scaled to
//! 28 nm), module-level area/power (Table II), quantization-unit
//! comparison (Table III), and technology scaling between nodes
//! (Table IV, methodology of [45]).

pub mod area;
pub mod ops;
pub mod scaling;

pub use area::{esact_breakdown, quant_unit_comparison, ModuleBudget, QuantUnitCost};
pub use ops::{OpEnergy, E28};
pub use scaling::{scale_energy, scale_freq_area, TechNode};
