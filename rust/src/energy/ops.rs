//! Op-level energy numbers.
//!
//! Basis: Horowitz, "Computing's energy problem" (ISSCC 2014), 45 nm
//! numbers, scaled to 28 nm with the standard ~0.5× dynamic-energy
//! factor per full node (capacitance·V² scaling); SRAM/DRAM numbers
//! follow the same convention the paper's comparison baselines use.
//! Absolute pJ values are model inputs, not synthesis measurements —
//! Tables II-IV are reproduced *structurally* (ratios, rankings).

/// Energy per operation in picojoules at a given node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpEnergy {
    /// 8-bit integer add.
    pub add8: f64,
    /// 16-bit integer add (converter count accumulation).
    pub add16: f64,
    /// 32-bit integer add.
    pub add32: f64,
    /// 8-bit integer multiply.
    pub mul8: f64,
    /// 8-bit MAC (mul8 + add16 accumulate).
    pub mac8: f64,
    /// 4-bit multiply (Sanger's predictor).
    pub mul4: f64,
    /// comparison / mux (quantization ladders).
    pub cmp8: f64,
    /// flip-flop toggle (pipeline registers, FIFO cell).
    pub reg: f64,
    /// 64-bit SRAM read per byte (on-chip buffers).
    pub sram_byte: f64,
    /// DRAM access per byte.
    pub dram_byte: f64,
}

/// 28 nm op energies (pJ). Horowitz 45 nm × ~0.5 node factor:
/// add8 0.03→0.015, mul8 0.2→0.1, add32 0.1→0.05; SRAM ~0.6 pJ/byte
/// (32 KB macro read / 8 bytes), DRAM ~10 pJ/byte (LPDDR-class).
pub const E28: OpEnergy = OpEnergy {
    add8: 0.015,
    add16: 0.025,
    add32: 0.05,
    mul8: 0.10,
    mac8: 0.125,
    mul4: 0.03,
    cmp8: 0.012,
    reg: 0.003,
    sram_byte: 0.6,
    dram_byte: 10.0,
};

impl OpEnergy {
    /// Energy of the bit-level prediction unit per HLog product:
    /// SD encode (2 gate-level ops ≈ 1 cmp) + SJA exponent add (add8)
    /// + converter counter increments (2 × add16 amortized).
    pub fn hlog_product(&self) -> f64 {
        self.cmp8 + self.add8 + 2.0 * self.add16
    }

    /// Energy per predicted output element given K accumulated products
    /// (converter binary conversion + sign-group subtract amortized).
    pub fn hlog_dot(&self, k: usize) -> f64 {
        k as f64 * self.hlog_product() + 2.0 * self.add32
    }

    /// Energy per int8 MAC in the formal phase (PE array).
    pub fn pe_mac(&self) -> f64 {
        self.mac8 + self.reg
    }

    /// Sanger-style 4-bit quantized prediction per product.
    pub fn lin4_product(&self) -> f64 {
        self.mul4 + self.add16
    }

    /// APoT (Enhance) per product: position detection (3 cmp) + two
    /// exponent adds + adder-tree accumulation (2 add16). The paper
    /// notes the APoT transform itself retains >40% of multiply energy.
    pub fn apot_product(&self) -> f64 {
        3.0 * self.cmp8 + 2.0 * self.add8 + 2.0 * self.add16
    }

    /// PoT (FACT) per product: LDZ detect (1 cmp) + exponent add +
    /// one-hot counter increment.
    pub fn pot_product(&self) -> f64 {
        self.cmp8 + self.add8 + self.add16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_cheaper_than_mac() {
        // the whole premise: HLog prediction product ≪ int8 MAC
        assert!(E28.hlog_product() < E28.pe_mac());
        assert!(E28.hlog_product() / E28.pe_mac() < 0.7);
    }

    #[test]
    fn quant_method_energy_ranking() {
        // paper Table III power ranking: FACT(PoT) < ESACT(HLog) < Enhance(APoT) ≈ Sanger(4-bit)
        let pot = E28.pot_product();
        let hlog = E28.hlog_product();
        let apot = E28.apot_product();
        let lin4 = E28.lin4_product();
        assert!(pot < hlog, "pot {pot} hlog {hlog}");
        assert!(hlog < apot, "hlog {hlog} apot {apot}");
        // APoT's transform keeps a large share of the multiply energy
        // (paper cites >40% [43,44]); per-product it can even exceed the
        // bare 4-bit multiply — parity at the *unit* level (Table III)
        // comes from the shared adder tree, asserted in energy::area.
        assert!(apot <= lin4 * 2.5, "apot {apot} lin4 {lin4}");
    }

    #[test]
    fn memory_hierarchy_ordering() {
        assert!(E28.reg < E28.sram_byte);
        assert!(E28.sram_byte < E28.dram_byte);
        assert!(E28.dram_byte / E28.sram_byte > 10.0);
    }

    #[test]
    fn hlog_dot_scales_with_k() {
        assert!(E28.hlog_dot(128) > 100.0 * E28.hlog_product());
        assert!(E28.hlog_dot(1) < 10.0 * E28.pe_mac());
    }
}
