//! Module-level area / power budgets (paper Tables II and III).
//!
//! The paper's absolute numbers come from Synopsys DC + TSMC 28 nm; we
//! model each module as (component count × per-component area/power)
//! with per-component constants calibrated so the totals land on
//! Table II — the *structure* (which module dominates, the prediction
//! module's small share, the quant-method ranking of Table III) is then
//! generated, not transcribed, and responds to configuration changes.

use crate::config::HardwareConfig;

/// One module's silicon budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModuleBudget {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_mw: f64,
}

/// Per-component 28 nm constants (calibrated to Table II @ 500 MHz).
mod unit {
    /// One int8 MAC PE: area mm², power mW @500 MHz.
    pub const PE_AREA: f64 = 1.85 / 1024.0;
    pub const PE_POWER: f64 = 324.14 / 1024.0;
    /// SRAM: mm² and mW per KB (Table II: 512 KB → 1.6 mm², 317.84 mW).
    pub const SRAM_AREA_KB: f64 = 1.6 / 512.0;
    pub const SRAM_POWER_KB: f64 = 317.84 / 512.0;
    /// Shift detector lane (HLog SD).
    pub const SD_AREA: f64 = 0.0002;
    pub const SD_POWER: f64 = 0.06;
    /// 8-bit adder lane in a prediction array.
    pub const ADD_AREA: f64 = 0.00008;
    pub const ADD_POWER: f64 = 0.025;
    /// Subtractor lane (similarity unit).
    pub const SUB_AREA: f64 = 0.0001;
    pub const SUB_POWER: f64 = 0.03;
    /// Converter / one-hot adder block.
    pub const CONV_AREA: f64 = 0.055;
    pub const CONV_POWER: f64 = 16.0;
    /// 4-bit multiplier (Sanger).
    pub const MUL4_AREA: f64 = 0.00014;
    pub const MUL4_POWER: f64 = 0.055;
    /// LDZ detector (FACT PoT).
    pub const LDZ_AREA: f64 = 0.00009;
    pub const LDZ_POWER: f64 = 0.028;
    /// APoT position detector (Enhance): 3 leading-one positions.
    pub const POSDET_AREA: f64 = 0.00055;
    pub const POSDET_POWER: f64 = 0.18;
    /// Adder tree per 128 lanes (Sanger/Enhance accumulation).
    pub const TREE_AREA: f64 = 0.075;
    pub const TREE_POWER: f64 = 26.0;
    /// Functional module (top-k, layernorm, softmax, others) lump.
    pub const FUNC_AREA: f64 = 1.41;
    pub const FUNC_POWER: f64 = 92.71;
}

/// ESACT's four-module breakdown (Table II).
pub fn esact_breakdown(hw: &HardwareConfig) -> Vec<ModuleBudget> {
    let pes = (hw.pe_rows * hw.pe_cols) as f64;
    let sram_kb = (hw.weight_buf + hw.token_buf + hw.temp_buf) as f64 / 1024.0;
    // Sparsity prediction module: 8×26 subtractors (similarity, top-k
    // bound 0.2 → 26 ≈ 128·0.2), `pred_lanes` shift detectors, 8×128
    // adders (SJA), one converter.
    let n_sub = 8.0 * 26.0;
    let n_sd = hw.pred_lanes as f64;
    let n_add = 8.0 * hw.pred_lanes as f64;
    vec![
        ModuleBudget {
            name: "PE Array",
            area_mm2: pes * unit::PE_AREA,
            power_mw: pes * unit::PE_POWER,
        },
        ModuleBudget {
            name: "Sparsity Prediction Module",
            area_mm2: n_sub * unit::SUB_AREA
                + n_sd * unit::SD_AREA
                + n_add * unit::ADD_AREA
                + unit::CONV_AREA,
            power_mw: n_sub * unit::SUB_POWER
                + n_sd * unit::SD_POWER
                + n_add * unit::ADD_POWER
                + unit::CONV_POWER,
        },
        ModuleBudget {
            name: "SRAM",
            area_mm2: sram_kb * unit::SRAM_AREA_KB,
            power_mw: sram_kb * unit::SRAM_POWER_KB,
        },
        ModuleBudget {
            name: "Functional Module",
            area_mm2: unit::FUNC_AREA,
            power_mw: unit::FUNC_POWER,
        },
    ]
}

/// Totals over a breakdown.
pub fn totals(budget: &[ModuleBudget]) -> (f64, f64) {
    (
        budget.iter().map(|m| m.area_mm2).sum(),
        budget.iter().map(|m| m.power_mw).sum(),
    )
}

/// Quantization-unit cost for the Table III comparison (all at 128
/// lanes, 8-deep accumulation, 28 nm).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantUnitCost {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_mw: f64,
}

/// Table III: prediction-unit area/power of Sanger (4-bit multipliers +
/// adder tree), FACT (LDZ + adders + one-hot adder), Enhance (position
/// detectors + adders + adder tree), ESACT (shift detectors + adders +
/// converter).
pub fn quant_unit_comparison(lanes: usize) -> Vec<QuantUnitCost> {
    let n = lanes as f64;
    let deep = 8.0 * n; // 8×128 adders / multipliers
    vec![
        QuantUnitCost {
            name: "Sanger",
            area_mm2: deep * unit::MUL4_AREA + unit::TREE_AREA,
            power_mw: deep * unit::MUL4_POWER + unit::TREE_POWER,
        },
        QuantUnitCost {
            name: "FACT",
            area_mm2: n * unit::LDZ_AREA + deep * unit::ADD_AREA + unit::CONV_AREA * 0.6,
            power_mw: n * unit::LDZ_POWER + deep * unit::ADD_POWER + unit::CONV_POWER * 0.55,
        },
        QuantUnitCost {
            name: "Enhance",
            area_mm2: n * unit::POSDET_AREA + deep * unit::ADD_AREA + unit::TREE_AREA,
            power_mw: n * unit::POSDET_POWER + deep * unit::ADD_POWER + unit::TREE_POWER,
        },
        QuantUnitCost {
            name: "ESACT",
            area_mm2: n * unit::SD_AREA + deep * unit::ADD_AREA + unit::CONV_AREA,
            power_mw: n * unit::SD_POWER + deep * unit::ADD_POWER + unit::CONV_POWER,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    #[test]
    fn table2_totals() {
        let b = esact_breakdown(&HardwareConfig::default());
        let (area, power) = totals(&b);
        // Paper Table II: 5.09 mm², 792.12 mW
        assert!((area - 5.09).abs() < 0.15, "area {area}");
        assert!((power - 792.12).abs() < 25.0, "power {power}");
    }

    #[test]
    fn prediction_module_small_share() {
        // Paper: 4.52% of area, 7.25% of power
        let b = esact_breakdown(&HardwareConfig::default());
        let (area, power) = totals(&b);
        let pred = b.iter().find(|m| m.name.starts_with("Sparsity")).unwrap();
        let a_share = pred.area_mm2 / area;
        let p_share = pred.power_mw / power;
        assert!((a_share - 0.0452).abs() < 0.015, "area share {a_share}");
        assert!((p_share - 0.0725).abs() < 0.02, "power share {p_share}");
    }

    #[test]
    fn table3_ranking_and_magnitudes() {
        let v = quant_unit_comparison(128);
        let get = |n: &str| v.iter().find(|c| c.name == n).unwrap();
        let (sanger, fact, enh, esact) =
            (get("Sanger"), get("FACT"), get("Enhance"), get("ESACT"));
        // paper Table III: Sanger 0.23/81.7, FACT 0.14/37.98,
        // Enhance 0.26/80.76, ESACT 0.17/48.21
        assert!((sanger.area_mm2 - 0.23).abs() < 0.04, "{}", sanger.area_mm2);
        assert!((fact.area_mm2 - 0.14).abs() < 0.04, "{}", fact.area_mm2);
        assert!((enh.area_mm2 - 0.26).abs() < 0.04, "{}", enh.area_mm2);
        assert!((esact.area_mm2 - 0.17).abs() < 0.04, "{}", esact.area_mm2);
        assert!((sanger.power_mw - 81.7).abs() < 12.0, "{}", sanger.power_mw);
        assert!((fact.power_mw - 37.98).abs() < 8.0, "{}", fact.power_mw);
        assert!((enh.power_mw - 80.76).abs() < 12.0, "{}", enh.power_mw);
        assert!((esact.power_mw - 48.21).abs() < 8.0, "{}", esact.power_mw);
        // structural claims: ESACT cheaper than Sanger/Enhance, pricier than FACT
        assert!(esact.power_mw < sanger.power_mw);
        assert!(esact.power_mw < enh.power_mw);
        assert!(esact.power_mw > fact.power_mw);
    }

    #[test]
    fn breakdown_scales_with_pe_count() {
        let mut hw = HardwareConfig::default();
        hw.pe_rows = 32; // double the array
        let (area, _) = totals(&esact_breakdown(&hw));
        assert!(area > 5.09 + 1.5);
    }
}
