//! Configuration types: model shapes (the paper's 26-benchmark zoo),
//! SPLS hyperparameters, and the ESACT accelerator hardware parameters.

/// Transformer model shape — enough to compute FLOPs and drive the
/// cycle-level simulator. Matches the paper's workloads (§V-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    /// Sequence length L.
    pub seq_len: usize,
    /// Embedding dimension D.
    pub d_model: usize,
    /// Number of attention heads H.
    pub n_heads: usize,
    /// Number of transformer layers.
    pub n_layers: usize,
    /// FFN hidden dimension (usually 4·D).
    pub d_ffn: usize,
    /// Decoder (causal) models generate attention differently in Fig 4.
    pub causal: bool,
}

impl ModelConfig {
    pub const fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub const fn new(
        name: &'static str,
        seq_len: usize,
        d_model: usize,
        n_heads: usize,
        n_layers: usize,
        d_ffn: usize,
        causal: bool,
    ) -> Self {
        Self { name, seq_len, d_model, n_heads, n_layers, d_ffn, causal }
    }
}

/// BERT-Base shape at a given sequence length.
pub const fn bert_base(seq_len: usize) -> ModelConfig {
    ModelConfig::new("BERT-Base", seq_len, 768, 12, 12, 3072, false)
}

/// BERT-Large shape at a given sequence length.
pub const fn bert_large(seq_len: usize) -> ModelConfig {
    ModelConfig::new("BERT-Large", seq_len, 1024, 16, 24, 4096, false)
}

/// GPT-2 (117M) shape.
pub const fn gpt2(seq_len: usize) -> ModelConfig {
    ModelConfig::new("GPT-2", seq_len, 768, 12, 12, 3072, true)
}

/// Llama2-7b shape.
pub const fn llama2_7b(seq_len: usize) -> ModelConfig {
    ModelConfig::new("Llama2-7b", seq_len, 4096, 32, 32, 11008, true)
}

/// Bloom-7b shape.
pub const fn bloom_7b(seq_len: usize) -> ModelConfig {
    ModelConfig::new("Bloom-7b", seq_len, 4096, 32, 30, 16384, true)
}

/// ViT-B/16 (224×224 → 196 patches + CLS).
pub const fn vit_b16() -> ModelConfig {
    ModelConfig::new("ViT-B/16", 197, 768, 12, 12, 3072, false)
}

/// ViT-B/32 (224×224 → 49 patches + CLS).
pub const fn vit_b32() -> ModelConfig {
    ModelConfig::new("ViT-B/32", 50, 768, 12, 12, 3072, false)
}

/// SPLS hyperparameters (paper §V-B: top-k ratio k, similarity threshold
/// s, FFN threshold f, window size w).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplsConfig {
    /// Row-wise top-k keep ratio over the PAM (k in the paper).
    pub top_k: f32,
    /// L1-distance similarity threshold, normalized (s in the paper;
    /// larger s -> more rows declared similar -> more Q sparsity).
    pub sim_threshold: f32,
    /// MFI occurrence-count threshold for FFN token similarity
    /// (f in the paper; smaller f -> more FFN sparsity).
    pub ffn_threshold: usize,
    /// Local window size w (the paper fixes w = 8).
    pub window: usize,
}

impl Default for SplsConfig {
    fn default() -> Self {
        // Paper's representative operating point (Figs 15/16: k=0.12,
        // w=8; s/f tuned per-task — these defaults hold loss ≤ 1% on the
        // sparse-fine-tuned tiny substrate; the accuracy harness and
        // tests/integration_regression.rs pin the corridor).
        Self { top_k: 0.12, sim_threshold: 0.6, ffn_threshold: 2, window: 8 }
    }
}

/// ESACT accelerator hardware parameters (paper §IV/§V, Table II).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HardwareConfig {
    /// PE array rows (PE lines).
    pub pe_rows: usize,
    /// PE array columns.
    pub pe_cols: usize,
    /// Clock frequency in Hz.
    pub freq_hz: f64,
    /// Prediction-unit lanes (shift detectors).
    pub pred_lanes: usize,
    /// Weight buffer bytes.
    pub weight_buf: usize,
    /// Token buffer bytes.
    pub token_buf: usize,
    /// Temp buffer bytes.
    pub temp_buf: usize,
    /// Off-chip bandwidth bytes/s (paper: configured to 900 GB/s total,
    /// i.e. V100-matched across 125 units).
    pub dram_bw: f64,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        Self {
            pe_rows: 16,
            pe_cols: 64,
            freq_hz: 500e6,
            pred_lanes: 128,
            weight_buf: 192 * 1024,
            token_buf: 192 * 1024,
            temp_buf: 128 * 1024,
            dram_bw: 900e9 / 125.0, // per-unit share of the V100-matched BW
        }
    }
}

impl HardwareConfig {
    /// Peak MAC/s of the PE array (1 MAC/PE/cycle).
    pub fn peak_macs(&self) -> f64 {
        (self.pe_rows * self.pe_cols) as f64 * self.freq_hz
    }

    /// Peak ops/s counting one MAC as two ops (the TOPS convention used
    /// by the paper's 125-unit = 125 TOPS comparison — 125 × 1024 PEs ×
    /// 2 ops × 500 MHz ≈ 128 TOPS ≈ V100 peak).
    pub fn peak_ops(&self) -> f64 {
        2.0 * self.peak_macs()
    }
}

/// Deployment configuration for the coordinator (paper §V-C: 125 units
/// in 25 clusters, workloads partitioned batch → head → seq).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeployConfig {
    pub n_units: usize,
    pub n_clusters: usize,
}

impl Default for DeployConfig {
    fn default() -> Self {
        Self { n_units: 125, n_clusters: 25 }
    }
}

impl DeployConfig {
    pub fn units_per_cluster(&self) -> usize {
        self.n_units / self.n_clusters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_shapes() {
        assert_eq!(bert_base(128).d_head(), 64);
        assert_eq!(bert_large(512).d_head(), 64);
        assert_eq!(llama2_7b(512).d_head(), 128);
        assert!(gpt2(512).causal);
        assert!(!vit_b16().causal);
    }

    #[test]
    fn peak_throughput_matches_paper_setup() {
        let hw = HardwareConfig::default();
        // 16×64 PEs × 2 × 500 MHz = 1.024 TOPS/unit; ×125 units ≈ 125 TOPS
        let total = hw.peak_ops() * 125.0;
        assert!((total / 1e12 - 128.0).abs() < 1.0, "{}", total / 1e12);
    }

    #[test]
    fn deploy_partitioning() {
        let d = DeployConfig::default();
        assert_eq!(d.units_per_cluster(), 5);
    }

    #[test]
    fn spls_defaults_match_paper() {
        let s = SplsConfig::default();
        assert_eq!(s.window, 8);
        assert!((s.top_k - 0.12).abs() < 1e-6);
    }
}
