//! ESACT leader binary: experiment reproduction (`repro <id>`),
//! accuracy evaluation (`eval`), the serving loop (`serve`), and the
//! cycle simulator (`sim`).

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Result};

use esact::config::SplsConfig;
use esact::coordinator::server::Mode;
use esact::coordinator::{BatchPolicy, GenRequest, Request};
use esact::coordinator::Server;
use esact::decode::{DecodeConfig, DecodeMode, Sampling};
use esact::model;
use esact::net::client::{classify_body, generate_body, HttpClient, IdleConns};
use esact::net::{Gateway, GatewayConfig};
use esact::obs::prom;
use esact::quant::QuantMethod;
use esact::report::{figures, tables};
use esact::util::fault::FaultPlan;
use esact::util::rng::Xoshiro256pp;

const USAGE: &str = "\
esact — ESACT paper reproduction (see DESIGN.md)

USAGE:
  esact repro <id>            regenerate a paper figure/table
                              (fig1 fig3 fig4 fig6 fig7 fig15 fig16 fig17
                               fig18 fig19 fig20 fig21 table1..table4 | all)
  esact eval [n] [k s f w]    dense vs SPLS accuracy on the test set
  esact serve [n] [dense|spls] [replicas]
                              run the serving loop over n synthetic requests
                              on a replicated worker tier (default 1)
  esact serve [dense|spls] [replicas] --http <addr> [--max-conns N]
                 [--max-queue Q]
                              expose the replicated tier over HTTP/1.1 on a
                              single-threaded epoll event loop: POST
                              /v1/classify, POST /v1/generate (chunked
                              streaming), GET /metrics, GET /healthz; drain
                              with POST /admin/shutdown. --max-conns bounds
                              concurrent sockets (default 1024), not threads
  esact http-check <addr> [--shutdown] [--idle-churn N] [--chaos N]
                              probe a running gateway end to end (healthz,
                              classify, generate stream, metrics); with
                              --idle-churn N, hold N idle keep-alive
                              connections and churn them while probing; with
                              --chaos N, fire N classify requests at a
                              gateway launched under ESACT_FAULT_* knobs and
                              assert the tier survives (nonzero respawns,
                              typed replica_fault answers only); with
                              --shutdown, drain it afterwards
  esact generate [n] [dense|spls] [replicas] [--kv-budget B] [--prefix P]
                 [--new T] [--sample-topk K] [--seed S]
                              stream T tokens for each of n generation
                              sessions through the decode tier (spls =
                              incremental-SPLS gating + KV eviction at
                              budget B; greedy unless --sample-topk)
  esact sim <model> <L>       simulate one model (bert-base|bert-large|gpt2|
                               llama2|bloom|vit16|vit32)
  esact cluster <model> <L> <batch>  simulate the 125-unit deployment
  esact help

Artifacts are read from ./artifacts (run `make artifacts` first).";

fn artifact_dir() -> PathBuf {
    // ./artifacts, $ESACT_ARTIFACTS, or <crate>/artifacts — so the
    // binary works from the workspace root and from rust/ alike
    esact::util::artifacts_dir()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("repro") => repro(args.get(1).map(String::as_str).unwrap_or("all")),
        Some("eval") => eval(&args[1..]),
        Some("serve") => serve(&args[1..]),
        Some("http-check") => http_check(&args[1..]),
        Some("generate") => generate(&args[1..]),
        Some("sim") => sim(&args[1..]),
        Some("cluster") => cluster(&args[1..]),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn repro(id: &str) -> Result<()> {
    let dir = artifact_dir();
    // sweep sizes chosen so `repro all` completes in minutes
    let lim = 32;
    let all = [
        "fig1", "fig3", "fig4", "fig6", "fig7", "fig15", "fig16", "fig17", "fig18",
        "fig19", "fig20", "fig21", "table1", "table2", "table3", "table4",
    ];
    let ids: Vec<&str> = if id == "all" { all.to_vec() } else { vec![id] };
    for id in ids {
        let text = match id {
            "fig1" => figures::fig1(),
            "fig3" => figures::fig3(&dir)?,
            "fig4" => figures::fig4(&dir)?,
            "fig6" => figures::fig6(&dir)?,
            "fig7" => figures::fig7(),
            "fig15" => figures::fig15(),
            "fig16" => figures::fig16(&dir, lim)?,
            "fig17" => figures::fig17(&dir, lim)?,
            "fig18" => figures::fig18(&dir, lim)?,
            "fig19" => figures::fig19(&dir, lim)?,
            "fig20" => figures::fig20(),
            "fig21" => figures::fig21(),
            "table1" => tables::table1(),
            "table2" => tables::table2(),
            "table3" => tables::table3(),
            "table4" => tables::table4(),
            other => bail!("unknown experiment id {other}\n{USAGE}"),
        };
        println!("{text}\n{}", "=".repeat(72));
    }
    Ok(())
}

fn eval(args: &[String]) -> Result<()> {
    let dir = artifact_dir();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(128);
    let spls = SplsConfig {
        top_k: args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.12),
        sim_threshold: args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.6),
        ffn_threshold: args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2),
        window: args.get(4).and_then(|s| s.parse().ok()).unwrap_or(8),
    };
    let w = model::TinyWeights::load(&dir.join("tiny_weights.bin"))?;
    let set = model::TestSet::load(&dir.join("tiny_testset.bin"))?;
    let dense = model::eval_dense(&w, &set, n);
    let sparse = model::eval_sparse(&w, &set, n, &spls, QuantMethod::Hlog);
    println!("n = {n}, spls = {spls:?}");
    println!("dense  accuracy {:.4}", dense.accuracy);
    println!(
        "sparse accuracy {:.4} (loss {:+.2} pts) | sparsity: Q {:.3} KV {:.3} attn {:.3} FFN {:.3}",
        sparse.accuracy,
        sparse.loss_vs(&dense),
        sparse.q_sparsity,
        sparse.kv_sparsity,
        sparse.attn_sparsity,
        sparse.ffn_sparsity
    );
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    // positional [n] [dense|spls] [replicas]; flags anywhere
    let mut pos: Vec<&String> = Vec::new();
    let mut http: Option<String> = None;
    let mut max_conns = 1024usize; // concurrent sockets on the event loop
    let mut max_queue: Option<usize> = None;
    let mut i = 0usize;
    while i < args.len() {
        let value = |j: usize| args.get(j + 1).map(String::as_str);
        match args[i].as_str() {
            "--http" => {
                http = value(i).map(String::from);
                i += 2;
            }
            "--max-conns" => {
                max_conns = value(i).and_then(|s| s.parse().ok()).unwrap_or(1024);
                i += 2;
            }
            "--max-queue" => {
                max_queue = value(i).and_then(|s| s.parse().ok());
                i += 2;
            }
            _ => {
                pos.push(&args[i]);
                i += 1;
            }
        }
    }
    let mode = if pos.iter().any(|s| s.as_str() == "spls") { Mode::Spls } else { Mode::Dense };
    let nums: Vec<usize> = pos.iter().filter_map(|s| s.parse().ok()).collect();
    // deterministic chaos knobs (ESACT_FAULT_SEED / ESACT_FAULT_RATE /
    // ESACT_FAULT_EVERY); unset ⇒ None ⇒ injection fully off
    let fault_plan = FaultPlan::from_env();
    let build_server = |mode| -> Result<Server> {
        match fault_plan.clone() {
            Some(plan) => {
                eprintln!("fault injection armed: {plan:?}");
                Server::with_fault_plan(&artifact_dir(), mode, SplsConfig::default(), plan)
            }
            None => Server::new(&artifact_dir(), mode, SplsConfig::default()),
        }
    };
    if let Some(addr) = http {
        // network mode: numbers are [replicas] (no request count — the
        // gateway serves until drained)
        let replicas = nums.first().copied().unwrap_or(1).max(1);
        let mut policy = BatchPolicy::default();
        if let Some(q) = max_queue {
            policy.max_queue = q.max(1);
        }
        let cfg = GatewayConfig::builder()
            .addr(addr)
            .max_conns(max_conns)
            .replicas(replicas)
            .mode(mode)
            .policy(policy)
            .build()?;
        let srv = std::sync::Arc::new(build_server(mode)?);
        let gateway = Gateway::start(srv, cfg)?;
        println!("esact gateway listening on http://{}", gateway.local_addr());
        println!("  POST /v1/classify   POST /v1/generate (chunked stream)");
        println!("  GET  /healthz       GET  /metrics");
        println!("  POST /admin/shutdown drains and exits");
        let report = gateway.join()?;
        print!("{report}");
        return Ok(());
    }
    let n = nums.first().copied().unwrap_or(64);
    let replicas = nums.get(1).copied().unwrap_or(1).max(1);
    let srv = build_server(mode)?;
    let (tx, rx) = mpsc::channel();
    let (rtx, rrx) = mpsc::channel();
    let seq_len = srv.seq_len();
    let producer = std::thread::spawn(move || {
        let mut rng = Xoshiro256pp::new(2024);
        for i in 0..n {
            let (toks, _) = model::synth::gen_example(&mut rng, seq_len);
            tx.send(Request { id: i as u64, tokens: toks, arrived: Instant::now() })
                .unwrap();
        }
    });
    let drain = std::thread::spawn(move || rrx.iter().count());
    let outcome = srv.serve_replicated(rx, rtx, BatchPolicy::default(), replicas)?;
    producer.join().unwrap();
    let replies = drain.join().unwrap();
    println!("mode {mode:?} x{replicas}: {replies}/{n} replies");
    // the Display rows are exactly what a gateway's /metrics exports
    // (one source of truth — see coordinator::server::MetricRow)
    print!("{outcome}");
    Ok(())
}

/// Probe a running gateway end to end with the blocking HTTP client:
/// healthz → classify → generate stream → metrics (and optionally a
/// graceful drain). Exits non-zero on any failed check — this is what
/// CI's gateway smoke job runs.
fn http_check(args: &[String]) -> Result<()> {
    let addr = match args.first() {
        Some(a) if !a.starts_with("--") => a.clone(),
        _ => bail!("usage: esact http-check <addr> [--shutdown] [--idle-churn N] [--chaos N]"),
    };
    let shutdown = args.iter().any(|a| a == "--shutdown");
    let flag_n = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(0)
    };
    let idle_churn = flag_n("--idle-churn");
    let chaos = flag_n("--chaos");
    let mut client =
        HttpClient::connect_retry(&addr, 50, std::time::Duration::from_millis(100))?;

    // 0. optionally park a herd of idle keep-alive connections on the
    // event loop; the functional probes below must still pass while
    // they are held, and every held socket must remain usable after
    // churning half of them (CI's 256-connection idle-churn probe)
    let mut herd = if idle_churn > 0 {
        let mut herd = IdleConns::open(&addr, idle_churn)?;
        herd.churn(idle_churn / 2)?;
        println!(
            "idle-churn: holding {} idle connections (churned {})",
            herd.len(),
            idle_churn / 2
        );
        Some(herd)
    } else {
        None
    };

    // 1. healthz: must be ok, and tells us the request shapes
    let health = client.get("/healthz")?;
    if health.status != 200 {
        bail!("healthz returned {}", health.status);
    }
    let doc = health.json()?;
    let seq_len = doc.get("seq_len").and_then(|v| v.as_usize()).unwrap_or(64);
    let vocab = doc.get("vocab").and_then(|v| v.as_usize()).unwrap_or(64);
    let n_classes = doc.get("n_classes").and_then(|v| v.as_usize()).unwrap_or(16);
    println!("healthz ok: L={seq_len} vocab={vocab} classes={n_classes}");

    // 2. classify: a synthetic batch of 2
    let seqs: Vec<Vec<i32>> = (0..2)
        .map(|s| (0..seq_len).map(|i| ((i * 7 + s * 3) % vocab) as i32).collect())
        .collect();
    let reply = client.post_json("/v1/classify", &classify_body(&[&seqs[0][..], &seqs[1][..]]))?;
    if reply.status != 200 {
        bail!("classify returned {}: {}", reply.status, String::from_utf8_lossy(&reply.body));
    }
    let logits = reply.json()?;
    let rows = logits.get("logits").and_then(|l| l.as_arr().map(|a| a.len())).unwrap_or(0);
    if rows != 2 {
        bail!("classify returned {rows} logit rows, wanted 2");
    }
    println!("classify ok: 2 sequences -> 2 x {n_classes} logits");

    // 3. generate: stream a short greedy continuation
    let prompt: Vec<i32> = seqs[0][..8.min(seq_len)].to_vec();
    let stream = client.generate_stream(&generate_body(&prompt, 6, None))?;
    let result = stream.collect()?;
    if result.tokens.len() != 6 {
        bail!("generate streamed {} tokens, wanted 6", result.tokens.len());
    }
    println!(
        "generate ok: 6 tokens in {} chunks (ttft {:.1} ms)",
        result.chunks,
        result.ttft.map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0)
    );

    // 4. metrics: the tier rows must be present
    let metrics = client.get("/metrics")?;
    let text = String::from_utf8_lossy(&metrics.body).to_string();
    for needle in
        ["esact_serve_requests_total", "esact_generate_tokens_total", "esact_gateway_state"]
    {
        if !text.contains(needle) {
            bail!("metrics missing {needle}");
        }
    }
    println!("metrics ok: {} lines", text.lines().count());

    // 4b. parse the full exposition with the in-repo parser: every
    // sample name must be Prometheus-legal and covered by a # TYPE,
    // and all eight per-lane latency histograms must be well-formed
    let scrape = prom::parse(&text)
        .map_err(|e| anyhow::anyhow!("/metrics is not valid exposition: {e}"))?;
    for s in &scrape.samples {
        if !prom::valid_metric_name(&s.name) {
            bail!("metrics sample has an illegal name: {:?}", s.name);
        }
        if scrape.type_of(&s.name).is_none() {
            bail!("metrics sample {} has no # TYPE declaration", s.name);
        }
    }
    for lane in ["classify", "generate"] {
        for stem in ["latency", "queue_wait", "execute", "ttft"] {
            let name = format!("esact_{lane}_{stem}_seconds");
            let h = scrape
                .histogram(&name)
                .ok_or_else(|| anyhow::anyhow!("metrics missing histogram {name}"))?;
            if !h.is_well_formed() {
                bail!("histogram {name} is malformed (non-monotone or unclosed buckets)");
            }
        }
    }
    // faulted replies are never observed, so the classify histogram
    // count must reconcile exactly with requests served
    let served = scrape.value("esact_serve_requests_total").unwrap_or(-1.0);
    let lat = scrape.histogram("esact_classify_latency_seconds").expect("checked above");
    if lat.count as f64 != served {
        bail!("classify histogram count {} != serve_requests_total {served}", lat.count);
    }
    println!("exposition ok: {} samples, 8 histograms well-formed", scrape.samples.len());

    // 4c. /debug/trace: the spans for the probes above must be there
    // with monotone stage timestamps (faulted spans are fine — the
    // chaos job launches the gateway with fault injection armed)
    let tr = client.get("/debug/trace?n=8")?;
    if tr.status != 200 {
        bail!("/debug/trace returned {}", tr.status);
    }
    let doc = tr.json()?;
    let completed = doc.get("completed").and_then(|v| v.as_usize()).unwrap_or(0);
    let spans = doc.get("spans").and_then(|s| s.as_arr()).map(<[_]>::to_vec).unwrap_or_default();
    if completed < 3 || spans.is_empty() {
        bail!("/debug/trace shows {completed} completed spans ({} returned)", spans.len());
    }
    for span in &spans {
        let stages = span.get("stages").ok_or_else(|| anyhow::anyhow!("span without stages"))?;
        let ts: Vec<usize> = ["admitted", "queued", "dispatched", "exec_start"]
            .iter()
            .filter_map(|s| stages.get(s).and_then(|v| v.as_usize()))
            .collect();
        if ts.windows(2).any(|w| w[0] > w[1]) {
            bail!("span stages out of order: {ts:?}");
        }
    }
    println!("trace ok: {completed} spans completed, {} returned", spans.len());

    if let Some(mut herd) = herd.take() {
        let ok = herd.probe_all()?;
        if ok != idle_churn {
            bail!("idle-churn: only {ok}/{idle_churn} held connections answered healthz");
        }
        println!("idle-churn ok: {ok}/{idle_churn} held connections still serve requests");
    }

    // 5. chaos probe (CI's chaos-smoke job): the gateway was launched
    // with ESACT_FAULT_* knobs armed, so a burst of classify requests
    // must trip injected replica panics. Every request must still get
    // an HTTP answer (200, or a typed 500 `replica_fault` once a batch
    // exhausts its retry budget), the tier must keep serving, and the
    // supervisor's respawn counter must show the recoveries.
    if chaos > 0 {
        let (mut ok200, mut faulted) = (0usize, 0usize);
        for i in 0..chaos {
            let seq: Vec<i32> =
                (0..seq_len).map(|j| ((j * 11 + i * 5) % vocab) as i32).collect();
            let r = client.post_json("/v1/classify", &classify_body(&[&seq[..]]))?;
            match r.status {
                200 => ok200 += 1,
                500 => {
                    let Some(env) = r.error_envelope() else {
                        bail!("chaos: 500 without an error envelope");
                    };
                    if env.code != "replica_fault" {
                        bail!("chaos: 500 carried code {:?}, wanted replica_fault", env.code);
                    }
                    faulted += 1;
                }
                other => bail!("chaos: classify returned {other}"),
            }
        }
        let health = client.get("/healthz")?;
        if health.status != 200 {
            bail!("chaos: healthz returned {} after the fault burst", health.status);
        }
        let metrics = String::from_utf8_lossy(&client.get("/metrics")?.body).to_string();
        let counter = |name: &str| -> f64 {
            metrics
                .lines()
                .find(|l| l.starts_with(name))
                .and_then(|l| l.split_whitespace().last())
                .and_then(|v| v.parse().ok())
                .unwrap_or(-1.0)
        };
        let respawns = counter("esact_replica_respawns_total");
        if respawns <= 0.0 {
            bail!("chaos: expected injected faults to force respawns, counter = {respawns}");
        }
        let retried = counter("esact_jobs_retried_total");
        let job_faults = counter("esact_jobs_faulted_total");
        if retried + job_faults <= 0.0 {
            bail!("chaos: no retries or terminal faults recorded (retried={retried}, faulted={job_faults})");
        }
        println!(
            "chaos ok: {ok200}/{chaos} served, {faulted} typed replica_fault answers, \
             respawns={respawns} retried={retried} faulted={job_faults}"
        );
    }

    if shutdown {
        let r = client.post_json("/admin/shutdown", "")?;
        if r.status != 200 {
            bail!("shutdown returned {}", r.status);
        }
        println!("shutdown ok: gateway draining");
    }
    println!("http-check: all endpoints healthy");
    Ok(())
}

fn generate(args: &[String]) -> Result<()> {
    // positional: [n_sessions] [dense|spls] [replicas]; flags anywhere
    let mut pos: Vec<&String> = Vec::new();
    let mut kv_budget = usize::MAX;
    let mut prefix = 16usize;
    let mut max_new = 24usize;
    let mut sample_topk = 0usize;
    let mut seed = 7u64;
    let mut i = 0usize;
    while i < args.len() {
        let value = |j: usize| args.get(j + 1).map(String::as_str);
        match args[i].as_str() {
            "--kv-budget" => {
                kv_budget = value(i).and_then(|s| s.parse().ok()).unwrap_or(usize::MAX);
                i += 2;
            }
            "--prefix" => {
                prefix = value(i).and_then(|s| s.parse().ok()).unwrap_or(16);
                i += 2;
            }
            "--new" => {
                max_new = value(i).and_then(|s| s.parse().ok()).unwrap_or(24);
                i += 2;
            }
            "--sample-topk" => {
                sample_topk = value(i).and_then(|s| s.parse().ok()).unwrap_or(0);
                i += 2;
            }
            "--seed" => {
                seed = value(i).and_then(|s| s.parse().ok()).unwrap_or(7);
                i += 2;
            }
            _ => {
                pos.push(&args[i]);
                i += 1;
            }
        }
    }
    let n: usize = pos.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let mode = match pos.get(1).map(|s| s.as_str()) {
        Some("spls") => DecodeMode::Spls,
        Some("dense") => DecodeMode::Dense, // explicit: sliding window under a budget
        // like examples/generate_tiny: a finite budget implies the
        // SPLS-scored evicting path unless dense is asked for
        _ if kv_budget != usize::MAX => DecodeMode::Spls,
        _ => DecodeMode::Dense,
    };
    let replicas: usize = pos.get(2).and_then(|s| s.parse().ok()).unwrap_or(1);
    if kv_budget != usize::MAX {
        kv_budget = kv_budget.max(2); // finite budgets need ≥ 2 slots
    }
    let decode = DecodeConfig { mode, kv_budget, recent: 4, spls: SplsConfig::default() };

    let srv = Server::new(&artifact_dir(), Mode::Dense, SplsConfig::default())?;
    let (tx, rx) = std::sync::mpsc::channel();
    let (ctx, crx) = std::sync::mpsc::channel();
    let mut rng = Xoshiro256pp::new(2025);
    for i in 0..n {
        // prompts longer than the synthetic sequence cycle it (decode
        // clamps positions past the trained table)
        let (base, _) = model::synth::gen_example(&mut rng, srv.seq_len());
        let prompt: Vec<i32> = (0..prefix.max(1)).map(|j| base[j % base.len()]).collect();
        let sampling = if sample_topk > 0 {
            Sampling::TopK { k: sample_topk, temperature: 1.0, seed: seed + i as u64 }
        } else {
            Sampling::Greedy
        };
        tx.send(GenRequest {
            id: i as u64,
            prompt,
            prefix: None,
            max_new,
            sampling,
            arrived: Instant::now(),
        })
        .unwrap();
    }
    drop(tx);
    let printer = std::thread::spawn(move || {
        let mut tokens = 0usize;
        for chunk in crx.iter() {
            tokens += chunk.tokens.len();
            if !chunk.tokens.is_empty() || chunk.done {
                println!(
                    "  session {} +{:<3} {:?}{}",
                    chunk.id,
                    chunk.tokens.len(),
                    chunk.tokens,
                    if chunk.done { "  ✓ done" } else { "" }
                );
            }
        }
        tokens
    });
    let outcome = srv.serve_generate(rx, ctx, decode, replicas, 8)?;
    let streamed = printer.join().unwrap();
    let m = outcome.metrics;
    println!(
        "{mode:?} x{replicas} (budget {}): {} sessions, {streamed} tokens | \
         {:.0} tok/s | {} slices ({} stolen) | session p50 {:.1} ms p99 {:.1} ms | \
         step cache {:.0}% hit",
        if kv_budget == usize::MAX { "∞".to_string() } else { kv_budget.to_string() },
        m.sessions,
        m.tokens_per_sec(),
        m.slices,
        m.steals,
        m.p50_session.as_secs_f64() * 1e3,
        m.p99_session.as_secs_f64() * 1e3,
        m.plan_cache.step_hit_rate() * 100.0
    );
    // the same rows a gateway's /metrics would export for this tier
    print!("{outcome}");
    Ok(())
}

fn cluster(args: &[String]) -> Result<()> {
    use esact::config as c;
    let name = args.first().map(String::as_str).unwrap_or("bert-base");
    let l: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(32);
    let cfg = match name {
        "bert-base" => c::bert_base(l),
        "bert-large" => c::bert_large(l),
        "gpt2" => c::gpt2(l),
        "llama2" => c::llama2_7b(l),
        "bloom" => c::bloom_7b(l),
        "vit16" => c::vit_b16(),
        "vit32" => c::vit_b32(),
        other => bail!("unknown model {other}"),
    };
    let hw = esact::config::HardwareConfig::default();
    let spls = SplsConfig::default();
    let dep = esact::config::DeployConfig::default();
    let profile =
        esact::workloads::bench26::SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 };
    println!(
        "{} L={} batch={} on {} units / {} clusters:",
        cfg.name, cfg.seq_len, batch, dep.n_units, dep.n_clusters
    );
    for (label, feat) in [
        ("dense", esact::sim::Features::DENSE),
        ("full ESACT", esact::sim::Features::FULL),
    ] {
        let (cr, unit) = esact::sim::simulate_cluster(&cfg, &hw, &spls, &profile, &dep, batch, feat);
        println!(
            "  {label:<11} batch {:.3} ms | {:.0} seq/s | cluster util {:.3} | unit util {:.3}",
            cr.batch_seconds * 1e3,
            cr.throughput_seq_s,
            cr.cluster_utilization,
            unit.pe_utilization(&hw)
        );
    }
    Ok(())
}

fn sim(args: &[String]) -> Result<()> {
    use esact::config as c;
    let name = args.first().map(String::as_str).unwrap_or("bert-base");
    let l: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let cfg = match name {
        "bert-base" => c::bert_base(l),
        "bert-large" => c::bert_large(l),
        "gpt2" => c::gpt2(l),
        "llama2" => c::llama2_7b(l),
        "bloom" => c::bloom_7b(l),
        "vit16" => c::vit_b16(),
        "vit32" => c::vit_b32(),
        other => bail!("unknown model {other}"),
    };
    let hw = esact::config::HardwareConfig::default();
    let spls = SplsConfig::default();
    let profile = esact::workloads::bench26::SparsityProfile { q: 0.6, kv: 0.6, attn: 0.946, ffn: 0.5 };
    println!("{} L={}: mechanism ablation", cfg.name, cfg.seq_len);
    let labels = ["dense ASIC", "+SPLS", "+progressive", "+dynalloc"];
    let results = esact::sim::ablation(&cfg, &hw, &spls, &profile);
    let base = results[0].seconds(&hw);
    for (label, r) in labels.iter().zip(&results) {
        println!(
            "  {label:<13} {:>10} cycles  {:>8.3} ms  {:.2}× | util {:.3} | {:.2} TOPS/W | peak BW {:.2} GB/s",
            r.cycles,
            r.seconds(&hw) * 1e3,
            base / r.seconds(&hw),
            r.pe_utilization(&hw),
            r.tops_per_watt(&hw),
            r.peak_bw / 1e9
        );
    }
    println!("\n  per-layer stage breakdown (full features, cycles):");
    let b = esact::sim::layer_breakdown(&cfg, &hw, &spls, &profile, esact::sim::Features::FULL);
    for (stage, cyc) in [
        ("QKV generation", b.qkv_gen),
        ("attention", b.attention),
        ("output proj", b.out_proj),
        ("FFN", b.ffn),
        ("functional", b.functional),
        ("prediction*", b.prediction),
    ] {
        println!("    {stage:<15} {cyc:>10}");
    }
    println!("    (* overlapped by the progressive scheme)");
    Ok(())
}
