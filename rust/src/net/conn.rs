//! Per-connection state machine for the event-loop gateway, written
//! sans-io: every method takes `impl Read` / `impl Write` so the exact
//! transitions are unit-testable with scripted fakes (partial reads at
//! every split boundary, `WouldBlock` writers, mid-stream disconnects)
//! and the production loop just passes the nonblocking `TcpStream`.
//!
//! ```text
//!   Reading ──complete request──► Dispatched ──response enqueued──► Writing
//!      ▲                              │  (job parked on the tier;        │
//!      │                              │   stream chunks append here)     │
//!      │                              ▼                                  ▼
//!   KeepAlive ◄────── out-buffer drained, keep-alive ────────────── (drained)
//!      │                                                                 │
//!      └──── idle expiry / parse error / peer close ──► Closing ◄── !keep-alive
//! ```
//!
//! The connection owns the incremental [`RequestParser`] and a
//! cursor-tracked out-buffer; the gateway owns routing, admission and
//! completion bookkeeping. Idle time is measured from the last
//! *completed* request (connect time for a fresh socket), so a peer
//! trickling header bytes forever — the slow-loris shape — is reaped
//! by the same expiry as a silent one.
//!
//! Tracing: the `Reading → Dispatched` transition (a complete request
//! taken off the socket) is the moment the gateway stamps a span's
//! `accepted` stage; this state machine stays clock- and span-free by
//! design (sans-io), so the gateway backdates stages onto the span ids
//! the tier mints at submit (`crate::obs::span`).

use std::io::{self, Read, Write};
use std::time::{Duration, Instant};

use crate::net::http::{HttpError, Request, RequestParser};

/// Where the connection sits in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnState {
    /// Waiting for (more of) a request head/body.
    Reading,
    /// A request is in flight on the serving tier; reads are parked.
    Dispatched,
    /// A response (or stream tail) is buffered and being flushed.
    Writing,
    /// Response fully flushed; waiting for the next request.
    KeepAlive,
    /// Tear the socket down once the out-buffer drains.
    Closing,
}

/// What a flush attempt achieved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushOutcome {
    /// Out-buffer fully written.
    Drained,
    /// The socket refused more bytes (`WouldBlock`); write interest
    /// should stay armed.
    Blocked,
}

/// Cap on bytes consumed from the socket per `on_readable` call so one
/// firehose connection cannot starve the rest of the loop; epoll is
/// level-triggered, the remainder re-reports immediately.
const READ_QUANTUM: usize = 64 * 1024;

pub struct Conn {
    parser: RequestParser,
    out: Vec<u8>,
    out_pos: usize,
    state: ConnState,
    close_after_flush: bool,
    last_activity: Instant,
}

impl Conn {
    pub fn new(max_body: usize, now: Instant) -> Self {
        Conn {
            parser: RequestParser::new(max_body),
            out: Vec::new(),
            out_pos: 0,
            state: ConnState::Reading,
            close_after_flush: false,
            last_activity: now,
        }
    }

    pub fn state(&self) -> ConnState {
        self.state
    }

    /// Unflushed response bytes still queued.
    pub fn pending_out(&self) -> usize {
        self.out.len() - self.out_pos
    }

    pub fn wants_write(&self) -> bool {
        self.pending_out() > 0
    }

    /// Whether the loop should keep read interest armed: parked
    /// (`Dispatched`) and dying (`Closing`) connections don't read.
    pub fn wants_read(&self) -> bool {
        matches!(
            self.state,
            ConnState::Reading | ConnState::KeepAlive | ConnState::Writing
        )
    }

    /// Pull bytes from the socket into the parser. Returns `Ok(true)`
    /// if the peer half-closed (EOF), `Ok(false)` on `WouldBlock` or a
    /// filled read quantum. Hard socket errors bubble up for the loop
    /// to close on.
    pub fn on_readable(&mut self, io: &mut impl Read) -> io::Result<bool> {
        let mut buf = [0u8; 4096];
        let mut total = 0;
        loop {
            match io.read(&mut buf) {
                Ok(0) => return Ok(true),
                Ok(n) => {
                    self.parser.push(&buf[..n]);
                    total += n;
                    if total >= READ_QUANTUM {
                        return Ok(false);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    /// Try to take the next complete pipelined request. A successful
    /// take marks activity (idle expiry measures from here) and moves
    /// `Reading`/`KeepAlive` → `Dispatched`; the caller decides what
    /// the dispatch is (tier submit or an immediate local response).
    pub fn next_request(&mut self, now: Instant) -> Result<Option<Request>, HttpError> {
        if self.state != ConnState::Reading && self.state != ConnState::KeepAlive {
            return Ok(None);
        }
        match self.parser.take()? {
            Some(req) => {
                self.last_activity = now;
                self.state = ConnState::Dispatched;
                Ok(Some(req))
            }
            None => {
                if self.state == ConnState::KeepAlive && self.parser.buffered() > 0 {
                    self.state = ConnState::Reading;
                }
                Ok(None)
            }
        }
    }

    /// Append rendered response bytes (a full frame, a stream head, or
    /// one chunk) to the out-buffer.
    pub fn enqueue(&mut self, bytes: &[u8]) {
        self.out.extend_from_slice(bytes);
    }

    /// The current exchange produced its final bytes: leave
    /// `Dispatched`. `keep == false` tears the connection down once
    /// the out-buffer drains.
    pub fn complete(&mut self, keep: bool) {
        if !keep {
            self.close_after_flush = true;
        }
        self.state = if self.wants_write() {
            ConnState::Writing
        } else if self.close_after_flush {
            ConnState::Closing
        } else {
            ConnState::KeepAlive
        };
    }

    /// Force the connection towards teardown (parse error already
    /// answered, drain, idle expiry). Pending out-bytes still flush
    /// first unless the caller drops the socket outright.
    pub fn mark_closing(&mut self) {
        self.close_after_flush = true;
        if !self.wants_write() {
            self.state = ConnState::Closing;
        }
    }

    /// Flush as much of the out-buffer as the socket accepts.
    pub fn on_writable(&mut self, io: &mut impl Write) -> io::Result<FlushOutcome> {
        while self.out_pos < self.out.len() {
            match io.write(&self.out[self.out_pos..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    self.compact();
                    return Ok(FlushOutcome::Blocked);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.out.clear();
        self.out_pos = 0;
        if self.state == ConnState::Writing {
            self.state = if self.close_after_flush {
                ConnState::Closing
            } else {
                ConnState::KeepAlive
            };
        } else if self.close_after_flush && self.state != ConnState::Dispatched {
            self.state = ConnState::Closing;
        }
        Ok(FlushOutcome::Drained)
    }

    /// True once the socket should be dropped: marked closing and
    /// nothing left to flush.
    pub fn done(&self) -> bool {
        self.state == ConnState::Closing && !self.wants_write()
    }

    /// Idle expiry — never fires while a job is in flight
    /// (`Dispatched` resets on completion via `next_request`'s
    /// activity stamp on the *next* exchange; stream deadlines are the
    /// gateway's job). A write-stalled peer counts as idle too.
    pub fn idle_expired(&self, now: Instant, timeout: Duration) -> bool {
        self.state != ConnState::Dispatched
            && now.duration_since(self.last_activity) > timeout
    }

    /// Bytes buffered inside the parser (a partially received next
    /// request). Used by drain logic: a keep-alive socket with nothing
    /// buffered can close immediately, one mid-request gets its read.
    pub fn buffered(&self) -> usize {
        self.parser.buffered()
    }

    /// Reclaim out-buffer space once a flush consumed a meaningful
    /// prefix (long generate streams on slow readers would otherwise
    /// grow the buffer by the full stream length).
    fn compact(&mut self) {
        if self.out_pos >= 8 * 1024 && self.out_pos * 2 >= self.out.len() {
            self.out.drain(..self.out_pos);
            self.out_pos = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    /// Scripted reader: returns each slice in turn, then WouldBlock
    /// (or EOF if `eof` is set and the script is exhausted).
    struct ScriptRead {
        script: VecDeque<Vec<u8>>,
        eof: bool,
    }

    impl ScriptRead {
        fn new(parts: Vec<Vec<u8>>, eof: bool) -> Self {
            ScriptRead {
                script: parts.into(),
                eof,
            }
        }
    }

    impl Read for ScriptRead {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.script.pop_front() {
                Some(part) => {
                    assert!(part.len() <= buf.len(), "script chunk exceeds read buf");
                    buf[..part.len()].copy_from_slice(&part);
                    Ok(part.len())
                }
                None if self.eof => Ok(0),
                None => Err(io::Error::from(io::ErrorKind::WouldBlock)),
            }
        }
    }

    /// Writer that accepts at most `quota` bytes per call, then
    /// WouldBlock — a tiny socket send buffer.
    struct TrickleWrite {
        accepted: Vec<u8>,
        quota: usize,
        calls_until_block: usize,
    }

    impl TrickleWrite {
        fn new(quota: usize) -> Self {
            TrickleWrite {
                accepted: Vec::new(),
                quota,
                calls_until_block: 1,
            }
        }
    }

    impl Write for TrickleWrite {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.calls_until_block == 0 {
                return Err(io::Error::from(io::ErrorKind::WouldBlock));
            }
            self.calls_until_block -= 1;
            let n = buf.len().min(self.quota);
            self.accepted.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    const REQ: &[u8] = b"POST /v1/classify HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";

    #[test]
    fn every_split_boundary_yields_the_same_request() {
        // the http.rs property harness, driven through the state
        // machine's read path instead of the parser directly
        for cut in 0..=REQ.len() {
            let now = Instant::now();
            let mut conn = Conn::new(1 << 20, now);
            // empty slices would read as Ok(0) = EOF; keep them out
            let first: Vec<Vec<u8>> = [&REQ[..cut]]
                .iter()
                .filter(|p| !p.is_empty())
                .map(|p| p.to_vec())
                .collect();
            let mut io = ScriptRead::new(first, false);
            assert!(!conn.on_readable(&mut io).unwrap());
            let early = conn.next_request(now).unwrap();
            if cut < REQ.len() {
                assert!(early.is_none(), "cut={cut} produced a request early");
                assert_eq!(conn.state(), ConnState::Reading);
            }
            let rest_parts: Vec<Vec<u8>> = [&REQ[cut..]]
                .iter()
                .filter(|p| !p.is_empty())
                .map(|p| p.to_vec())
                .collect();
            let mut rest = ScriptRead::new(rest_parts, false);
            assert!(!conn.on_readable(&mut rest).unwrap());
            let req = match early {
                Some(r) => r,
                None => conn.next_request(now).unwrap().expect("complete request"),
            };
            assert_eq!(req.method, "POST");
            assert_eq!(req.path(), "/v1/classify");
            assert_eq!(req.body, b"hello");
            assert_eq!(conn.state(), ConnState::Dispatched);
            // parked connections don't read
            assert!(!conn.wants_read());
            assert!(conn.next_request(now).unwrap().is_none());
        }
    }

    #[test]
    fn mid_stream_client_disconnect_is_surfaced() {
        let now = Instant::now();
        let mut conn = Conn::new(1 << 20, now);
        // half a request then EOF: the peer gave up mid-send
        let mut io = ScriptRead::new(vec![REQ[..10].to_vec()], true);
        assert!(conn.on_readable(&mut io).unwrap(), "EOF must be reported");
        // and a write onto a reset socket is a hard error
        conn.enqueue(b"leftover");
        conn.complete(true);
        struct Dead;
        impl Write for Dead {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                Err(io::Error::from(io::ErrorKind::BrokenPipe))
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        assert_eq!(
            conn.on_writable(&mut Dead).unwrap_err().kind(),
            io::ErrorKind::BrokenPipe
        );
    }

    #[test]
    fn idle_expiry_counts_from_last_completed_request() {
        let t0 = Instant::now();
        let timeout = Duration::from_millis(100);
        let mut conn = Conn::new(1 << 20, t0);
        // a fresh silent connection expires
        assert!(!conn.idle_expired(t0 + Duration::from_millis(50), timeout));
        assert!(conn.idle_expired(t0 + Duration::from_millis(150), timeout));

        // slow-loris: trickled header bytes do NOT reset the clock
        let mut io = ScriptRead::new(vec![b"POST /x HT".to_vec()], false);
        conn.on_readable(&mut io).unwrap();
        assert!(conn.next_request(t0 + Duration::from_millis(60)).unwrap().is_none());
        assert!(conn.idle_expired(t0 + Duration::from_millis(150), timeout));

        // a completed request does
        let t1 = t0 + Duration::from_millis(140);
        let mut conn2 = Conn::new(1 << 20, t0);
        let mut io2 = ScriptRead::new(vec![REQ.to_vec()], false);
        conn2.on_readable(&mut io2).unwrap();
        assert!(conn2.next_request(t1).unwrap().is_some());
        conn2.complete(true);
        assert!(!conn2.idle_expired(t1 + Duration::from_millis(90), timeout));
        assert!(conn2.idle_expired(t1 + Duration::from_millis(110), timeout));

        // ... but never while the job is parked on the tier
        let mut conn3 = Conn::new(1 << 20, t0);
        let mut io3 = ScriptRead::new(vec![REQ.to_vec()], false);
        conn3.on_readable(&mut io3).unwrap();
        assert!(conn3.next_request(t0).unwrap().is_some());
        assert_eq!(conn3.state(), ConnState::Dispatched);
        assert!(!conn3.idle_expired(t0 + Duration::from_secs(3600), timeout));
    }

    #[test]
    fn write_backpressure_flushes_incrementally_and_honors_close() {
        let now = Instant::now();
        let mut conn = Conn::new(1 << 20, now);
        let mut io = ScriptRead::new(vec![REQ.to_vec()], false);
        conn.on_readable(&mut io).unwrap();
        conn.next_request(now).unwrap().unwrap();

        let body: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        conn.enqueue(&body);
        conn.complete(false); // Connection: close semantics
        assert_eq!(conn.state(), ConnState::Writing);

        let mut sink = TrickleWrite::new(64);
        let mut rounds = 0;
        while conn.wants_write() {
            sink.calls_until_block = 1;
            let out = conn.on_writable(&mut sink).unwrap();
            rounds += 1;
            if conn.wants_write() {
                assert_eq!(out, FlushOutcome::Blocked);
            }
            assert!(rounds < 100, "flush must make progress");
        }
        assert!(rounds > 10, "64-byte quota must take many rounds");
        assert_eq!(sink.accepted, body, "bytes arrive in order, none lost");
        assert_eq!(conn.state(), ConnState::Closing);
        assert!(conn.done());
    }

    #[test]
    fn keep_alive_round_trips_back_to_reading_for_pipelined_requests() {
        let now = Instant::now();
        let mut conn = Conn::new(1 << 20, now);
        let mut two = Vec::new();
        two.extend_from_slice(REQ);
        two.extend_from_slice(b"GET /healthz HTTP/1.1\r\n\r\n");
        let mut io = ScriptRead::new(vec![two], false);
        conn.on_readable(&mut io).unwrap();

        let first = conn.next_request(now).unwrap().unwrap();
        assert_eq!(first.method, "POST");
        conn.enqueue(b"HTTP/1.1 200 OK\r\nContent-Length: 0\r\n\r\n");
        conn.complete(true);
        let mut sink = TrickleWrite::new(usize::MAX);
        sink.calls_until_block = usize::MAX;
        assert_eq!(conn.on_writable(&mut sink).unwrap(), FlushOutcome::Drained);
        assert_eq!(conn.state(), ConnState::KeepAlive);

        // the second, already-buffered request dispatches without
        // another byte from the socket
        let second = conn.next_request(now).unwrap().unwrap();
        assert_eq!(second.path(), "/healthz");
        assert_eq!(conn.state(), ConnState::Dispatched);
        conn.complete(true);
        assert_eq!(conn.state(), ConnState::KeepAlive);
        assert!(conn.next_request(now).unwrap().is_none());
    }
}
