//! Blocking HTTP/1.1 client + HTTP load generator for the gateway —
//! the measurement half of the network subsystem (std-only, like the
//! server side). The client speaks exactly what the gateway serves:
//! keep-alive `Content-Length` exchanges and chunked generate streams.
//! The load generator reuses the serving tier's arrival schedules
//! (`coordinator::loadgen`) so HTTP benchmarks are directly comparable
//! to the in-process serving bench: closed-loop (per-connection
//! back-to-back) and open-loop Poisson drivers over a pool of request
//! bodies, reporting client-side latency percentiles and throughput.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::loadgen::{arrivals, Arrival};
use crate::net::http::{parse_response_head, ChunkDecoder, ChunkEvent, ResponseHead};
use crate::net::json::{self, Json};
use crate::util::rng::Xoshiro256pp;
use crate::util::stats;

/// One buffered HTTP response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body).context("response body is not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow::anyhow!("bad JSON in response: {e}"))
    }

    /// Decode the unified error envelope every non-2xx gateway
    /// response carries: `{"error":{"code","message","retry_after_ms"?}}`.
    /// `None` if the body is not an envelope (e.g. a 2xx response).
    pub fn error_envelope(&self) -> Option<ErrorEnvelope> {
        let doc = self.json().ok()?;
        let err = doc.get("error")?;
        Some(ErrorEnvelope {
            code: err.get("code")?.as_str()?.to_string(),
            message: err.get("message")?.as_str()?.to_string(),
            retry_after_ms: err
                .get("retry_after_ms")
                .and_then(|v| v.as_i64())
                .map(|v| v as u64),
        })
    }
}

/// The gateway's machine-readable error shape (see README §Error
/// codes). `retry_after_ms` is present on 429s only.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorEnvelope {
    pub code: String,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

/// A keep-alive connection to the gateway.
pub struct HttpClient {
    stream: TcpStream,
    /// Bytes read past the previous exchange (keep-alive pipelining).
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(Self { stream, buf: Vec::new() })
    }

    /// Connect with retries — the readiness probe for freshly spawned
    /// gateways (CI smoke, benches).
    pub fn connect_retry(addr: &str, attempts: usize, delay: Duration) -> Result<Self> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(client) => return Ok(client),
                Err(e) => last = Some(e),
            }
            std::thread::sleep(delay);
        }
        Err(last.expect("at least one attempt"))
    }

    pub fn get(&mut self, path: &str) -> Result<Response> {
        self.request("GET", path, None)
    }

    pub fn post_json(&mut self, path: &str, body: &str) -> Result<Response> {
        self.request("POST", path, Some(body.as_bytes()))
    }

    fn send_request(&mut self, method: &str, path: &str, body: Option<&[u8]>) -> Result<()> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: esact\r\n");
        if let Some(b) = body {
            head.push_str("Content-Type: application/json\r\n");
            head.push_str(&format!("Content-Length: {}\r\n", b.len()));
        }
        head.push_str("\r\n");
        self.stream.write_all(head.as_bytes())?;
        if let Some(b) = body {
            self.stream.write_all(b)?;
        }
        self.stream.flush()?;
        Ok(())
    }

    fn fill(&mut self) -> Result<()> {
        let mut tmp = [0u8; 8192];
        let n = self.stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed by the gateway");
        }
        self.buf.extend_from_slice(&tmp[..n]);
        Ok(())
    }

    fn read_head(&mut self) -> Result<ResponseHead> {
        loop {
            if let Some(end) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head = std::str::from_utf8(&self.buf[..end])
                    .context("response head is not UTF-8")?;
                let parsed = parse_response_head(head)?;
                self.buf.drain(..end + 4);
                return Ok(parsed);
            }
            if self.buf.len() > 64 * 1024 {
                bail!("response head too large");
            }
            self.fill()?;
        }
    }

    /// One full request/response exchange (chunked responses are
    /// buffered to completion; use [`HttpClient::generate_stream`] for
    /// incremental consumption).
    fn request(&mut self, method: &str, path: &str, body: Option<&[u8]>) -> Result<Response> {
        self.send_request(method, path, body)?;
        let head = self.read_head()?;
        let body = if head.is_chunked() {
            let mut dec = ChunkDecoder::new();
            dec.push(&std::mem::take(&mut self.buf));
            let mut out = Vec::new();
            loop {
                match dec.next_event()? {
                    ChunkEvent::Data(d) => out.extend_from_slice(&d),
                    ChunkEvent::End => {
                        self.buf = dec.leftover();
                        break;
                    }
                    ChunkEvent::Need => {
                        let mut tmp = [0u8; 8192];
                        let n = self.stream.read(&mut tmp)?;
                        if n == 0 {
                            bail!("stream truncated");
                        }
                        dec.push(&tmp[..n]);
                    }
                }
            }
            out
        } else {
            let n = head.content_length().unwrap_or(0);
            while self.buf.len() < n {
                self.fill()?;
            }
            self.buf.drain(..n).collect()
        };
        Ok(Response { status: head.status, headers: head.headers, body })
    }

    /// Open a `/v1/generate` stream. Errors if the gateway answered
    /// with a buffered (non-streaming) response — its status and body
    /// are in the error message.
    pub fn generate_stream(&mut self, body: &str) -> Result<GenStream<'_>> {
        self.send_request("POST", "/v1/generate", Some(body.as_bytes()))?;
        let started = Instant::now();
        let head = self.read_head()?;
        if !head.is_chunked() {
            let n = head.content_length().unwrap_or(0);
            while self.buf.len() < n {
                self.fill()?;
            }
            let body: Vec<u8> = self.buf.drain(..n).collect();
            bail!(
                "gateway refused the stream: {} {}",
                head.status,
                String::from_utf8_lossy(&body)
            );
        }
        let mut dec = ChunkDecoder::new();
        dec.push(&std::mem::take(&mut self.buf));
        Ok(GenStream { client: self, dec, started, done: false })
    }
}

/// An open generate stream; yields one decoded chunk line at a time.
pub struct GenStream<'a> {
    client: &'a mut HttpClient,
    dec: ChunkDecoder,
    started: Instant,
    done: bool,
}

/// What a fully-consumed stream produced.
#[derive(Debug)]
pub struct StreamResult {
    pub tokens: Vec<i32>,
    /// Time to first generated token (None if the stream was empty).
    pub ttft: Option<Duration>,
    pub chunks: usize,
    pub wall: Duration,
}

impl GenStream<'_> {
    /// Next `{"tokens": [...], "done": bool}` line, or `None` at end
    /// of stream. A server-reported error line becomes an `Err`.
    pub fn next_chunk(&mut self) -> Result<Option<(Vec<i32>, bool)>> {
        if self.done {
            return Ok(None);
        }
        loop {
            match self.dec.next_event()? {
                ChunkEvent::Data(d) => {
                    let text = std::str::from_utf8(&d).context("chunk is not UTF-8")?;
                    let doc = Json::parse(text.trim_end())
                        .map_err(|e| anyhow::anyhow!("bad chunk JSON: {e}"))?;
                    if let Some(err) = doc.get("error") {
                        // envelope object (current wire format); a bare
                        // string is accepted for older peers
                        if let Some(flat) = err.as_str() {
                            bail!("stream error from gateway: {flat}");
                        }
                        let code =
                            err.get("code").and_then(|c| c.as_str()).unwrap_or("error");
                        let message =
                            err.get("message").and_then(|m| m.as_str()).unwrap_or("");
                        bail!("stream error from gateway: {code}: {message}");
                    }
                    let tokens = doc
                        .get("tokens")
                        .and_then(json::to_i32_vec)
                        .context("chunk without tokens")?;
                    let done = doc.get("done").and_then(|d| d.as_bool()).unwrap_or(false);
                    return Ok(Some((tokens, done)));
                }
                ChunkEvent::End => {
                    self.client.buf = self.dec.leftover();
                    self.done = true;
                    return Ok(None);
                }
                ChunkEvent::Need => {
                    let mut tmp = [0u8; 8192];
                    let n = self.client.stream.read(&mut tmp)?;
                    if n == 0 {
                        bail!("stream truncated");
                    }
                    self.dec.push(&tmp[..n]);
                }
            }
        }
    }

    /// Drain the stream to completion.
    pub fn collect(mut self) -> Result<StreamResult> {
        let mut tokens = Vec::new();
        let mut ttft = None;
        let mut chunks = 0usize;
        while let Some((fresh, _done)) = self.next_chunk()? {
            chunks += 1;
            if !fresh.is_empty() && ttft.is_none() {
                ttft = Some(self.started.elapsed());
            }
            tokens.extend(fresh);
        }
        Ok(StreamResult { tokens, ttft, chunks, wall: self.started.elapsed() })
    }
}

/// Build a `/v1/classify` body for a batch of sequences.
pub fn classify_body(batch: &[&[i32]]) -> String {
    let mut body = String::from("{\"tokens\":[");
    for (i, seq) in batch.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&json::i32_array(seq));
    }
    body.push_str("]}");
    body
}

/// Build a `/v1/generate` body.
pub fn generate_body(prompt: &[i32], max_new: usize, top_k: Option<(usize, f32, u64)>) -> String {
    let mut body = format!("{{\"prompt\":{},\"max_new\":{max_new}", json::i32_array(prompt));
    if let Some((k, temperature, seed)) = top_k {
        body.push_str(&format!(",\"top_k\":{k},\"temperature\":{temperature},\"seed\":{seed}"));
    }
    body.push('}');
    body
}

/// Build a `/v1/generate` body with a declared shared prefix: the
/// session decodes `prefix ++ prompt` through the gateway's paged KV
/// pool, mapping the prefix's published blocks when another session
/// already prefilled it.
pub fn generate_body_with_prefix(
    prefix: &[i32],
    prompt: &[i32],
    max_new: usize,
    top_k: Option<(usize, f32, u64)>,
) -> String {
    let mut body = format!(
        "{{\"prefix\":{},\"prompt\":{},\"max_new\":{max_new}",
        json::i32_array(prefix),
        json::i32_array(prompt)
    );
    if let Some((k, temperature, seed)) = top_k {
        body.push_str(&format!(",\"top_k\":{k},\"temperature\":{temperature},\"seed\":{seed}"));
    }
    body.push('}');
    body
}

/// Shared-prefix generate workload: `sessions` request bodies drawn
/// round-robin from `prefixes` (K distinct shared prompt prefixes),
/// each with its own `tail_len`-token random tail — the multi-session
/// serving shape the paged KV pool's prefix trie is built for. Greedy
/// sampling so replayed workloads are bit-deterministic.
pub fn shared_prefix_bodies(
    prefixes: &[Vec<i32>],
    sessions: usize,
    tail_len: usize,
    max_new: usize,
    vocab: usize,
    seed: u64,
) -> Vec<String> {
    assert!(!prefixes.is_empty(), "need at least one shared prefix");
    assert!(tail_len >= 1, "every session needs a non-empty tail");
    let mut rng = Xoshiro256pp::new(seed);
    (0..sessions)
        .map(|i| {
            let prefix = &prefixes[i % prefixes.len()];
            let tail: Vec<i32> =
                (0..tail_len).map(|_| rng.below(vocab as u64) as i32).collect();
            generate_body_with_prefix(prefix, &tail, max_new, None)
        })
        .collect()
}

/// Aggregate results of one HTTP generate load run.
#[derive(Debug, Default)]
pub struct GenLoadReport {
    pub sessions: usize,
    pub ok: usize,
    /// 4xx/5xx refusals plus transport failures.
    pub errors: usize,
    /// Tokens streamed across all completed sessions.
    pub tokens: usize,
    pub wall: Duration,
    /// Time to first token of each OK session, seconds (sorted).
    pub ttft: Vec<f64>,
}

impl GenLoadReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.tokens as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn ttft_p50_ms(&self) -> f64 {
        if self.ttft.is_empty() {
            0.0
        } else {
            stats::percentile(&self.ttft, 0.50) * 1e3
        }
    }
}

/// Closed-loop generate load: `connections` keep-alive connections,
/// each opening back-to-back generate streams over `bodies` (claimed
/// in order, each exactly once) and draining every stream to the done
/// chunk. Pair with [`shared_prefix_bodies`] for the prefix-sharing
/// workload.
pub fn closed_loop_generate(
    addr: &str,
    connections: usize,
    bodies: &[String],
) -> Result<GenLoadReport> {
    assert!(!bodies.is_empty());
    let connections = connections.max(1);
    let issued = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    let workers: Vec<_> = (0..connections)
        .map(|_| {
            let issued = Arc::clone(&issued);
            let addr = addr.to_string();
            let bodies = bodies.to_vec();
            std::thread::spawn(move || -> Result<GenLoadReport> {
                let mut client =
                    HttpClient::connect_retry(&addr, 20, Duration::from_millis(50))?;
                let mut report = GenLoadReport::default();
                loop {
                    let i = issued.fetch_add(1, Ordering::Relaxed);
                    if i >= bodies.len() {
                        break;
                    }
                    report.sessions += 1;
                    let outcome = client
                        .generate_stream(&bodies[i])
                        .and_then(|stream| stream.collect());
                    match outcome {
                        Ok(res) => {
                            report.ok += 1;
                            report.tokens += res.tokens.len();
                            if let Some(t) = res.ttft {
                                report.ttft.push(t.as_secs_f64());
                            }
                        }
                        Err(_) => {
                            report.errors += 1;
                            // reconnect once; give up on repeat failure
                            client = HttpClient::connect_retry(
                                &addr,
                                5,
                                Duration::from_millis(50),
                            )?;
                        }
                    }
                }
                Ok(report)
            })
        })
        .collect();
    let mut merged = GenLoadReport::default();
    for w in workers {
        let r = w.join().expect("generate loadgen worker panicked")?;
        merged.sessions += r.sessions;
        merged.ok += r.ok;
        merged.errors += r.errors;
        merged.tokens += r.tokens;
        merged.ttft.extend(r.ttft);
    }
    merged.wall = start.elapsed();
    merged.ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(merged)
}

/// A herd of open-but-idle keep-alive connections — the C10K
/// connection-sweep bench and the CI idle-churn probe hold one of
/// these while foreground requests run, asserting the event loop's
/// per-idle-socket cost stays flat.
pub struct IdleConns {
    addr: String,
    conns: Vec<TcpStream>,
}

impl IdleConns {
    /// Open `n` idle connections to the gateway.
    pub fn open(addr: &str, n: usize) -> Result<Self> {
        let mut conns = Vec::with_capacity(n);
        for i in 0..n {
            let s = TcpStream::connect(addr)
                .with_context(|| format!("idle conn {i}/{n} to {addr}"))?;
            s.set_nodelay(true)?;
            conns.push(s);
        }
        Ok(Self { addr: addr.to_string(), conns })
    }

    pub fn len(&self) -> usize {
        self.conns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conns.is_empty()
    }

    /// Connection churn: close `k` sockets and open `k` fresh ones.
    pub fn churn(&mut self, k: usize) -> Result<()> {
        let k = k.min(self.conns.len());
        for s in self.conns.drain(..k) {
            drop(s);
        }
        for _ in 0..k {
            let s = TcpStream::connect(&self.addr)?;
            s.set_nodelay(true)?;
            self.conns.push(s);
        }
        Ok(())
    }

    /// Issue `GET /healthz` on every held connection and count the
    /// 200s — proves the idle herd is still individually usable, not
    /// just half-open. Consumes each response fully so the sockets
    /// stay clean for reuse.
    pub fn probe_all(&mut self) -> Result<usize> {
        let mut ok = 0usize;
        for s in &mut self.conns {
            s.set_read_timeout(Some(Duration::from_secs(5)))?;
            s.write_all(b"GET /healthz HTTP/1.1\r\nHost: esact\r\n\r\n")?;
            let mut buf = Vec::new();
            let mut tmp = [0u8; 2048];
            let head_end = loop {
                if let Some(end) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                    break end;
                }
                let n = s.read(&mut tmp).context("idle probe read")?;
                if n == 0 {
                    bail!("gateway closed an idle connection mid-probe");
                }
                buf.extend_from_slice(&tmp[..n]);
            };
            let head = std::str::from_utf8(&buf[..head_end]).context("probe head utf8")?;
            let parsed = parse_response_head(head)?;
            let body_len = parsed.content_length().unwrap_or(0);
            let mut have = buf.len() - (head_end + 4);
            while have < body_len {
                let n = s.read(&mut tmp).context("idle probe body read")?;
                if n == 0 {
                    bail!("gateway truncated an idle probe body");
                }
                have += n;
            }
            if parsed.status == 200 {
                ok += 1;
            }
        }
        Ok(ok)
    }
}

/// Open `n` slow-loris connections: each sends a partial request head
/// and then stalls forever. The gateway's idle sweep must reap every
/// one of them (`esact_gateway_conns_reaped_total`); hold the returned
/// sockets so the OS doesn't close them early.
pub fn open_lorises(addr: &str, n: usize) -> Result<Vec<TcpStream>> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut s = TcpStream::connect(addr)
            .with_context(|| format!("loris conn {i}/{n} to {addr}"))?;
        s.set_nodelay(true)?;
        s.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-Le")?;
        out.push(s);
    }
    Ok(out)
}

/// Fetch `/metrics` and return the value of one exact (unlabeled) row.
pub fn metric_value(client: &mut HttpClient, name: &str) -> Result<Option<f64>> {
    let resp = client.get("/metrics")?;
    let text = std::str::from_utf8(&resp.body).context("metrics body is not UTF-8")?;
    Ok(text
        .lines()
        .find(|l| l.strip_prefix(name).is_some_and(|rest| rest.starts_with(' ')))
        .and_then(|l| l.split_whitespace().last())
        .and_then(|v| v.parse().ok()))
}

/// Aggregate results of one HTTP load run.
#[derive(Debug, Default)]
pub struct LoadReport {
    pub sent: usize,
    pub ok: usize,
    /// 429 responses (admission shed).
    pub shed: usize,
    pub errors: usize,
    pub wall: Duration,
    /// Client-side latency of each OK request, seconds (sorted).
    pub latencies: Vec<f64>,
    /// Server-side median queue wait (ms), scraped from the gateway's
    /// classify histograms by [`LoadReport::scrape_stages`] — `None`
    /// until scraped. Lets bench cells assert *where* time went.
    pub queue_wait_p50_ms: Option<f64>,
    /// Server-side median execute time (ms); see `queue_wait_p50_ms`.
    pub execute_p50_ms: Option<f64>,
}

impl LoadReport {
    /// Fill the per-stage medians from the gateway's `/metrics`
    /// histograms (`esact_classify_queue_wait_seconds` /
    /// `esact_classify_execute_seconds`), parsed with the in-repo
    /// Prometheus text parser. Call once after the run completes so
    /// the scrape reflects every request this report counted.
    pub fn scrape_stages(&mut self, client: &mut HttpClient) -> Result<()> {
        let resp = client.get("/metrics")?;
        let text = std::str::from_utf8(&resp.body).context("metrics body is not UTF-8")?;
        let scrape = crate::obs::prom::parse(text)
            .map_err(|e| anyhow::anyhow!("bad /metrics exposition: {e}"))?;
        self.queue_wait_p50_ms = scrape
            .histogram("esact_classify_queue_wait_seconds")
            .filter(|h| h.count > 0)
            .map(|h| h.quantile(0.5) * 1e3);
        self.execute_p50_ms = scrape
            .histogram("esact_classify_execute_seconds")
            .filter(|h| h.count > 0)
            .map(|h| h.quantile(0.5) * 1e3);
        Ok(())
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.ok as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile_ms(0.50)
    }

    pub fn p99_ms(&self) -> f64 {
        self.percentile_ms(0.99)
    }

    fn percentile_ms(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            stats::percentile(&self.latencies, q) * 1e3
        }
    }

    fn absorb(&mut self, other: LoadReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.latencies.extend(other.latencies);
    }

    fn finish(&mut self, wall: Duration) {
        self.wall = wall;
        self.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
}

/// Closed-loop classify load: `connections` keep-alive connections,
/// each posting back-to-back single-sequence requests round-robin over
/// `pool` until `total` requests have been issued in aggregate.
pub fn closed_loop_classify(
    addr: &str,
    connections: usize,
    total: usize,
    pool: &[Vec<i32>],
) -> Result<LoadReport> {
    assert!(!pool.is_empty());
    let connections = connections.max(1);
    let issued = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    let workers: Vec<_> = (0..connections)
        .map(|_| {
            let issued = Arc::clone(&issued);
            let addr = addr.to_string();
            let pool = pool.to_vec();
            std::thread::spawn(move || -> Result<LoadReport> {
                let mut client =
                    HttpClient::connect_retry(&addr, 20, Duration::from_millis(50))?;
                let mut report = LoadReport::default();
                loop {
                    let i = issued.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let body = classify_body(&[&pool[i % pool.len()][..]]);
                    let t0 = Instant::now();
                    report.sent += 1;
                    match client.post_json("/v1/classify", &body) {
                        Ok(resp) if resp.status == 200 => {
                            report.ok += 1;
                            report.latencies.push(t0.elapsed().as_secs_f64());
                        }
                        Ok(resp) if resp.status == 429 => report.shed += 1,
                        Ok(_) => report.errors += 1,
                        Err(_) => {
                            report.errors += 1;
                            // reconnect once; give up on repeat failure
                            client = HttpClient::connect_retry(
                                &addr,
                                5,
                                Duration::from_millis(50),
                            )?;
                        }
                    }
                }
                Ok(report)
            })
        })
        .collect();
    let mut merged = LoadReport::default();
    for w in workers {
        merged.absorb(w.join().expect("loadgen worker panicked")?);
    }
    merged.finish(start.elapsed());
    Ok(merged)
}

/// Open-loop Poisson classify load at `rate` requests/second: a
/// scheduler thread fires arrivals on the shared
/// `coordinator::loadgen` schedule; `connections` workers post them as
/// they land (queueing delay counts toward latency, as in any
/// open-loop harness).
pub fn poisson_classify(
    addr: &str,
    rate: f64,
    n: usize,
    connections: usize,
    pool: &[Vec<i32>],
    seed: u64,
) -> Result<LoadReport> {
    assert!(!pool.is_empty());
    let mut rng = Xoshiro256pp::new(seed);
    let schedule = arrivals(&mut rng, Arrival::Poisson { rate }, n);
    let (tx, rx) = mpsc::channel::<(usize, Instant)>();
    let rx = Arc::new(Mutex::new(rx));
    let start = Instant::now();
    let scheduler = std::thread::spawn(move || {
        for (i, at) in schedule.into_iter().enumerate() {
            if let Some(wait) = at.0.checked_sub(start.elapsed()) {
                std::thread::sleep(wait);
            }
            if tx.send((i, Instant::now())).is_err() {
                break;
            }
        }
    });
    let workers: Vec<_> = (0..connections.max(1))
        .map(|_| {
            let rx = Arc::clone(&rx);
            let addr = addr.to_string();
            let pool = pool.to_vec();
            std::thread::spawn(move || -> Result<LoadReport> {
                let mut client =
                    HttpClient::connect_retry(&addr, 20, Duration::from_millis(50))?;
                let mut report = LoadReport::default();
                loop {
                    let job = rx.lock().unwrap().recv();
                    let Ok((i, arrived)) = job else { break };
                    let body = classify_body(&[&pool[i % pool.len()][..]]);
                    report.sent += 1;
                    match client.post_json("/v1/classify", &body) {
                        Ok(resp) if resp.status == 200 => {
                            report.ok += 1;
                            report.latencies.push(arrived.elapsed().as_secs_f64());
                        }
                        Ok(resp) if resp.status == 429 => report.shed += 1,
                        Ok(_) => report.errors += 1,
                        Err(_) => {
                            report.errors += 1;
                            client = HttpClient::connect_retry(
                                &addr,
                                5,
                                Duration::from_millis(50),
                            )?;
                        }
                    }
                }
                Ok(report)
            })
        })
        .collect();
    scheduler.join().expect("scheduler panicked");
    let mut merged = LoadReport::default();
    for w in workers {
        merged.absorb(w.join().expect("loadgen worker panicked")?);
    }
    merged.finish(start.elapsed());
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_prefix_bodies_round_robin_prefixes_with_distinct_tails() {
        let prefixes = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let bodies = shared_prefix_bodies(&prefixes, 5, 4, 8, 64, 7);
        assert_eq!(bodies.len(), 5);
        for (i, body) in bodies.iter().enumerate() {
            let want = if i % 2 == 0 { "\"prefix\":[1,2,3]" } else { "\"prefix\":[4,5,6]" };
            assert!(body.contains(want), "session {i} wrong prefix: {body}");
            assert!(body.contains("\"max_new\":8"), "{body}");
        }
        // sessions sharing a prefix still diverge in their tails (the
        // CoW-exercising shape), and replays are deterministic
        assert_ne!(bodies[0], bodies[2]);
        assert_eq!(bodies, shared_prefix_bodies(&prefixes, 5, 4, 8, 64, 7));
    }

    #[test]
    fn generate_body_with_prefix_is_valid_json_with_both_arrays() {
        let body = generate_body_with_prefix(&[1, 2], &[3], 4, Some((2, 0.5, 9)));
        let doc = Json::parse(&body).unwrap();
        assert_eq!(json::to_i32_vec(doc.get("prefix").unwrap()).unwrap(), vec![1, 2]);
        assert_eq!(json::to_i32_vec(doc.get("prompt").unwrap()).unwrap(), vec![3]);
        assert_eq!(doc.get("max_new").unwrap().as_usize(), Some(4));
        assert_eq!(doc.get("top_k").unwrap().as_usize(), Some(2));
    }
}
