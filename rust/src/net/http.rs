//! Incremental HTTP/1.1 codec for the gateway and its client — std
//! only (no hyper/tokio in the vendored crate set, see DESIGN.md
//! §Environment). The request parser is a pull-based state machine fed
//! arbitrary byte slices, so it is robust to requests split across any
//! read boundary and to pipelined requests sharing one read; framing
//! limits (header bytes, declared body size) are enforced *while
//! buffering*, so a hostile peer cannot balloon memory before the
//! request is even complete. Responses are written with
//! `Content-Length` framing, or `Transfer-Encoding: chunked` for the
//! streaming generate endpoint ([`ChunkedWriter`] / [`ChunkDecoder`]).

use std::fmt;
use std::io::{self, Write};

/// Hard cap on request-head bytes (request line + headers). Beyond it
/// the parser fails with 431 before a terminator ever arrives.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Default cap on declared request-body bytes (413 beyond it).
pub const DEFAULT_MAX_BODY: usize = 1 << 20;

/// Parse/framing failures, each mapping onto the HTTP status the
/// gateway answers with before closing the connection.
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, header, or framing → 400.
    Bad(String),
    /// Request head exceeds [`MAX_HEAD_BYTES`] → 431.
    HeadTooLarge,
    /// Declared `Content-Length` exceeds the configured cap → 413.
    BodyTooLarge,
    /// A well-formed version we do not speak → 505.
    Version(String),
    /// Request bodies with `Transfer-Encoding` are not accepted → 501.
    UnsupportedTransfer,
}

impl HttpError {
    pub fn status(&self) -> u16 {
        match self {
            HttpError::Bad(_) => 400,
            HttpError::HeadTooLarge => 431,
            HttpError::BodyTooLarge => 413,
            HttpError::Version(_) => 505,
            HttpError::UnsupportedTransfer => 501,
        }
    }
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::Bad(msg) => write!(f, "malformed request: {msg}"),
            HttpError::HeadTooLarge => write!(f, "request head exceeds {MAX_HEAD_BYTES} bytes"),
            HttpError::BodyTooLarge => write!(f, "request body exceeds the configured cap"),
            HttpError::Version(v) => write!(f, "unsupported HTTP version {v:?}"),
            HttpError::UnsupportedTransfer => {
                write!(f, "Transfer-Encoding request bodies are not supported")
            }
        }
    }
}

impl std::error::Error for HttpError {}

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Raw request target (path plus optional query).
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        header_of(&self.headers, name)
    }

    /// Target with any query string stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// One query parameter's value (`/debug/trace?n=8` → `"8"` for
    /// `n`). First match wins; a bare key yields an empty string.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        let query = self.target.split_once('?')?.1;
        query.split('&').find_map(|pair| {
            let (k, v) = match pair.split_once('=') {
                Some((k, v)) => (k, v),
                None => (pair, ""),
            };
            (k == name).then_some(v)
        })
    }

    /// HTTP/1.1 defaults to keep-alive; `Connection: close` (or
    /// HTTP/1.0 without `keep-alive`) closes after the response.
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        if self.version == "HTTP/1.0" {
            conn.eq_ignore_ascii_case("keep-alive")
        } else {
            !conn.eq_ignore_ascii_case("close")
        }
    }
}

fn header_of<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// A request head parsed and waiting for its body bytes.
struct PendingBody {
    request: Request,
    content_length: usize,
}

/// Incremental request parser: [`push`](RequestParser::push) raw bytes
/// in, [`take`](RequestParser::take) complete requests out. Bytes
/// beyond one request stay buffered for the next `take` (pipelining).
pub struct RequestParser {
    buf: Vec<u8>,
    max_body: usize,
    pending: Option<PendingBody>,
}

impl RequestParser {
    pub fn new(max_body: usize) -> Self {
        Self { buf: Vec::new(), max_body, pending: None }
    }

    /// Feed bytes from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a completed request.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Try to complete one request. `Ok(None)` means more bytes are
    /// needed; an error is terminal for the connection (the framing
    /// state can no longer be trusted).
    pub fn take(&mut self) -> Result<Option<Request>, HttpError> {
        if self.pending.is_none() {
            let Some(head_end) = find_head_end(&self.buf) else {
                if self.buf.len() > MAX_HEAD_BYTES {
                    return Err(HttpError::HeadTooLarge);
                }
                return Ok(None);
            };
            if head_end > MAX_HEAD_BYTES {
                return Err(HttpError::HeadTooLarge);
            }
            let head = std::str::from_utf8(&self.buf[..head_end])
                .map_err(|_| HttpError::Bad("head is not valid UTF-8".to_string()))?;
            let (request, content_length) = parse_head(head, self.max_body)?;
            self.buf.drain(..head_end + 4); // head + CRLFCRLF
            self.pending = Some(PendingBody { request, content_length });
        }
        let need = self.pending.as_ref().map(|p| p.content_length).unwrap_or(0);
        if self.buf.len() < need {
            return Ok(None);
        }
        let mut pending = self.pending.take().expect("checked above");
        pending.request.body = self.buf.drain(..need).collect();
        Ok(Some(pending.request))
    }
}

/// Index of the `\r\n\r\n` head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse a request head (everything before CRLFCRLF) into the request
/// plus its declared body length.
fn parse_head(head: &str, max_body: usize) -> Result<(Request, usize), HttpError> {
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(HttpError::Bad(format!("bad request line {request_line:?}"))),
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::Bad(format!("bad method {method:?}")));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::Version(version.to_string()));
    }
    let mut headers = Vec::new();
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::Bad(format!("header line without ':': {line:?}")));
        };
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(HttpError::Bad(format!("bad header name {name:?}")));
        }
        headers.push((name.to_string(), value.trim().to_string()));
    }
    if header_of(&headers, "transfer-encoding").is_some() {
        return Err(HttpError::UnsupportedTransfer);
    }
    // duplicate Content-Length headers are a request-smuggling vector
    // (RFC 7230 §3.3.2): reject instead of silently picking one
    if headers.iter().filter(|(k, _)| k.eq_ignore_ascii_case("content-length")).count() > 1 {
        return Err(HttpError::Bad("multiple Content-Length headers".to_string()));
    }
    let content_length = match header_of(&headers, "content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::Bad(format!("bad Content-Length {v:?}")))?,
    };
    if content_length > max_body {
        return Err(HttpError::BodyTooLarge);
    }
    let request = Request {
        method: method.to_string(),
        target: target.to_string(),
        version: version.to_string(),
        headers,
        body: Vec::new(),
    };
    Ok((request, content_length))
}

/// Reason phrase for the statuses the gateway emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Render one complete `Content-Length`-framed response into bytes.
///
/// This is the resumable write side: the event-loop gateway appends
/// the rendered frame to a connection's out-buffer and flushes it as
/// the socket allows, instead of blocking a thread in `write_all`.
pub fn render_response(code: u16, headers: &[(&str, &str)], body: &[u8]) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {code} {}\r\nContent-Length: {}\r\n",
        status_text(code),
        body.len()
    )
    .into_bytes();
    for (k, v) in headers {
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(b": ");
        out.extend_from_slice(v.as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body);
    out
}

/// Render the head of a `Transfer-Encoding: chunked` response.
pub fn render_stream_head(code: u16, headers: &[(&str, &str)]) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {code} {}\r\nTransfer-Encoding: chunked\r\n",
        status_text(code)
    )
    .into_bytes();
    for (k, v) in headers {
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(b": ");
        out.extend_from_slice(v.as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out
}

/// Render one data chunk. Empty data renders to nothing — a
/// zero-length chunk is the protocol's end-of-stream marker.
pub fn render_chunk(data: &[u8]) -> Vec<u8> {
    if data.is_empty() {
        return Vec::new();
    }
    let mut out = format!("{:x}\r\n", data.len()).into_bytes();
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
    out
}

/// Render the stream terminator (`0\r\n\r\n`).
pub fn render_final_chunk() -> Vec<u8> {
    b"0\r\n\r\n".to_vec()
}

/// Write one complete `Content-Length`-framed response.
pub fn write_response(
    w: &mut impl Write,
    code: u16,
    headers: &[(&str, &str)],
    body: &[u8],
) -> io::Result<()> {
    w.write_all(&render_response(code, headers, body))?;
    w.flush()
}

/// Streaming response writer: `Transfer-Encoding: chunked`, one flush
/// per chunk so the peer sees tokens as they are produced.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    /// Write the response head and switch to chunked framing.
    pub fn begin(w: &'a mut W, code: u16, headers: &[(&str, &str)]) -> io::Result<Self> {
        w.write_all(&render_stream_head(code, headers))?;
        w.flush()?;
        Ok(Self { w })
    }

    /// Write one data chunk. Empty data is skipped — a zero-length
    /// chunk is the protocol's end-of-stream marker.
    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        self.w.write_all(&render_chunk(data))?;
        self.w.flush()
    }

    /// Terminate the stream (`0\r\n\r\n`).
    pub fn finish(self) -> io::Result<()> {
        self.w.write_all(&render_final_chunk())?;
        self.w.flush()
    }
}

/// One decoded event from a chunked stream.
#[derive(Debug, PartialEq)]
pub enum ChunkEvent {
    /// More bytes are needed.
    Need,
    /// One data chunk.
    Data(Vec<u8>),
    /// The zero-length terminator arrived; the stream is complete.
    End,
}

/// Incremental `Transfer-Encoding: chunked` decoder (client side).
/// Bytes past the terminator stay buffered for the next exchange on a
/// kept-alive connection.
#[derive(Default)]
pub struct ChunkDecoder {
    buf: Vec<u8>,
    done: bool,
}

impl ChunkDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered beyond the decoded stream (valid after `End`).
    pub fn leftover(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }

    /// Decode the next chunk if fully buffered.
    pub fn next_event(&mut self) -> Result<ChunkEvent, HttpError> {
        if self.done {
            return Ok(ChunkEvent::End);
        }
        let Some(line_end) = self.buf.windows(2).position(|w| w == b"\r\n") else {
            if self.buf.len() > 32 {
                return Err(HttpError::Bad("oversized chunk-size line".to_string()));
            }
            return Ok(ChunkEvent::Need);
        };
        let size_line = std::str::from_utf8(&self.buf[..line_end])
            .map_err(|_| HttpError::Bad("chunk-size line is not UTF-8".to_string()))?;
        // chunk extensions (";…") are legal; ignore them
        let size_text = size_line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_text, 16)
            .map_err(|_| HttpError::Bad(format!("bad chunk size {size_text:?}")))?;
        // bound the declared size before trusting it: a hostile peer
        // declaring usize::MAX would overflow the frame arithmetic, and
        // a huge-but-valid size would make us buffer without limit
        if size > DEFAULT_MAX_BODY {
            return Err(HttpError::Bad(format!("chunk size {size} over the cap")));
        }
        let frame = line_end + 2 + size + 2; // size line + data + CRLF
        if self.buf.len() < frame {
            return Ok(ChunkEvent::Need);
        }
        if &self.buf[line_end + 2 + size..frame] != b"\r\n" {
            return Err(HttpError::Bad("chunk data not CRLF-terminated".to_string()));
        }
        let data: Vec<u8> = self.buf[line_end + 2..line_end + 2 + size].to_vec();
        self.buf.drain(..frame);
        if size == 0 {
            self.done = true;
            Ok(ChunkEvent::End)
        } else {
            Ok(ChunkEvent::Data(data))
        }
    }
}

/// A parsed response head (client side).
#[derive(Clone, Debug)]
pub struct ResponseHead {
    pub status: u16,
    pub headers: Vec<(String, String)>,
}

impl ResponseHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        header_of(&self.headers, name)
    }

    pub fn content_length(&self) -> Option<usize> {
        self.header("content-length").and_then(|v| v.parse().ok())
    }

    pub fn is_chunked(&self) -> bool {
        self.header("transfer-encoding")
            .is_some_and(|v| v.eq_ignore_ascii_case("chunked"))
    }
}

/// Parse a response head (status line + headers, no trailing CRLFCRLF).
pub fn parse_response_head(head: &str) -> Result<ResponseHead, HttpError> {
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    let code = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Bad(format!("bad status line {status_line:?}")));
    }
    let status: u16 = code
        .parse()
        .map_err(|_| HttpError::Bad(format!("bad status code {code:?}")))?;
    let mut headers = Vec::new();
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::Bad(format!("header line without ':': {line:?}")));
        };
        headers.push((name.to_string(), value.trim().to_string()));
    }
    Ok(ResponseHead { status, headers })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(chunks: &[&[u8]], max_body: usize) -> Result<Vec<Request>, HttpError> {
        let mut p = RequestParser::new(max_body);
        let mut out = Vec::new();
        for c in chunks {
            p.push(c);
            while let Some(r) = p.take()? {
                out.push(r);
            }
        }
        Ok(out)
    }

    const POST: &[u8] =
        b"POST /v1/classify HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"a\":[1]}";

    #[test]
    fn parses_a_complete_request() {
        let reqs = parse_all(&[POST], DEFAULT_MAX_BODY).unwrap();
        assert_eq!(reqs.len(), 1);
        let r = &reqs[0];
        assert_eq!(r.method, "POST");
        assert_eq!(r.path(), "/v1/classify");
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.header("HOST"), Some("x"), "header lookup is case-insensitive");
        assert_eq!(r.body, b"{\"a\":[1]}");
        assert!(r.keep_alive(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn every_split_point_yields_the_same_request() {
        // the partial-read property: feeding the same bytes split at
        // every boundary (including mid-request-line, mid-header,
        // mid-body) must parse identically
        let whole = parse_all(&[POST], DEFAULT_MAX_BODY).unwrap();
        for cut in 1..POST.len() {
            let (a, b) = POST.split_at(cut);
            let split = parse_all(&[a, b], DEFAULT_MAX_BODY)
                .unwrap_or_else(|e| panic!("split at {cut}: {e}"));
            assert_eq!(split.len(), 1, "split at {cut}");
            assert_eq!(split[0].body, whole[0].body, "split at {cut}");
            assert_eq!(split[0].target, whole[0].target);
        }
        // and byte-at-a-time
        let bytes: Vec<&[u8]> = POST.chunks(1).collect();
        let trickled = parse_all(&bytes, DEFAULT_MAX_BODY).unwrap();
        assert_eq!(trickled[0].body, whole[0].body);
    }

    #[test]
    fn query_params_parse_from_the_target() {
        let reqs = parse_all(
            &[b"GET /debug/trace?n=8&lane=classify&raw HTTP/1.1\r\n\r\n"],
            DEFAULT_MAX_BODY,
        )
        .unwrap();
        let r = &reqs[0];
        assert_eq!(r.path(), "/debug/trace");
        assert_eq!(r.query_param("n"), Some("8"));
        assert_eq!(r.query_param("lane"), Some("classify"));
        assert_eq!(r.query_param("raw"), Some(""), "bare key yields empty value");
        assert_eq!(r.query_param("missing"), None);

        let no_query = parse_all(&[b"GET /metrics HTTP/1.1\r\n\r\n"], DEFAULT_MAX_BODY).unwrap();
        assert_eq!(no_query[0].query_param("n"), None);
    }

    #[test]
    fn pipelined_requests_parse_in_order() {
        let two = [
            b"GET /healthz HTTP/1.1\r\n\r\n".as_slice(),
            b"POST /x HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /metrics HTTP/1.1\r\n\r\n"
                .as_slice(),
        ]
        .concat();
        let reqs = parse_all(&[&two], DEFAULT_MAX_BODY).unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].path(), "/healthz");
        assert_eq!(reqs[1].body, b"hi");
        assert_eq!(reqs[2].path(), "/metrics");
    }

    #[test]
    fn missing_content_length_means_empty_body() {
        let reqs =
            parse_all(&[b"POST /v1/classify HTTP/1.1\r\n\r\n"], DEFAULT_MAX_BODY).unwrap();
        assert_eq!(reqs[0].body, b"");
    }

    #[test]
    fn oversized_declared_body_is_413() {
        let req = b"POST /x HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        let err = parse_all(&[req], 100).unwrap_err();
        assert_eq!(err.status(), 413);
    }

    #[test]
    fn bad_content_length_is_400() {
        for bad in ["abc", "-1", "1e3", "18446744073709551616"] {
            let req = format!("POST /x HTTP/1.1\r\nContent-Length: {bad}\r\n\r\n");
            let err = parse_all(&[req.as_bytes()], DEFAULT_MAX_BODY).unwrap_err();
            assert_eq!(err.status(), 400, "Content-Length {bad:?}");
        }
    }

    #[test]
    fn duplicate_content_length_is_rejected_not_desynced() {
        // picking either value would let body bytes be reparsed as a
        // smuggled second request; the only safe answer is 400
        let req = b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 100\r\n\r\n";
        let err = parse_all(&[req], DEFAULT_MAX_BODY).unwrap_err();
        assert_eq!(err.status(), 400);
    }

    #[test]
    fn oversized_head_fails_before_terminator_arrives() {
        // no CRLFCRLF ever sent: the parser must fail at the cap, not
        // buffer forever
        let mut p = RequestParser::new(DEFAULT_MAX_BODY);
        p.push(b"GET /x HTTP/1.1\r\nX: ");
        let filler = vec![b'a'; MAX_HEAD_BYTES + 64];
        p.push(&filler);
        assert_eq!(p.take().unwrap_err().status(), 431);
    }

    #[test]
    fn malformed_heads_are_400_or_505() {
        for (bad, want) in [
            ("GARBAGE\r\n\r\n", 400),
            ("GET /x\r\n\r\n", 400),
            ("GET /x HTTP/1.1 extra\r\n\r\n", 400),
            ("get /x HTTP/1.1\r\n\r\n", 400),
            ("GET /x HTTP/2.0\r\n\r\n", 505),
            ("GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n", 400),
            ("GET /x HTTP/1.1\r\nbad name: v\r\n\r\n", 400),
            ("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
        ] {
            let err = parse_all(&[bad.as_bytes()], DEFAULT_MAX_BODY).unwrap_err();
            assert_eq!(err.status(), want, "{bad:?}");
        }
    }

    #[test]
    fn non_utf8_head_is_400_but_binary_bodies_are_fine() {
        let err =
            parse_all(&[b"GET /\xff\xfe HTTP/1.1\r\n\r\n"], DEFAULT_MAX_BODY).unwrap_err();
        assert_eq!(err.status(), 400);
        // bodies are raw bytes; UTF-8 validation is the route handler's
        // concern (it answers 400 without panicking)
        let reqs = parse_all(
            &[b"POST /x HTTP/1.1\r\nContent-Length: 2\r\n\r\n\xff\xfe"],
            DEFAULT_MAX_BODY,
        )
        .unwrap();
        assert_eq!(reqs[0].body, b"\xff\xfe");
    }

    #[test]
    fn connection_close_and_http10_semantics() {
        let reqs = parse_all(
            &[b"GET /x HTTP/1.1\r\nConnection: close\r\n\r\nGET /y HTTP/1.0\r\n\r\n"],
            DEFAULT_MAX_BODY,
        )
        .unwrap();
        assert!(!reqs[0].keep_alive());
        assert!(!reqs[1].keep_alive(), "HTTP/1.0 defaults to close");
    }

    #[test]
    fn response_writer_emits_exact_framing() {
        let mut out = Vec::new();
        write_response(&mut out, 429, &[("Retry-After", "1")], b"slow down").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Length: 9\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.ends_with("\r\n\r\nslow down"));
    }

    #[test]
    fn render_helpers_match_the_blocking_writers_byte_for_byte() {
        let headers = [("Content-Type", "application/json"), ("Retry-After", "1")];
        let mut wrote = Vec::new();
        write_response(&mut wrote, 429, &headers, b"{\"x\":1}").unwrap();
        assert_eq!(wrote, render_response(429, &headers, b"{\"x\":1}"));

        let mut stream = Vec::new();
        {
            let mut w = ChunkedWriter::begin(&mut stream, 200, &[("X", "y")]).unwrap();
            w.chunk(b"abc").unwrap();
            w.finish().unwrap();
        }
        let mut rendered = render_stream_head(200, &[("X", "y")]);
        rendered.extend_from_slice(&render_chunk(b"abc"));
        rendered.extend_from_slice(&render_final_chunk());
        assert_eq!(stream, rendered);
        assert!(render_chunk(b"").is_empty(), "empty chunk is not a frame");
    }

    #[test]
    fn chunked_writer_and_decoder_round_trip() {
        let mut wire = Vec::new();
        {
            let mut w = ChunkedWriter::begin(&mut wire, 200, &[("X", "y")]).unwrap();
            w.chunk(b"hello ").unwrap();
            w.chunk(b"").unwrap(); // skipped, not a terminator
            w.chunk(b"world").unwrap();
            w.finish().unwrap();
        }
        let text = String::from_utf8(wire.clone()).unwrap();
        let head_end = text.find("\r\n\r\n").unwrap();
        let head = parse_response_head(&text[..head_end]).unwrap();
        assert_eq!(head.status, 200);
        assert!(head.is_chunked());
        let body = &wire[head_end + 4..];
        // decode byte-at-a-time: boundary robustness on the read side
        let mut dec = ChunkDecoder::new();
        let mut data = Vec::new();
        let mut ended = false;
        for b in body {
            dec.push(&[*b]);
            loop {
                match dec.next_event().unwrap() {
                    ChunkEvent::Need => break,
                    ChunkEvent::Data(d) => data.extend_from_slice(&d),
                    ChunkEvent::End => {
                        ended = true;
                        break;
                    }
                }
            }
        }
        assert!(ended);
        assert_eq!(data, b"hello world");
    }

    #[test]
    fn chunk_decoder_rejects_garbage_sizes() {
        let mut dec = ChunkDecoder::new();
        dec.push(b"zz\r\nxx\r\n");
        assert!(dec.next_event().is_err());
        let mut dec = ChunkDecoder::new();
        dec.push(b"5\r\nhelloXX"); // missing CRLF after data
        assert!(dec.next_event().is_err());
        // declared sizes near usize::MAX must error, not overflow the
        // frame arithmetic; huge-but-valid sizes must not buffer forever
        let mut dec = ChunkDecoder::new();
        dec.push(b"ffffffffffffffff\r\n");
        assert!(dec.next_event().is_err());
        let mut dec = ChunkDecoder::new();
        dec.push(b"10000000000\r\n"); // 2^40: over the cap
        assert!(dec.next_event().is_err());
    }

    #[test]
    fn response_head_parses_status_and_headers() {
        let h = parse_response_head("HTTP/1.1 503 Service Unavailable\r\nRetry-After: 1")
            .unwrap();
        assert_eq!(h.status, 503);
        assert_eq!(h.header("retry-after"), Some("1"));
        assert!(parse_response_head("NOPE").is_err());
    }
}
