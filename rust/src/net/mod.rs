//! L4 network frontend: a dependency-free HTTP/1.1 gateway that puts
//! the replicated serving coordinator on a socket, plus the matching
//! blocking client and HTTP load generator. Everything here is std-only
//! (TcpListener/TcpStream + threads + raw epoll FFI) so the default
//! build stays hermetic — no tokio, hyper, or serde (DESIGN.md
//! §Network gateway).
//!
//! * [`http`] — incremental request parser (partial-read/pipelining
//!   safe, bounded heads and bodies), response renderers/writers,
//!   chunked codec.
//! * [`json`] — minimal JSON with bit-exact f32 transport (the
//!   loopback parity tests ride on it).
//! * [`poll`] — std-only `epoll(7)` + `eventfd(2)` readiness layer the
//!   event loop parks in (Linux; level-triggered).
//! * [`conn`] — sans-io per-connection state machine
//!   (Reading → Dispatched → Writing → KeepAlive/Closing) feeding the
//!   [`http`] parser; unit-tested with scripted partial reads/writes.
//! * [`gateway`] — the event-loop gateway: one thread, thousands of
//!   sockets, the four routes over the coordinator's `TierHandle`,
//!   admission-bound 429 backpressure, unified error envelope,
//!   graceful drain.
//! * [`client`] — keep-alive client, streaming consumer, closed-loop
//!   and Poisson HTTP loadgen reusing `coordinator::loadgen` schedules.

pub mod client;
pub mod conn;
pub mod gateway;
pub mod http;
pub mod json;
pub mod poll;

pub use client::{
    ErrorEnvelope, GenLoadReport, HttpClient, IdleConns, LoadReport, StreamResult,
};
pub use gateway::{
    Gateway, GatewayConfig, GatewayConfigBuilder, GatewayReport, ShutdownHandle,
};
