//! L4 network frontend: a dependency-free HTTP/1.1 gateway that puts
//! the replicated serving coordinator on a socket, plus the matching
//! blocking client and HTTP load generator. Everything here is std-only
//! (TcpListener/TcpStream + threads) so the default build stays
//! hermetic — no tokio, hyper, or serde (DESIGN.md §Network gateway).
//!
//! * [`http`] — incremental request parser (partial-read/pipelining
//!   safe, bounded heads and bodies), response writers, chunked codec.
//! * [`json`] — minimal JSON with bit-exact f32 transport (the
//!   loopback parity tests ride on it).
//! * [`gateway`] — accept loop, bounded connection pool, the four
//!   routes over `Server::serve_replicated`/`serve_generate`,
//!   admission-bound 429 backpressure, graceful drain.
//! * [`client`] — keep-alive client, streaming consumer, closed-loop
//!   and Poisson HTTP loadgen reusing `coordinator::loadgen` schedules.

pub mod client;
pub mod gateway;
pub mod http;
pub mod json;

pub use client::{HttpClient, LoadReport, StreamResult};
pub use gateway::{Gateway, GatewayConfig, GatewayReport, ShutdownHandle};
