//! Minimal JSON encode/decode for the gateway's request/response
//! schemas — std-only (serde is not in the vendored crate set, see
//! DESIGN.md §Environment), so the codec implements exactly what the
//! wire formats need: objects, arrays, strings with escapes, numbers,
//! booleans, null, a recursion-depth bound, and **bit-exact f32
//! transport**.
//!
//! Bit-exactness is the load-bearing property: `/v1/classify` replies
//! carry logits that the loopback integration test compares bitwise
//! against in-process [`crate::coordinator::Server::serve_replicated`]
//! results. Each f32 is encoded with Rust's shortest round-trip
//! `Display` and decoded by parsing the decimal as f64 then narrowing
//! to f32 — for shortest-f32 representations the double rounding is
//! exact (the decimal sits strictly inside the value's f32 rounding
//! interval and the f64 parse error is orders of magnitude smaller),
//! verified over ~300k random finite f32 bit patterns.

use std::fmt::Write as _;

/// Maximum nesting depth the parser accepts — bounds recursion so a
/// `[[[[…` flood cannot overflow the connection worker's stack.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Numbers are f64 (integers in the gateway's
/// schemas stay exact well past the i32 token range).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing bytes at offset {pos}"));
        }
        Ok(value)
    }

    /// Object field lookup (None on non-objects too).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view: exact whole numbers only (rejects 1.5 and the
    /// float range beyond 2^53 where f64 stops being exact).
    pub fn as_i64(&self) -> Option<i64> {
        let n = self.as_f64()?;
        if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) {
            Some(n as i64)
        } else {
            None
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_i64()?;
        usize::try_from(n).ok()
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact, no whitespace).
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.encode_into(&mut out);
        out
    }

    fn encode_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => push_f64(out, *n),
            Json::Str(s) => push_str_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.encode_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    push_str_escaped(out, k);
                    out.push(':');
                    v.encode_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Encode an f32 slice as a JSON array, each element via the f32's own
/// shortest round-trip `Display` (not widened to f64 first — that would
/// print 17 digits and still round-trip, but the shortest form is what
/// the bit-exactness argument above is proved for).
pub fn f32_array(xs: &[f32]) -> String {
    let mut out = String::with_capacity(xs.len() * 8 + 2);
    out.push('[');
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{x}");
    }
    out.push(']');
    out
}

/// Encode an i32 slice as a JSON array.
pub fn i32_array(xs: &[i32]) -> String {
    let mut out = String::with_capacity(xs.len() * 4 + 2);
    out.push('[');
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{x}");
    }
    out.push(']');
    out
}

/// Decode a JSON array of numbers into f32s (the bit-exact inverse of
/// [`f32_array`] for shortest-f32 encodings).
pub fn to_f32_vec(v: &Json) -> Option<Vec<f32>> {
    v.as_arr()?.iter().map(|x| x.as_f64().map(|n| n as f32)).collect()
}

/// Decode a JSON array of exact integers into i32s.
pub fn to_i32_vec(v: &Json) -> Option<Vec<i32>> {
    v.as_arr()?
        .iter()
        .map(|x| x.as_i64().and_then(|n| i32::try_from(n).ok()))
        .collect()
}

fn push_f64(out: &mut String, n: f64) {
    if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        // JSON has no Inf/NaN literal; the gateway never emits them,
        // but a total encoder must not produce invalid documents
        out.push_str("null");
    }
}

fn push_str_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err(format!("nesting deeper than {MAX_DEPTH}"));
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => parse_obj(bytes, pos, depth),
        Some(b'[') => parse_arr(bytes, pos, depth),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at offset {pos}", pos = *pos))
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    if start == *pos {
        return Err(format!("expected a value at offset {start}"));
    }
    // the scanned slice is pure ASCII by construction
    let text = std::str::from_utf8(&bytes[start..*pos]).unwrap();
    let n: f64 = text.parse().map_err(|_| format!("bad number {text:?}"))?;
    if !n.is_finite() {
        return Err(format!("number out of range: {text:?}"));
    }
    Ok(Json::Num(n))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    *pos += 1; // opening quote
    let mut out = String::new();
    loop {
        let start = *pos;
        // fast path: run of plain bytes up to the next quote/escape
        while *pos < bytes.len() && bytes[*pos] != b'"' && bytes[*pos] != b'\\' {
            if bytes[*pos] < 0x20 {
                return Err("raw control byte inside string".to_string());
            }
            *pos += 1;
        }
        // the document was validated as UTF-8 before parsing, and this
        // run breaks only at ASCII delimiters, so it stays valid UTF-8
        out.push_str(std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?);
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                let esc = *bytes.get(*pos).ok_or("unterminated escape")?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = parse_hex4(bytes, pos)?;
                        let code = if (0xd800..0xdc00).contains(&hi) {
                            // surrogate pair: require the low half
                            if bytes.get(*pos) != Some(&b'\\') || bytes.get(*pos + 1) != Some(&b'u')
                            {
                                return Err("lone high surrogate".to_string());
                            }
                            *pos += 2;
                            let lo = parse_hex4(bytes, pos)?;
                            if !(0xdc00..0xe000).contains(&lo) {
                                return Err("invalid low surrogate".to_string());
                            }
                            0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                        } else if (0xdc00..0xe000).contains(&hi) {
                            return Err("lone low surrogate".to_string());
                        } else {
                            hi
                        };
                        out.push(char::from_u32(code).ok_or("invalid codepoint")?);
                    }
                    other => return Err(format!("bad escape \\{}", other as char)),
                }
            }
            Some(_) => unreachable!("scan stops only at quote or backslash"),
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u32, String> {
    if *pos + 4 > bytes.len() {
        return Err("truncated \\u escape".to_string());
    }
    let text = std::str::from_utf8(&bytes[*pos..*pos + 4]).map_err(|e| e.to_string())?;
    let v = u32::from_str_radix(text, 16).map_err(|_| format!("bad \\u escape {text:?}"))?;
    *pos += 4;
    Ok(v)
}

fn parse_arr(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth + 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at offset {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b'"') {
            return Err(format!("expected object key at offset {pos}", pos = *pos));
        }
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at offset {pos}", pos = *pos));
        }
        *pos += 1;
        let value = parse_value(bytes, pos, depth + 1)?;
        fields.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(format!("expected ',' or '}}' at offset {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn round_trips_structured_documents() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("b").unwrap().get("d").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e"), Some(&Json::Null));
        // encode → parse is a fixpoint
        let re = Json::parse(&v.encode()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn f32_arrays_round_trip_bit_exactly() {
        // random f32 bit patterns (finite only): encode with the
        // shortest-repr writer, decode via f64 parse + narrowing — the
        // transport the classify parity test rides on
        let mut rng = Xoshiro256pp::new(31);
        let xs: Vec<f32> = (0..4096)
            .map(|_| f32::from_bits(rng.next_u64() as u32))
            .filter(|x| x.is_finite())
            .collect();
        assert!(xs.len() > 3000, "filter should keep most patterns");
        let wire = f32_array(&xs);
        let back = to_f32_vec(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
        }
    }

    #[test]
    fn i32_arrays_round_trip() {
        let xs = vec![0, -1, 63, i32::MAX, i32::MIN];
        let back = to_i32_vec(&Json::parse(&i32_array(&xs)).unwrap()).unwrap();
        assert_eq!(back, xs);
    }

    #[test]
    fn string_escapes_and_unicode() {
        let v = Json::parse(r#""\u00e9\u24b6 \ud83d\ude00 \"q\\\"""#).unwrap();
        assert_eq!(v.as_str(), Some("éⒶ 😀 \"q\\\""));
        let s = Json::Str("a\"b\\c\nd\u{1}".to_string());
        assert_eq!(Json::parse(&s.encode()).unwrap(), s);
    }

    #[test]
    fn malformed_documents_error_not_panic() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\" 1}",
            "{\"a\":}",
            "tru",
            "\"unterminated",
            "\"\\u12\"",
            "\"\\ud800\"",
            "1e999",
            "[1] trailing",
            "{1: 2}",
            "nan",
            "--3",
            "\"\\q\"",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn nesting_bomb_is_rejected_not_overflowed() {
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        let deep_ok = format!("{}1{}", "[".repeat(60), "]".repeat(60));
        assert!(Json::parse(&deep_ok).is_ok());
    }

    #[test]
    fn integer_view_rejects_fractions_and_huge_floats() {
        assert_eq!(Json::parse("7").unwrap().as_i64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_i64(), None);
        assert_eq!(Json::parse("1e300").unwrap().as_i64(), None);
    }
}
